//! End-to-end integration: the engine must produce golden-correct results
//! under every scheduler, and co-execution must agree bit-for-bit with a
//! single-device run (same executables, disjoint ranges).
//!
//! These tests need `make artifacts` to have run.

use enginecl::coordinator::{DeviceSpec, Engine, Program, SchedulerKind};
use enginecl::platform::NodeConfig;
use enginecl::runtime::{
    host::{max_abs_rel_err, merge_ranges},
    ArtifactRegistry, ChunkExecutor, HostBuf,
};

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("run `make artifacts` before cargo test")
}

/// Build an engine with golden inputs for `bench`, fast-sim profile
/// (no init sleeps — keep tests quick, but keep speed stretching so
/// scheduling behaves heterogeneously).
fn engine_for(reg: &ArtifactRegistry, bench: &str, devices: Vec<DeviceSpec>) -> Engine {
    let manifest = reg.bench(bench).unwrap().clone();
    let mut engine = Engine::with_registry(reg.clone());
    engine.node(NodeConfig::batel());
    engine.use_devices(devices);
    engine.configurator().simulate_init = false;
    let mut program = Program::new();
    program.kernel(bench, &manifest.kernel);
    for buf in reg.golden_inputs(&manifest).unwrap() {
        program.input(buf.as_f32().unwrap().to_vec());
    }
    for out in &manifest.outputs {
        program.output(out.elems);
    }
    engine.program(program);
    engine
}

fn check_against_golden(reg: &ArtifactRegistry, bench: &str, engine: &Engine, tol: f64) {
    let manifest = reg.bench(bench).unwrap();
    let golden = reg.golden_outputs(manifest).unwrap();
    for (i, g) in golden.iter().enumerate() {
        let got = engine.output(i).unwrap();
        if bench.starts_with("ray") || bench == "mandelbrot" {
            let (ok, stat) = enginecl::runtime::host::golden_close(bench, got, g.as_f32().unwrap());
            assert!(ok, "{bench} output {i}: mismatch fraction {stat:.4}");
        } else {
            let (abs, rel) = max_abs_rel_err(got, g.as_f32().unwrap());
            assert!(
                rel < tol || abs < tol,
                "{bench} output {i}: max abs {abs:.3e}, rel {rel:.3e} (tol {tol:.0e})"
            );
        }
    }
}

fn all_devices() -> Vec<DeviceSpec> {
    (0..3).map(DeviceSpec::new).collect()
}

// ---- single device vs golden ---------------------------------------

#[test]
fn binomial_single_device_matches_golden() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(1)]);
    e.run().unwrap();
    check_against_golden(&reg, "binomial", &e, 1e-3);
}

#[test]
fn nbody_single_device_matches_golden() {
    let reg = registry();
    let mut e = engine_for(&reg, "nbody", vec![DeviceSpec::new(1)]);
    e.run().unwrap();
    check_against_golden(&reg, "nbody", &e, 2e-3);
}

#[test]
fn gaussian_single_device_matches_golden() {
    let reg = registry();
    let mut e = engine_for(&reg, "gaussian", vec![DeviceSpec::new(0)]);
    e.run().unwrap();
    check_against_golden(&reg, "gaussian", &e, 1e-3);
}

// ---- co-execution under every scheduler vs golden -------------------

fn coexec_matches_golden(bench: &str, kind: SchedulerKind, tol: f64) {
    let reg = registry();
    let mut e = engine_for(&reg, bench, all_devices());
    e.scheduler(kind);
    e.run().unwrap();
    check_against_golden(&reg, bench, &e, tol);
    let report = e.report().unwrap();
    assert_eq!(report.gws, reg.bench(bench).unwrap().n);
    // Every device that reports packages must have computed something.
    let items: usize = report.devices.iter().map(|d| d.items()).sum();
    assert_eq!(items, report.gws, "all work items computed exactly once");
}

#[test]
fn binomial_coexec_static() {
    coexec_matches_golden("binomial", SchedulerKind::static_default(), 1e-3);
}

#[test]
fn binomial_coexec_dynamic() {
    coexec_matches_golden("binomial", SchedulerKind::dynamic(50), 1e-3);
}

#[test]
fn binomial_coexec_hguided() {
    coexec_matches_golden("binomial", SchedulerKind::hguided(), 1e-3);
}

#[test]
fn mandelbrot_coexec_hguided() {
    // Iteration counts are integers; escape-boundary pixels may flip by
    // one iteration vs the jnp oracle, so compare with atol ~1.
    let reg = registry();
    let mut e = engine_for(&reg, "mandelbrot", all_devices());
    e.scheduler(SchedulerKind::hguided());
    e.run().unwrap();
    let golden = reg.golden_outputs(reg.bench("mandelbrot").unwrap()).unwrap();
    let got = e.output(0).unwrap();
    let want = golden[0].as_f32().unwrap();
    let mismatched = got
        .iter()
        .zip(want)
        .filter(|(a, b)| (**a - **b).abs() > 1.0)
        .count();
    assert!(
        (mismatched as f64) < 0.005 * want.len() as f64,
        "{mismatched} mandelbrot pixels differ by >1 iteration"
    );
}

#[test]
fn ray_scenes_coexec_dynamic() {
    for bench in ["ray1", "ray2", "ray3"] {
        let reg = registry();
        let mut e = engine_for(&reg, bench, all_devices());
        e.scheduler(SchedulerKind::dynamic(50));
        e.run().unwrap();
        check_against_golden(&reg, bench, &e, 2e-3);
    }
}

#[test]
fn nbody_coexec_static_rev() {
    coexec_matches_golden(
        "nbody",
        SchedulerKind::Static { props: None, reversed: true },
        2e-3,
    );
}

// ---- co-execution == single device, bitwise -------------------------

#[test]
fn coexec_equals_single_device_bitwise() {
    let reg = registry();
    let mut solo = engine_for(&reg, "binomial", vec![DeviceSpec::new(1)]);
    solo.run().unwrap();
    let want = solo.output(0).unwrap().to_vec();

    for kind in [
        SchedulerKind::static_default(),
        SchedulerKind::dynamic(37),
        SchedulerKind::hguided(),
    ] {
        let mut co = engine_for(&reg, "binomial", all_devices());
        co.scheduler(kind.clone());
        co.run().unwrap();
        assert_eq!(
            co.output(0).unwrap(),
            &want[..],
            "scheduler {} changed results",
            kind.label()
        );
    }
}

// ---- pipelined co-execution ------------------------------------------

/// The tentpole invariant: enabling the transfer/compute pipeline must
/// not change a single output bit, under every base scheduler.
#[test]
fn pipelined_outputs_bit_identical_to_blocking() {
    let reg = registry();
    for kind in [
        SchedulerKind::static_default(),
        SchedulerKind::dynamic(16),
        SchedulerKind::hguided(),
    ] {
        let mut blocking = engine_for(&reg, "binomial", all_devices());
        blocking.scheduler(kind.clone());
        blocking.pipeline(1);
        blocking.run().unwrap();
        let want = blocking.output(0).unwrap().to_vec();

        let mut piped = engine_for(&reg, "binomial", all_devices());
        piped.scheduler(kind.clone());
        piped.pipeline(2);
        piped.run().unwrap();
        assert_eq!(
            piped.output(0).unwrap(),
            &want[..],
            "pipelining changed results under {}",
            kind.label()
        );
        let report = piped.report().unwrap();
        let items: usize = report.devices.iter().map(|d| d.items()).sum();
        assert_eq!(items, report.gws, "all work items computed exactly once");
        assert!(report.scheduler.contains("+pipe"), "report labels the pipeline");
    }
}

/// The `+pipe` scheduler-spec path (what the CLI uses) must behave like
/// the Tier-1 `Engine::pipeline` call and still match the golden oracle.
#[test]
fn pipe_suffix_spec_matches_golden() {
    let reg = registry();
    let kind = enginecl::coordinator::scheduler::parse_kind("hguided+pipe").unwrap();
    let mut e = engine_for(&reg, "mandelbrot", all_devices());
    e.scheduler(kind);
    e.run().unwrap();
    check_against_golden(&reg, "mandelbrot", &e, 1e-3);
    assert_eq!(e.report().unwrap().scheduler, "HGuided+pipe");
}

/// The overlap must be visible in the introspector: with pipelining on,
/// at least one package's H2D staging span sits inside another package's
/// compute window on the same device; with pipelining off, none do.
#[test]
fn pipelined_traces_show_transfer_compute_overlap() {
    let reg = registry();
    let mut piped = engine_for(&reg, "binomial", vec![DeviceSpec::new(1)]);
    piped.scheduler(SchedulerKind::dynamic(8));
    piped.pipeline(2);
    piped.run().unwrap();
    let report = piped.report().unwrap();
    assert!(
        report.has_transfer_overlap(),
        "no overlapped transfer in pipelined traces:\n{}",
        report.package_csv()
    );

    let mut blocking = engine_for(&reg, "binomial", vec![DeviceSpec::new(1)]);
    blocking.scheduler(SchedulerKind::dynamic(8));
    blocking.run().unwrap();
    assert_eq!(
        blocking.report().unwrap().transfer_overlap_count(),
        0,
        "blocking run must not report overlap"
    );
}

/// The result merge must not depend on the optional introspection
/// traces: with `introspect` off the outputs still come back complete
/// (regression test for the trace-driven merge coupling).
#[test]
fn outputs_merge_with_introspection_disabled() {
    let reg = registry();
    for depth in [1usize, 2] {
        let mut e = engine_for(&reg, "binomial", all_devices());
        e.scheduler(SchedulerKind::dynamic(8));
        e.pipeline(depth);
        e.configurator().introspect = false;
        e.run().unwrap();
        check_against_golden(&reg, "binomial", &e, 1e-3);
        assert_eq!(
            e.report().unwrap().total_packages(),
            0,
            "no traces collected with introspection off"
        );
    }
}

/// Deeper pipelines are valid up to the engine bound and keep results
/// correct on an adaptive scheduler.
#[test]
fn deep_pipeline_matches_golden() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", all_devices());
    e.scheduler(SchedulerKind::dynamic(16));
    e.pipeline(4);
    e.run().unwrap();
    check_against_golden(&reg, "binomial", &e, 1e-3);
}

// ---- zero-copy arena vs the seed merge path --------------------------

/// The tentpole memory invariant: the arena path (workers writing
/// directly into disjoint windows of the final buffers) must be
/// bit-identical to the seed's copy-then-merge path, for every native
/// kernel and scheduler spec including `+pipe`.
///
/// The seed-path oracle is reconstructed explicitly: one executor
/// computes the full problem into full-size buffers (bit-identical to
/// any chunked computation — the kernels are per-item deterministic),
/// then `merge_ranges` scatters exactly the item-ranges each device
/// reported into a fresh destination, which is what the seed engine did
/// with each worker's private full-size outputs.
#[test]
fn arena_outputs_bit_identical_to_seed_merge_path() {
    let reg = registry();
    let kinds = [
        SchedulerKind::static_default(),
        SchedulerKind::Static { props: None, reversed: true },
        SchedulerKind::dynamic(16),
        SchedulerKind::hguided(),
        SchedulerKind::dynamic(16).pipelined(2),
        SchedulerKind::hguided().pipelined(2),
    ];
    for bench in ["binomial", "gaussian", "mandelbrot", "nbody", "ray1"] {
        let manifest = reg.bench(bench).unwrap().clone();
        let inputs = reg.golden_inputs(&manifest).unwrap();
        let mut oracle = ChunkExecutor::new(&reg, &manifest, &inputs).unwrap();
        let mut full: Vec<HostBuf> =
            manifest.outputs.iter().map(|o| HostBuf::zeros_f32(o.elems)).collect();
        oracle.execute_range(0, manifest.n, &mut full).unwrap();

        for kind in &kinds {
            let mut e = engine_for(&reg, bench, all_devices());
            e.scheduler(kind.clone());
            e.configurator().simulate_speed = false;
            e.run().unwrap();
            let report = e.report().unwrap().clone();
            for (i, (spec, src)) in manifest.outputs.iter().zip(&full).enumerate() {
                let mut merged = vec![0.0f32; spec.elems];
                for d in &report.devices {
                    let ranges: Vec<(usize, usize)> =
                        d.packages.iter().map(|p| (p.begin_item, p.end_item)).collect();
                    merge_ranges(
                        &mut merged,
                        src.as_f32().unwrap(),
                        &ranges,
                        spec.elems_per_item,
                    );
                }
                assert_eq!(
                    e.output(i).unwrap(),
                    &merged[..],
                    "{bench}/{}: arena output {i} differs from the seed merge path",
                    kind.label()
                );
            }
        }
    }
}

/// The acceptance counters: with the default (resident) config, a run
/// uploads zero input bytes (shared views), stages only per-launch
/// offsets, and moves zero d2h bytes (in-place arena writes) — O(N)
/// host allocations per run instead of the seed's O(devices × N). The
/// §5.2 re-upload ablation stages windows that stay linear in N.
///
/// Native-backend-only: the PJRT backend pays real per-device uploads
/// (and per-launch literal re-uploads in ablation mode), so its byte
/// counters are legitimately nonzero.
#[cfg(not(feature = "pjrt"))]
#[test]
fn zero_copy_counters_show_o_n_not_o_devices_n() {
    let reg = registry();
    let manifest = reg.bench("gaussian").unwrap().clone();
    let total_input_bytes: usize = manifest.inputs.iter().map(|b| 4 * b.elems).sum();

    let mut e = engine_for(&reg, "gaussian", all_devices());
    e.scheduler(SchedulerKind::dynamic(8));
    e.configurator().simulate_speed = false;
    e.run().unwrap();
    let r = e.report().unwrap();
    assert_eq!(r.input_upload_bytes(), 0, "workers must share the engine's input views");
    assert_eq!(r.d2h_bytes(), 0, "results must be written in place through the arena");
    assert!(
        r.h2d_bytes() < total_input_bytes / 8,
        "resident staging must be offsets-only, not input copies: {} bytes",
        r.h2d_bytes()
    );

    let mut e2 = engine_for(&reg, "gaussian", all_devices());
    e2.scheduler(SchedulerKind::dynamic(8));
    e2.configurator().simulate_speed = false;
    e2.configurator().resident_inputs = false;
    e2.run().unwrap();
    let r2 = e2.report().unwrap();
    assert!(r2.h2d_bytes() > 0, "re-upload ablation must stage real input bytes");
    assert!(
        r2.h2d_bytes() <= total_input_bytes + 4 * 1024,
        "per-launch window staging must stay linear in N: {} bytes for {} input bytes",
        r2.h2d_bytes(),
        total_input_bytes
    );
    assert_eq!(e.output(0).unwrap(), e2.output(0).unwrap(), "ablation changes cost, not results");
}

/// With the global exec lock gone, device compute windows genuinely
/// overlap in wall time (raw config, one static package per device).
/// Skipped on single-core hosts, where nothing can physically overlap.
#[test]
fn devices_compute_in_parallel_without_exec_lock() {
    if std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) < 2 {
        return;
    }
    let reg = registry();
    let mut e = engine_for(&reg, "nbody", all_devices());
    e.scheduler(SchedulerKind::static_with(vec![1.0, 1.0, 1.0]));
    e.configurator().simulate_speed = false;
    e.run().unwrap();
    let r = e.report().unwrap();
    // Raw config: each package's [exec_start, end) is its real compute
    // window. Under the seed's exec lock no two windows could ever
    // overlap; parallel workers must overlap at least one pair.
    let windows: Vec<(std::time::Duration, std::time::Duration)> = r
        .devices
        .iter()
        .flat_map(|d| d.packages.iter().map(|p| (p.exec_start, p.end)))
        .collect();
    assert_eq!(windows.len(), 3, "one package per device under equal static");
    let overlapping = windows
        .iter()
        .enumerate()
        .any(|(i, a)| windows.iter().skip(i + 1).any(|b| a.0 < b.1 && b.0 < a.1));
    assert!(
        overlapping,
        "no two compute windows overlap — co-execution is serialized:\n{}",
        r.ascii_timeline(60)
    );
}

// ---- prefix runs (problem-size sweeps) -------------------------------

#[test]
fn prefix_gws_only_touches_prefix() {
    let reg = registry();
    let manifest = reg.bench("binomial").unwrap().clone();
    let gws = manifest.granule * 8;
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    e.global_work_items(gws);
    e.run().unwrap();
    let out = e.output(0).unwrap();
    let golden = reg.golden_outputs(&manifest).unwrap();
    let want = golden[0].as_f32().unwrap();
    let (_, rel) = max_abs_rel_err(&out[..gws], &want[..gws]);
    assert!(rel < 1e-3);
    assert!(out[gws..].iter().all(|&x| x == 0.0), "tail untouched");
}

// ---- validation / error model ----------------------------------------

#[test]
fn errors_are_collected_on_engine() {
    let reg = registry();
    let mut e = Engine::with_registry(reg.clone());
    e.use_devices(vec![DeviceSpec::new(0)]);
    let mut p = Program::new();
    p.kernel("no-such-kernel", "k");
    e.program(p);
    assert!(e.run().is_err());
    assert!(e.has_errors());
    assert_eq!(e.get_errors().len(), 1);
}

#[test]
fn misaligned_gws_rejected() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    e.global_work_items(100); // granule is 256
    assert!(e.run().is_err());
}

#[test]
fn oversized_gws_rejected() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    e.global_work_items(1 << 30);
    assert!(e.run().is_err());
}

#[test]
fn wrong_input_arity_rejected() {
    let reg = registry();
    let mut e = Engine::with_registry(reg.clone());
    e.use_devices(vec![DeviceSpec::new(0)]);
    let mut p = Program::new();
    p.kernel("binomial", "binomial_opts");
    // No inputs registered; binomial expects 1.
    p.output(reg.bench("binomial").unwrap().outputs[0].elems);
    e.program(p);
    assert!(e.run().is_err());
}

#[test]
fn bad_static_proportions_rejected() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", all_devices());
    e.scheduler(SchedulerKind::static_with(vec![0.5, 0.5])); // 2 props, 3 devs
    assert!(e.run().is_err());
}

/// Regression: a failed run must clear the previous run's report
/// instead of leaving it visible through `report()` — callers that
/// ignore the error and read introspection would silently get the
/// *prior* run's numbers.
#[test]
fn failed_run_clears_stale_report() {
    use enginecl::platform::FaultPlan;
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    e.configurator().simulate_speed = false;
    e.run().unwrap();
    assert!(e.report().is_some(), "successful run leaves a report");
    let first_wall = e.report().unwrap().wall;

    // A single-device panic cannot be recovered: the run fails.
    e.fault_plan(FaultPlan::panic_at(0, 0));
    assert!(e.run().is_err());
    assert!(
        e.report().is_none(),
        "failed run must clear the stale report (was wall={first_wall:?})"
    );

    // And the engine stays reusable: clearing the plan restores runs
    // (and the report).
    e.configurator().fault_plan = None;
    e.run().unwrap();
    assert!(e.report().is_some());
}

#[test]
fn arg_validation_accepts_baked_and_rejects_unbaked() {
    let reg = registry();
    let manifest = reg.bench("binomial").unwrap().clone();
    // Accept: the baked steps value.
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    {
        let steps = manifest.scalars["steps"];
        let mut p = Program::new();
        p.kernel("binomial", &manifest.kernel);
        for buf in reg.golden_inputs(&manifest).unwrap() {
            p.input(buf.as_f32().unwrap().to_vec());
        }
        p.output(manifest.outputs[0].elems);
        p.arg_scalar(0, steps);
        p.arg_local_alloc(3, 255 * 16);
        e.program(p);
    }
    e.configurator().simulate_init = false;
    e.run().unwrap();

    // Reject: a steps value the artifact was not compiled with.
    let mut e2 = Engine::with_registry(reg.clone());
    e2.use_devices(vec![DeviceSpec::new(0)]);
    let mut p2 = Program::new();
    p2.kernel("binomial", &manifest.kernel);
    for buf in reg.golden_inputs(&manifest).unwrap() {
        p2.input(buf.as_f32().unwrap().to_vec());
    }
    p2.output(manifest.outputs[0].elems);
    p2.arg_scalar(0, 9999.0);
    e2.program(p2);
    assert!(e2.run().is_err());
}
