//! End-to-end integration: the engine must produce golden-correct results
//! under every scheduler, and co-execution must agree bit-for-bit with a
//! single-device run (same executables, disjoint ranges).
//!
//! These tests need `make artifacts` to have run.

use enginecl::coordinator::{DeviceSpec, Engine, Program, SchedulerKind};
use enginecl::platform::NodeConfig;
use enginecl::runtime::{host::max_abs_rel_err, ArtifactRegistry};

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("run `make artifacts` before cargo test")
}

/// Build an engine with golden inputs for `bench`, fast-sim profile
/// (no init sleeps — keep tests quick, but keep speed stretching so
/// scheduling behaves heterogeneously).
fn engine_for(reg: &ArtifactRegistry, bench: &str, devices: Vec<DeviceSpec>) -> Engine {
    let manifest = reg.bench(bench).unwrap().clone();
    let mut engine = Engine::with_registry(reg.clone());
    engine.node(NodeConfig::batel());
    engine.use_devices(devices);
    engine.configurator().simulate_init = false;
    let mut program = Program::new();
    program.kernel(bench, &manifest.kernel);
    for buf in reg.golden_inputs(&manifest).unwrap() {
        program.input(buf.as_f32().unwrap().to_vec());
    }
    for out in &manifest.outputs {
        program.output(out.elems);
    }
    engine.program(program);
    engine
}

fn check_against_golden(reg: &ArtifactRegistry, bench: &str, engine: &Engine, tol: f64) {
    let manifest = reg.bench(bench).unwrap();
    let golden = reg.golden_outputs(manifest).unwrap();
    for (i, g) in golden.iter().enumerate() {
        let got = engine.output(i).unwrap();
        if bench.starts_with("ray") || bench == "mandelbrot" {
            let (ok, stat) = enginecl::runtime::host::golden_close(bench, got, g.as_f32().unwrap());
            assert!(ok, "{bench} output {i}: mismatch fraction {stat:.4}");
        } else {
            let (abs, rel) = max_abs_rel_err(got, g.as_f32().unwrap());
            assert!(
                rel < tol || abs < tol,
                "{bench} output {i}: max abs {abs:.3e}, rel {rel:.3e} (tol {tol:.0e})"
            );
        }
    }
}

fn all_devices() -> Vec<DeviceSpec> {
    (0..3).map(DeviceSpec::new).collect()
}

// ---- single device vs golden ---------------------------------------

#[test]
fn binomial_single_device_matches_golden() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(1)]);
    e.run().unwrap();
    check_against_golden(&reg, "binomial", &e, 1e-3);
}

#[test]
fn nbody_single_device_matches_golden() {
    let reg = registry();
    let mut e = engine_for(&reg, "nbody", vec![DeviceSpec::new(1)]);
    e.run().unwrap();
    check_against_golden(&reg, "nbody", &e, 2e-3);
}

#[test]
fn gaussian_single_device_matches_golden() {
    let reg = registry();
    let mut e = engine_for(&reg, "gaussian", vec![DeviceSpec::new(0)]);
    e.run().unwrap();
    check_against_golden(&reg, "gaussian", &e, 1e-3);
}

// ---- co-execution under every scheduler vs golden -------------------

fn coexec_matches_golden(bench: &str, kind: SchedulerKind, tol: f64) {
    let reg = registry();
    let mut e = engine_for(&reg, bench, all_devices());
    e.scheduler(kind);
    e.run().unwrap();
    check_against_golden(&reg, bench, &e, tol);
    let report = e.report().unwrap();
    assert_eq!(report.gws, reg.bench(bench).unwrap().n);
    // Every device that reports packages must have computed something.
    let items: usize = report.devices.iter().map(|d| d.items()).sum();
    assert_eq!(items, report.gws, "all work items computed exactly once");
}

#[test]
fn binomial_coexec_static() {
    coexec_matches_golden("binomial", SchedulerKind::static_default(), 1e-3);
}

#[test]
fn binomial_coexec_dynamic() {
    coexec_matches_golden("binomial", SchedulerKind::dynamic(50), 1e-3);
}

#[test]
fn binomial_coexec_hguided() {
    coexec_matches_golden("binomial", SchedulerKind::hguided(), 1e-3);
}

#[test]
fn mandelbrot_coexec_hguided() {
    // Iteration counts are integers; escape-boundary pixels may flip by
    // one iteration vs the jnp oracle, so compare with atol ~1.
    let reg = registry();
    let mut e = engine_for(&reg, "mandelbrot", all_devices());
    e.scheduler(SchedulerKind::hguided());
    e.run().unwrap();
    let golden = reg.golden_outputs(reg.bench("mandelbrot").unwrap()).unwrap();
    let got = e.output(0).unwrap();
    let want = golden[0].as_f32().unwrap();
    let mismatched = got
        .iter()
        .zip(want)
        .filter(|(a, b)| (**a - **b).abs() > 1.0)
        .count();
    assert!(
        (mismatched as f64) < 0.005 * want.len() as f64,
        "{mismatched} mandelbrot pixels differ by >1 iteration"
    );
}

#[test]
fn ray_scenes_coexec_dynamic() {
    for bench in ["ray1", "ray2", "ray3"] {
        let reg = registry();
        let mut e = engine_for(&reg, bench, all_devices());
        e.scheduler(SchedulerKind::dynamic(50));
        e.run().unwrap();
        check_against_golden(&reg, bench, &e, 2e-3);
    }
}

#[test]
fn nbody_coexec_static_rev() {
    coexec_matches_golden(
        "nbody",
        SchedulerKind::Static { props: None, reversed: true },
        2e-3,
    );
}

// ---- co-execution == single device, bitwise -------------------------

#[test]
fn coexec_equals_single_device_bitwise() {
    let reg = registry();
    let mut solo = engine_for(&reg, "binomial", vec![DeviceSpec::new(1)]);
    solo.run().unwrap();
    let want = solo.output(0).unwrap().to_vec();

    for kind in [
        SchedulerKind::static_default(),
        SchedulerKind::dynamic(37),
        SchedulerKind::hguided(),
    ] {
        let mut co = engine_for(&reg, "binomial", all_devices());
        co.scheduler(kind.clone());
        co.run().unwrap();
        assert_eq!(
            co.output(0).unwrap(),
            &want[..],
            "scheduler {} changed results",
            kind.label()
        );
    }
}

// ---- pipelined co-execution ------------------------------------------

/// The tentpole invariant: enabling the transfer/compute pipeline must
/// not change a single output bit, under every base scheduler.
#[test]
fn pipelined_outputs_bit_identical_to_blocking() {
    let reg = registry();
    for kind in [
        SchedulerKind::static_default(),
        SchedulerKind::dynamic(16),
        SchedulerKind::hguided(),
    ] {
        let mut blocking = engine_for(&reg, "binomial", all_devices());
        blocking.scheduler(kind.clone());
        blocking.pipeline(1);
        blocking.run().unwrap();
        let want = blocking.output(0).unwrap().to_vec();

        let mut piped = engine_for(&reg, "binomial", all_devices());
        piped.scheduler(kind.clone());
        piped.pipeline(2);
        piped.run().unwrap();
        assert_eq!(
            piped.output(0).unwrap(),
            &want[..],
            "pipelining changed results under {}",
            kind.label()
        );
        let report = piped.report().unwrap();
        let items: usize = report.devices.iter().map(|d| d.items()).sum();
        assert_eq!(items, report.gws, "all work items computed exactly once");
        assert!(report.scheduler.contains("+pipe"), "report labels the pipeline");
    }
}

/// The `+pipe` scheduler-spec path (what the CLI uses) must behave like
/// the Tier-1 `Engine::pipeline` call and still match the golden oracle.
#[test]
fn pipe_suffix_spec_matches_golden() {
    let reg = registry();
    let kind = enginecl::coordinator::scheduler::parse_kind("hguided+pipe").unwrap();
    let mut e = engine_for(&reg, "mandelbrot", all_devices());
    e.scheduler(kind);
    e.run().unwrap();
    check_against_golden(&reg, "mandelbrot", &e, 1e-3);
    assert_eq!(e.report().unwrap().scheduler, "HGuided+pipe");
}

/// The overlap must be visible in the introspector: with pipelining on,
/// at least one package's H2D staging span sits inside another package's
/// compute window on the same device; with pipelining off, none do.
#[test]
fn pipelined_traces_show_transfer_compute_overlap() {
    let reg = registry();
    let mut piped = engine_for(&reg, "binomial", vec![DeviceSpec::new(1)]);
    piped.scheduler(SchedulerKind::dynamic(8));
    piped.pipeline(2);
    piped.run().unwrap();
    let report = piped.report().unwrap();
    assert!(
        report.has_transfer_overlap(),
        "no overlapped transfer in pipelined traces:\n{}",
        report.package_csv()
    );

    let mut blocking = engine_for(&reg, "binomial", vec![DeviceSpec::new(1)]);
    blocking.scheduler(SchedulerKind::dynamic(8));
    blocking.run().unwrap();
    assert_eq!(
        blocking.report().unwrap().transfer_overlap_count(),
        0,
        "blocking run must not report overlap"
    );
}

/// The result merge must not depend on the optional introspection
/// traces: with `introspect` off the outputs still come back complete
/// (regression test for the trace-driven merge coupling).
#[test]
fn outputs_merge_with_introspection_disabled() {
    let reg = registry();
    for depth in [1usize, 2] {
        let mut e = engine_for(&reg, "binomial", all_devices());
        e.scheduler(SchedulerKind::dynamic(8));
        e.pipeline(depth);
        e.configurator().introspect = false;
        e.run().unwrap();
        check_against_golden(&reg, "binomial", &e, 1e-3);
        assert_eq!(
            e.report().unwrap().total_packages(),
            0,
            "no traces collected with introspection off"
        );
    }
}

/// Deeper pipelines are valid up to the engine bound and keep results
/// correct on an adaptive scheduler.
#[test]
fn deep_pipeline_matches_golden() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", all_devices());
    e.scheduler(SchedulerKind::dynamic(16));
    e.pipeline(4);
    e.run().unwrap();
    check_against_golden(&reg, "binomial", &e, 1e-3);
}

// ---- prefix runs (problem-size sweeps) -------------------------------

#[test]
fn prefix_gws_only_touches_prefix() {
    let reg = registry();
    let manifest = reg.bench("binomial").unwrap().clone();
    let gws = manifest.granule * 8;
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    e.global_work_items(gws);
    e.run().unwrap();
    let out = e.output(0).unwrap();
    let golden = reg.golden_outputs(&manifest).unwrap();
    let want = golden[0].as_f32().unwrap();
    let (_, rel) = max_abs_rel_err(&out[..gws], &want[..gws]);
    assert!(rel < 1e-3);
    assert!(out[gws..].iter().all(|&x| x == 0.0), "tail untouched");
}

// ---- validation / error model ----------------------------------------

#[test]
fn errors_are_collected_on_engine() {
    let reg = registry();
    let mut e = Engine::with_registry(reg.clone());
    e.use_devices(vec![DeviceSpec::new(0)]);
    let mut p = Program::new();
    p.kernel("no-such-kernel", "k");
    e.program(p);
    assert!(e.run().is_err());
    assert!(e.has_errors());
    assert_eq!(e.get_errors().len(), 1);
}

#[test]
fn misaligned_gws_rejected() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    e.global_work_items(100); // granule is 256
    assert!(e.run().is_err());
}

#[test]
fn oversized_gws_rejected() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    e.global_work_items(1 << 30);
    assert!(e.run().is_err());
}

#[test]
fn wrong_input_arity_rejected() {
    let reg = registry();
    let mut e = Engine::with_registry(reg.clone());
    e.use_devices(vec![DeviceSpec::new(0)]);
    let mut p = Program::new();
    p.kernel("binomial", "binomial_opts");
    // No inputs registered; binomial expects 1.
    p.output(reg.bench("binomial").unwrap().outputs[0].elems);
    e.program(p);
    assert!(e.run().is_err());
}

#[test]
fn bad_static_proportions_rejected() {
    let reg = registry();
    let mut e = engine_for(&reg, "binomial", all_devices());
    e.scheduler(SchedulerKind::static_with(vec![0.5, 0.5])); // 2 props, 3 devs
    assert!(e.run().is_err());
}

#[test]
fn arg_validation_accepts_baked_and_rejects_unbaked() {
    let reg = registry();
    let manifest = reg.bench("binomial").unwrap().clone();
    // Accept: the baked steps value.
    let mut e = engine_for(&reg, "binomial", vec![DeviceSpec::new(0)]);
    {
        let steps = manifest.scalars["steps"];
        let mut p = Program::new();
        p.kernel("binomial", &manifest.kernel);
        for buf in reg.golden_inputs(&manifest).unwrap() {
            p.input(buf.as_f32().unwrap().to_vec());
        }
        p.output(manifest.outputs[0].elems);
        p.arg_scalar(0, steps);
        p.arg_local_alloc(3, 255 * 16);
        e.program(p);
    }
    e.configurator().simulate_init = false;
    e.run().unwrap();

    // Reject: a steps value the artifact was not compiled with.
    let mut e2 = Engine::with_registry(reg.clone());
    e2.use_devices(vec![DeviceSpec::new(0)]);
    let mut p2 = Program::new();
    p2.kernel("binomial", &manifest.kernel);
    for buf in reg.golden_inputs(&manifest).unwrap() {
        p2.input(buf.as_f32().unwrap().to_vec());
    }
    p2.output(manifest.outputs[0].elems);
    p2.arg_scalar(0, 9999.0);
    e2.program(p2);
    assert!(e2.run().is_err());
}
