//! Service front-end properties under storm traffic (PR-8).
//!
//! Four pins over `coordinator::service`:
//!
//! 1. **Exactly-once at scale** — a ≥1000-request seeded mixed-tenant
//!    storm resolves every handle, the ledger ends all-Responded with
//!    zero skipped transitions, and coalescing actually engaged.
//! 2. **Bit-identity** — a coalesced member's demuxed outputs equal a
//!    solo `Engine` run of the same kernel at the same gws, bit for bit.
//! 3. **Cache monotonicity** — artifact-cache hits only grow across
//!    sequential storm waves while misses stay pinned at the distinct
//!    (kernel, device) pair count.
//! 4. **Fairness** — no tenant's p95 admission wait exceeds K× the
//!    fleet median, even with one tenant drawing double traffic.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use enginecl::coordinator::service::{Request, ResponseHandle, Served, Service, ServiceConfig};
use enginecl::coordinator::{Configurator, EclError, LedgerCounts, SchedulerKind};
use enginecl::platform::NodeConfig;
use enginecl::runtime::ArtifactRegistry;
use enginecl::testing::chaos_engine;
use enginecl::util::rng::XorShift;

const STORM_KERNELS: [&str; 4] = ["binomial", "gaussian", "mandelbrot", "nbody"];

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("artifact registry (synthetic fallback)")
}

fn fast_cfg() -> Configurator {
    Configurator { simulate_init: false, simulate_speed: false, ..Default::default() }
}

/// A storm service: tenant t0 draws double traffic (see `storm_request`)
/// and pays for it with a double DRR weight, so weighted fairness — not
/// raw round-robin — is what the fairness pin exercises.
fn storm_service(reg: &ArtifactRegistry, seed: u64) -> Service {
    let mut weights = BTreeMap::new();
    weights.insert("t0".to_string(), 2);
    let cfg = ServiceConfig { seed, weights, session_config: fast_cfg(), ..Default::default() };
    Service::new(reg.clone(), NodeConfig::batel(), cfg)
}

/// One seeded storm request. Draw order is fixed (kernel, size
/// multiplier, tenant, scheduler, deadline) so a seed pins the whole
/// storm. The tenant draw is over `tenants + 1` slots with overflow
/// folded onto t0 — the deliberate 2x-heavy tenant.
fn storm_request(rng: &mut XorShift, reg: &ArtifactRegistry, tenants: usize) -> Request {
    let kernel = STORM_KERNELS[rng.below(STORM_KERNELS.len())];
    let bench = reg.bench(kernel).expect("storm kernel");
    let mult = 1 + rng.below(4);
    let t = rng.below(tenants + 1);
    let tenant = format!("t{}", if t >= tenants { 0 } else { t });
    let sched = if rng.below(2) == 0 {
        SchedulerKind::static_default()
    } else {
        SchedulerKind::dynamic(50)
    };
    let deadlined = rng.next_f64() < 0.25;
    let dl_ms = 50 + rng.below(200) as u64;
    let mut req = Request::new(kernel)
        .gws((bench.granule * mult).min(bench.n))
        .scheduler(sched)
        .tenant(&tenant);
    if deadlined {
        req = req.deadline(Duration::from_millis(dl_ms));
    }
    req
}

/// Ingest with backpressure handling: a full mailbox is retried after a
/// dispatch round (the documented contract of `EclError::MailboxFull`).
fn ingest_retrying(svc: &Service, req: Request) -> ResponseHandle {
    loop {
        match svc.ingest(req.clone()) {
            Ok(h) => return h,
            Err(EclError::MailboxFull { .. }) => {
                svc.pump_round();
            }
            Err(e) => panic!("storm request rejected: {e}"),
        }
    }
}

/// Nearest-rank percentile over an unsorted sample.
fn percentile(mut xs: Vec<u64>, p: f64) -> f64 {
    assert!(!xs.is_empty());
    xs.sort_unstable();
    let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
    xs[idx.min(xs.len() - 1)] as f64
}

#[test]
fn thousand_request_storm_is_exactly_once_and_fair() {
    const REQUESTS: usize = 1000;
    const TENANTS: usize = 5;
    let reg = registry();
    let svc = storm_service(&reg, 0x51CE);
    let mut rng = XorShift::new(0x5707_81CE);
    let mut handles = Vec::with_capacity(REQUESTS);
    for i in 0..REQUESTS {
        let req = storm_request(&mut rng, &reg, TENANTS);
        handles.push((req.tenant.clone(), ingest_retrying(&svc, req)));
        // Pump in bursts so mailboxes breathe and the DRR sees real
        // cross-tenant contention instead of one giant final queue.
        if (i + 1) % 128 == 0 {
            svc.pump_round();
        }
    }
    svc.drain();

    // Exactly-once: every handle resolves Ok, the ledger is terminal.
    let mut waits: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for (tenant, h) in handles {
        let resp = h.wait();
        let served: Served = resp.result.expect("storm request served");
        waits.entry(tenant).or_default().push(served.report.wait_rounds());
    }
    assert_eq!(
        svc.ledger_counts(),
        LedgerCounts { queued: 0, dispatched: 0, responded: REQUESTS },
        "ledger is terminal: every request responded, none stranded"
    );
    assert_eq!(svc.ledger_violations(), 0, "no skipped ledger transitions");

    // Coalescing engaged: strictly fewer sessions than requests.
    let stats = svc.stats();
    assert_eq!(stats.ingested, REQUESTS as u64);
    assert_eq!(stats.responded, REQUESTS as u64);
    assert!(
        stats.batches < REQUESTS as u64,
        "storm coalesced: {} batches served {} requests",
        stats.batches,
        REQUESTS
    );
    assert!(stats.coalesced_requests > 0, "some requests shared a batch");

    // Fairness: no tenant's p95 wait exceeds K x the fleet median.
    let fleet: Vec<u64> = waits.values().flatten().copied().collect();
    let median = percentile(fleet, 50.0).max(1.0);
    for (tenant, w) in &waits {
        assert!(!w.is_empty(), "tenant {tenant} saw traffic");
        let p95 = percentile(w.clone(), 95.0);
        assert!(
            p95 <= 6.0 * median,
            "tenant {tenant} starved: p95 wait {p95} vs fleet median {median}"
        );
    }
}

#[test]
fn coalesced_outputs_are_bit_identical_to_solo_runs() {
    let reg = registry();
    let cfg = ServiceConfig {
        coalesce_max: 8,
        session_config: fast_cfg(),
        ..Default::default()
    };
    let svc = Service::new(reg.clone(), NodeConfig::batel(), cfg);

    // Three same-kernel different-size requests coalesce into one batch
    // at the max gws; a fourth kernel rides along solo.
    let kind = SchedulerKind::static_default();
    let binom = reg.bench("binomial").expect("binomial").clone();
    let sizes = [binom.granule, binom.granule * 2, binom.granule * 3];
    let mut handles = Vec::new();
    for &g in &sizes {
        handles.push((
            "binomial",
            g,
            svc.ingest(Request::new("binomial").gws(g).scheduler(kind.clone()))
                .expect("ingest"),
        ));
    }
    let gauss = reg.bench("gaussian").expect("gaussian").clone();
    let g_gws = gauss.granule * 2;
    handles.push((
        "gaussian",
        g_gws,
        svc.ingest(Request::new("gaussian").gws(g_gws).scheduler(kind.clone()))
            .expect("ingest"),
    ));
    svc.drain();

    for (kernel, gws, h) in handles {
        let served = h.wait().result.expect("served");
        if kernel == "binomial" {
            assert_eq!(served.report.batch_size, 3, "binomial members share one batch");
            assert_eq!(served.report.batch_gws, binom.granule * 3);
        }
        // Solo oracle: same kernel, same gws, fresh engine over the same
        // golden inputs. Per-item kernels make the demuxed prefix
        // bit-identical — not approximately equal.
        let mut solo = chaos_engine(&reg, kernel, 3, kind.clone(), None);
        solo.global_work_items(gws);
        solo.run().expect("solo run");
        let manifest = reg.bench(kernel).expect("manifest").clone();
        assert_eq!(served.outputs.len(), manifest.outputs.len());
        for (j, out) in served.outputs.iter().enumerate() {
            let epi = manifest.outputs[j].elems_per_item;
            let solo_out = solo.output(j).expect("solo output");
            assert_eq!(out.len(), gws * epi, "demux prefix length");
            assert_eq!(
                out.as_slice(),
                &solo_out[..gws * epi],
                "coalesced {kernel} output {j} at gws {gws} diverged from its solo run"
            );
        }
    }
}

#[test]
fn artifact_cache_hits_grow_monotonically_across_waves() {
    let reg = registry();
    // coalesce_max 1: every request is its own session, so each wave
    // pays the same number of worker acquisitions.
    let cfg = ServiceConfig { coalesce_max: 1, session_config: fast_cfg(), ..Default::default() };
    let svc = Service::new(reg.clone(), NodeConfig::batel(), cfg);
    let devices = svc.runtime().node().devices.len();

    let mut last_hits = 0u64;
    for wave in 0..3 {
        let handles: Vec<_> = (0..3)
            .map(|_| svc.ingest(Request::new("mandelbrot")).expect("ingest"))
            .collect();
        svc.drain();
        for h in handles {
            assert!(h.wait().result.is_ok());
        }
        let stats = svc.stats();
        // Misses are pinned at the distinct (kernel, device) pair count
        // from wave 0 on; only hits move, and only upward.
        assert_eq!(
            stats.artifact_cache_misses as usize, devices,
            "wave {wave}: one miss per device, ever"
        );
        assert!(
            stats.artifact_cache_hits > last_hits || wave == 0,
            "wave {wave}: hits grew ({last_hits} -> {})",
            stats.artifact_cache_hits
        );
        assert!(stats.artifact_cache_hits >= last_hits, "hits never regress");
        last_hits = stats.artifact_cache_hits;
    }
    // Nine sessions, each acquiring once per device worker; all but the
    // first wave's first session hit.
    assert_eq!(
        (last_hits + svc.stats().artifact_cache_misses) as usize,
        9 * devices,
        "every worker acquisition is counted exactly once"
    );
}

#[test]
fn live_mode_storm_resolves_every_request() {
    const REQUESTS: usize = 100;
    let reg = registry();
    let svc = Arc::new(storm_service(&reg, 0xB007));
    svc.start();
    let mut rng = XorShift::new(0xB007_57A6);
    let mut handles = Vec::with_capacity(REQUESTS);
    for _ in 0..REQUESTS {
        let req = storm_request(&mut rng, &reg, 4);
        // Live mode drains shards continuously; backpressure still
        // possible under burst, so spin briefly instead of pumping.
        loop {
            match svc.ingest(req.clone()) {
                Ok(h) => {
                    handles.push(h);
                    break;
                }
                Err(EclError::MailboxFull { .. }) => std::thread::yield_now(),
                Err(e) => panic!("live ingest rejected: {e}"),
            }
        }
    }
    for h in handles {
        assert!(h.wait().result.is_ok(), "live storm request served");
    }
    svc.shutdown();
    assert_eq!(svc.pending(), 0);
    assert_eq!(svc.ledger_violations(), 0);
    let counts = svc.ledger_counts();
    assert_eq!(counts.responded, REQUESTS);
    assert_eq!(counts.queued + counts.dispatched, 0);
}
