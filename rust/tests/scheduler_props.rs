//! Property tests on scheduler invariants (the paper's correctness core:
//! whatever the algorithm, every work-item is computed exactly once).

use std::time::Duration;

use enginecl::coordinator::scheduler::{
    Adaptive, Dynamic, HGuided, PackageTiming, Pipelined, SchedDevice, Scheduler,
    SchedulerKind, Static,
};
use enginecl::prop_assert;
use enginecl::testing::forall;
use enginecl::util::rng::XorShift;

#[derive(Debug)]
struct Case {
    total_granules: usize,
    granule: usize,
    powers: Vec<f64>,
    sched: usize, // 0 static, 1 static-rev, 2 dynamic, 3 hguided, 4 adaptive
    packages: usize,
    k: f64,
    min_granules: usize,
    /// Wrap the base strategy in the Pipelined composition.
    pipelined: bool,
    depth: usize,
}

fn gen_case(r: &mut XorShift) -> Case {
    let ndev = r.range(1, 4);
    Case {
        total_granules: r.range(1, 2048),
        granule: [1, 64, 128, 256, 512][r.below(5)],
        powers: (0..ndev).map(|_| 0.05 + r.next_f64()).collect(),
        sched: r.below(5),
        packages: r.range(1, 300),
        k: 1.0 + r.next_f64() * 4.0,
        min_granules: r.range(1, 8),
        pipelined: r.below(2) == 1,
        depth: r.range(2, 4),
    }
}

fn build_base(case: &Case) -> Box<dyn Scheduler> {
    match case.sched {
        0 => Box::new(Static::new(None, false)),
        1 => Box::new(Static::new(None, true)),
        2 => Box::new(Dynamic::new(case.packages)),
        3 => Box::new(HGuided::new(case.k, case.min_granules)),
        _ => Box::new(Adaptive::new(case.k, case.min_granules, 0.5)),
    }
}

fn build(case: &Case) -> Box<dyn Scheduler> {
    if case.pipelined {
        Box::new(Pipelined::new(build_base(case), case.depth))
    } else {
        build_base(case)
    }
}

fn devices(case: &Case) -> Vec<SchedDevice> {
    case.powers
        .iter()
        .enumerate()
        .map(|(i, p)| SchedDevice::new(format!("d{i}"), *p))
        .collect()
}

/// Drain a scheduler round-robin, simulating devices finishing in a
/// seed-dependent order — and completing with seed-dependent timings
/// fed back through `observe`, so the feedback loop is live during
/// every invariant check — returning all assigned ranges per device.
fn drain(case: &Case, seed: u64) -> Vec<(usize, enginecl::coordinator::Range)> {
    let mut s = build(case);
    let devs = devices(case);
    s.start(case.total_granules, case.granule, &devs);
    let mut rng = XorShift::new(seed);
    let mut active: Vec<usize> = (0..devs.len()).collect();
    let mut out = Vec::new();
    while !active.is_empty() {
        let pick = rng.below(active.len());
        let dev = active[pick];
        match s.next_package(dev) {
            Some(r) => {
                let span = Duration::from_micros(1 + rng.below(10_000) as u64);
                s.observe(dev, r, PackageTiming { span, raw_exec: span / 4 });
                out.push((dev, r));
            }
            None => {
                active.remove(pick);
            }
        }
    }
    out
}

#[test]
fn prop_every_item_assigned_exactly_once() {
    forall("exactly-once coverage", gen_case, |case| {
        let assigned = drain(case, 99);
        let total_items = case.total_granules * case.granule;
        let mut seen = vec![0u8; total_items];
        for (_, r) in &assigned {
            prop_assert!(r.end <= total_items, "range {r:?} exceeds {total_items}");
            for slot in &mut seen[r.begin..r.end] {
                prop_assert!(*slot == 0, "item assigned twice in {r:?}");
                *slot = 1;
            }
        }
        prop_assert!(
            seen.iter().all(|&s| s == 1),
            "uncovered items: {}",
            seen.iter().filter(|&&s| s == 0).count()
        );
        Ok(())
    });
}

#[test]
fn prop_packages_are_granule_aligned() {
    forall("granule alignment", gen_case, |case| {
        for (_, r) in drain(case, 7) {
            prop_assert!(r.begin % case.granule == 0, "begin misaligned: {r:?}");
            prop_assert!(r.len() % case.granule == 0, "length misaligned: {r:?}");
        }
        Ok(())
    });
}

#[test]
fn prop_static_gives_at_most_one_package_per_device() {
    forall(
        "static one package",
        |r| {
            let mut c = gen_case(r);
            c.sched = r.below(2);
            c
        },
        |case| {
            let assigned = drain(case, 3);
            let ndev = case.powers.len();
            for d in 0..ndev {
                let count = assigned.iter().filter(|(dev, _)| *dev == d).count();
                prop_assert!(count <= 1, "device {d} got {count} packages under Static");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dynamic_package_count_bounded() {
    forall(
        "dynamic package count",
        |r| {
            let mut c = gen_case(r);
            c.sched = 2;
            c
        },
        |case| {
            let assigned = drain(case, 11);
            prop_assert!(
                assigned.len() <= case.packages.min(case.total_granules),
                "dynamic issued {} > {} packages",
                assigned.len(),
                case.packages
            );
            Ok(())
        },
    );
}

#[test]
fn prop_hguided_sizes_non_increasing_per_device() {
    forall(
        "hguided monotone",
        |r| {
            let mut c = gen_case(r);
            c.sched = 3;
            c
        },
        |case| {
            // Single-device drain isolates the geometric decrease (multi-
            // device interleavings change G_r between calls to the same
            // device, but per-device sizes must still never grow beyond
            // the clamp).
            let mut s = HGuided::new(case.k, case.min_granules);
            s.start(case.total_granules, case.granule, &devices(case)[..1]);
            let mut last = usize::MAX;
            while let Some(r) = s.next_package(0) {
                prop_assert!(
                    r.len() <= last,
                    "package grew: {} after {last}",
                    r.len()
                );
                last = r.len();
            }
            Ok(())
        },
    );
}

#[test]
fn prop_hguided_respects_power_ordering_on_first_packets() {
    forall(
        "hguided power ordering",
        |r| {
            let mut c = gen_case(r);
            c.sched = 3;
            // At least 2 devices with distinct powers.
            c.powers = vec![0.1 + r.next_f64() * 0.3, 0.6 + r.next_f64() * 0.4];
            c.total_granules = 1000 + r.below(1000);
            c
        },
        |case| {
            // First packet of the stronger device (fresh schedulers so
            // both see the full pending set).
            let devs = devices(case);
            let mut a = HGuided::new(case.k, case.min_granules);
            a.start(case.total_granules, case.granule, &devs);
            let weak = a.next_package(0).unwrap().len();
            let mut b = HGuided::new(case.k, case.min_granules);
            b.start(case.total_granules, case.granule, &devs);
            let strong = b.next_package(1).unwrap().len();
            prop_assert!(
                strong >= weak,
                "stronger device got smaller first packet: {strong} < {weak}"
            );
            Ok(())
        },
    );
}

#[test]
fn prop_schedulers_deterministic_for_fixed_order() {
    forall("determinism", gen_case, |case| {
        let a = drain(case, 42);
        let b = drain(case, 42);
        prop_assert!(a.len() == b.len(), "different package counts");
        for ((da, ra), (db, rb)) in a.iter().zip(&b) {
            prop_assert!(da == db && ra == rb, "divergent assignment");
        }
        Ok(())
    });
}

#[test]
fn kinds_build_the_right_strategies() {
    assert_eq!(SchedulerKind::static_default().build().name(), "Static");
    assert_eq!(SchedulerKind::dynamic(50).build().name(), "Dynamic 50");
    assert_eq!(SchedulerKind::hguided().build().name(), "HGuided");
    assert_eq!(SchedulerKind::hguided_static().build().name(), "HGuided-static");
    assert_eq!(SchedulerKind::adaptive().build().name(), "Adaptive");
    assert_eq!(SchedulerKind::hguided().pipelined(2).build().name(), "HGuided+pipe");
    assert_eq!(SchedulerKind::adaptive().pipelined(2).build().name(), "Adaptive+pipe");
    assert_eq!(SchedulerKind::hguided().pipelined(3).build().pipeline_depth(), 3);
}

/// The ISSUE-1 pipeline invariant, explicitly: for every base strategy,
/// the Pipelined wrapper still yields disjoint granule-aligned ranges
/// exactly covering [0, gws) under arbitrary completion interleavings.
#[test]
fn prop_pipelined_wrapper_preserves_exact_coverage() {
    forall(
        "pipelined exactly-once coverage",
        |r| {
            let mut c = gen_case(r);
            c.pipelined = true;
            c
        },
        |case| {
            let assigned = drain(case, 17);
            let total_items = case.total_granules * case.granule;
            let mut seen = vec![0u8; total_items];
            for (_, r) in &assigned {
                prop_assert!(r.begin % case.granule == 0, "begin misaligned: {r:?}");
                prop_assert!(r.len() % case.granule == 0, "length misaligned: {r:?}");
                prop_assert!(r.end <= total_items, "range {r:?} exceeds {total_items}");
                for slot in &mut seen[r.begin..r.end] {
                    prop_assert!(*slot == 0, "item assigned twice in {r:?}");
                    *slot = 1;
                }
            }
            prop_assert!(
                seen.iter().all(|&s| s == 1),
                "uncovered items: {}",
                seen.iter().filter(|&&s| s == 0).count()
            );
            Ok(())
        },
    );
}

/// Pipelining changes *when* packages are requested, never *what* the
/// base strategy hands out: for an identical request order the wrapped
/// and unwrapped schedulers produce the same assignment sequence.
#[test]
fn prop_pipelined_wrapper_is_transparent() {
    forall("pipelined transparency", gen_case, |case| {
        let devs = devices(case);
        let mut base = build_base(case);
        let mut piped = Pipelined::new(build_base(case), 2);
        base.start(case.total_granules, case.granule, &devs);
        piped.start(case.total_granules, case.granule, &devs);
        let mut rng = XorShift::new(23);
        for _ in 0..2 * case.total_granules + 4 {
            let dev = rng.below(devs.len());
            let a = base.next_package(dev);
            let b = piped.next_package(dev);
            prop_assert!(a == b, "diverged on dev {dev}: {a:?} vs {b:?}");
            if a.is_none() {
                break;
            }
        }
        Ok(())
    });
}
