//! Steal × fault chaos grid (PR-10): cooperative work stealing must
//! never compromise the recovery contract.
//!
//! The acceptance invariant mirrors `fault_props.rs`: for every kernel
//! × base scheduler × steal policy × fault plan, the run **completes**,
//! its outputs are **bit-identical** to the no-steal fault-free run,
//! and the trace ledger is **exactly-once** — the executed packages
//! (survivors' own, requeued, and stolen alike) tile `[0, gws)` with no
//! gap and no overlap.
//!
//! A steal is a three-way race (master revokes, victim yields, thief
//! executes), and a kill can land in any leg: before the victim acks
//! (the dead victim's whole ledger is reclaimed, the steal aborts), or
//! after the transfer (the thief dies holding stolen work, which must
//! requeue like any other pending range). Package-ordinal fault plans
//! cannot pin one leg by construction — dispatch order is
//! thread-timing dependent — so the grid drives kills and vanishes at
//! several ordinals on both early and late devices, under both steal
//! policies, and the seeded sweep (pinned by `ECL_CHAOS_SEED` in CI)
//! varies the landing spot further. Whatever leg a fault lands in, the
//! contract below must hold; the arena's exactly-once ledger is the
//! oracle that catches a lost or doubled granule regardless of
//! interleaving.

use enginecl::coordinator::scheduler::{SchedulerKind, StealPolicy, DEFAULT_STEAL_THRESHOLD};
use enginecl::platform::fault::FaultPlan;
use enginecl::runtime::ArtifactRegistry;
use enginecl::testing::{assert_exactly_once, chaos_engine, chaos_seed};

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("artifact registry (synthetic fallback)")
}

/// The straggler kernel when the registry carries it (always true for
/// the synthetic registry), plus the regular control.
fn sweep_kernels(reg: &ArtifactRegistry) -> Vec<&'static str> {
    let mut kernels = vec!["binomial"];
    if reg.benches.contains_key("collatz") {
        kernels.push("collatz");
    }
    kernels
}

fn bases() -> Vec<(&'static str, fn() -> SchedulerKind)> {
    vec![("hguided", SchedulerKind::hguided), ("adaptive", SchedulerKind::adaptive)]
}

/// Both active policies per base. The `Stealing` wrapper forces the
/// pipeline deep enough that a victim owns at least one yieldable slot.
fn steal_kinds(base: fn() -> SchedulerKind) -> Vec<SchedulerKind> {
    vec![
        base().stealing(StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD }),
        base().stealing(StealPolicy::Eager),
    ]
}

/// Fault-free, steal-free reference outputs for `bench` under `base`
/// (3 devices) — the bit-identity target for every steal run.
fn no_steal_outputs(reg: &ArtifactRegistry, bench: &str, base: fn() -> SchedulerKind) -> Vec<Vec<f32>> {
    let mut e = chaos_engine(reg, bench, 3, base(), None);
    e.run().expect("no-steal baseline run");
    let n = reg.bench(bench).unwrap().outputs.len();
    (0..n).map(|i| e.output(i).unwrap().to_vec()).collect()
}

/// Run `bench` under a stealing `kind` with an optional fault plan and
/// assert the full contract against the no-steal reference.
fn check_steal_run(
    reg: &ArtifactRegistry,
    bench: &str,
    kind: &SchedulerKind,
    plan: Option<FaultPlan>,
    want: &[Vec<f32>],
) {
    let label = kind.label();
    let mut e = chaos_engine(reg, bench, 3, kind.clone(), plan.clone());
    e.run().unwrap_or_else(|err| {
        panic!("{bench}/{label}: steal run must complete (plan {plan:?}): {err}")
    });
    let report = e.report().unwrap().clone();
    for (i, w) in want.iter().enumerate() {
        assert!(
            e.output(i).unwrap() == &w[..],
            "{bench}/{label}: output {i} not bit-identical to the no-steal run (plan {plan:?})"
        );
    }
    assert_exactly_once(&report);
    for f in &report.faults {
        assert!(f.recovered, "{bench}/{label}: fault not recovered: {:?}", f.message);
    }
    // Steal accounting is self-consistent whether or not any steal
    // fired this interleaving (timing-dependent under fast-sim).
    if report.steals_issued > 0 {
        assert!(
            report.stolen_items() > 0,
            "{bench}/{label}: {} steals issued but no stolen items executed",
            report.steals_issued
        );
    } else {
        assert_eq!(
            report.stolen_packages(),
            0,
            "{bench}/{label}: stolen packages without an issued steal"
        );
    }
}

/// Fault-free: `+steal` is invisible in the results — outputs stay
/// bit-identical to the no-steal run and the ledger exactly-once, on
/// both the regular and the straggler kernel.
#[test]
fn steal_outputs_bit_identical_to_no_steal() {
    let reg = registry();
    for bench in sweep_kernels(&reg) {
        for (_, base) in bases() {
            let want = no_steal_outputs(&reg, bench, base);
            for kind in steal_kinds(base) {
                check_steal_run(&reg, bench, &kind, None, &want);
            }
        }
    }
}

/// The kill grid: early and late kill points on different devices while
/// stealing is active. A kill can land before the victim yields, while
/// a yield is in flight, or after a thief absorbed the ranges — the
/// recovery contract is the same in every leg.
#[test]
fn kills_during_stealing_recover_exactly_once() {
    let reg = registry();
    let plans = [FaultPlan::kill(1, 0), FaultPlan::kill(2, 1), FaultPlan::vanish(1, 0)];
    for bench in sweep_kernels(&reg) {
        for (_, base) in bases() {
            let want = no_steal_outputs(&reg, bench, base);
            for kind in steal_kinds(base) {
                for plan in &plans {
                    check_steal_run(&reg, bench, &kind, Some(plan.clone()), &want);
                }
            }
        }
    }
}

/// Seeded chaos: the kill point is derived from `ECL_CHAOS_SEED`
/// (logged, so a CI failure reproduces locally with the same env),
/// landing faults at varied points of the steal protocol.
#[test]
fn seeded_steal_chaos_reproducible_from_logged_seed() {
    let reg = registry();
    let seed = chaos_seed();
    eprintln!("steal chaos sweep: ECL_CHAOS_SEED={seed} (export to reproduce)");
    let bench = if reg.benches.contains_key("collatz") { "collatz" } else { "binomial" };
    for (i, (name, base)) in bases().into_iter().enumerate() {
        let want = no_steal_outputs(&reg, bench, base);
        for (j, kind) in steal_kinds(base).into_iter().enumerate() {
            let plan = FaultPlan::seeded_kill(
                seed.wrapping_add((i * 2 + j) as u64),
                3,
                2,
            );
            eprintln!("  case {name}/{}: plan={plan:?}", kind.label());
            check_steal_run(&reg, bench, &kind, Some(plan), &want);
        }
    }
}

/// With a single device there is no one to steal from — the policy must
/// be inert, not a hang or a self-steal.
#[test]
fn single_device_steal_is_inert() {
    let reg = registry();
    let kind = SchedulerKind::hguided().stealing(StealPolicy::Eager);
    let mut e = chaos_engine(&reg, "binomial", 1, kind, None);
    e.run().expect("single-device steal run");
    let report = e.report().unwrap();
    assert_eq!(report.steals_issued, 0, "no victim exists on a 1-device run");
    assert_eq!(report.stolen_packages(), 0);
    assert_exactly_once(report);
}

/// Results are stable across repetitions: thread timing may change
/// which steals fire, but never the bytes (the per-item outputs are
/// pure functions of the index).
#[test]
fn repeated_steal_runs_keep_outputs_stable() {
    let reg = registry();
    let bench = if reg.benches.contains_key("collatz") { "collatz" } else { "binomial" };
    let want = no_steal_outputs(&reg, bench, SchedulerKind::hguided);
    let kind = SchedulerKind::hguided().stealing(StealPolicy::Eager);
    for _ in 0..3 {
        check_steal_run(&reg, bench, &kind, None, &want);
    }
}
