//! PR-9 energy acceptance suite: exactly-once joule accounting under
//! faults, deterministic `BENCH_energy.json` emission, the warm-model
//! EDP guard, and the end-to-end energy-objective engine path
//! (EDP-refused devices surface as deliberate non-participants, not
//! imbalance).

use enginecl::coordinator::SchedulerKind;
use enginecl::harness::energy::{run_energy, EnergyBenchConfig, BENCH_POWER_CAP_W};
use enginecl::platform::fault::FaultPlan;
use enginecl::platform::NodeConfig;
use enginecl::runtime::ArtifactRegistry;
use enginecl::testing::{assert_exactly_once, chaos_engine};

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("artifact registry (synthetic fallback)")
}

/// Every traced package's joules must equal its device's busy watts
/// integrated over the occupancy window, and device/total accessors
/// must close over busy + idle.
fn assert_energy_consistent(report: &enginecl::coordinator::RunReport) {
    let wall = report.wall.as_secs_f64();
    let mut total = 0.0f64;
    for (i, d) in report.devices.iter().enumerate() {
        assert!(d.busy_watts > 0.0, "{}: profile watts must be plumbed", d.name);
        assert!(d.idle_watts > 0.0, "{}: idle watts must be plumbed", d.name);
        let mut busy_secs = 0.0f64;
        let mut busy_joules = 0.0f64;
        for p in &d.packages {
            let span = p.end.saturating_sub(p.start).as_secs_f64();
            assert!(
                (p.energy_j - d.busy_watts * span).abs() <= 1e-9 * d.busy_watts.max(1.0),
                "{} package {}..{}: {} J != {} W x {} s",
                d.name,
                p.begin_item,
                p.end_item,
                p.energy_j,
                d.busy_watts,
                span
            );
            busy_secs += span;
            busy_joules += p.energy_j;
        }
        let expect = busy_joules + d.idle_watts * (wall - busy_secs).max(0.0);
        let got = report.device_energy_j(i);
        assert!(
            (got - expect).abs() <= 1e-6 * expect.max(1.0),
            "{}: device energy {got} J != busy {busy_joules} + idle over slack",
            d.name
        );
        total += got;
    }
    let t = report.total_energy_j();
    assert!((t - total).abs() <= 1e-6 * total.max(1.0), "total energy must sum devices");
    assert!(t.is_finite() && t > 0.0);
    let shares = report.energy_shares();
    assert_eq!(shares.len(), report.devices.len());
    assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9, "shares normalize");
    assert!((report.edp() - t * wall).abs() <= 1e-6 * (t * wall).max(1.0));
}

/// Satellite 4: a recovered `kill:dev1@pkg2` run charges each
/// granule's joules exactly once — the dead device's unfinished
/// package never reaches a trace, the requeued replacement is billed
/// on its executing survivor, and the package ranges (the billing
/// keys) tile `[0, gws)` exactly.
#[test]
fn recovered_run_charges_joules_exactly_once() {
    let reg = registry();
    let plan = FaultPlan::parse("kill:dev1@pkg2").expect("valid fault spec");
    let mut e = chaos_engine(&reg, "binomial", 3, SchedulerKind::dynamic(4), Some(plan));
    e.run().expect("killed run must recover");
    let report = e.report().unwrap().clone();
    assert_eq!(report.faults.len(), 1, "the kill must fire");
    assert!(report.recovered());
    assert!(report.requeued_packages() >= 1, "reclaimed work surfaces as requeued packages");
    assert_exactly_once(&report);
    assert_energy_consistent(&report);
}

/// A fault-free run satisfies the same energy closure (the invariant
/// is not a recovery special case).
#[test]
fn fault_free_run_energy_is_consistent() {
    let reg = registry();
    let mut e = chaos_engine(&reg, "gaussian", 3, SchedulerKind::hguided(), None);
    e.run().expect("fault-free run");
    let report = e.report().unwrap().clone();
    assert_exactly_once(&report);
    assert_energy_consistent(&report);
}

/// End-to-end `adaptive:obj=edp` on batel: the Phi is EDP-inefficient
/// (300 W busy for 0.42 relative rate), so the scheduler refuses it
/// from the start; the engine must mark it `refused`, give it zero
/// packages, and exclude it from the balance metric instead of
/// reading deliberate shedding as imbalance.
#[test]
fn edp_objective_engine_run_sheds_and_marks_the_phi() {
    let reg = registry();
    let mut e = chaos_engine(&reg, "mandelbrot", 3, SchedulerKind::adaptive_edp(), None);
    e.run().expect("EDP-objective run");
    let report = e.report().unwrap().clone();
    assert_exactly_once(&report);
    assert_energy_consistent(&report);
    let phi = &report.devices[2];
    assert_eq!(phi.items(), 0, "the Phi must be EDP-refused on batel");
    assert!(phi.refused, "shed device must carry the refused mark");
    assert!(report.devices[0].items() > 0 && report.devices[1].items() > 0);
    // Deliberate shedding is not imbalance: the metric spans only the
    // two participants.
    assert!(
        report.balance_efficiency() > 0.0,
        "refused devices must not zero the balance metric"
    );
}

/// Satellite 4 (determinism half): same-seed sweeps are byte-identical
/// on the JSON artifact, across quick and full modes.
#[test]
fn same_seed_energy_bench_replays_byte_identical() {
    let reg = ArtifactRegistry::synthetic();
    let node = NodeConfig::batel();
    for quick in [false, true] {
        let cfg = EnergyBenchConfig { seed: 7, quick, ..Default::default() };
        let a = run_energy(&reg, &node, &cfg).unwrap().json();
        let b = run_energy(&reg, &node, &cfg).unwrap().json();
        assert_eq!(a, b, "BENCH_energy.json must be a pure function of the seed (quick={quick})");
    }
}

/// The CI reference point: seed 7 clears the guard (EDP superiority on
/// >= 4/5 kernels, a clean power-cap column).
#[test]
fn seed_seven_clears_the_energy_guard() {
    let reg = ArtifactRegistry::synthetic();
    let node = NodeConfig::batel();
    let cfg = EnergyBenchConfig { seed: 7, quick: false, ..Default::default() };
    let bench = run_energy(&reg, &node, &cfg).unwrap();
    bench.guard().unwrap_or_else(|e| panic!("guard failed:\n{e}\n{}", bench.json()));
    for c in bench.cells.iter().filter(|c| c.spec == "adaptive:power=400") {
        assert!(c.peak_power_w <= BENCH_POWER_CAP_W, "{}: {:.1} W", c.kernel, c.peak_power_w);
    }
}
