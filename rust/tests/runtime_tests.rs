//! Runtime-layer integration: artifact registry over the real manifest,
//! ChunkExecutor correctness (vs golden), decomposition round-trips and
//! the resident-vs-literal input ablation.

use enginecl::runtime::{
    decompose_range, host::max_abs_rel_err, ArtifactRegistry, ChunkExecutor, HostBuf,
};

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("run `make artifacts` before cargo test")
}

#[test]
fn registry_has_all_paper_benches() {
    let reg = registry();
    for b in ["gaussian", "binomial", "mandelbrot", "nbody", "ray1", "ray2", "ray3"] {
        assert!(reg.benches.contains_key(b), "missing {b}");
    }
}

#[test]
fn manifests_are_internally_consistent() {
    let reg = registry();
    for (name, b) in &reg.benches {
        assert!(b.n % b.granule == 0, "{name}: n not granule-aligned");
        assert!(b.chunks.contains_key(&b.granule), "{name}: no granule chunk");
        assert!(b.chunks.contains_key(&b.n), "{name}: no full-size chunk");
        for out in &b.outputs {
            assert_eq!(out.elems, b.n * out.elems_per_item, "{name}/{}", out.name);
        }
        // Greedy decomposition must close over every granule multiple.
        for mult in 1..=16usize {
            let len = mult * b.granule;
            if len <= b.n {
                let plan = decompose_range(b, 0, len).unwrap();
                assert_eq!(plan.iter().map(|(_, s)| s).sum::<usize>(), len);
            }
        }
    }
}

#[test]
fn executor_full_run_matches_golden() {
    let reg = registry();
    let manifest = reg.bench("binomial").unwrap().clone();
    let inputs = reg.golden_inputs(&manifest).unwrap();
    let golden = reg.golden_outputs(&manifest).unwrap();
    let mut exec = ChunkExecutor::new(&reg, &manifest, &inputs).unwrap();
    let mut outs = vec![HostBuf::zeros_f32(manifest.outputs[0].elems)];
    let timing = exec.execute_range(0, manifest.n, &mut outs).unwrap();
    assert_eq!(timing.launches, 1, "full problem is one launch");
    let (_, rel) = max_abs_rel_err(outs[0].as_f32().unwrap(), golden[0].as_f32().unwrap());
    assert!(rel < 1e-3, "rel err {rel}");
}

#[test]
fn executor_chunked_equals_full() {
    let reg = registry();
    let manifest = reg.bench("nbody").unwrap().clone();
    let inputs = reg.golden_inputs(&manifest).unwrap();
    let mut exec = ChunkExecutor::new(&reg, &manifest, &inputs).unwrap();

    let mut full = vec![
        HostBuf::zeros_f32(manifest.outputs[0].elems),
        HostBuf::zeros_f32(manifest.outputs[1].elems),
    ];
    exec.execute_range(0, manifest.n, &mut full).unwrap();

    let mut chunked = vec![
        HostBuf::zeros_f32(manifest.outputs[0].elems),
        HostBuf::zeros_f32(manifest.outputs[1].elems),
    ];
    let step = manifest.granule * 3; // forces greedy decomposition
    let mut off = 0;
    while off < manifest.n {
        let end = (off + step).min(manifest.n);
        exec.execute_range(off, end, &mut chunked).unwrap();
        off = end;
    }
    assert_eq!(full[0], chunked[0], "pos outputs identical");
    assert_eq!(full[1], chunked[1], "vel outputs identical");
}

#[test]
fn resident_and_literal_inputs_agree() {
    let reg = registry();
    let manifest = reg.bench("gaussian").unwrap().clone();
    let inputs = reg.golden_inputs(&manifest).unwrap();
    let gws = manifest.granule * 4;

    let mut a = ChunkExecutor::with_options(&reg, &manifest, &inputs, true).unwrap();
    let mut outs_a = vec![HostBuf::zeros_f32(manifest.outputs[0].elems)];
    a.execute_range(0, gws, &mut outs_a).unwrap();

    let mut b = ChunkExecutor::with_options(&reg, &manifest, &inputs, false).unwrap();
    let mut outs_b = vec![HostBuf::zeros_f32(manifest.outputs[0].elems)];
    b.execute_range(0, gws, &mut outs_b).unwrap();

    assert_eq!(outs_a[0], outs_b[0]);
}

#[test]
fn executor_rejects_bad_ranges() {
    let reg = registry();
    let manifest = reg.bench("binomial").unwrap().clone();
    let inputs = reg.golden_inputs(&manifest).unwrap();
    let mut exec = ChunkExecutor::new(&reg, &manifest, &inputs).unwrap();
    let mut outs = vec![HostBuf::zeros_f32(manifest.outputs[0].elems)];
    assert!(exec.execute_range(0, manifest.n + manifest.granule, &mut outs).is_err());
    assert!(exec.execute_range(13, 269, &mut outs).is_err()); // misaligned
    assert!(exec.execute_range(0, manifest.granule, &mut []).is_err()); // arity
}

#[test]
fn executor_rejects_wrong_input_shape() {
    let reg = registry();
    let manifest = reg.bench("binomial").unwrap().clone();
    let bad = vec![HostBuf::F32(vec![0.0; 10])];
    assert!(ChunkExecutor::new(&reg, &manifest, &bad).is_err());
}

#[test]
fn mandelbrot_chunk_cost_is_irregular() {
    // The *raw* execution time of equal-size chunks must differ strongly
    // between empty and interior regions — the property the dynamic
    // schedulers exploit (Figures 6, 9).
    let reg = registry();
    let manifest = reg.bench("mandelbrot").unwrap().clone();
    let mut exec = ChunkExecutor::new(&reg, &manifest, &[]).unwrap();
    let mut outs = vec![HostBuf::zeros_f32(manifest.outputs[0].elems)];
    let chunk = manifest.n / 8;
    // Warm up both executables.
    exec.execute_range(0, chunk, &mut outs).unwrap();
    let mut times = Vec::new();
    for i in 0..8 {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t = exec
                .execute_range(i * chunk, (i + 1) * chunk, &mut outs)
                .unwrap();
            best = best.min(t.exec.as_secs_f64());
        }
        times.push(best);
    }
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        max > 1.5 * min,
        "mandelbrot rows should have irregular cost: {times:?}"
    );
}

#[test]
fn golden_loaders_shape_check() {
    let reg = registry();
    for (_, b) in &reg.benches {
        let ins = reg.golden_inputs(b).unwrap();
        for (spec, buf) in b.inputs.iter().zip(&ins) {
            assert_eq!(buf.len(), spec.elems);
        }
        let outs = reg.golden_outputs(b).unwrap();
        for (spec, buf) in b.outputs.iter().zip(&outs) {
            assert_eq!(buf.len(), spec.elems);
        }
    }
}
