//! QoS property suite: the makespan predictor's grounding contract
//! across the kernel × scheduler grid, and the QoS × faults chaos
//! scenario (a deadlined session losing a device mid-run).
//!
//! The predictor contract under test (ISSUE-6 satellite): a *cold*
//! store never causes an admission rejection (its estimates carry no
//! absolute scale), and a *fully warm* store prices a solo re-run of
//! the same configuration within a wide error band of the realized
//! wall time — wide because these are real native-compute runs on a
//! shared CI machine, and the property is "the right order of
//! magnitude, priced from measured rates", not clock accuracy.

use std::time::Duration;

use enginecl::coordinator::lease::LeasePolicy;
use enginecl::coordinator::qos::{QosEvent, QosPolicy};
use enginecl::coordinator::runtime::Runtime;
use enginecl::coordinator::SchedulerKind;
use enginecl::harness::balance::balance_kernels;
use enginecl::platform::fault::FaultPlan;
use enginecl::platform::NodeConfig;
use enginecl::runtime::ArtifactRegistry;
use enginecl::testing::{assert_exactly_once, chaos_seed, chaos_session};

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("artifact registry (synthetic fallback)")
}

fn qos_runtime(reg: &ArtifactRegistry, seed: u64) -> Runtime {
    Runtime::qos_configured(
        reg.clone(),
        NodeConfig::batel(),
        LeasePolicy::Rotation,
        usize::MAX,
        seed,
        QosPolicy::enabled(),
    )
}

/// Granule-aligned quarter problem size — keeps the 5 × 3 × 2 grid of
/// real runs fast while every device still sees work.
fn quarter_gws(reg: &ArtifactRegistry, bench: &str) -> usize {
    let m = reg.bench(bench).unwrap();
    (m.n / m.granule / 4).max(1) * m.granule
}

/// The scheduler axis of the predictor grid.
fn predictor_kinds() -> Vec<SchedulerKind> {
    vec![SchedulerKind::static_default(), SchedulerKind::hguided(), SchedulerKind::adaptive()]
}

/// Cold store: estimates are flagged cold, and even an absurd deadline
/// must not be rejected at admission — the session runs (and misses)
/// instead. Warm store: the estimate is fully warm and brackets the
/// realized solo wall time within the error band.
#[test]
fn predictor_grounding_across_the_grid() {
    let reg = registry();
    let seed = chaos_seed();
    eprintln!("predictor grid: ECL_CHAOS_SEED={seed} (export to reproduce)");
    for kernel in balance_kernels() {
        for kind in predictor_kinds() {
            let label = format!("{kernel}/{}", kind.label());
            let rt = qos_runtime(&reg, seed);
            let gws = quarter_gws(&reg, kernel);

            // --- cold leg -------------------------------------------
            let spec = chaos_session(&reg, kernel, 3, kind.clone(), None)
                .gws(gws)
                .deadline(Duration::from_nanos(1));
            let est = rt.predict_session(&spec).expect("well-formed spec prices");
            assert!(est.cold(), "{label}: fresh runtime store must price cold");
            assert!(!est.fully_warm(), "{label}: cold estimate must not clear the reject bar");
            let outcome = rt.submit(spec).wait();
            let report = outcome.result.as_ref().unwrap_or_else(|e| {
                panic!("{label}: cold store must never reject or fail a session: {e}")
            });
            assert_exactly_once(report);
            assert_eq!(
                outcome.met_deadline(),
                Some(false),
                "{label}: the 1ns deadline was of course missed — but served, not rejected"
            );

            // --- warm leg -------------------------------------------
            let spec = chaos_session(&reg, kernel, 3, kind.clone(), None).gws(gws);
            let est = rt.predict_session(&spec).expect("well-formed spec prices");
            assert!(
                est.fully_warm(),
                "{label}: one completed session must warm all 3 devices \
                 ({}/{} warm)",
                est.warm_devices,
                est.devices
            );
            let outcome = rt.submit(spec).wait();
            let report = outcome.result.as_ref().unwrap_or_else(|e| panic!("{label}: {e}"));
            let realized = report.wall.as_secs_f64().max(1e-9);
            let ratio = est.secs / realized;
            assert!(
                (0.02..=50.0).contains(&ratio),
                "{label}: warm prediction {:.6}s vs realized {:.6}s (ratio {ratio:.3}) \
                 outside the error band",
                est.secs,
                realized
            );
            rt.wait_idle();
        }
    }
}

/// QoS × faults: a deadlined session loses device 1 at its third
/// package while a best-effort session shares the node. The runtime
/// must recover the kill (exactly-once, solo-identical outputs), and
/// either meet the deadline or visibly shed/flag: with an unmeetable
/// deadline the controller journals the at-risk transition (and pauses
/// the best-effort victim when one is running). The scenario replays
/// under the pinned `ECL_CHAOS_SEED` with byte-identical outputs.
#[test]
fn deadlined_session_surviving_kill_meets_or_sheds() {
    let reg = registry();
    let seed = chaos_seed();
    eprintln!("qos chaos: ECL_CHAOS_SEED={seed} (export to reproduce)");

    let run_once = || {
        let rt = qos_runtime(&reg, seed);
        let best_effort =
            chaos_session(&reg, "gaussian", 3, SchedulerKind::dynamic(8), None).label("be");
        let deadlined =
            chaos_session(&reg, "binomial", 3, SchedulerKind::dynamic(10), Some(FaultPlan::kill(1, 2)))
                .label("dl")
                .deadline(Duration::from_nanos(1));
        let handles = rt.submit_all(vec![best_effort, deadlined]);
        let outcomes: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
        rt.wait_idle();
        let journal = rt.qos().journal();
        assert_eq!(rt.qos().paused_count(), 0, "no victim stays paused after the batch");
        (outcomes, journal)
    };

    let (outcomes, journal) = run_once();
    let be = &outcomes[0];
    let dl = &outcomes[1];

    let dr = dl.result.as_ref().expect("deadlined session must recover from the kill");
    assert!(dr.recovered(), "the dev1 kill was recovered by survivors");
    assert!(dr.requeued_packages() >= 1, "reclaimed work was requeued");
    assert_exactly_once(dr);

    let br = be.result.as_ref().expect("best-effort session completes despite shedding");
    assert!(br.faults.is_empty(), "the fault must not leak into the best-effort session");
    assert_exactly_once(br);

    // Met-or-shed: the 1ns deadline cannot be met, so the controller
    // must have flagged the session at risk (shedding the best-effort
    // victim if it was still running at that moment).
    let met = dl.met_deadline() == Some(true);
    let at_risk = journal.iter().any(|e| matches!(e, QosEvent::AtRisk { .. }));
    assert!(met || at_risk, "unmet deadline without an at-risk journal entry: {journal:?}");
    // A pause (if one fired) is always paired with a resume.
    let paused = journal.iter().filter(|e| matches!(e, QosEvent::Paused { .. })).count();
    let resumed = journal.iter().filter(|e| matches!(e, QosEvent::Resumed { .. })).count();
    assert_eq!(paused, resumed, "every shed victim resumes: {journal:?}");

    // Replay under the same pinned seed: byte-identical outputs.
    let (outcomes2, _) = run_once();
    for (a, b) in outcomes.iter().zip(&outcomes2) {
        let n = a.program.outputs().len();
        for i in 0..n {
            assert!(
                a.output(i).unwrap() == b.output(i).unwrap(),
                "{}: output {i} differs between same-seed replays",
                a.label
            );
        }
    }
}

/// ISSUE-8 satellite: a poisoned performance store — non-finite or
/// non-positive rates, e.g. a corrupt persisted snapshot or a
/// zero-duration timing artifact — must never block admission or leak
/// a non-finite makespan estimate. The predictor filters poisoned
/// rates down to the imputation path (poisoned ≠ warm), so the
/// deadlined session is admitted under the cold-store rule and runs to
/// completion.
#[test]
fn poisoned_perf_store_never_blocks_admission() {
    let reg = registry();
    let rt = qos_runtime(&reg, 0x9015);
    for bad in [f64::INFINITY, f64::NAN, 0.0, -5.0] {
        for d in &NodeConfig::batel().devices {
            rt.perf_model().force_estimate("binomial", &d.name, bad, 10);
        }
        let spec = chaos_session(&reg, "binomial", 3, SchedulerKind::dynamic(8), None)
            .gws(quarter_gws(&reg, "binomial"))
            // Unfittably tight: only a (bogus) fully-warm prediction
            // could reject this — the poisoned store must not be one.
            .deadline(Duration::from_millis(1))
            .label(&format!("poisoned-{bad}"));
        if let Some(est) = rt.predict_session(&spec) {
            assert!(est.secs.is_finite(), "estimate leaked non-finite secs from rate {bad}");
            assert!(!est.fully_warm(), "poisoned rates (rate {bad}) must not count as warm");
        }
        let outcome = rt.submit(spec).wait();
        assert!(
            outcome.result.is_ok(),
            "poisoned store (rate {bad}) must not reject or break the session: {:?}",
            outcome.result.as_ref().err()
        );
    }
}
