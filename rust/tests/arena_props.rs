//! Property tests for the zero-copy output arena: for *arbitrary*
//! schedules produced by the real schedulers, the claimed windows must
//! be pairwise disjoint, granule-aligned, and exactly cover `[0, n)` —
//! the invariants that make the workers' direct (lock-free) writes into
//! shared memory sound.

use enginecl::coordinator::scheduler::{
    Adaptive, Dynamic, HGuided, Pipelined, SchedDevice, Scheduler, Static,
};
use enginecl::coordinator::Range;
use enginecl::prop_assert;
use enginecl::runtime::OutputArena;
use enginecl::testing::forall;
use enginecl::util::rng::XorShift;

#[derive(Debug)]
struct Case {
    total_granules: usize,
    granule: usize,
    powers: Vec<f64>,
    sched: usize, // 0 static, 1 static-rev, 2 dynamic, 3 adaptive, 4 hguided
    packages: usize,
    k: f64,
    min_granules: usize,
    pipelined: bool,
    /// Output geometry: elems per item, per output buffer.
    epis: Vec<usize>,
    seed: u64,
}

fn gen_case(r: &mut XorShift) -> Case {
    let ndev = r.range(1, 4);
    let nouts = r.range(1, 3);
    Case {
        total_granules: r.range(1, 1024),
        granule: [1, 16, 64, 256][r.below(4)],
        powers: (0..ndev).map(|_| 0.05 + r.next_f64()).collect(),
        sched: r.below(5),
        packages: r.range(1, 200),
        k: 1.0 + r.next_f64() * 4.0,
        min_granules: r.range(1, 8),
        pipelined: r.below(2) == 1,
        epis: (0..nouts).map(|_| r.range(1, 5)).collect(),
        seed: r.next_u64(),
    }
}

fn build(case: &Case) -> Box<dyn Scheduler> {
    let base: Box<dyn Scheduler> = match case.sched {
        0 => Box::new(Static::new(None, false)),
        1 => Box::new(Static::new(None, true)),
        2 => Box::new(Dynamic::new(case.packages)),
        3 => Box::new(Adaptive::new(case.k, case.min_granules, 0.5)),
        _ => Box::new(HGuided::new(case.k, case.min_granules)),
    };
    if case.pipelined {
        Box::new(Pipelined::new(base, 2))
    } else {
        base
    }
}

/// Drain the scheduler with a random device interleaving (devices
/// "finish" in seed-dependent order), returning all assigned ranges.
fn drain(case: &Case) -> Vec<Range> {
    let devs: Vec<SchedDevice> = case
        .powers
        .iter()
        .enumerate()
        .map(|(i, p)| SchedDevice::new(format!("d{i}"), *p))
        .collect();
    let mut s = build(case);
    s.start(case.total_granules, case.granule, &devs);
    let mut rng = XorShift::new(case.seed);
    let mut active: Vec<usize> = (0..devs.len()).collect();
    let mut out = Vec::new();
    while !active.is_empty() {
        let pick = rng.below(active.len());
        let dev = active[pick];
        match s.next_package(dev) {
            Some(r) => {
                // Feed seed-dependent feedback so adaptive strategies
                // exercise their re-sizing paths — the cover invariants
                // must hold whatever the observations say.
                let span = std::time::Duration::from_micros(1 + rng.below(5_000) as u64);
                s.observe(
                    dev,
                    r,
                    enginecl::coordinator::scheduler::PackageTiming {
                        span,
                        raw_exec: span / 4,
                    },
                );
                out.push(r);
            }
            None => {
                active.remove(pick);
            }
        }
    }
    out
}

fn arena_for(case: &Case) -> OutputArena {
    let n = case.total_granules * case.granule;
    OutputArena::new(
        case.epis.iter().map(|&e| (vec![0.0f32; n * e], e)).collect(),
        case.granule,
        n,
    )
    .unwrap()
}

#[test]
fn prop_arena_accepts_every_scheduler_cover() {
    forall("arena accepts scheduler covers", gen_case, |case| {
        let n = case.total_granules * case.granule;
        let arena = arena_for(case);
        for r in drain(case) {
            // Every claim must succeed: the schedulers promise disjoint
            // granule-aligned ranges, and the arena enforces exactly that.
            if let Err(e) = arena.claim(r.begin, r.end) {
                return Err(format!("claim {r:?} rejected: {e:#}"));
            }
        }
        prop_assert!(
            arena.claimed_items() == n,
            "claims cover {} of {n} items",
            arena.claimed_items()
        );
        // Sorted claims must tile [0, n) exactly: contiguous, aligned,
        // no gaps, no overlaps.
        let mut cursor = 0usize;
        for (b, e) in arena.claimed_ranges() {
            prop_assert!(b == cursor, "gap or overlap at {b} (expected {cursor})");
            prop_assert!(
                b % case.granule == 0 && e % case.granule == 0,
                "claim {b}..{e} misaligned to granule {}",
                case.granule
            );
            cursor = e;
        }
        prop_assert!(cursor == n, "claims stop at {cursor}, want {n}");
        Ok(())
    });
}

#[test]
fn prop_arena_rejects_any_double_claim() {
    forall("arena rejects double claims", gen_case, |case| {
        let arena = arena_for(case);
        let ranges = drain(case);
        for r in &ranges {
            arena.claim(r.begin, r.end).map_err(|e| format!("{e:#}"))?;
        }
        // Re-claiming any already-claimed range (a buggy scheduler
        // double-assigning work) must be rejected, not silently aliased.
        let mut rng = XorShift::new(case.seed ^ 0xDEAD);
        for _ in 0..ranges.len().min(8) {
            let r = &ranges[rng.below(ranges.len())];
            prop_assert!(
                arena.claim(r.begin, r.end).is_err(),
                "double claim {r:?} accepted"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_windows_map_to_exactly_once_memory() {
    forall("window writes land exactly once", gen_case, |case| {
        let n = case.total_granules * case.granule;
        let arena = arena_for(case);
        // Write package-index markers through every window; each output
        // element must end up written exactly once with its range's
        // marker — the memory-level statement of the exactly-once
        // scheduling invariant.
        let ranges = drain(case);
        for (idx, r) in ranges.iter().enumerate() {
            let mut windows = arena.claim(r.begin, r.end).map_err(|e| format!("{e:#}"))?;
            for w in &mut windows {
                w.as_mut_slice().fill(idx as f32 + 1.0);
            }
        }
        let bufs = arena.into_buffers();
        for (buf, &epi) in bufs.iter().zip(&case.epis) {
            prop_assert!(buf.len() == n * epi, "buffer length changed");
            for (idx, r) in ranges.iter().enumerate() {
                let lo = r.begin * epi;
                let hi = r.end * epi;
                prop_assert!(
                    buf[lo..hi].iter().all(|&x| x == idx as f32 + 1.0),
                    "range {r:?} not fully owned by its writer"
                );
            }
        }
        Ok(())
    });
}
