//! Chaos suite: deterministic fault injection against the engine's
//! recovery path.
//!
//! The acceptance invariant: for every kernel × scheduler spec (the
//! three paper schedulers and their `+pipe` variants) × a single-device
//! kill point, the faulted run **completes**, its outputs are
//! **bit-identical** to the fault-free run, and the trace ledger is
//! **exactly-once** (the surviving packages plus the requeued ones tile
//! `[0, gws)` with no gap and no overlap).
//!
//! Seeded sweeps log `ECL_CHAOS_SEED` so a CI failure is reproducible
//! locally by exporting the same value.

use std::collections::VecDeque;
use std::time::Duration;

use enginecl::coordinator::scheduler::{EnergyObjective, SchedDevice, SchedulerKind};
use enginecl::coordinator::work::{split_range, Range};
use enginecl::coordinator::{EclError, Engine};
use enginecl::platform::fault::{FaultKind, FaultPlan, FaultTrigger};
use enginecl::runtime::exec::FAULT_POISON;
use enginecl::runtime::ArtifactRegistry;
use enginecl::testing::{assert_exactly_once, chaos_engine, chaos_seed, forall};
use enginecl::util::rng::XorShift;

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("artifact registry (synthetic fallback)")
}

const KERNELS: [&str; 5] = ["binomial", "gaussian", "mandelbrot", "nbody", "ray1"];

/// The sweep list: the paper kernels, plus the heavy-tailed `collatz`
/// straggler workload when the registry carries it (always true for the
/// synthetic registry; disk manifests predating PR-10 may lack it).
fn sweep_kernels(reg: &ArtifactRegistry) -> Vec<&'static str> {
    let mut kernels: Vec<&'static str> = KERNELS.to_vec();
    if reg.benches.contains_key("collatz") {
        kernels.push("collatz");
    }
    kernels
}

/// Fault-free reference outputs for `bench` under `kind` (3 devices).
fn baseline_outputs(reg: &ArtifactRegistry, bench: &str, kind: &SchedulerKind) -> Vec<Vec<f32>> {
    let mut e = chaos_engine(reg, bench, 3, kind.clone(), None);
    e.run().expect("fault-free baseline run");
    let n = reg.bench(bench).unwrap().outputs.len();
    (0..n).map(|i| e.output(i).unwrap().to_vec()).collect()
}

/// Run `bench` under `kind` with `plan` injected and assert the
/// recovery contract against precomputed fault-free outputs.
/// `expect_revoked = Some(n)`: the plan *must* fire exactly one fault
/// that revoked `n` arena claims; `None`: the fault may or may not fire
/// (late kill points on adaptive schedulers), assert conditionally.
fn check_faulted_against(
    reg: &ArtifactRegistry,
    bench: &str,
    kind: &SchedulerKind,
    plan: FaultPlan,
    expect_revoked: Option<usize>,
    want: &[Vec<f32>],
) {
    let label = kind.label();
    let mut e = chaos_engine(reg, bench, 3, kind.clone(), Some(plan.clone()));
    e.run().unwrap_or_else(|err| {
        panic!("{bench}/{label}: faulted run must recover (plan {plan:?}): {err}")
    });
    let report = e.report().unwrap().clone();
    for (i, w) in want.iter().enumerate() {
        let got = e.output(i).unwrap();
        assert!(
            got == &w[..],
            "{bench}/{label}: output {i} not bit-identical to the fault-free run (plan {plan:?})"
        );
        assert!(got.iter().all(|&x| x != FAULT_POISON), "{bench}/{label}: poison survived");
    }
    assert_exactly_once(&report);
    match expect_revoked {
        Some(revoked) => {
            assert_eq!(report.faults.len(), 1, "{bench}/{label}: exactly one fault event");
            let f = &report.faults[0];
            assert!(f.recovered, "{bench}/{label}: fault must be recovered");
            assert!(f.reclaimed_items > 0, "{bench}/{label}: a killed package reclaims work");
            assert_eq!(f.revoked_claims, revoked, "{bench}/{label}: revoked claims");
            assert!(report.recovered());
            assert!(
                report.requeued_packages() >= 1,
                "{bench}/{label}: reclaimed work must surface as requeued packages"
            );
            assert_eq!(report.requeued_items(), f.reclaimed_items);
        }
        None => {
            for f in &report.faults {
                assert!(f.recovered, "{bench}/{label}: {:?} not recovered", f.message);
            }
        }
    }
}

fn check_faulted(
    reg: &ArtifactRegistry,
    bench: &str,
    kind: SchedulerKind,
    plan: FaultPlan,
    expect_revoked: Option<usize>,
) {
    let want = baseline_outputs(reg, bench, &kind);
    check_faulted_against(reg, bench, &kind, plan, expect_revoked, &want);
}

/// The acceptance sweep body: kill the second device at its first
/// package, for every kernel.
fn kill_sweep(kind: SchedulerKind) {
    let reg = registry();
    for bench in sweep_kernels(&reg) {
        check_faulted(&reg, bench, kind.clone(), FaultPlan::kill(1, 0), Some(1));
    }
}

#[test]
fn kill_recovery_static() {
    kill_sweep(SchedulerKind::static_default());
}

#[test]
fn kill_recovery_dynamic() {
    kill_sweep(SchedulerKind::dynamic(12));
}

#[test]
fn kill_recovery_hguided() {
    kill_sweep(SchedulerKind::hguided());
}

#[test]
fn kill_recovery_static_pipe() {
    kill_sweep(SchedulerKind::static_default().pipelined(2));
}

#[test]
fn kill_recovery_dynamic_pipe() {
    kill_sweep(SchedulerKind::dynamic(12).pipelined(2));
}

#[test]
fn kill_recovery_hguided_pipe() {
    kill_sweep(SchedulerKind::hguided().pipelined(2));
}

/// The feedback-driven scheduler through the batched dispatch path:
/// adaptive package sizing is timing-dependent, but a kill at the
/// second device's first package (its probe) always fires, and the
/// recovery contract — bit-identical outputs, exactly-once ledger, one
/// recovered fault — is timing-independent.
#[test]
fn kill_recovery_adaptive() {
    kill_sweep(SchedulerKind::adaptive());
}

#[test]
fn kill_recovery_adaptive_pipe() {
    kill_sweep(SchedulerKind::adaptive().pipelined(2));
}

/// Any device may die, and at a later package too (late kill points may
/// not fire on adaptive schedulers — then the run is simply fault-free,
/// which the conditional contract accepts).
#[test]
fn kill_any_device_any_early_point() {
    let reg = registry();
    for kind in [SchedulerKind::dynamic(12), SchedulerKind::hguided()] {
        let want = baseline_outputs(&reg, "binomial", &kind);
        for dev in 0..3usize {
            for pkg in [0usize, 1] {
                let expect = if pkg == 0 { Some(1) } else { None };
                check_faulted_against(
                    &reg,
                    "binomial",
                    &kind,
                    FaultPlan::kill(dev, pkg),
                    expect,
                    &want,
                );
            }
        }
    }
}

/// Seeded chaos: the kill point is derived from `ECL_CHAOS_SEED`
/// (logged, so a CI failure reproduces locally with the same env).
#[test]
fn seeded_chaos_sweep_reproducible_from_logged_seed() {
    let reg = registry();
    let seed = chaos_seed();
    eprintln!("chaos sweep: ECL_CHAOS_SEED={seed} (export to reproduce)");
    let kinds = [
        SchedulerKind::dynamic(12),
        SchedulerKind::hguided(),
        SchedulerKind::dynamic(8).pipelined(2),
    ];
    for (i, kind) in kinds.iter().enumerate() {
        let plan = FaultPlan::seeded_kill(seed.wrapping_add(i as u64), 3, 2);
        eprintln!("  case {i}: scheduler={} plan={plan:?}", kind.label());
        check_faulted(&reg, "gaussian", kind.clone(), plan, None);
    }
}

// ---- golden-trace determinism ----------------------------------------

fn trace_signature(e: &Engine) -> Vec<Vec<(usize, usize, bool)>> {
    e.report()
        .unwrap()
        .devices
        .iter()
        .map(|d| d.packages.iter().map(|p| (p.begin_item, p.end_item, p.requeued)).collect())
        .collect()
}

/// Same seed + same `FaultPlan` ⇒ identical `RunReport` package
/// sequences across repeated multi-threaded runs, for configurations
/// whose package→device binding is structurally deterministic: Static's
/// pre-split with a *single* survivor (it pulls every reclaimed piece
/// in queue order), and single-device runs (pure FIFO).
#[test]
fn golden_trace_determinism_under_fixed_plan() {
    let reg = registry();

    // Two devices, Static, kill the second at its first package.
    let mut sigs = Vec::new();
    for _ in 0..4 {
        let mut e = chaos_engine(
            &reg,
            "binomial",
            2,
            SchedulerKind::static_default(),
            Some(FaultPlan::kill(1, 0)),
        );
        e.run().expect("2-device static kill recovers");
        assert!(e.report().unwrap().recovered());
        sigs.push(trace_signature(&e));
    }
    for (i, s) in sigs.iter().enumerate().skip(1) {
        assert_eq!(s, &sigs[0], "static-kill trace diverged on repetition {i}");
    }
    // The survivor ran its own share plus exactly one reclaimed piece
    // (single survivor → the dead share is not split). Whether the own
    // package or the reclaimed piece executes first is OS-scheduling
    // dependent, so only the content is asserted here — the cross-run
    // equality above is what pins the sequence.
    let survivor = &sigs[0][0];
    assert!(survivor.len() >= 2);
    assert_eq!(
        survivor.iter().filter(|p| p.2).count(),
        1,
        "exactly one reclaimed piece for a single survivor"
    );
    assert!(sigs[0][1].is_empty(), "the killed device completed nothing");

    // Single device, transient faults: FIFO, trivially reproducible —
    // but it must actually reproduce, stalls and slowdowns included.
    let mut sigs = Vec::new();
    for _ in 0..3 {
        let plan = FaultPlan::stall(0, 2, Duration::from_millis(5)).with(
            0,
            FaultKind::Slowdown(2.0),
            FaultTrigger::Package(4),
        );
        let mut e = chaos_engine(&reg, "gaussian", 1, SchedulerKind::dynamic(9), Some(plan));
        e.run().expect("transient faults never fail a run");
        sigs.push(trace_signature(&e));
    }
    for s in &sigs[1..] {
        assert_eq!(s, &sigs[0], "single-device trace must reproduce");
    }
}

// ---- failure-mode regressions ----------------------------------------

/// A worker panic is caught, surfaced as `EclError::Worker`, and leaves
/// the engine reusable: the next `run()` succeeds (regression for the
/// seed's silent hang-then-generic-error on panicking workers).
#[test]
fn panic_surfaces_worker_error_and_engine_stays_usable() {
    let reg = registry();
    let kind = SchedulerKind::dynamic(6);
    let mut e = chaos_engine(&reg, "binomial", 1, kind.clone(), Some(FaultPlan::panic_at(0, 1)));
    assert!(e.run().is_err(), "a single-device panic cannot be recovered");
    match &e.get_errors()[0] {
        EclError::Worker { message, .. } => {
            assert!(message.contains("panic"), "panic payload surfaced: {message}")
        }
        other => panic!("want EclError::Worker, got: {other}"),
    }
    // Reusable: clear the plan, run again, results are correct.
    e.configurator().fault_plan = None;
    e.run().expect("engine must be reusable after a worker failure");
    let want = baseline_outputs(&reg, "binomial", &kind);
    assert_eq!(e.output(0).unwrap(), &want[0][..]);
}

/// A worker that exits without sending *anything* (the "vanish" mode —
/// a segfaulting driver) is noticed by the master's liveness sweep and
/// its work recovered by the survivors.
#[test]
fn vanished_worker_is_detected_and_recovered() {
    let reg = registry();
    // Vanish at package 0: no claim was taken (revoked = 0), but the
    // assigned range must still be reclaimed and requeued.
    check_faulted(&reg, "gaussian", SchedulerKind::dynamic(10), FaultPlan::vanish(1, 0), Some(0));
}

/// Vanish-detection latency regression (PR-7): the master's liveness
/// sweep is now driven by an adaptive poll derived from observed
/// package times, clamped to [5 ms, 250 ms]. A silently-dead worker
/// must therefore still be noticed within a bounded number of poll
/// ticks — if the adaptive interval ever escaped its clamp (or the
/// sweep stopped running), this small recovered run would stretch far
/// past the generous wall-clock bound.
#[test]
fn vanish_detection_latency_is_bounded() {
    let reg = registry();
    let kind = SchedulerKind::dynamic(10);
    let want = baseline_outputs(&reg, "gaussian", &kind);
    let t0 = std::time::Instant::now();
    check_faulted_against(&reg, "gaussian", &kind, FaultPlan::vanish(1, 0), Some(0), &want);
    let wall = t0.elapsed();
    assert!(
        wall < Duration::from_secs(10),
        "vanish recovery took {wall:?} — the liveness poll must stay clamped \
         (max 250 ms per tick)"
    );
}

/// With no survivors, a vanished worker surfaces as a dead-channel
/// `EclError::Worker` — and the engine stays reusable.
#[test]
fn vanish_single_device_is_a_dead_channel_worker_error() {
    let reg = registry();
    let mut e =
        chaos_engine(&reg, "binomial", 1, SchedulerKind::dynamic(4), Some(FaultPlan::vanish(0, 0)));
    assert!(e.run().is_err());
    match &e.get_errors()[0] {
        EclError::Worker { message, .. } => {
            assert!(message.contains("without reporting"), "{message}")
        }
        other => panic!("want EclError::Worker, got: {other}"),
    }
    e.configurator().fault_plan = None;
    e.run().expect("engine must be reusable after a silent worker death");
}

/// `fault_tolerant = false` restores the seed's abort-on-failure
/// semantics: the run errors with `EclError::Worker`.
#[test]
fn fault_tolerance_off_restores_abort_semantics() {
    let reg = registry();
    let mut e = chaos_engine(
        &reg,
        "binomial",
        3,
        SchedulerKind::dynamic(8),
        Some(FaultPlan::kill(1, 0)),
    );
    e.configurator().fault_tolerant = false;
    assert!(e.run().is_err());
    assert!(
        matches!(&e.get_errors()[0], EclError::Worker { .. }),
        "want EclError::Worker, got {:?}",
        e.get_errors()
    );
}

/// A plan naming a device slot outside the selection is a
/// configuration error, not a silently-clean run — the chaos run would
/// otherwise "pass" without ever exercising recovery.
#[test]
fn fault_plan_for_missing_device_is_rejected() {
    let reg = registry();
    let mut e = chaos_engine(
        &reg,
        "binomial",
        3,
        SchedulerKind::dynamic(8),
        Some(FaultPlan::kill(5, 0)),
    );
    assert!(e.run().is_err());
    assert!(
        e.get_errors()[0].to_string().contains("fault plan targets device slot 5"),
        "got: {:?}",
        e.get_errors()
    );
}

/// Stalls and slowdowns are transient: timing changes, results do not,
/// and no fault event is recorded (nothing failed).
#[test]
fn transient_faults_change_timing_not_results() {
    let reg = registry();
    let kind = SchedulerKind::dynamic(10);
    let want = baseline_outputs(&reg, "binomial", &kind);
    let plan = FaultPlan::stall(1, 0, Duration::from_millis(20)).with(
        2,
        FaultKind::Slowdown(3.0),
        FaultTrigger::Package(0),
    );
    let mut e = chaos_engine(&reg, "binomial", 3, kind, Some(plan));
    e.run().expect("transient faults must not fail the run");
    assert_eq!(e.output(0).unwrap(), &want[0][..]);
    let report = e.report().unwrap();
    assert!(report.faults.is_empty(), "stall/slowdown are not failures");
    assert_exactly_once(report);
}

// ---- requeue partition property --------------------------------------

/// Simulate the master's requeue protocol against a scheduler (the same
/// `split_range` + reclaim logic the engine uses) and check the
/// partition invariant directly, over randomized device counts,
/// granules, problem sizes, schedulers and kill points.
fn simulate_cover_with_kill(
    kind: &SchedulerKind,
    powers: &[f64],
    total_granules: usize,
    granule: usize,
    kill_dev: usize,
    kill_ordinal: usize,
) -> Result<(), String> {
    let ndev = powers.len();
    let mut sched = kind.build();
    let devs: Vec<SchedDevice> = powers
        .iter()
        .enumerate()
        .map(|(i, p)| SchedDevice::new(format!("d{i}"), *p))
        .collect();
    sched.start(total_granules, granule, &devs);

    let mut alive = vec![true; ndev];
    let mut started = vec![0usize; ndev];
    let mut requeue: VecDeque<Range> = VecDeque::new();
    let mut executed: Vec<(usize, usize)> = Vec::new();
    loop {
        let mut progress = false;
        for d in 0..ndev {
            if !alive[d] {
                continue;
            }
            let next = requeue.pop_front().or_else(|| sched.next_package(d));
            let Some(r) = next else { continue };
            progress = true;
            if d == kill_dev && started[d] == kill_ordinal {
                // d dies holding r: reclaim it plus any scheduler
                // reservation, split among the survivors.
                alive[d] = false;
                let mut reclaimed = vec![r];
                reclaimed.extend(sched.reclaim_device(d));
                let survivors = alive.iter().filter(|&&a| a).count();
                if survivors == 0 {
                    return Err("kill left no survivors".into());
                }
                for rr in reclaimed {
                    for piece in split_range(rr.begin, rr.end, survivors, granule) {
                        requeue.push_back(piece);
                    }
                }
            } else {
                started[d] += 1;
                executed.push((r.begin, r.end));
            }
        }
        if !progress {
            break;
        }
    }

    executed.sort_unstable();
    let mut cursor = 0usize;
    for (b, e) in &executed {
        if *b != cursor || e <= b {
            return Err(format!("gap/overlap at item {cursor}: range {b}..{e}"));
        }
        cursor = *e;
    }
    let total = total_granules * granule;
    if cursor != total {
        return Err(format!("cover ends at {cursor}, want {total}"));
    }
    Ok(())
}

#[derive(Debug)]
struct CoverCase {
    kind: SchedulerKind,
    powers: Vec<f64>,
    total_granules: usize,
    granule: usize,
    kill_dev: usize,
    kill_ordinal: usize,
}

/// Property: HGuided and Dynamic (and Static, with `reclaim_device`)
/// always produce a complete, non-overlapping cover of `[0, gws)` —
/// including after a mid-run kill and requeue.
#[test]
fn schedulers_cover_exactly_even_after_requeue() {
    let gen = |rng: &mut XorShift| {
        let kind = match rng.below(4) {
            0 => SchedulerKind::static_default(),
            1 => SchedulerKind::dynamic(rng.range(1, 40)),
            2 => SchedulerKind::Adaptive {
                k: 1.0 + rng.next_f64() * 3.0,
                min_granules: rng.range(1, 4),
                alpha: 0.5,
                objective: EnergyObjective::Time,
                power_cap: None,
            },
            _ => SchedulerKind::HGuided {
                k: 1.0 + rng.next_f64() * 3.0,
                min_granules: rng.range(1, 4),
                feedback: rng.below(2) == 1,
            },
        };
        let ndev = rng.range(2, 4);
        let powers: Vec<f64> = (0..ndev).map(|_| 0.1 + rng.next_f64()).collect();
        CoverCase {
            kind,
            powers,
            total_granules: rng.range(1, 300),
            granule: [1, 8, 64][rng.below(3)],
            kill_dev: rng.below(ndev),
            kill_ordinal: rng.below(4),
        }
    };
    forall("cover-after-requeue", gen, |c: &CoverCase| {
        simulate_cover_with_kill(
            &c.kind,
            &c.powers,
            c.total_granules,
            c.granule,
            c.kill_dev,
            c.kill_ordinal,
        )
    });
}
