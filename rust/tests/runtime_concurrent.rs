//! Concurrency battery: the persistent runtime's concurrent run
//! sessions over one device set.
//!
//! The contract under test, per session: outputs **bit-identical** to
//! the same session run solo, an **exactly-once** trace ledger (the
//! packages tile `[0, gws)` with no gap and no overlap), and — across
//! sessions — device leases that are mutually exclusive, starvation-free,
//! reclaimed on worker death, and (under the rotation policy)
//! deterministic per device for a fixed seed and admission order.
//!
//! Seeded sweeps log `ECL_CHAOS_SEED` so a CI failure is reproducible
//! locally by exporting the same value.

use std::collections::BTreeMap;
use std::time::Duration;

use enginecl::coordinator::lease::{GrantRecord, LeasePolicy, SessionId};
use enginecl::coordinator::qos::{QosPolicy, STARVATION_BOUND};
use enginecl::coordinator::runtime::RunSession;
use enginecl::coordinator::SchedulerKind;
use enginecl::harness::concurrent::{measure_config, run_concurrent, SessionSpec};
use enginecl::platform::fault::FaultPlan;
use enginecl::platform::NodeConfig;
use enginecl::runtime::ArtifactRegistry;
use enginecl::testing::{
    assert_exactly_once, chaos_engine, chaos_runtime, chaos_seed, chaos_session,
    trace_signature,
};
use enginecl::util::rng::XorShift;

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::discover().expect("artifact registry (synthetic fallback)")
}

/// Solo (single-session) reference outputs for `bench` under `kind` on
/// 3 devices — computed through the *engine* path, which also pins the
/// engine-as-thin-runtime-wrapper equivalence.
fn solo_outputs(reg: &ArtifactRegistry, bench: &str, kind: &SchedulerKind) -> Vec<Vec<f32>> {
    let mut e = chaos_engine(reg, bench, 3, kind.clone(), None);
    e.run().expect("solo baseline run");
    let n = reg.bench(bench).unwrap().outputs.len();
    (0..n).map(|i| e.output(i).unwrap().to_vec()).collect()
}

/// The soak mix: 8 sessions across 5 kernels and
/// `{static,dynamic,hguided} × {blocking,+pipe}`.
fn soak_combos() -> Vec<(&'static str, SchedulerKind)> {
    vec![
        ("binomial", SchedulerKind::static_default()),
        ("gaussian", SchedulerKind::dynamic(12)),
        ("mandelbrot", SchedulerKind::hguided()),
        ("nbody", SchedulerKind::static_default().pipelined(2)),
        ("ray1", SchedulerKind::dynamic(10).pipelined(2)),
        ("binomial", SchedulerKind::hguided().pipelined(2)),
        ("gaussian", SchedulerKind::static_default()),
        ("mandelbrot", SchedulerKind::dynamic(8)),
    ]
}

fn soak(policy: LeasePolicy, seed: u64) {
    let reg = registry();
    let combos = soak_combos();
    let want: Vec<Vec<Vec<f32>>> =
        combos.iter().map(|(b, k)| solo_outputs(&reg, b, k)).collect();

    let rt = chaos_runtime(&reg, policy, seed);
    let sessions: Vec<RunSession> = combos
        .iter()
        .map(|(b, k)| chaos_session(&reg, b, 3, k.clone(), None))
        .collect();
    let handles = rt.submit_all(sessions);
    assert_eq!(handles.len(), combos.len());
    for ((handle, (bench, kind)), want) in handles.into_iter().zip(&combos).zip(&want) {
        let label = format!("{bench}/{}", kind.label());
        let outcome = handle.wait();
        let report = outcome
            .result
            .as_ref()
            .unwrap_or_else(|e| panic!("{label}: concurrent session failed: {e}"));
        assert_exactly_once(report);
        for (i, w) in want.iter().enumerate() {
            assert!(
                outcome.output(i).unwrap() == &w[..],
                "{label}: output {i} not bit-identical to its solo run"
            );
        }
        assert!(report.faults.is_empty(), "{label}: clean run records no faults");
    }
    rt.wait_idle();
    for d in 0..rt.node().devices.len() {
        assert_eq!(rt.arbiter().holder(d), None, "no lease survives the batch");
        assert!(
            rt.arbiter().registered_sessions(d).is_empty(),
            "every registration retired with its worker"
        );
    }
}

/// 8 mixed-kernel sessions under the deterministic rotation policy.
#[test]
fn soak_eight_mixed_sessions_rotation() {
    soak(LeasePolicy::Rotation, 0x50AC);
}

/// The same mix under first-come-first-served leasing.
#[test]
fn soak_eight_mixed_sessions_fifo() {
    soak(LeasePolicy::Fifo, 0x50AD);
}

/// Every admitted session completes under a capped runtime and a
/// seeded random admission order — no starvation, no lost handles.
#[test]
fn no_starvation_under_seeded_random_admission_order() {
    let reg = registry();
    let seed = chaos_seed();
    eprintln!("admission shuffle: ECL_CHAOS_SEED={seed} (export to reproduce)");
    let mut rng = XorShift::new(seed | 1);
    let mut combos = soak_combos();
    combos.truncate(6);
    // Fisher–Yates with the logged seed.
    for i in (1..combos.len()).rev() {
        let j = rng.below(i + 1);
        combos.swap(i, j);
    }
    let rt = enginecl::coordinator::Runtime::configured(
        reg.clone(),
        NodeConfig::batel(),
        LeasePolicy::Rotation,
        2, // at most two sessions in flight: the queue must drain
        seed,
    );
    let sessions: Vec<RunSession> = combos
        .iter()
        .enumerate()
        .map(|(i, (b, k))| {
            let s = chaos_session(&reg, b, 3, k.clone(), None);
            // Sprinkle deadlines so admission exercises the EDF branch.
            if i % 2 == 0 {
                s.deadline(Duration::from_secs(120))
            } else {
                s
            }
        })
        .collect();
    let handles = rt.submit_all(sessions);
    for handle in handles {
        let label = handle.label().to_string();
        let outcome = handle.wait();
        let report = outcome
            .result
            .as_ref()
            .unwrap_or_else(|e| panic!("{label}: session starved or failed: {e}"));
        assert_exactly_once(report);
        if let Some(met) = outcome.met_deadline() {
            assert!(met, "{label}: generous deadline must be met");
        }
    }
    rt.wait_idle();
}

/// With an in-flight cap of 1, a queued session carrying a deadline is
/// admitted before an earlier plain submission (EDF), and the two
/// sessions' lease grants do not interleave (cap-1 serializes).
#[test]
fn deadlined_session_admitted_first_when_capped() {
    let reg = registry();
    let rt = enginecl::coordinator::Runtime::configured(
        reg.clone(),
        NodeConfig::batel(),
        LeasePolicy::Rotation,
        1,
        3,
    );
    let plain = chaos_session(&reg, "binomial", 2, SchedulerKind::dynamic(4), None);
    let urgent = chaos_session(&reg, "gaussian", 2, SchedulerKind::dynamic(4), None)
        .deadline(Duration::from_secs(60));
    let handles = rt.submit_all(vec![plain, urgent]);
    let ids: Vec<SessionId> = handles.iter().map(|h| h.id()).collect();
    let outcomes: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
    for o in &outcomes {
        assert!(o.result.is_ok(), "{}: {:?}", o.label, o.result.as_ref().err());
    }
    assert_eq!(outcomes[1].met_deadline(), Some(true));
    rt.wait_idle();
    let journal = rt.lease_journal();
    assert!(!journal.is_empty());
    assert_eq!(
        journal[0].session, ids[1],
        "the deadlined session must be admitted (and granted) first"
    );
    let first_plain = journal
        .iter()
        .position(|g| g.session == ids[0])
        .expect("plain session ran too");
    let last_urgent = journal
        .iter()
        .rposition(|g| g.session == ids[1])
        .expect("urgent session ran");
    assert!(
        last_urgent < first_plain,
        "cap-1 admission must fully serialize the two sessions' grants"
    );
}

/// Small granule-aligned problem for admission-order tests (the
/// observable is the admission sequence, not the compute).
fn small_gws(reg: &ArtifactRegistry, bench: &str) -> usize {
    let m = reg.bench(bench).unwrap();
    (m.n / m.granule).clamp(1, 8) * m.granule
}

/// Equal-deadline sessions admit in an order fixed by the runtime seed
/// and their labels — never by submission order (the seeded EDF
/// tie-break). Shuffling the submission batch reproduces the identical
/// admission-grant sequence, label for label.
#[test]
fn equal_deadline_admission_order_survives_submission_shuffle() {
    let reg = registry();
    let benches = ["binomial", "gaussian", "mandelbrot", "nbody"];
    let admit_labels = |order: &[usize]| -> Vec<String> {
        let rt = enginecl::coordinator::Runtime::qos_configured(
            reg.clone(),
            NodeConfig::batel(),
            LeasePolicy::Rotation,
            1, // serialize admissions: the order is the whole observable
            0xEDF0,
            QosPolicy::enabled(),
        );
        let sessions: Vec<RunSession> = order
            .iter()
            .map(|&i| {
                let bench = benches[i];
                chaos_session(&reg, bench, 3, SchedulerKind::dynamic(4), None)
                    .gws(small_gws(&reg, bench))
                    .label(bench)
                    .deadline(Duration::from_secs(300))
            })
            .collect();
        let handles = rt.submit_all(sessions);
        let by_id: BTreeMap<SessionId, String> =
            handles.iter().map(|h| (h.id(), h.label().to_string())).collect();
        for h in handles {
            let label = h.label().to_string();
            let o = h.wait();
            assert!(o.result.is_ok(), "{label}: {:?}", o.result.as_ref().err());
        }
        rt.wait_idle();
        rt.admission_order().iter().map(|id| by_id[id].clone()).collect()
    };
    let straight = admit_labels(&[0, 1, 2, 3]);
    let shuffled = admit_labels(&[2, 0, 3, 1]);
    assert_eq!(straight.len(), 4, "every session admitted exactly once");
    assert_eq!(
        straight, shuffled,
        "equal-deadline admission order must depend only on seed + label"
    );
}

/// Bounded wait: a saturated stream of deadlined sessions cannot starve
/// a best-effort submission — after [`STARVATION_BOUND`] EDF bypasses,
/// the queue head is admitted unconditionally.
fn starvation_bounded(policy: LeasePolicy, seed: u64) {
    let reg = registry();
    let rt = enginecl::coordinator::Runtime::configured(
        reg.clone(),
        NodeConfig::batel(),
        policy,
        1, // cap 1: every deadlined session genuinely jumps the queue
        seed,
    );
    let mut sessions = vec![chaos_session(
        &reg,
        "gaussian",
        3,
        SchedulerKind::dynamic(4),
        None,
    )
    .gws(small_gws(&reg, "gaussian"))
    .label("best-effort")];
    for i in 0..7 {
        sessions.push(
            chaos_session(&reg, "binomial", 3, SchedulerKind::dynamic(4), None)
                .gws(small_gws(&reg, "binomial"))
                .label(&format!("deadlined-{i}"))
                .deadline(Duration::from_secs(600)),
        );
    }
    let handles = rt.submit_all(sessions);
    let be_id = handles[0].id();
    for h in handles {
        let label = h.label().to_string();
        let o = h.wait();
        assert!(o.result.is_ok(), "{label}: {:?}", o.result.as_ref().err());
    }
    rt.wait_idle();
    let order = rt.admission_order();
    assert_eq!(order.len(), 8);
    let pos = order
        .iter()
        .position(|&s| s == be_id)
        .expect("the best-effort session was admitted");
    assert!(
        pos <= STARVATION_BOUND,
        "best-effort admitted at position {pos}, beyond the starvation bound \
         {STARVATION_BOUND} (order {order:?})"
    );
}

/// The bounded-wait guarantee under the deterministic rotation policy.
#[test]
fn deadlined_stream_cannot_starve_best_effort_rotation() {
    starvation_bounded(LeasePolicy::Rotation, 0xBE57);
}

/// The same guarantee under first-come-first-served leasing.
#[test]
fn deadlined_stream_cannot_starve_best_effort_fifo() {
    starvation_bounded(LeasePolicy::Fifo, 0xBE58);
}

/// A `FaultPlan` kill inside one session: that session recovers
/// (requeue to survivors, outputs still bit-identical), the *other*
/// session never notices, and the dead worker's lease/rotation entry is
/// reclaimed — no device is left held or blocked.
#[test]
fn killed_device_leases_reclaimed_and_other_session_unaffected() {
    let reg = registry();
    let kind = SchedulerKind::dynamic(10);
    let want_a = solo_outputs(&reg, "binomial", &kind);
    let want_b = solo_outputs(&reg, "gaussian", &kind);

    let rt = chaos_runtime(&reg, LeasePolicy::Rotation, 5);
    let faulted =
        chaos_session(&reg, "binomial", 3, kind.clone(), Some(FaultPlan::kill(1, 0)));
    let clean = chaos_session(&reg, "gaussian", 3, kind, None);
    let handles = rt.submit_all(vec![faulted, clean]);
    let outcomes: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();

    let fault_o = &outcomes[0];
    let fr = fault_o.result.as_ref().expect("faulted session must recover");
    assert!(fr.recovered(), "the kill was recovered by survivors");
    assert!(fr.requeued_packages() >= 1, "reclaimed work surfaced as requeued packages");
    assert_exactly_once(fr);
    for (i, w) in want_a.iter().enumerate() {
        assert!(
            fault_o.output(i).unwrap() == &w[..],
            "faulted session output {i} differs from its solo run"
        );
    }

    let clean_o = &outcomes[1];
    let cr = clean_o.result.as_ref().expect("clean session unaffected by the kill");
    assert!(cr.faults.is_empty(), "the fault must not leak across sessions");
    assert_exactly_once(cr);
    for (i, w) in want_b.iter().enumerate() {
        assert!(
            clean_o.output(i).unwrap() == &w[..],
            "clean session output {i} differs from its solo run"
        );
    }

    rt.wait_idle();
    for d in 0..rt.node().devices.len() {
        assert_eq!(rt.arbiter().holder(d), None, "dead worker's lease reclaimed");
        assert!(
            rt.arbiter().registered_sessions(d).is_empty(),
            "dead worker's rotation entry reclaimed"
        );
    }
}

/// Per-session golden-trace signature (see `testing::trace_signature`).
type Signature = Vec<Vec<(usize, usize, bool)>>;

/// One golden batch: two 3-device Static sessions (structurally
/// deterministic package→device binding) plus a single-device Dynamic
/// session contending on device 0.
fn golden_batch(reg: &ArtifactRegistry, seed: u64) -> (Vec<Signature>, Vec<GrantRecord>) {
    let rt = chaos_runtime(reg, LeasePolicy::Rotation, seed);
    let sessions = vec![
        chaos_session(reg, "binomial", 3, SchedulerKind::static_default(), None),
        chaos_session(reg, "gaussian", 3, SchedulerKind::static_default(), None),
        chaos_session(reg, "mandelbrot", 1, SchedulerKind::dynamic(6), None),
    ];
    let handles = rt.submit_all(sessions);
    let sigs = handles
        .into_iter()
        .map(|h| {
            let label = h.label().to_string();
            let o = h.wait();
            let report = o
                .result
                .as_ref()
                .unwrap_or_else(|e| panic!("{label}: golden batch session failed: {e}"));
            trace_signature(report)
        })
        .collect();
    rt.wait_idle();
    (sigs, rt.lease_journal())
}

/// The per-device grant sequence (sessions in grant order). The
/// *global* journal interleaving across devices is wall-clock ordered,
/// but each device's own sequence is what rotation pins.
fn per_device_grants(journal: &[GrantRecord], ndev: usize) -> Vec<Vec<SessionId>> {
    (0..ndev)
        .map(|d| journal.iter().filter(|g| g.device == d).map(|g| g.session).collect())
        .collect()
}

/// Golden-trace determinism for concurrent runs: fixed simclock seed +
/// fixed admission order ⇒ identical per-session `PackageTrace` streams
/// and identical per-device lease-grant sequences across two
/// executions.
#[test]
fn golden_concurrent_trace_determinism() {
    let reg = registry();
    let (sig1, j1) = golden_batch(&reg, 42);
    let (sig2, j2) = golden_batch(&reg, 42);
    assert_eq!(sig1, sig2, "per-session package streams must reproduce exactly");
    assert_eq!(
        per_device_grants(&j1, 3),
        per_device_grants(&j2, 3),
        "per-device lease interleavings must reproduce exactly"
    );
    // Structure sanity: rotation leads with the first-admitted session
    // on every device, and device 0 carries all 6 dynamic packages of
    // the single-device session after the two static windows.
    let grants = per_device_grants(&j1, 3);
    assert_eq!(&grants[0][..2], &[0, 1][..], "admission order leads the rotation");
    assert_eq!(grants[0].iter().filter(|&&s| s == 2).count(), 6);
    for d in 1..3 {
        assert_eq!(
            grants[d].as_slice(),
            &[0, 1][..],
            "static sessions take one window each off device {d}"
        );
    }
}

/// Bulk-dispatch equivalence grid (PR-7): across 5 kernels ×
/// {static,dynamic,hguided,adaptive} × {blocking,+pipe}, a session run
/// solo and the same session run twice concurrently must produce
/// bit-identical outputs and exactly-once package ledgers. The batched
/// master refills whole `AssignBatch`es and coalesces prefetch
/// acknowledgements into `Done` — any range duplicated, dropped, or
/// misordered by the batching shows up here as a ledger gap/overlap or
/// an output diff.
#[test]
fn bulk_dispatch_equivalence_grid() {
    let reg = registry();
    let kinds = [
        SchedulerKind::static_default(),
        SchedulerKind::dynamic(6),
        SchedulerKind::hguided(),
        SchedulerKind::adaptive(),
    ];
    for bench in ["binomial", "gaussian", "mandelbrot", "nbody", "ray1"] {
        let gws = small_gws(&reg, bench);
        for base in &kinds {
            for depth in [1usize, 2] {
                let kind =
                    if depth > 1 { base.clone().pipelined(depth) } else { base.clone() };
                let label = format!("{bench}/{}", kind.label());
                // Solo reference through its own runtime.
                let solo_rt = chaos_runtime(&reg, LeasePolicy::Rotation, 0xD15);
                let solo = solo_rt
                    .submit(chaos_session(&reg, bench, 2, kind.clone(), None).gws(gws))
                    .wait();
                let sr = solo
                    .result
                    .as_ref()
                    .unwrap_or_else(|e| panic!("{label}: solo run failed: {e}"));
                assert_exactly_once(sr);
                let nouts = reg.bench(bench).unwrap().outputs.len();
                let want: Vec<Vec<f32>> =
                    (0..nouts).map(|i| solo.output(i).unwrap().to_vec()).collect();
                solo_rt.wait_idle();
                // The same combo twice, concurrently, contending on the
                // same two devices.
                let rt = chaos_runtime(&reg, LeasePolicy::Rotation, 0xD16);
                let handles = rt.submit_all(vec![
                    chaos_session(&reg, bench, 2, kind.clone(), None).gws(gws),
                    chaos_session(&reg, bench, 2, kind.clone(), None).gws(gws),
                ]);
                for h in handles {
                    let o = h.wait();
                    let r = o
                        .result
                        .as_ref()
                        .unwrap_or_else(|e| panic!("{label}: concurrent run failed: {e}"));
                    assert_exactly_once(r);
                    for (i, w) in want.iter().enumerate() {
                        assert!(
                            o.output(i).unwrap() == &w[..],
                            "{label}: concurrent output {i} not bit-identical to solo"
                        );
                    }
                }
                rt.wait_idle();
            }
        }
    }
}

/// Pinned-seed lease-journal replay over pipelined sessions (PR-7): the
/// sharded arbiter merges per-device journal slices on read, and the
/// batched master changes *when* grants are requested — neither may
/// change *what* each device's grant sequence is. Two executions of the
/// same seeded batch (including +pipe depth-2 sessions, which golden
/// batches did not cover before) must reproduce identical per-session
/// trace signatures and identical per-device grant sequences.
#[test]
fn pipelined_batch_lease_journal_replay() {
    let reg = registry();
    let run = |seed: u64| -> (Vec<Signature>, Vec<GrantRecord>) {
        let rt = chaos_runtime(&reg, LeasePolicy::Rotation, seed);
        let sessions = vec![
            chaos_session(&reg, "binomial", 3, SchedulerKind::static_default().pipelined(2), None),
            chaos_session(&reg, "gaussian", 3, SchedulerKind::dynamic(6).pipelined(2), None),
            chaos_session(&reg, "mandelbrot", 2, SchedulerKind::static_default(), None),
        ];
        let handles = rt.submit_all(sessions);
        let sigs = handles
            .into_iter()
            .map(|h| {
                let label = h.label().to_string();
                let o = h.wait();
                let report = o
                    .result
                    .as_ref()
                    .unwrap_or_else(|e| panic!("{label}: replay batch session failed: {e}"));
                trace_signature(report)
            })
            .collect();
        rt.wait_idle();
        (sigs, rt.lease_journal())
    };
    let (sig1, j1) = run(0x7E9);
    let (sig2, j2) = run(0x7E9);
    assert_eq!(sig1, sig2, "pipelined package streams must reproduce exactly");
    assert_eq!(
        per_device_grants(&j1, 3),
        per_device_grants(&j2, 3),
        "per-device lease grant sequences must reproduce exactly"
    );
    // The merged journal must itself be serial-ordered — the sharded
    // arbiter's merge-on-read contract.
    for w in j1.windows(2) {
        assert!(w[0].serial < w[1].serial, "merged journal must be strictly serial-sorted");
    }
}

/// Acceptance: two sessions submitted together on the 3-device batel
/// node finish with simclock makespan strictly less than the sum of
/// their solo makespans, while each session's outputs stay
/// bit-identical to its solo run. (Coarse dynamic packages leave each
/// solo run with a tail-imbalance idle window; co-execution fills it.)
#[test]
fn two_concurrent_sessions_beat_serial_execution() {
    let reg = registry();
    // Quarter-size problems keep the simclock holds short while still
    // dominating the dispatch overheads.
    let quarter = |bench: &str| {
        let m = reg.bench(bench).unwrap();
        let granules = (m.n / m.granule / 4).max(1);
        Some(granules * m.granule)
    };
    let specs = vec![
        SessionSpec {
            bench: "binomial".into(),
            scheduler: SchedulerKind::dynamic(5),
            gws: quarter("binomial"),
        },
        SessionSpec {
            bench: "gaussian".into(),
            scheduler: SchedulerKind::dynamic(5),
            gws: quarter("gaussian"),
        },
    ];
    let report = run_concurrent(
        &reg,
        &NodeConfig::batel(),
        &specs,
        LeasePolicy::Rotation,
        9,
        measure_config(),
    )
    .expect("concurrent harness completes");
    assert!(report.all_outputs_match(), "co-execution changed results");
    assert!(
        report.batch_wall < report.solo_sum,
        "batch makespan {:?} must be strictly less than the serial sum {:?}",
        report.batch_wall,
        report.solo_sum
    );
    let contention: Duration = report.sessions.iter().map(|s| s.lease_wait).sum();
    assert!(
        contention > Duration::ZERO,
        "sharing three devices between two sessions must show some lease wait"
    );
}

/// ISSUE-8 satellite: *two* interleaved deadlined streams leapfrogging
/// each other through EDF must still age the best-effort queue head on
/// every bypass — the head is admitted within [`STARVATION_BOUND`]
/// jumps no matter how many distinct streams take turns in front of it,
/// and EDF keeps ordering the streams themselves (earliest deadlines
/// first) around the forced admission.
#[test]
fn two_deadline_streams_cannot_starve_best_effort_head() {
    let reg = registry();
    let rt = enginecl::coordinator::Runtime::configured(
        reg.clone(),
        NodeConfig::batel(),
        LeasePolicy::Rotation,
        1, // cap 1: every admission is a fresh EDF pick over the queue
        0xED1F,
    );
    let mut sessions = vec![chaos_session(&reg, "gaussian", 3, SchedulerKind::dynamic(4), None)
        .gws(small_gws(&reg, "gaussian"))
        .label("best-effort-head")];
    // Stream A (urgent) and stream B (loose), interleaved in the batch
    // so the EDF pick alternates position while the head waits.
    for i in 0..4u64 {
        sessions.push(
            chaos_session(&reg, "binomial", 3, SchedulerKind::dynamic(4), None)
                .gws(small_gws(&reg, "binomial"))
                .label(&format!("stream-a-{i}"))
                .deadline(Duration::from_secs(100 + i)),
        );
        sessions.push(
            chaos_session(&reg, "mandelbrot", 3, SchedulerKind::dynamic(4), None)
                .gws(small_gws(&reg, "mandelbrot"))
                .label(&format!("stream-b-{i}"))
                .deadline(Duration::from_secs(600 + i)),
        );
    }
    let handles = rt.submit_all(sessions);
    let be_id = handles[0].id();
    let a_ids: Vec<SessionId> = (0..4).map(|i| handles[1 + 2 * i].id()).collect();
    for h in handles {
        let label = h.label().to_string();
        let o = h.wait();
        assert!(o.result.is_ok(), "{label}: {:?}", o.result.as_ref().err());
    }
    rt.wait_idle();
    let order = rt.admission_order();
    assert_eq!(order.len(), 9);
    let pos = order
        .iter()
        .position(|&s| s == be_id)
        .expect("the best-effort head was admitted");
    assert!(
        pos <= STARVATION_BOUND,
        "best-effort head admitted at position {pos}, beyond the starvation bound \
         {STARVATION_BOUND} (order {order:?})"
    );
    // EDF still ran the urgent stream first — aging the head must not
    // scramble deadline order among the streams.
    assert_eq!(
        &order[..a_ids.len().min(pos)],
        &a_ids[..a_ids.len().min(pos)],
        "urgent stream A must fill every admission slot before the forced head (order {order:?})"
    );
}
