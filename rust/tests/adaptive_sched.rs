//! PR-5 battery: the feedback-driven scheduling core.
//!
//! * Exactly-once covers and bit-identical outputs for every scheduler
//!   spec — feedback and tail cutoffs included — under chaos kills.
//! * `Adaptive` convergence on a two-speed node whose profile lies (the
//!   speeds differ only through a `slow:` fault plan).
//! * `Adaptive` beating static-profile HGuided when the node's fastest
//!   device degrades mid-run.
//! * The balance-efficiency acceptance bar on the reference node.
//! * The persistent performance model: sessions feed it (fault-recovered
//!   runs included) and later sessions warm-start from it.
//!
//! Outputs are always compared against the *blocking seed path* (a
//! single-device Static run): scheduling feedback may move package
//! boundaries, never results.

use std::time::Duration;

use enginecl::coordinator::scheduler::parse_spec;
use enginecl::coordinator::{LeasePolicy, SchedulerKind};
use enginecl::harness::runs::build_engine;
use enginecl::platform::{DeviceKind, DeviceProfile, FaultPlan, NodeConfig};
use enginecl::runtime::ArtifactRegistry;
use enginecl::testing::{
    assert_exactly_once, chaos_engine, chaos_runtime, chaos_seed, chaos_session,
};

fn registry() -> ArtifactRegistry {
    ArtifactRegistry::synthetic()
}

/// The blocking seed path: one device, Static, depth 1, no simulation.
/// Every co-executed / adaptive / fault-recovered run must reproduce
/// these outputs bit for bit.
fn blocking_baseline(reg: &ArtifactRegistry, bench: &str) -> Vec<Vec<f32>> {
    let mut e = chaos_engine(reg, bench, 1, SchedulerKind::static_default(), None);
    e.run().expect("blocking baseline run");
    let nouts = reg.bench(bench).unwrap().outputs.len();
    (0..nouts).map(|i| e.output(i).unwrap().to_vec()).collect()
}

fn assert_outputs_match(e: &enginecl::coordinator::Engine, want: &[Vec<f32>], what: &str) {
    for (i, w) in want.iter().enumerate() {
        assert_eq!(
            e.output(i).expect("output present"),
            &w[..],
            "{what}: output {i} diverged from the blocking seed path"
        );
    }
}

// ---- exactly-once under chaos kills, every spec -----------------------

#[test]
fn every_spec_covers_exactly_once_under_chaos_kills() {
    let reg = registry();
    let want = blocking_baseline(&reg, "binomial");
    for spec in
        ["static", "dynamic:8", "hguided", "hguided:feedback=0", "adaptive", "adaptive+pipe"]
    {
        let kind = parse_spec(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
        for salt in 0..4u64 {
            // Kills at early ordinals so the plan reliably fires even
            // for schedulers that hand a device few (or zero tail)
            // packages; a plan that happens not to fire still must
            // leave a perfect cover.
            let plan = FaultPlan::seeded_kill(chaos_seed() ^ (salt * 0x9E37), 3, 2);
            let mut e = chaos_engine(&reg, "binomial", 3, kind.clone(), Some(plan.clone()));
            e.run().unwrap_or_else(|err| panic!("{spec} under {plan:?}: {err}"));
            let report = e.report().unwrap();
            if !report.faults.is_empty() {
                assert!(report.recovered(), "{spec}: fault not recovered under {plan:?}");
            }
            assert_exactly_once(report);
            assert_outputs_match(&e, &want, spec);
        }
    }
}

// ---- convergence on a mis-profiled two-speed node ---------------------

/// Two devices the *profile* claims are identical; only a fault plan
/// makes one slower. Any scheduler trusting `relative_power` splits
/// this 50/50 and eats the imbalance — convergence must come from
/// observed timings alone.
fn two_speed_node() -> NodeConfig {
    let twin = |name: &str| {
        DeviceProfile::new(name, DeviceKind::Gpu, 1.0)
            .with_init(Duration::from_millis(5), Duration::ZERO)
            .with_package_overhead(Duration::from_micros(300))
            .with_jitter(0.01)
    };
    NodeConfig { name: "two-speed".into(), devices: vec![twin("twin-a"), twin("twin-b")] }
}

#[test]
fn adaptive_converges_on_a_two_speed_node() {
    let reg = registry();
    let node = two_speed_node();
    // Binomial is the compute-dominated kernel: a simulated slowdown
    // actually moves its package spans (per-package overheads, which a
    // `slow:` fault does not stretch, are a small share of the span).
    let want = blocking_baseline(&reg, "binomial");
    let mut e = build_engine(
        &reg,
        &node,
        "binomial",
        (0..2).map(enginecl::coordinator::DeviceSpec::new).collect(),
        parse_spec("adaptive").unwrap(),
        None,
    )
    .expect("build two-speed engine");
    e.configurator().simulate_init = false;
    // twin-b is 4x slower from its very first package — the profile
    // never said so (`slow:` grammar, as the CLI would install it).
    e.fault_plan(FaultPlan::parse("slow:dev1@pkg0:4").expect("valid slow spec"));
    e.run().expect("two-speed adaptive run");
    let report = e.report().unwrap().clone();
    assert_exactly_once(&report);
    assert!(report.faults.is_empty(), "slowdown is a degradation, not a failure");

    let busys: Vec<f64> = report
        .devices
        .iter()
        .filter(|d| !d.packages.is_empty())
        .map(|d| d.busy().as_secs_f64())
        .collect();
    assert_eq!(busys.len(), 2, "both twins computed work");
    let max = busys.iter().cloned().fold(0.0f64, f64::max);
    let min = busys.iter().cloned().fold(f64::INFINITY, f64::min);
    let spread = (max - min) / max;
    assert!(
        spread <= 0.40,
        "busy-time spread {spread:.3} exceeds the convergence bound (busys {busys:?})"
    );
    assert!(
        report.balance_efficiency() >= 0.72,
        "two-speed balance efficiency {:.3} below bound",
        report.balance_efficiency()
    );
    assert_outputs_match(&e, &want, "two-speed adaptive");
}

// ---- adaptive vs static-profile hguided under degradation -------------

#[test]
fn adaptive_beats_static_profile_hguided_when_the_gpu_degrades() {
    let reg = registry();
    let node = NodeConfig::batel();
    let want = blocking_baseline(&reg, "binomial");
    // The node's fastest device (slot 1 = tesla-k20m) throttles 8x from
    // its third package on — `slow:` grammar, exactly as the CLI would
    // install it. By then a static-profile schedule has committed to
    // feeding the "fastest" device the biggest packages and keeps doing
    // so (its last clamp-sized chunk becomes a long straggler tail);
    // the feedback loop re-estimates within a package or two and shifts
    // the work away.
    let plan = FaultPlan::parse("slow:dev1@pkg2:8").expect("valid slow spec");
    let run = |spec: &str| {
        let kind = parse_spec(spec).unwrap();
        let mut e = build_engine(
            &reg,
            &node,
            "binomial",
            (0..3).map(enginecl::coordinator::DeviceSpec::new).collect(),
            kind,
            None,
        )
        .expect("build degraded-gpu engine");
        e.configurator().simulate_init = false;
        e.fault_plan(plan.clone());
        e.run().unwrap_or_else(|err| panic!("{spec} degraded run: {err}"));
        let report = e.report().unwrap().clone();
        assert_exactly_once(&report);
        assert_outputs_match(&e, &want, spec);
        report
    };
    let adaptive = run("adaptive");
    let static_hg = run("hguided:feedback=0");
    // The feedback loop provably shifts work off the degraded device...
    assert!(
        adaptive.devices[1].items() < static_hg.devices[1].items(),
        "adaptive must give the degraded gpu less work: {} vs {} items",
        adaptive.devices[1].items(),
        static_hg.devices[1].items()
    );
    // ...and that shows as better balance efficiency.
    let (a, h) = (adaptive.balance_efficiency(), static_hg.balance_efficiency());
    assert!(
        a >= h + 0.05,
        "adaptive must beat static-profile hguided on a degraded device: \
         adaptive {a:.3} vs hguided-static {h:.3}"
    );
}

// ---- the acceptance bar on the reference node -------------------------

#[test]
fn adaptive_balance_efficiency_on_the_reference_node() {
    let reg = registry();
    let node = NodeConfig::batel();
    for bench in ["gaussian", "ray1", "binomial", "mandelbrot", "nbody"] {
        let want = blocking_baseline(&reg, bench);
        // Two attempts, best taken: the bar is on what the scheduler
        // *reaches*; a noisy-neighbor CI core shouldn't flake it.
        let mut best = 0.0f64;
        for attempt in 0..2 {
            let mut e = build_engine(
                &reg,
                &node,
                bench,
                (0..3).map(enginecl::coordinator::DeviceSpec::new).collect(),
                parse_spec("adaptive").unwrap(),
                None,
            )
            .expect("build reference engine");
            e.configurator().simulate_init = false;
            e.run().unwrap_or_else(|err| panic!("{bench} adaptive run: {err}"));
            let report = e.report().unwrap().clone();
            assert_exactly_once(&report);
            assert_outputs_match(&e, &want, bench);
            best = best.max(report.balance_efficiency());
            if best >= 0.85 {
                break;
            }
            eprintln!(
                "{bench}: attempt {attempt} balance efficiency {:.3}, retrying",
                report.balance_efficiency()
            );
        }
        assert!(
            best >= 0.85,
            "{bench}: adaptive balance efficiency {best:.3} below the 0.85 acceptance bar"
        );
    }
}

// ---- the persistent performance model ---------------------------------

#[test]
fn sessions_feed_the_store_and_later_sessions_warm_start() {
    let reg = registry();
    let rt = chaos_runtime(&reg, LeasePolicy::Rotation, 7);
    let store = rt.perf_model().clone();
    assert_eq!(store.total_samples(), 0, "cold store");

    // Session 1: hguided over binomial, sequentially.
    let outcome = rt
        .submit(chaos_session(&reg, "binomial", 3, SchedulerKind::hguided(), None))
        .wait();
    let report = outcome.result.as_ref().expect("session 1 completes");
    rt.wait_idle();
    let after_first = store.total_samples();
    assert!(after_first > 0, "session observations ingested");
    for d in report.devices.iter().filter(|d| !d.packages.is_empty()) {
        let e = store
            .estimate_record("binomial", &d.name)
            .unwrap_or_else(|| panic!("no estimate for {}", d.name));
        assert!(e.rate > 0.0 && e.samples > 0);
    }
    // The journal attributes every record to session ids seen so far.
    assert!(store.journal().iter().all(|o| o.kernel == "binomial"));

    // Session 2: adaptive warm-starts from session 1's estimates (the
    // devices are observed, so no probe sizing) and completes with
    // identical outputs.
    let outcome = rt
        .submit(chaos_session(&reg, "binomial", 3, SchedulerKind::adaptive(), None))
        .wait();
    let report2 = outcome.result.as_ref().expect("session 2 completes");
    rt.wait_idle();
    let items: usize = report2.devices.iter().map(|d| d.items()).sum();
    assert_eq!(items, report2.gws, "warm-started cover is exactly-once");
    assert!(
        store.total_samples() > after_first,
        "the second session kept feeding the store"
    );
}

#[test]
fn fault_recovered_runs_still_feed_the_store() {
    let reg = registry();
    // Kill device 1 at its second package: its first package completes,
    // so even the dead device must have contributed an estimate.
    let mut e = chaos_engine(
        &reg,
        "binomial",
        2,
        SchedulerKind::dynamic(8),
        Some(FaultPlan::kill(1, 1)),
    );
    e.run().expect("kill at pkg1 recovers with a survivor");
    let report = e.report().unwrap();
    assert!(report.recovered());
    let store = e.perf_model();
    assert!(store.total_samples() > 0);
    for d in report.devices.iter().filter(|d| !d.packages.is_empty()) {
        assert!(
            store.estimate("binomial", &d.name).is_some(),
            "device {} computed packages but left no estimate",
            d.name
        );
    }
}

#[test]
fn repeated_engine_runs_accumulate_and_stay_bit_identical() {
    let reg = registry();
    let want = blocking_baseline(&reg, "binomial");
    let mut e = chaos_engine(&reg, "binomial", 3, SchedulerKind::adaptive(), None);
    e.run().expect("cold run");
    let cold_samples = e.perf_model().total_samples();
    assert!(cold_samples > 0);
    assert_outputs_match(&e, &want, "cold adaptive");
    // Second run warm-starts from the first run's estimates; results
    // are unchanged and the model keeps accumulating.
    e.run().expect("warm run");
    assert_outputs_match(&e, &want, "warm adaptive");
    assert_exactly_once(e.report().unwrap());
    assert!(e.perf_model().total_samples() > cold_samples);
}
