//! Usability-metric engine over the real paired sources: the EngineCL
//! examples must score drastically better than the native baselines on
//! every Table-3 metric — the paper's usability claim, as a test.

use std::path::Path;

use enginecl::metrics::analyze_source;

fn read(rel: &str) -> String {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(root.join(rel)).unwrap_or_else(|_| panic!("missing {rel}"))
}

const PAIRS: &[(&str, &str, &str)] = &[
    ("binomial", "examples/quickstart.rs", "examples/native/native_binomial.rs"),
    ("nbody", "examples/nbody_coexec.rs", "examples/native/native_nbody.rs"),
    ("gaussian", "examples/gaussian_blur.rs", "examples/native/native_gaussian.rs"),
    ("mandelbrot", "examples/mandelbrot_hguided.rs", "examples/native/native_mandelbrot.rs"),
    ("ray", "examples/raytrace_scenes.rs", "examples/native/native_ray.rs"),
];

#[test]
fn enginecl_beats_native_on_code_density() {
    for (name, ecl_path, native_path) in PAIRS {
        let ecl = analyze_source(&read(ecl_path));
        let native = analyze_source(&read(native_path));
        assert!(
            native.tok as f64 >= 2.0 * ecl.tok as f64,
            "{name}: TOK ratio too small ({} vs {})",
            native.tok,
            ecl.tok
        );
        assert!(
            native.loc as f64 >= 1.8 * ecl.loc as f64,
            "{name}: LOC ratio too small ({} vs {})",
            native.loc,
            ecl.loc
        );
    }
}

#[test]
fn enginecl_reaches_ideal_cyclomatic_complexity() {
    for (name, ecl_path, native_path) in PAIRS {
        let ecl = analyze_source(&read(ecl_path));
        let native = analyze_source(&read(native_path));
        // Rust's `?` postfix counts as a decision point in our CC
        // approximation; the EngineCL region has a couple of those.
        assert!(ecl.cc <= 4, "{name}: EngineCL CC should be ~1-3, got {}", ecl.cc);
        assert!(native.cc > ecl.cc, "{name}: native CC must exceed EngineCL");
    }
}

#[test]
fn enginecl_minimizes_error_sections() {
    for (name, ecl_path, native_path) in PAIRS {
        let ecl = analyze_source(&read(ecl_path));
        let native = analyze_source(&read(native_path));
        assert!(
            ecl.errc <= 2,
            "{name}: EngineCL region should have <=2 error sections, got {}",
            ecl.errc
        );
        assert!(
            native.errc >= 5 * ecl.errc.max(1),
            "{name}: ERRC ratio too small ({} vs {})",
            native.errc,
            ecl.errc
        );
    }
}

#[test]
fn interface_complexity_reduced() {
    for (name, ecl_path, native_path) in PAIRS {
        let ecl = analyze_source(&read(ecl_path));
        let native = analyze_source(&read(native_path));
        assert!(
            native.oac > ecl.oac && native.is > ecl.is,
            "{name}: OAC/IS must shrink (native {}/{} vs ecl {}/{})",
            native.oac,
            native.is,
            ecl.oac,
            ecl.is
        );
    }
}

#[test]
fn table1_model_matches_native_counts() {
    // The paper's Table 1 analytical model: native per-device primitive
    // management should grow with D; EngineCL needs a single line per
    // added device. We verify the *model direction* over our native
    // sources: every native baseline repeats client+compile+upload per
    // logical device, the EngineCL sources never mention the runtime.
    for (name, ecl_path, native_path) in PAIRS {
        let native = read(native_path);
        let ecl = read(ecl_path);
        assert!(
            native.contains("ChunkExecutor") || native.contains("PjRtClient")
                || native.contains("execute_range"),
            "{name}: native baseline must drive the runtime directly"
        );
        assert!(
            !ecl.contains("ChunkExecutor") && !ecl.contains("PjRtClient"),
            "{name}: EngineCL example must not touch the runtime layer"
        );
    }
}
