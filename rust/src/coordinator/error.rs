//! Engine error model — the paper's API collects runtime errors on the
//! engine (`engine.has_errors()` / `get_errors()`) instead of forcing an
//! error-check section after every call (the ERRC usability metric).

use thiserror::Error;

#[derive(Debug, Error)]
pub enum EclError {
    #[error("no program set: call engine.program(..) before run()")]
    NoProgram,

    #[error("no devices selected: call engine.use_mask(..) or use_devices(..)")]
    NoDevices,

    #[error("unknown benchmark kernel '{0}'")]
    UnknownKernel(String),

    #[error("global work size {gws} exceeds compiled problem size {n}")]
    WorkSizeTooLarge { gws: usize, n: usize },

    #[error("global work size {gws} is not a multiple of the granule {granule}")]
    MisalignedWorkSize { gws: usize, granule: usize },

    #[error("program expects {expected} input buffers, got {got}")]
    InputArity { expected: usize, got: usize },

    #[error("program expects {expected} output buffers, got {got}")]
    OutputArity { expected: usize, got: usize },

    #[error("buffer '{name}' has {got} elements, manifest expects {expected}")]
    BufferSize { name: String, expected: usize, got: usize },

    #[error("kernel argument {index} ('{name}') = {got}, artifact was baked with {expected}")]
    ArgMismatch { index: usize, name: String, expected: f64, got: f64 },

    #[error("kernel argument {index}: no such baked argument")]
    UnknownArg { index: usize },

    #[error("static scheduler got {got} proportions for {devices} devices")]
    BadProportions { got: usize, devices: usize },

    #[error("device worker '{device}' failed: {message}")]
    Worker { device: String, message: String },

    #[error("runtime error: {0}")]
    Runtime(String),
}

impl From<anyhow::Error> for EclError {
    fn from(e: anyhow::Error) -> Self {
        EclError::Runtime(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = EclError::WorkSizeTooLarge { gws: 10, n: 5 };
        assert!(e.to_string().contains("10"));
        let e = EclError::ArgMismatch {
            index: 2,
            name: "steps".into(),
            expected: 254.0,
            got: 100.0,
        };
        assert!(e.to_string().contains("steps"));
    }

    #[test]
    fn from_anyhow() {
        let a = anyhow::anyhow!("boom");
        let e: EclError = a.into();
        assert!(matches!(e, EclError::Runtime(_)));
    }
}
