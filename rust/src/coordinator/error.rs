//! Engine error model — the paper's API collects runtime errors on the
//! engine (`engine.has_errors()` / `get_errors()`) instead of forcing an
//! error-check section after every call (the ERRC usability metric).
//!
//! `Display` and `std::error::Error` are implemented by hand so the crate
//! carries no proc-macro dependency (the build must work offline).

use std::fmt;

/// Everything `Engine::run` can reject or report.
#[derive(Debug)]
pub enum EclError {
    /// No program set: call `engine.program(..)` before `run()`.
    NoProgram,
    /// No devices selected: call `engine.use_mask(..)` or `use_devices(..)`.
    NoDevices,
    /// The program names a kernel no artifact provides.
    UnknownKernel(String),
    /// Requested global work size exceeds the compiled problem size.
    WorkSizeTooLarge { gws: usize, n: usize },
    /// Requested global work size is not granule-aligned.
    MisalignedWorkSize { gws: usize, granule: usize },
    /// Wrong number of input buffers.
    InputArity { expected: usize, got: usize },
    /// Wrong number of output buffers.
    OutputArity { expected: usize, got: usize },
    /// A buffer's element count disagrees with the manifest.
    BufferSize { name: String, expected: usize, got: usize },
    /// A scalar kernel argument differs from the AOT-baked value.
    ArgMismatch { index: usize, name: String, expected: f64, got: f64 },
    /// A kernel argument index with no baked counterpart.
    UnknownArg { index: usize },
    /// Static proportions don't match the selected device count.
    BadProportions { got: usize, devices: usize },
    /// Pipeline depth outside the supported range.
    BadPipelineDepth { depth: usize, max: usize },
    /// A device worker thread failed.
    Worker { device: String, message: String },
    /// A service ingestion shard's bounded mailbox is full. This is
    /// backpressure, not failure: retry after the dispatcher (or a
    /// `Service::pump_round` call) drains the shard.
    MailboxFull { shard: usize, cap: usize },
    /// QoS admission control rejected the session up front: the
    /// performance model priced its makespan above the deadline with
    /// margin to spare (only ever raised on fully warm estimates — a
    /// cold store never rejects; see `coordinator::qos`).
    AdmissionRejected { label: String, predicted: std::time::Duration, deadline: std::time::Duration },
    /// Any other runtime failure, stringified.
    Runtime(String),
}

impl fmt::Display for EclError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EclError::NoProgram => {
                write!(f, "no program set: call engine.program(..) before run()")
            }
            EclError::NoDevices => {
                write!(f, "no devices selected: call engine.use_mask(..) or use_devices(..)")
            }
            EclError::UnknownKernel(k) => write!(f, "unknown benchmark kernel '{k}'"),
            EclError::WorkSizeTooLarge { gws, n } => {
                write!(f, "global work size {gws} exceeds compiled problem size {n}")
            }
            EclError::MisalignedWorkSize { gws, granule } => {
                write!(f, "global work size {gws} is not a multiple of the granule {granule}")
            }
            EclError::InputArity { expected, got } => {
                write!(f, "program expects {expected} input buffers, got {got}")
            }
            EclError::OutputArity { expected, got } => {
                write!(f, "program expects {expected} output buffers, got {got}")
            }
            EclError::BufferSize { name, expected, got } => {
                write!(f, "buffer '{name}' has {got} elements, manifest expects {expected}")
            }
            EclError::ArgMismatch { index, name, expected, got } => write!(
                f,
                "kernel argument {index} ('{name}') = {got}, artifact was baked with {expected}"
            ),
            EclError::UnknownArg { index } => {
                write!(f, "kernel argument {index}: no such baked argument")
            }
            EclError::BadProportions { got, devices } => {
                write!(f, "static scheduler got {got} proportions for {devices} devices")
            }
            EclError::BadPipelineDepth { depth, max } => {
                write!(f, "pipeline depth {depth} out of range (1..={max})")
            }
            EclError::Worker { device, message } => {
                write!(f, "device worker '{device}' failed: {message}")
            }
            EclError::MailboxFull { shard, cap } => write!(
                f,
                "service shard {shard} mailbox full (cap {cap}): retry after a dispatch round"
            ),
            EclError::AdmissionRejected { label, predicted, deadline } => write!(
                f,
                "session '{label}' rejected at admission: predicted makespan {}ms cannot fit deadline {}ms",
                predicted.as_millis(),
                deadline.as_millis()
            ),
            EclError::Runtime(msg) => write!(f, "runtime error: {msg}"),
        }
    }
}

impl std::error::Error for EclError {}

impl From<anyhow::Error> for EclError {
    fn from(e: anyhow::Error) -> Self {
        EclError::Runtime(format!("{e:#}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages() {
        let e = EclError::WorkSizeTooLarge { gws: 10, n: 5 };
        assert!(e.to_string().contains("10"));
        let e = EclError::ArgMismatch {
            index: 2,
            name: "steps".into(),
            expected: 254.0,
            got: 100.0,
        };
        assert!(e.to_string().contains("steps"));
        let e = EclError::BadPipelineDepth { depth: 99, max: 8 };
        assert!(e.to_string().contains("99"));
        let e = EclError::AdmissionRejected {
            label: "video-frame".into(),
            predicted: std::time::Duration::from_millis(250),
            deadline: std::time::Duration::from_millis(100),
        };
        let s = e.to_string();
        assert!(s.contains("video-frame") && s.contains("250") && s.contains("100"), "{s}");
    }

    #[test]
    fn from_anyhow() {
        let a = anyhow::anyhow!("boom");
        let e: EclError = a.into();
        assert!(matches!(e, EclError::Runtime(_)));
    }
}
