//! Configurator (Tier-2, paper Figure 3): knobs for the runtime internals
//! and access to execution statistics.

use crate::platform::fault::FaultPlan;

/// Tunables for `Engine::run`. Defaults reproduce the optimized runtime;
/// the ablation benches flip individual flags.
#[derive(Debug, Clone)]
pub struct Configurator {
    /// Upload inputs once per device and keep them resident (paper §5.2
    /// buffer optimization). Off = re-upload per package.
    pub resident_inputs: bool,
    /// Compile all chunk-size executables during device init (the paper's
    /// initialization optimization: build while other devices discover).
    /// Off = compile lazily on first use of each size.
    pub eager_compile: bool,
    /// Simulate device init latencies (profiles' init/init_contention).
    /// Off for overhead microbenchmarks that isolate the dispatch path.
    pub simulate_init: bool,
    /// Stretch execution times per device profile. Off = run at raw PJRT
    /// speed (used by the overhead experiment where EngineCL must be
    /// compared against the native driver on the *same* device).
    pub simulate_speed: bool,
    /// Collect per-package traces (Introspector).
    pub introspect: bool,
    /// Recover from device-worker failures: revoke the dead device's
    /// unfinished arena claims and requeue the work to survivors. Off =
    /// the seed's abort-on-failure behavior (first failure ends the run
    /// with `EclError::Worker` once all workers have drained).
    pub fault_tolerant: bool,
    /// Deterministic fault injection schedule (chaos testing). `None`
    /// (the default) injects nothing.
    pub fault_plan: Option<FaultPlan>,
    /// Seed feedback-capable schedulers (HGuided, Adaptive) from the
    /// performance-model store's cross-session throughput estimates at
    /// run start. Off = every run cold-starts from the profile priors
    /// (observations are still *recorded* either way — the knob gates
    /// consumption, not learning).
    pub warm_start: bool,
    /// Base seed for the run's simclock jitter streams (each device
    /// worker derives its own stream from it). `0` means "unset": solo
    /// engine runs keep the legacy fixed seed, and the persistent
    /// runtime fills in a per-session seed derived from its own seed
    /// and the session id — so a fixed runtime seed plus a fixed
    /// admission order reproduces every session's timing draws.
    pub rng_seed: u64,
}

impl Default for Configurator {
    fn default() -> Self {
        Self {
            resident_inputs: true,
            eager_compile: true,
            simulate_init: true,
            simulate_speed: true,
            introspect: true,
            fault_tolerant: true,
            fault_plan: None,
            warm_start: true,
            rng_seed: 0,
        }
    }
}

impl Configurator {
    /// Configuration for overhead measurements: no simulation, pure
    /// dispatch machinery on one device.
    pub fn raw() -> Self {
        Self { simulate_init: false, simulate_speed: false, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_optimized() {
        let c = Configurator::default();
        assert!(c.resident_inputs && c.eager_compile && c.simulate_init && c.simulate_speed);
        assert!(c.fault_tolerant, "recovery is on by default");
        assert!(c.fault_plan.is_none(), "no injection by default");
        assert!(c.warm_start, "cross-session warm start is on by default");
        assert_eq!(c.rng_seed, 0, "seed unset by default (legacy stream)");
    }

    #[test]
    fn raw_disables_simulation() {
        let c = Configurator::raw();
        assert!(!c.simulate_init && !c.simulate_speed);
        assert!(c.resident_inputs);
    }
}
