//! `Adaptive` — the feedback-driven scheduler (spec `adaptive`,
//! composable with `+pipe`): a closed-loop guided self-scheduler that
//! starts from a profile (or warm-start) prior, re-estimates every
//! device's throughput online from completed-package timings, and sizes
//! packages with a decaying chunk schedule plus a minimum-package
//! clamp.
//!
//! Compared to [`HGuided`](super::HGuided), which inherits the paper's
//! formula and (since the feedback refactor) merely swaps powers for
//! observed rates, `Adaptive` is built around the loop:
//!
//! * **Probe first.** Packages assigned to a device that has no
//!   measured estimate yet (no warm-start, nothing observed) are
//!   deliberately small — half the regular chunk, capped at the
//!   equal-share size. The probe sizing covers the first *two*
//!   pre-observation packages, not just the first, because under
//!   `+pipe` (depth 2) the master requests the lookahead package
//!   before the probe's observation can possibly return — so a
//!   mis-calibrated profile costs at most a double-buffer's worth of
//!   probes before real measurements take over.
//! * **EWMA re-estimation.** Every `observe` folds the package's
//!   granules/sec into the device's estimate with weight `alpha`
//!   (default 0.5 — responsive enough to track a `slow:` fault's
//!   mid-run degradation within a couple of packages).
//! * **Decaying chunks.** Package sizes follow the guided schedule
//!   `remaining * share / k` split across devices, so early packages
//!   are large (few sync points) and late ones small (devices converge
//!   on a common finish line even when an estimate was stale).
//! * **Minimum clamp.** An absolute floor of `min_granules` bounds the
//!   tail's package count; unlike HGuided's power-scaled floor it does
//!   not trust the profile, because the profile may be wrong — that is
//!   the whole point of this scheduler.
//! * **Tail cutoff.** A chunk is *refused* (terminal `None` for that
//!   device) when the rest of the live devices would drain the entire
//!   pending pool faster than this device finishes just its chunk —
//!   the clamp-sized tail package that HGuided is obliged to hand a
//!   straggler is exactly what stretches its last-device completion.
//!   The cutoff never fires while the pool is large (chunk time is a
//!   `1/(k·n)` fraction of pool time), never fires on the last live
//!   device (someone must drain the pool), and a refused device still
//!   executes requeued recovery work (the requeue path bypasses the
//!   scheduler by design).
//!
//! Like Dynamic/HGuided it is pool-based: packages are carved off one
//! shared cursor on demand, so the exactly-once cover invariant is
//! structural (asserted by the scheduler property suite) and feedback
//! can never change *what* is computed — only how big the pieces are
//! and who computes them. The one recovery wrinkle the cutoff adds is
//! handled in `reclaim_device`: when the *last* live device dies, the
//! undelivered remainder of the pool is handed back to the engine so
//! the requeue path can split it over the remaining (refused but
//! healthy) workers instead of stranding it.
//!
//! `next_package` stays off the allocation path; the only non-O(1)
//! piece is the tail-cutoff's live-rate sum, an O(ndev) fold over a
//! handful of devices (the estimates it reads are maintained
//! incrementally by [`ThroughputModel`]).

use crate::coordinator::work::Range;

use super::{PackageTiming, QosTracker, SchedDevice, Scheduler, ThroughputModel, QOS_TIGHTEN};

/// Chunk decay divisor: each request takes `share/k` of the remainder.
pub const DEFAULT_K: f64 = 2.0;
/// Absolute minimum package size, in granules.
pub const DEFAULT_MIN_GRANULES: usize = 1;
/// EWMA weight of the newest observation.
pub const DEFAULT_ALPHA: f64 = 0.5;

/// Tail-cutoff threshold: refuse a chunk when the device would need
/// longer for it than the rest of the live node needs for the *whole*
/// pending pool (scaled by this factor).
const TAIL_BETA: f64 = 1.0;

#[derive(Debug)]
pub struct Adaptive {
    k: f64,
    min_granules: usize,
    alpha: f64,
    // ---- per-run state (reset in `start`) ----------------------------
    granule: usize,
    total: usize,
    /// Next unassigned granule.
    cursor: usize,
    ndev: usize,
    model: ThroughputModel,
    /// Packages assigned so far per device (probe bookkeeping).
    assigned: Vec<usize>,
    /// Devices this scheduler has gone terminal for: tail-cutoff
    /// refusals plus devices reclaimed by the recovery path.
    terminal: Vec<bool>,
    /// Deadline-risk state (no-op for best-effort sessions).
    qos: QosTracker,
}

impl Adaptive {
    pub fn new(k: f64, min_granules: usize, alpha: f64) -> Self {
        Self {
            k: if k <= 0.0 { DEFAULT_K } else { k },
            min_granules: min_granules.max(1),
            alpha: if alpha > 0.0 && alpha <= 1.0 { alpha } else { DEFAULT_ALPHA },
            granule: 1,
            total: 0,
            cursor: 0,
            ndev: 0,
            model: ThroughputModel::new(DEFAULT_ALPHA),
            assigned: Vec::new(),
            terminal: Vec::new(),
            qos: QosTracker::default(),
        }
    }

    /// Package size in granules for device `dev` given `pending`
    /// unassigned granules.
    fn packet_granules(&self, dev: usize, pending: usize) -> usize {
        let n = self.ndev as f64;
        let share = self.model.share(dev);
        let mut raw = if self.assigned[dev] < 2 && !self.model.observed(dev) {
            // Probe: half the regular chunk, capped at the equal-share
            // size in case the prior *over*-rates the device — one
            // cheap observation beats one wrong commitment. (The cap
            // works both ways: a prior-weak device probes below its
            // share so the tail cutoff never mistakes the probe itself
            // for a straggler chunk.) Covers the first two
            // pre-observation packages: a `+pipe` lookahead is
            // requested before the probe's observation can return.
            pending as f64 * share.min(1.0 / n) / (2.0 * self.k * n)
        } else {
            pending as f64 * share / (self.k * n)
        };
        // Deadline-driven tail sizing: while the session's deadline is
        // at risk, halve the chunk so devices re-synchronize at finer
        // granularity (the straggler overhang is what blows deadlines).
        // Never taken without a QoS hint — sizing stays bit-identical
        // for best-effort sessions.
        if self.qos.at_risk(pending, &self.model) {
            raw /= QOS_TIGHTEN;
        }
        (raw.floor() as usize).max(self.min_granules).min(pending)
    }

    /// Estimated throughput of the live devices other than `dev`.
    fn live_rest_rate(&self, dev: usize) -> f64 {
        (0..self.ndev)
            .filter(|&d| d != dev && !self.terminal[d])
            .map(|d| self.model.rate(d))
            .sum()
    }
}

impl Scheduler for Adaptive {
    fn name(&self) -> String {
        "Adaptive".into()
    }

    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]) {
        self.granule = granule;
        self.total = total_granules;
        self.cursor = 0;
        self.ndev = devices.len();
        self.model = ThroughputModel::new(self.alpha);
        self.model.start(devices);
        self.assigned = vec![0; devices.len()];
        self.terminal = vec![false; devices.len()];
        self.qos.start(devices);
    }

    fn next_package(&mut self, dev: usize) -> Option<Range> {
        let pending = self.total - self.cursor;
        if pending == 0 {
            return None;
        }
        if self.terminal.get(dev).copied().unwrap_or(true) {
            return None;
        }
        let take = self.packet_granules(dev, pending);
        // Tail cutoff (see module docs): refuse when the rest of the
        // live node drains the whole pending pool faster than this
        // device finishes its chunk. `rest == 0` means this is the last
        // live device — it must take the work.
        let rest = self.live_rest_rate(dev);
        if rest > 0.0 {
            let time_dev = take as f64 / self.model.rate(dev).max(1e-12);
            let time_rest = pending as f64 / rest;
            if time_dev > TAIL_BETA * time_rest {
                self.terminal[dev] = true;
                return None;
            }
        }
        self.assigned[dev] += 1;
        let begin = self.cursor;
        self.cursor += take;
        Some(Range::new(begin * self.granule, self.cursor * self.granule))
    }

    fn observe(&mut self, dev: usize, range: Range, timing: PackageTiming) {
        let granules = range.len() as f64 / self.granule.max(1) as f64;
        self.model.observe(dev, granules, timing.span);
        self.qos.observe(dev, timing.span);
    }

    /// Recovery: mark the dead device terminal so the tail cutoff never
    /// counts it as a live drain — and, when *no* live device remains,
    /// hand the undelivered remainder of the pool back to the engine so
    /// the requeue path (which bypasses the scheduler) can split it
    /// over the surviving, possibly tail-refused, workers instead of
    /// stranding it.
    fn reclaim_device(&mut self, dev: usize) -> Vec<Range> {
        if dev < self.ndev {
            self.terminal[dev] = true;
        }
        if self.cursor < self.total && (0..self.ndev).all(|d| self.terminal[d]) {
            let r = Range::new(self.cursor * self.granule, self.total * self.granule);
            self.cursor = self.total;
            return vec![r];
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;

    fn devs(powers: &[f64]) -> Vec<SchedDevice> {
        powers
            .iter()
            .enumerate()
            .map(|(i, p)| SchedDevice::new(format!("d{i}"), *p))
            .collect()
    }

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    fn timing(span: Duration) -> PackageTiming {
        PackageTiming { span, raw_exec: span / 4 }
    }

    /// Drain with an active set (a refused device is terminal, the
    /// others keep pulling) and return the ranges in assignment order.
    fn drain(s: &mut Adaptive, ndev: usize, observe_span: impl Fn(usize) -> Duration) -> Vec<Range> {
        let mut active: Vec<usize> = (0..ndev).collect();
        let mut out = Vec::new();
        let mut turn = 0usize;
        while !active.is_empty() {
            let dev = active[turn % active.len()];
            match s.next_package(dev) {
                Some(r) => {
                    s.observe(dev, r, timing(observe_span(dev)));
                    out.push(r);
                    turn += 1;
                }
                None => {
                    let idx = turn % active.len();
                    active.remove(idx);
                }
            }
        }
        out
    }

    #[test]
    fn covers_everything_with_refusals_allowed() {
        let mut s = Adaptive::new(2.0, 2, 0.5);
        s.start(1000, 64, &devs(&[0.3, 1.0, 0.42]));
        let ranges = drain(&mut s, 3, |_| ms(5));
        let mut cursor = 0;
        for r in &ranges {
            assert_eq!(r.begin, cursor, "contiguous cover");
            assert_eq!(r.begin % 64, 0);
            assert_eq!(r.len() % 64, 0);
            cursor = r.end;
        }
        assert_eq!(cursor, 1000 * 64, "whole pool covered");
    }

    #[test]
    fn pre_observation_packages_are_probes() {
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(10_000, 1, &devs(&[1.0, 1.0]));
        // Probe = pending / (2*k*n*n) = 10_000 / 16 = 625.
        let probe = s.next_package(0).unwrap();
        assert_eq!(probe.len(), 625);
        // The second pre-observation request (the `+pipe` lookahead
        // case) is still probe-sized: the mis-commitment bound holds
        // for a double-buffered device too.
        let second = s.next_package(0).unwrap();
        assert!(
            second.len() <= probe.len(),
            "unobserved lookahead stays probe-sized: {} vs {}",
            second.len(),
            probe.len()
        );
        // Once observed, sizing switches to the (larger) share formula.
        s.observe(0, probe, timing(ms(100)));
        let third = s.next_package(0).unwrap().len();
        assert!(third > probe.len(), "post-observation package grows: {third}");
    }

    #[test]
    fn shares_follow_observed_throughput_not_priors() {
        // Priors claim equal devices; observations say device 1 is 4x
        // slower. After the probes, device 0's packages must be several
        // times larger than device 1's.
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(100_000, 1, &devs(&[1.0, 1.0]));
        for dev in 0..2 {
            let r = s.next_package(dev).unwrap();
            let span = if dev == 1 { ms(400) } else { ms(100) };
            s.observe(dev, r, timing(span));
        }
        let fast = s.next_package(0).unwrap().len();
        let slow = s.next_package(1).unwrap().len();
        assert!(
            fast > slow * 3,
            "observed 4x speed gap must show in sizing: fast {fast} vs slow {slow}"
        );
    }

    #[test]
    fn warm_start_skips_the_probe() {
        let mut d = devs(&[1.0, 1.0]);
        d[0].warm_rate = Some(1000.0);
        d[1].warm_rate = Some(250.0);
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(10_000, 1, &d);
        let a = s.next_package(0).unwrap().len();
        let b = s.next_package(1).unwrap().len();
        // Warm rates are trusted immediately: 4x ratio, no probe sizing.
        assert!(a > b * 2, "warm-started shares: {a} vs {b}");
        assert!(a > 625, "no probe clamp on a warm device: {a}");
    }

    #[test]
    fn respects_min_granules_and_terminates() {
        let mut s = Adaptive::new(2.0, 4, 0.5);
        s.start(1000, 1, &devs(&[1.0, 1.0]));
        let sizes: Vec<usize> = drain(&mut s, 2, |_| ms(10)).iter().map(Range::len).collect();
        for &sz in &sizes[..sizes.len() - 1] {
            assert!(sz >= 4, "package below the clamp: {sz}");
        }
        assert_eq!(sizes.iter().sum::<usize>(), 1000);
    }

    #[test]
    fn degradation_mid_run_shifts_work_away() {
        // Both devices observed fast; then device 1 degrades 4x. Its
        // next packages must shrink relative to device 0's.
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(1_000_000, 1, &devs(&[1.0, 1.0]));
        for round in 0..6 {
            for dev in 0..2 {
                let r = s.next_package(dev).unwrap();
                let per_granule = if dev == 1 && round >= 2 { 4 } else { 1 };
                let span = Duration::from_micros((r.len() * per_granule) as u64);
                s.observe(dev, r, timing(span));
            }
        }
        let fast = s.next_package(0).unwrap().len();
        let slow = s.next_package(1).unwrap().len();
        assert!(
            fast > slow * 2,
            "post-degradation sizing must shift work: fast {fast} vs slow {slow}"
        );
    }

    /// The tail cutoff: on a tiny pool, a device whose estimated rate
    /// is far below the node's is refused (terminal) instead of being
    /// handed a clamp-sized chunk that would outlive the whole pool —
    /// and the last live device is never refused.
    #[test]
    fn tail_cutoff_refuses_stragglers_but_never_the_last_device() {
        // 4-granule pool (the nbody shape) over batel-like powers.
        let mut s = Adaptive::new(2.0, 2, 0.5);
        s.start(4, 256, &devs(&[0.3, 1.0, 0.42]));
        assert!(s.next_package(0).is_none(), "cpu chunk outlives the pool: refused");
        assert!(s.next_package(2).is_none(), "acc likewise");
        let r = s.next_package(1).expect("the fast device must be granted");
        assert!(!r.is_empty());
        // The refusals are terminal...
        assert!(s.next_package(0).is_none());
        // ...and the last live device drains the rest alone.
        let mut cursor = r.end;
        while let Some(r) = s.next_package(1) {
            assert_eq!(r.begin, cursor);
            cursor = r.end;
        }
        assert_eq!(cursor, 4 * 256, "gpu drained the whole pool");
    }

    #[test]
    fn cutoff_never_fires_on_a_large_pool() {
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(10_000, 1, &devs(&[0.05, 1.0]));
        // Even a 20x-weaker prior is granted while the pool is deep.
        assert!(s.next_package(0).is_some(), "weak device still served mid-run");
    }

    /// Recovery contract: when the last live device dies, the
    /// undelivered pool remainder is handed back (exactly once) so the
    /// requeue path can cover it; with live devices left, nothing is.
    #[test]
    fn reclaim_returns_remainder_only_when_no_live_device_is_left() {
        let mut s = Adaptive::new(2.0, 2, 0.5);
        s.start(4, 256, &devs(&[0.3, 1.0, 0.42]));
        assert!(s.next_package(0).is_none(), "cpu tail-refused");
        assert!(s.next_package(2).is_none(), "acc tail-refused");
        let first = s.next_package(1).expect("gpu granted");
        // gpu dies holding `first`; it was the last live device, so the
        // scheduler must surrender the undelivered remainder.
        let reclaimed = s.reclaim_device(1);
        assert_eq!(reclaimed.len(), 1);
        assert_eq!(reclaimed[0].begin, first.end);
        assert_eq!(reclaimed[0].end, 4 * 256);
        assert!(s.reclaim_device(1).is_empty(), "remainder handed back once");
        assert!(s.next_package(1).is_none(), "reclaimed device is terminal");

        // With another live device, a death reclaims nothing — the
        // survivor keeps draining the shared pool.
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(100, 1, &devs(&[1.0, 1.0]));
        s.next_package(0).unwrap();
        assert!(s.reclaim_device(0).is_empty(), "dev1 still drains the pool");
        let mut total = 0;
        while let Some(r) = s.next_package(1) {
            total += r.len();
        }
        assert!(total > 0, "survivor pulled the remaining pool");
    }

    #[test]
    fn qos_pressure_at_start_shrinks_packages() {
        use super::super::QosHint;
        let d = devs(&[1.0, 1.0]);
        let mut plain = Adaptive::new(2.0, 1, 0.5);
        plain.start(10_000, 1, &d);
        let mut dq = d.clone();
        for dev in &mut dq {
            // Admission already priced the run over its deadline.
            dev.qos = Some(QosHint::new(1.0, 2.0));
        }
        let mut hinted = Adaptive::new(2.0, 1, 0.5);
        hinted.start(10_000, 1, &dq);
        let a = plain.next_package(0).unwrap().len();
        let b = hinted.next_package(0).unwrap().len();
        assert!(b < a, "at-risk hint must shrink the chunk: {b} vs {a}");
        assert!(b >= a / 3, "tightening is a halving, not a collapse: {b} vs {a}");
    }

    #[test]
    fn qos_risk_emerges_from_observed_slowness() {
        use super::super::QosHint;
        // Prediction was comfortable (1s vs 20s deadline), but the node
        // turns out ~100x slower than priced: after one observation the
        // tracker's busy+remaining overruns the deadline and sizing
        // tightens relative to a hint-free twin fed identical spans.
        let d = devs(&[1.0, 1.0]);
        let mut dq = d.clone();
        for dev in &mut dq {
            dev.qos = Some(QosHint::new(20.0, 1.0));
        }
        let mut plain = Adaptive::new(2.0, 1, 0.5);
        plain.start(10_000, 1, &d);
        let mut hinted = Adaptive::new(2.0, 1, 0.5);
        hinted.start(10_000, 1, &dq);
        let pa = plain.next_package(0).unwrap();
        let pb = hinted.next_package(0).unwrap();
        assert_eq!(pa, pb, "with slack the hint must not move boundaries");
        // ~600 granules in 8s => 75 g/s => ~125s remaining >> 20s.
        plain.observe(0, pa, timing(Duration::from_secs(8)));
        hinted.observe(0, pb, timing(Duration::from_secs(8)));
        let a = plain.next_package(0).unwrap().len();
        let b = hinted.next_package(0).unwrap().len();
        assert!(b < a, "observed slowness must trigger tightening: {b} vs {a}");
    }

    #[test]
    fn qos_hint_with_ample_slack_is_boundary_neutral() {
        use super::super::QosHint;
        let d = devs(&[0.3, 1.0, 0.42]);
        let mut dq = d.clone();
        for dev in &mut dq {
            dev.qos = Some(QosHint::new(1e6, 1.0));
        }
        let mut plain = Adaptive::new(2.0, 2, 0.5);
        plain.start(1000, 64, &d);
        let mut hinted = Adaptive::new(2.0, 2, 0.5);
        hinted.start(1000, 64, &dq);
        let a = drain(&mut plain, 3, |_| ms(5));
        // drain() owns its observe loop, so run the hinted twin through
        // an identical schedule by hand.
        let b = drain(&mut hinted, 3, |_| ms(5));
        assert_eq!(a, b, "huge slack: identical covers with and without the hint");
    }

    #[test]
    fn zero_granules_yields_nothing() {
        let mut s = Adaptive::new(2.0, 2, 0.5);
        s.start(0, 8, &devs(&[1.0]));
        assert!(s.next_package(0).is_none());
    }

    #[test]
    fn bad_knobs_fall_back_to_defaults() {
        let s = Adaptive::new(-1.0, 0, 7.0);
        assert!((s.k - DEFAULT_K).abs() < 1e-12);
        assert_eq!(s.min_granules, 1);
        assert!((s.alpha - DEFAULT_ALPHA).abs() < 1e-12);
    }
}
