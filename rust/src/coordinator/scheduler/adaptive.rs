//! `Adaptive` — the feedback-driven scheduler (spec `adaptive`,
//! composable with `+pipe`): a closed-loop guided self-scheduler that
//! starts from a profile (or warm-start) prior, re-estimates every
//! device's throughput online from completed-package timings, and sizes
//! packages with a decaying chunk schedule plus a minimum-package
//! clamp.
//!
//! Compared to [`HGuided`](super::HGuided), which inherits the paper's
//! formula and (since the feedback refactor) merely swaps powers for
//! observed rates, `Adaptive` is built around the loop:
//!
//! * **Probe first.** Packages assigned to a device that has no
//!   measured estimate yet (no warm-start, nothing observed) are
//!   deliberately small — half the regular chunk, capped at the
//!   equal-share size. The probe sizing covers the first *two*
//!   pre-observation packages, not just the first, because under
//!   `+pipe` (depth 2) the master requests the lookahead package
//!   before the probe's observation can possibly return — so a
//!   mis-calibrated profile costs at most a double-buffer's worth of
//!   probes before real measurements take over.
//! * **EWMA re-estimation.** Every `observe` folds the package's
//!   granules/sec into the device's estimate with weight `alpha`
//!   (default 0.5 — responsive enough to track a `slow:` fault's
//!   mid-run degradation within a couple of packages).
//! * **Decaying chunks.** Package sizes follow the guided schedule
//!   `remaining * share / k` split across devices, so early packages
//!   are large (few sync points) and late ones small (devices converge
//!   on a common finish line even when an estimate was stale).
//! * **Minimum clamp.** An absolute floor of `min_granules` bounds the
//!   tail's package count; unlike HGuided's power-scaled floor it does
//!   not trust the profile, because the profile may be wrong — that is
//!   the whole point of this scheduler.
//! * **Tail cutoff.** A chunk is *refused* (terminal `None` for that
//!   device) when the rest of the live devices would drain the entire
//!   pending pool faster than this device finishes just its chunk —
//!   the clamp-sized tail package that HGuided is obliged to hand a
//!   straggler is exactly what stretches its last-device completion.
//!   The cutoff never fires while the pool is large (chunk time is a
//!   `1/(k·n)` fraction of pool time), never fires on the last live
//!   device (someone must drain the pool), and a refused device still
//!   executes requeued recovery work (the requeue path bypasses the
//!   scheduler by design).
//!
//! Like Dynamic/HGuided it is pool-based: packages are carved off one
//! shared cursor on demand, so the exactly-once cover invariant is
//! structural (asserted by the scheduler property suite) and feedback
//! can never change *what* is computed — only how big the pieces are
//! and who computes them. The one recovery wrinkle the cutoff adds is
//! handled in `reclaim_device`: when the *last* live device dies, the
//! undelivered remainder of the pool is handed back to the engine so
//! the requeue path can split it over the remaining (refused but
//! healthy) workers instead of stranding it.
//!
//! Float-ordering audit (PR-10, discharged): no comparison in this file
//! unwraps a `partial_cmp`. The subset selector ranks with strict `<`
//! over scores whose operands are clamped finite at ingress (powers and
//! rates via `ThroughputModel`, watts via `.max(0.0)`, epg priors and
//! caps via `is_finite` filters), and its infeasible-cap tiebreak uses
//! IEEE `total_cmp`. The NaN regression test below pins the no-panic,
//! full-cover behavior for a fully poisoned device profile.
//!
//! `next_package` stays off the allocation path; the only non-O(1)
//! piece is the tail-cutoff's live-rate sum, an O(ndev) fold over a
//! handful of devices (the estimates it reads are maintained
//! incrementally by [`ThroughputModel`]).

use crate::coordinator::work::Range;

use super::{
    EnergyObjective, PackageTiming, QosTracker, SchedDevice, Scheduler, ThroughputModel,
    QOS_TIGHTEN,
};

/// Chunk decay divisor: each request takes `share/k` of the remainder.
pub const DEFAULT_K: f64 = 2.0;
/// Absolute minimum package size, in granules.
pub const DEFAULT_MIN_GRANULES: usize = 1;
/// EWMA weight of the newest observation.
pub const DEFAULT_ALPHA: f64 = 0.5;

/// Tail-cutoff threshold: refuse a chunk when the device would need
/// longer for it than the rest of the live node needs for the *whole*
/// pending pool (scaled by this factor).
const TAIL_BETA: f64 = 1.0;

/// Largest live-device count the energy selector will enumerate subsets
/// for (2^n candidates). Paper nodes have 3 devices; this is a safety
/// valve, not a practical limit.
const ENERGY_SELECT_MAX_DEVICES: usize = 12;

#[derive(Debug)]
pub struct Adaptive {
    k: f64,
    min_granules: usize,
    alpha: f64,
    /// What the active-set selector optimizes (time = classic behavior,
    /// bit-identical to pre-energy Adaptive).
    objective: EnergyObjective,
    /// Node power budget in watts (`adaptive:power=W`); `None` = uncapped.
    power_cap: Option<f64>,
    // ---- per-run state (reset in `start`) ----------------------------
    granule: usize,
    total: usize,
    /// Next unassigned granule.
    cursor: usize,
    ndev: usize,
    model: ThroughputModel,
    /// Packages assigned so far per device (probe bookkeeping).
    assigned: Vec<usize>,
    /// Devices this scheduler has gone terminal for: tail-cutoff and
    /// energy-selector refusals plus devices reclaimed by recovery.
    terminal: Vec<bool>,
    /// Deadline-risk state (no-op for best-effort sessions).
    qos: QosTracker,
    /// Busy power draw per device (watts, from the device profile).
    busy_watts: Vec<f64>,
    /// Idle power draw per device (watts).
    idle_watts: Vec<f64>,
    /// Joules/granule EWMA per device, seeded from the store's
    /// warm-start prior when present; `None` until the first energy
    /// observation on a cold device.
    epg: Vec<Option<f64>>,
    /// The power cap was infeasible even for a single device; the
    /// selector kept the lowest-draw device and recorded the breach.
    cap_violated: bool,
}

impl Adaptive {
    pub fn new(k: f64, min_granules: usize, alpha: f64) -> Self {
        Self::with_objective(k, min_granules, alpha, EnergyObjective::Time, None)
    }

    /// Full-knob constructor backing `adaptive:obj=…,power=…` specs.
    /// With `objective == Time` and no cap, behavior is bit-identical
    /// to the classic `new` (the energy selector never runs).
    pub fn with_objective(
        k: f64,
        min_granules: usize,
        alpha: f64,
        objective: EnergyObjective,
        power_cap: Option<f64>,
    ) -> Self {
        Self {
            k: if k <= 0.0 { DEFAULT_K } else { k },
            min_granules: min_granules.max(1),
            alpha: if alpha > 0.0 && alpha <= 1.0 { alpha } else { DEFAULT_ALPHA },
            objective,
            power_cap: power_cap.filter(|w| w.is_finite() && *w > 0.0),
            granule: 1,
            total: 0,
            cursor: 0,
            ndev: 0,
            model: ThroughputModel::new(DEFAULT_ALPHA),
            assigned: Vec::new(),
            terminal: Vec::new(),
            qos: QosTracker::default(),
            busy_watts: Vec::new(),
            idle_watts: Vec::new(),
            epg: Vec::new(),
            cap_violated: false,
        }
    }

    /// Package size in granules for device `dev` given `pending`
    /// unassigned granules.
    fn packet_granules(&self, dev: usize, pending: usize) -> usize {
        let n = self.ndev as f64;
        let share = self.model.share(dev);
        let mut raw = if self.assigned[dev] < 2 && !self.model.observed(dev) {
            // Probe: half the regular chunk, capped at the equal-share
            // size in case the prior *over*-rates the device — one
            // cheap observation beats one wrong commitment. (The cap
            // works both ways: a prior-weak device probes below its
            // share so the tail cutoff never mistakes the probe itself
            // for a straggler chunk.) Covers the first two
            // pre-observation packages: a `+pipe` lookahead is
            // requested before the probe's observation can return.
            pending as f64 * share.min(1.0 / n) / (2.0 * self.k * n)
        } else {
            pending as f64 * share / (self.k * n)
        };
        // Deadline-driven tail sizing: while the session's deadline is
        // at risk, halve the chunk so devices re-synchronize at finer
        // granularity (the straggler overhang is what blows deadlines).
        // Never taken without a QoS hint — sizing stays bit-identical
        // for best-effort sessions.
        if self.qos.at_risk(pending, &self.model) {
            raw /= QOS_TIGHTEN;
        }
        (raw.floor() as usize).max(self.min_granules).min(pending)
    }

    /// Estimated throughput of the live devices other than `dev`.
    fn live_rest_rate(&self, dev: usize) -> f64 {
        (0..self.ndev)
            .filter(|&d| d != dev && !self.terminal[d])
            .map(|d| self.model.rate(d))
            .sum()
    }

    /// Effective busy draw of `dev`: the measured joules/granule times
    /// the estimated rate when an energy observation (or warm-start
    /// prior) exists — i.e. watts the device *actually* burns per unit
    /// of progress — falling back to the profile's nameplate draw.
    fn effective_busy_watts(&self, dev: usize) -> f64 {
        self.epg[dev]
            .map(|e| e * self.model.rate(dev))
            .filter(|w| w.is_finite() && *w > 0.0)
            .unwrap_or(self.busy_watts[dev])
    }

    /// Energy-aware active-set selection: over every non-empty subset
    /// of the live devices, estimate node power (busy draw of the
    /// subset + idle draw of the excluded) and completion time
    /// (pool / summed rate), keep the subset optimizing the objective
    /// subject to the power cap, and refuse the rest via the existing
    /// `terminal` mechanism (sticky, never the last live device — a
    /// subset is non-empty by construction).
    ///
    /// Never runs for plain time-objective uncapped runs, so classic
    /// Adaptive sizing stays bit-identical. Re-run after each
    /// observation: better rate/epg estimates can tighten the set
    /// (exclusions are monotone — a refused device never comes back,
    /// matching the master's `dry` bookkeeping).
    fn select_active_set(&mut self) {
        if self.objective == EnergyObjective::Time && self.power_cap.is_none() {
            return;
        }
        let live: Vec<usize> = (0..self.ndev).filter(|&d| !self.terminal[d]).collect();
        if live.len() <= 1 || live.len() > ENERGY_SELECT_MAX_DEVICES {
            return;
        }
        // Node draw always includes every live device's idle floor;
        // activating a device adds its (busy - idle) increment.
        let idle_floor: f64 = live.iter().map(|&d| self.idle_watts[d]).sum();
        let mut best: Option<(f64, u32)> = None;
        for mask in 1u32..(1 << live.len()) {
            let mut rate = 0.0;
            let mut extra = 0.0;
            for (bit, &d) in live.iter().enumerate() {
                if mask & (1 << bit) != 0 {
                    rate += self.model.rate(d);
                    extra += (self.effective_busy_watts(d) - self.idle_watts[d]).max(0.0);
                }
            }
            let node_watts = idle_floor + extra;
            if let Some(cap) = self.power_cap {
                if node_watts > cap {
                    continue;
                }
            }
            // Scores: Time minimizes makespan (1/rate — the pool size
            // is a common factor); EDP minimizes watts/rate², i.e.
            // P·T² with the pool² factor dropped. Ranking is therefore
            // independent of how much of the pool remains.
            let score = match self.objective {
                EnergyObjective::Time => 1.0 / rate.max(1e-12),
                EnergyObjective::Edp => node_watts / (rate * rate).max(1e-24),
            };
            let better = match best {
                None => true,
                // Strict improvement only: ties keep the earlier
                // (smaller-mask) subset, a deterministic choice.
                Some((s, _)) => score < s,
            };
            if better {
                best = Some((score, mask));
            }
        }
        match best {
            Some((_, mask)) => {
                for (bit, &d) in live.iter().enumerate() {
                    if mask & (1 << bit) == 0 {
                        self.terminal[d] = true;
                    }
                }
            }
            None => {
                // Cap infeasible even for one device: someone must
                // compute. Keep the lowest-draw live device and record
                // the breach (surfaced by the energy harness).
                let keep = live
                    .iter()
                    .copied()
                    .min_by(|&a, &b| {
                        self.effective_busy_watts(a).total_cmp(&self.effective_busy_watts(b))
                    })
                    .expect("live is non-empty");
                for &d in &live {
                    if d != keep {
                        self.terminal[d] = true;
                    }
                }
                self.cap_violated = true;
            }
        }
    }
}

impl Scheduler for Adaptive {
    fn name(&self) -> String {
        let mut s = String::from("Adaptive");
        if self.objective == EnergyObjective::Edp {
            s.push_str("-EDP");
        }
        if self.power_cap.is_some() {
            s.push_str("-cap");
        }
        s
    }

    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]) {
        self.granule = granule;
        self.total = total_granules;
        self.cursor = 0;
        self.ndev = devices.len();
        self.model = ThroughputModel::new(self.alpha);
        self.model.start(devices);
        self.assigned = vec![0; devices.len()];
        self.terminal = vec![false; devices.len()];
        self.qos.start(devices);
        self.busy_watts = devices.iter().map(|d| d.busy_watts.max(0.0)).collect();
        self.idle_watts = devices.iter().map(|d| d.idle_watts.max(0.0)).collect();
        self.epg = devices
            .iter()
            .map(|d| d.warm_epg.filter(|e| e.is_finite() && *e > 0.0))
            .collect();
        self.cap_violated = false;
        self.select_active_set();
    }

    fn next_package(&mut self, dev: usize) -> Option<Range> {
        let pending = self.total - self.cursor;
        if pending == 0 {
            return None;
        }
        if self.terminal.get(dev).copied().unwrap_or(true) {
            return None;
        }
        let take = self.packet_granules(dev, pending);
        // Tail cutoff (see module docs): refuse when the rest of the
        // live node drains the whole pending pool faster than this
        // device finishes its chunk. `rest == 0` means this is the last
        // live device — it must take the work.
        let rest = self.live_rest_rate(dev);
        if rest > 0.0 {
            let time_dev = take as f64 / self.model.rate(dev).max(1e-12);
            let time_rest = pending as f64 / rest;
            if time_dev > TAIL_BETA * time_rest {
                self.terminal[dev] = true;
                return None;
            }
        }
        self.assigned[dev] += 1;
        let begin = self.cursor;
        self.cursor += take;
        Some(Range::new(begin * self.granule, self.cursor * self.granule))
    }

    fn observe(&mut self, dev: usize, range: Range, timing: PackageTiming) {
        let granules = range.len() as f64 / self.granule.max(1) as f64;
        self.model.observe(dev, granules, timing.span);
        self.qos.observe(dev, timing.span);
        // Joules/granule EWMA: the package burned busy_watts over its
        // occupancy span. Same alpha as the rate model so energy and
        // throughput estimates track the device at the same cadence.
        if dev < self.ndev && granules > 0.0 {
            let sample = self.busy_watts[dev] * timing.span.as_secs_f64() / granules;
            if sample.is_finite() && sample >= 0.0 {
                self.epg[dev] = Some(match self.epg[dev] {
                    Some(prev) => self.alpha * sample + (1.0 - self.alpha) * prev,
                    None => sample,
                });
            }
        }
        // Fresh estimates can change the energy-optimal active set.
        self.select_active_set();
    }

    /// Recovery: mark the dead device terminal so the tail cutoff never
    /// counts it as a live drain — and, when *no* live device remains,
    /// hand the undelivered remainder of the pool back to the engine so
    /// the requeue path (which bypasses the scheduler) can split it
    /// over the surviving, possibly tail-refused, workers instead of
    /// stranding it.
    fn reclaim_device(&mut self, dev: usize) -> Vec<Range> {
        if dev < self.ndev {
            self.terminal[dev] = true;
        }
        if self.cursor < self.total && (0..self.ndev).all(|d| self.terminal[d]) {
            let r = Range::new(self.cursor * self.granule, self.total * self.granule);
            self.cursor = self.total;
            return vec![r];
        }
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;

    fn devs(powers: &[f64]) -> Vec<SchedDevice> {
        powers
            .iter()
            .enumerate()
            .map(|(i, p)| SchedDevice::new(format!("d{i}"), *p))
            .collect()
    }

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    fn timing(span: Duration) -> PackageTiming {
        PackageTiming { span, raw_exec: span / 4 }
    }

    /// Drain with an active set (a refused device is terminal, the
    /// others keep pulling) and return the ranges in assignment order.
    fn drain(s: &mut Adaptive, ndev: usize, observe_span: impl Fn(usize) -> Duration) -> Vec<Range> {
        let mut active: Vec<usize> = (0..ndev).collect();
        let mut out = Vec::new();
        let mut turn = 0usize;
        while !active.is_empty() {
            let dev = active[turn % active.len()];
            match s.next_package(dev) {
                Some(r) => {
                    s.observe(dev, r, timing(observe_span(dev)));
                    out.push(r);
                    turn += 1;
                }
                None => {
                    let idx = turn % active.len();
                    active.remove(idx);
                }
            }
        }
        out
    }

    #[test]
    fn covers_everything_with_refusals_allowed() {
        let mut s = Adaptive::new(2.0, 2, 0.5);
        s.start(1000, 64, &devs(&[0.3, 1.0, 0.42]));
        let ranges = drain(&mut s, 3, |_| ms(5));
        let mut cursor = 0;
        for r in &ranges {
            assert_eq!(r.begin, cursor, "contiguous cover");
            assert_eq!(r.begin % 64, 0);
            assert_eq!(r.len() % 64, 0);
            cursor = r.end;
        }
        assert_eq!(cursor, 1000 * 64, "whole pool covered");
    }

    #[test]
    fn pre_observation_packages_are_probes() {
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(10_000, 1, &devs(&[1.0, 1.0]));
        // Probe = pending / (2*k*n*n) = 10_000 / 16 = 625.
        let probe = s.next_package(0).unwrap();
        assert_eq!(probe.len(), 625);
        // The second pre-observation request (the `+pipe` lookahead
        // case) is still probe-sized: the mis-commitment bound holds
        // for a double-buffered device too.
        let second = s.next_package(0).unwrap();
        assert!(
            second.len() <= probe.len(),
            "unobserved lookahead stays probe-sized: {} vs {}",
            second.len(),
            probe.len()
        );
        // Once observed, sizing switches to the (larger) share formula.
        s.observe(0, probe, timing(ms(100)));
        let third = s.next_package(0).unwrap().len();
        assert!(third > probe.len(), "post-observation package grows: {third}");
    }

    #[test]
    fn shares_follow_observed_throughput_not_priors() {
        // Priors claim equal devices; observations say device 1 is 4x
        // slower. After the probes, device 0's packages must be several
        // times larger than device 1's.
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(100_000, 1, &devs(&[1.0, 1.0]));
        for dev in 0..2 {
            let r = s.next_package(dev).unwrap();
            let span = if dev == 1 { ms(400) } else { ms(100) };
            s.observe(dev, r, timing(span));
        }
        let fast = s.next_package(0).unwrap().len();
        let slow = s.next_package(1).unwrap().len();
        assert!(
            fast > slow * 3,
            "observed 4x speed gap must show in sizing: fast {fast} vs slow {slow}"
        );
    }

    #[test]
    fn warm_start_skips_the_probe() {
        let mut d = devs(&[1.0, 1.0]);
        d[0].warm_rate = Some(1000.0);
        d[1].warm_rate = Some(250.0);
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(10_000, 1, &d);
        let a = s.next_package(0).unwrap().len();
        let b = s.next_package(1).unwrap().len();
        // Warm rates are trusted immediately: 4x ratio, no probe sizing.
        assert!(a > b * 2, "warm-started shares: {a} vs {b}");
        assert!(a > 625, "no probe clamp on a warm device: {a}");
    }

    #[test]
    fn respects_min_granules_and_terminates() {
        let mut s = Adaptive::new(2.0, 4, 0.5);
        s.start(1000, 1, &devs(&[1.0, 1.0]));
        let sizes: Vec<usize> = drain(&mut s, 2, |_| ms(10)).iter().map(Range::len).collect();
        for &sz in &sizes[..sizes.len() - 1] {
            assert!(sz >= 4, "package below the clamp: {sz}");
        }
        assert_eq!(sizes.iter().sum::<usize>(), 1000);
    }

    #[test]
    fn degradation_mid_run_shifts_work_away() {
        // Both devices observed fast; then device 1 degrades 4x. Its
        // next packages must shrink relative to device 0's.
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(1_000_000, 1, &devs(&[1.0, 1.0]));
        for round in 0..6 {
            for dev in 0..2 {
                let r = s.next_package(dev).unwrap();
                let per_granule = if dev == 1 && round >= 2 { 4 } else { 1 };
                let span = Duration::from_micros((r.len() * per_granule) as u64);
                s.observe(dev, r, timing(span));
            }
        }
        let fast = s.next_package(0).unwrap().len();
        let slow = s.next_package(1).unwrap().len();
        assert!(
            fast > slow * 2,
            "post-degradation sizing must shift work: fast {fast} vs slow {slow}"
        );
    }

    /// The tail cutoff: on a tiny pool, a device whose estimated rate
    /// is far below the node's is refused (terminal) instead of being
    /// handed a clamp-sized chunk that would outlive the whole pool —
    /// and the last live device is never refused.
    #[test]
    fn tail_cutoff_refuses_stragglers_but_never_the_last_device() {
        // 4-granule pool (the nbody shape) over batel-like powers.
        let mut s = Adaptive::new(2.0, 2, 0.5);
        s.start(4, 256, &devs(&[0.3, 1.0, 0.42]));
        assert!(s.next_package(0).is_none(), "cpu chunk outlives the pool: refused");
        assert!(s.next_package(2).is_none(), "acc likewise");
        let r = s.next_package(1).expect("the fast device must be granted");
        assert!(!r.is_empty());
        // The refusals are terminal...
        assert!(s.next_package(0).is_none());
        // ...and the last live device drains the rest alone.
        let mut cursor = r.end;
        while let Some(r) = s.next_package(1) {
            assert_eq!(r.begin, cursor);
            cursor = r.end;
        }
        assert_eq!(cursor, 4 * 256, "gpu drained the whole pool");
    }

    #[test]
    fn cutoff_never_fires_on_a_large_pool() {
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(10_000, 1, &devs(&[0.05, 1.0]));
        // Even a 20x-weaker prior is granted while the pool is deep.
        assert!(s.next_package(0).is_some(), "weak device still served mid-run");
    }

    /// Recovery contract: when the last live device dies, the
    /// undelivered pool remainder is handed back (exactly once) so the
    /// requeue path can cover it; with live devices left, nothing is.
    #[test]
    fn reclaim_returns_remainder_only_when_no_live_device_is_left() {
        let mut s = Adaptive::new(2.0, 2, 0.5);
        s.start(4, 256, &devs(&[0.3, 1.0, 0.42]));
        assert!(s.next_package(0).is_none(), "cpu tail-refused");
        assert!(s.next_package(2).is_none(), "acc tail-refused");
        let first = s.next_package(1).expect("gpu granted");
        // gpu dies holding `first`; it was the last live device, so the
        // scheduler must surrender the undelivered remainder.
        let reclaimed = s.reclaim_device(1);
        assert_eq!(reclaimed.len(), 1);
        assert_eq!(reclaimed[0].begin, first.end);
        assert_eq!(reclaimed[0].end, 4 * 256);
        assert!(s.reclaim_device(1).is_empty(), "remainder handed back once");
        assert!(s.next_package(1).is_none(), "reclaimed device is terminal");

        // With another live device, a death reclaims nothing — the
        // survivor keeps draining the shared pool.
        let mut s = Adaptive::new(2.0, 1, 0.5);
        s.start(100, 1, &devs(&[1.0, 1.0]));
        s.next_package(0).unwrap();
        assert!(s.reclaim_device(0).is_empty(), "dev1 still drains the pool");
        let mut total = 0;
        while let Some(r) = s.next_package(1) {
            total += r.len();
        }
        assert!(total > 0, "survivor pulled the remaining pool");
    }

    #[test]
    fn qos_pressure_at_start_shrinks_packages() {
        use super::super::QosHint;
        let d = devs(&[1.0, 1.0]);
        let mut plain = Adaptive::new(2.0, 1, 0.5);
        plain.start(10_000, 1, &d);
        let mut dq = d.clone();
        for dev in &mut dq {
            // Admission already priced the run over its deadline.
            dev.qos = Some(QosHint::new(1.0, 2.0));
        }
        let mut hinted = Adaptive::new(2.0, 1, 0.5);
        hinted.start(10_000, 1, &dq);
        let a = plain.next_package(0).unwrap().len();
        let b = hinted.next_package(0).unwrap().len();
        assert!(b < a, "at-risk hint must shrink the chunk: {b} vs {a}");
        assert!(b >= a / 3, "tightening is a halving, not a collapse: {b} vs {a}");
    }

    #[test]
    fn qos_risk_emerges_from_observed_slowness() {
        use super::super::QosHint;
        // Prediction was comfortable (1s vs 20s deadline), but the node
        // turns out ~100x slower than priced: after one observation the
        // tracker's busy+remaining overruns the deadline and sizing
        // tightens relative to a hint-free twin fed identical spans.
        let d = devs(&[1.0, 1.0]);
        let mut dq = d.clone();
        for dev in &mut dq {
            dev.qos = Some(QosHint::new(20.0, 1.0));
        }
        let mut plain = Adaptive::new(2.0, 1, 0.5);
        plain.start(10_000, 1, &d);
        let mut hinted = Adaptive::new(2.0, 1, 0.5);
        hinted.start(10_000, 1, &dq);
        let pa = plain.next_package(0).unwrap();
        let pb = hinted.next_package(0).unwrap();
        assert_eq!(pa, pb, "with slack the hint must not move boundaries");
        // ~600 granules in 8s => 75 g/s => ~125s remaining >> 20s.
        plain.observe(0, pa, timing(Duration::from_secs(8)));
        hinted.observe(0, pb, timing(Duration::from_secs(8)));
        let a = plain.next_package(0).unwrap().len();
        let b = hinted.next_package(0).unwrap().len();
        assert!(b < a, "observed slowness must trigger tightening: {b} vs {a}");
    }

    #[test]
    fn qos_hint_with_ample_slack_is_boundary_neutral() {
        use super::super::QosHint;
        let d = devs(&[0.3, 1.0, 0.42]);
        let mut dq = d.clone();
        for dev in &mut dq {
            dev.qos = Some(QosHint::new(1e6, 1.0));
        }
        let mut plain = Adaptive::new(2.0, 2, 0.5);
        plain.start(1000, 64, &d);
        let mut hinted = Adaptive::new(2.0, 2, 0.5);
        hinted.start(1000, 64, &dq);
        let a = drain(&mut plain, 3, |_| ms(5));
        // drain() owns its observe loop, so run the hinted twin through
        // an identical schedule by hand.
        let b = drain(&mut hinted, 3, |_| ms(5));
        assert_eq!(a, b, "huge slack: identical covers with and without the hint");
    }

    #[test]
    fn zero_granules_yields_nothing() {
        let mut s = Adaptive::new(2.0, 2, 0.5);
        s.start(0, 8, &devs(&[1.0]));
        assert!(s.next_package(0).is_none());
    }

    #[test]
    fn bad_knobs_fall_back_to_defaults() {
        let s = Adaptive::new(-1.0, 0, 7.0);
        assert!((s.k - DEFAULT_K).abs() < 1e-12);
        assert_eq!(s.min_granules, 1);
        assert!((s.alpha - DEFAULT_ALPHA).abs() < 1e-12);
        assert_eq!(s.objective, EnergyObjective::Time);
        assert_eq!(s.power_cap, None);
        // Degenerate caps are dropped, not obeyed.
        let s = Adaptive::with_objective(2.0, 1, 0.5, EnergyObjective::Time, Some(f64::NAN));
        assert_eq!(s.power_cap, None);
    }

    /// Batel-shaped device set with real watts: cpu 95/10, gpu 225/12,
    /// phi 300/15, relative rates 0.3 / 1.0 / 0.42.
    fn batel_devs() -> Vec<SchedDevice> {
        vec![
            SchedDevice::new("cpu", 0.3).with_watts(95.0, 10.0),
            SchedDevice::new("gpu", 1.0).with_watts(225.0, 12.0),
            SchedDevice::new("phi", 0.42).with_watts(300.0, 15.0),
        ]
    }

    /// EDP selection on the batel shape: {cpu, gpu} wins (198 W/r²
    /// vs 210 for all three, 250 for gpu solo), so the power-hungry
    /// Phi is refused from the start while both others are served.
    #[test]
    fn edp_objective_drops_the_power_hungry_straggler() {
        let mut s = Adaptive::with_objective(2.0, 1, 0.5, EnergyObjective::Edp, None);
        s.start(10_000, 1, &batel_devs());
        assert!(s.next_package(2).is_none(), "phi is EDP-refused");
        assert!(s.terminal[2], "refusal is terminal");
        assert!(s.next_package(0).is_some(), "cpu stays in the EDP-optimal set");
        assert!(s.next_package(1).is_some(), "gpu stays in the EDP-optimal set");
        assert!(!s.cap_violated);
    }

    /// Time objective with watts plumbed but no cap is bit-identical
    /// to the classic scheduler — the selector must never run.
    #[test]
    fn time_objective_with_watts_is_boundary_neutral() {
        let mut plain = Adaptive::new(2.0, 2, 0.5);
        plain.start(1000, 64, &devs(&[0.3, 1.0, 0.42]));
        let mut energy_aware = Adaptive::with_objective(2.0, 2, 0.5, EnergyObjective::Time, None);
        energy_aware.start(1000, 64, &batel_devs());
        let a = drain(&mut plain, 3, |_| ms(5));
        let b = drain(&mut energy_aware, 3, |_| ms(5));
        assert_eq!(a, b, "watts alone must not move package boundaries");
    }

    /// A 400 W cap on batel admits {cpu, gpu} (335 W) but not any set
    /// containing the Phi alongside another device; the time objective
    /// picks the max-rate feasible subset.
    #[test]
    fn power_cap_excludes_devices_beyond_the_budget() {
        let mut s = Adaptive::with_objective(2.0, 1, 0.5, EnergyObjective::Time, Some(400.0));
        s.start(10_000, 1, &batel_devs());
        assert!(s.next_package(2).is_none(), "phi would blow the cap");
        assert!(s.next_package(0).is_some());
        assert!(s.next_package(1).is_some());
        assert!(!s.cap_violated, "a feasible cap is not a violation");
    }

    /// A cap below even the cheapest single device is infeasible:
    /// someone must compute, so the lowest-draw device is kept, the
    /// breach is recorded, and the pool still drains to completion.
    #[test]
    fn infeasible_cap_keeps_lowest_draw_device_and_records_violation() {
        let mut s = Adaptive::with_objective(2.0, 1, 0.5, EnergyObjective::Time, Some(50.0));
        s.start(1000, 1, &batel_devs());
        assert!(s.cap_violated, "infeasible cap must be flagged");
        assert!(s.next_package(1).is_none(), "gpu shed to approach the cap");
        assert!(s.next_package(2).is_none(), "phi shed to approach the cap");
        let mut cursor = 0;
        while let Some(r) = s.next_package(0) {
            assert_eq!(r.begin, cursor);
            s.observe(0, r, timing(ms(5)));
            cursor = r.end;
        }
        assert_eq!(cursor, 1000, "the kept device drains the whole pool");
    }

    /// Float-ordering audit regression (PR-10): a device whose profile
    /// is fully NaN-poisoned (power, watts, warm rate, warm epg) must
    /// degrade to the ingress clamps — the run never panics and the
    /// pool is still covered exactly, even with the energy selector
    /// (EDP objective) scoring subsets over the poisoned estimates.
    #[test]
    fn nan_poisoned_profile_still_covers_and_never_panics() {
        let mut poisoned = SchedDevice::new("poisoned", f64::NAN)
            .with_watts(f64::NAN, f64::NAN)
            .with_warm_epg(Some(f64::NAN));
        poisoned.warm_rate = Some(f64::NAN);
        let d = vec![poisoned, SchedDevice::new("healthy", 1.0).with_watts(100.0, 10.0)];
        for objective in [EnergyObjective::Time, EnergyObjective::Edp] {
            let mut s = Adaptive::with_objective(2.0, 1, 0.5, objective, None);
            s.start(1000, 1, &d);
            let ranges = drain(&mut s, 2, |_| ms(5));
            let mut cursor = 0;
            for r in &ranges {
                assert_eq!(r.begin, cursor, "contiguous cover ({objective:?})");
                cursor = r.end;
            }
            assert_eq!(cursor, 1000, "poisoned profile still covers ({objective:?})");
        }
    }

    /// The joules/granule EWMA: seeded by the first sample, folded with
    /// alpha thereafter, and warm-start priors are trusted immediately.
    #[test]
    fn energy_per_granule_ewma_tracks_observations() {
        let mut s = Adaptive::new(2.0, 1, 0.5);
        let d = vec![
            SchedDevice::new("a", 1.0).with_watts(100.0, 10.0),
            SchedDevice::new("b", 1.0).with_watts(100.0, 10.0).with_warm_epg(Some(3.0)),
        ];
        s.start(10_000, 1, &d);
        assert_eq!(s.epg[0], None, "cold device has no estimate");
        assert_eq!(s.epg[1], Some(3.0), "warm prior trusted immediately");
        // 100 W for 1 s over 100 granules = 1 J/granule.
        s.observe(0, Range::new(0, 100), timing(Duration::from_secs(1)));
        assert!((s.epg[0].unwrap() - 1.0).abs() < 1e-9);
        // Next sample 2 J/granule folds with alpha 0.5 → 1.5.
        s.observe(0, Range::new(100, 200), timing(Duration::from_secs(2)));
        assert!((s.epg[0].unwrap() - 1.5).abs() < 1e-9);
    }
}
