//! Pluggable scheduling system (paper Figure 4: Strategy pattern).
//!
//! A scheduler hands out granule-ranges to devices on request. The engine
//! calls `start` once with the work size and device descriptions, then
//! `next_package(dev)` every time device `dev` is idle; `None` is terminal
//! for that device. All three of the paper's algorithms are implemented;
//! new ones plug in through the same trait.

pub mod dynamic;
pub mod hguided;
pub mod static_sched;

pub use dynamic::Dynamic;
pub use hguided::HGuided;
pub use static_sched::Static;

use crate::coordinator::work::Range;

/// Device description given to schedulers at `start`.
#[derive(Debug, Clone)]
pub struct SchedDevice {
    pub name: String,
    /// Relative computing power (HGuided's P_i; Static's default props).
    pub power: f64,
}

/// The Strategy interface.
pub trait Scheduler: Send {
    fn name(&self) -> String;

    /// Reset internal state for a run over `total_granules` granules of
    /// `granule` work-items each, across `devices`.
    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]);

    /// The next package for device `dev` (indexes `devices` from `start`),
    /// in *work-items*. `None` = no more work for this device, ever.
    fn next_package(&mut self, dev: usize) -> Option<Range>;
}

/// Engine-facing configuration enum (Tier-2 API); materialized into a
/// boxed Strategy at run time.
#[derive(Debug, Clone)]
pub enum SchedulerKind {
    /// One package per device, proportional to `props` (or to device
    /// powers when `None`). `reversed` flips the delivery order
    /// (the paper's "Static rev").
    Static { props: Option<Vec<f64>>, reversed: bool },
    /// `packages` equal chunks, first-come-first-served.
    Dynamic { packages: usize },
    /// Geometrically decreasing packages weighted by device power.
    HGuided { k: f64, min_granules: usize },
}

impl SchedulerKind {
    pub fn static_default() -> Self {
        SchedulerKind::Static { props: None, reversed: false }
    }

    pub fn static_with(props: Vec<f64>) -> Self {
        SchedulerKind::Static { props: Some(props), reversed: false }
    }

    pub fn dynamic(packages: usize) -> Self {
        SchedulerKind::Dynamic { packages }
    }

    pub fn hguided() -> Self {
        SchedulerKind::HGuided { k: 2.0, min_granules: 2 }
    }

    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Static { props, reversed } => {
                Box::new(Static::new(props.clone(), *reversed))
            }
            SchedulerKind::Dynamic { packages } => Box::new(Dynamic::new(*packages)),
            SchedulerKind::HGuided { k, min_granules } => {
                Box::new(HGuided::new(*k, *min_granules))
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            SchedulerKind::Static { reversed: false, .. } => "Static".into(),
            SchedulerKind::Static { reversed: true, .. } => "Static rev".into(),
            SchedulerKind::Dynamic { packages } => format!("Dynamic {packages}"),
            SchedulerKind::HGuided { .. } => "HGuided".into(),
        }
    }
}

/// Parse a CLI scheduler spec: `static`, `static-rev`, `dynamic:N`,
/// `hguided`, `hguided:k=3,min=4`.
pub fn parse_kind(s: &str) -> Option<SchedulerKind> {
    let (head, tail) = s.split_once(':').unwrap_or((s, ""));
    match head {
        "static" => Some(SchedulerKind::Static { props: None, reversed: false }),
        "static-rev" => Some(SchedulerKind::Static { props: None, reversed: true }),
        "dynamic" => {
            let packages = if tail.is_empty() { 50 } else { tail.parse().ok()? };
            Some(SchedulerKind::Dynamic { packages })
        }
        "hguided" => {
            let mut k = 2.0;
            let mut min = 2;
            for part in tail.split(',').filter(|p| !p.is_empty()) {
                let (key, val) = part.split_once('=')?;
                match key {
                    "k" => k = val.parse().ok()?,
                    "min" => min = val.parse().ok()?,
                    _ => return None,
                }
            }
            Some(SchedulerKind::HGuided { k, min_granules: min })
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(SchedulerKind::static_default().label(), "Static");
        assert_eq!(SchedulerKind::dynamic(150).label(), "Dynamic 150");
        assert_eq!(SchedulerKind::hguided().label(), "HGuided");
        assert_eq!(
            SchedulerKind::Static { props: None, reversed: true }.label(),
            "Static rev"
        );
    }

    #[test]
    fn parse_specs() {
        assert!(matches!(parse_kind("static"), Some(SchedulerKind::Static { reversed: false, .. })));
        assert!(matches!(parse_kind("static-rev"), Some(SchedulerKind::Static { reversed: true, .. })));
        assert!(matches!(parse_kind("dynamic:150"), Some(SchedulerKind::Dynamic { packages: 150 })));
        assert!(matches!(parse_kind("dynamic"), Some(SchedulerKind::Dynamic { packages: 50 })));
        match parse_kind("hguided:k=3.5,min=4") {
            Some(SchedulerKind::HGuided { k, min_granules }) => {
                assert!((k - 3.5).abs() < 1e-9);
                assert_eq!(min_granules, 4);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse_kind("nope").is_none());
        assert!(parse_kind("hguided:bogus=1").is_none());
    }
}
