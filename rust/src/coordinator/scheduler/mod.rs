//! Pluggable scheduling system (paper Figure 4: Strategy pattern).
//!
//! A scheduler hands out granule-ranges to devices on request. The engine
//! calls `start` once with the work size and device descriptions, then
//! `next_package(dev)` every time device `dev` has a free pipeline slot;
//! `None` is terminal for that device. All three of the paper's
//! algorithms are implemented; new ones plug in through the same trait,
//! and the [`Pipelined`] wrapper composes package pipelining with any of
//! them (spec suffix `+pipe`).

pub mod dynamic;
pub mod hguided;
pub mod pipelined;
pub mod static_sched;

pub use dynamic::Dynamic;
pub use hguided::HGuided;
pub use pipelined::Pipelined;
pub use static_sched::Static;

use crate::coordinator::work::Range;

/// Device description given to schedulers at `start`.
#[derive(Debug, Clone)]
pub struct SchedDevice {
    pub name: String,
    /// Relative computing power (HGuided's P_i; Static's default props).
    pub power: f64,
}

/// The Strategy interface.
pub trait Scheduler: Send {
    fn name(&self) -> String;

    /// Reset internal state for a run over `total_granules` granules of
    /// `granule` work-items each, across `devices`.
    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]);

    /// The next package for device `dev` (indexes `devices` from `start`),
    /// in *work-items*. `None` = no more work for this device, ever.
    fn next_package(&mut self, dev: usize) -> Option<Range>;

    /// Packages the engine keeps in flight per device. The default `1`
    /// is the paper's blocking assign-on-completion loop; the
    /// [`Pipelined`] wrapper raises it to enable transfer/compute
    /// overlap in the device workers.
    fn pipeline_depth(&self) -> usize {
        1
    }

    /// Hand back any ranges this scheduler has *reserved* for device
    /// `dev` but not yet delivered — called by the engine's recovery
    /// path when `dev`'s worker dies, so reserved work can be requeued
    /// to survivors. Pool-based schedulers (Dynamic, HGuided) reserve
    /// nothing per device — survivors simply drain the shared pool — so
    /// the default returns nothing. Static overrides it: its pre-split
    /// package for a device that died before pulling it would otherwise
    /// be stranded forever.
    fn reclaim_device(&mut self, _dev: usize) -> Vec<Range> {
        Vec::new()
    }
}

/// Engine-facing configuration enum (Tier-2 API); materialized into a
/// boxed Strategy at run time.
#[derive(Debug, Clone)]
pub enum SchedulerKind {
    /// One package per device, proportional to `props` (or to device
    /// powers when `None`). `reversed` flips the delivery order
    /// (the paper's "Static rev").
    Static { props: Option<Vec<f64>>, reversed: bool },
    /// `packages` equal chunks, first-come-first-served.
    Dynamic { packages: usize },
    /// Geometrically decreasing packages weighted by device power.
    HGuided { k: f64, min_granules: usize },
    /// Any base strategy with per-device package pipelining of `depth`.
    Pipelined { inner: Box<SchedulerKind>, depth: usize },
}

impl SchedulerKind {
    pub fn static_default() -> Self {
        SchedulerKind::Static { props: None, reversed: false }
    }

    pub fn static_with(props: Vec<f64>) -> Self {
        SchedulerKind::Static { props: Some(props), reversed: false }
    }

    pub fn dynamic(packages: usize) -> Self {
        SchedulerKind::Dynamic { packages }
    }

    pub fn hguided() -> Self {
        SchedulerKind::HGuided { k: 2.0, min_granules: 2 }
    }

    /// Wrap this strategy with package pipelining of `depth` (2 =
    /// double-buffered, the sweet spot).
    pub fn pipelined(self, depth: usize) -> Self {
        SchedulerKind::Pipelined { inner: Box::new(self), depth }
    }

    /// The base (unwrapped) strategy — what partitioning validation
    /// inspects regardless of pipelining.
    pub fn base(&self) -> &SchedulerKind {
        match self {
            SchedulerKind::Pipelined { inner, .. } => inner.base(),
            other => other,
        }
    }

    /// The pipeline depth this spec requests (1 = blocking). A
    /// `Pipelined` wrapper always means at least double-buffering,
    /// matching the clamp in [`Pipelined::new`].
    pub fn pipeline_depth(&self) -> usize {
        match self {
            SchedulerKind::Pipelined { inner, depth } => {
                (*depth).max(inner.pipeline_depth()).max(2)
            }
            _ => 1,
        }
    }

    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Static { props, reversed } => {
                Box::new(Static::new(props.clone(), *reversed))
            }
            SchedulerKind::Dynamic { packages } => Box::new(Dynamic::new(*packages)),
            SchedulerKind::HGuided { k, min_granules } => {
                Box::new(HGuided::new(*k, *min_granules))
            }
            SchedulerKind::Pipelined { inner, depth } => {
                Box::new(Pipelined::new(inner.build(), *depth))
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            SchedulerKind::Static { reversed: false, .. } => "Static".into(),
            SchedulerKind::Static { reversed: true, .. } => "Static rev".into(),
            SchedulerKind::Dynamic { packages } => format!("Dynamic {packages}"),
            SchedulerKind::HGuided { .. } => "HGuided".into(),
            SchedulerKind::Pipelined { inner, .. } => format!("{}+pipe", inner.label()),
        }
    }
}

/// Parse a CLI scheduler spec: `static`, `static-rev`, `dynamic:N`,
/// `hguided`, `hguided:k=…,min=…` — each optionally with a `+pipe`
/// suffix (`+pipe` = depth 2, `+pipeN` = depth N) enabling the package
/// pipeline, e.g. `hguided+pipe` or `dynamic:150+pipe3`.
pub fn parse_kind(s: &str) -> Option<SchedulerKind> {
    if let Some(idx) = s.rfind("+pipe") {
        let (base, suffix) = s.split_at(idx);
        let digits = &suffix["+pipe".len()..];
        let depth = if digits.is_empty() { 2 } else { digits.parse().ok()? };
        if depth < 2 {
            return None;
        }
        return parse_kind(base).map(|k| k.pipelined(depth));
    }
    let (head, tail) = s.split_once(':').unwrap_or((s, ""));
    match head {
        "static" => Some(SchedulerKind::Static { props: None, reversed: false }),
        "static-rev" => Some(SchedulerKind::Static { props: None, reversed: true }),
        "dynamic" => {
            let packages = if tail.is_empty() { 50 } else { tail.parse().ok()? };
            Some(SchedulerKind::Dynamic { packages })
        }
        "hguided" => {
            let mut k = 2.0;
            let mut min = 2;
            for part in tail.split(',').filter(|p| !p.is_empty()) {
                let (key, val) = part.split_once('=')?;
                match key {
                    "k" => k = val.parse().ok()?,
                    "min" => min = val.parse().ok()?,
                    _ => return None,
                }
            }
            Some(SchedulerKind::HGuided { k, min_granules: min })
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(SchedulerKind::static_default().label(), "Static");
        assert_eq!(SchedulerKind::dynamic(150).label(), "Dynamic 150");
        assert_eq!(SchedulerKind::hguided().label(), "HGuided");
        assert_eq!(
            SchedulerKind::Static { props: None, reversed: true }.label(),
            "Static rev"
        );
        assert_eq!(SchedulerKind::hguided().pipelined(2).label(), "HGuided+pipe");
    }

    #[test]
    fn parse_specs() {
        assert!(matches!(parse_kind("static"), Some(SchedulerKind::Static { reversed: false, .. })));
        assert!(matches!(parse_kind("static-rev"), Some(SchedulerKind::Static { reversed: true, .. })));
        assert!(matches!(parse_kind("dynamic:150"), Some(SchedulerKind::Dynamic { packages: 150 })));
        assert!(matches!(parse_kind("dynamic"), Some(SchedulerKind::Dynamic { packages: 50 })));
        match parse_kind("hguided:k=3.5,min=4") {
            Some(SchedulerKind::HGuided { k, min_granules }) => {
                assert!((k - 3.5).abs() < 1e-9);
                assert_eq!(min_granules, 4);
            }
            other => panic!("{other:?}"),
        }
        assert!(parse_kind("nope").is_none());
        assert!(parse_kind("hguided:bogus=1").is_none());
    }

    #[test]
    fn parse_pipe_suffix() {
        let k = parse_kind("hguided+pipe").unwrap();
        assert_eq!(k.pipeline_depth(), 2);
        assert!(matches!(k.base(), SchedulerKind::HGuided { .. }));

        let k = parse_kind("dynamic:150+pipe3").unwrap();
        assert_eq!(k.pipeline_depth(), 3);
        assert!(matches!(k.base(), SchedulerKind::Dynamic { packages: 150 }));

        let k = parse_kind("static-rev+pipe").unwrap();
        assert_eq!(k.label(), "Static rev+pipe");

        assert!(parse_kind("+pipe").is_none(), "needs a base spec");
        assert!(parse_kind("hguided+pipe1").is_none(), "depth < 2 is not a pipeline");
        assert!(parse_kind("hguided+pipex").is_none());
    }

    #[test]
    fn base_unwraps_nesting() {
        let k = SchedulerKind::dynamic(7).pipelined(2).pipelined(3);
        assert!(matches!(k.base(), SchedulerKind::Dynamic { packages: 7 }));
        assert_eq!(k.pipeline_depth(), 3);
    }
}
