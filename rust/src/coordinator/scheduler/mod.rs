//! Pluggable scheduling system (paper Figure 4: Strategy pattern),
//! closed into a feedback loop since the adaptive-scheduling refactor.
//!
//! A scheduler hands out granule-ranges to devices on request. The engine
//! calls `start` once with the work size and device descriptions, then
//! `next_package(dev)` every time device `dev` has a free pipeline slot
//! (`None` is terminal for that device) — and, new in the feedback loop,
//! `observe(dev, range, timing)` every time a package *completes*, so
//! adaptive strategies can re-estimate device throughput online instead
//! of trusting the static `DeviceProfile::relative_power` priors. All
//! three of the paper's algorithms are implemented plus the online
//! [`Adaptive`] strategy; new ones plug in through the same trait, and
//! the [`Pipelined`] wrapper composes package pipelining with any of
//! them (spec suffix `+pipe`).
//!
//! The feedback data flow (see docs/ARCHITECTURE.md):
//!
//! ```text
//!   worker ──Done{timing}──▶ master ──observe(dev, range, timing)──▶ scheduler
//!      └──Finished{observations}──▶ master ──record──▶ PerfModelStore
//! ```
//!
//! Completed-package timings drive the run's own scheduler immediately;
//! the per-run observation ledger is folded into the persistent
//! [`PerfModelStore`](crate::platform::perfmodel::PerfModelStore) at
//! session end, so *later* sessions warm-start from what earlier
//! sessions measured ([`SchedDevice::warm_rate`]).

pub mod adaptive;
pub mod dynamic;
pub mod hguided;
pub mod pipelined;
pub mod static_sched;
pub mod steal;

pub use adaptive::Adaptive;
pub use dynamic::Dynamic;
pub use hguided::HGuided;
pub use pipelined::Pipelined;
pub use static_sched::Static;
pub use steal::{price_steal, StealPolicy, Stealing, DEFAULT_STEAL_THRESHOLD};

use std::time::Duration;

use crate::coordinator::work::Range;

/// Device description given to schedulers at `start`.
#[derive(Debug, Clone)]
pub struct SchedDevice {
    pub name: String,
    /// Relative computing power (HGuided's P_i; Static's default props).
    pub power: f64,
    /// Warm-start prior from the performance-model store: the EWMA
    /// granules/sec earlier sessions observed for this kernel on this
    /// device. `None` = cold start from `power` alone.
    pub warm_rate: Option<f64>,
    /// Deadline-pressure hint for the session this run belongs to
    /// (`None` for best-effort sessions — sizing is then untouched, a
    /// bit-for-bit invariant the HGuided regression test pins). Set by
    /// the runtime from the session deadline and the admission-time
    /// makespan prediction; consumed by the feedback schedulers'
    /// deadline-driven tail sizing.
    pub qos: Option<QosHint>,
    /// Power draw while a package occupies this device, in watts (from
    /// the device profile). Plumbed through every scheduler; only the
    /// energy-objective Adaptive acts on it — HGuided and the rest
    /// carry the hint untouched, so their sizing stays bit-for-bit.
    pub busy_watts: f64,
    /// Idle power draw, in watts.
    pub idle_watts: f64,
    /// Warm-start joules/granule prior from the performance-model
    /// store's energy map. `None` = cold start from `busy_watts` and
    /// relative rates alone.
    pub warm_epg: Option<f64>,
}

impl SchedDevice {
    pub fn new(name: impl Into<String>, power: f64) -> Self {
        Self {
            name: name.into(),
            power,
            warm_rate: None,
            qos: None,
            busy_watts: 0.0,
            idle_watts: 0.0,
            warm_epg: None,
        }
    }

    pub fn with_warm_rate(mut self, rate: Option<f64>) -> Self {
        self.warm_rate = rate;
        self
    }

    pub fn with_qos(mut self, qos: Option<QosHint>) -> Self {
        self.qos = qos;
        self
    }

    pub fn with_watts(mut self, busy: f64, idle: f64) -> Self {
        self.busy_watts = busy;
        self.idle_watts = idle;
        self
    }

    pub fn with_warm_epg(mut self, epg: Option<f64>) -> Self {
        self.warm_epg = epg;
        self
    }
}

/// The QoS hint the runtime threads into `SchedDevice` for deadlined
/// sessions: the deadline itself plus the admission-time makespan
/// prediction (0.0 when the store was too cold to price the session —
/// urgency then comes only from in-run observations).
///
/// Feedback schedulers (Adaptive, HGuided) use it to detect a deadline
/// at risk — predicted remaining time exceeding the time left — and
/// respond by *shrinking the tail*: package sizes drop by
/// [`QOS_TIGHTEN`], so devices re-synchronize at finer granularity and
/// the straggler overhang that would blow the deadline shrinks. Without
/// a hint (or while slack is positive) sizing is exactly the non-QoS
/// formula.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QosHint {
    /// Session deadline, in seconds from run start.
    pub deadline_secs: f64,
    /// Admission-time predicted makespan in seconds; 0.0 = unpriced.
    pub predicted_secs: f64,
}

impl QosHint {
    pub fn new(deadline_secs: f64, predicted_secs: f64) -> Self {
        Self { deadline_secs, predicted_secs }
    }

    /// The hint says the run is at risk before anything was observed.
    pub fn pressured_at_start(&self) -> bool {
        self.predicted_secs > 0.0 && self.predicted_secs > self.deadline_secs
    }
}

/// Chunk-divisor multiplier applied by the feedback schedulers while a
/// deadline is at risk: packages shrink to half so the tail converges
/// at finer granularity.
pub const QOS_TIGHTEN: f64 = 2.0;

/// Per-run deadline-risk state shared by the feedback schedulers: the
/// session's [`QosHint`] (if any) plus each device's cumulative
/// observed package span. The busiest device's cumulative span is the
/// scheduler's elapsed-time proxy (it needs no clock — determinism is
/// preserved), and pending-over-rate-sum is its remaining-time
/// estimate; their sum overrunning the deadline is what "at risk"
/// means. All queries are O(1) so the hot-path audit holds.
#[derive(Debug, Default)]
pub struct QosTracker {
    hint: Option<QosHint>,
    busy: Vec<f64>,
    busy_max: f64,
}

impl QosTracker {
    pub fn start(&mut self, devices: &[SchedDevice]) {
        self.hint = devices.iter().find_map(|d| d.qos);
        self.busy.clear();
        self.busy.resize(devices.len(), 0.0);
        self.busy_max = 0.0;
    }

    pub fn observe(&mut self, dev: usize, span: Duration) {
        if self.hint.is_none() || dev >= self.busy.len() {
            return;
        }
        self.busy[dev] += span.as_secs_f64();
        if self.busy[dev] > self.busy_max {
            self.busy_max = self.busy[dev];
        }
    }

    /// Is the deadline at risk with `pending` granules left, given the
    /// model's current aggregate-rate estimate? Always `false` without
    /// a hint (best-effort sessions: sizing must not move). Before any
    /// observation the only absolute-scale signal is the admission
    /// prediction carried in the hint.
    pub fn at_risk(&self, pending: usize, model: &ThroughputModel) -> bool {
        let Some(h) = self.hint else { return false };
        if self.busy_max <= 0.0 {
            return h.pressured_at_start();
        }
        let remaining = pending as f64 / model.rate_sum();
        self.busy_max + remaining > h.deadline_secs
    }
}

/// Timing of one completed package, as fed back to the scheduler (and,
/// at session end, to the performance-model store). `span` is the
/// package's simulated occupancy of the device — compute window plus
/// the stretched hold, including staging in blocking mode — i.e. the
/// duration that determines when the device is free again, which is
/// exactly what load balancing needs to predict.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct PackageTiming {
    /// Simulated device-occupancy span of the package.
    pub span: Duration,
    /// Raw (un-stretched) backend execution time.
    pub raw_exec: Duration,
}

/// One completed package plus its timing — the per-run observation
/// ledger entry workers ship with `Finished`/`Failed` (collected
/// regardless of the `introspect` flag, like [`TransferStats`]).
///
/// [`TransferStats`]: crate::coordinator::introspector::TransferStats
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PackageObservation {
    pub range: Range,
    pub timing: PackageTiming,
}

/// The Strategy interface.
pub trait Scheduler: Send {
    fn name(&self) -> String;

    /// Reset internal state for a run over `total_granules` granules of
    /// `granule` work-items each, across `devices`.
    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]);

    /// The next package for device `dev` (indexes `devices` from `start`),
    /// in *work-items*. `None` = no more work for this device, ever.
    fn next_package(&mut self, dev: usize) -> Option<Range>;

    /// Feedback: device `dev` completed `range` in `timing.span`. Called
    /// by the master loop on every `Done` event, *before* the next
    /// `next_package` for that device, so adaptive strategies size the
    /// following packages from observed throughput. Strategies whose
    /// partitioning is fixed up front (Static's pre-split, Dynamic's
    /// equal chunks) ignore it — the default is a no-op.
    fn observe(&mut self, _dev: usize, _range: Range, _timing: PackageTiming) {}

    /// Packages the engine keeps in flight per device. The default `1`
    /// is the paper's blocking assign-on-completion loop; the
    /// [`Pipelined`] wrapper raises it to enable transfer/compute
    /// overlap in the device workers.
    fn pipeline_depth(&self) -> usize {
        1
    }

    /// Hand back any ranges this scheduler has *reserved* for device
    /// `dev` but not yet delivered — called by the engine's recovery
    /// path when `dev`'s worker dies, so reserved work can be requeued
    /// to survivors. Pool-based schedulers (Dynamic, HGuided, Adaptive)
    /// reserve nothing per device — survivors simply drain the shared
    /// pool — so the default returns nothing. Static overrides it: its
    /// pre-split package for a device that died before pulling it would
    /// otherwise be stranded forever.
    fn reclaim_device(&mut self, _dev: usize) -> Vec<Range> {
        Vec::new()
    }

    /// Notification that the master moved `items` assigned-but-unstarted
    /// work-items from `victim` to `thief` (cooperative stealing,
    /// `+steal`). The moved ranges were already *delivered* by
    /// `next_package` — they are gone from every scheduler pool — and
    /// `observe` will attribute their completion timing to the executing
    /// thief, so pool-based strategies need no ledger correction and the
    /// default is a no-op. Strategies that keep per-device calibration
    /// state may override it: being stolen from is evidence the victim's
    /// estimate was stale ([`Adaptive`] re-probes the victim).
    fn on_steal(&mut self, _victim: usize, _thief: usize, _items: usize) {}
}

/// Online per-device throughput estimator shared by the feedback-driven
/// strategies (HGuided, Adaptive): an EWMA of observed granules/sec per
/// device, with profile-power imputation for devices that have not been
/// observed yet.
///
/// Observed rates are absolute (granules/sec); profile powers are
/// relative (fractions of the fastest device). The model bridges the
/// two scales through the *implied rate per unit power* of the observed
/// devices, so a half-observed device set still yields comparable
/// estimates. Until anything is observed the estimates degrade to the
/// powers themselves — sizing formulas that consume only estimate
/// *ratios* are then bit-identical to their static-profile ancestors
/// (asserted by HGuided's regression test).
///
/// All queries are O(1): the observed/unobserved sums are maintained
/// incrementally by `observe`, never recomputed by scans — this is what
/// keeps `next_package` off the master's `Done` hot path allocation- and
/// scan-free (the PR-2 hot-loop audit, discharged).
#[derive(Debug, Default)]
pub struct ThroughputModel {
    alpha: f64,
    /// Static profile priors (relative power), clamped positive.
    powers: Vec<f64>,
    /// EWMA observed rate (granules/sec); `None` until first observation.
    rates: Vec<Option<f64>>,
    sum_obs_rate: f64,
    sum_obs_power: f64,
    sum_unobs_power: f64,
}

impl ThroughputModel {
    /// `alpha` is the EWMA smoothing factor: the weight of the newest
    /// sample (1.0 = trust only the last package, 0 → frozen; clamped
    /// into (0, 1]).
    pub fn new(alpha: f64) -> Self {
        Self { alpha: alpha.clamp(0.01, 1.0), ..Default::default() }
    }

    /// Reset for a run. Warm-start rates (the store's cross-session
    /// estimates) seed the observed state when present, so the very
    /// first package is already sized from measured throughput.
    pub fn start(&mut self, devices: &[SchedDevice]) {
        self.powers = devices.iter().map(|d| d.power.max(1e-6)).collect();
        self.rates = devices
            .iter()
            .map(|d| d.warm_rate.filter(|r| r.is_finite() && *r > 0.0))
            .collect();
        self.sum_obs_rate = 0.0;
        self.sum_obs_power = 0.0;
        self.sum_unobs_power = 0.0;
        for (i, r) in self.rates.iter().enumerate() {
            match r {
                Some(rate) => {
                    self.sum_obs_rate += rate;
                    self.sum_obs_power += self.powers[i];
                }
                None => self.sum_unobs_power += self.powers[i],
            }
        }
    }

    /// Fold one completed package: `granules` granules over `span`.
    pub fn observe(&mut self, dev: usize, granules: f64, span: Duration) {
        if dev >= self.rates.len() || !granules.is_finite() || granules <= 0.0 {
            return;
        }
        let secs = span.as_secs_f64();
        if secs <= 0.0 {
            return;
        }
        let sample = granules / secs;
        match self.rates[dev] {
            Some(prev) => {
                let next = self.alpha * sample + (1.0 - self.alpha) * prev;
                self.sum_obs_rate += next - prev;
                self.rates[dev] = Some(next);
            }
            None => {
                self.rates[dev] = Some(sample);
                self.sum_obs_rate += sample;
                self.sum_obs_power += self.powers[dev];
                self.sum_unobs_power = (self.sum_unobs_power - self.powers[dev]).max(0.0);
            }
        }
    }

    /// True once `dev` has an estimate grounded in a measurement
    /// (in-run observation or warm-start prior).
    pub fn observed(&self, dev: usize) -> bool {
        self.rates.get(dev).map(|r| r.is_some()).unwrap_or(false)
    }

    /// Granules/sec per unit of profile power implied by the observed
    /// devices (1.0 until anything is observed) — the bridge that puts
    /// observed absolute rates and unobserved relative priors on one
    /// scale.
    fn implied_rate_per_power(&self) -> f64 {
        if self.sum_obs_power > 0.0 {
            (self.sum_obs_rate / self.sum_obs_power).max(1e-9)
        } else {
            1.0
        }
    }

    /// Current throughput estimate for `dev`, comparable across devices.
    pub fn rate(&self, dev: usize) -> f64 {
        match self.rates.get(dev).copied().flatten() {
            Some(r) => r.max(1e-9),
            None => self.powers[dev] * self.implied_rate_per_power(),
        }
    }

    /// Sum of all devices' estimates — O(1), maintained incrementally.
    pub fn rate_sum(&self) -> f64 {
        (self.sum_obs_rate.max(0.0) + self.sum_unobs_power * self.implied_rate_per_power())
            .max(1e-9)
    }

    /// `dev`'s share of the estimated node throughput, in (0, 1].
    pub fn share(&self, dev: usize) -> f64 {
        (self.rate(dev) / self.rate_sum()).clamp(1e-9, 1.0)
    }
}

/// Optimization objective for the [`Adaptive`] scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EnergyObjective {
    /// Minimize makespan — the classic objective; every device that
    /// helps finish sooner participates.
    #[default]
    Time,
    /// Minimize energy-delay product: devices whose marginal joules
    /// outweigh their marginal speedup are excluded from the active
    /// set. The fastest split is often not the greenest one.
    Edp,
}

/// Engine-facing configuration enum (Tier-2 API); materialized into a
/// boxed Strategy at run time.
#[derive(Debug, Clone, PartialEq)]
pub enum SchedulerKind {
    /// One package per device, proportional to `props` (or to device
    /// powers when `None`). `reversed` flips the delivery order
    /// (the paper's "Static rev").
    Static { props: Option<Vec<f64>>, reversed: bool },
    /// `packages` equal chunks, first-come-first-served.
    Dynamic { packages: usize },
    /// Geometrically decreasing packages weighted by device throughput:
    /// observed EWMA granules/sec when `feedback` is on (the default),
    /// the static profile powers when off (the paper's original
    /// formulation, kept for ablation as `hguided:feedback=0`).
    HGuided { k: f64, min_granules: usize, feedback: bool },
    /// Fully feedback-driven: profile/warm-start prior, per-device
    /// probe packages, online EWMA re-estimation (`alpha`), decaying
    /// chunk schedule (`k`) with an absolute minimum-package clamp.
    /// `objective` selects what the active device set optimizes
    /// (`adaptive:obj=edp` minimizes energy-delay product) and
    /// `power_cap` bounds node power in watts (`adaptive:power=W`).
    Adaptive {
        k: f64,
        min_granules: usize,
        alpha: f64,
        objective: EnergyObjective,
        power_cap: Option<f64>,
    },
    /// Any base strategy with per-device package pipelining of `depth`.
    Pipelined { inner: Box<SchedulerKind>, depth: usize },
    /// Any base strategy with cooperative work stealing (spec suffix
    /// `+steal[:threshold|:eager]`, composable with `+pipe`). Forces a
    /// pipeline depth of at least [`steal::MIN_STEAL_PIPELINE`] so
    /// victims hold assigned-but-unstarted backlog to yield.
    Stealing { inner: Box<SchedulerKind>, policy: StealPolicy },
}

impl SchedulerKind {
    pub fn static_default() -> Self {
        SchedulerKind::Static { props: None, reversed: false }
    }

    pub fn static_with(props: Vec<f64>) -> Self {
        SchedulerKind::Static { props: Some(props), reversed: false }
    }

    pub fn dynamic(packages: usize) -> Self {
        SchedulerKind::Dynamic { packages }
    }

    pub fn hguided() -> Self {
        SchedulerKind::HGuided { k: 2.0, min_granules: 2, feedback: true }
    }

    /// The paper's original static-profile HGuided (no throughput
    /// feedback) — the ablation baseline the adaptive acceptance runs
    /// compare against.
    pub fn hguided_static() -> Self {
        SchedulerKind::HGuided { k: 2.0, min_granules: 2, feedback: false }
    }

    pub fn adaptive() -> Self {
        SchedulerKind::Adaptive {
            k: adaptive::DEFAULT_K,
            min_granules: adaptive::DEFAULT_MIN_GRANULES,
            alpha: adaptive::DEFAULT_ALPHA,
            objective: EnergyObjective::Time,
            power_cap: None,
        }
    }

    /// Adaptive with the EDP-minimizing objective (`adaptive:obj=edp`).
    pub fn adaptive_edp() -> Self {
        match Self::adaptive() {
            SchedulerKind::Adaptive { k, min_granules, alpha, .. } => SchedulerKind::Adaptive {
                k,
                min_granules,
                alpha,
                objective: EnergyObjective::Edp,
                power_cap: None,
            },
            _ => unreachable!(),
        }
    }

    /// Adaptive under a node power cap in watts (`adaptive:power=W`).
    pub fn adaptive_power_capped(watts: f64) -> Self {
        match Self::adaptive() {
            SchedulerKind::Adaptive { k, min_granules, alpha, objective, .. } => {
                SchedulerKind::Adaptive {
                    k,
                    min_granules,
                    alpha,
                    objective,
                    power_cap: Some(watts),
                }
            }
            _ => unreachable!(),
        }
    }

    /// Wrap this strategy with package pipelining of `depth` (2 =
    /// double-buffered, the sweet spot; clamped up to 2, matching
    /// [`Pipelined::new`]).
    pub fn pipelined(self, depth: usize) -> Self {
        SchedulerKind::Pipelined { inner: Box::new(self), depth: depth.max(2) }
    }

    /// Wrap this strategy with cooperative work stealing under `policy`
    /// (`StealPolicy::Off` is an identity — no wrapper).
    pub fn stealing(self, policy: StealPolicy) -> Self {
        if policy.is_off() {
            self
        } else {
            SchedulerKind::Stealing { inner: Box::new(self), policy }
        }
    }

    /// The base (unwrapped) strategy — what partitioning validation
    /// inspects regardless of pipelining or stealing.
    pub fn base(&self) -> &SchedulerKind {
        match self {
            SchedulerKind::Pipelined { inner, .. } => inner.base(),
            SchedulerKind::Stealing { inner, .. } => inner.base(),
            other => other,
        }
    }

    /// The steal policy this spec requests (`+steal` suffix), unwrapping
    /// other wrappers; [`StealPolicy::Off`] when absent.
    pub fn steal_policy(&self) -> StealPolicy {
        match self {
            SchedulerKind::Stealing { policy, .. } => *policy,
            SchedulerKind::Pipelined { inner, .. } => inner.steal_policy(),
            _ => StealPolicy::Off,
        }
    }

    /// The node power cap this spec requests in watts, if any
    /// (`adaptive:power=W`), unwrapping pipelining.
    pub fn power_cap(&self) -> Option<f64> {
        match self.base() {
            SchedulerKind::Adaptive { power_cap, .. } => *power_cap,
            _ => None,
        }
    }

    /// The pipeline depth this spec requests (1 = blocking). A
    /// `Pipelined` wrapper always means at least double-buffering,
    /// matching the clamp in [`Pipelined::new`].
    pub fn pipeline_depth(&self) -> usize {
        match self {
            SchedulerKind::Pipelined { inner, depth } => {
                (*depth).max(inner.pipeline_depth()).max(2)
            }
            SchedulerKind::Stealing { inner, .. } => {
                inner.pipeline_depth().max(steal::MIN_STEAL_PIPELINE)
            }
            _ => 1,
        }
    }

    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Static { props, reversed } => {
                Box::new(Static::new(props.clone(), *reversed))
            }
            SchedulerKind::Dynamic { packages } => Box::new(Dynamic::new(*packages)),
            SchedulerKind::HGuided { k, min_granules, feedback } => {
                Box::new(HGuided::with_feedback(*k, *min_granules, *feedback))
            }
            SchedulerKind::Adaptive { k, min_granules, alpha, objective, power_cap } => {
                Box::new(Adaptive::with_objective(
                    *k,
                    *min_granules,
                    *alpha,
                    *objective,
                    *power_cap,
                ))
            }
            SchedulerKind::Pipelined { inner, depth } => {
                Box::new(Pipelined::new(inner.build(), *depth))
            }
            SchedulerKind::Stealing { inner, policy } => {
                Box::new(Stealing::new(inner.build(), *policy))
            }
        }
    }

    pub fn label(&self) -> String {
        match self {
            SchedulerKind::Static { reversed: false, .. } => "Static".into(),
            SchedulerKind::Static { reversed: true, .. } => "Static rev".into(),
            SchedulerKind::Dynamic { packages } => format!("Dynamic {packages}"),
            SchedulerKind::HGuided { feedback: true, .. } => "HGuided".into(),
            SchedulerKind::HGuided { feedback: false, .. } => "HGuided-static".into(),
            SchedulerKind::Adaptive { objective, power_cap, .. } => {
                let mut s = String::from("Adaptive");
                if *objective == EnergyObjective::Edp {
                    s.push_str("-EDP");
                }
                if power_cap.is_some() {
                    s.push_str("-cap");
                }
                s
            }
            SchedulerKind::Pipelined { inner, .. } => format!("{}+pipe", inner.label()),
            SchedulerKind::Stealing { inner, policy } => {
                format!("{}{}", inner.label(), policy.label_suffix())
            }
        }
    }

    /// The canonical CLI spec for this kind — `parse_spec(k.spec())`
    /// round-trips to an equal kind for every expressible configuration
    /// (explicit Static `props` have no spec syntax and format as plain
    /// `static`).
    pub fn spec(&self) -> String {
        match self {
            SchedulerKind::Static { reversed: false, .. } => "static".into(),
            SchedulerKind::Static { reversed: true, .. } => "static-rev".into(),
            SchedulerKind::Dynamic { packages } => format!("dynamic:{packages}"),
            SchedulerKind::HGuided { k, min_granules, feedback } => {
                let mut s = format!("hguided:k={k},min={min_granules}");
                if !*feedback {
                    s.push_str(",feedback=0");
                }
                s
            }
            SchedulerKind::Adaptive { k, min_granules, alpha, objective, power_cap } => {
                let mut s = format!("adaptive:k={k},min={min_granules},alpha={alpha}");
                if *objective == EnergyObjective::Edp {
                    s.push_str(",obj=edp");
                }
                if let Some(w) = power_cap {
                    s.push_str(&format!(",power={w}"));
                }
                s
            }
            SchedulerKind::Pipelined { inner, depth } => {
                format!("{}+pipe{depth}", inner.spec())
            }
            SchedulerKind::Stealing { inner, policy } => {
                format!("{}{}", inner.spec(), policy.spec_suffix())
            }
        }
    }
}

/// Every valid CLI scheduler spec, for error messages.
pub const VALID_SPECS: &str = "static, static-rev, dynamic[:N], \
     hguided[:k=F,min=N,feedback=0|1], \
     adaptive[:k=F,min=N,alpha=F,obj=time|edp,power=W] \
     — each optionally with +pipe[N] (N >= 2) and/or \
     +steal[:threshold|:eager] (threshold >= 1.0) suffixes, e.g. \
     hguided+pipe, dynamic:150+pipe3, adaptive:obj=edp, \
     hguided+pipe3+steal, adaptive+steal:eager";

/// Parse a CLI scheduler spec: `static`, `static-rev`, `dynamic:N`,
/// `hguided[:k=…,min=…,feedback=0|1]`, `adaptive[:k=…,min=…,alpha=…]` —
/// each optionally with a `+pipe` suffix (`+pipe` = depth 2, `+pipeN` =
/// depth N) enabling the package pipeline and/or a `+steal` suffix
/// (`+steal` = tail-only at the default threshold, `+steal:F` = custom
/// threshold F >= 1.0, `+steal:eager` = steal on any predicted win)
/// enabling cooperative work stealing, e.g. `hguided+pipe`,
/// `dynamic:150+pipe3`, `hguided+pipe3+steal` or `adaptive+steal:eager`.
/// Unknown names, knobs or malformed values are rejected with an error
/// naming the valid specs — never a silent fallback.
pub fn parse_spec(s: &str) -> Result<SchedulerKind, String> {
    // Wrapper suffixes compose in spelling order: strip whichever of
    // `+pipe`/`+steal` occurs *last* and recurse on the prefix, so
    // `hguided+pipe3+steal` never misreads `3+steal` as a pipe depth.
    let pipe_idx = s.rfind("+pipe");
    let steal_idx = s.rfind("+steal");
    if let Some(idx) = steal_idx.filter(|si| pipe_idx.map_or(true, |pi| *si > pi)) {
        let (base, suffix) = s.split_at(idx);
        let arg = &suffix["+steal".len()..];
        if base.is_empty() {
            return Err(format!("'+steal' needs a base spec; valid specs: {VALID_SPECS}"));
        }
        let policy = match arg {
            "" => StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD },
            ":eager" => StealPolicy::Eager,
            _ => {
                let val = arg.strip_prefix(':').unwrap_or(arg);
                let threshold: f64 = val
                    .parse()
                    .ok()
                    .filter(|t: &f64| t.is_finite() && *t >= 1.0)
                    .ok_or_else(|| {
                        format!(
                            "bad steal policy '{val}' in '{s}' (want +steal, \
                             +steal:eager or +steal:F with F >= 1.0)"
                        )
                    })?;
                StealPolicy::TailOnly { threshold }
            }
        };
        return parse_spec(base).map(|k| k.stealing(policy));
    }
    if let Some(idx) = pipe_idx {
        let (base, suffix) = s.split_at(idx);
        let digits = &suffix["+pipe".len()..];
        if base.is_empty() {
            return Err(format!("'+pipe' needs a base spec; valid specs: {VALID_SPECS}"));
        }
        let depth: usize = if digits.is_empty() {
            2
        } else {
            digits
                .parse()
                .map_err(|_| format!("bad pipeline depth '{digits}' in '{s}' (want +pipe or +pipeN, N >= 2)"))?
        };
        if depth < 2 {
            return Err(format!(
                "pipeline depth {depth} in '{s}' is not a pipeline (need N >= 2; depth 1 is the blocking loop — drop the suffix)"
            ));
        }
        return parse_spec(base).map(|k| k.pipelined(depth));
    }
    let (head, tail) = s.split_once(':').unwrap_or((s, ""));
    let parse_f64 = |key: &str, val: &str| -> Result<f64, String> {
        val.parse::<f64>()
            .ok()
            .filter(|v| v.is_finite() && *v > 0.0)
            .ok_or_else(|| format!("bad value '{val}' for '{key}' in '{s}' (want a positive number)"))
    };
    let parse_usize = |key: &str, val: &str| -> Result<usize, String> {
        val.parse::<usize>()
            .map_err(|_| format!("bad value '{val}' for '{key}' in '{s}' (want a non-negative integer)"))
    };
    match head {
        "static" => Ok(SchedulerKind::Static { props: None, reversed: false }),
        "static-rev" => Ok(SchedulerKind::Static { props: None, reversed: true }),
        "dynamic" => {
            let packages = if tail.is_empty() {
                50
            } else {
                parse_usize("dynamic", tail)?
            };
            Ok(SchedulerKind::Dynamic { packages })
        }
        "hguided" => {
            let mut k = 2.0;
            let mut min = 2;
            let mut feedback = true;
            for part in tail.split(',').filter(|p| !p.is_empty()) {
                let (key, val) = part
                    .split_once('=')
                    .ok_or_else(|| format!("bad knob '{part}' in '{s}' (want key=value)"))?;
                match key {
                    "k" => k = parse_f64("k", val)?,
                    "min" => min = parse_usize("min", val)?,
                    "feedback" => {
                        feedback = match val {
                            "1" => true,
                            "0" => false,
                            other => {
                                return Err(format!(
                                    "bad value '{other}' for 'feedback' in '{s}' (want 0 or 1)"
                                ))
                            }
                        }
                    }
                    other => {
                        return Err(format!(
                            "unknown hguided knob '{other}' in '{s}' (valid: k, min, feedback)"
                        ))
                    }
                }
            }
            Ok(SchedulerKind::HGuided { k, min_granules: min, feedback })
        }
        "adaptive" => {
            let mut k = adaptive::DEFAULT_K;
            let mut min = adaptive::DEFAULT_MIN_GRANULES;
            let mut alpha = adaptive::DEFAULT_ALPHA;
            let mut objective = EnergyObjective::Time;
            let mut power_cap = None;
            for part in tail.split(',').filter(|p| !p.is_empty()) {
                let (key, val) = part
                    .split_once('=')
                    .ok_or_else(|| format!("bad knob '{part}' in '{s}' (want key=value)"))?;
                match key {
                    "k" => k = parse_f64("k", val)?,
                    "min" => min = parse_usize("min", val)?,
                    "alpha" => {
                        alpha = parse_f64("alpha", val)?;
                        if alpha > 1.0 {
                            return Err(format!(
                                "bad value '{val}' for 'alpha' in '{s}' (want a weight in (0, 1])"
                            ));
                        }
                    }
                    "obj" => {
                        objective = match val {
                            "time" => EnergyObjective::Time,
                            "edp" => EnergyObjective::Edp,
                            other => {
                                return Err(format!(
                                    "bad value '{other}' for 'obj' in '{s}' (want time or edp)"
                                ))
                            }
                        }
                    }
                    "power" => power_cap = Some(parse_f64("power", val)?),
                    other => {
                        return Err(format!(
                            "unknown adaptive knob '{other}' in '{s}' (valid: k, min, alpha, obj, power)"
                        ))
                    }
                }
            }
            Ok(SchedulerKind::Adaptive { k, min_granules: min, alpha, objective, power_cap })
        }
        other => Err(format!("unknown scheduler '{other}'; valid specs: {VALID_SPECS}")),
    }
}

/// `Option` shim over [`parse_spec`] for callers that only care whether
/// the spec is valid (the error text is what the CLI surfaces).
pub fn parse_kind(s: &str) -> Option<SchedulerKind> {
    parse_spec(s).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(SchedulerKind::static_default().label(), "Static");
        assert_eq!(SchedulerKind::dynamic(150).label(), "Dynamic 150");
        assert_eq!(SchedulerKind::hguided().label(), "HGuided");
        assert_eq!(SchedulerKind::hguided_static().label(), "HGuided-static");
        assert_eq!(SchedulerKind::adaptive().label(), "Adaptive");
        assert_eq!(SchedulerKind::adaptive_edp().label(), "Adaptive-EDP");
        assert_eq!(SchedulerKind::adaptive_power_capped(400.0).label(), "Adaptive-cap");
        assert_eq!(
            SchedulerKind::Static { props: None, reversed: true }.label(),
            "Static rev"
        );
        assert_eq!(SchedulerKind::hguided().pipelined(2).label(), "HGuided+pipe");
        assert_eq!(SchedulerKind::adaptive().pipelined(2).label(), "Adaptive+pipe");
        assert_eq!(
            SchedulerKind::hguided()
                .pipelined(3)
                .stealing(StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD })
                .label(),
            "HGuided+pipe+steal"
        );
        assert_eq!(
            SchedulerKind::adaptive().stealing(StealPolicy::Eager).label(),
            "Adaptive+steal-eager"
        );
        assert_eq!(
            SchedulerKind::adaptive().stealing(StealPolicy::Off).label(),
            "Adaptive",
            "Off policy wraps nothing"
        );
    }

    #[test]
    fn parse_specs() {
        assert!(matches!(parse_kind("static"), Some(SchedulerKind::Static { reversed: false, .. })));
        assert!(matches!(parse_kind("static-rev"), Some(SchedulerKind::Static { reversed: true, .. })));
        assert!(matches!(parse_kind("dynamic:150"), Some(SchedulerKind::Dynamic { packages: 150 })));
        assert!(matches!(parse_kind("dynamic"), Some(SchedulerKind::Dynamic { packages: 50 })));
        match parse_kind("hguided:k=3.5,min=4") {
            Some(SchedulerKind::HGuided { k, min_granules, feedback }) => {
                assert!((k - 3.5).abs() < 1e-9);
                assert_eq!(min_granules, 4);
                assert!(feedback, "feedback defaults on");
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            parse_kind("hguided:feedback=0"),
            Some(SchedulerKind::HGuided { feedback: false, .. })
        ));
        match parse_kind("adaptive:k=3,min=4,alpha=0.25") {
            Some(SchedulerKind::Adaptive { k, min_granules, alpha, objective, power_cap }) => {
                assert!((k - 3.0).abs() < 1e-9);
                assert_eq!(min_granules, 4);
                assert!((alpha - 0.25).abs() < 1e-9);
                assert_eq!(objective, EnergyObjective::Time, "objective defaults to time");
                assert_eq!(power_cap, None, "uncapped by default");
            }
            other => panic!("{other:?}"),
        }
        match parse_kind("adaptive:obj=edp") {
            Some(SchedulerKind::Adaptive { objective, power_cap, .. }) => {
                assert_eq!(objective, EnergyObjective::Edp);
                assert_eq!(power_cap, None);
            }
            other => panic!("{other:?}"),
        }
        match parse_kind("adaptive:power=400") {
            Some(SchedulerKind::Adaptive { objective, power_cap, .. }) => {
                assert_eq!(objective, EnergyObjective::Time);
                assert_eq!(power_cap, Some(400.0));
            }
            other => panic!("{other:?}"),
        }
        assert!(parse_kind("nope").is_none());
        assert!(parse_kind("hguided:bogus=1").is_none());
        assert!(parse_kind("adaptive:alpha=2").is_none(), "alpha > 1 rejected");
        assert!(parse_kind("adaptive:alpha=0").is_none(), "alpha 0 rejected");
        assert!(parse_kind("adaptive:obj=joules").is_none(), "unknown objective rejected");
        assert!(parse_kind("adaptive:power=0").is_none(), "zero cap rejected");
        assert!(parse_kind("adaptive:power=nan").is_none(), "NaN cap rejected");
    }

    #[test]
    fn parse_errors_name_the_valid_specs() {
        let err = parse_spec("guided").unwrap_err();
        assert!(err.contains("unknown scheduler 'guided'"), "{err}");
        assert!(err.contains("adaptive"), "lists valid specs: {err}");
        let err = parse_spec("hguided:q=1").unwrap_err();
        assert!(err.contains("unknown hguided knob 'q'"), "{err}");
        let err = parse_spec("adaptive:k=-1").unwrap_err();
        assert!(err.contains("bad value '-1'"), "{err}");
        let err = parse_spec("dynamic:x").unwrap_err();
        assert!(err.contains("bad value 'x'"), "{err}");
        let err = parse_spec("+pipe").unwrap_err();
        assert!(err.contains("needs a base spec"), "{err}");
        let err = parse_spec("hguided+pipe1").unwrap_err();
        assert!(err.contains("depth 1"), "{err}");
        let err = parse_spec("hguided+pipex").unwrap_err();
        assert!(err.contains("bad pipeline depth"), "{err}");
    }

    #[test]
    fn parse_pipe_suffix() {
        let k = parse_kind("hguided+pipe").unwrap();
        assert_eq!(k.pipeline_depth(), 2);
        assert!(matches!(k.base(), SchedulerKind::HGuided { .. }));

        let k = parse_kind("dynamic:150+pipe3").unwrap();
        assert_eq!(k.pipeline_depth(), 3);
        assert!(matches!(k.base(), SchedulerKind::Dynamic { packages: 150 }));

        let k = parse_kind("static-rev+pipe").unwrap();
        assert_eq!(k.label(), "Static rev+pipe");

        let k = parse_kind("adaptive+pipe").unwrap();
        assert_eq!(k.pipeline_depth(), 2);
        assert!(matches!(k.base(), SchedulerKind::Adaptive { .. }));

        assert!(parse_kind("+pipe").is_none(), "needs a base spec");
        assert!(parse_kind("hguided+pipe1").is_none(), "depth < 2 is not a pipeline");
        assert!(parse_kind("hguided+pipex").is_none());
    }

    #[test]
    fn parse_steal_suffix() {
        let k = parse_kind("hguided+steal").unwrap();
        assert_eq!(
            k.steal_policy(),
            StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD }
        );
        assert!(matches!(k.base(), SchedulerKind::HGuided { .. }));
        assert_eq!(
            k.pipeline_depth(),
            steal::MIN_STEAL_PIPELINE,
            "bare +steal forces a stealable pipeline"
        );

        let k = parse_kind("adaptive+steal:eager").unwrap();
        assert_eq!(k.steal_policy(), StealPolicy::Eager);
        assert!(matches!(k.base(), SchedulerKind::Adaptive { .. }));

        let k = parse_kind("dynamic:150+steal:1.5").unwrap();
        assert_eq!(k.steal_policy(), StealPolicy::TailOnly { threshold: 1.5 });
        assert!(matches!(k.base(), SchedulerKind::Dynamic { packages: 150 }));

        // Composition with +pipe in either spelling order; the pipe
        // depth must never swallow the steal suffix as digits.
        let k = parse_kind("hguided+pipe3+steal").unwrap();
        assert_eq!(k.pipeline_depth(), 3);
        assert!(!k.steal_policy().is_off());
        assert!(matches!(k.base(), SchedulerKind::HGuided { .. }));
        let k = parse_kind("hguided+steal+pipe4").unwrap();
        assert_eq!(k.pipeline_depth(), 4);
        assert!(!k.steal_policy().is_off());

        // A +pipe under +steal keeps its explicit depth when >= the
        // stealable minimum; a too-shallow pipe is raised to it.
        let k = parse_kind("hguided+pipe+steal").unwrap();
        assert_eq!(k.pipeline_depth(), steal::MIN_STEAL_PIPELINE);

        assert!(parse_kind("+steal").is_none(), "needs a base spec");
        assert!(parse_kind("hguided+steal:0.5").is_none(), "threshold < 1.0 rejected");
        assert!(parse_kind("hguided+steal:nan").is_none(), "NaN threshold rejected");
        assert!(parse_kind("hguided+steal:always").is_none(), "unknown word rejected");
        assert!(parse_kind("hguided+steal:").is_none(), "dangling colon rejected");
        let err = parse_spec("hguided+steal:always").unwrap_err();
        assert!(err.contains("bad steal policy 'always'"), "{err}");
        let err = parse_spec("+steal").unwrap_err();
        assert!(err.contains("needs a base spec"), "{err}");
    }

    /// Every expressible spec must round-trip `parse_spec(k.spec()) == k`
    /// — the CLI satellite's parse/format contract.
    #[test]
    fn specs_round_trip() {
        let kinds = vec![
            SchedulerKind::static_default(),
            SchedulerKind::Static { props: None, reversed: true },
            SchedulerKind::dynamic(50),
            SchedulerKind::dynamic(150),
            SchedulerKind::hguided(),
            SchedulerKind::hguided_static(),
            SchedulerKind::HGuided { k: 3.5, min_granules: 4, feedback: true },
            SchedulerKind::adaptive(),
            SchedulerKind::Adaptive {
                k: 1.5,
                min_granules: 8,
                alpha: 0.25,
                objective: EnergyObjective::Time,
                power_cap: None,
            },
            SchedulerKind::adaptive_edp(),
            SchedulerKind::adaptive_power_capped(400.0),
            SchedulerKind::Adaptive {
                k: 2.5,
                min_granules: 2,
                alpha: 0.5,
                objective: EnergyObjective::Edp,
                power_cap: Some(250.0),
            },
            SchedulerKind::static_default().pipelined(2),
            SchedulerKind::dynamic(150).pipelined(3),
            SchedulerKind::hguided().pipelined(2),
            SchedulerKind::hguided_static().pipelined(4),
            SchedulerKind::adaptive().pipelined(2),
            SchedulerKind::adaptive().pipelined(3),
            SchedulerKind::hguided()
                .stealing(StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD }),
            SchedulerKind::hguided()
                .pipelined(3)
                .stealing(StealPolicy::TailOnly { threshold: 1.5 }),
            SchedulerKind::adaptive().stealing(StealPolicy::Eager),
            SchedulerKind::adaptive()
                .stealing(StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD })
                .pipelined(4),
            SchedulerKind::dynamic(64).pipelined(3).stealing(StealPolicy::Eager),
        ];
        for k in kinds {
            let spec = k.spec();
            let parsed = parse_spec(&spec)
                .unwrap_or_else(|e| panic!("spec '{spec}' of {k:?} failed to parse: {e}"));
            assert_eq!(parsed, k, "round trip through '{spec}'");
        }
    }

    #[test]
    fn base_unwraps_nesting() {
        let k = SchedulerKind::dynamic(7).pipelined(2).pipelined(3);
        assert!(matches!(k.base(), SchedulerKind::Dynamic { packages: 7 }));
        assert_eq!(k.pipeline_depth(), 3);
    }

    // ---- ThroughputModel ------------------------------------------------

    fn devs(powers: &[f64]) -> Vec<SchedDevice> {
        powers
            .iter()
            .enumerate()
            .map(|(i, p)| SchedDevice::new(format!("d{i}"), *p))
            .collect()
    }

    #[test]
    fn model_cold_start_degrades_to_powers() {
        let mut m = ThroughputModel::new(0.5);
        m.start(&devs(&[0.3, 1.0, 0.42]));
        assert!((m.rate(0) - 0.3).abs() < 1e-12);
        assert!((m.rate(1) - 1.0).abs() < 1e-12);
        assert!((m.rate_sum() - 1.72).abs() < 1e-12);
        assert!(!m.observed(0));
        assert!((m.share(1) - 1.0 / 1.72).abs() < 1e-12);
    }

    #[test]
    fn model_observation_replaces_prior_then_ewma() {
        let mut m = ThroughputModel::new(0.5);
        m.start(&devs(&[1.0, 1.0]));
        m.observe(0, 100.0, Duration::from_secs(1));
        assert!(m.observed(0));
        assert!((m.rate(0) - 100.0).abs() < 1e-9, "first sample replaces the prior");
        m.observe(0, 50.0, Duration::from_secs(1));
        assert!((m.rate(0) - 75.0).abs() < 1e-9, "EWMA with alpha 0.5");
    }

    #[test]
    fn model_imputes_unobserved_devices_from_observed_scale() {
        let mut m = ThroughputModel::new(0.5);
        m.start(&devs(&[0.5, 1.0]));
        // Device 1 (power 1.0) observed at 200 granules/sec => implied
        // 200/power-unit => device 0 (power 0.5) imputed at 100.
        m.observe(1, 200.0, Duration::from_secs(1));
        assert!((m.rate(0) - 100.0).abs() < 1e-9, "got {}", m.rate(0));
        assert!((m.rate_sum() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn model_warm_start_counts_as_observed() {
        let mut m = ThroughputModel::new(0.5);
        let mut d = devs(&[0.5, 1.0]);
        d[0].warm_rate = Some(80.0);
        m.start(&d);
        assert!(m.observed(0));
        assert!(!m.observed(1));
        assert!((m.rate(0) - 80.0).abs() < 1e-9);
        // Implied scale from the warm device: 80 / 0.5 = 160 per power.
        assert!((m.rate(1) - 160.0).abs() < 1e-9);
    }

    #[test]
    fn model_ignores_degenerate_observations() {
        let mut m = ThroughputModel::new(0.5);
        m.start(&devs(&[1.0]));
        m.observe(0, 0.0, Duration::from_secs(1));
        m.observe(0, 10.0, Duration::ZERO);
        m.observe(7, 10.0, Duration::from_secs(1));
        assert!(!m.observed(0), "degenerate samples are dropped");
        assert!((m.rate(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn model_tracks_degradation() {
        let mut m = ThroughputModel::new(0.5);
        m.start(&devs(&[1.0, 1.0]));
        m.observe(0, 100.0, Duration::from_secs(1));
        m.observe(1, 100.0, Duration::from_secs(1));
        // Device 1 degrades 4x; after a few packages its estimate drops
        // toward 25 and its share toward 1/5.
        for _ in 0..6 {
            m.observe(1, 25.0, Duration::from_secs(1));
        }
        assert!(m.rate(1) < 30.0, "degraded estimate converged: {}", m.rate(1));
        assert!(m.share(1) < 0.25, "share shifted away: {}", m.share(1));
    }
}
