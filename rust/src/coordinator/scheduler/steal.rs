//! Cooperative work stealing (spec suffix `+steal[:threshold|:eager]`).
//!
//! Every strategy in this crate is assign-once: a range handed to a
//! device only moves if that device *dies* (the fault-recovery requeue).
//! Under heavy-tailed package costs (the `collatz` hotspot band) the
//! last package on the slowest device dictates the makespan while the
//! fast devices idle. The stealing layer makes the fault path's
//! involuntary migration voluntary: when a device goes dry, the master
//! revokes assigned-but-unstarted ranges from the most backlogged
//! victim and re-dispatches them to the thief through the normal
//! `AssignBatch` path (flagged `stolen` in the traces).
//!
//! This module owns the three policy-level pieces, all deliberately
//! free of engine state so the master loop and the `run --steal`
//! virtual-clock bench price steals with the *same* code:
//!
//! * [`StealPolicy`] — off / tail-only (default threshold
//!   [`DEFAULT_STEAL_THRESHOLD`]) / eager, parsed from the `+steal`
//!   spec suffix in [`parse_spec`](super::parse_spec).
//! * [`price_steal`] — the pricing rule: never steal work the victim
//!   would finish before the thief's transfer-and-restart cost, sized
//!   so victim and thief finish their shares together.
//! * [`Stealing`] — the [`Scheduler`] wrapper (mirroring
//!   [`Pipelined`](super::Pipelined)) that labels the run and forces a
//!   pipeline deep enough that victims actually hold stealable backlog.

use super::{PackageTiming, SchedDevice, Scheduler};
use crate::coordinator::work::Range;

/// Default tail-only profitability threshold: a steal must be priced to
/// cut the victim's remaining time by >= 20% before the master issues
/// it. High enough that regular (uniform-cost) kernels price every
/// steal out near the tail, low enough that a hotspot band triggers.
pub const DEFAULT_STEAL_THRESHOLD: f64 = 1.2;

/// Minimum pipeline depth the [`Stealing`] wrapper forces. With the
/// default double-buffered pipeline a worker holds only its in-flight
/// package plus one staged prefetch — both excluded from yielding (the
/// H2D transfer is already paid) — so nothing would ever be stealable.
/// Depth 3 gives every victim at least one assigned-but-unstarted
/// queue slot.
pub const MIN_STEAL_PIPELINE: usize = 3;

/// When (and how aggressively) the master steals for a dry device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StealPolicy {
    /// Never steal — the assign-once baseline.
    Off,
    /// Steal only when priced clearly profitable: the victim's predicted
    /// remaining time must exceed `threshold` times the post-steal
    /// predicted finish (threshold >= 1.0; the `+steal` default is
    /// [`DEFAULT_STEAL_THRESHOLD`]).
    TailOnly { threshold: f64 },
    /// Steal on any predicted improvement (threshold 1.0) — the
    /// ablation bound; regular kernels measure its overhead.
    Eager,
}

impl StealPolicy {
    pub fn is_off(&self) -> bool {
        matches!(self, StealPolicy::Off)
    }

    /// The profitability threshold this policy prices with.
    pub fn threshold(&self) -> f64 {
        match self {
            StealPolicy::Off => f64::INFINITY,
            StealPolicy::TailOnly { threshold } => *threshold,
            StealPolicy::Eager => 1.0,
        }
    }

    /// Label suffix (`RunReport::scheduler` spelling).
    pub fn label_suffix(&self) -> &'static str {
        match self {
            StealPolicy::Off => "",
            StealPolicy::TailOnly { .. } => "+steal",
            StealPolicy::Eager => "+steal-eager",
        }
    }

    /// Canonical spec suffix (round-trips through `parse_spec`).
    pub fn spec_suffix(&self) -> String {
        match self {
            StealPolicy::Off => String::new(),
            StealPolicy::TailOnly { threshold } if *threshold == DEFAULT_STEAL_THRESHOLD => {
                "+steal".into()
            }
            StealPolicy::TailOnly { threshold } => format!("+steal:{threshold}"),
            StealPolicy::Eager => "+steal:eager".into(),
        }
    }
}

/// Price one candidate steal: should the master move work from a victim
/// with `backlog_items` assigned-but-unstarted work-items (out of
/// `total_items` still outstanding on it, in-flight included) to a dry
/// thief, given both devices' modeled rates in granules/sec?
///
/// Returns the number of work-items to request (granule-aligned, >= one
/// granule) or `None` when the steal is priced out. The rule, in
/// granule-time units (documented in ARCHITECTURE.md):
///
/// * share the yieldable backlog so both finish together:
///   `S = backlog × r_t / (r_t + r_v)`, floored to a granule multiple;
/// * charge the thief a restart surcharge of one granule's time
///   (`C = 1/r_t`) — the H2D staging and ramp the victim has already
///   paid for this work;
/// * steal iff `T_old > threshold × T_new` where `T_old = W_v / r_v`
///   and `T_new = max((W_v − S)/r_v, S/r_t + C)`.
///
/// A steal is therefore *never* issued for work the victim would finish
/// before the thief could restart it — on uniform-cost kernels with a
/// healthy balance the tail shares shrink below profitability and the
/// policy stays quiet.
pub fn price_steal(
    policy: StealPolicy,
    granule: usize,
    backlog_items: usize,
    total_items: usize,
    victim_rate: f64,
    thief_rate: f64,
) -> Option<usize> {
    if policy.is_off() || granule == 0 || backlog_items < granule {
        return None;
    }
    let threshold = policy.threshold();
    if !threshold.is_finite() || threshold < 1.0 {
        return None;
    }
    let rv = if victim_rate.is_finite() { victim_rate.max(1e-9) } else { 1e-9 };
    let rt = if thief_rate.is_finite() { thief_rate.max(1e-9) } else { 1e-9 };
    let g = granule as f64;
    // Finish-together share of the yieldable backlog, granule-floored
    // (but at least one granule — a sub-granule ideal share still beats
    // idling when the ratio test below passes).
    let ideal = backlog_items as f64 * rt / (rt + rv);
    let take_granules = ((ideal / g) as usize).max(1).min(backlog_items / granule);
    let sg = take_granules as f64;
    let wg = total_items as f64 / g;
    let t_old = wg / rv;
    let t_new = ((wg - sg).max(0.0) / rv).max(sg / rt + 1.0 / rt);
    if t_old > threshold * t_new {
        Some(take_granules * granule)
    } else {
        None
    }
}

/// Scheduler wrapper enabling cooperative stealing over any strategy —
/// the runtime object behind the `+steal` suffix. The steal machinery
/// itself lives in the master loop (it needs the per-device pending
/// ledgers and worker channels); this wrapper carries the policy into
/// the run label and forces [`MIN_STEAL_PIPELINE`] so victims hold a
/// stealable backlog, forwarding everything else to the wrapped
/// strategy.
pub struct Stealing {
    inner: Box<dyn Scheduler>,
    policy: StealPolicy,
}

impl Stealing {
    pub fn new(inner: Box<dyn Scheduler>, policy: StealPolicy) -> Self {
        Self { inner, policy }
    }
}

impl Scheduler for Stealing {
    fn name(&self) -> String {
        format!("{}{}", self.inner.name(), self.policy.label_suffix())
    }

    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]) {
        self.inner.start(total_granules, granule, devices);
    }

    fn next_package(&mut self, dev: usize) -> Option<Range> {
        self.inner.next_package(dev)
    }

    fn observe(&mut self, dev: usize, range: Range, timing: PackageTiming) {
        self.inner.observe(dev, range, timing);
    }

    fn pipeline_depth(&self) -> usize {
        self.inner.pipeline_depth().max(MIN_STEAL_PIPELINE)
    }

    fn reclaim_device(&mut self, dev: usize) -> Vec<Range> {
        self.inner.reclaim_device(dev)
    }

    fn on_steal(&mut self, victim: usize, thief: usize, items: usize) {
        self.inner.on_steal(victim, thief, items);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::Dynamic;

    #[test]
    fn off_never_prices_a_steal() {
        assert_eq!(price_steal(StealPolicy::Off, 64, 640, 1280, 1.0, 100.0), None);
    }

    #[test]
    fn sub_granule_backlog_is_not_stealable() {
        let p = StealPolicy::Eager;
        assert_eq!(price_steal(p, 64, 0, 1280, 1.0, 100.0), None);
        assert_eq!(price_steal(p, 64, 63, 1280, 1.0, 100.0), None);
        assert_eq!(price_steal(p, 0, 640, 1280, 1.0, 100.0), None, "zero granule");
    }

    #[test]
    fn deep_backlog_on_a_slow_victim_is_stolen() {
        // Victim: 10 granules queued + in-flight at 1 granule/sec (10s
        // left). Thief at 10 granules/sec. Finish-together share ~9
        // granules; post-steal finish ~1s — well past any threshold.
        let take = price_steal(
            StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD },
            64,
            640,
            704,
            1.0,
            10.0,
        )
        .expect("profitable steal");
        assert_eq!(take % 64, 0, "granule-aligned");
        assert!(take >= 64 && take <= 640, "within the backlog: {take}");
        assert!(take >= 512, "most of the backlog moves to the 10x thief: {take}");
    }

    #[test]
    fn near_finished_victim_is_priced_out() {
        // One granule queued on an equal-rate victim: the thief's
        // restart surcharge makes moving it pointless.
        assert_eq!(
            price_steal(StealPolicy::TailOnly { threshold: 1.2 }, 64, 64, 128, 1.0, 1.0),
            None
        );
    }

    #[test]
    fn eager_threshold_is_tighter_than_tail_only() {
        // A marginal imbalance (~25% win) that tail-only (1.2) takes
        // and a stricter custom threshold refuses.
        let args = (64usize, 320usize, 960usize, 1.0f64, 1.0f64);
        let eager = price_steal(StealPolicy::Eager, args.0, args.1, args.2, args.3, args.4);
        let strict = price_steal(
            StealPolicy::TailOnly { threshold: 2.0 },
            args.0,
            args.1,
            args.2,
            args.3,
            args.4,
        );
        assert!(eager.is_some(), "eager takes any predicted improvement");
        assert_eq!(strict, None, "a 2.0 threshold prices the same steal out");
    }

    #[test]
    fn poisoned_rates_do_not_panic_or_steal_everything() {
        // NaN rates (a poisoned model) degrade to the epsilon clamp and
        // still produce a bounded, aligned answer — never a panic.
        let take = price_steal(StealPolicy::Eager, 64, 640, 1280, f64::NAN, f64::NAN);
        if let Some(t) = take {
            assert_eq!(t % 64, 0);
            assert!(t <= 640);
        }
        assert_eq!(
            price_steal(
                StealPolicy::TailOnly { threshold: f64::NAN },
                64,
                640,
                1280,
                1.0,
                10.0
            ),
            None,
            "a NaN threshold refuses rather than panics"
        );
    }

    #[test]
    fn policy_suffixes_round_trip_shapes() {
        assert_eq!(StealPolicy::Off.spec_suffix(), "");
        assert_eq!(
            StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD }.spec_suffix(),
            "+steal"
        );
        assert_eq!(StealPolicy::TailOnly { threshold: 1.5 }.spec_suffix(), "+steal:1.5");
        assert_eq!(StealPolicy::Eager.spec_suffix(), "+steal:eager");
        assert_eq!(StealPolicy::Eager.label_suffix(), "+steal-eager");
        assert!(StealPolicy::Off.is_off());
        assert_eq!(StealPolicy::Eager.threshold(), 1.0);
    }

    #[test]
    fn wrapper_forces_a_stealable_pipeline() {
        let s = Stealing::new(
            Box::new(Dynamic::new(8)),
            StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD },
        );
        assert_eq!(s.pipeline_depth(), MIN_STEAL_PIPELINE);
        assert_eq!(s.name(), "Dynamic 8+steal");
    }
}
