//! HGuided scheduler (paper §5.3) — the best performer in the paper's
//! evaluation: guided self-scheduling weighted by heterogeneous device
//! throughputs. Large packages early (few synchronization points),
//! shrinking toward the end (all devices finish together), sized per
//! device:
//!
//!   packet_size_i = floor( G_r * R_i / (k * n * sum_j R_j) )
//!
//! clamped below by a per-device minimum that scales with profile power
//! ("giving bigger package sizes in the most powerful devices").
//!
//! Since the adaptive-scheduling refactor, `R_i` is the device's
//! *observed* throughput — an EWMA of granules/sec fed back through
//! [`Scheduler::observe`] on every completed package (seeded from the
//! performance-model store's warm rates when available) — instead of
//! the static `DeviceProfile::relative_power` prior. With no
//! observations the [`ThroughputModel`] degrades to the powers exactly,
//! so sizing is bit-identical to the paper's original static-profile
//! formula (the regression test below asserts this against an
//! independent reimplementation of the old code). `feedback = false`
//! (spec `hguided:feedback=0`) pins that static behavior for ablations
//! and for comparing against [`Adaptive`](super::Adaptive).
//!
//! Float-ordering audit (PR-10, discharged): no comparison in this file
//! unwraps a `partial_cmp`. Poisoned priors are clamped at ingress —
//! powers through `.max(1e-6)` (NaN-rejecting: `f64::max` returns the
//! finite operand), warm rates through the model's `is_finite` filter —
//! so the sizing formula's operands are always finite and the NaN
//! regression test below pins the no-panic, full-cover behavior.
//!
//! Hot-loop note (PR-2 audit, discharged): `next_package` runs on the
//! master's `Done` path for every package, so it is O(1) and
//! allocation-free — pure arithmetic over per-run state. The
//! observed-throughput sums are maintained *incrementally* by
//! `observe` (`ThroughputModel`), never recomputed by a scan of the
//! remaining pool or the device list. Keep it that way: no per-package
//! `Vec`s, `String`s or O(ndev) reductions.

use crate::coordinator::work::Range;

use super::{PackageTiming, QosTracker, SchedDevice, Scheduler, ThroughputModel, QOS_TIGHTEN};

/// EWMA weight of the newest observation. More conservative than
/// [`Adaptive`](super::Adaptive)'s default: HGuided's geometric decay
/// already limits per-package risk, so it smooths harder against
/// content-dependent cost wobble (Mandelbrot regions).
const FEEDBACK_ALPHA: f64 = 0.3;

#[derive(Debug)]
pub struct HGuided {
    k: f64,
    min_granules: usize,
    /// Consume observed throughput (default). Off = the paper's static
    /// profile-power sizing, byte-for-byte.
    feedback: bool,
    granule: usize,
    /// Static profile priors: the minimum clamp stays power-scaled even
    /// under feedback (it is a floor heuristic, not an estimate).
    powers: Vec<f64>,
    power_max: f64,
    model: ThroughputModel,
    /// Next unassigned granule.
    cursor: usize,
    total: usize,
    /// Deadline-risk state (no-op for best-effort sessions).
    qos: QosTracker,
}

impl HGuided {
    pub fn new(k: f64, min_granules: usize) -> Self {
        Self::with_feedback(k, min_granules, true)
    }

    pub fn with_feedback(k: f64, min_granules: usize, feedback: bool) -> Self {
        Self {
            k: if k <= 0.0 { 2.0 } else { k },
            min_granules: min_granules.max(1),
            feedback,
            granule: 1,
            powers: Vec::new(),
            power_max: 0.0,
            model: ThroughputModel::new(FEEDBACK_ALPHA),
            cursor: 0,
            total: 0,
            qos: QosTracker::default(),
        }
    }

    /// Package size (in granules) for device `dev` given `pending`
    /// unassigned granules — the paper's formula over the model's
    /// throughput estimates, plus the minimum clamp.
    fn packet_granules(&self, dev: usize, pending: usize) -> usize {
        let n = self.powers.len() as f64;
        let mut raw =
            (pending as f64 * self.model.rate(dev)) / (self.k * n * self.model.rate_sum());
        // Deadline-driven tail sizing (same rule as Adaptive): while
        // the session's deadline is at risk, halve the chunk so the
        // straggler overhang shrinks. Unreachable without a QoS hint —
        // the bit-for-bit regression oracle below stays intact.
        if self.qos.at_risk(pending, &self.model) {
            raw /= QOS_TIGHTEN;
        }
        let p = self.powers[dev];
        let min_i =
            ((self.min_granules as f64 * p / self.power_max).round() as usize).max(1);
        (raw.floor() as usize).max(min_i).min(pending)
    }
}

impl Scheduler for HGuided {
    fn name(&self) -> String {
        if self.feedback { "HGuided".into() } else { "HGuided-static".into() }
    }

    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]) {
        self.granule = granule;
        self.powers = devices.iter().map(|d| d.power.max(1e-6)).collect();
        self.power_max = self.powers.iter().cloned().fold(f64::MIN, f64::max);
        self.model = ThroughputModel::new(FEEDBACK_ALPHA);
        if self.feedback {
            self.model.start(devices);
        } else {
            // Strip warm rates: static mode must see priors only.
            let cold: Vec<SchedDevice> =
                devices.iter().map(|d| SchedDevice::new(d.name.clone(), d.power)).collect();
            self.model.start(&cold);
        }
        self.cursor = 0;
        self.total = total_granules;
        self.qos.start(devices);
    }

    fn next_package(&mut self, dev: usize) -> Option<Range> {
        let pending = self.total - self.cursor;
        if pending == 0 {
            return None;
        }
        let take = self.packet_granules(dev, pending);
        let begin = self.cursor;
        self.cursor += take;
        Some(Range::new(begin * self.granule, self.cursor * self.granule))
    }

    fn observe(&mut self, dev: usize, range: Range, timing: PackageTiming) {
        if !self.feedback {
            // Static mode never folds observations into the model, so
            // the tracker's remaining-time estimate would have no
            // absolute scale — its QoS response stays admission-
            // prediction-only (`QosHint::pressured_at_start`).
            return;
        }
        let granules = range.len() as f64 / self.granule.max(1) as f64;
        self.model.observe(dev, granules, timing.span);
        self.qos.observe(dev, timing.span);
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;

    fn devs(powers: &[f64]) -> Vec<SchedDevice> {
        powers
            .iter()
            .enumerate()
            .map(|(i, p)| SchedDevice::new(format!("d{i}"), *p))
            .collect()
    }

    #[test]
    fn covers_everything_round_robin() {
        let mut s = HGuided::new(2.0, 2);
        let d = devs(&[0.3, 1.0, 0.42]);
        s.start(1000, 64, &d);
        let mut cursor = 0;
        let mut i = 0;
        while let Some(r) = s.next_package(i % 3) {
            assert_eq!(r.begin, cursor);
            assert_eq!(r.begin % 64, 0);
            assert_eq!(r.len() % 64, 0);
            cursor = r.end;
            i += 1;
        }
        assert_eq!(cursor, 1000 * 64);
    }

    #[test]
    fn sizes_decrease_for_same_device() {
        let mut s = HGuided::new(2.0, 1);
        s.start(10_000, 1, &devs(&[1.0, 1.0]));
        let mut last = usize::MAX;
        for _ in 0..20 {
            let r = s.next_package(0).unwrap();
            assert!(r.len() <= last, "monotonically non-increasing");
            last = r.len();
        }
    }

    #[test]
    fn powerful_devices_get_bigger_packets() {
        let mut a = HGuided::new(2.0, 2);
        a.start(10_000, 1, &devs(&[0.2, 1.0]));
        let weak = a.next_package(0).unwrap().len();
        let mut b = HGuided::new(2.0, 2);
        b.start(10_000, 1, &devs(&[0.2, 1.0]));
        let strong = b.next_package(1).unwrap().len();
        assert!(strong > weak * 3, "strong {strong} vs weak {weak}");
    }

    #[test]
    fn respects_min_granules() {
        let mut s = HGuided::new(2.0, 4);
        s.start(1000, 1, &devs(&[1.0, 1.0]));
        // Drain; every package ≥ min (except possibly the final remainder).
        let mut sizes = Vec::new();
        while let Some(r) = s.next_package(0) {
            sizes.push(r.len());
        }
        for &sz in &sizes[..sizes.len() - 1] {
            assert!(sz >= 4);
        }
        assert_eq!(sizes.iter().sum::<usize>(), 1000);
    }

    #[test]
    fn smaller_k_gives_bigger_first_packet() {
        let mut a = HGuided::new(1.0, 1);
        a.start(1000, 1, &devs(&[1.0]));
        let mut b = HGuided::new(4.0, 1);
        b.start(1000, 1, &devs(&[1.0]));
        assert!(a.next_package(0).unwrap().len() > b.next_package(0).unwrap().len());
    }

    /// The paper's original static-profile sizing, reimplemented
    /// independently as the regression oracle: the feedback rewrite may
    /// not move a single boundary while no observation has been fed.
    struct OldHGuided {
        k: f64,
        min_granules: usize,
        granule: usize,
        powers: Vec<f64>,
        power_sum: f64,
        power_max: f64,
        cursor: usize,
        total: usize,
    }

    impl OldHGuided {
        fn start(k: f64, min_granules: usize, total: usize, granule: usize, d: &[SchedDevice]) -> Self {
            let powers: Vec<f64> = d.iter().map(|x| x.power.max(1e-6)).collect();
            let power_sum = powers.iter().sum();
            let power_max = powers.iter().cloned().fold(f64::MIN, f64::max);
            Self { k, min_granules, granule, powers, power_sum, power_max, cursor: 0, total }
        }

        fn next_package(&mut self, dev: usize) -> Option<(usize, usize)> {
            let pending = self.total - self.cursor;
            if pending == 0 {
                return None;
            }
            let n = self.powers.len() as f64;
            let p = self.powers[dev];
            let raw = (pending as f64 * p) / (self.k * n * self.power_sum);
            let min_i =
                ((self.min_granules as f64 * p / self.power_max).round() as usize).max(1);
            let take = (raw.floor() as usize).max(min_i).min(pending);
            let begin = self.cursor;
            self.cursor += take;
            Some((begin * self.granule, self.cursor * self.granule))
        }
    }

    /// PR-2 audit regression: without observations, the rewritten
    /// (O(1), feedback-capable) HGuided produces bit-identical covers
    /// to the old static-profile implementation — same boundaries, same
    /// order, for feedback on *and* off, across power sets, k, min and
    /// interleavings.
    #[test]
    fn matches_old_static_formula_bit_for_bit() {
        let cases: &[(&[f64], f64, usize, usize, usize)] = &[
            (&[0.3, 1.0, 0.42], 2.0, 2, 1000, 64),
            (&[1.0], 1.0, 1, 777, 1),
            (&[0.2, 1.0], 3.5, 4, 4096, 8),
            (&[0.05, 0.5, 0.95, 1.0], 2.0, 2, 513, 128),
            (&[1.0, 1.0], 4.0, 8, 10_000, 1),
        ];
        for &(powers, k, min, total, granule) in cases {
            for feedback in [true, false] {
                let d = devs(powers);
                let mut new = HGuided::with_feedback(k, min, feedback);
                new.start(total, granule, &d);
                let mut old = OldHGuided::start(k, min, total, granule, &d);
                let mut dev = 0usize;
                loop {
                    let a = new.next_package(dev % powers.len()).map(|r| (r.begin, r.end));
                    let b = old.next_package(dev % powers.len());
                    assert_eq!(
                        a, b,
                        "boundary moved (powers {powers:?} k={k} min={min} feedback={feedback})"
                    );
                    if a.is_none() {
                        break;
                    }
                    dev += 1;
                }
            }
        }
    }

    #[test]
    fn feedback_shifts_shares_static_mode_does_not() {
        let slow_obs = PackageTiming { span: Duration::from_millis(400), raw_exec: Duration::from_millis(100) };
        let fast_obs = PackageTiming { span: Duration::from_millis(100), raw_exec: Duration::from_millis(25) };
        for (feedback, expect_shift) in [(true, true), (false, false)] {
            let mut s = HGuided::with_feedback(2.0, 1, feedback);
            s.start(100_000, 1, &devs(&[1.0, 1.0]));
            // Equal priors; observations say device 1 is 4x slower.
            for _ in 0..4 {
                let r0 = s.next_package(0).unwrap();
                s.observe(0, r0, fast_obs);
                let r1 = s.next_package(1).unwrap();
                s.observe(1, r1, slow_obs);
            }
            let fast = s.next_package(0).unwrap().len();
            let slow = s.next_package(1).unwrap().len();
            if expect_shift {
                assert!(
                    fast > slow * 2,
                    "feedback must shift sizing: fast {fast} vs slow {slow}"
                );
            } else {
                // Static mode keeps the ~equal-power ratio (the next
                // pending shrinks between the two calls, so allow the
                // geometric decay, not a throughput shift).
                assert!(
                    fast < slow * 2,
                    "static mode must not shift sizing: fast {fast} vs slow {slow}"
                );
            }
        }
    }

    #[test]
    fn qos_pressure_shrinks_packages_without_breaking_cover() {
        use super::super::QosHint;
        let d = devs(&[0.3, 1.0, 0.42]);
        let mut dq = d.clone();
        for dev in &mut dq {
            dev.qos = Some(QosHint::new(1.0, 3.0));
        }
        let mut plain = HGuided::new(2.0, 2);
        plain.start(1000, 64, &d);
        let mut hinted = HGuided::new(2.0, 2);
        hinted.start(1000, 64, &dq);
        let a = plain.next_package(1).unwrap().len();
        let b = hinted.next_package(1).unwrap().len();
        assert!(b < a, "over-deadline prediction must shrink the first chunk: {b} vs {a}");
        // The tightened scheduler still covers the pool exactly.
        let mut cursor = b;
        let mut i = 0;
        while let Some(r) = hinted.next_package(i % 3) {
            assert_eq!(r.begin, cursor);
            cursor = r.end;
            i += 1;
        }
        assert_eq!(cursor, 1000 * 64);
    }

    #[test]
    fn qos_hint_with_slack_is_boundary_neutral() {
        use super::super::QosHint;
        let d = devs(&[0.3, 1.0, 0.42]);
        let mut dq = d.clone();
        for dev in &mut dq {
            dev.qos = Some(QosHint::new(1e6, 1.0));
        }
        let mut plain = HGuided::new(2.0, 2);
        plain.start(1000, 64, &d);
        let mut hinted = HGuided::new(2.0, 2);
        hinted.start(1000, 64, &dq);
        let mut i = 0;
        loop {
            let a = plain.next_package(i % 3);
            let b = hinted.next_package(i % 3);
            assert_eq!(a, b, "slack hint moved a boundary");
            if a.is_none() {
                break;
            }
            i += 1;
        }
    }

    /// Float-ordering audit regression (PR-10): NaN/inf priors (a
    /// poisoned profile power, a corrupt warm-start rate) must degrade
    /// to the clamped floors — never a panic, never a stalled cover.
    #[test]
    fn nan_priors_degrade_to_clamped_floors_not_panic() {
        let mut d = devs(&[f64::NAN, 1.0]);
        d[0].warm_rate = Some(f64::NAN);
        d[1].warm_rate = Some(f64::INFINITY);
        let mut s = HGuided::new(2.0, 2);
        s.start(1000, 64, &d);
        let mut cursor = 0;
        let mut i = 0;
        while let Some(r) = s.next_package(i % 2) {
            assert_eq!(r.begin, cursor, "contiguous cover");
            assert!(!r.is_empty());
            cursor = r.end;
            i += 1;
        }
        assert_eq!(cursor, 1000 * 64, "poisoned priors still cover the pool");
    }

    #[test]
    fn warm_rates_seed_feedback_but_not_static_mode() {
        let mut d = devs(&[1.0, 1.0]);
        d[0].warm_rate = Some(400.0);
        d[1].warm_rate = Some(100.0);
        let mut warm = HGuided::with_feedback(2.0, 1, true);
        warm.start(10_000, 1, &d);
        let a = warm.next_package(0).unwrap().len();
        let mut warm_b = HGuided::with_feedback(2.0, 1, true);
        warm_b.start(10_000, 1, &d);
        let b = warm_b.next_package(1).unwrap().len();
        assert!(a > b * 2, "warm rates drive sizing: {a} vs {b}");

        let mut cold = HGuided::with_feedback(2.0, 1, false);
        cold.start(10_000, 1, &d);
        let c = cold.next_package(0).unwrap().len();
        let mut cold_b = HGuided::with_feedback(2.0, 1, false);
        cold_b.start(10_000, 1, &d);
        let e = cold_b.next_package(1).unwrap().len();
        assert_eq!(c, e, "static mode ignores warm rates");
    }
}
