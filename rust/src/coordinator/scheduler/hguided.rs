//! HGuided scheduler (paper §5.3) — the best performer in the paper's
//! evaluation: guided self-scheduling weighted by heterogeneous device
//! powers. Large packages early (few synchronization points), shrinking
//! toward the end (all devices finish together), sized per device:
//!
//!   packet_size_i = floor( G_r * P_i / (k * n * sum_j P_j) )
//!
//! clamped below by a per-device minimum that also scales with power
//! ("giving bigger package sizes in the most powerful devices").
//!
//! Hot-loop note: `next_package` runs on the master's `Done` path for
//! every package, so it must not allocate — it is pure arithmetic over
//! the per-run state (`powers` is built once per `start`; sizing reads
//! it in place). Keep it that way: no per-package `Vec`s or `String`s
//! (the audit that turned `Dynamic`'s materialized queue into O(1)
//! arithmetic applies here too).

use crate::coordinator::work::Range;

use super::{SchedDevice, Scheduler};

#[derive(Debug)]
pub struct HGuided {
    k: f64,
    min_granules: usize,
    granule: usize,
    powers: Vec<f64>,
    power_sum: f64,
    power_max: f64,
    /// Next unassigned granule.
    cursor: usize,
    total: usize,
}

impl HGuided {
    pub fn new(k: f64, min_granules: usize) -> Self {
        Self {
            k: if k <= 0.0 { 2.0 } else { k },
            min_granules: min_granules.max(1),
            granule: 1,
            powers: Vec::new(),
            power_sum: 0.0,
            power_max: 0.0,
            cursor: 0,
            total: 0,
        }
    }

    /// Package size (in granules) for device `dev` given `pending`
    /// unassigned granules — the paper's formula plus the minimum clamp.
    fn packet_granules(&self, dev: usize, pending: usize) -> usize {
        let n = self.powers.len() as f64;
        let p = self.powers[dev];
        let raw = (pending as f64 * p) / (self.k * n * self.power_sum);
        let min_i =
            ((self.min_granules as f64 * p / self.power_max).round() as usize).max(1);
        (raw.floor() as usize).max(min_i).min(pending)
    }
}

impl Scheduler for HGuided {
    fn name(&self) -> String {
        "HGuided".into()
    }

    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]) {
        self.granule = granule;
        self.powers = devices.iter().map(|d| d.power.max(1e-6)).collect();
        self.power_sum = self.powers.iter().sum();
        self.power_max = self.powers.iter().cloned().fold(f64::MIN, f64::max);
        self.cursor = 0;
        self.total = total_granules;
    }

    fn next_package(&mut self, dev: usize) -> Option<Range> {
        let pending = self.total - self.cursor;
        if pending == 0 {
            return None;
        }
        let take = self.packet_granules(dev, pending);
        let begin = self.cursor;
        self.cursor += take;
        Some(Range::new(begin * self.granule, self.cursor * self.granule))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn devs(powers: &[f64]) -> Vec<SchedDevice> {
        powers
            .iter()
            .enumerate()
            .map(|(i, p)| SchedDevice { name: format!("d{i}"), power: *p })
            .collect()
    }

    #[test]
    fn covers_everything_round_robin() {
        let mut s = HGuided::new(2.0, 2);
        let d = devs(&[0.3, 1.0, 0.42]);
        s.start(1000, 64, &d);
        let mut cursor = 0;
        let mut i = 0;
        while let Some(r) = s.next_package(i % 3) {
            assert_eq!(r.begin, cursor);
            assert_eq!(r.begin % 64, 0);
            assert_eq!(r.len() % 64, 0);
            cursor = r.end;
            i += 1;
        }
        assert_eq!(cursor, 1000 * 64);
    }

    #[test]
    fn sizes_decrease_for_same_device() {
        let mut s = HGuided::new(2.0, 1);
        s.start(10_000, 1, &devs(&[1.0, 1.0]));
        let mut last = usize::MAX;
        for _ in 0..20 {
            let r = s.next_package(0).unwrap();
            assert!(r.len() <= last, "monotonically non-increasing");
            last = r.len();
        }
    }

    #[test]
    fn powerful_devices_get_bigger_packets() {
        let mut a = HGuided::new(2.0, 2);
        a.start(10_000, 1, &devs(&[0.2, 1.0]));
        let weak = a.next_package(0).unwrap().len();
        let mut b = HGuided::new(2.0, 2);
        b.start(10_000, 1, &devs(&[0.2, 1.0]));
        let strong = b.next_package(1).unwrap().len();
        assert!(strong > weak * 3, "strong {strong} vs weak {weak}");
    }

    #[test]
    fn respects_min_granules() {
        let mut s = HGuided::new(2.0, 4);
        s.start(1000, 1, &devs(&[1.0, 1.0]));
        // Drain; every package ≥ min (except possibly the final remainder).
        let mut sizes = Vec::new();
        while let Some(r) = s.next_package(0) {
            sizes.push(r.len());
        }
        for &sz in &sizes[..sizes.len() - 1] {
            assert!(sz >= 4);
        }
        assert_eq!(sizes.iter().sum::<usize>(), 1000);
    }

    #[test]
    fn smaller_k_gives_bigger_first_packet() {
        let mut a = HGuided::new(1.0, 1);
        a.start(1000, 1, &devs(&[1.0]));
        let mut b = HGuided::new(4.0, 1);
        b.start(1000, 1, &devs(&[1.0]));
        assert!(a.next_package(0).unwrap().len() > b.next_package(0).unwrap().len());
    }
}
