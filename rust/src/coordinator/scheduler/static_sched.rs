//! Static scheduler (paper §5.3): one package per device, sized before
//! execution from known proportions. Minimal synchronization (one package
//! each), best for regular kernels on well-characterized devices; not
//! adaptive, so irregular loads (Mandelbrot) imbalance it badly — which
//! Figure 9 shows and our Figure-9 bench reproduces.
//!
//! Delivery order matters for irregular problems (which *region* each
//! device gets): `Static` hands the first slice to the first device,
//! `Static rev` reverses the slice order (paper §7.3).

use crate::coordinator::work::{proportional_split, Range};

use super::{SchedDevice, Scheduler};

#[derive(Debug)]
pub struct Static {
    props: Option<Vec<f64>>,
    reversed: bool,
    granule: usize,
    /// Pre-computed package per device; taken on first request.
    packages: Vec<Option<Range>>,
}

impl Static {
    pub fn new(props: Option<Vec<f64>>, reversed: bool) -> Self {
        Self { props, reversed, granule: 1, packages: Vec::new() }
    }
}

impl Scheduler for Static {
    fn name(&self) -> String {
        if self.reversed { "Static rev".into() } else { "Static".into() }
    }

    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]) {
        self.granule = granule;
        let mut props: Vec<f64> = match &self.props {
            Some(p) => {
                assert_eq!(p.len(), devices.len(), "one proportion per device");
                p.clone()
            }
            None => devices.iter().map(|d| d.power).collect(),
        };
        // Float-ordering audit (PR-10): a poisoned proportion (NaN/inf
        // power from a bad profile, or a negative user prop) must
        // degrade, not trip `proportional_split`'s sum assertion. Bad
        // entries get a zero share; an entirely-poisoned set falls back
        // to equal shares (someone must compute).
        for p in &mut props {
            if !p.is_finite() || *p < 0.0 {
                *p = 0.0;
            }
        }
        if props.iter().sum::<f64>() <= 0.0 {
            props = vec![1.0; props.len()];
        }
        // Slice the dataset contiguously; delivery order decides which
        // device gets which region.
        let order: Vec<usize> = if self.reversed {
            (0..devices.len()).rev().collect()
        } else {
            (0..devices.len()).collect()
        };
        let ordered_props: Vec<f64> = order.iter().map(|&i| props[i]).collect();
        let slices = proportional_split(total_granules, &ordered_props);
        let mut packages = vec![None; devices.len()];
        for (slot, (gb, ge)) in order.iter().zip(slices) {
            if ge > gb {
                packages[*slot] = Some(Range::new(gb * granule, ge * granule));
            }
        }
        self.packages = packages;
    }

    fn next_package(&mut self, dev: usize) -> Option<Range> {
        self.packages.get_mut(dev).and_then(|p| p.take())
    }

    /// The pre-split package of a dead device that never pulled it.
    /// Without this the engine's recovery path could never re-split a
    /// Static share lost to an init-time failure (the documented Static
    /// degradation: after a fault the run is no longer one-package-per-
    /// device — survivors execute the reclaimed share as extra packages).
    fn reclaim_device(&mut self, dev: usize) -> Vec<Range> {
        self.packages.get_mut(dev).and_then(|p| p.take()).into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn devs(powers: &[f64]) -> Vec<SchedDevice> {
        powers
            .iter()
            .enumerate()
            .map(|(i, p)| SchedDevice::new(format!("d{i}"), *p))
            .collect()
    }

    #[test]
    fn one_package_each_then_none() {
        let mut s = Static::new(Some(vec![0.25, 0.75]), false);
        s.start(100, 64, &devs(&[1.0, 1.0]));
        let a = s.next_package(0).unwrap();
        let b = s.next_package(1).unwrap();
        assert_eq!(a.len() + b.len(), 100 * 64);
        assert!(s.next_package(0).is_none());
        assert!(s.next_package(1).is_none());
    }

    #[test]
    fn proportions_respected() {
        let mut s = Static::new(Some(vec![0.1, 0.9]), false);
        s.start(1000, 1, &devs(&[1.0, 1.0]));
        let a = s.next_package(0).unwrap();
        let b = s.next_package(1).unwrap();
        assert!((a.len() as f64 - 100.0).abs() <= 1.0);
        assert!((b.len() as f64 - 900.0).abs() <= 1.0);
        // Device 0 gets the *first* region.
        assert_eq!(a.begin, 0);
        assert_eq!(b.end, 1000);
    }

    #[test]
    fn reversed_flips_regions() {
        let mut s = Static::new(Some(vec![0.5, 0.5]), true);
        s.start(10, 1, &devs(&[1.0, 1.0]));
        let a = s.next_package(0).unwrap();
        let b = s.next_package(1).unwrap();
        // Reversed: the last device gets the first region.
        assert_eq!(b.begin, 0);
        assert_eq!(a.end, 10);
    }

    #[test]
    fn defaults_to_power_proportions() {
        let mut s = Static::new(None, false);
        s.start(100, 1, &devs(&[1.0, 3.0]));
        let a = s.next_package(0).unwrap();
        let b = s.next_package(1).unwrap();
        assert_eq!(a.len(), 25);
        assert_eq!(b.len(), 75);
    }

    #[test]
    fn reclaim_returns_untaken_package_once() {
        let mut s = Static::new(Some(vec![0.5, 0.5]), false);
        s.start(10, 1, &devs(&[1.0, 1.0]));
        let reclaimed = s.reclaim_device(1);
        assert_eq!(reclaimed.len(), 1);
        assert_eq!(reclaimed[0].len(), 5);
        assert!(s.next_package(1).is_none(), "reclaimed package is gone");
        assert!(s.reclaim_device(1).is_empty(), "second reclaim finds nothing");
        // A package already delivered cannot be reclaimed from the scheduler.
        s.next_package(0).unwrap();
        assert!(s.reclaim_device(0).is_empty());
    }

    /// Float-ordering audit regression (PR-10): NaN profile powers used
    /// to flow raw into `proportional_split`, whose `sum > 0` assertion
    /// panics on a NaN sum. Poisoned entries must degrade to a zero
    /// share — and an all-poisoned profile to equal shares — instead.
    #[test]
    fn nan_power_profile_degrades_instead_of_panicking() {
        // One poisoned device: it gets nothing, the healthy one gets all.
        let mut s = Static::new(None, false);
        s.start(100, 1, &devs(&[f64::NAN, 1.0]));
        assert!(s.next_package(0).is_none(), "NaN power → zero share");
        assert_eq!(s.next_package(1).unwrap().len(), 100);

        // Every device poisoned (NaN and negative): equal-share fallback.
        let mut s = Static::new(None, false);
        s.start(100, 1, &devs(&[f64::NAN, -3.0]));
        let a = s.next_package(0).unwrap();
        let b = s.next_package(1).unwrap();
        assert_eq!(a.len(), 50);
        assert_eq!(b.len(), 50);
        assert_eq!(a.len() + b.len(), 100, "full cover");

        // Explicit user props get the same sanitation.
        let mut s = Static::new(Some(vec![f64::INFINITY, 1.0]), false);
        s.start(10, 1, &devs(&[1.0, 1.0]));
        assert!(s.next_package(0).is_none());
        assert_eq!(s.next_package(1).unwrap().len(), 10);
    }

    #[test]
    fn zero_power_device_gets_nothing() {
        let mut s = Static::new(Some(vec![0.0, 1.0]), false);
        s.start(10, 1, &devs(&[1.0, 1.0]));
        assert!(s.next_package(0).is_none());
        assert_eq!(s.next_package(1).unwrap().len(), 10);
    }
}
