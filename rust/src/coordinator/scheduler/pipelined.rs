//! `Pipelined` — a strategy *wrapper* that composes package pipelining
//! with any base scheduling algorithm.
//!
//! The wrapper delegates every sizing decision to the inner strategy, so
//! all partitioning invariants (disjoint granule-aligned ranges exactly
//! covering `[0, gws)`) are inherited unchanged — the property tests
//! assert this for all three paper schedulers. What it adds is the
//! *pipeline depth*: the engine reads it and keeps each device `depth`
//! packages ahead, so workers overlap the next package's H2D transfer
//! with the current package's compute (see the worker docs in
//! `coordinator::device`).
//!
//! Interaction with adaptive strategies: prefetching asks the inner
//! scheduler for a package *earlier* than assign-on-completion would
//! have, so Dynamic/HGuided size decisions see a slightly larger pending
//! set. This trades a little end-of-run balance for transfer overlap and
//! a shorter assign round-trip — the paper's follow-up (arXiv:2010.12607)
//! shows the trade wins on short, transfer-heavy loads.

use crate::coordinator::work::Range;

use super::{SchedDevice, Scheduler};

/// Composes a base strategy with a per-device package pipeline.
pub struct Pipelined {
    inner: Box<dyn Scheduler>,
    depth: usize,
}

impl Pipelined {
    /// Wrap `inner`, keeping each device up to `depth` packages ahead
    /// (`depth` is clamped to at least 2 — 1 would be the blocking loop).
    pub fn new(inner: Box<dyn Scheduler>, depth: usize) -> Self {
        Self { inner, depth: depth.max(2) }
    }
}

impl Scheduler for Pipelined {
    fn name(&self) -> String {
        format!("{}+pipe", self.inner.name())
    }

    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]) {
        self.inner.start(total_granules, granule, devices);
    }

    fn next_package(&mut self, dev: usize) -> Option<Range> {
        self.inner.next_package(dev)
    }

    fn pipeline_depth(&self) -> usize {
        self.depth
    }

    fn reclaim_device(&mut self, dev: usize) -> Vec<Range> {
        self.inner.reclaim_device(dev)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Dynamic, HGuided, SchedulerKind, Static};
    use super::*;

    fn devs(n: usize) -> Vec<SchedDevice> {
        (0..n).map(|i| SchedDevice { name: format!("d{i}"), power: 0.5 + i as f64 }).collect()
    }

    #[test]
    fn delegates_ranges_unchanged() {
        let mut plain = Dynamic::new(10);
        let mut piped = Pipelined::new(Box::new(Dynamic::new(10)), 2);
        plain.start(100, 8, &devs(2));
        piped.start(100, 8, &devs(2));
        loop {
            let a = plain.next_package(0);
            let b = piped.next_package(0);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn reports_depth_and_name() {
        let p = Pipelined::new(Box::new(Static::new(None, false)), 3);
        assert_eq!(p.pipeline_depth(), 3);
        assert_eq!(p.name(), "Static+pipe");
        let p = Pipelined::new(Box::new(HGuided::new(2.0, 2)), 0);
        assert_eq!(p.pipeline_depth(), 2, "clamped up to double-buffering");
    }

    #[test]
    fn kind_builds_wrapped_strategy() {
        let kind = SchedulerKind::dynamic(50).pipelined(2);
        let s = kind.build();
        assert_eq!(s.name(), "Dynamic 50+pipe");
        assert_eq!(s.pipeline_depth(), 2);
        assert_eq!(kind.label(), "Dynamic 50+pipe");
    }
}
