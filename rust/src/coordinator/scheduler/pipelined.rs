//! `Pipelined` — a strategy *wrapper* that composes package pipelining
//! with any base scheduling algorithm.
//!
//! The wrapper delegates every sizing decision to the inner strategy, so
//! all partitioning invariants (disjoint granule-aligned ranges exactly
//! covering `[0, gws)`) are inherited unchanged — the property tests
//! assert this for all three paper schedulers. What it adds is the
//! *pipeline depth*: the engine reads it and keeps each device `depth`
//! packages ahead, so workers overlap the next package's H2D transfer
//! with the current package's compute (see the worker docs in
//! `coordinator::device`).
//!
//! Interaction with adaptive strategies: prefetching asks the inner
//! scheduler for a package *earlier* than assign-on-completion would
//! have, so Dynamic/HGuided size decisions see a slightly larger pending
//! set. This trades a little end-of-run balance for transfer overlap and
//! a shorter assign round-trip — the paper's follow-up (arXiv:2010.12607)
//! shows the trade wins on short, transfer-heavy loads.

use crate::coordinator::work::Range;

use super::{PackageTiming, SchedDevice, Scheduler};

/// Composes a base strategy with a per-device package pipeline.
pub struct Pipelined {
    inner: Box<dyn Scheduler>,
    depth: usize,
}

impl Pipelined {
    /// Wrap `inner`, keeping each device up to `depth` packages ahead
    /// (`depth` is clamped to at least 2 — 1 would be the blocking loop).
    pub fn new(inner: Box<dyn Scheduler>, depth: usize) -> Self {
        Self { inner, depth: depth.max(2) }
    }
}

impl Scheduler for Pipelined {
    fn name(&self) -> String {
        format!("{}+pipe", self.inner.name())
    }

    fn start(&mut self, total_granules: usize, granule: usize, devices: &[SchedDevice]) {
        self.inner.start(total_granules, granule, devices);
    }

    fn next_package(&mut self, dev: usize) -> Option<Range> {
        self.inner.next_package(dev)
    }

    /// Feedback passes straight through: `adaptive+pipe` (and
    /// feedback-HGuided under `+pipe`) re-estimate throughput exactly
    /// as their blocking counterparts do — prefetching only changes
    /// *when* sizing decisions happen, never what they learn from.
    fn observe(&mut self, dev: usize, range: Range, timing: PackageTiming) {
        self.inner.observe(dev, range, timing);
    }

    fn pipeline_depth(&self) -> usize {
        self.depth
    }

    fn reclaim_device(&mut self, dev: usize) -> Vec<Range> {
        self.inner.reclaim_device(dev)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Dynamic, HGuided, SchedulerKind, Static};
    use super::*;

    fn devs(n: usize) -> Vec<SchedDevice> {
        (0..n).map(|i| SchedDevice::new(format!("d{i}"), 0.5 + i as f64)).collect()
    }

    #[test]
    fn delegates_ranges_unchanged() {
        let mut plain = Dynamic::new(10);
        let mut piped = Pipelined::new(Box::new(Dynamic::new(10)), 2);
        plain.start(100, 8, &devs(2));
        piped.start(100, 8, &devs(2));
        loop {
            let a = plain.next_package(0);
            let b = piped.next_package(0);
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn reports_depth_and_name() {
        let p = Pipelined::new(Box::new(Static::new(None, false)), 3);
        assert_eq!(p.pipeline_depth(), 3);
        assert_eq!(p.name(), "Static+pipe");
        let p = Pipelined::new(Box::new(HGuided::new(2.0, 2)), 0);
        assert_eq!(p.pipeline_depth(), 2, "clamped up to double-buffering");
    }

    #[test]
    fn kind_builds_wrapped_strategy() {
        let kind = SchedulerKind::dynamic(50).pipelined(2);
        let s = kind.build();
        assert_eq!(s.name(), "Dynamic 50+pipe");
        assert_eq!(s.pipeline_depth(), 2);
        assert_eq!(kind.label(), "Dynamic 50+pipe");
    }

    /// `observe` reaches the wrapped strategy: a wrapped and an
    /// unwrapped Adaptive fed the same assignments and observations
    /// stay in lockstep — the feedback loop composes with `+pipe`.
    #[test]
    fn observe_forwards_to_inner() {
        use super::super::{Adaptive, PackageTiming};
        use std::time::Duration;

        let equal: Vec<SchedDevice> =
            (0..2).map(|i| SchedDevice::new(format!("d{i}"), 1.0)).collect();
        let mut plain = Adaptive::new(2.0, 1, 0.5);
        let mut piped = Pipelined::new(Box::new(Adaptive::new(2.0, 1, 0.5)), 2);
        plain.start(100_000, 1, &equal);
        piped.start(100_000, 1, &equal);
        for round in 0..6 {
            for dev in 0..2 {
                let a = plain.next_package(dev);
                let b = piped.next_package(dev);
                assert_eq!(a, b, "diverged at round {round} dev {dev}");
                let Some(r) = a else { return };
                // Device 1 is observed 4x slower; both schedulers must
                // fold the same feedback and keep producing equal sizes.
                let span = Duration::from_micros((r.len() * if dev == 1 { 4 } else { 1 }) as u64);
                let t = PackageTiming { span, raw_exec: span / 4 };
                plain.observe(dev, r, t);
                piped.observe(dev, r, t);
            }
        }
    }
}
