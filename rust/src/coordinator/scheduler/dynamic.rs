//! Dynamic scheduler (paper §5.3): the dataset is divided into a given
//! number of equal packages, well above the device count; the master
//! assigns the next package to whichever device completes first.
//! Adaptive (good for irregular kernels), but every package is a
//! host<->device synchronization point — with many packages the overhead
//! shows, with few a slow device can grab too large a tail package
//! (Figure 9's Binomial/Dynamic-50 imbalance).
//!
//! Hot-loop note: the seed materialized the whole schedule into a
//! `VecDeque<Range>` at `start` (an O(packages) allocation rebuilt every
//! run, popped on the master's `Done` hot path). Packages of an equal
//! split are pure arithmetic, so the scheduler now keeps O(1) state and
//! computes each package on demand — `next_package` allocates nothing,
//! and the ranges are bit-identical to `equal_split`'s (asserted by a
//! unit test below).

use crate::coordinator::work::{equal_split, Range};

use super::{SchedDevice, Scheduler};

#[derive(Debug)]
pub struct Dynamic {
    /// Requested package count (≥ 1).
    packages: usize,
    // ---- per-run state (O(1), reset in `start`) ----------------------
    /// Effective package count (≤ total granules, as in `equal_split`).
    effective: usize,
    /// Granules per package (floor); the first `extra` packages get one
    /// more granule.
    base: usize,
    extra: usize,
    granule: usize,
    /// Next package index to hand out.
    next: usize,
}

impl Dynamic {
    pub fn new(packages: usize) -> Self {
        Self {
            packages: packages.max(1),
            effective: 0,
            base: 0,
            extra: 0,
            granule: 1,
            next: 0,
        }
    }

    /// Begin granule of package `i` under the largest-remainder split:
    /// the first `extra` packages are `base + 1` granules long.
    fn begin_granule(&self, i: usize) -> usize {
        i * self.base + i.min(self.extra)
    }
}

impl Scheduler for Dynamic {
    fn name(&self) -> String {
        format!("Dynamic {}", self.packages)
    }

    fn start(&mut self, total_granules: usize, granule: usize, _devices: &[SchedDevice]) {
        self.effective = if total_granules == 0 {
            0
        } else {
            self.packages.min(total_granules)
        };
        self.base = if self.effective == 0 { 0 } else { total_granules / self.effective };
        self.extra = if self.effective == 0 { 0 } else { total_granules % self.effective };
        self.granule = granule;
        self.next = 0;
    }

    fn next_package(&mut self, _dev: usize) -> Option<Range> {
        if self.next >= self.effective {
            return None;
        }
        let i = self.next;
        self.next += 1;
        let b = self.begin_granule(i);
        let e = self.begin_granule(i + 1);
        Some(Range::new(b * self.granule, e * self.granule))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn devs(n: usize) -> Vec<SchedDevice> {
        (0..n).map(|i| SchedDevice::new(format!("d{i}"), 1.0)).collect()
    }

    #[test]
    fn fifo_covers_everything() {
        let mut s = Dynamic::new(7);
        s.start(100, 8, &devs(3));
        let mut cursor = 0;
        let mut count = 0;
        while let Some(r) = s.next_package(count % 3) {
            assert_eq!(r.begin, cursor, "contiguous FIFO");
            cursor = r.end;
            count += 1;
        }
        assert_eq!(cursor, 100 * 8);
        assert_eq!(count, 7);
    }

    #[test]
    fn near_equal_packages() {
        let mut s = Dynamic::new(50);
        s.start(1024, 128, &devs(2));
        let mut lens = Vec::new();
        while let Some(r) = s.next_package(0) {
            lens.push(r.len());
        }
        assert_eq!(lens.len(), 50);
        let mx = lens.iter().max().unwrap();
        let mn = lens.iter().min().unwrap();
        assert!(mx - mn <= 128);
    }

    #[test]
    fn more_packages_than_granules_degrades_gracefully() {
        let mut s = Dynamic::new(100);
        s.start(3, 16, &devs(2));
        let mut total = 0;
        let mut n = 0;
        while let Some(r) = s.next_package(0) {
            total += r.len();
            n += 1;
        }
        assert_eq!(total, 48);
        assert_eq!(n, 3, "at most one package per granule");
    }

    #[test]
    fn zero_granules_yields_nothing() {
        let mut s = Dynamic::new(10);
        s.start(0, 8, &devs(1));
        assert!(s.next_package(0).is_none());
    }

    /// The on-demand arithmetic must reproduce `equal_split` exactly —
    /// the allocation-free rewrite may not move a single boundary.
    #[test]
    fn matches_equal_split_bit_for_bit() {
        for (total, packages, granule) in
            [(100usize, 7usize, 8usize), (5, 5, 1), (3, 10, 16), (1024, 50, 128), (1, 300, 64)]
        {
            let want: Vec<(usize, usize)> = equal_split(total, packages)
                .into_iter()
                .filter(|(b, e)| e > b)
                .map(|(b, e)| (b * granule, e * granule))
                .collect();
            let mut s = Dynamic::new(packages);
            s.start(total, granule, &devs(2));
            let mut got = Vec::new();
            while let Some(r) = s.next_package(0) {
                got.push((r.begin, r.end));
            }
            assert_eq!(got, want, "total={total} packages={packages}");
        }
    }
}
