//! Dynamic scheduler (paper §5.3): the dataset is divided into a given
//! number of equal packages, well above the device count; the master
//! assigns the next package to whichever device completes first.
//! Adaptive (good for irregular kernels), but every package is a
//! host<->device synchronization point — with many packages the overhead
//! shows, with few a slow device can grab too large a tail package
//! (Figure 9's Binomial/Dynamic-50 imbalance).

use std::collections::VecDeque;

use crate::coordinator::work::{equal_split, Range};

use super::{SchedDevice, Scheduler};

#[derive(Debug)]
pub struct Dynamic {
    packages: usize,
    queue: VecDeque<Range>,
}

impl Dynamic {
    pub fn new(packages: usize) -> Self {
        Self { packages: packages.max(1), queue: VecDeque::new() }
    }
}

impl Scheduler for Dynamic {
    fn name(&self) -> String {
        format!("Dynamic {}", self.packages)
    }

    fn start(&mut self, total_granules: usize, granule: usize, _devices: &[SchedDevice]) {
        self.queue = equal_split(total_granules, self.packages)
            .into_iter()
            .filter(|(b, e)| e > b)
            .map(|(b, e)| Range::new(b * granule, e * granule))
            .collect();
    }

    fn next_package(&mut self, _dev: usize) -> Option<Range> {
        self.queue.pop_front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn devs(n: usize) -> Vec<SchedDevice> {
        (0..n).map(|i| SchedDevice { name: format!("d{i}"), power: 1.0 }).collect()
    }

    #[test]
    fn fifo_covers_everything() {
        let mut s = Dynamic::new(7);
        s.start(100, 8, &devs(3));
        let mut cursor = 0;
        let mut count = 0;
        while let Some(r) = s.next_package(count % 3) {
            assert_eq!(r.begin, cursor, "contiguous FIFO");
            cursor = r.end;
            count += 1;
        }
        assert_eq!(cursor, 100 * 8);
        assert_eq!(count, 7);
    }

    #[test]
    fn near_equal_packages() {
        let mut s = Dynamic::new(50);
        s.start(1024, 128, &devs(2));
        let mut lens = Vec::new();
        while let Some(r) = s.next_package(0) {
            lens.push(r.len());
        }
        assert_eq!(lens.len(), 50);
        let mx = lens.iter().max().unwrap();
        let mn = lens.iter().min().unwrap();
        assert!(mx - mn <= 128);
    }

    #[test]
    fn more_packages_than_granules_degrades_gracefully() {
        let mut s = Dynamic::new(100);
        s.start(3, 16, &devs(2));
        let mut total = 0;
        let mut n = 0;
        while let Some(r) = s.next_package(0) {
            total += r.len();
            n += 1;
        }
        assert_eq!(total, 48);
        assert_eq!(n, 3, "at most one package per granule");
    }
}
