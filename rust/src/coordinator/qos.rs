//! QoS control for the persistent runtime: predictive admission,
//! deterministic load shedding, and the seeded EDF tie-break.
//!
//! # State machine (see docs/ARCHITECTURE.md "QoS & admission control")
//!
//! Every session a QoS-enabled runtime admits is registered here as
//! `Deadlined` or `BestEffort`. A deadlined session's master reports
//! its predicted slack on every completed package; the controller
//! drives three transitions:
//!
//! * **slack < 0, not yet at risk** → the session enters the at-risk
//!   set and one *shed* fires: a seeded, deterministic pick among the
//!   running, unpaused best-effort sessions is paused (its master stops
//!   assigning packages and parks its lease slots, freeing device time
//!   for the at-risk session). While any session is at risk, queued
//!   best-effort sessions are also held back at admission.
//! * **slack >= 0, was at risk** → the session leaves the at-risk set;
//!   when the set empties, every paused victim resumes.
//! * **session ends** (deregister) → same cleanup; a victim is never
//!   left paused behind a departed cause.
//!
//! All decisions draw from one [`XorShift`] seeded at construction and
//! are journaled as [`QosEvent`]s, so a fixed seed plus a fixed event
//! order replays the identical pause/resume/reject sequence — the
//! chaos suite's determinism contract.
//!
//! # Admission rejection
//!
//! When the [`MakespanPredictor`](crate::platform::MakespanPredictor)
//! prices a deadlined session's makespan above
//! `reject_factor * deadline` on a *fully warm* estimate, admission
//! fails the session up front with `EclError::AdmissionRejected`
//! instead of letting it burn device time it provably cannot use. Cold
//! or half-warm estimates never reject (the predictor property suite
//! pins this).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::lease::SessionId;
use crate::util::rng::XorShift;

/// Admissions a FIFO-queue head may lose to later-submitted deadlined
/// sessions before it is admitted unconditionally — the bounded-wait
/// guarantee that keeps a stream of deadlined sessions from starving
/// best-effort work forever.
pub const STARVATION_BOUND: usize = 4;

/// Deterministic tie-break rank for equal-deadline admissions: a
/// seeded hash of the session label, so the admit order of an
/// equal-deadline group depends on the runtime seed — never on
/// submission order (satellite: the shuffle regression test).
pub fn admission_tiebreak(seed: u64, label: &str) -> u64 {
    // FNV-1a over the label folded into splitmix64 with the seed.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in label.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    let mut z = h ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Runtime-level QoS knobs (`Runtime::qos_configured`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QosPolicy {
    /// Master switch: off (the default) leaves every admission and
    /// master-loop path exactly as the pre-QoS runtime.
    pub enabled: bool,
    /// Reject a deadlined session at admission when its fully-warm
    /// predicted makespan exceeds `reject_factor * deadline`. The
    /// margin (> 1) keeps borderline predictions from spuriously
    /// rejecting sessions that could still make it.
    pub reject_factor: f64,
    /// Pause best-effort sessions while a deadlined session's slack is
    /// negative.
    pub shed: bool,
}

impl Default for QosPolicy {
    fn default() -> Self {
        Self { enabled: false, reject_factor: 1.5, shed: true }
    }
}

impl QosPolicy {
    /// The reference QoS configuration: admission rejection and
    /// shedding both armed.
    pub fn enabled() -> Self {
        Self { enabled: true, ..Self::default() }
    }
}

/// Priority class of a registered session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QosClass {
    Deadlined,
    BestEffort,
}

/// One journaled QoS decision (the replayability observable).
#[derive(Debug, Clone, PartialEq)]
pub enum QosEvent {
    /// Admission rejected `session` outright.
    Rejected { session: SessionId, label: String, predicted: Duration, deadline: Duration },
    /// `session`'s predicted slack went negative.
    AtRisk { session: SessionId },
    /// `session`'s slack recovered while still running.
    Cleared { session: SessionId },
    /// Best-effort `victim` paused to free device time for `cause`.
    Paused { victim: SessionId, cause: SessionId },
    /// `victim` resumed (every at-risk session cleared or ended).
    Resumed { victim: SessionId },
}

#[derive(Debug)]
struct CtlState {
    rng: XorShift,
    running: BTreeMap<SessionId, QosClass>,
    at_risk: BTreeSet<SessionId>,
    paused: BTreeSet<SessionId>,
    journal: Vec<QosEvent>,
}

/// The runtime's shed/preempt brain (one per [`Runtime`]); see the
/// module docs for the state machine.
///
/// [`Runtime`]: crate::coordinator::runtime::Runtime
#[derive(Debug)]
pub struct QosController {
    shed: bool,
    state: Mutex<CtlState>,
}

impl QosController {
    pub fn new(seed: u64, policy: QosPolicy) -> Self {
        Self {
            shed: policy.shed,
            state: Mutex::new(CtlState {
                rng: XorShift::new(seed ^ 0x51A0_C0DE),
                running: BTreeMap::new(),
                at_risk: BTreeSet::new(),
                paused: BTreeSet::new(),
                journal: Vec::new(),
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, CtlState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// A session cleared admission.
    pub fn register(&self, session: SessionId, class: QosClass) {
        self.lock().running.insert(session, class);
    }

    /// A session ended (any outcome). Clears its risk/pause state and
    /// resumes victims if it was the last at-risk session.
    pub fn deregister(&self, session: SessionId) {
        let mut st = self.lock();
        st.running.remove(&session);
        st.paused.remove(&session);
        if st.at_risk.remove(&session) && st.at_risk.is_empty() {
            Self::resume_all(&mut st);
        }
    }

    /// A deadlined session's master reports its predicted slack (secs).
    /// Negative slack marks it at risk and sheds one best-effort
    /// victim; recovered slack clears it (and resumes victims once no
    /// session is at risk).
    pub fn report_slack(&self, session: SessionId, slack_secs: f64) {
        let mut st = self.lock();
        if slack_secs < 0.0 {
            if st.at_risk.insert(session) {
                st.journal.push(QosEvent::AtRisk { session });
                if self.shed {
                    // Seeded, deterministic victim pick over the
                    // BTreeMap's sorted ids — replayable for a fixed
                    // seed and event order.
                    let candidates: Vec<SessionId> = st
                        .running
                        .iter()
                        .filter(|(id, class)| {
                            **class == QosClass::BestEffort && !st.paused.contains(*id)
                        })
                        .map(|(id, _)| *id)
                        .collect();
                    if !candidates.is_empty() {
                        let victim = candidates[st.rng.below(candidates.len())];
                        st.paused.insert(victim);
                        st.journal.push(QosEvent::Paused { victim, cause: session });
                    }
                }
            }
        } else if st.at_risk.remove(&session) {
            st.journal.push(QosEvent::Cleared { session });
            if st.at_risk.is_empty() {
                Self::resume_all(&mut st);
            }
        }
    }

    fn resume_all(st: &mut CtlState) {
        let victims: Vec<SessionId> = st.paused.iter().copied().collect();
        st.paused.clear();
        for victim in victims {
            st.journal.push(QosEvent::Resumed { victim });
        }
    }

    /// Checked by best-effort session masters every loop iteration.
    pub fn is_paused(&self, session: SessionId) -> bool {
        self.lock().paused.contains(&session)
    }

    /// Any deadlined session currently at risk? (Admission holds queued
    /// best-effort sessions back while true.)
    pub fn any_at_risk(&self) -> bool {
        !self.lock().at_risk.is_empty()
    }

    /// Journal an admission rejection (the typed error travels on the
    /// session handle; this is the controller-side record).
    pub fn record_rejection(
        &self,
        session: SessionId,
        label: &str,
        predicted: Duration,
        deadline: Duration,
    ) {
        self.lock().journal.push(QosEvent::Rejected {
            session,
            label: label.to_string(),
            predicted,
            deadline,
        });
    }

    /// The decision journal so far.
    pub fn journal(&self) -> Vec<QosEvent> {
        self.lock().journal.clone()
    }

    /// Paused-victim count right now (test observable).
    pub fn paused_count(&self) -> usize {
        self.lock().paused.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn tiebreak_is_deterministic_and_seed_sensitive() {
        assert_eq!(admission_tiebreak(7, "a"), admission_tiebreak(7, "a"));
        assert_ne!(admission_tiebreak(7, "a"), admission_tiebreak(8, "a"));
        assert_ne!(admission_tiebreak(7, "a"), admission_tiebreak(7, "b"));
    }

    #[test]
    fn negative_slack_sheds_one_best_effort_victim() {
        let ctl = QosController::new(7, QosPolicy::enabled());
        ctl.register(0, QosClass::Deadlined);
        ctl.register(1, QosClass::BestEffort);
        ctl.register(2, QosClass::BestEffort);
        ctl.report_slack(0, -0.5);
        assert!(ctl.any_at_risk());
        assert_eq!(ctl.paused_count(), 1, "exactly one victim per at-risk entry");
        let paused_first = ctl.is_paused(1);
        let paused_second = ctl.is_paused(2);
        assert!(paused_first ^ paused_second, "one of the two best-effort sessions");
        // Repeated negative reports do not shed again.
        ctl.report_slack(0, -1.0);
        assert_eq!(ctl.paused_count(), 1);
    }

    #[test]
    fn victim_choice_is_seed_deterministic() {
        let run = |seed: u64| {
            let ctl = QosController::new(seed, QosPolicy::enabled());
            ctl.register(0, QosClass::Deadlined);
            for s in 1..=5 {
                ctl.register(s, QosClass::BestEffort);
            }
            ctl.report_slack(0, -0.1);
            ctl.journal()
        };
        assert_eq!(run(42), run(42), "same seed, same journal");
    }

    #[test]
    fn recovered_slack_resumes_victims() {
        let ctl = QosController::new(7, QosPolicy::enabled());
        ctl.register(0, QosClass::Deadlined);
        ctl.register(1, QosClass::BestEffort);
        ctl.report_slack(0, -0.5);
        assert_eq!(ctl.paused_count(), 1);
        ctl.report_slack(0, 0.2);
        assert!(!ctl.any_at_risk());
        assert_eq!(ctl.paused_count(), 0, "victims resume when the risk clears");
        let journal = ctl.journal();
        assert!(matches!(journal.last(), Some(QosEvent::Resumed { victim: 1 })), "{journal:?}");
    }

    #[test]
    fn departed_cause_never_leaves_victims_paused() {
        let ctl = QosController::new(7, QosPolicy::enabled());
        ctl.register(0, QosClass::Deadlined);
        ctl.register(1, QosClass::BestEffort);
        ctl.report_slack(0, -0.5);
        assert_eq!(ctl.paused_count(), 1);
        ctl.deregister(0);
        assert_eq!(ctl.paused_count(), 0, "session end releases its victims");
    }

    #[test]
    fn shedding_can_be_disarmed() {
        let ctl = QosController::new(7, QosPolicy { shed: false, ..QosPolicy::enabled() });
        ctl.register(0, QosClass::Deadlined);
        ctl.register(1, QosClass::BestEffort);
        ctl.report_slack(0, -0.5);
        assert!(ctl.any_at_risk(), "risk is still tracked");
        assert_eq!(ctl.paused_count(), 0, "but nothing is paused");
    }

    #[test]
    fn rejection_is_journaled() {
        let ctl = QosController::new(7, QosPolicy::enabled());
        ctl.record_rejection(3, "batch", ms(500), ms(100));
        match &ctl.journal()[0] {
            QosEvent::Rejected { session, label, predicted, deadline } => {
                assert_eq!(*session, 3);
                assert_eq!(label, "batch");
                assert_eq!(*predicted, ms(500));
                assert_eq!(*deadline, ms(100));
            }
            other => panic!("{other:?}"),
        }
    }
}
