//! Buffer proxy (paper Figure 4: Proxy pattern) — one interface over the
//! programmer's containers regardless of their nature. The engine reads
//! inputs through it and writes results back into the user's storage after
//! `run()`, so user code keeps using plain `Vec<f32>`s.

use crate::runtime::HostBuf;

/// Direction of a program buffer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    In,
    Out,
}

/// A registered program buffer. Owns a snapshot for inputs; outputs are
/// materialized by the engine and copied out after the run.
#[derive(Debug, Clone)]
pub struct Buffer {
    pub direction: Direction,
    data: HostBuf,
}

impl Buffer {
    pub fn input(data: Vec<f32>) -> Self {
        Self { direction: Direction::In, data: HostBuf::F32(data) }
    }

    /// Output buffer of `len` f32 elements (zero-initialized).
    pub fn output(len: usize) -> Self {
        Self { direction: Direction::Out, data: HostBuf::zeros_f32(len) }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn host(&self) -> &HostBuf {
        &self.data
    }

    pub fn host_mut(&mut self) -> &mut HostBuf {
        &mut self.data
    }

    pub fn as_f32(&self) -> &[f32] {
        self.data.as_f32().expect("f32 buffer")
    }

    /// Replace contents (used by the engine to publish results).
    pub fn store(&mut self, data: HostBuf) {
        self.data = data;
    }

    pub fn take(self) -> HostBuf {
        self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_snapshot() {
        let b = Buffer::input(vec![1.0, 2.0]);
        assert_eq!(b.direction, Direction::In);
        assert_eq!(b.as_f32(), &[1.0, 2.0]);
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn output_zeroed() {
        let b = Buffer::output(3);
        assert_eq!(b.direction, Direction::Out);
        assert_eq!(b.as_f32(), &[0.0; 3]);
    }

    #[test]
    fn store_and_take() {
        let mut b = Buffer::output(2);
        b.store(HostBuf::F32(vec![5.0, 6.0]));
        assert_eq!(b.as_f32(), &[5.0, 6.0]);
        assert_eq!(b.take(), HostBuf::F32(vec![5.0, 6.0]));
    }
}
