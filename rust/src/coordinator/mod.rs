//! The coordinator — EngineCL's contribution, re-implemented in Rust.
//!
//! Tier-1 (paper Figure 3): [`Engine`] and [`Program`] — the facade most
//! programs need — plus the persistent [`Runtime`] for concurrent
//! [`RunSession`]s over one device set. Tier-2: [`DeviceSpec`],
//! [`Configurator`], scheduler selection, the lease policy. Tier-3
//! (internal): device worker threads, the lease arbiter, work
//! decomposition, the runtime layer and the introspector.

pub mod buffer;
pub mod config;
pub mod device;
pub mod engine;
pub mod error;
pub mod introspector;
pub mod lease;
pub mod program;
pub mod qos;
pub mod runtime;
pub mod scheduler;
pub mod service;
pub mod work;

pub use buffer::Buffer;
pub use config::Configurator;
pub use device::{DeviceMask, DeviceSpec};
pub use engine::Engine;
pub use error::EclError;
pub use introspector::{DeviceTrace, FaultEvent, PackageTrace, RunReport, TransferStats};
pub use lease::{GrantRecord, LeaseArbiter, LeasePolicy, SessionId};
pub use program::{Arg, Program};
pub use qos::{QosClass, QosController, QosEvent, QosPolicy};
pub use runtime::{RunSession, Runtime, SessionHandle, SessionOutcome};
pub use scheduler::{EnergyObjective, SchedulerKind};
pub use service::{
    LedgerCounts, LedgerState, Request, RequestId, RequestReport, Response, ResponseHandle,
    Served, Service, ServiceConfig, ServiceStats,
};
pub use work::Range;
