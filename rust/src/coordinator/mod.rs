//! The coordinator — EngineCL's contribution, re-implemented in Rust.
//!
//! Tier-1 (paper Figure 3): [`Engine`] and [`Program`] — the facade most
//! programs need. Tier-2: [`DeviceSpec`], [`Configurator`], scheduler
//! selection. Tier-3 (internal): device worker threads, work
//! decomposition, the runtime layer and the introspector.

pub mod buffer;
pub mod config;
pub mod device;
pub mod engine;
pub mod error;
pub mod introspector;
pub mod program;
pub mod scheduler;
pub mod work;

pub use buffer::Buffer;
pub use config::Configurator;
pub use device::{DeviceMask, DeviceSpec};
pub use engine::Engine;
pub use error::EclError;
pub use introspector::{DeviceTrace, FaultEvent, PackageTrace, RunReport, TransferStats};
pub use program::{Arg, Program};
pub use scheduler::SchedulerKind;
pub use work::Range;
