//! `Engine` — the Tier-1 facade (paper Figure 4): device selection, work
//! sizes, scheduler choice, pipeline depth, program consumption and
//! `run()`.
//!
//! Since the persistent runtime landed, the engine no longer owns the
//! execution machinery: `run()` is a thin one-session wrapper over the
//! session execution core in `coordinator::runtime` (`SessionExec`) —
//! the same validation, zero-copy buffer setup, device workers, master
//! scheduling loop and fault recovery that concurrent
//! [`Runtime`](crate::coordinator::runtime::Runtime) sessions use, fed
//! a private single-participant lease arbiter (whose grants are
//! therefore always immediate). See `runtime.rs` for the master-loop
//! and fault-tolerance mechanics, and `lease.rs` for how concurrent
//! sessions share devices.

use crate::coordinator::config::Configurator;
use crate::coordinator::device::{DeviceMask, DeviceSpec};
use crate::coordinator::error::EclError;
use crate::coordinator::introspector::RunReport;
use crate::coordinator::lease::{LeaseArbiter, LeasePolicy};
use crate::coordinator::program::Program;
use crate::coordinator::runtime::{check_device_selection, SessionExec, SessionLeases};
use std::sync::Arc;

use crate::coordinator::scheduler::SchedulerKind;
use crate::platform::fault::FaultPlan;
use crate::platform::perfmodel::PerfModelStore;
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

/// Most packages a pipelined device keeps in flight. Deeper pipelines buy
/// nothing (one package computes while one stages) but starve adaptive
/// schedulers of late sizing decisions.
pub const MAX_PIPELINE_DEPTH: usize = 8;

/// The paper's `ecl::EngineCL`.
pub struct Engine {
    registry: ArtifactRegistry,
    node: NodeConfig,
    selected: Vec<DeviceSpec>,
    scheduler: SchedulerKind,
    /// Tier-1 pipeline override; `None` defers to the scheduler spec
    /// (a `Pipelined` wrapper / `+pipe` suffix).
    pipeline_depth: Option<usize>,
    config: Configurator,
    gws: Option<usize>,
    lws: Option<usize>,
    program: Option<Program>,
    report: Option<RunReport>,
    errors: Vec<EclError>,
    /// Cross-run performance model: repeated `run()`s on one engine
    /// warm-start their schedulers from earlier runs' observed
    /// throughput (see `platform::perfmodel`).
    perf: Arc<PerfModelStore>,
}

impl Engine {
    /// Discover artifacts and start from the default (Batel) node.
    pub fn new() -> Result<Self, EclError> {
        Ok(Self::with_registry(ArtifactRegistry::discover()?))
    }

    pub fn with_registry(registry: ArtifactRegistry) -> Self {
        Self {
            registry,
            node: NodeConfig::batel(),
            selected: Vec::new(),
            scheduler: SchedulerKind::static_default(),
            pipeline_depth: None,
            config: Configurator::default(),
            gws: None,
            lws: None,
            program: None,
            report: None,
            errors: Vec::new(),
            perf: Arc::new(PerfModelStore::new()),
        }
    }

    /// Select the simulated node (paper: the machine you run on).
    pub fn node(&mut self, node: NodeConfig) -> &mut Self {
        self.node = node;
        self
    }

    pub fn node_config(&self) -> &NodeConfig {
        &self.node
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Select devices by mask (paper: `engine.use(ecl::DeviceMask::CPU)`).
    pub fn use_mask(&mut self, mask: DeviceMask) -> &mut Self {
        self.selected = self
            .node
            .devices
            .iter()
            .enumerate()
            .filter(|(_, d)| mask.matches(d.kind))
            .map(|(i, _)| DeviceSpec::new(i))
            .collect();
        self
    }

    /// Select explicit devices, optionally with kernel specializations
    /// (paper Listing 2: `engine.use(Device(0,0), Device(0,1,phi_bin),..)`).
    pub fn use_devices(&mut self, devices: Vec<DeviceSpec>) -> &mut Self {
        self.selected = devices;
        self
    }

    pub fn global_work_items(&mut self, gws: usize) -> &mut Self {
        self.gws = Some(gws);
        self
    }

    pub fn local_work_items(&mut self, lws: usize) -> &mut Self {
        self.lws = Some(lws);
        self
    }

    /// Both sizes in one call (paper: `engine.work_items(gws, lws)`).
    pub fn work_items(&mut self, gws: usize, lws: usize) -> &mut Self {
        self.gws = Some(gws);
        self.lws = Some(lws);
        self
    }

    pub fn scheduler(&mut self, kind: SchedulerKind) -> &mut Self {
        self.scheduler = kind;
        self
    }

    /// Set the per-device package pipeline depth (Tier-1 access to the
    /// co-execution pipeline): `1` is the blocking assign-on-completion
    /// loop, `2` (the sweet spot) double-buffers — each device uploads
    /// package *n+1* while computing package *n* and never idles on the
    /// master's round-trip. Values are validated in `run()` against
    /// [`MAX_PIPELINE_DEPTH`]. Composes with every scheduler; equivalent
    /// to the `+pipe` scheduler-spec suffix.
    pub fn pipeline(&mut self, depth: usize) -> &mut Self {
        self.pipeline_depth = Some(depth);
        self
    }

    /// The pipeline depth `run()` will use: the Tier-1 override if set,
    /// else whatever the scheduler spec carries (1 = blocking).
    pub fn effective_pipeline_depth(&self) -> usize {
        self.pipeline_depth.unwrap_or_else(|| self.scheduler.pipeline_depth()).max(1)
    }

    /// Tier-2 access to runtime internals.
    pub fn configurator(&mut self) -> &mut Configurator {
        &mut self.config
    }

    /// This engine's cross-run performance model: per-(kernel, device)
    /// throughput estimates accumulated by every `run()` so far —
    /// feedback-capable schedulers warm-start from it (disable via
    /// `configurator().warm_start`), and [`PerfModelStore::clear`]
    /// cold-restarts it.
    pub fn perf_model(&self) -> &Arc<PerfModelStore> {
        &self.perf
    }

    /// Install a deterministic fault-injection plan for subsequent runs
    /// (chaos testing the recovery path) — Tier-1 sugar for
    /// `configurator().fault_plan`. Device indices in the plan refer to
    /// the *selected* device slots. Clear with
    /// `engine.configurator().fault_plan = None`.
    pub fn fault_plan(&mut self, plan: FaultPlan) -> &mut Self {
        self.config.fault_plan = Some(plan);
        self
    }

    /// Consume the program (paper: `engine.program(std::move(program))`).
    pub fn program(&mut self, program: Program) -> &mut Self {
        self.program = Some(program);
        self
    }

    pub fn has_errors(&self) -> bool {
        !self.errors.is_empty()
    }

    pub fn get_errors(&self) -> &[EclError] {
        &self.errors
    }

    /// Introspection data of the last run (paper's Configurator stats).
    /// `None` until a run succeeds — a failed run clears it rather than
    /// leaving the *previous* run's report visible.
    pub fn report(&self) -> Option<&RunReport> {
        self.report.as_ref()
    }

    /// Computed output `i` of the last run.
    pub fn output(&self, i: usize) -> Option<&[f32]> {
        self.program.as_ref().and_then(|p| p.outputs().get(i)).map(|b| b.as_f32())
    }

    /// Run the program on the selected devices. Errors are both returned
    /// and collected on the engine (paper's error model).
    pub fn run(&mut self) -> Result<(), EclError> {
        // Clear prior-run introspection *before* anything can fail: a
        // failed run must never leave a stale report (or stale success
        // state) from an earlier run visible through `report()`.
        self.report = None;
        match self.run_inner() {
            Ok(report) => {
                self.report = Some(report);
                Ok(())
            }
            Err(e) => {
                let msg = format!("{e}");
                self.errors.push(e);
                Err(EclError::Runtime(msg))
            }
        }
    }

    /// One-session wrapper over the runtime's session execution core: a
    /// private arbiter with this engine as the only participant, so
    /// every lease acquire is immediate and behavior is exactly the
    /// pre-runtime engine's.
    fn run_inner(&mut self) -> Result<RunReport, EclError> {
        let program = self.program.as_mut().ok_or(EclError::NoProgram)?;
        if self.selected.is_empty() {
            return Err(EclError::NoDevices);
        }
        // Checked here (not just in SessionExec) because registering
        // with the arbiter below indexes the device table.
        check_device_selection(&self.node, &self.selected)?;
        let arbiter = LeaseArbiter::new(self.node.devices.len(), LeasePolicy::Rotation);
        let registrations: Vec<_> = self
            .selected
            .iter()
            .map(|s| arbiter.register(s.index, 0))
            .collect();
        let exec = SessionExec {
            registry: self.registry.clone(),
            node: self.node.clone(),
            selected: self.selected.clone(),
            scheduler: self.scheduler.clone(),
            pipeline_depth: self.pipeline_depth,
            config: self.config.clone(),
            gws: self.gws,
            session: 0,
            leases: SessionLeases { arbiter, registrations },
            perf: Some(Arc::clone(&self.perf)),
            qos: None,
            artifacts: None,
        };
        exec.run(program)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_depth_resolution() {
        let mut e = Engine::with_registry(ArtifactRegistry::synthetic());
        assert_eq!(e.effective_pipeline_depth(), 1, "blocking by default");
        e.scheduler(SchedulerKind::hguided().pipelined(2));
        assert_eq!(e.effective_pipeline_depth(), 2, "scheduler spec carries depth");
        e.pipeline(3);
        assert_eq!(e.effective_pipeline_depth(), 3, "Tier-1 override wins");
        e.pipeline(0);
        assert_eq!(e.effective_pipeline_depth(), 1, "clamped to >= 1");
    }

    #[test]
    fn oversized_pipeline_depth_rejected() {
        let reg = ArtifactRegistry::synthetic();
        let mut e = Engine::with_registry(reg.clone());
        e.use_devices(vec![DeviceSpec::new(0)]);
        e.pipeline(MAX_PIPELINE_DEPTH + 1);
        e.program(crate::harness::runs::build_program(&reg, "binomial").unwrap());
        assert!(e.run().is_err());
        assert!(matches!(e.get_errors()[0], EclError::BadPipelineDepth { .. }));
    }

    #[test]
    fn out_of_range_device_rejected() {
        let reg = ArtifactRegistry::synthetic();
        let mut e = Engine::with_registry(reg.clone());
        e.use_devices(vec![DeviceSpec::new(42)]);
        e.program(crate::harness::runs::build_program(&reg, "binomial").unwrap());
        let err = e.run().unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }
}
