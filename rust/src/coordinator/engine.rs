//! `Engine` — the Tier-1 facade (paper Figure 4): device selection, work
//! sizes, scheduler choice, pipeline depth, program consumption and
//! `run()`.
//!
//! `run()` materializes the program's inputs into shared views and its
//! outputs into the run's output arena, spawns one worker thread per
//! selected device, drives the master scheduling loop
//! (assign-on-completion, the paper's Scheduler thread — extended with
//! per-device prefetch when pipelining is on), recovers the arena
//! buffers back into the program's output containers (zero-copy — the
//! workers already wrote every result in place) and leaves a full
//! `RunReport` for introspection.
//!
//! # Master loop
//!
//! The loop is event-driven over the worker channel:
//!
//! * `Ready` — device initialized; top its pipeline up to `depth`
//!   packages (the first assignment carries the second range as a
//!   `lookahead`, halving the fill round-trips).
//! * `Uploaded` — a prefetch's H2D staging landed; release the
//!   device's staging slot (at most two assignments may be un-staged
//!   at once — back-pressure for slow buses) and top up again.
//! * `Done` — a package completed; one slot freed, assign the next
//!   package or send `Finish` when the scheduler is dry for that device.
//! * `Finished`/`Failed` — worker exited; collect its traces and
//!   transfer stats (results are already in the arena) or the failure.
//!
//! With `depth == 1` this reduces exactly to the paper's blocking
//! assign-on-completion loop.
//!
//! # Fault tolerance
//!
//! The loop tracks, per device, every range assigned but not yet
//! reported `Done` (by the time a worker sends `Done`, the package's
//! results are fully in the arena). When a worker dies — it reports
//! `Failed`, or the liveness sweep finds its thread exited without
//! reporting — the master *recovers* instead of aborting (default;
//! `Configurator::fault_tolerant = false` restores abort-on-failure):
//! the dead device's unfinished ranges plus any scheduler reservation
//! (`Scheduler::reclaim_device` — Static's pre-split share) are
//! reclaimed, their arena claims revoked ([`OutputArena::revoke`]), and
//! the ranges are requeued — split so every survivor can pull a piece.
//! Survivors drain the requeue queue before asking the scheduler, so
//! Dynamic/HGuided absorb the lost work adaptively and Static degrades
//! to a documented re-split (survivors run extra packages). `Finish` is
//! deferred until all work is provably complete — a failure can then
//! never strand requeued work on a device that was already told to
//! exit. Every failure is recorded as a [`FaultEvent`] on the
//! `RunReport`, and requeued packages are flagged in their traces.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::config::Configurator;
use crate::coordinator::device::{
    spawn_worker, Assignment, DeviceMask, DeviceSpec, FromWorker, ToWorker, WorkerCtx,
};
use crate::coordinator::error::EclError;
use crate::coordinator::introspector::{DeviceTrace, FaultEvent, RunReport};
use crate::coordinator::program::{Arg, Program};
use crate::coordinator::scheduler::{SchedDevice, Scheduler, SchedulerKind};
use crate::coordinator::work::{split_range, Range};
use crate::platform::fault::FaultPlan;
use crate::platform::{DeviceKind, NodeConfig};
use crate::runtime::{input_views, ArtifactRegistry, HostBuf, InputView, OutputArena};

/// Most packages a pipelined device keeps in flight. Deeper pipelines buy
/// nothing (one package computes while one stages) but starve adaptive
/// schedulers of late sizing decisions.
pub const MAX_PIPELINE_DEPTH: usize = 8;

/// The paper's `ecl::EngineCL`.
pub struct Engine {
    registry: ArtifactRegistry,
    node: NodeConfig,
    selected: Vec<DeviceSpec>,
    scheduler: SchedulerKind,
    /// Tier-1 pipeline override; `None` defers to the scheduler spec
    /// (a `Pipelined` wrapper / `+pipe` suffix).
    pipeline_depth: Option<usize>,
    config: Configurator,
    gws: Option<usize>,
    lws: Option<usize>,
    program: Option<Program>,
    report: Option<RunReport>,
    errors: Vec<EclError>,
}

impl Engine {
    /// Discover artifacts and start from the default (Batel) node.
    pub fn new() -> Result<Self, EclError> {
        Ok(Self::with_registry(ArtifactRegistry::discover()?))
    }

    pub fn with_registry(registry: ArtifactRegistry) -> Self {
        Self {
            registry,
            node: NodeConfig::batel(),
            selected: Vec::new(),
            scheduler: SchedulerKind::static_default(),
            pipeline_depth: None,
            config: Configurator::default(),
            gws: None,
            lws: None,
            program: None,
            report: None,
            errors: Vec::new(),
        }
    }

    /// Select the simulated node (paper: the machine you run on).
    pub fn node(&mut self, node: NodeConfig) -> &mut Self {
        self.node = node;
        self
    }

    pub fn node_config(&self) -> &NodeConfig {
        &self.node
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Select devices by mask (paper: `engine.use(ecl::DeviceMask::CPU)`).
    pub fn use_mask(&mut self, mask: DeviceMask) -> &mut Self {
        self.selected = self
            .node
            .devices
            .iter()
            .enumerate()
            .filter(|(_, d)| mask.matches(d.kind))
            .map(|(i, _)| DeviceSpec::new(i))
            .collect();
        self
    }

    /// Select explicit devices, optionally with kernel specializations
    /// (paper Listing 2: `engine.use(Device(0,0), Device(0,1,phi_bin),..)`).
    pub fn use_devices(&mut self, devices: Vec<DeviceSpec>) -> &mut Self {
        self.selected = devices;
        self
    }

    pub fn global_work_items(&mut self, gws: usize) -> &mut Self {
        self.gws = Some(gws);
        self
    }

    pub fn local_work_items(&mut self, lws: usize) -> &mut Self {
        self.lws = Some(lws);
        self
    }

    /// Both sizes in one call (paper: `engine.work_items(gws, lws)`).
    pub fn work_items(&mut self, gws: usize, lws: usize) -> &mut Self {
        self.gws = Some(gws);
        self.lws = Some(lws);
        self
    }

    pub fn scheduler(&mut self, kind: SchedulerKind) -> &mut Self {
        self.scheduler = kind;
        self
    }

    /// Set the per-device package pipeline depth (Tier-1 access to the
    /// co-execution pipeline): `1` is the blocking assign-on-completion
    /// loop, `2` (the sweet spot) double-buffers — each device uploads
    /// package *n+1* while computing package *n* and never idles on the
    /// master's round-trip. Values are validated in `run()` against
    /// [`MAX_PIPELINE_DEPTH`]. Composes with every scheduler; equivalent
    /// to the `+pipe` scheduler-spec suffix.
    pub fn pipeline(&mut self, depth: usize) -> &mut Self {
        self.pipeline_depth = Some(depth);
        self
    }

    /// The pipeline depth `run()` will use: the Tier-1 override if set,
    /// else whatever the scheduler spec carries (1 = blocking).
    pub fn effective_pipeline_depth(&self) -> usize {
        self.pipeline_depth.unwrap_or_else(|| self.scheduler.pipeline_depth()).max(1)
    }

    /// Tier-2 access to runtime internals.
    pub fn configurator(&mut self) -> &mut Configurator {
        &mut self.config
    }

    /// Install a deterministic fault-injection plan for subsequent runs
    /// (chaos testing the recovery path) — Tier-1 sugar for
    /// `configurator().fault_plan`. Device indices in the plan refer to
    /// the *selected* device slots. Clear with
    /// `engine.configurator().fault_plan = None`.
    pub fn fault_plan(&mut self, plan: FaultPlan) -> &mut Self {
        self.config.fault_plan = Some(plan);
        self
    }

    /// Consume the program (paper: `engine.program(std::move(program))`).
    pub fn program(&mut self, program: Program) -> &mut Self {
        self.program = Some(program);
        self
    }

    pub fn has_errors(&self) -> bool {
        !self.errors.is_empty()
    }

    pub fn get_errors(&self) -> &[EclError] {
        &self.errors
    }

    /// Introspection data of the last run (paper's Configurator stats).
    pub fn report(&self) -> Option<&RunReport> {
        self.report.as_ref()
    }

    /// Computed output `i` of the last run.
    pub fn output(&self, i: usize) -> Option<&[f32]> {
        self.program.as_ref().and_then(|p| p.outputs().get(i)).map(|b| b.as_f32())
    }

    /// Run the program on the selected devices. Errors are both returned
    /// and collected on the engine (paper's error model).
    pub fn run(&mut self) -> Result<(), EclError> {
        match self.run_inner() {
            Ok(report) => {
                self.report = Some(report);
                Ok(())
            }
            Err(e) => {
                let msg = format!("{e}");
                self.errors.push(e);
                Err(EclError::Runtime(msg))
            }
        }
    }

    fn run_inner(&mut self) -> Result<RunReport, EclError> {
        let program = self.program.as_mut().ok_or(EclError::NoProgram)?;
        if self.selected.is_empty() {
            return Err(EclError::NoDevices);
        }
        let kernel = program.kernel_name().ok_or(EclError::NoProgram)?.to_string();
        let bench = self
            .registry
            .bench(&kernel)
            .map_err(|_| EclError::UnknownKernel(kernel.clone()))?
            .clone();

        // ---- validation (the checks OpenCL leaves to the programmer) --
        let gws = self.gws.unwrap_or(bench.n);
        if gws > bench.n {
            return Err(EclError::WorkSizeTooLarge { gws, n: bench.n });
        }
        if gws % bench.granule != 0 {
            return Err(EclError::MisalignedWorkSize { gws, granule: bench.granule });
        }
        if program.inputs().len() != bench.inputs.len() {
            return Err(EclError::InputArity {
                expected: bench.inputs.len(),
                got: program.inputs().len(),
            });
        }
        if program.outputs().len() != bench.outputs.len() {
            return Err(EclError::OutputArity {
                expected: bench.outputs.len(),
                got: program.outputs().len(),
            });
        }
        for (spec, buf) in bench.inputs.iter().zip(program.inputs()) {
            if buf.len() != spec.elems {
                return Err(EclError::BufferSize {
                    name: spec.name.clone(),
                    expected: spec.elems,
                    got: buf.len(),
                });
            }
        }
        for (spec, buf) in bench.outputs.iter().zip(program.outputs()) {
            if buf.len() != spec.elems {
                return Err(EclError::BufferSize {
                    name: spec.name.clone(),
                    expected: spec.elems,
                    got: buf.len(),
                });
            }
            // Validated *before* any buffer is moved into the arena: a
            // failure here must not destroy outputs already taken.
            if buf.host().as_f32().is_none() {
                return Err(EclError::Runtime(format!(
                    "output buffer '{}' must be f32",
                    spec.name
                )));
            }
            // The arena windows are item-addressed, so the manifest
            // geometry must be internally consistent before we commit
            // the program's buffers to it.
            if spec.elems != bench.n * spec.elems_per_item {
                return Err(EclError::Runtime(format!(
                    "manifest output '{}' inconsistent: {} elems for {} items x {} per item",
                    spec.name, spec.elems, bench.n, spec.elems_per_item
                )));
            }
        }
        if bench.granule == 0 || bench.n % bench.granule != 0 {
            return Err(EclError::Runtime(format!(
                "manifest geometry inconsistent: n={} granule={}",
                bench.n, bench.granule
            )));
        }
        validate_args(program.args(), &bench.scalars)?;
        if let SchedulerKind::Static { props: Some(p), .. } = self.scheduler.base() {
            if p.len() != self.selected.len() {
                return Err(EclError::BadProportions {
                    got: p.len(),
                    devices: self.selected.len(),
                });
            }
        }
        // A fault plan naming a device slot outside the selection would
        // silently never fire — the chaos run would "pass" without ever
        // exercising recovery. Reject it up front.
        if let Some(plan) = &self.config.fault_plan {
            for spec in &plan.faults {
                if spec.device >= self.selected.len() {
                    return Err(EclError::Runtime(format!(
                        "fault plan targets device slot {} but only {} device(s) are selected",
                        spec.device,
                        self.selected.len()
                    )));
                }
            }
        }
        // Field-precise equivalent of effective_pipeline_depth(): the
        // program borrow above outlives this whole function.
        let depth = match self.pipeline_depth {
            Some(d) => d,
            None => self.scheduler.pipeline_depth(),
        }
        .max(1);
        if depth > MAX_PIPELINE_DEPTH {
            return Err(EclError::BadPipelineDepth { depth, max: MAX_PIPELINE_DEPTH });
        }

        // ---- zero-copy buffer setup ------------------------------------
        // Inputs: one shared immutable view per program input (a single
        // O(N) materialization; every worker shares the allocation).
        let inputs: Vec<InputView> = input_views(program.inputs().iter().map(|b| b.host()))
            .map_err(|e| EclError::Runtime(format!("{e:#}")))?;
        // Outputs: move the program's buffers into the run's arena.
        // Workers claim disjoint granule-aligned windows and write
        // results in place; the buffers come back after the join. All
        // outputs were already validated f32 above, so this loop is
        // infallible — it can never abandon a half-taken program.
        let mut arena_bufs: Vec<(Vec<f32>, usize)> = Vec::with_capacity(bench.outputs.len());
        for (spec, out) in bench.outputs.iter().zip(program.outputs_mut()) {
            let data = out
                .host_mut()
                .as_f32_mut()
                .expect("outputs validated f32 above");
            arena_bufs.push((std::mem::take(data), spec.elems_per_item));
        }
        let arena = Arc::new(
            OutputArena::new(arena_bufs, bench.granule, bench.n)
                .map_err(|e| EclError::Runtime(format!("{e:#}")))?,
        );

        // ---- spawn device workers -------------------------------------
        let epoch = Instant::now();
        let has_cpu = self
            .selected
            .iter()
            .any(|s| self.node.devices[s.index].kind == DeviceKind::Cpu);
        let coexec = self.selected.len() > 1;

        let (to_master_tx, from_workers) = channel::<FromWorker>();
        let mut to_workers: Vec<Sender<ToWorker>> = Vec::new();
        let mut handles = Vec::new();
        let init_barrier = Arc::new(std::sync::Barrier::new(self.selected.len()));
        for (slot, spec) in self.selected.iter().enumerate() {
            let profile = self.node.devices[spec.index].clone();
            let contended = coexec
                && has_cpu
                && profile.kind == DeviceKind::Accelerator
                && self.config.simulate_init;
            let (tx, rx) = channel::<ToWorker>();
            to_workers.push(tx);
            let ctx = WorkerCtx {
                dev: slot,
                profile,
                registry: self.registry.clone(),
                bench: bench.clone(),
                inputs: inputs.clone(),
                arena: Arc::clone(&arena),
                config: self.config.clone(),
                epoch,
                contended_init: contended,
                init_barrier: Arc::clone(&init_barrier),
                pipeline_depth: depth,
                seed: 0x9E3779B9 + slot as u64 * 0x85EBCA77,
                injector: self
                    .config
                    .fault_plan
                    .as_ref()
                    .map(|p| p.injector_for(slot))
                    .unwrap_or_default(),
            };
            handles.push(spawn_worker(ctx, to_master_tx.clone(), rx));
        }
        drop(to_master_tx);

        // ---- master scheduling loop ------------------------------------
        let sched_devices: Vec<SchedDevice> = self
            .selected
            .iter()
            .map(|s| {
                let d = &self.node.devices[s.index];
                SchedDevice { name: d.name.clone(), power: d.relative_power }
            })
            .collect();
        let mut scheduler = self.scheduler.build();
        scheduler.start(gws / bench.granule, bench.granule, &sched_devices);

        let ndev = self.selected.len();
        let mut device_traces: Vec<DeviceTrace> = self
            .selected
            .iter()
            .map(|s| {
                let d = &self.node.devices[s.index];
                DeviceTrace {
                    name: d.name.clone(),
                    kind: d.kind,
                    init_start: Default::default(),
                    init_end: Default::default(),
                    packages: Vec::new(),
                    xfer: Default::default(),
                }
            })
            .collect();
        // Assignments whose H2D staging has not been confirmed by an
        // Uploaded event yet (pipelined devices only) are capped at 2:
        // one staging, one queued behind it — back-pressure so a device
        // with a slow bus is never flooded with un-staged ranges while
        // an adaptive scheduler could still size them better elsewhere.
        let staging_cap = if depth > 1 { 2 } else { usize::MAX };
        let mut master = MasterState {
            depth,
            staging_cap,
            granule: bench.granule,
            fault_tolerant: self.config.fault_tolerant,
            scheduler,
            to_workers,
            pending: vec![VecDeque::new(); ndev],
            unstaged: vec![0usize; ndev],
            finish_sent: vec![false; ndev],
            failed: vec![false; ndev],
            dry: vec![false; ndev],
            reclaimed: VecDeque::new(),
        };
        let mut reported = vec![false; ndev];
        let mut finished = 0usize;
        let mut failure: Option<EclError> = None;
        let mut faults: Vec<FaultEvent> = Vec::new();

        // How often the idle master sweeps for worker threads that died
        // without reporting (panics are caught and converted to Failed
        // events in the worker shell; the sweep catches *silent* exits —
        // the chaos layer's "vanish" mode, a segfaulting driver).
        const LIVENESS_POLL: Duration = Duration::from_millis(25);

        while finished < ndev {
            match from_workers.recv_timeout(LIVENESS_POLL) {
                Ok(ev) => handle_event(
                    ev,
                    &mut master,
                    arena.as_ref(),
                    &mut device_traces,
                    &mut reported,
                    &mut finished,
                    &mut faults,
                    &mut failure,
                    epoch,
                ),
                Err(err) => {
                    // Idle, or the channel died. Sweep for workers that
                    // exited without reporting. A disconnected channel
                    // means no worker can ever report again, so every
                    // unreported device is dead regardless of the (racy)
                    // thread-finished flag. Order matters: snapshot the
                    // exited-but-unreported workers *first*, then drain
                    // the channel — a worker that finished cleanly in
                    // the race window between the timeout and the
                    // snapshot sent its Finished/Failed *before* its
                    // thread exited, so the drain honors it; only what
                    // is still unreported after the drain is a genuine
                    // silent death.
                    let disconnected = err == RecvTimeoutError::Disconnected;
                    let dead: Vec<usize> = (0..ndev)
                        .filter(|&d| !reported[d] && (disconnected || handles[d].is_finished()))
                        .collect();
                    while let Ok(ev) = from_workers.try_recv() {
                        handle_event(
                            ev,
                            &mut master,
                            arena.as_ref(),
                            &mut device_traces,
                            &mut reported,
                            &mut finished,
                            &mut faults,
                            &mut failure,
                            epoch,
                        );
                    }
                    for dev in dead {
                        if !reported[dev] {
                            reported[dev] = true;
                            finished += 1;
                            register_failure(
                                &mut master,
                                arena.as_ref(),
                                &device_traces,
                                &mut faults,
                                &mut failure,
                                epoch,
                                dev,
                                "worker exited without reporting a result (dead channel)"
                                    .to_string(),
                            );
                        }
                    }
                }
            }
            // Fault-tolerant mode defers Finish until every range is
            // provably complete (see MasterState::finish_if_complete).
            master.finish_if_complete();
        }
        for h in handles {
            let _ = h.join();
        }

        // ---- recover the arena: results are already in place -----------
        // Every worker wrote its packages directly into disjoint arena
        // windows, so "collecting results" is handing the allocations
        // back to the program's containers — no merge, no copy. Done
        // before the failure return so partial results survive a worker
        // failure, matching the seed's semantics.
        match Arc::try_unwrap(arena) {
            Ok(arena) => {
                for (buf, out) in arena.into_buffers().into_iter().zip(program.outputs_mut()) {
                    out.store(HostBuf::F32(buf));
                }
            }
            Err(_) => {
                failure.get_or_insert(EclError::Runtime(
                    "output arena still shared after worker join".into(),
                ));
            }
        }
        if let Some(e) = failure {
            return Err(e);
        }

        // The label reflects the *effective* depth: a Tier-1
        // pipeline(1) override on a "+pipe" spec ran blocking, and vice
        // versa — harness pairings key off this suffix.
        let mut scheduler_label = master.scheduler.name();
        if depth > 1 && !scheduler_label.contains("+pipe") {
            scheduler_label.push_str("+pipe");
        } else if depth <= 1 && scheduler_label.ends_with("+pipe") {
            let len = scheduler_label.len() - "+pipe".len();
            scheduler_label.truncate(len);
        }
        Ok(RunReport {
            bench: bench.name.clone(),
            scheduler: scheduler_label,
            gws,
            wall: epoch.elapsed(),
            devices: device_traces,
            faults,
        })
    }
}

/// Recovery-aware assignment state for the master loop: per-device
/// in-flight ranges (what recovery must reclaim when a device dies),
/// staging back-pressure counters, and the shared queue of reclaimed
/// ranges that survivors drain before asking the scheduler.
struct MasterState {
    depth: usize,
    staging_cap: usize,
    granule: usize,
    fault_tolerant: bool,
    scheduler: Box<dyn Scheduler>,
    to_workers: Vec<Sender<ToWorker>>,
    /// Ranges assigned but not yet reported `Done`, per device, in
    /// execution (assignment) order.
    pending: Vec<VecDeque<Range>>,
    unstaged: Vec<usize>,
    finish_sent: Vec<bool>,
    failed: Vec<bool>,
    /// The scheduler returned `None` for this device (terminal, per the
    /// trait contract).
    dry: Vec<bool>,
    /// Reclaimed ranges awaiting requeue.
    reclaimed: VecDeque<Range>,
}

/// What `MasterState::handle_failure` did, for the fault event record.
struct FailureOutcome {
    reclaimed_items: usize,
    revoked_claims: usize,
    recovered: bool,
}

impl MasterState {
    fn ndev(&self) -> usize {
        self.pending.len()
    }

    fn next_scheduler_range(&mut self, dev: usize) -> Option<Range> {
        if self.dry[dev] {
            return None;
        }
        let r = self.scheduler.next_package(dev);
        if r.is_none() {
            self.dry[dev] = true;
        }
        r
    }

    /// The next range for `dev`: reclaimed (requeued) work first, then
    /// the scheduler. Returns the range plus its requeued flag.
    fn next_range(&mut self, dev: usize) -> Option<(Range, bool)> {
        if let Some(r) = self.reclaimed.pop_front() {
            return Some((r, true));
        }
        self.next_scheduler_range(dev).map(|r| (r, false))
    }

    /// Top device `dev`'s pipeline up to `depth` packages (and at most
    /// `staging_cap` unconfirmed stagings). The first message batches
    /// two ranges (range + lookahead) so a pipelined worker starts
    /// one-ahead off a single round-trip.
    fn top_up(&mut self, dev: usize) {
        if self.finish_sent[dev] || self.failed[dev] {
            return;
        }
        while self.pending[dev].len() < self.depth && self.unstaged[dev] < self.staging_cap {
            let Some((range, requeued)) = self.next_range(dev) else {
                // Legacy abort-on-failure mode finishes a device the
                // moment it runs dry (blocking workers only when idle;
                // pipelined workers drain their local queue). The
                // fault-tolerant loop instead defers Finish to
                // `finish_if_complete`: a later failure may still
                // requeue work onto this device.
                if !self.fault_tolerant && (self.pending[dev].is_empty() || self.depth > 1) {
                    self.to_workers[dev].send(ToWorker::Finish).ok();
                    self.finish_sent[dev] = true;
                }
                return;
            };
            self.pending[dev].push_back(range);
            if self.depth > 1 {
                self.unstaged[dev] += 1;
            }
            let lookahead = if self.depth > 1
                && self.pending[dev].len() < self.depth
                && self.unstaged[dev] < self.staging_cap
                && self.reclaimed.is_empty()
            {
                let next = self.next_scheduler_range(dev);
                if let Some(n) = next {
                    self.pending[dev].push_back(n);
                    self.unstaged[dev] += 1;
                }
                next
            } else {
                None
            };
            self.to_workers[dev]
                .send(ToWorker::Assign(Assignment { range, lookahead, requeued }))
                .ok();
        }
    }

    /// All work provably done: nothing reclaimed waits, nothing is in
    /// flight, and the scheduler is dry for every live device. Only
    /// then can no future failure surface new work (dead devices have
    /// nothing pending), so Finish is safe to broadcast.
    fn complete(&self) -> bool {
        self.reclaimed.is_empty()
            && self.pending.iter().all(|q| q.is_empty())
            && (0..self.ndev()).all(|d| self.failed[d] || self.dry[d])
    }

    /// Fault-tolerant finish: broadcast Finish to every live device
    /// once the run is complete. No-op in legacy mode (per-device
    /// Finish already happened in `top_up`).
    fn finish_if_complete(&mut self) {
        if !self.fault_tolerant || !self.complete() {
            return;
        }
        for dev in 0..self.ndev() {
            if !self.failed[dev] && !self.finish_sent[dev] {
                self.to_workers[dev].send(ToWorker::Finish).ok();
                self.finish_sent[dev] = true;
            }
        }
    }

    /// Device `dev`'s worker died. Reclaim its unfinished assignments
    /// plus any scheduler reservation, revoke their arena claims, and
    /// requeue the ranges — each split so every survivor can pull a
    /// piece (a Static share would otherwise land whole on a single
    /// survivor). Legacy mode reclaims nothing (abort semantics).
    fn handle_failure(&mut self, dev: usize, arena: &OutputArena) -> FailureOutcome {
        self.failed[dev] = true;
        let mut ranges: Vec<Range> = self.pending[dev].drain(..).collect();
        ranges.extend(self.scheduler.reclaim_device(dev));
        let reclaimed_items: usize = ranges.iter().map(Range::len).sum();
        if !self.fault_tolerant {
            return FailureOutcome { reclaimed_items, revoked_claims: 0, recovered: false };
        }
        let survivors = (0..self.ndev())
            .filter(|&d| !self.failed[d] && !self.finish_sent[d])
            .count();
        let recovered = reclaimed_items == 0 || survivors > 0;
        let mut revoked_claims = 0usize;
        for r in &ranges {
            // SAFETY: the failed worker has exited (liveness sweep) or
            // reported failure after dropping its windows on the error
            // path, so no live window covers any of these ranges.
            if unsafe { arena.revoke(r.begin, r.end) } {
                revoked_claims += 1;
            }
            if survivors > 0 {
                for piece in split_range(r.begin, r.end, survivors, self.granule) {
                    self.reclaimed.push_back(piece);
                }
            }
        }
        if !self.reclaimed.is_empty() {
            for d in 0..self.ndev() {
                if !self.failed[d] {
                    self.top_up(d);
                }
            }
        }
        FailureOutcome { reclaimed_items, revoked_claims, recovered }
    }
}

/// Fold one worker event into the master loop's state. Called from the
/// blocking receive and from the liveness sweep's channel drain (which
/// must process every already-sent event before declaring an exited
/// worker silently dead).
#[allow(clippy::too_many_arguments)]
fn handle_event(
    ev: FromWorker,
    master: &mut MasterState,
    arena: &OutputArena,
    device_traces: &mut [DeviceTrace],
    reported: &mut [bool],
    finished: &mut usize,
    faults: &mut Vec<FaultEvent>,
    failure: &mut Option<EclError>,
    epoch: Instant,
) {
    match ev {
        FromWorker::Ready { dev, init_start, init_end } => {
            device_traces[dev].init_start = init_start;
            device_traces[dev].init_end = init_end;
            master.top_up(dev);
        }
        FromWorker::Uploaded { dev } => {
            // A prefetch landed on the device: release its staging slot
            // and keep the pipe full.
            master.unstaged[dev] = master.unstaged[dev].saturating_sub(1);
            master.top_up(dev);
        }
        FromWorker::Done { dev } => {
            // Workers execute in assignment order, so the front pending
            // range is the completed one; its results are fully in the
            // arena by the time Done is sent.
            master.pending[dev].pop_front();
            master.top_up(dev);
        }
        FromWorker::Finished { dev, traces, xfer } => {
            device_traces[dev].packages = traces;
            device_traces[dev].xfer = xfer;
            if !reported[dev] {
                reported[dev] = true;
                *finished += 1;
            }
        }
        FromWorker::Failed { dev, message, traces, xfer } => {
            // The packages the worker *completed* stay attributed to it
            // — their results are already in the arena.
            device_traces[dev].packages = traces;
            device_traces[dev].xfer = xfer;
            if !reported[dev] {
                reported[dev] = true;
                *finished += 1;
                register_failure(
                    master,
                    arena,
                    device_traces,
                    faults,
                    failure,
                    epoch,
                    dev,
                    message,
                );
            }
        }
    }
}

/// Fold one worker failure into the master state: reclaim + requeue (or
/// record the abort), and append the introspector's fault event.
#[allow(clippy::too_many_arguments)]
fn register_failure(
    master: &mut MasterState,
    arena: &OutputArena,
    device_traces: &[DeviceTrace],
    faults: &mut Vec<FaultEvent>,
    failure: &mut Option<EclError>,
    epoch: Instant,
    dev: usize,
    message: String,
) {
    let outcome = master.handle_failure(dev, arena);
    if !outcome.recovered {
        failure.get_or_insert(EclError::Worker {
            device: device_traces[dev].name.clone(),
            message: message.clone(),
        });
    }
    faults.push(FaultEvent {
        device: dev,
        device_name: device_traces[dev].name.clone(),
        message,
        at: epoch.elapsed(),
        reclaimed_items: outcome.reclaimed_items,
        revoked_claims: outcome.revoked_claims,
        recovered: outcome.recovered,
    });
}

/// Validate recorded scalar args against the baked manifest scalars.
fn validate_args(args: &BTreeMap<usize, Arg>, scalars: &BTreeMap<String, f64>) -> Result<(), EclError> {
    let baked: Vec<(&String, &f64)> = scalars.iter().collect();
    let mut scalar_idx = 0usize;
    for (index, arg) in args {
        if let Arg::Scalar(v) = arg {
            // Scalars must match some baked value (AOT kernels cannot take
            // new scalar values at run time — the paper's JIT could).
            let matched = baked.iter().any(|(_, bv)| (*bv - v).abs() < 1e-9);
            if !matched {
                let (name, expected) = baked
                    .get(scalar_idx.min(baked.len().saturating_sub(1)))
                    .map(|(n, v)| ((*n).clone(), **v))
                    .unwrap_or(("<none>".into(), f64::NAN));
                return Err(EclError::ArgMismatch { index: *index, name, expected, got: *v });
            }
            scalar_idx += 1;
        }
    }
    if scalar_idx > scalars.len() {
        return Err(EclError::UnknownArg { index: scalar_idx });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_args_accepts_baked_values() {
        let mut scalars = BTreeMap::new();
        scalars.insert("steps".to_string(), 254.0);
        scalars.insert("dt".to_string(), 0.005);
        let mut args = BTreeMap::new();
        args.insert(0, Arg::Scalar(254.0));
        args.insert(1, Arg::BufferRef);
        args.insert(2, Arg::LocalAlloc(1024));
        assert!(validate_args(&args, &scalars).is_ok());
    }

    #[test]
    fn validate_args_rejects_unbaked_scalar() {
        let mut scalars = BTreeMap::new();
        scalars.insert("steps".to_string(), 254.0);
        let mut args = BTreeMap::new();
        args.insert(0, Arg::Scalar(100.0));
        let err = validate_args(&args, &scalars).unwrap_err();
        assert!(matches!(err, EclError::ArgMismatch { .. }));
    }

    #[test]
    fn pipeline_depth_resolution() {
        let mut e = Engine::with_registry(ArtifactRegistry::synthetic());
        assert_eq!(e.effective_pipeline_depth(), 1, "blocking by default");
        e.scheduler(SchedulerKind::hguided().pipelined(2));
        assert_eq!(e.effective_pipeline_depth(), 2, "scheduler spec carries depth");
        e.pipeline(3);
        assert_eq!(e.effective_pipeline_depth(), 3, "Tier-1 override wins");
        e.pipeline(0);
        assert_eq!(e.effective_pipeline_depth(), 1, "clamped to >= 1");
    }

    #[test]
    fn oversized_pipeline_depth_rejected() {
        let reg = ArtifactRegistry::synthetic();
        let mut e = Engine::with_registry(reg.clone());
        e.use_devices(vec![DeviceSpec::new(0)]);
        e.pipeline(MAX_PIPELINE_DEPTH + 1);
        let bench = reg.bench("binomial").unwrap().clone();
        let mut p = Program::new();
        p.kernel("binomial", &bench.kernel);
        for buf in reg.golden_inputs(&bench).unwrap() {
            p.input(buf.as_f32().unwrap().to_vec());
        }
        p.output(bench.outputs[0].elems);
        e.program(p);
        assert!(e.run().is_err());
        assert!(matches!(e.get_errors()[0], EclError::BadPipelineDepth { .. }));
    }
}
