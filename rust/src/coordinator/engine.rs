//! `Engine` — the Tier-1 facade (paper Figure 4): device selection, work
//! sizes, scheduler choice, program consumption and `run()`.
//!
//! `run()` spawns one worker thread per selected device, drives the
//! master scheduling loop (assign-on-completion, the paper's Scheduler
//! thread), merges the disjoint result ranges back into the program's
//! output containers and leaves a full `RunReport` for introspection.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::config::Configurator;
use crate::coordinator::device::{
    spawn_worker, DeviceMask, DeviceSpec, FromWorker, ToWorker, WorkerCtx,
};
use crate::coordinator::error::EclError;
use crate::coordinator::introspector::{DeviceTrace, RunReport};
use crate::coordinator::program::{Arg, Program};
use crate::coordinator::scheduler::{SchedDevice, SchedulerKind};
use crate::platform::{DeviceKind, NodeConfig};
use crate::runtime::{ArtifactRegistry, HostBuf};

/// The paper's `ecl::EngineCL`.
pub struct Engine {
    registry: ArtifactRegistry,
    node: NodeConfig,
    selected: Vec<DeviceSpec>,
    scheduler: SchedulerKind,
    config: Configurator,
    gws: Option<usize>,
    lws: Option<usize>,
    program: Option<Program>,
    report: Option<RunReport>,
    errors: Vec<EclError>,
}

impl Engine {
    /// Discover artifacts and start from the default (Batel) node.
    pub fn new() -> Result<Self, EclError> {
        Ok(Self::with_registry(ArtifactRegistry::discover()?))
    }

    pub fn with_registry(registry: ArtifactRegistry) -> Self {
        Self {
            registry,
            node: NodeConfig::batel(),
            selected: Vec::new(),
            scheduler: SchedulerKind::static_default(),
            config: Configurator::default(),
            gws: None,
            lws: None,
            program: None,
            report: None,
            errors: Vec::new(),
        }
    }

    /// Select the simulated node (paper: the machine you run on).
    pub fn node(&mut self, node: NodeConfig) -> &mut Self {
        self.node = node;
        self
    }

    pub fn node_config(&self) -> &NodeConfig {
        &self.node
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Select devices by mask (paper: `engine.use(ecl::DeviceMask::CPU)`).
    pub fn use_mask(&mut self, mask: DeviceMask) -> &mut Self {
        self.selected = self
            .node
            .devices
            .iter()
            .enumerate()
            .filter(|(_, d)| mask.matches(d.kind))
            .map(|(i, _)| DeviceSpec::new(i))
            .collect();
        self
    }

    /// Select explicit devices, optionally with kernel specializations
    /// (paper Listing 2: `engine.use(Device(0,0), Device(0,1,phi_bin),..)`).
    pub fn use_devices(&mut self, devices: Vec<DeviceSpec>) -> &mut Self {
        self.selected = devices;
        self
    }

    pub fn global_work_items(&mut self, gws: usize) -> &mut Self {
        self.gws = Some(gws);
        self
    }

    pub fn local_work_items(&mut self, lws: usize) -> &mut Self {
        self.lws = Some(lws);
        self
    }

    /// Both sizes in one call (paper: `engine.work_items(gws, lws)`).
    pub fn work_items(&mut self, gws: usize, lws: usize) -> &mut Self {
        self.gws = Some(gws);
        self.lws = Some(lws);
        self
    }

    pub fn scheduler(&mut self, kind: SchedulerKind) -> &mut Self {
        self.scheduler = kind;
        self
    }

    /// Tier-2 access to runtime internals.
    pub fn configurator(&mut self) -> &mut Configurator {
        &mut self.config
    }

    /// Consume the program (paper: `engine.program(std::move(program))`).
    pub fn program(&mut self, program: Program) -> &mut Self {
        self.program = Some(program);
        self
    }

    pub fn has_errors(&self) -> bool {
        !self.errors.is_empty()
    }

    pub fn get_errors(&self) -> &[EclError] {
        &self.errors
    }

    /// Introspection data of the last run (paper's Configurator stats).
    pub fn report(&self) -> Option<&RunReport> {
        self.report.as_ref()
    }

    /// Computed output `i` of the last run.
    pub fn output(&self, i: usize) -> Option<&[f32]> {
        self.program.as_ref().and_then(|p| p.outputs().get(i)).map(|b| b.as_f32())
    }

    /// Run the program on the selected devices. Errors are both returned
    /// and collected on the engine (paper's error model).
    pub fn run(&mut self) -> Result<(), EclError> {
        match self.run_inner() {
            Ok(report) => {
                self.report = Some(report);
                Ok(())
            }
            Err(e) => {
                let msg = format!("{e}");
                self.errors.push(e);
                Err(EclError::Runtime(msg))
            }
        }
    }

    fn run_inner(&mut self) -> Result<RunReport, EclError> {
        let program = self.program.as_mut().ok_or(EclError::NoProgram)?;
        if self.selected.is_empty() {
            return Err(EclError::NoDevices);
        }
        let kernel = program.kernel_name().ok_or(EclError::NoProgram)?.to_string();
        let bench = self
            .registry
            .bench(&kernel)
            .map_err(|_| EclError::UnknownKernel(kernel.clone()))?
            .clone();

        // ---- validation (the checks OpenCL leaves to the programmer) --
        let gws = self.gws.unwrap_or(bench.n);
        if gws > bench.n {
            return Err(EclError::WorkSizeTooLarge { gws, n: bench.n });
        }
        if gws % bench.granule != 0 {
            return Err(EclError::MisalignedWorkSize { gws, granule: bench.granule });
        }
        if program.inputs().len() != bench.inputs.len() {
            return Err(EclError::InputArity {
                expected: bench.inputs.len(),
                got: program.inputs().len(),
            });
        }
        if program.outputs().len() != bench.outputs.len() {
            return Err(EclError::OutputArity {
                expected: bench.outputs.len(),
                got: program.outputs().len(),
            });
        }
        for (spec, buf) in bench.inputs.iter().zip(program.inputs()) {
            if buf.len() != spec.elems {
                return Err(EclError::BufferSize {
                    name: spec.name.clone(),
                    expected: spec.elems,
                    got: buf.len(),
                });
            }
        }
        for (spec, buf) in bench.outputs.iter().zip(program.outputs()) {
            if buf.len() != spec.elems {
                return Err(EclError::BufferSize {
                    name: spec.name.clone(),
                    expected: spec.elems,
                    got: buf.len(),
                });
            }
        }
        validate_args(program.args(), &bench.scalars)?;
        if let SchedulerKind::Static { props: Some(p), .. } = &self.scheduler {
            if p.len() != self.selected.len() {
                return Err(EclError::BadProportions {
                    got: p.len(),
                    devices: self.selected.len(),
                });
            }
        }

        // ---- spawn device workers -------------------------------------
        let inputs: Arc<Vec<HostBuf>> =
            Arc::new(program.inputs().iter().map(|b| b.host().clone()).collect());
        let epoch = Instant::now();
        let exec_lock = Arc::new(Mutex::new(()));
        let has_cpu = self
            .selected
            .iter()
            .any(|s| self.node.devices[s.index].kind == DeviceKind::Cpu);
        let coexec = self.selected.len() > 1;

        let (to_master_tx, from_workers) = channel::<FromWorker>();
        let mut to_workers: Vec<Sender<ToWorker>> = Vec::new();
        let mut handles = Vec::new();
        let init_barrier = Arc::new(std::sync::Barrier::new(self.selected.len()));
        for (slot, spec) in self.selected.iter().enumerate() {
            let profile = self.node.devices[spec.index].clone();
            let contended = coexec
                && has_cpu
                && profile.kind == DeviceKind::Accelerator
                && self.config.simulate_init;
            let (tx, rx) = channel::<ToWorker>();
            to_workers.push(tx);
            let ctx = WorkerCtx {
                dev: slot,
                profile,
                registry: self.registry.clone(),
                bench: bench.clone(),
                inputs: Arc::clone(&inputs),
                config: self.config.clone(),
                epoch,
                exec_lock: Arc::clone(&exec_lock),
                contended_init: contended,
                init_barrier: Arc::clone(&init_barrier),
                seed: 0x9E3779B9 + slot as u64 * 0x85EBCA77,
            };
            handles.push(spawn_worker(ctx, to_master_tx.clone(), rx));
        }
        drop(to_master_tx);

        // ---- master scheduling loop ------------------------------------
        let sched_devices: Vec<SchedDevice> = self
            .selected
            .iter()
            .map(|s| {
                let d = &self.node.devices[s.index];
                SchedDevice { name: d.name.clone(), power: d.relative_power }
            })
            .collect();
        let mut scheduler = self.scheduler.build();
        scheduler.start(gws / bench.granule, bench.granule, &sched_devices);

        let ndev = self.selected.len();
        let mut device_traces: Vec<DeviceTrace> = self
            .selected
            .iter()
            .map(|s| {
                let d = &self.node.devices[s.index];
                DeviceTrace {
                    name: d.name.clone(),
                    kind: d.kind,
                    init_start: Default::default(),
                    init_end: Default::default(),
                    packages: Vec::new(),
                }
            })
            .collect();
        let mut worker_outputs: Vec<Option<Vec<HostBuf>>> = (0..ndev).map(|_| None).collect();
        let mut finished = 0usize;
        let mut failure: Option<EclError> = None;

        let assign = |dev: usize, scheduler: &mut Box<dyn crate::coordinator::scheduler::Scheduler>,
                          to_workers: &[Sender<ToWorker>]| {
            match scheduler.next_package(dev) {
                Some(range) => {
                    to_workers[dev].send(ToWorker::Assign(range)).ok();
                }
                None => {
                    to_workers[dev].send(ToWorker::Finish).ok();
                }
            }
        };

        while finished < ndev {
            match from_workers.recv() {
                Ok(FromWorker::Ready { dev, init_start, init_end }) => {
                    device_traces[dev].init_start = init_start;
                    device_traces[dev].init_end = init_end;
                    assign(dev, &mut scheduler, &to_workers);
                }
                Ok(FromWorker::Done { dev }) => {
                    assign(dev, &mut scheduler, &to_workers);
                }
                Ok(FromWorker::Finished { dev, outputs, traces }) => {
                    device_traces[dev].packages = traces;
                    worker_outputs[dev] = Some(outputs);
                    finished += 1;
                }
                Ok(FromWorker::Failed { dev, message }) => {
                    failure.get_or_insert(EclError::Worker {
                        device: device_traces[dev].name.clone(),
                        message,
                    });
                    finished += 1;
                }
                Err(_) => break,
            }
        }
        for h in handles {
            let _ = h.join();
        }
        if let Some(e) = failure {
            return Err(e);
        }

        // ---- merge disjoint result ranges back into the program --------
        for (dev, outs) in worker_outputs.into_iter().enumerate() {
            let Some(outs) = outs else { continue };
            let ranges: Vec<(usize, usize)> = device_traces[dev]
                .packages
                .iter()
                .map(|p| (p.begin_item, p.end_item))
                .collect();
            for ((src, spec), dst) in
                outs.iter().zip(&bench.outputs).zip(program.outputs_mut())
            {
                let src = src.as_f32().expect("worker outputs are f32");
                let dst = dst.host_mut().as_f32_mut().expect("program outputs are f32");
                for &(b, e) in &ranges {
                    let lo = b * spec.elems_per_item;
                    let hi = e * spec.elems_per_item;
                    dst[lo..hi].copy_from_slice(&src[lo..hi]);
                }
            }
        }

        Ok(RunReport {
            bench: bench.name.clone(),
            scheduler: scheduler.name(),
            gws,
            wall: epoch.elapsed(),
            devices: device_traces,
        })
    }
}

/// Validate recorded scalar args against the baked manifest scalars.
fn validate_args(args: &BTreeMap<usize, Arg>, scalars: &BTreeMap<String, f64>) -> Result<(), EclError> {
    let baked: Vec<(&String, &f64)> = scalars.iter().collect();
    let mut scalar_idx = 0usize;
    for (index, arg) in args {
        if let Arg::Scalar(v) = arg {
            // Scalars must match some baked value (AOT kernels cannot take
            // new scalar values at run time — the paper's JIT could).
            let matched = baked.iter().any(|(_, bv)| (*bv - v).abs() < 1e-9);
            if !matched {
                let (name, expected) = baked
                    .get(scalar_idx.min(baked.len().saturating_sub(1)))
                    .map(|(n, v)| ((*n).clone(), **v))
                    .unwrap_or(("<none>".into(), f64::NAN));
                return Err(EclError::ArgMismatch { index: *index, name, expected, got: *v });
            }
            scalar_idx += 1;
        }
    }
    if scalar_idx > scalars.len() {
        return Err(EclError::UnknownArg { index: scalar_idx });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_args_accepts_baked_values() {
        let mut scalars = BTreeMap::new();
        scalars.insert("steps".to_string(), 254.0);
        scalars.insert("dt".to_string(), 0.005);
        let mut args = BTreeMap::new();
        args.insert(0, Arg::Scalar(254.0));
        args.insert(1, Arg::BufferRef);
        args.insert(2, Arg::LocalAlloc(1024));
        assert!(validate_args(&args, &scalars).is_ok());
    }

    #[test]
    fn validate_args_rejects_unbaked_scalar() {
        let mut scalars = BTreeMap::new();
        scalars.insert("steps".to_string(), 254.0);
        let mut args = BTreeMap::new();
        args.insert(0, Arg::Scalar(100.0));
        let err = validate_args(&args, &scalars).unwrap_err();
        assert!(matches!(err, EclError::ArgMismatch { .. }));
    }
}
