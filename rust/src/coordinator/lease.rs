//! Device lease arbiter — how concurrent run sessions share the node.
//!
//! A persistent runtime admits many [`RunSession`](crate::coordinator::runtime::RunSession)s
//! at once, but a simulated device can only honestly execute one
//! session's package at a time (the workers' simclock holds are
//! wall-clock sleeps — two sessions occupying one device simultaneously
//! would simulate a device twice as fast as its profile). The arbiter is
//! the enforcement point: every device worker must hold that device's
//! *lease* for the whole occupancy window of a package (staging +
//! compute + simulated hold) and release it between packages, so
//! concurrent sessions interleave per package window across the device
//! set instead of overlapping on one device.
//!
//! # Sharding
//!
//! The arbiter is sharded per device slot: one `Mutex<DeviceState>` +
//! `Condvar` pair per device. Every lease operation — register, park,
//! acquire, release, deregister — touches exactly one device's state,
//! so the shard lock is the natural unit of mutual exclusion and an
//! 8-session soak hammering device 2 never serializes (or spuriously
//! wakes) waiters on device 0. The only cross-device state is two
//! atomics: the token allocator and the global grant `serial`, bumped
//! under the granting shard's lock so each device's journal slice stays
//! strictly serial-ordered. [`LeaseArbiter::journal`] merges the
//! per-shard journals by serial on read; per-device grant subsequences
//! (what rotation pins and the golden tests assert) are exactly what a
//! single global journal would record, and cross-device interleaving is
//! wall-clock grant order as before.
//!
//! # Participants, not sessions
//!
//! Registration is per *worker* (a `(session, device)` pair), keyed by a
//! unique token — a session that selects the same node device twice gets
//! two independent participants. Registration is RAII
//! ([`DeviceRegistration`]): when a worker exits — cleanly, by error, by
//! a caught panic, or by the chaos layer's silent *vanish* — its
//! registration drops and the arbiter forgets it, so a dead session can
//! never hold a turn (or a lease: [`LeaseGuard`] is RAII too) hostage.
//!
//! # Policies
//!
//! * [`LeasePolicy::Rotation`] (default) — deterministic turn-taking:
//!   each device cycles through its registered participants in
//!   registration order (= admission order, since the runtime registers
//!   whole batches under one lock). The device *waits* for the
//!   turn-holder rather than leapfrogging it, so the grant sequence is a
//!   pure function of each session's own request/park/deregister
//!   sequence — never of wall-clock arrival races. That is what makes
//!   concurrent golden-trace tests reproducible. The cost is utilization:
//!   a device can idle while a slow turn-holder initializes.
//!
//!   To keep turn-taking deadlock-free with the fault-tolerant engine
//!   (which holds dry devices open in case a failure requeues work), a
//!   session's master *parks* a participant that provably has nothing to
//!   request (scheduler dry, nothing in flight, nothing reclaimed);
//!   parked participants are skipped by the rotation and un-parked the
//!   moment work is assigned to them again. Parking can only delay a
//!   grant decision (the rotation waits, then skips), never reorder it.
//!
//! * [`LeasePolicy::Fifo`] — first-come-first-served ticket queue:
//!   maximal utilization (a free device goes to whoever asked first),
//!   starvation-free, but contended grant order follows wall-clock
//!   arrival and is not reproducible across executions.
//!
//! Every grant is appended to the granting shard's journal
//! ([`GrantRecord`]) — the observable the concurrency battery uses to
//! pin interleavings. Hot asserts that only need cardinality should use
//! [`LeaseArbiter::journal_len`] / [`LeaseArbiter::registered_count`]
//! instead of the snapshot accessors, which pay an O(n) copy.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Identifies one admitted run session within a runtime.
pub type SessionId = u64;

/// How a device arbitrates between sessions competing for it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeasePolicy {
    /// Deterministic round-robin turn-taking over registered
    /// participants (skipping parked ones). Reproducible interleavings;
    /// a device may idle waiting for its turn-holder.
    Rotation,
    /// First-come-first-served ticket queue. Maximal utilization;
    /// contended grant order follows wall-clock arrival.
    Fifo,
}

/// One granted lease, in global grant order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GrantRecord {
    /// Global grant sequence number (across all devices).
    pub serial: u64,
    /// Node device index.
    pub device: usize,
    pub session: SessionId,
}

#[derive(Debug)]
struct Entry {
    token: u64,
    session: SessionId,
    /// Parked participants provably have nothing to request and are
    /// skipped by the rotation until un-parked.
    parked: bool,
}

#[derive(Debug, Default)]
struct DeviceState {
    /// Participants in registration order (the rotation order).
    entries: Vec<Entry>,
    /// Index into `entries` of the participant whose turn it is.
    turn: usize,
    /// Token currently holding the device, if any.
    holder: Option<u64>,
    /// Waiting tokens in arrival order (Fifo policy only).
    queue: VecDeque<u64>,
    grants: u64,
    /// This device's slice of the grant journal (strictly
    /// serial-ordered: serials are allocated under this shard's lock).
    journal: Vec<GrantRecord>,
}

impl DeviceState {
    /// Advance `turn` past parked entries (at most one full cycle; if
    /// every entry is parked the cursor stays put — nothing is eligible
    /// until an un-park or a new registration).
    fn normalize(&mut self) {
        let n = self.entries.len();
        if n == 0 {
            self.turn = 0;
            return;
        }
        if self.turn >= n {
            self.turn = 0;
        }
        for _ in 0..n {
            if !self.entries[self.turn].parked {
                return;
            }
            self.turn = (self.turn + 1) % n;
        }
    }

    fn position(&self, token: u64) -> Option<usize> {
        self.entries.iter().position(|e| e.token == token)
    }
}

/// One device slot's lock + wait queue. Waiters for a device park on
/// its own condvar, so grants and releases elsewhere never wake them.
#[derive(Debug, Default)]
struct Shard {
    state: Mutex<DeviceState>,
    cv: Condvar,
}

/// The shared arbiter. One per runtime (and one per solo `Engine::run`,
/// where its single registered session makes every acquire immediate).
#[derive(Debug)]
pub struct LeaseArbiter {
    policy: LeasePolicy,
    shards: Vec<Shard>,
    /// Global grant sequence. Bumped under the granting shard's lock,
    /// so each shard's journal slice is strictly serial-ordered and the
    /// merged journal reconstructs the global grant order.
    serial: AtomicU64,
    /// Participant token allocator (tokens are globally unique).
    next_token: AtomicU64,
}

impl LeaseArbiter {
    pub fn new(devices: usize, policy: LeasePolicy) -> Arc<Self> {
        Arc::new(Self {
            policy,
            shards: (0..devices).map(|_| Shard::default()).collect(),
            serial: AtomicU64::new(0),
            next_token: AtomicU64::new(1),
        })
    }

    /// Poison-tolerant shard lock: the arbiter's critical sections never
    /// panic, but RAII releases run during *worker* unwinds (injected
    /// panics) and must never double-panic.
    fn shard(&self, device: usize) -> MutexGuard<'_, DeviceState> {
        self.shards[device].state.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn policy(&self) -> LeasePolicy {
        self.policy
    }

    pub fn device_count(&self) -> usize {
        self.shards.len()
    }

    /// Register a participant (one worker of `session`) on `device`.
    /// Registration order is the rotation order; the runtime registers
    /// admitted batches under one lock so it equals admission order.
    pub fn register(self: &Arc<Self>, device: usize, session: SessionId) -> DeviceRegistration {
        let token = self.next_token.fetch_add(1, Ordering::Relaxed);
        self.shard(device).entries.push(Entry { token, session, parked: false });
        self.shards[device].cv.notify_all();
        DeviceRegistration { arb: Arc::clone(self), device, session, token }
    }

    /// Session currently holding `device`'s lease.
    pub fn holder(&self, device: usize) -> Option<SessionId> {
        let d = self.shard(device);
        d.holder.and_then(|t| d.entries.iter().find(|e| e.token == t).map(|e| e.session))
    }

    /// Sessions registered on `device`, in rotation order (snapshot:
    /// clones the entry list — prefer [`Self::registered_count`] when
    /// only the cardinality matters).
    pub fn registered_sessions(&self, device: usize) -> Vec<SessionId> {
        self.shard(device).entries.iter().map(|e| e.session).collect()
    }

    /// Number of participants registered on `device` — O(1), no clone.
    /// The hot path for contention estimates (the QoS predictor prices
    /// every queued session with it).
    pub fn registered_count(&self, device: usize) -> usize {
        self.shard(device).entries.len()
    }

    /// Leases granted on `device` so far.
    pub fn grant_count(&self, device: usize) -> u64 {
        self.shard(device).grants
    }

    /// Total grants across all devices — O(devices), no journal copy.
    pub fn journal_len(&self) -> usize {
        (0..self.shards.len()).map(|d| self.shard(d).journal.len()).sum()
    }

    /// The global grant journal (all devices, merged by grant serial).
    /// This is a snapshot accessor that copies every record — meant for
    /// test assertions and post-run reporting, not hot paths.
    pub fn journal(&self) -> Vec<GrantRecord> {
        let mut out: Vec<GrantRecord> = Vec::new();
        for d in 0..self.shards.len() {
            out.extend(self.shard(d).journal.iter().copied());
        }
        out.sort_unstable_by_key(|g| g.serial);
        out
    }

    /// Grants of `session` only, in grant order.
    pub fn journal_for(&self, session: SessionId) -> Vec<GrantRecord> {
        let mut out: Vec<GrantRecord> = Vec::new();
        for d in 0..self.shards.len() {
            out.extend(self.shard(d).journal.iter().filter(|g| g.session == session).copied());
        }
        out.sort_unstable_by_key(|g| g.serial);
        out
    }

    /// Mark a participant as having provably nothing to request
    /// (`parked = true`) or as active again. Called by session masters;
    /// un-parking always precedes the assignment that makes the worker
    /// request again, so a parked turn-holder can never be waited on.
    pub(crate) fn set_parked(&self, device: usize, token: u64, parked: bool) {
        {
            let mut d = self.shard(device);
            if let Some(pos) = d.position(token) {
                if d.entries[pos].parked != parked {
                    d.entries[pos].parked = parked;
                    if self.policy == LeasePolicy::Rotation {
                        d.normalize();
                    }
                }
            }
        }
        self.shards[device].cv.notify_all();
    }

    fn acquire_token(&self, device: usize, token: u64, session: SessionId) {
        let mut d = self.shard(device);
        // A request is intent: a participant that asks again while
        // parked (defensive — masters un-park before assigning)
        // re-enters the rotation.
        if let Some(pos) = d.position(token) {
            if d.entries[pos].parked {
                d.entries[pos].parked = false;
            }
        }
        if self.policy == LeasePolicy::Fifo {
            d.queue.push_back(token);
        }
        loop {
            let eligible = if d.holder.is_some() {
                false
            } else {
                match self.policy {
                    LeasePolicy::Rotation => {
                        d.normalize();
                        match d.entries.get(d.turn) {
                            Some(e) => e.token == token,
                            // Defensive: an unregistered acquire on
                            // an otherwise-empty device proceeds.
                            None => true,
                        }
                    }
                    LeasePolicy::Fifo => d.queue.front() == Some(&token),
                }
            };
            if eligible {
                d.holder = Some(token);
                d.grants += 1;
                if self.policy == LeasePolicy::Fifo {
                    d.queue.pop_front();
                }
                let serial = self.serial.fetch_add(1, Ordering::Relaxed);
                d.journal.push(GrantRecord { serial, device, session });
                return;
            }
            d = self.shards[device].cv.wait(d).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn release_token(&self, device: usize, token: u64) {
        {
            let mut d = self.shard(device);
            if d.holder == Some(token) {
                d.holder = None;
                if self.policy == LeasePolicy::Rotation {
                    // The releasing participant's window is over: the
                    // turn moves to the next registered entry.
                    if let Some(pos) = d.position(token) {
                        d.turn = (pos + 1) % d.entries.len().max(1);
                    }
                    d.normalize();
                }
            }
        }
        self.shards[device].cv.notify_all();
    }

    fn deregister_token(&self, device: usize, token: u64) {
        {
            let mut d = self.shard(device);
            if d.holder == Some(token) {
                // Defensive: a registration should outlive its guards,
                // but a dying worker must never strand the device.
                d.holder = None;
            }
            if let Some(pos) = d.position(token) {
                d.entries.remove(pos);
                if pos < d.turn {
                    d.turn -= 1;
                }
                d.normalize();
            }
            d.queue.retain(|t| *t != token);
        }
        self.shards[device].cv.notify_all();
    }
}

/// A worker's registration on one device. Dropping it (worker exit —
/// clean or not) removes the participant from the rotation and releases
/// any lease it still holds, which is how leases are reclaimed when a
/// session's device is killed by a fault plan.
#[derive(Debug)]
pub struct DeviceRegistration {
    arb: Arc<LeaseArbiter>,
    device: usize,
    session: SessionId,
    token: u64,
}

impl DeviceRegistration {
    pub fn device(&self) -> usize {
        self.device
    }

    pub fn session(&self) -> SessionId {
        self.session
    }

    /// Opaque participant token (what masters pass to `set_parked`).
    pub fn token(&self) -> u64 {
        self.token
    }

    /// Block until this participant is granted the device, covering one
    /// package occupancy window. Release by dropping the guard.
    pub fn acquire(&self) -> LeaseGuard {
        self.arb.acquire_token(self.device, self.token, self.session);
        LeaseGuard {
            arb: Arc::clone(&self.arb),
            device: self.device,
            token: self.token,
        }
    }
}

impl Drop for DeviceRegistration {
    fn drop(&mut self) {
        self.arb.deregister_token(self.device, self.token);
    }
}

/// A held whole-device lease for one package window (RAII release).
#[derive(Debug)]
pub struct LeaseGuard {
    arb: Arc<LeaseArbiter>,
    device: usize,
    token: u64,
}

impl Drop for LeaseGuard {
    fn drop(&mut self) {
        self.arb.release_token(self.device, self.token);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    #[test]
    fn solo_session_always_granted() {
        let arb = LeaseArbiter::new(2, LeasePolicy::Rotation);
        let reg = arb.register(0, 7);
        for _ in 0..3 {
            let g = reg.acquire();
            assert_eq!(arb.holder(0), Some(7));
            drop(g);
            assert_eq!(arb.holder(0), None);
        }
        assert_eq!(arb.grant_count(0), 3);
        assert_eq!(arb.grant_count(1), 0);
        let j = arb.journal();
        assert_eq!(j.len(), 3);
        assert!(j.iter().all(|g| g.device == 0 && g.session == 7));
        assert_eq!(j[0].serial, 0);
        assert_eq!(j[2].serial, 2);
    }

    #[test]
    fn rotation_alternates_in_registration_order() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 1);
        let b = arb.register(0, 2);
        // a leads (registered first); after each release the turn moves
        // to the next participant, so windows strictly alternate.
        drop(a.acquire());
        drop(b.acquire());
        drop(a.acquire());
        drop(b.acquire());
        let sessions: Vec<SessionId> = arb.journal().iter().map(|g| g.session).collect();
        assert_eq!(sessions, vec![1, 2, 1, 2]);
    }

    #[test]
    fn rotation_skips_parked_participants() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 1);
        let b = arb.register(0, 2);
        // Park a: b can acquire repeatedly without waiting for a.
        arb.set_parked(0, a.token(), true);
        for _ in 0..3 {
            drop(b.acquire());
        }
        // Un-park a: it gets the next turn after b's window.
        arb.set_parked(0, a.token(), false);
        drop(b.acquire());
        drop(a.acquire());
        let sessions: Vec<SessionId> = arb.journal().iter().map(|g| g.session).collect();
        assert_eq!(sessions, vec![2, 2, 2, 2, 1]);
        drop(a);
        drop(b);
        assert!(arb.registered_sessions(0).is_empty());
    }

    #[test]
    fn deregistration_unblocks_the_rotation() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 1);
        let b = arb.register(0, 2);
        drop(a.acquire()); // turn -> b
        drop(b); // b exits without ever acquiring
        // a can immediately go again — the rotation skips the ghost.
        drop(a.acquire());
        assert_eq!(arb.grant_count(0), 2);
        assert_eq!(arb.registered_sessions(0), vec![1]);
    }

    #[test]
    fn dropped_registration_releases_held_lease() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 1);
        let g = a.acquire();
        assert_eq!(arb.holder(0), Some(1));
        // Worker death drops both, guard first in a real unwind; the
        // reverse (defensive) order must also leave the device free.
        drop(a);
        assert_eq!(arb.holder(0), None);
        drop(g); // releasing a deregistered token is a no-op
        assert_eq!(arb.holder(0), None);
    }

    /// Mutual exclusion under a many-thread hammer, both policies: at
    /// most one holder per device at any instant, and every requester
    /// eventually completes all its windows (no starvation).
    ///
    /// Participation contract per policy: under Rotation a registered
    /// participant must keep requesting (or park/deregister) — the
    /// engine's masters guarantee that via parking — so each hammer
    /// thread registers on exactly one device and requests it until it
    /// deregisters. Fifo has no turns, so threads may roam devices.
    #[test]
    fn mutual_exclusion_and_progress_under_contention() {
        for policy in [LeasePolicy::Rotation, LeasePolicy::Fifo] {
            let ndev = 2;
            let nthreads = 5;
            let rounds = 20;
            let arb = LeaseArbiter::new(ndev, policy);
            let busy: Arc<Vec<AtomicBool>> =
                Arc::new((0..ndev).map(|_| AtomicBool::new(false)).collect());
            let completed = Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for t in 0..nthreads {
                let regs: Vec<DeviceRegistration> = match policy {
                    LeasePolicy::Rotation => vec![arb.register(t % ndev, t as SessionId)],
                    LeasePolicy::Fifo => {
                        (0..ndev).map(|d| arb.register(d, t as SessionId)).collect()
                    }
                };
                let busy = Arc::clone(&busy);
                let completed = Arc::clone(&completed);
                handles.push(std::thread::spawn(move || {
                    for r in 0..rounds {
                        let reg = &regs[(t + r) % regs.len()];
                        let d = reg.device();
                        let g = reg.acquire();
                        assert!(
                            !busy[d].swap(true, Ordering::SeqCst),
                            "two holders on device {d}"
                        );
                        std::thread::yield_now();
                        busy[d].store(false, Ordering::SeqCst);
                        drop(g);
                        completed.fetch_add(1, Ordering::SeqCst);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(completed.load(Ordering::SeqCst), nthreads * rounds);
            let total: u64 = (0..ndev).map(|d| arb.grant_count(d)).sum();
            assert_eq!(total as usize, nthreads * rounds);
            for d in 0..ndev {
                assert_eq!(arb.holder(d), None);
                assert!(arb.registered_sessions(d).is_empty());
            }
        }
    }

    #[test]
    fn journal_projection_matches_per_session_grants() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 10);
        let b = arb.register(0, 20);
        drop(a.acquire());
        drop(b.acquire());
        drop(a.acquire());
        let ja = arb.journal_for(10);
        assert_eq!(ja.len(), 2);
        assert!(ja.iter().all(|g| g.session == 10));
        assert_eq!(arb.journal_for(20).len(), 1);
        assert_eq!(arb.journal_for(99).len(), 0);
    }

    /// The counter accessors agree with the snapshot accessors, without
    /// paying their copies.
    #[test]
    fn counters_match_snapshots() {
        let arb = LeaseArbiter::new(2, LeasePolicy::Rotation);
        assert_eq!(arb.registered_count(0), 0);
        assert_eq!(arb.journal_len(), 0);
        let a = arb.register(0, 1);
        let b = arb.register(0, 2);
        let c = arb.register(1, 2);
        assert_eq!(arb.registered_count(0), arb.registered_sessions(0).len());
        assert_eq!(arb.registered_count(1), arb.registered_sessions(1).len());
        drop(a.acquire());
        drop(c.acquire());
        drop(b.acquire());
        assert_eq!(arb.journal_len(), arb.journal().len());
        assert_eq!(arb.journal_len(), 3);
        drop((a, b, c));
        assert_eq!(arb.registered_count(0), 0);
        assert_eq!(arb.registered_count(1), 0);
    }

    /// The merged journal is strictly serial-sorted and its per-device
    /// projections match each device's own grant order — the property
    /// the shard merge must preserve.
    #[test]
    fn merged_journal_is_serial_sorted_across_devices() {
        let arb = LeaseArbiter::new(3, LeasePolicy::Rotation);
        let regs: Vec<DeviceRegistration> =
            (0..3).map(|d| arb.register(d, 100 + d as SessionId)).collect();
        // Interleave grants across devices: 0,1,2,0,1,2,...
        for _ in 0..3 {
            for reg in &regs {
                drop(reg.acquire());
            }
        }
        let j = arb.journal();
        assert_eq!(j.len(), 9);
        for w in j.windows(2) {
            assert!(w[0].serial < w[1].serial, "journal must be serial-sorted");
        }
        for d in 0..3 {
            let dev: Vec<&GrantRecord> = j.iter().filter(|g| g.device == d).collect();
            assert_eq!(dev.len(), 3);
            assert!(dev.iter().all(|g| g.session == 100 + d as SessionId));
            for w in dev.windows(2) {
                assert!(w[0].serial < w[1].serial);
            }
        }
    }

    /// Shard independence: a waiter blocked on one device must not stop
    /// grants on another device — the whole point of sharding.
    #[test]
    fn blocked_waiter_on_one_device_does_not_serialize_another() {
        let arb = LeaseArbiter::new(2, LeasePolicy::Rotation);
        let a0 = arb.register(0, 1);
        let b0 = arb.register(0, 2);
        let a1 = arb.register(1, 1);
        let held = a0.acquire(); // session 1 holds device 0
        let waiter = {
            let arb = Arc::clone(&arb);
            std::thread::spawn(move || {
                // Blocks until device 0 frees *and* the turn reaches b0.
                drop(b0.acquire());
                drop(b0);
                arb.grant_count(0)
            })
        };
        // Device 1 keeps granting while device 0 has a parked waiter.
        for _ in 0..50 {
            drop(a1.acquire());
        }
        assert_eq!(arb.grant_count(1), 50);
        drop(held); // free device 0: the waiter's turn arrives
        let grants0 = waiter.join().unwrap();
        assert_eq!(grants0, 2);
        assert_eq!(arb.holder(0), None);
        assert_eq!(arb.holder(1), None);
    }
}
