//! Device lease arbiter — how concurrent run sessions share the node.
//!
//! A persistent runtime admits many [`RunSession`](crate::coordinator::runtime::RunSession)s
//! at once, but a simulated device can only honestly execute one
//! session's package at a time (the workers' simclock holds are
//! wall-clock sleeps — two sessions occupying one device simultaneously
//! would simulate a device twice as fast as its profile). The arbiter is
//! the enforcement point: every device worker must hold that device's
//! *lease* for the whole occupancy window of a package (staging +
//! compute + simulated hold) and release it between packages, so
//! concurrent sessions interleave per package window across the device
//! set instead of overlapping on one device.
//!
//! # Participants, not sessions
//!
//! Registration is per *worker* (a `(session, device)` pair), keyed by a
//! unique token — a session that selects the same node device twice gets
//! two independent participants. Registration is RAII
//! ([`DeviceRegistration`]): when a worker exits — cleanly, by error, by
//! a caught panic, or by the chaos layer's silent *vanish* — its
//! registration drops and the arbiter forgets it, so a dead session can
//! never hold a turn (or a lease: [`LeaseGuard`] is RAII too) hostage.
//!
//! # Policies
//!
//! * [`LeasePolicy::Rotation`] (default) — deterministic turn-taking:
//!   each device cycles through its registered participants in
//!   registration order (= admission order, since the runtime registers
//!   whole batches under one lock). The device *waits* for the
//!   turn-holder rather than leapfrogging it, so the grant sequence is a
//!   pure function of each session's own request/park/deregister
//!   sequence — never of wall-clock arrival races. That is what makes
//!   concurrent golden-trace tests reproducible. The cost is utilization:
//!   a device can idle while a slow turn-holder initializes.
//!
//!   To keep turn-taking deadlock-free with the fault-tolerant engine
//!   (which holds dry devices open in case a failure requeues work), a
//!   session's master *parks* a participant that provably has nothing to
//!   request (scheduler dry, nothing in flight, nothing reclaimed);
//!   parked participants are skipped by the rotation and un-parked the
//!   moment work is assigned to them again. Parking can only delay a
//!   grant decision (the rotation waits, then skips), never reorder it.
//!
//! * [`LeasePolicy::Fifo`] — first-come-first-served ticket queue:
//!   maximal utilization (a free device goes to whoever asked first),
//!   starvation-free, but contended grant order follows wall-clock
//!   arrival and is not reproducible across executions.
//!
//! Every grant is appended to a global journal ([`GrantRecord`]) — the
//! observable the concurrency battery uses to pin interleavings.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

/// Identifies one admitted run session within a runtime.
pub type SessionId = u64;

/// How a device arbitrates between sessions competing for it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeasePolicy {
    /// Deterministic round-robin turn-taking over registered
    /// participants (skipping parked ones). Reproducible interleavings;
    /// a device may idle waiting for its turn-holder.
    Rotation,
    /// First-come-first-served ticket queue. Maximal utilization;
    /// contended grant order follows wall-clock arrival.
    Fifo,
}

/// One granted lease, in global grant order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GrantRecord {
    /// Global grant sequence number (across all devices).
    pub serial: u64,
    /// Node device index.
    pub device: usize,
    pub session: SessionId,
}

#[derive(Debug)]
struct Entry {
    token: u64,
    session: SessionId,
    /// Parked participants provably have nothing to request and are
    /// skipped by the rotation until un-parked.
    parked: bool,
}

#[derive(Debug, Default)]
struct DeviceState {
    /// Participants in registration order (the rotation order).
    entries: Vec<Entry>,
    /// Index into `entries` of the participant whose turn it is.
    turn: usize,
    /// Token currently holding the device, if any.
    holder: Option<u64>,
    /// Waiting tokens in arrival order (Fifo policy only).
    queue: VecDeque<u64>,
    grants: u64,
}

impl DeviceState {
    /// Advance `turn` past parked entries (at most one full cycle; if
    /// every entry is parked the cursor stays put — nothing is eligible
    /// until an un-park or a new registration).
    fn normalize(&mut self) {
        let n = self.entries.len();
        if n == 0 {
            self.turn = 0;
            return;
        }
        if self.turn >= n {
            self.turn = 0;
        }
        for _ in 0..n {
            if !self.entries[self.turn].parked {
                return;
            }
            self.turn = (self.turn + 1) % n;
        }
    }

    fn position(&self, token: u64) -> Option<usize> {
        self.entries.iter().position(|e| e.token == token)
    }
}

#[derive(Debug)]
struct ArbState {
    devices: Vec<DeviceState>,
    serial: u64,
    next_token: u64,
    journal: Vec<GrantRecord>,
}

/// The shared arbiter. One per runtime (and one per solo `Engine::run`,
/// where its single registered session makes every acquire immediate).
#[derive(Debug)]
pub struct LeaseArbiter {
    policy: LeasePolicy,
    state: Mutex<ArbState>,
    cv: Condvar,
}

impl LeaseArbiter {
    pub fn new(devices: usize, policy: LeasePolicy) -> Arc<Self> {
        Arc::new(Self {
            policy,
            state: Mutex::new(ArbState {
                devices: (0..devices).map(|_| DeviceState::default()).collect(),
                serial: 0,
                next_token: 1,
                journal: Vec::new(),
            }),
            cv: Condvar::new(),
        })
    }

    /// Poison-tolerant lock: the arbiter's critical sections never
    /// panic, but RAII releases run during *worker* unwinds (injected
    /// panics) and must never double-panic.
    fn lock(&self) -> MutexGuard<'_, ArbState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    pub fn policy(&self) -> LeasePolicy {
        self.policy
    }

    pub fn device_count(&self) -> usize {
        self.lock().devices.len()
    }

    /// Register a participant (one worker of `session`) on `device`.
    /// Registration order is the rotation order; the runtime registers
    /// admitted batches under one lock so it equals admission order.
    pub fn register(self: &Arc<Self>, device: usize, session: SessionId) -> DeviceRegistration {
        let token = {
            let mut st = self.lock();
            let token = st.next_token;
            st.next_token += 1;
            st.devices[device].entries.push(Entry { token, session, parked: false });
            token
        };
        self.cv.notify_all();
        DeviceRegistration { arb: Arc::clone(self), device, session, token }
    }

    /// Session currently holding `device`'s lease.
    pub fn holder(&self, device: usize) -> Option<SessionId> {
        let st = self.lock();
        let d = &st.devices[device];
        d.holder.and_then(|t| d.entries.iter().find(|e| e.token == t).map(|e| e.session))
    }

    /// Sessions registered on `device`, in rotation order.
    pub fn registered_sessions(&self, device: usize) -> Vec<SessionId> {
        self.lock().devices[device].entries.iter().map(|e| e.session).collect()
    }

    /// Leases granted on `device` so far.
    pub fn grant_count(&self, device: usize) -> u64 {
        self.lock().devices[device].grants
    }

    /// The global grant journal (all devices, grant order).
    pub fn journal(&self) -> Vec<GrantRecord> {
        self.lock().journal.clone()
    }

    /// Grants of `session` only, in grant order.
    pub fn journal_for(&self, session: SessionId) -> Vec<GrantRecord> {
        self.lock().journal.iter().filter(|g| g.session == session).copied().collect()
    }

    /// Mark a participant as having provably nothing to request
    /// (`parked = true`) or as active again. Called by session masters;
    /// un-parking always precedes the assignment that makes the worker
    /// request again, so a parked turn-holder can never be waited on.
    pub(crate) fn set_parked(&self, device: usize, token: u64, parked: bool) {
        {
            let mut st = self.lock();
            let d = &mut st.devices[device];
            if let Some(pos) = d.position(token) {
                if d.entries[pos].parked != parked {
                    d.entries[pos].parked = parked;
                    if self.policy == LeasePolicy::Rotation {
                        d.normalize();
                    }
                }
            }
        }
        self.cv.notify_all();
    }

    fn acquire_token(&self, device: usize, token: u64, session: SessionId) {
        let mut st = self.lock();
        {
            // A request is intent: a participant that asks again while
            // parked (defensive — masters un-park before assigning)
            // re-enters the rotation.
            let d = &mut st.devices[device];
            if let Some(pos) = d.position(token) {
                if d.entries[pos].parked {
                    d.entries[pos].parked = false;
                }
            }
            if self.policy == LeasePolicy::Fifo {
                d.queue.push_back(token);
            }
        }
        loop {
            let eligible = {
                let d = &mut st.devices[device];
                if d.holder.is_some() {
                    false
                } else {
                    match self.policy {
                        LeasePolicy::Rotation => {
                            d.normalize();
                            match d.entries.get(d.turn) {
                                Some(e) => e.token == token,
                                // Defensive: an unregistered acquire on
                                // an otherwise-empty device proceeds.
                                None => true,
                            }
                        }
                        LeasePolicy::Fifo => d.queue.front() == Some(&token),
                    }
                }
            };
            if eligible {
                let d = &mut st.devices[device];
                d.holder = Some(token);
                d.grants += 1;
                if self.policy == LeasePolicy::Fifo {
                    d.queue.pop_front();
                }
                let serial = st.serial;
                st.serial += 1;
                st.journal.push(GrantRecord { serial, device, session });
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn release_token(&self, device: usize, token: u64) {
        {
            let mut st = self.lock();
            let d = &mut st.devices[device];
            if d.holder == Some(token) {
                d.holder = None;
                if self.policy == LeasePolicy::Rotation {
                    // The releasing participant's window is over: the
                    // turn moves to the next registered entry.
                    if let Some(pos) = d.position(token) {
                        d.turn = (pos + 1) % d.entries.len().max(1);
                    }
                    d.normalize();
                }
            }
        }
        self.cv.notify_all();
    }

    fn deregister_token(&self, device: usize, token: u64) {
        {
            let mut st = self.lock();
            let d = &mut st.devices[device];
            if d.holder == Some(token) {
                // Defensive: a registration should outlive its guards,
                // but a dying worker must never strand the device.
                d.holder = None;
            }
            if let Some(pos) = d.position(token) {
                d.entries.remove(pos);
                if pos < d.turn {
                    d.turn -= 1;
                }
                d.normalize();
            }
            d.queue.retain(|t| *t != token);
        }
        self.cv.notify_all();
    }
}

/// A worker's registration on one device. Dropping it (worker exit —
/// clean or not) removes the participant from the rotation and releases
/// any lease it still holds, which is how leases are reclaimed when a
/// session's device is killed by a fault plan.
#[derive(Debug)]
pub struct DeviceRegistration {
    arb: Arc<LeaseArbiter>,
    device: usize,
    session: SessionId,
    token: u64,
}

impl DeviceRegistration {
    pub fn device(&self) -> usize {
        self.device
    }

    pub fn session(&self) -> SessionId {
        self.session
    }

    /// Opaque participant token (what masters pass to `set_parked`).
    pub fn token(&self) -> u64 {
        self.token
    }

    /// Block until this participant is granted the device, covering one
    /// package occupancy window. Release by dropping the guard.
    pub fn acquire(&self) -> LeaseGuard {
        self.arb.acquire_token(self.device, self.token, self.session);
        LeaseGuard {
            arb: Arc::clone(&self.arb),
            device: self.device,
            token: self.token,
        }
    }
}

impl Drop for DeviceRegistration {
    fn drop(&mut self) {
        self.arb.deregister_token(self.device, self.token);
    }
}

/// A held whole-device lease for one package window (RAII release).
#[derive(Debug)]
pub struct LeaseGuard {
    arb: Arc<LeaseArbiter>,
    device: usize,
    token: u64,
}

impl Drop for LeaseGuard {
    fn drop(&mut self) {
        self.arb.release_token(self.device, self.token);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    #[test]
    fn solo_session_always_granted() {
        let arb = LeaseArbiter::new(2, LeasePolicy::Rotation);
        let reg = arb.register(0, 7);
        for _ in 0..3 {
            let g = reg.acquire();
            assert_eq!(arb.holder(0), Some(7));
            drop(g);
            assert_eq!(arb.holder(0), None);
        }
        assert_eq!(arb.grant_count(0), 3);
        assert_eq!(arb.grant_count(1), 0);
        let j = arb.journal();
        assert_eq!(j.len(), 3);
        assert!(j.iter().all(|g| g.device == 0 && g.session == 7));
        assert_eq!(j[0].serial, 0);
        assert_eq!(j[2].serial, 2);
    }

    #[test]
    fn rotation_alternates_in_registration_order() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 1);
        let b = arb.register(0, 2);
        // a leads (registered first); after each release the turn moves
        // to the next participant, so windows strictly alternate.
        drop(a.acquire());
        drop(b.acquire());
        drop(a.acquire());
        drop(b.acquire());
        let sessions: Vec<SessionId> = arb.journal().iter().map(|g| g.session).collect();
        assert_eq!(sessions, vec![1, 2, 1, 2]);
    }

    #[test]
    fn rotation_skips_parked_participants() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 1);
        let b = arb.register(0, 2);
        // Park a: b can acquire repeatedly without waiting for a.
        arb.set_parked(0, a.token(), true);
        for _ in 0..3 {
            drop(b.acquire());
        }
        // Un-park a: it gets the next turn after b's window.
        arb.set_parked(0, a.token(), false);
        drop(b.acquire());
        drop(a.acquire());
        let sessions: Vec<SessionId> = arb.journal().iter().map(|g| g.session).collect();
        assert_eq!(sessions, vec![2, 2, 2, 2, 1]);
        drop(a);
        drop(b);
        assert!(arb.registered_sessions(0).is_empty());
    }

    #[test]
    fn deregistration_unblocks_the_rotation() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 1);
        let b = arb.register(0, 2);
        drop(a.acquire()); // turn -> b
        drop(b); // b exits without ever acquiring
        // a can immediately go again — the rotation skips the ghost.
        drop(a.acquire());
        assert_eq!(arb.grant_count(0), 2);
        assert_eq!(arb.registered_sessions(0), vec![1]);
    }

    #[test]
    fn dropped_registration_releases_held_lease() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 1);
        let g = a.acquire();
        assert_eq!(arb.holder(0), Some(1));
        // Worker death drops both, guard first in a real unwind; the
        // reverse (defensive) order must also leave the device free.
        drop(a);
        assert_eq!(arb.holder(0), None);
        drop(g); // releasing a deregistered token is a no-op
        assert_eq!(arb.holder(0), None);
    }

    /// Mutual exclusion under a many-thread hammer, both policies: at
    /// most one holder per device at any instant, and every requester
    /// eventually completes all its windows (no starvation).
    ///
    /// Participation contract per policy: under Rotation a registered
    /// participant must keep requesting (or park/deregister) — the
    /// engine's masters guarantee that via parking — so each hammer
    /// thread registers on exactly one device and requests it until it
    /// deregisters. Fifo has no turns, so threads may roam devices.
    #[test]
    fn mutual_exclusion_and_progress_under_contention() {
        for policy in [LeasePolicy::Rotation, LeasePolicy::Fifo] {
            let ndev = 2;
            let nthreads = 5;
            let rounds = 20;
            let arb = LeaseArbiter::new(ndev, policy);
            let busy: Arc<Vec<AtomicBool>> =
                Arc::new((0..ndev).map(|_| AtomicBool::new(false)).collect());
            let completed = Arc::new(AtomicUsize::new(0));
            let mut handles = Vec::new();
            for t in 0..nthreads {
                let regs: Vec<DeviceRegistration> = match policy {
                    LeasePolicy::Rotation => vec![arb.register(t % ndev, t as SessionId)],
                    LeasePolicy::Fifo => {
                        (0..ndev).map(|d| arb.register(d, t as SessionId)).collect()
                    }
                };
                let busy = Arc::clone(&busy);
                let completed = Arc::clone(&completed);
                handles.push(std::thread::spawn(move || {
                    for r in 0..rounds {
                        let reg = &regs[(t + r) % regs.len()];
                        let d = reg.device();
                        let g = reg.acquire();
                        assert!(
                            !busy[d].swap(true, Ordering::SeqCst),
                            "two holders on device {d}"
                        );
                        std::thread::yield_now();
                        busy[d].store(false, Ordering::SeqCst);
                        drop(g);
                        completed.fetch_add(1, Ordering::SeqCst);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(completed.load(Ordering::SeqCst), nthreads * rounds);
            let total: u64 = (0..ndev).map(|d| arb.grant_count(d)).sum();
            assert_eq!(total as usize, nthreads * rounds);
            for d in 0..ndev {
                assert_eq!(arb.holder(d), None);
                assert!(arb.registered_sessions(d).is_empty());
            }
        }
    }

    #[test]
    fn journal_projection_matches_per_session_grants() {
        let arb = LeaseArbiter::new(1, LeasePolicy::Rotation);
        let a = arb.register(0, 10);
        let b = arb.register(0, 20);
        drop(a.acquire());
        drop(b.acquire());
        drop(a.acquire());
        let ja = arb.journal_for(10);
        assert_eq!(ja.len(), 2);
        assert!(ja.iter().all(|g| g.session == 10));
        assert_eq!(arb.journal_for(20).len(), 1);
        assert_eq!(arb.journal_for(99).len(), 0);
    }
}
