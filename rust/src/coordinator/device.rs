//! Device abstraction (Tier-2) and the per-device worker thread (Tier-3).
//!
//! Exactly as the paper's Figure 1: the low-level runtime (OpenCL there,
//! PJRT / the native executor here) is encapsulated inside a `Device`
//! managed by its own thread. Each worker owns an executor over the
//! engine's shared input views, claims disjoint windows of the run's
//! output arena, simulates its profile's init latency and speed,
//! executes assigned packages and streams completion events to the
//! engine's master loop.
//!
//! # Memory model
//!
//! Workers hold no per-device copies of anything sized O(N): inputs are
//! shared [`InputView`]s (pointer bumps) and results go straight into
//! the [`OutputArena`]'s claim-checked disjoint windows — there is no
//! full-size per-worker output buffer and no end-of-run merge. Device
//! compute runs *genuinely in parallel* across worker threads: the seed's
//! global `exec_lock` (which physically serialized all executions so raw
//! timings stayed clean) is gone. The trade is explicit: **results** are
//! timing-independent (disjoint writes, per-item-deterministic kernels —
//! bit-identical under any interleaving), while **raw timings** now
//! include physical core contention, so on an oversubscribed host the
//! simulated durations of contended packages inflate and adaptive
//! schedules can shift with machine load. That is the same trade a real
//! co-executing node makes (devices there contend for the bus and host
//! cores too); the `BASE_SLOWDOWN` stretch keeps wall-clock overlap
//! absorbed, and the serialization it replaced made multi-device
//! wall-clock numbers meaningless.
//!
//! # Worker pipeline
//!
//! With `pipeline_depth <= 1` the worker is the paper's blocking loop:
//! receive a package, stage its H2D transfer, execute, send
//! `Done`, wait for the next assignment — every package pays the full
//! transfer plus a master round-trip of idle time.
//!
//! With `pipeline_depth >= 2` the worker double-buffers: the master keeps
//! a queue of up to `depth` assigned packages per device — every refill
//! travels as one [`AssignBatch`] (an inline array of decided ranges,
//! so the pipeline fills in a single message) — and the worker stages
//! package *n+1*'s H2D transfer inside package *n*'s compute window.
//! `Done` is sent *before* the simulated compute hold completes,
//! shrinking the assign-on-completion round-trip to nothing
//! (arXiv:2010.12607's optimization for short loads), and carries a
//! `prefetched` flag when the next package's staging landed inside the
//! compute window — coalescing what used to be a separate `Uploaded`
//! message into the completion event (one steady-state message per
//! package instead of two). A standalone `Uploaded` survives only for
//! *exposed* stagings (the pipeline's fill bubble), where there is no
//! adjacent `Done` to ride on. The simulated clock charges
//! `max(compute, overlapped-upload) + write-back` per package instead
//! of their sum (see `TimeScaler::target_overlapped`).
//!
//! # Timing feedback
//!
//! Every `Done` event carries the completed package's
//! [`PackageTiming`] — its simulated occupancy span, decided before the
//! hold sleeps it out — which the master routes into
//! `Scheduler::observe` so adaptive strategies re-size subsequent
//! packages from *measured* throughput. Workers also keep a per-run
//! observation ledger (range + timing per completed package, collected
//! regardless of the `introspect` flag) shipped with `Finished`/`Failed`;
//! the session folds it into the persistent performance-model store at
//! session end, failure or not.
//!
//! # Device leasing
//!
//! Since the persistent runtime, a device may be shared by several
//! concurrent run sessions. Each worker therefore holds its device's
//! whole-device *lease* (`coordinator::lease`) for exactly one package
//! occupancy window — staging, compute and the simulated hold — and
//! releases it between packages, so other sessions' packages interleave
//! on the device instead of overlapping (which would simulate more
//! throughput than the profile has). In a pipelined worker the prefetch
//! of package *n+1* stages under package *n*'s lease; the staged data
//! survives the lease gap in the executor. Time spent *waiting* for the
//! lease is never charged to the package's simulated duration (the
//! device was simply busy with another session) but is accumulated and
//! reported per device (`DeviceTrace::lease_wait`). Both the lease
//! guard and the rotation registration are RAII, so any worker exit —
//! clean, error, panic or silent vanish — frees the device for the
//! other sessions.
//!
//! # Work stealing
//!
//! When a `+steal` policy is active the master may revoke a dry-spell
//! victim's *assigned-but-unstarted* backlog: a [`ToWorker::Steal`]
//! asks this worker to yield up to `max_items` from the **back** of its
//! local queue (deepest assignments first — the work it would start
//! last). The worker never yields its in-flight package or the staged
//! prefetch (their H2D transfers are already paid); if the budget ends
//! inside a queued range the range is split at a granule boundary and
//! only the unstarted suffix leaves. The worker always acks with a
//! [`FromWorker::Yielded`] — possibly empty — so the master can retire
//! the outstanding-steal marker; because the ack is sent from the same
//! thread as `Done`/`Failed`, channel order guarantees the master sees
//! the yield before any later completion or death of this worker (the
//! exactly-once argument under steal × fault races).
//!
//! # Fault injection and failure reporting
//!
//! Each worker polls its [`FaultInjector`] once per package boundary
//! (`platform::fault`): *Kill* claims the package's arena windows,
//! poisons them, executes half the sub-launches and dies (a device lost
//! mid-package); *Panic* unwinds (caught in the `spawn_worker` shell
//! and converted into a `Failed` event); *Vanish* exits silently so the
//! engine's liveness sweep has to notice the dead thread; *Stall*
//! sleeps; *Slowdown* degrades the worker's [`TimeScaler`]. A failing
//! worker ships the traces of its *completed* packages with the
//! `Failed` event — those results are already in the arena and stay
//! attributed — while its unfinished ranges are the master's to revoke
//! and requeue.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::config::Configurator;
use crate::coordinator::engine::MAX_PIPELINE_DEPTH;
use crate::coordinator::introspector::{PackageTrace, TransferStats};
use crate::coordinator::lease::DeviceRegistration;
use crate::coordinator::scheduler::{PackageObservation, PackageTiming};
use crate::coordinator::work::Range;
use crate::platform::fault::{FaultInjector, FaultKind};
use crate::platform::{ArtifactCache, DeviceKind, DeviceProfile, TimeScaler};
use crate::runtime::exec::{poison_windows, FAULT_POISON};
use crate::runtime::{
    ArtifactRegistry, BenchManifest, ChunkExecutor, InputView, OutputArena, StagedPackage,
};

/// Paper-style device selection masks (`ecl::DeviceMask`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceMask {
    Cpu,
    Gpu,
    Accelerator,
    /// Every device in the node.
    All,
    /// GPUs + accelerators (no CPU).
    AcceleratorsOnly,
}

impl DeviceMask {
    pub fn matches(&self, kind: DeviceKind) -> bool {
        match self {
            DeviceMask::Cpu => kind == DeviceKind::Cpu,
            DeviceMask::Gpu => matches!(kind, DeviceKind::Gpu | DeviceKind::IntegratedGpu),
            DeviceMask::Accelerator => kind == DeviceKind::Accelerator,
            DeviceMask::All => true,
            DeviceMask::AcceleratorsOnly => kind != DeviceKind::Cpu,
        }
    }
}

/// Explicit device selection (paper: `ecl::Device(platform, device,
/// kernel?)`) — an index into the node's device list plus an optional
/// kernel specialization label (artifact family override).
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub index: usize,
    pub kernel: Option<String>,
}

impl DeviceSpec {
    pub fn new(index: usize) -> Self {
        Self { index, kernel: None }
    }

    /// Select with a device-specialized kernel (paper Listing 2: the Phi
    /// got a binary kernel, the GPU a tuned source kernel).
    pub fn with_kernel(index: usize, kernel: &str) -> Self {
        Self { index, kernel: Some(kernel.to_string()) }
    }
}

// ---- worker protocol (Tier-3) ---------------------------------------

/// One assigned range within a batch refill.
#[derive(Debug, Clone, Copy)]
pub(crate) struct AssignedRange {
    pub range: Range,
    /// `range` is recovered work reclaimed from a dead device (marks
    /// the package's trace so recovery is visible in the introspector).
    pub requeued: bool,
    /// `range` was stolen from another device's unstarted backlog
    /// (marks the package's trace so migrations are countable).
    pub stolen: bool,
}

/// One master refill: every range the master decided for this device in
/// a single top-up, shipped as one message. The storage is an inline
/// array bounded by [`MAX_PIPELINE_DEPTH`] (a refill can never exceed
/// the pipeline depth), so assembling and sending a batch allocates
/// nothing.
#[derive(Debug, Clone, Copy)]
pub(crate) struct AssignBatch {
    ranges: [AssignedRange; MAX_PIPELINE_DEPTH],
    len: usize,
}

impl AssignBatch {
    pub fn new() -> Self {
        Self {
            ranges: [AssignedRange { range: Range::new(0, 0), requeued: false, stolen: false };
                MAX_PIPELINE_DEPTH],
            len: 0,
        }
    }

    /// Append a decided range. The master's refill loop is bounded by
    /// the pipeline depth, so this can never overflow the inline array.
    pub fn push(&mut self, range: Range, requeued: bool, stolen: bool) {
        debug_assert!(self.len < MAX_PIPELINE_DEPTH, "refill exceeded pipeline depth");
        self.ranges[self.len] = AssignedRange { range, requeued, stolen };
        self.len += 1;
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn is_full(&self) -> bool {
        self.len == MAX_PIPELINE_DEPTH
    }

    /// The batch's ranges in master decision order.
    pub fn iter(&self) -> impl Iterator<Item = &AssignedRange> {
        self.ranges[..self.len].iter()
    }
}

impl Default for AssignBatch {
    fn default() -> Self {
        Self::new()
    }
}

pub(crate) enum ToWorker {
    /// A batched refill of one or more assigned ranges (decision order
    /// preserved; the worker enqueues them front to back).
    Assign(AssignBatch),
    /// Yield up to `max_items` assigned-but-unstarted work-items from
    /// the back of the local queue (splitting the cut entry at a
    /// `granule` boundary); always ack with [`FromWorker::Yielded`].
    /// The in-flight package and the staged prefetch are never yielded.
    Steal { max_items: usize, granule: usize },
    /// No more work will be assigned; drain the local queue and exit.
    Finish,
}

pub(crate) enum FromWorker {
    /// Device initialized (driver sim + input binding + builds done).
    Ready {
        dev: usize,
        init_start: Duration,
        init_end: Duration,
        /// Artifact-cache outcome of the init (`None` = no cache wired).
        cache_hit: Option<bool>,
    },
    /// An *exposed* (fill-bubble) H2D staging landed on the device —
    /// the master may top the pipeline back up. Steady-state prefetch
    /// stagings do not send this: they ride on the next `Done`'s
    /// `prefetched` flag instead (one message per package, not two).
    Uploaded { dev: usize },
    /// Package completed (pipelined workers send this as soon as the
    /// next package can be decided, shrinking the assign round-trip);
    /// ready for the next assignment. By the time `Done` is sent the
    /// package's results are fully written into the arena (only the
    /// simulated hold may still be pending), so the master can safely
    /// consider the range finished for recovery bookkeeping. `timing`
    /// is the package's simulated occupancy — the feedback the master
    /// routes into `Scheduler::observe` before sizing the next package.
    /// `prefetched` coalesces the `Uploaded` that used to precede every
    /// steady-state pipelined `Done`: the next package's H2D staging
    /// landed inside this package's compute window, so the master
    /// releases the staging slot first, then books the completion —
    /// the exact event order the two separate messages produced.
    Done { dev: usize, timing: PackageTiming, prefetched: bool },
    /// Ack of a [`ToWorker::Steal`]: the ranges this worker removed
    /// from its local queue (possibly none — the backlog may have
    /// drained between the master's decision and the worker absorbing
    /// the message). Deepest-first: `ranges[0]` is the assignment the
    /// worker would have started last. Sent from the worker thread, so
    /// it is ordered before any later `Done`/`Failed` on this channel.
    Yielded { dev: usize, ranges: Vec<Range> },
    /// Worker exited. Results are already in the output arena (written
    /// in place, package by package); only the introspection traces,
    /// the per-run observation ledger (for the performance-model
    /// store), the per-run transfer byte counts and the total time
    /// spent waiting for device leases travel back.
    Finished {
        dev: usize,
        traces: Vec<PackageTrace>,
        observations: Vec<PackageObservation>,
        xfer: TransferStats,
        lease_wait: Duration,
    },
    /// Worker died (error or caught panic). Traces and observations of
    /// the packages it *completed* travel back — their results are in
    /// the arena and must stay attributed (and the store still learns
    /// from them); the failing package is not among them.
    Failed {
        dev: usize,
        message: String,
        traces: Vec<PackageTrace>,
        observations: Vec<PackageObservation>,
        xfer: TransferStats,
        lease_wait: Duration,
    },
}

pub(crate) struct WorkerCtx {
    pub dev: usize,
    pub profile: DeviceProfile,
    pub registry: ArtifactRegistry,
    pub bench: BenchManifest,
    /// Shared immutable input views (pointer bumps, not copies).
    pub inputs: Vec<InputView>,
    /// The run's output arena; this worker claims disjoint windows of it.
    pub arena: Arc<OutputArena>,
    pub config: Configurator,
    pub epoch: Instant,
    /// True when a CPU device co-executes in the same engine — triggers
    /// the profile's `init_contention` (the paper's Phi driver effect).
    pub contended_init: bool,
    /// All workers rendezvous here between *real* initialization (client
    /// creation + executable builds, which burn physical CPU) and the
    /// *simulated* driver-init sleeps. Without the barrier one device's
    /// compile phase would steal cores from another's compute phase —
    /// contention the simulated machine would not have.
    pub init_barrier: Arc<std::sync::Barrier>,
    /// Packages the master keeps in flight on this device; `<= 1` is the
    /// blocking worker, `>= 2` the double-buffered pipeline.
    pub pipeline_depth: usize,
    pub seed: u64,
    /// Deterministic fault schedule for this device (chaos layer);
    /// polled once per package boundary. Empty when no plan is set.
    pub injector: FaultInjector,
    /// This worker's registration with the runtime's lease arbiter:
    /// acquired once per package occupancy window, deregistered (RAII)
    /// when the worker exits however it exits.
    pub lease: DeviceRegistration,
    /// The runtime's compiled-artifact cache plus this session's store
    /// key (`<kernel>` or `<kernel>+pipe`). On a hit the worker skips
    /// eager compilation and the simulated driver init — the repeat-
    /// traffic setup savings the service front-end measures. `None`
    /// (solo engines, uncached runtimes) keeps init behavior and
    /// timing exactly as before.
    pub artifacts: Option<(Arc<ArtifactCache>, String)>,
}

/// How a worker's package loop ended (errors are a third, `Err`, exit).
enum WorkerExit {
    /// Clean drain: every assigned package completed.
    Finished,
    /// Injected silent death: exit without sending *any* event — the
    /// engine's liveness detection must notice the dead thread.
    Vanished,
}

pub(crate) fn spawn_worker(
    mut ctx: WorkerCtx,
    to_master: Sender<FromWorker>,
    from_master: Receiver<ToWorker>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("ecl-dev-{}", ctx.profile.name))
        .spawn(move || {
            let dev = ctx.dev;
            let mut traces: Vec<PackageTrace> = Vec::new();
            let mut observations: Vec<PackageObservation> = Vec::new();
            let mut xfer = TransferStats::default();
            let mut lease_wait = Duration::ZERO;
            // A panicking worker (a kernel bug, an injected Panic fault)
            // must not just drop its channel: catch the unwind and
            // convert it into a Failed event so the master can recover
            // immediately instead of waiting for liveness detection.
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                worker_loop(
                    &mut ctx,
                    &to_master,
                    &from_master,
                    &mut traces,
                    &mut observations,
                    &mut xfer,
                    &mut lease_wait,
                )
            }));
            // The unwind (or the loop's error return) already dropped
            // any held lease guard; dropping the ctx below retires the
            // arbiter registration itself, so a dead worker can never
            // hold a device or a rotation turn hostage.
            match result {
                Ok(Ok(WorkerExit::Finished)) => {
                    to_master
                        .send(FromWorker::Finished { dev, traces, observations, xfer, lease_wait })
                        .ok();
                }
                Ok(Ok(WorkerExit::Vanished)) => {}
                Ok(Err(e)) => {
                    to_master
                        .send(FromWorker::Failed {
                            dev,
                            message: format!("{e:#}"),
                            traces,
                            observations,
                            xfer,
                            lease_wait,
                        })
                        .ok();
                }
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&'static str>()
                        .map(|s| (*s).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "worker thread panicked".to_string());
                    to_master
                        .send(FromWorker::Failed {
                            dev,
                            message: format!("panic: {msg}"),
                            traces,
                            observations,
                            xfer,
                            lease_wait,
                        })
                        .ok();
                }
            }
        })
        .expect("spawn device worker")
}

/// Fold one master message into the worker's local state: a batch's
/// ranges enter the queue in decision order, `Steal` truncates the
/// queue from the back and acks with `Yielded`, `Finish` marks the
/// drain.
fn absorb(
    msg: ToWorker,
    queue: &mut VecDeque<AssignedRange>,
    finishing: &mut bool,
    to_master: &Sender<FromWorker>,
    dev: usize,
) {
    match msg {
        ToWorker::Assign(batch) => {
            for a in batch.iter() {
                queue.push_back(*a);
            }
        }
        ToWorker::Steal { max_items, granule } => {
            let granule = granule.max(1);
            let mut yielded: Vec<Range> = Vec::new();
            let mut budget = max_items;
            while budget > 0 {
                let Some(back) = queue.back_mut() else { break };
                let len = back.range.len();
                if len <= budget {
                    // Whole entry leaves the queue.
                    yielded.push(back.range);
                    budget -= len;
                    queue.pop_back();
                } else {
                    // Budget ends inside this entry: keep the front at
                    // a granule-aligned cut (rounding the kept part
                    // *up*, so the yielded suffix never exceeds the
                    // budget) and yield the unstarted remainder. A cut
                    // past the end means the whole entry stays.
                    let keep_items = len - budget;
                    let keep_granules = keep_items.div_ceil(granule);
                    let cut = back.range.begin + keep_granules * granule;
                    if cut < back.range.end {
                        yielded.push(Range::new(cut, back.range.end));
                        back.range = Range::new(back.range.begin, cut);
                    }
                    break;
                }
            }
            // Always ack — an empty yield still retires the master's
            // outstanding-steal marker for this device.
            to_master.send(FromWorker::Yielded { dev, ranges: yielded }).ok();
        }
        ToWorker::Finish => *finishing = true,
    }
}

/// A package whose H2D staging completed, waiting to execute.
struct Prefetched {
    range: Range,
    requeued: bool,
    stolen: bool,
    staged: StagedPackage,
    /// Epoch offsets of the staging span.
    h2d_start: Duration,
    h2d_end: Duration,
    /// Wall-clock instant staging began (blocking hold baseline).
    staged_at: Instant,
}

/// Stage a package's H2D phase. No lock: staging is a host-side copy
/// (or a no-op in resident mode) that a real bus would also run
/// concurrently with other devices' compute.
fn stage_package(
    exec: &mut ChunkExecutor,
    epoch: Instant,
    assigned: AssignedRange,
) -> anyhow::Result<Prefetched> {
    let staged_at = Instant::now();
    let h2d_start = epoch.elapsed();
    let staged = exec.stage(assigned.range.begin, assigned.range.end)?;
    let h2d_end = epoch.elapsed();
    Ok(Prefetched {
        range: assigned.range,
        requeued: assigned.requeued,
        stolen: assigned.stolen,
        staged,
        h2d_start,
        h2d_end,
        staged_at,
    })
}

fn worker_loop(
    ctx: &mut WorkerCtx,
    to_master: &Sender<FromWorker>,
    from_master: &Receiver<ToWorker>,
    traces: &mut Vec<PackageTrace>,
    observations: &mut Vec<PackageObservation>,
    xfer: &mut TransferStats,
    lease_wait: &mut Duration,
) -> anyhow::Result<WorkerExit> {
    let dev = ctx.dev;
    let epoch = ctx.epoch;
    let init_start = epoch.elapsed();
    let pipelined = ctx.pipeline_depth > 1;

    // 0. Artifact-cache probe: atomically claim (kernel-key, device)
    // residency. The first worker on a pair pays the build (eager
    // compilation + simulated driver init below); every later worker on
    // the same pair rides the resident artifact — the persistent
    // service's repeat-traffic setup savings. `None` = no cache wired:
    // setup runs exactly as before.
    let cache_hit = ctx
        .artifacts
        .as_ref()
        .map(|(cache, key)| cache.acquire(key, &ctx.profile.name));
    let resident = cache_hit == Some(true);

    // 1. Real initialization: executor over the shared input views (a
    // pointer bump per input in resident mode — no per-device copy).
    let mut exec = ChunkExecutor::with_views(
        &ctx.registry,
        &ctx.bench,
        &ctx.inputs,
        ctx.config.resident_inputs,
    )?;
    if ctx.config.eager_compile && !resident {
        exec.prepare_all()?;
    }
    xfer.input_upload_bytes = exec.input_upload_bytes();

    // 2. Rendezvous: no device starts computing while another is still
    // burning physical cores on compilation (see WorkerCtx::init_barrier).
    ctx.init_barrier.wait();

    // 3. Simulated driver/platform initialization (Figure 13): the Phi
    // arrives late, later still when a CPU device shares the engine.
    // Skipped on a cache hit: a persistent runtime keeps the driver
    // warm and the executables built, so repeat traffic pays neither.
    if ctx.config.simulate_init && !resident {
        let mut wait = ctx.profile.init;
        if ctx.contended_init {
            wait += ctx.profile.init_contention;
        }
        std::thread::sleep(wait);
    }

    let init_end = epoch.elapsed();
    let mut scaler = TimeScaler::new(&ctx.profile, ctx.seed);
    let mut queue: VecDeque<AssignedRange> = VecDeque::new();
    let mut staged: Option<Prefetched> = None;
    let mut finishing = false;
    // Packages started on this device (the fault triggers' ordinal).
    let mut ordinal = 0usize;

    to_master.send(FromWorker::Ready { dev, init_start, init_end, cache_hit }).ok();

    // 4. Package loop.
    loop {
        // Absorb any pending assignments without blocking.
        loop {
            match from_master.try_recv() {
                Ok(msg) => absorb(msg, &mut queue, &mut finishing, to_master, dev),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    finishing = true;
                    break;
                }
            }
        }

        // Out of local work: block for more, or exit when finishing.
        if staged.is_none() && queue.is_empty() {
            if finishing {
                break;
            }
            match from_master.recv() {
                Ok(msg) => {
                    absorb(msg, &mut queue, &mut finishing, to_master, dev);
                    continue;
                }
                Err(_) => break,
            }
        }

        // Check the device out of the shared arbiter for this package's
        // occupancy window (staging + compute + simulated hold).
        // Concurrent sessions interleave here, one whole-device window
        // at a time. The wait is the device serving other sessions and
        // is never charged to this package's simulated duration; the
        // guard drops at the end of the loop iteration, freeing the
        // device between packages.
        let wait_started = Instant::now();
        let _lease = ctx.lease.acquire();
        *lease_wait += wait_started.elapsed();

        // Ensure the head package is staged (exposed H2D: nothing to
        // hide it behind — the pipeline's fill bubble, or blocking mode).
        let current = match staged.take() {
            Some(p) => p,
            None => {
                let assigned = queue.pop_front().expect("checked non-empty");
                let p = stage_package(&mut exec, epoch, assigned)?;
                if pipelined {
                    to_master.send(FromWorker::Uploaded { dev }).ok();
                }
                p
            }
        };

        // Deterministic fault injection (package boundary; chaos layer).
        match ctx.injector.on_package(ordinal, epoch.elapsed()) {
            Some(FaultKind::Kill) => {
                // A device lost mid-package: claim the windows (the
                // ledger now records a claim no completion will ever
                // follow), scribble poison over them, run only a prefix
                // of the sub-launches, and die. Recovery must revoke
                // the claim and fully rewrite the range.
                let (b, e) = (current.range.begin, current.range.end);
                let mut windows = ctx
                    .arena
                    .claim(b, e)
                    .map_err(|err| anyhow::anyhow!("arena claim failed: {err}"))?;
                let mut slices: Vec<&mut [f32]> =
                    windows.iter_mut().map(|w| w.as_mut_slice()).collect();
                poison_windows(&mut slices, FAULT_POISON);
                let prefix = current.staged.launches() as usize / 2;
                if prefix > 0 {
                    exec.execute_staged_prefix(current.staged, &mut slices, prefix)?;
                }
                anyhow::bail!("fault injection: killed at package {ordinal} (items {b}..{e})");
            }
            Some(FaultKind::Panic) => {
                panic!("fault injection: panic at package {ordinal}");
            }
            Some(FaultKind::Vanish) => return Ok(WorkerExit::Vanished),
            Some(FaultKind::Stall(d)) => std::thread::sleep(d),
            Some(FaultKind::Slowdown(f)) => scaler.degrade(f),
            None => {}
        }
        ordinal += 1;

        // Claim this package's disjoint arena windows and execute the
        // kernels straight into them — truly parallel with every other
        // device (no exec lock), no scratch, no write-back copy.
        let mut windows = ctx
            .arena
            .claim(current.range.begin, current.range.end)
            .map_err(|e| anyhow::anyhow!("arena claim failed: {e}"))?;
        let exec_started = Instant::now();
        let exec_start = epoch.elapsed();
        let timing = {
            let mut slices: Vec<&mut [f32]> =
                windows.iter_mut().map(|w| w.as_mut_slice()).collect();
            exec.execute_staged(current.staged, &mut slices)?
        };
        let exec_end = epoch.elapsed();
        xfer.h2d_bytes += timing.h2d_bytes;
        xfer.d2h_bytes += timing.d2h_bytes;

        // Overlap: stage the next package's H2D inside this package's
        // compute window, and report completion early so the master's
        // next assignment travels during the hold. The staging is not
        // announced with its own `Uploaded` message — it rides on this
        // package's `Done` as the `prefetched` flag (the two events
        // were always sent back to back with nothing but arithmetic
        // between them, so coalescing halves the steady-state message
        // rate without reordering anything the master can observe).
        let mut overlapped_h2d = Duration::ZERO;
        let mut prefetched = false;
        if pipelined {
            if let Some(assigned) = queue.pop_front() {
                let p = stage_package(&mut exec, epoch, assigned)?;
                overlapped_h2d = p.staged.h2d();
                staged = Some(p);
                prefetched = true;
            }
        }

        // Hold to the simulated package duration. Device compute
        // stretches with the profile; transfers pass at host speed —
        // overlapped uploads hide behind compute entirely. Without
        // speed simulation the successor's staging ran strictly *after*
        // this package (single host thread), so the package ends at
        // `exec_end` and the trace claims no overlap — raw traces stay
        // honest about what physically happened.
        //
        // The package's occupancy `span` — the feedback the schedulers
        // and the performance-model store consume — is decided *before*
        // the hold sleeps it out (the simulated target is pure
        // arithmetic), so a pipelined worker still sends its early
        // `Done` with the timing attached and the master sizes the next
        // package from this one's span while the hold is still pending.
        let (end, span) = if ctx.config.simulate_speed {
            if pipelined {
                let target = scaler.target_overlapped(
                    timing.exec,
                    timing.launches,
                    overlapped_h2d,
                    timing.d2h,
                );
                to_master
                    .send(FromWorker::Done {
                        dev,
                        timing: PackageTiming { span: target, raw_exec: timing.exec },
                        prefetched,
                    })
                    .ok();
                scaler.hold(exec_started, target);
                (epoch.elapsed(), target)
            } else {
                let target = scaler.target(timing.exec, timing.launches) + timing.xfer();
                scaler.hold(current.staged_at, target);
                let end = epoch.elapsed();
                (end, end.saturating_sub(current.h2d_start))
            }
        } else {
            // No speed simulation: the span is the physical one —
            // compute window for pipelined packages, staging + compute
            // for blocking ones.
            let span = if pipelined {
                exec_end.saturating_sub(exec_start)
            } else {
                exec_end.saturating_sub(current.h2d_start)
            };
            if pipelined {
                to_master
                    .send(FromWorker::Done {
                        dev,
                        timing: PackageTiming { span, raw_exec: timing.exec },
                        prefetched,
                    })
                    .ok();
            }
            (exec_end, span)
        };
        let pkg_timing = PackageTiming { span, raw_exec: timing.exec };
        observations.push(PackageObservation { range: current.range, timing: pkg_timing });

        if ctx.config.introspect {
            // Blocking packages own their staging span; pipelined
            // packages start at compute (staging ran earlier, inside
            // the previous package's window).
            let start = if pipelined { exec_start } else { current.h2d_start };
            traces.push(PackageTrace {
                device: dev,
                begin_item: current.range.begin,
                end_item: current.range.end,
                start,
                end,
                h2d_start: current.h2d_start,
                h2d_end: current.h2d_end,
                exec_start,
                raw_exec: timing.exec,
                launches: timing.launches,
                h2d_bytes: timing.h2d_bytes,
                d2h_bytes: timing.d2h_bytes,
                // Busy watts over the package's occupancy window: the
                // device draws full power for exactly as long as the
                // package holds it. Idle draw is billed at report level.
                energy_j: ctx.profile.busy_watts * end.saturating_sub(start).as_secs_f64(),
                requeued: current.requeued,
                stolen: current.stolen,
            });
        }
        if !pipelined {
            to_master.send(FromWorker::Done { dev, timing: pkg_timing, prefetched: false }).ok();
        }
    }

    Ok(WorkerExit::Finished)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_matching() {
        assert!(DeviceMask::Cpu.matches(DeviceKind::Cpu));
        assert!(!DeviceMask::Cpu.matches(DeviceKind::Gpu));
        assert!(DeviceMask::Gpu.matches(DeviceKind::IntegratedGpu));
        assert!(DeviceMask::All.matches(DeviceKind::Accelerator));
        assert!(DeviceMask::AcceleratorsOnly.matches(DeviceKind::Gpu));
        assert!(!DeviceMask::AcceleratorsOnly.matches(DeviceKind::Cpu));
    }

    #[test]
    fn device_spec_builders() {
        let d = DeviceSpec::new(2);
        assert_eq!(d.index, 2);
        assert!(d.kernel.is_none());
        let d = DeviceSpec::with_kernel(1, "nbody.gpu");
        assert_eq!(d.kernel.as_deref(), Some("nbody.gpu"));
    }

    // ---- absorb / steal truncation ----------------------------------

    fn queued(ranges: &[(usize, usize)]) -> VecDeque<AssignedRange> {
        ranges
            .iter()
            .map(|&(b, e)| AssignedRange { range: Range::new(b, e), requeued: false, stolen: false })
            .collect()
    }

    fn steal(
        queue: &mut VecDeque<AssignedRange>,
        max_items: usize,
        granule: usize,
    ) -> Vec<Range> {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut finishing = false;
        absorb(ToWorker::Steal { max_items, granule }, queue, &mut finishing, &tx, 3);
        assert!(!finishing, "a steal never marks the drain");
        match rx.try_recv() {
            Ok(FromWorker::Yielded { dev, ranges }) => {
                assert_eq!(dev, 3);
                ranges
            }
            _ => panic!("steal must always ack with Yielded"),
        }
    }

    #[test]
    fn steal_yields_whole_entries_deepest_first() {
        let mut q = queued(&[(0, 64), (64, 128), (128, 192)]);
        let got = steal(&mut q, 128, 16);
        assert_eq!(got, vec![Range::new(128, 192), Range::new(64, 128)]);
        assert_eq!(q.len(), 1);
        assert_eq!(q[0].range, Range::new(0, 64));
    }

    #[test]
    fn steal_splits_the_cut_entry_at_a_granule_boundary() {
        // Budget 40 inside a 100-item entry, granule 16: keep
        // ceil(60/16)=4 granules -> cut at 64, yield 64..100 (36 items,
        // within budget).
        let mut q = queued(&[(0, 100)]);
        let got = steal(&mut q, 40, 16);
        assert_eq!(got, vec![Range::new(64, 100)]);
        assert_eq!(q[0].range, Range::new(0, 64));
    }

    #[test]
    fn steal_never_yields_a_partial_granule() {
        // Budget smaller than the entry's tail granule: the rounded-up
        // keep covers the whole range, nothing moves — but the ack is
        // still sent (the empty Vec the helper returns).
        let mut q = queued(&[(0, 16)]);
        let got = steal(&mut q, 8, 16);
        assert!(got.is_empty());
        assert_eq!(q[0].range, Range::new(0, 16));
    }

    #[test]
    fn steal_on_an_empty_queue_acks_empty() {
        let mut q = queued(&[]);
        let got = steal(&mut q, 512, 16);
        assert!(got.is_empty());
        assert!(q.is_empty());
    }

    #[test]
    fn steal_budget_spans_entries_then_splits() {
        // 3 entries of 64; budget 96 takes the whole back entry then
        // splits the middle one at its halfway granule.
        let mut q = queued(&[(0, 64), (64, 128), (128, 192)]);
        let got = steal(&mut q, 96, 32);
        assert_eq!(got, vec![Range::new(128, 192), Range::new(96, 128)]);
        assert_eq!(q.len(), 2);
        assert_eq!(q[1].range, Range::new(64, 96));
    }

    #[test]
    fn assign_and_finish_still_absorb() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let mut q = queued(&[]);
        let mut finishing = false;
        let mut batch = AssignBatch::new();
        batch.push(Range::new(0, 32), false, false);
        batch.push(Range::new(32, 64), true, true);
        absorb(ToWorker::Assign(batch), &mut q, &mut finishing, &tx, 0);
        assert_eq!(q.len(), 2);
        assert!(!q[0].requeued && !q[0].stolen);
        assert!(q[1].requeued && q[1].stolen);
        absorb(ToWorker::Finish, &mut q, &mut finishing, &tx, 0);
        assert!(finishing);
    }
}
