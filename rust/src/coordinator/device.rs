//! Device abstraction (Tier-2) and the per-device worker thread (Tier-3).
//!
//! Exactly as the paper's Figure 1: the low-level runtime (OpenCL there,
//! PJRT here) is encapsulated inside a `Device` managed by its own thread.
//! Each worker owns a PJRT client + executables + resident buffers,
//! simulates its profile's init latency and speed, executes assigned
//! packages and streams completion events to the engine's master loop.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::config::Configurator;
use crate::coordinator::introspector::PackageTrace;
use crate::coordinator::work::Range;
use crate::platform::{DeviceKind, DeviceProfile, TimeScaler};
use crate::runtime::{ArtifactRegistry, BenchManifest, ChunkExecutor, HostBuf};

/// Paper-style device selection masks (`ecl::DeviceMask`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceMask {
    Cpu,
    Gpu,
    Accelerator,
    /// Every device in the node.
    All,
    /// GPUs + accelerators (no CPU).
    AcceleratorsOnly,
}

impl DeviceMask {
    pub fn matches(&self, kind: DeviceKind) -> bool {
        match self {
            DeviceMask::Cpu => kind == DeviceKind::Cpu,
            DeviceMask::Gpu => matches!(kind, DeviceKind::Gpu | DeviceKind::IntegratedGpu),
            DeviceMask::Accelerator => kind == DeviceKind::Accelerator,
            DeviceMask::All => true,
            DeviceMask::AcceleratorsOnly => kind != DeviceKind::Cpu,
        }
    }
}

/// Explicit device selection (paper: `ecl::Device(platform, device,
/// kernel?)`) — an index into the node's device list plus an optional
/// kernel specialization label (artifact family override).
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    pub index: usize,
    pub kernel: Option<String>,
}

impl DeviceSpec {
    pub fn new(index: usize) -> Self {
        Self { index, kernel: None }
    }

    /// Select with a device-specialized kernel (paper Listing 2: the Phi
    /// got a binary kernel, the GPU a tuned source kernel).
    pub fn with_kernel(index: usize, kernel: &str) -> Self {
        Self { index, kernel: Some(kernel.to_string()) }
    }
}

// ---- worker protocol (Tier-3) ---------------------------------------

pub(crate) enum ToWorker {
    Assign(Range),
    Finish,
}

pub(crate) enum FromWorker {
    /// Device initialized (driver sim + input upload + builds done).
    Ready { dev: usize, init_start: std::time::Duration, init_end: std::time::Duration },
    /// Package completed; ready for the next assignment.
    Done { dev: usize },
    /// Worker exited; full-size output buffers + its package traces.
    Finished { dev: usize, outputs: Vec<HostBuf>, traces: Vec<PackageTrace> },
    Failed { dev: usize, message: String },
}

pub(crate) struct WorkerCtx {
    pub dev: usize,
    pub profile: DeviceProfile,
    pub registry: ArtifactRegistry,
    pub bench: BenchManifest,
    pub inputs: Arc<Vec<HostBuf>>,
    pub config: Configurator,
    pub epoch: Instant,
    /// Serializes physical PJRT executions across device threads so raw
    /// timings are clean; the stretch absorbs the wait (simclock docs).
    pub exec_lock: Arc<Mutex<()>>,
    /// True when a CPU device co-executes in the same engine — triggers
    /// the profile's `init_contention` (the paper's Phi driver effect).
    pub contended_init: bool,
    /// All workers rendezvous here between *real* initialization (client
    /// creation + executable builds, which burn physical CPU) and the
    /// *simulated* driver-init sleeps. Without the barrier one device's
    /// compile phase would steal cores from another's compute phase —
    /// contention the simulated machine would not have.
    pub init_barrier: Arc<std::sync::Barrier>,
    pub seed: u64,
}

pub(crate) fn spawn_worker(
    ctx: WorkerCtx,
    to_master: Sender<FromWorker>,
    from_master: Receiver<ToWorker>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("ecl-dev-{}", ctx.profile.name))
        .spawn(move || {
            if let Err(e) = worker_main(&ctx, &to_master, &from_master) {
                let _ = to_master.send(FromWorker::Failed {
                    dev: ctx.dev,
                    message: format!("{e:#}"),
                });
            }
        })
        .expect("spawn device worker")
}

fn worker_main(
    ctx: &WorkerCtx,
    to_master: &Sender<FromWorker>,
    from_master: &Receiver<ToWorker>,
) -> anyhow::Result<()> {
    let init_start = ctx.epoch.elapsed();

    // 1. Real initialization: client, resident inputs, executable builds.
    let mut exec = ChunkExecutor::with_options(
        &ctx.registry,
        &ctx.bench,
        &ctx.inputs,
        ctx.config.resident_inputs,
    )?;
    if ctx.config.eager_compile {
        exec.prepare_all()?;
    }
    let mut outputs: Vec<HostBuf> = ctx
        .bench
        .outputs
        .iter()
        .map(|o| HostBuf::zeros_f32(o.elems))
        .collect();

    // 2. Rendezvous: no device starts computing while another is still
    // burning physical cores on compilation (see WorkerCtx::init_barrier).
    ctx.init_barrier.wait();

    // 3. Simulated driver/platform initialization (Figure 13): the Phi
    // arrives late, later still when a CPU device shares the engine.
    if ctx.config.simulate_init {
        let mut wait = ctx.profile.init;
        if ctx.contended_init {
            wait += ctx.profile.init_contention;
        }
        std::thread::sleep(wait);
    }

    let init_end = ctx.epoch.elapsed();
    let mut scaler = TimeScaler::new(&ctx.profile, ctx.seed);
    let mut traces: Vec<PackageTrace> = Vec::new();

    to_master
        .send(FromWorker::Ready { dev: ctx.dev, init_start, init_end })
        .ok();

    // 4. Package loop.
    while let Ok(msg) = from_master.recv() {
        match msg {
            ToWorker::Finish => break,
            ToWorker::Assign(range) => {
                let started = Instant::now();
                let start_off = ctx.epoch.elapsed();
                let timing = {
                    let _guard = ctx.exec_lock.lock().unwrap();
                    exec.execute_range(range.begin, range.end, &mut outputs)?
                };
                if ctx.config.simulate_speed {
                    // Device compute stretches with the profile; host-side
                    // transfer/management time passes through unstretched.
                    let target =
                        scaler.target(timing.exec, timing.launches) + timing.xfer;
                    scaler.hold(started, target);
                }
                let end_off = ctx.epoch.elapsed();
                if ctx.config.introspect {
                    traces.push(PackageTrace {
                        device: ctx.dev,
                        begin_item: range.begin,
                        end_item: range.end,
                        start: start_off,
                        end: end_off,
                        raw_exec: timing.exec,
                        launches: timing.launches,
                    });
                }
                to_master.send(FromWorker::Done { dev: ctx.dev }).ok();
            }
        }
    }

    to_master
        .send(FromWorker::Finished { dev: ctx.dev, outputs, traces })
        .ok();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_matching() {
        assert!(DeviceMask::Cpu.matches(DeviceKind::Cpu));
        assert!(!DeviceMask::Cpu.matches(DeviceKind::Gpu));
        assert!(DeviceMask::Gpu.matches(DeviceKind::IntegratedGpu));
        assert!(DeviceMask::All.matches(DeviceKind::Accelerator));
        assert!(DeviceMask::AcceleratorsOnly.matches(DeviceKind::Gpu));
        assert!(!DeviceMask::AcceleratorsOnly.matches(DeviceKind::Cpu));
    }

    #[test]
    fn device_spec_builders() {
        let d = DeviceSpec::new(2);
        assert_eq!(d.index, 2);
        assert!(d.kernel.is_none());
        let d = DeviceSpec::with_kernel(1, "nbody.gpu");
        assert_eq!(d.kernel.as_deref(), Some("nbody.gpu"));
    }
}
