//! Work-item ranges and partitioning helpers.
//!
//! All scheduling happens in *granules* (the paper's work-groups): a
//! package is a contiguous granule-aligned range of work-items.

/// A half-open range of work-items `[begin, end)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Range {
    pub begin: usize,
    pub end: usize,
}

impl Range {
    pub fn new(begin: usize, end: usize) -> Self {
        debug_assert!(end >= begin);
        Self { begin, end }
    }

    pub fn len(&self) -> usize {
        self.end - self.begin
    }

    pub fn is_empty(&self) -> bool {
        self.end == self.begin
    }
}

/// Split `total` granules proportionally to `props` (normalized), granule-
/// aligned, remainder granules going to the largest shares first. Returns
/// one (possibly empty) contiguous slice per prop, in order.
pub fn proportional_split(total_granules: usize, props: &[f64]) -> Vec<(usize, usize)> {
    assert!(!props.is_empty());
    let sum: f64 = props.iter().sum();
    assert!(sum > 0.0, "proportions must sum > 0");
    // Largest-remainder method on granule counts.
    let exact: Vec<f64> = props.iter().map(|p| p / sum * total_granules as f64).collect();
    let mut counts: Vec<usize> = exact.iter().map(|e| e.floor() as usize).collect();
    let mut assigned: usize = counts.iter().sum();
    let mut order: Vec<usize> = (0..props.len()).collect();
    // `total_cmp`, not `partial_cmp(..).unwrap()`: a NaN prop (poisoned
    // rate upstream) must not panic the remainder ordering — under IEEE
    // total order it simply sorts deterministically.
    order.sort_by(|&a, &b| {
        let ra = exact[a] - counts[a] as f64;
        let rb = exact[b] - counts[b] as f64;
        rb.total_cmp(&ra)
    });
    let mut i = 0;
    while assigned < total_granules {
        counts[order[i % order.len()]] += 1;
        assigned += 1;
        i += 1;
    }
    // Convert to contiguous (begin, end) granule ranges.
    let mut out = Vec::with_capacity(props.len());
    let mut cursor = 0;
    for c in counts {
        out.push((cursor, cursor + c));
        cursor += c;
    }
    debug_assert_eq!(cursor, total_granules);
    out
}

/// Split the granule-aligned work-item range `[begin, end)` into at
/// most `parts` near-equal contiguous granule-aligned pieces (empty
/// pieces are dropped). The engine's recovery path uses this to break a
/// dead device's reclaimed ranges into pieces every survivor can pull —
/// one Static-sized package would otherwise land whole on a single
/// survivor.
pub fn split_range(begin: usize, end: usize, parts: usize, granule: usize) -> Vec<Range> {
    debug_assert!(granule > 0 && begin % granule == 0 && (end - begin) % granule == 0);
    let total_granules = (end - begin) / granule;
    equal_split(total_granules, parts.max(1))
        .into_iter()
        .filter(|(a, b)| b > a)
        .map(|(a, b)| Range::new(begin + a * granule, begin + b * granule))
        .collect()
}

/// Split `total_granules` into `packages` near-equal contiguous slices
/// (first `total % packages` slices get one extra granule).
pub fn equal_split(total_granules: usize, packages: usize) -> Vec<(usize, usize)> {
    assert!(packages > 0);
    let packages = packages.min(total_granules.max(1));
    let base = total_granules / packages;
    let extra = total_granules % packages;
    let mut out = Vec::with_capacity(packages);
    let mut cursor = 0;
    for i in 0..packages {
        let len = base + usize::from(i < extra);
        out.push((cursor, cursor + len));
        cursor += len;
    }
    debug_assert_eq!(cursor, total_granules);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_basics() {
        let r = Range::new(128, 384);
        assert_eq!(r.len(), 256);
        assert!(!r.is_empty());
        assert!(Range::new(5, 5).is_empty());
    }

    #[test]
    fn proportional_covers_exactly() {
        for total in [1usize, 7, 100, 1023] {
            let parts = proportional_split(total, &[0.08, 0.3, 0.62]);
            assert_eq!(parts.len(), 3);
            assert_eq!(parts[0].0, 0);
            assert_eq!(parts.last().unwrap().1, total);
            for w in parts.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
    }

    #[test]
    fn proportional_respects_ratios() {
        let parts = proportional_split(1000, &[1.0, 3.0]);
        let l0 = parts[0].1 - parts[0].0;
        let l1 = parts[1].1 - parts[1].0;
        assert_eq!(l0 + l1, 1000);
        assert!((l0 as f64 - 250.0).abs() <= 1.0);
        assert!((l1 as f64 - 750.0).abs() <= 1.0);
    }

    #[test]
    fn proportional_zero_share_allowed() {
        let parts = proportional_split(10, &[0.0, 1.0]);
        assert_eq!(parts[0], (0, 0));
        assert_eq!(parts[1], (0, 10));
    }

    #[test]
    fn proportional_survives_non_finite_share() {
        // Regression: an infinite prop (a poisoned upstream rate) makes
        // `p / sum * total` go NaN, and the largest-remainder sort used
        // `partial_cmp(..).unwrap()` — instant panic. The cover contract
        // must survive instead.
        for props in [[f64::INFINITY, 1.0], [f64::INFINITY, f64::INFINITY]] {
            let parts = proportional_split(10, &props);
            assert_eq!(parts[0].0, 0);
            assert_eq!(parts.last().unwrap().1, 10);
            for w in parts.windows(2) {
                assert_eq!(w[0].1, w[1].0, "contiguous");
            }
        }
    }

    #[test]
    fn split_range_partitions_and_aligns() {
        for (begin, end, parts, granule) in
            [(0usize, 1024usize, 3usize, 64usize), (256, 320, 4, 8), (128, 256, 1, 128), (0, 8, 5, 8)]
        {
            let pieces = split_range(begin, end, parts, granule);
            assert!(!pieces.is_empty());
            assert!(pieces.len() <= parts.max(1));
            assert_eq!(pieces[0].begin, begin);
            assert_eq!(pieces.last().unwrap().end, end);
            for w in pieces.windows(2) {
                assert_eq!(w[0].end, w[1].begin, "contiguous");
            }
            for p in &pieces {
                assert_eq!(p.begin % granule, 0);
                assert_eq!(p.len() % granule, 0);
                assert!(!p.is_empty());
            }
        }
    }

    #[test]
    fn equal_split_covers() {
        for (total, packages) in [(100usize, 7usize), (5, 5), (3, 10), (1024, 50)] {
            let parts = equal_split(total, packages);
            assert_eq!(parts[0].0, 0);
            assert_eq!(parts.last().unwrap().1, total);
            let lens: Vec<usize> = parts.iter().map(|(a, b)| b - a).collect();
            let mx = lens.iter().max().unwrap();
            let mn = lens.iter().min().unwrap();
            assert!(mx - mn <= 1, "near-equal: {lens:?}");
        }
    }
}
