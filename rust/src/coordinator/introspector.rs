//! Introspector (paper §4.1, Figures 5/6/12/13): per-package execution
//! traces collected during a run — the custom profiling the authors built
//! because vendor tools could not observe multi-device co-execution.
//!
//! Since the pipelined engine, every package carries transfer/compute
//! sub-spans: `h2d_start..h2d_end` is the host→device staging window and
//! `exec_start..end` the compute-and-merge window. With pipelining on,
//! a package's H2D span sits *inside the previous package's compute
//! window* — [`RunReport::transfer_overlap_count`] is how the harnesses
//! verify the overlap actually happened.
//!
//! Since the zero-copy memory subsystem, every trace also counts bytes
//! moved per direction ([`TransferStats`], [`RunReport::h2d_bytes`] /
//! [`RunReport::d2h_bytes`] / [`RunReport::input_upload_bytes`]), so the
//! elimination of per-device input copies and the d2h scatter is a
//! measurable number, not a claim.

use std::time::Duration;

use crate::platform::DeviceKind;

/// One executed package.
#[derive(Debug, Clone)]
pub struct PackageTrace {
    /// Index into `RunReport::devices`.
    pub device: usize,
    pub begin_item: usize,
    pub end_item: usize,
    /// Offsets from the engine's run epoch: package occupancy window.
    /// Blocking mode: starts at H2D staging. Pipelined: starts at compute
    /// (the staging ran during the previous package's window).
    pub start: Duration,
    pub end: Duration,
    /// Host→device staging sub-span (argument/input upload).
    pub h2d_start: Duration,
    pub h2d_end: Duration,
    /// Start of the compute sub-span (`exec_start..end` is compute).
    pub exec_start: Duration,
    /// Raw (un-stretched) backend execution time.
    pub raw_exec: Duration,
    /// Sub-launches the package decomposed into.
    pub launches: u32,
    /// Bytes the package's H2D staging moved (offset args in resident
    /// mode, input windows in the §5.2 re-upload ablation).
    pub h2d_bytes: usize,
    /// Bytes the package's D2H phase moved; 0 = results written in
    /// place through the output arena (the zero-copy path).
    pub d2h_bytes: usize,
    /// True when this package is recovered work: its range was reclaimed
    /// from a dead device's unfinished assignments and requeued here.
    pub requeued: bool,
    /// True when this package is stolen work: its range was revoked
    /// (assigned-but-unstarted) from a backlogged device's queue and
    /// re-dispatched here — the `+steal` tail-squashing path.
    pub stolen: bool,
    /// Joules the package consumed: the device's busy watts integrated
    /// over the occupancy window (`start..end`, H2D + compute). Idle
    /// draw between packages is charged at the device level
    /// ([`RunReport::device_energy_j`]), never here, so a granule's
    /// joules are billed exactly once even when its range is requeued
    /// after a fault (the dead device's unfinished package never
    /// reaches a trace).
    pub energy_j: f64,
}

impl PackageTrace {
    pub fn items(&self) -> usize {
        self.end_item - self.begin_item
    }

    /// True when this package's H2D staging ran while `other` (another
    /// package on the same device) was computing — the pipelined
    /// engine's transfer/compute overlap, visible in the trace.
    pub fn h2d_overlaps_compute_of(&self, other: &PackageTrace) -> bool {
        self.h2d_end > self.h2d_start // non-empty transfer span
            && self.begin_item != other.begin_item // a different package
            && self.h2d_start < other.end
            && self.h2d_end > other.exec_start
    }
}

/// One observed device failure and what the engine did about it — the
/// introspector's record of the fault-tolerance path (injected faults
/// and real worker deaths look identical here).
#[derive(Debug, Clone)]
pub struct FaultEvent {
    /// Index into `RunReport::devices`.
    pub device: usize,
    pub device_name: String,
    /// The worker's failure message (or the engine's liveness verdict
    /// for workers that died without reporting).
    pub message: String,
    /// Run-epoch offset at which the master observed the failure.
    pub at: Duration,
    /// Work-items reclaimed from the dead device (unfinished
    /// assignments plus any scheduler reservation) and requeued.
    pub reclaimed_items: usize,
    /// Arena claims revoked (the dead device had claimed but never
    /// completed these ranges — their windows held partial writes).
    pub revoked_claims: usize,
    /// True when survivors absorbed the reclaimed work and the run
    /// completed; false when the failure aborted the run.
    pub recovered: bool,
}

/// Bytes a device worker moved between host and device over a whole
/// run. Collected unconditionally (unlike the per-package traces, which
/// honor the `introspect` flag) because the overhead harness counts the
/// zero-copy win with introspection off.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferStats {
    /// Bytes copied to make the run's inputs visible to this device.
    /// 0 = the worker shared the engine's input views (zero-copy).
    pub input_upload_bytes: usize,
    /// Bytes moved host→device across all packages (staging).
    pub h2d_bytes: usize,
    /// Bytes moved device→host across all packages. 0 = every result
    /// was written directly into the output arena.
    pub d2h_bytes: usize,
}

/// Per-device timeline.
#[derive(Debug, Clone)]
pub struct DeviceTrace {
    pub name: String,
    pub kind: DeviceKind,
    /// Offsets from run epoch: device thread spawn -> ready for work.
    /// Includes driver init simulation + executable builds (the paper's
    /// Figure 13 initialization phase).
    pub init_start: Duration,
    pub init_end: Duration,
    pub packages: Vec<PackageTrace>,
    /// Bytes moved per direction over the whole run.
    pub xfer: TransferStats,
    /// Total time this device's worker spent waiting for the device
    /// lease — i.e., the device serving *other* sessions' package
    /// windows. Zero in a solo run (single-participant arbiter).
    pub lease_wait: Duration,
    /// Artifact-cache outcome of this device's init: `Some(true)` when
    /// the (kernel-key, device) artifact was already resident (setup
    /// skipped), `Some(false)` when this worker paid the build, `None`
    /// when the session ran without a cache (solo engine, uncached
    /// runtime).
    pub cache_hit: Option<bool>,
    /// Power draw while a package occupies this device, in watts
    /// (copied from the [`DeviceProfile`](crate::platform::DeviceProfile)).
    pub busy_watts: f64,
    /// Power draw while this device sits idle in the node, in watts.
    pub idle_watts: f64,
    /// True when the scheduler *refused* this device while work still
    /// remained (tail cutoff, energy-objective exclusion) — as opposed
    /// to going dry because the pool was simply exhausted. Refused
    /// devices are deliberate non-participants: the balance metrics
    /// exclude them instead of reading the refusal as imbalance.
    pub refused: bool,
}

impl DeviceTrace {
    /// Work-items this device computed.
    pub fn items(&self) -> usize {
        self.packages.iter().map(PackageTrace::items).sum()
    }

    /// When this device finished its last package (run epoch offset);
    /// init_end if it never got work.
    pub fn completion(&self) -> Duration {
        self.packages.iter().map(|p| p.end).max().unwrap_or(self.init_end)
    }

    /// Busy time: sum of package durations.
    pub fn busy(&self) -> Duration {
        self.packages.iter().map(|p| p.end.saturating_sub(p.start)).sum()
    }

    /// Packages whose H2D staging overlapped another package's compute
    /// window on this device (0 without pipelining).
    pub fn overlapped_transfers(&self) -> usize {
        self.packages
            .iter()
            .filter(|p| self.packages.iter().any(|q| p.h2d_overlaps_compute_of(q)))
            .count()
    }
}

/// The full record of one engine run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub bench: String,
    pub scheduler: String,
    /// Id of the run session this report belongs to (0 for solo
    /// `Engine::run` sessions; admission-ordered ids under a
    /// [`Runtime`](crate::coordinator::runtime::Runtime)).
    pub session: u64,
    pub gws: usize,
    /// Wall time of `Engine::run` (epoch -> all results merged).
    pub wall: Duration,
    pub devices: Vec<DeviceTrace>,
    /// Device failures observed during the run, in observation order.
    /// Empty on a clean run; a non-empty list on a *successful* run
    /// means every failure was recovered (work requeued to survivors).
    pub faults: Vec<FaultEvent>,
    /// `Steal` revocations the master issued (acked or not). 0 under
    /// non-`+steal` specs; pair with [`stolen_items`](Self::stolen_items)
    /// to see how much work the acks actually moved.
    pub steals_issued: usize,
}

impl RunReport {
    /// Start of the compute phase: the earliest device-ready time. Late
    /// initializers (the Phi under CPU contention, Figure 13) are charged
    /// for their lateness relative to this epoch — as the paper's
    /// response times are.
    pub fn compute_epoch(&self) -> Duration {
        self.devices.iter().map(|d| d.init_end).min().unwrap_or_default()
    }

    /// Per-device response time: from the compute epoch to the device's
    /// last package completion.
    pub fn device_response(&self, i: usize) -> Duration {
        self.devices[i].completion().saturating_sub(self.compute_epoch())
    }

    /// Co-execution response time: until the last device finished.
    pub fn response_time(&self) -> Duration {
        (0..self.devices.len())
            .map(|i| self.device_response(i))
            .max()
            .unwrap_or_default()
    }

    /// The paper's balance metric: T_firstDone / T_lastDone over devices
    /// that computed work (1.0 = all finished simultaneously).
    pub fn balance(&self) -> f64 {
        let epoch = self.compute_epoch().as_secs_f64();
        let completions: Vec<f64> = self
            .devices
            .iter()
            .filter(|d| !d.packages.is_empty())
            .map(|d| d.completion().as_secs_f64() - epoch)
            .collect();
        if completions.len() < 2 {
            return 1.0;
        }
        let first = completions.iter().cloned().fold(f64::INFINITY, f64::min);
        let last = completions.iter().cloned().fold(0.0, f64::max);
        if last == 0.0 {
            1.0
        } else {
            first / last
        }
    }

    /// Per-run balance *efficiency* (the Fig. 13 busy-time metric):
    /// mean device busy-time over max device busy-time, across the
    /// run's *participants*. 1.0 = every participant was busy equally
    /// long; a low value means one device carried the run while others
    /// idled — the signature of a mis-calibrated profile or a degraded
    /// device that a static schedule kept over-feeding.
    ///
    /// A participant is a device that computed packages, or a live one
    /// the scheduler was still willing to feed — the latter contribute
    /// zero busy time, so a run where one device hogged all the work
    /// reads as maximally *imbalanced* (the old metric silently dropped
    /// empty devices and reported a perfect 1.0). Devices the scheduler
    /// deliberately refused (tail cutoff, energy exclusion) and devices
    /// that died mid-run are non-participants and stay excluded; 1.0 is
    /// kept only for genuine single-participant runs.
    pub fn balance_efficiency(&self) -> f64 {
        let busys: Vec<f64> = self
            .devices
            .iter()
            .enumerate()
            .filter_map(|(i, d)| {
                if !d.packages.is_empty() {
                    Some(d.busy().as_secs_f64())
                } else if d.refused || self.faults.iter().any(|f| f.device == i) {
                    None
                } else {
                    Some(0.0)
                }
            })
            .collect();
        if busys.len() < 2 {
            return 1.0;
        }
        let max = busys.iter().cloned().fold(0.0f64, f64::max);
        if max <= 0.0 {
            return 1.0;
        }
        let mean = busys.iter().sum::<f64>() / busys.len() as f64;
        mean / max
    }

    /// Work-share per device, normalized to 1.0 (Figure 12).
    pub fn work_shares(&self) -> Vec<f64> {
        let total: usize = self.devices.iter().map(DeviceTrace::items).sum();
        self.devices
            .iter()
            .map(|d| if total == 0 { 0.0 } else { d.items() as f64 / total as f64 })
            .collect()
    }

    /// Total packages executed.
    pub fn total_packages(&self) -> usize {
        self.devices.iter().map(|d| d.packages.len()).sum()
    }

    /// Packages (across all devices) whose H2D transfer span overlapped
    /// another package's compute span on the same device. Nonzero means
    /// the pipeline actually hid transfers behind compute.
    pub fn transfer_overlap_count(&self) -> usize {
        self.devices.iter().map(DeviceTrace::overlapped_transfers).sum()
    }

    /// Convenience: did any device overlap a transfer with compute?
    pub fn has_transfer_overlap(&self) -> bool {
        self.transfer_overlap_count() > 0
    }

    /// Packages (across all devices) that were recovered work — ranges
    /// reclaimed from a dead device and requeued to a survivor.
    pub fn requeued_packages(&self) -> usize {
        self.devices
            .iter()
            .flat_map(|d| d.packages.iter())
            .filter(|p| p.requeued)
            .count()
    }

    /// Work-items executed as recovered (requeued) packages.
    pub fn requeued_items(&self) -> usize {
        self.devices
            .iter()
            .flat_map(|d| d.packages.iter())
            .filter(|p| p.requeued)
            .map(PackageTrace::items)
            .sum()
    }

    /// Packages (across all devices) that were stolen work — ranges
    /// revoked from a backlogged device's unstarted queue and
    /// re-dispatched to a dry one (`+steal`).
    pub fn stolen_packages(&self) -> usize {
        self.devices
            .iter()
            .flat_map(|d| d.packages.iter())
            .filter(|p| p.stolen)
            .count()
    }

    /// Work-items executed as stolen packages.
    pub fn stolen_items(&self) -> usize {
        self.devices
            .iter()
            .flat_map(|d| d.packages.iter())
            .filter(|p| p.stolen)
            .map(PackageTrace::items)
            .sum()
    }

    /// Estimated tail time the steals recovered: the total occupancy of
    /// the stolen packages on their thieves. Each of these spans is work
    /// the victim no longer serializes behind its own backlog, so —
    /// since steals are priced to move work only to a faster-or-equal
    /// device — this is a lower bound on the makespan time bought back.
    pub fn steal_time_recovered(&self) -> Duration {
        self.devices
            .iter()
            .flat_map(|d| d.packages.iter())
            .filter(|p| p.stolen)
            .map(|p| p.end.saturating_sub(p.start))
            .sum()
    }

    /// True when the run saw at least one device failure and every one
    /// of them was recovered.
    pub fn recovered(&self) -> bool {
        !self.faults.is_empty() && self.faults.iter().all(|f| f.recovered)
    }

    /// Total bytes moved host→device across all devices (staging).
    pub fn h2d_bytes(&self) -> usize {
        self.devices.iter().map(|d| d.xfer.h2d_bytes).sum()
    }

    /// Total bytes moved device→host across all devices. 0 means every
    /// result was written in place through the output arena.
    pub fn d2h_bytes(&self) -> usize {
        self.devices.iter().map(|d| d.xfer.d2h_bytes).sum()
    }

    /// Total bytes copied to make inputs device-visible. 0 means every
    /// worker shared the engine's input views — O(N) per run instead of
    /// the seed's O(devices × N).
    pub fn input_upload_bytes(&self) -> usize {
        self.devices.iter().map(|d| d.xfer.input_upload_bytes).sum()
    }

    /// Total time this session's workers spent waiting for device
    /// leases (the devices serving other sessions). Zero in a solo run;
    /// under a concurrent runtime it is the session's contention bill.
    pub fn lease_wait_total(&self) -> Duration {
        self.devices.iter().map(|d| d.lease_wait).sum()
    }

    /// Devices whose compiled artifact was already resident in the
    /// runtime's [`ArtifactCache`](crate::platform::ArtifactCache) —
    /// they skipped eager compilation and the simulated driver init.
    /// 0 for uncached sessions.
    pub fn artifact_cache_hits(&self) -> usize {
        self.devices.iter().filter(|d| d.cache_hit == Some(true)).count()
    }

    /// Devices that paid the artifact build this session (cache misses).
    /// 0 for uncached sessions.
    pub fn artifact_cache_misses(&self) -> usize {
        self.devices.iter().filter(|d| d.cache_hit == Some(false)).count()
    }

    /// Joules device `i` consumed over the run: each package's busy
    /// energy (busy watts × occupancy span, integrated per package in
    /// the trace) plus idle watts over the rest of the wall — init,
    /// inter-package gaps and lease waits all draw idle power.
    pub fn device_energy_j(&self, i: usize) -> f64 {
        let d = &self.devices[i];
        let busy_j: f64 = d.packages.iter().map(|p| p.energy_j).sum();
        let idle_s = (self.wall.as_secs_f64() - d.busy().as_secs_f64()).max(0.0);
        busy_j + d.idle_watts * idle_s
    }

    /// Total joules the node consumed over the run, across all devices.
    pub fn total_energy_j(&self) -> f64 {
        (0..self.devices.len()).map(|i| self.device_energy_j(i)).sum()
    }

    /// Per-device share of the run's total energy, normalized to 1.0
    /// (the energy analogue of [`work_shares`](Self::work_shares)).
    pub fn energy_shares(&self) -> Vec<f64> {
        let total = self.total_energy_j();
        (0..self.devices.len())
            .map(|i| if total > 0.0 { self.device_energy_j(i) / total } else { 0.0 })
            .collect()
    }

    /// Energy-delay product (joule-seconds): total energy × wall time.
    /// The co-execution objective where adding a watt-hungry device
    /// that barely shortens the run makes things *worse* — the frontier
    /// `adaptive:obj=edp` optimizes.
    pub fn edp(&self) -> f64 {
        self.total_energy_j() * self.wall.as_secs_f64()
    }

    /// ASCII timeline (one row per device) — the Introspector "visual
    /// representation" of Figures 5/6 for terminals. `i` marks init,
    /// `#` compute windows, `u` H2D staging visible outside compute
    /// (exposed, un-overlapped transfer).
    pub fn ascii_timeline(&self, width: usize) -> String {
        let wall = self.wall.as_secs_f64().max(1e-9);
        // Column for run-epoch offset `t`, clamped to the row. The clamp
        // must happen *before* any arithmetic on the index: a package
        // whose `end` exceeds the recorded wall (possible after a fault
        // requeue) casts to a saturated usize, and the old `.max(b + 1)`
        // on that value overflowed in debug builds.
        let col = |t: Duration| -> usize {
            (((t.as_secs_f64() / wall) * width as f64) as usize).min(width)
        };
        let mut out = String::new();
        for d in &self.devices {
            let mut row = vec![b'.'; width];
            for c in row.iter_mut().take(col(d.init_end)).skip(col(d.init_start)) {
                *c = b'i';
            }
            // Exposed uploads first; compute windows overwrite them, so
            // only transfer time the pipeline failed to hide stays 'u'.
            for p in &d.packages {
                for c in row.iter_mut().take(col(p.h2d_end)).skip(col(p.h2d_start)) {
                    *c = b'u';
                }
            }
            for p in &d.packages {
                let b = col(p.start);
                let e = col(p.end).max((b + 1).min(width));
                for c in row.iter_mut().take(e).skip(b) {
                    *c = b'#';
                }
            }
            out.push_str(&format!(
                "{:>16} |{}| {:>7.1}ms {:>6} items {:>4} pkgs\n",
                d.name,
                String::from_utf8(row).unwrap(),
                d.completion().as_secs_f64() * 1e3,
                d.items(),
                d.packages.len()
            ));
        }
        out
    }

    /// CSV of package traces — the data behind Figures 5/6, with the
    /// pipelined sub-spans.
    pub fn package_csv(&self) -> String {
        let mut s = String::from(
            "device,kind,begin_item,end_item,start_ms,end_ms,h2d_start_ms,h2d_end_ms,exec_start_ms,raw_ms,launches,h2d_bytes,d2h_bytes,energy_j,requeued,stolen\n",
        );
        for d in &self.devices {
            for p in &d.packages {
                s.push_str(&format!(
                    "{},{},{},{},{:.3},{:.3},{:.3},{:.3},{:.3},{:.3},{},{},{},{:.6},{},{}\n",
                    d.name,
                    d.kind.label(),
                    p.begin_item,
                    p.end_item,
                    p.start.as_secs_f64() * 1e3,
                    p.end.as_secs_f64() * 1e3,
                    p.h2d_start.as_secs_f64() * 1e3,
                    p.h2d_end.as_secs_f64() * 1e3,
                    p.exec_start.as_secs_f64() * 1e3,
                    p.raw_exec.as_secs_f64() * 1e3,
                    p.launches,
                    p.h2d_bytes,
                    p.d2h_bytes,
                    p.energy_j,
                    u8::from(p.requeued),
                    u8::from(p.stolen)
                ));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    /// A blocking-style package: H2D at the window start, compute after,
    /// energy charged at 100 busy watts over the occupancy window.
    fn mk(device: usize, b: usize, e: usize, s: u64, t: u64) -> PackageTrace {
        PackageTrace {
            device,
            begin_item: b,
            end_item: e,
            start: ms(s),
            end: ms(t),
            h2d_start: ms(s),
            h2d_end: ms(s + 1),
            exec_start: ms(s + 1),
            raw_exec: ms((t - s) / 4),
            launches: 1,
            h2d_bytes: 4,
            d2h_bytes: 0,
            energy_j: 100.0 * (t - s) as f64 * 1e-3,
            requeued: false,
            stolen: false,
        }
    }

    fn mk_report() -> RunReport {
        RunReport {
            bench: "toy".into(),
            scheduler: "Static".into(),
            session: 0,
            gws: 100,
            wall: ms(100),
            devices: vec![
                DeviceTrace {
                    name: "cpu".into(),
                    kind: DeviceKind::Cpu,
                    init_start: ms(0),
                    init_end: ms(10),
                    packages: vec![mk(0, 0, 30, 10, 80)],
                    xfer: TransferStats { input_upload_bytes: 0, h2d_bytes: 4, d2h_bytes: 0 },
                    lease_wait: ms(0),
                    cache_hit: None,
                    busy_watts: 100.0,
                    idle_watts: 10.0,
                    refused: false,
                },
                DeviceTrace {
                    name: "gpu".into(),
                    kind: DeviceKind::Gpu,
                    init_start: ms(0),
                    init_end: ms(5),
                    packages: vec![mk(1, 30, 100, 5, 100)],
                    xfer: TransferStats { input_upload_bytes: 0, h2d_bytes: 4, d2h_bytes: 0 },
                    lease_wait: ms(0),
                    cache_hit: None,
                    busy_watts: 100.0,
                    idle_watts: 10.0,
                    refused: false,
                },
            ],
            faults: Vec::new(),
            steals_issued: 0,
        }
    }

    #[test]
    fn balance_ratio() {
        let r = mk_report();
        // compute epoch = min(init_end) = 5ms; (80-5)/(100-5) = 75/95.
        assert!((r.balance() - 75.0 / 95.0).abs() < 1e-9);
        assert_eq!(r.compute_epoch(), ms(5));
        assert_eq!(r.response_time(), ms(95));
        assert_eq!(r.device_response(0), ms(75));
    }

    #[test]
    fn balance_efficiency_mean_over_max() {
        let r = mk_report();
        // Busy times: cpu 70ms, gpu 95ms => mean 82.5 / max 95.
        assert!((r.balance_efficiency() - 82.5 / 95.0).abs() < 1e-9);
        let mut solo = mk_report();
        solo.devices.truncate(1);
        assert_eq!(solo.balance_efficiency(), 1.0, "one device is trivially balanced");
        let mut refused = mk_report();
        refused.devices[0].packages.clear();
        refused.devices[0].refused = true;
        assert_eq!(
            refused.balance_efficiency(),
            1.0,
            "scheduler-refused devices are deliberate non-participants"
        );
    }

    #[test]
    fn hogged_run_reports_imbalance_not_perfection() {
        // Regression: a 3-device run where one device got *everything*
        // used to report a perfect 1.0 — the empty devices were silently
        // dropped and the metric degenerated to a single-device case.
        let mut r = mk_report();
        r.devices[0].packages.clear();
        r.devices.push(DeviceTrace {
            name: "acc".into(),
            kind: DeviceKind::Accelerator,
            init_start: ms(0),
            init_end: ms(8),
            packages: Vec::new(),
            xfer: TransferStats::default(),
            lease_wait: ms(0),
            cache_hit: None,
            busy_watts: 100.0,
            idle_watts: 10.0,
            refused: false,
        });
        // gpu hogs all work (95ms busy); cpu and acc are live, willing
        // and empty: mean/max = (0 + 0 + 95)/3 / 95 = 1/3.
        assert!((r.balance_efficiency() - 1.0 / 3.0).abs() < 1e-9);
        // A faulted empty device is not a participant: back to 1/2 + 95/2.
        r.faults.push(FaultEvent {
            device: 2,
            device_name: "acc".into(),
            message: "killed".into(),
            at: ms(1),
            reclaimed_items: 0,
            revoked_claims: 0,
            recovered: true,
        });
        assert!((r.balance_efficiency() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn work_shares_sum_to_one() {
        let r = mk_report();
        let shares = r.work_shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((shares[0] - 0.3).abs() < 1e-12);
    }

    #[test]
    fn single_device_balance_is_one() {
        let mut r = mk_report();
        r.devices.truncate(1);
        assert_eq!(r.balance(), 1.0);
    }

    #[test]
    fn completion_and_busy() {
        let r = mk_report();
        assert_eq!(r.devices[0].completion(), ms(80));
        assert_eq!(r.devices[0].busy(), ms(70));
        assert_eq!(r.total_packages(), 2);
    }

    #[test]
    fn csv_and_timeline_render() {
        let r = mk_report();
        let csv = r.package_csv();
        assert!(csv.lines().count() == 3);
        assert!(csv.contains("cpu,CPU,0,30"));
        let tl = r.ascii_timeline(40);
        assert_eq!(tl.lines().count(), 2);
        assert!(tl.contains('#'));
    }

    #[test]
    fn blocking_traces_report_no_overlap() {
        let r = mk_report();
        assert_eq!(r.transfer_overlap_count(), 0);
        assert!(!r.has_transfer_overlap());
    }

    #[test]
    fn bytes_moved_aggregate_across_devices() {
        let mut r = mk_report();
        r.devices[0].xfer =
            TransferStats { input_upload_bytes: 100, h2d_bytes: 8, d2h_bytes: 16 };
        assert_eq!(r.h2d_bytes(), 12);
        assert_eq!(r.d2h_bytes(), 16);
        assert_eq!(r.input_upload_bytes(), 100);
        assert_eq!(r.lease_wait_total(), ms(0), "solo traces carry no lease wait");
        r.devices[0].lease_wait = ms(7);
        r.devices[1].lease_wait = ms(5);
        assert_eq!(r.lease_wait_total(), ms(12));
        let csv = r.package_csv();
        assert!(csv.starts_with("device,"));
        assert!(csv
            .lines()
            .next()
            .unwrap()
            .ends_with("h2d_bytes,d2h_bytes,energy_j,requeued,stolen"));
    }

    #[test]
    fn timeline_clamps_overflowing_trace() {
        // Regression: a package whose `end` exceeds the recorded wall
        // (possible after a fault requeue) saturated the f64→usize cast
        // and the render's `.max(b + 1)` overflowed in debug builds.
        let mut r = mk_report();
        let mut p = mk(1, 100, 130, 99, 100);
        p.start = Duration::from_secs(40); // way past the 100ms wall
        p.end = Duration::from_secs(90);
        p.h2d_start = Duration::from_secs(40);
        p.h2d_end = Duration::from_secs(41);
        r.devices[1].packages.push(p);
        let tl = r.ascii_timeline(40);
        assert_eq!(tl.lines().count(), 2);
        for line in tl.lines() {
            let bar = line.split('|').nth(1).expect("row has a |bar|");
            assert_eq!(bar.len(), 40, "row stays exactly `width` wide");
        }
    }

    #[test]
    fn energy_integrates_busy_and_idle_watts() {
        let r = mk_report();
        // cpu: 70ms busy @100W (energy_j from the trace) + 30ms idle @10W.
        let cpu = 100.0 * 0.070 + 10.0 * 0.030;
        // gpu: 95ms busy @100W + 5ms idle @10W.
        let gpu = 100.0 * 0.095 + 10.0 * 0.005;
        assert!((r.device_energy_j(0) - cpu).abs() < 1e-9);
        assert!((r.device_energy_j(1) - gpu).abs() < 1e-9);
        assert!((r.total_energy_j() - (cpu + gpu)).abs() < 1e-9);
        let shares = r.energy_shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(shares[1] > shares[0], "the busier device bills more joules");
        assert!((r.edp() - r.total_energy_j() * 0.1).abs() < 1e-9);
    }

    #[test]
    fn fault_and_requeue_accounting() {
        let mut r = mk_report();
        assert!(!r.recovered(), "no faults, nothing recovered");
        assert_eq!(r.requeued_packages(), 0);

        // The gpu picks up a reclaimed package from a dead cpu.
        let mut requeued = mk(1, 0, 30, 85, 95);
        requeued.requeued = true;
        r.devices[1].packages.push(requeued);
        r.devices[0].packages.clear();
        r.faults.push(FaultEvent {
            device: 0,
            device_name: "cpu".into(),
            message: "fault injection: killed at package 0".into(),
            at: ms(80),
            reclaimed_items: 30,
            revoked_claims: 1,
            recovered: true,
        });
        assert!(r.recovered());
        assert_eq!(r.requeued_packages(), 1);
        assert_eq!(r.requeued_items(), 30);
        let csv = r.package_csv();
        assert!(csv.lines().any(|l| l.ends_with(",1,0")), "requeued column set");

        r.faults.push(FaultEvent {
            device: 1,
            device_name: "gpu".into(),
            message: "cascade".into(),
            at: ms(90),
            reclaimed_items: 10,
            revoked_claims: 0,
            recovered: false,
        });
        assert!(!r.recovered(), "one unrecovered fault poisons the run");
    }

    #[test]
    fn steal_accounting_and_csv_column() {
        let mut r = mk_report();
        assert_eq!(r.stolen_packages(), 0);
        assert_eq!(r.stolen_items(), 0);
        assert_eq!(r.steal_time_recovered(), ms(0));

        // The gpu executes a package stolen from the cpu's backlog.
        let mut stolen = mk(1, 0, 30, 85, 95);
        stolen.stolen = true;
        r.devices[1].packages.push(stolen);
        r.steals_issued = 1;
        assert_eq!(r.stolen_packages(), 1);
        assert_eq!(r.stolen_items(), 30);
        assert_eq!(r.steal_time_recovered(), ms(10), "the thief's occupancy span");
        assert_eq!(r.requeued_packages(), 0, "stolen is not requeued");
        let csv = r.package_csv();
        assert!(csv.lines().any(|l| l.ends_with(",0,1")), "stolen column set");
    }

    #[test]
    fn pipelined_traces_report_overlap() {
        let mut r = mk_report();
        // Package 2 on the gpu: its H2D ran at 40..45ms, inside package
        // 1's 6..100ms compute window — a pipelined prefetch.
        r.devices[1].packages.push(PackageTrace {
            device: 1,
            begin_item: 100,
            end_item: 130,
            start: ms(100),
            end: ms(120),
            h2d_start: ms(40),
            h2d_end: ms(45),
            exec_start: ms(100),
            raw_exec: ms(5),
            launches: 1,
            h2d_bytes: 4,
            d2h_bytes: 0,
            energy_j: 2.0,
            requeued: false,
            stolen: false,
        });
        assert_eq!(r.transfer_overlap_count(), 1);
        assert!(r.has_transfer_overlap());
        // The overlap is one-directional: package 1's own H2D (5..6ms)
        // precedes every compute window, so it is not counted.
        assert_eq!(r.devices[0].overlapped_transfers(), 0);
    }
}
