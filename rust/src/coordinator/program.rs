//! `Program` — the application-domain unit of the paper (§4.2): inputs,
//! outputs, a kernel and an output pattern, decoupled from the engine.
//!
//! Kernels are AOT-compiled (the three-layer architecture bakes scalar
//! arguments into the artifacts), so `arg(..)` records the value and the
//! engine validates it against the manifest at `run()` — preserving the
//! paper's API surface and its error semantics without a JIT.

use std::collections::BTreeMap;

use crate::coordinator::buffer::Buffer;

/// A recorded kernel argument (paper Listing 1: positional or aggregate,
/// plus local-memory allocations).
#[derive(Debug, Clone, PartialEq)]
pub enum Arg {
    /// Scalar value, validated against the baked manifest scalars.
    Scalar(f64),
    /// A buffer argument, matched by registration order.
    BufferRef,
    /// Local memory reservation in bytes (paper's `Arg::LocalAlloc`);
    /// AOT kernels size their VMEM blocks statically, so this is
    /// API-compatibility metadata only.
    LocalAlloc(usize),
}

/// The paper's Tier-1 `Program`.
#[derive(Debug, Clone, Default)]
pub struct Program {
    kernel_name: Option<String>,
    kernel_entry: Option<String>,
    inputs: Vec<Buffer>,
    outputs: Vec<Buffer>,
    args: BTreeMap<usize, Arg>,
    out_pattern: (usize, usize),
}

impl Program {
    pub fn new() -> Self {
        Self { out_pattern: (1, 1), ..Default::default() }
    }

    /// Register an input container (paper: `program.in(vector)`).
    pub fn input(&mut self, data: Vec<f32>) -> &mut Self {
        self.inputs.push(Buffer::input(data));
        self
    }

    /// Register an output container of `len` f32s (paper: `program.out`).
    pub fn output(&mut self, len: usize) -> &mut Self {
        self.outputs.push(Buffer::output(len));
        self
    }

    /// Output pattern: `num` out indexes per `den` work-items (paper §4.2;
    /// e.g. Binomial is 1:255 — 255 work-items produce one output).
    pub fn out_pattern(&mut self, num: usize, den: usize) -> &mut Self {
        self.out_pattern = (num, den);
        self
    }

    /// Select the kernel: `name` is the benchmark artifact family,
    /// `entry` the kernel function (informational, as the source string
    /// was in the paper).
    pub fn kernel(&mut self, name: &str, entry: &str) -> &mut Self {
        self.kernel_name = Some(name.to_string());
        self.kernel_entry = Some(entry.to_string());
        self
    }

    /// Positional scalar argument (paper: `program.arg(0, steps)`).
    pub fn arg_scalar(&mut self, index: usize, value: f64) -> &mut Self {
        self.args.insert(index, Arg::Scalar(value));
        self
    }

    /// Aggregate buffer argument (paper: `program.arg(in)`); buffers are
    /// matched by registration order, this records the position.
    pub fn arg_buffer(&mut self, index: usize) -> &mut Self {
        self.args.insert(index, Arg::BufferRef);
        self
    }

    /// Local-memory reservation (paper: `ecl::Arg::LocalAlloc`).
    pub fn arg_local_alloc(&mut self, index: usize, bytes: usize) -> &mut Self {
        self.args.insert(index, Arg::LocalAlloc(bytes));
        self
    }

    // ---- engine-side accessors -------------------------------------

    pub fn kernel_name(&self) -> Option<&str> {
        self.kernel_name.as_deref()
    }

    pub fn kernel_entry(&self) -> Option<&str> {
        self.kernel_entry.as_deref()
    }

    pub fn inputs(&self) -> &[Buffer] {
        &self.inputs
    }

    pub fn outputs(&self) -> &[Buffer] {
        &self.outputs
    }

    pub fn outputs_mut(&mut self) -> &mut [Buffer] {
        &mut self.outputs
    }

    pub fn args(&self) -> &BTreeMap<usize, Arg> {
        &self.args
    }

    pub fn get_out_pattern(&self) -> (usize, usize) {
        self.out_pattern
    }

    /// Move the computed output data out of the program (paper: after
    /// `run()` the containers hold the results).
    pub fn take_outputs(self) -> Vec<Buffer> {
        self.outputs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chain() {
        let mut p = Program::new();
        p.input(vec![1.0; 8])
            .output(8)
            .out_pattern(1, 255)
            .kernel("binomial", "binomial_opts")
            .arg_scalar(0, 254.0)
            .arg_buffer(1)
            .arg_local_alloc(3, 255 * 16);
        assert_eq!(p.kernel_name(), Some("binomial"));
        assert_eq!(p.inputs().len(), 1);
        assert_eq!(p.outputs().len(), 1);
        assert_eq!(p.get_out_pattern(), (1, 255));
        assert_eq!(p.args().len(), 3);
        assert_eq!(p.args()[&0], Arg::Scalar(254.0));
        assert_eq!(p.args()[&3], Arg::LocalAlloc(255 * 16));
    }

    #[test]
    fn default_out_pattern_is_1_1() {
        assert_eq!(Program::new().get_out_pattern(), (1, 1));
    }
}
