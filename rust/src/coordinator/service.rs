//! Service front-end: traffic-scale ingestion over the persistent
//! [`Runtime`].
//!
//! The runtime (PR-5/PR-7) already multiplexes concurrent sessions over
//! one device fleet, but every caller still hand-builds a `RunSession`
//! and pays full per-session setup. This layer turns the runtime into a
//! *service*: clients toss small [`Request`]s at it and get per-request
//! [`Response`]s back, while the front-end does the traffic engineering
//! in between:
//!
//! 1. **Sharded ingestion** — requests land in one of `shards` bounded
//!    mailboxes (picked by a seeded tenant/id hash). A full mailbox is
//!    backpressure ([`EclError::MailboxFull`]), never silent loss.
//! 2. **Weighted fair admission** — drained requests queue per tenant
//!    and leave by deficit round-robin: each round every backlogged
//!    tenant earns `quantum × weight` work-items of credit and releases
//!    requests from its FIFO head while the credit lasts. A heavy
//!    tenant can saturate its own queue, not the fleet. This sits
//!    *under* the runtime's EDF + starvation-bound admission, which
//!    still orders whatever the DRR releases.
//! 3. **Coalescing** — released requests with the same (kernel,
//!    scheduler) collapse into one batched `RunSession` whose global
//!    work size is the largest member's. Kernels compute
//!    `output[i] = f(inputs, i)` per item over the canonical golden
//!    inputs, so member `k`'s answer is exactly the output prefix
//!    `[0, gws_k × elems_per_item)` of the batch — demultiplexed back
//!    bit-identical to a solo run (pinned by `tests/service_props.rs`).
//! 4. **Artifact + program caching** — the backing runtime is built
//!    [`Runtime::with_artifact_cache`], so repeat traffic skips eager
//!    compilation and simulated driver init; the service additionally
//!    memoizes golden-input programs per kernel so repeat requests skip
//!    registry regeneration. Both caches export hit/miss counters
//!    ([`ServiceStats`]).
//!
//! Two driving modes share one code path: the deterministic
//! [`Service::pump_round`] (what the storm harness and the tests call —
//! ingest order in, response order out, reproducible under a fixed
//! seed), and the threaded live mode ([`Service::start`] /
//! [`Service::shutdown`]) where shard drainers and a dispatcher run the
//! same rounds continuously.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread;
use std::time::Duration;

use crate::coordinator::config::Configurator;
use crate::coordinator::error::EclError;
use crate::coordinator::lease::LeasePolicy;
use crate::coordinator::program::Program;
use crate::coordinator::qos::QosPolicy;
use crate::coordinator::runtime::{RunSession, Runtime, SessionOutcome};
use crate::coordinator::scheduler::SchedulerKind;
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

/// Monotone per-service request identifier (assigned at ingestion).
pub type RequestId = u64;

/// Memoized golden inputs for one kernel (shared across every request
/// that coalesces onto it).
type GoldenInputs = Arc<Vec<Vec<f32>>>;

// ---- requests and responses -------------------------------------------

/// One unit of service traffic: which kernel, how much of it, how, and
/// for whom. Small by design — the service supplies the program (golden
/// inputs), the batch, and the runtime plumbing.
#[derive(Debug, Clone)]
pub struct Request {
    pub kernel: String,
    /// Work items wanted; `None` = the kernel's full problem size.
    pub gws: Option<usize>,
    pub scheduler: SchedulerKind,
    /// Soft completion target, forwarded to the runtime's EDF admission
    /// (a batch inherits the earliest member deadline).
    pub deadline: Option<Duration>,
    /// Client label for weighted fair admission.
    pub tenant: String,
}

impl Request {
    pub fn new(kernel: &str) -> Self {
        Self {
            kernel: kernel.to_string(),
            gws: None,
            scheduler: SchedulerKind::static_default(),
            deadline: None,
            tenant: "default".to_string(),
        }
    }

    pub fn gws(mut self, gws: usize) -> Self {
        self.gws = Some(gws);
        self
    }

    pub fn scheduler(mut self, kind: SchedulerKind) -> Self {
        self.scheduler = kind;
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn tenant(mut self, tenant: &str) -> Self {
        self.tenant = tenant.to_string();
        self
    }
}

/// How a request was served — the per-request slice of its batch.
#[derive(Debug, Clone)]
pub struct RequestReport {
    /// Runtime session id of the batched run that served this request.
    pub session: u64,
    /// Label of the batched session (shared by coalesced siblings).
    pub batch_label: String,
    /// Requests coalesced into the batch (1 = ran solo).
    pub batch_size: usize,
    /// Global work size of the batched session (max member gws).
    pub batch_gws: usize,
    /// Wall time of the batched run.
    pub wall: Duration,
    /// Artifact-cache hits among the batch's device workers.
    pub cache_hits: usize,
    /// Artifact-cache misses (devices that paid the build).
    pub cache_misses: usize,
    /// Ingestion shard the request landed on.
    pub shard: usize,
    /// Admission round the request entered the tenant queue.
    pub enqueue_round: u64,
    /// Admission round the DRR released it for dispatch.
    pub dispatch_round: u64,
}

impl RequestReport {
    /// Rounds spent waiting in the tenant queue — the fairness metric
    /// (per-tenant p95 wait vs the fleet median).
    pub fn wait_rounds(&self) -> u64 {
        self.dispatch_round.saturating_sub(self.enqueue_round)
    }
}

/// A successfully served request: per-output result vectors, each
/// exactly the request's own prefix (`gws × elems_per_item` elements),
/// plus the batch report slice.
#[derive(Debug, Clone)]
pub struct Served {
    pub outputs: Vec<Vec<f32>>,
    pub report: RequestReport,
}

/// Terminal answer for one request.
#[derive(Debug)]
pub struct Response {
    pub id: RequestId,
    pub tenant: String,
    pub result: Result<Served, EclError>,
}

/// Client side of an ingested request; resolves exactly once.
pub struct ResponseHandle {
    id: RequestId,
    tenant: String,
    rx: Receiver<Response>,
}

impl ResponseHandle {
    pub fn id(&self) -> RequestId {
        self.id
    }

    pub fn tenant(&self) -> &str {
        &self.tenant
    }

    /// Block until the service responds. Never panics: a dropped
    /// service yields an error response.
    pub fn wait(self) -> Response {
        let ResponseHandle { id, tenant, rx } = self;
        match rx.recv() {
            Ok(resp) => resp,
            Err(_) => Response {
                id,
                tenant,
                result: Err(EclError::Runtime(
                    "service dropped the request without responding".into(),
                )),
            },
        }
    }
}

// ---- configuration ----------------------------------------------------

/// Service tuning knobs (all deterministic under a fixed seed).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Ingestion shards (bounded mailboxes + live-mode drain threads).
    pub shards: usize,
    /// Capacity of each shard mailbox; a full shard backpressures.
    pub mailbox_cap: usize,
    /// Most requests one batched session may serve.
    pub coalesce_max: usize,
    /// DRR credit (work-items) each weight-1 tenant earns per round.
    pub quantum: usize,
    /// Per-tenant DRR weights; absent tenants weigh 1.
    pub weights: BTreeMap<String, usize>,
    /// Runtime concurrency cap (sessions in flight).
    pub max_in_flight: usize,
    pub lease: LeasePolicy,
    pub seed: u64,
    pub qos: QosPolicy,
    /// Configurator applied to every batched session.
    pub session_config: Configurator,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            shards: 4,
            mailbox_cap: 256,
            coalesce_max: 8,
            quantum: 4096,
            weights: BTreeMap::new(),
            max_in_flight: 4,
            lease: LeasePolicy::Rotation,
            seed: 0,
            qos: QosPolicy::default(),
            session_config: Configurator::default(),
        }
    }
}

// ---- ledger -----------------------------------------------------------

/// Exactly-once accounting state of a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LedgerState {
    /// Ingested; waiting in a mailbox or a tenant queue.
    Queued,
    /// Released by the DRR into a batch this round.
    Dispatched,
    /// Response sent (terminal).
    Responded,
}

/// Snapshot of the ledger, by state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LedgerCounts {
    pub queued: usize,
    pub dispatched: usize,
    pub responded: usize,
}

// ---- internals --------------------------------------------------------

/// A request in flight through the service.
struct Pending {
    id: RequestId,
    req: Request,
    /// Resolved work items (DRR cost and demux prefix length).
    items: usize,
    shard: usize,
    enqueue_round: u64,
    tx: Sender<Response>,
}

#[derive(Default)]
struct TenantState {
    /// Unspent DRR credit, in work-items.
    deficit: u64,
    fifo: VecDeque<Pending>,
}

struct Core {
    /// Per-tenant admission queues in label order (deterministic DRR
    /// visit order).
    tenants: BTreeMap<String, TenantState>,
    /// Completed admission rounds.
    round: u64,
    /// Requests currently sitting in tenant queues.
    queued: usize,
    ledger: BTreeMap<RequestId, LedgerState>,
    /// Transitions that skipped a state (0 unless exactly-once broke).
    ledger_violations: u64,
    ingested: u64,
    responded: u64,
    batches: u64,
    /// Requests that shared a batch with at least one sibling.
    coalesced: u64,
}

struct Shard {
    tx: SyncSender<Pending>,
    /// Present until live mode hands the receiver to a drain thread;
    /// `pump_round` drains it in place through the mutex.
    rx: Mutex<Option<Receiver<Pending>>>,
}

/// Aggregate service counters (all monotone).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub ingested: u64,
    pub responded: u64,
    /// Batched sessions dispatched.
    pub batches: u64,
    /// Requests that shared a batch with at least one sibling.
    pub coalesced_requests: u64,
    /// Completed admission rounds.
    pub rounds: u64,
    pub program_cache_hits: u64,
    pub program_cache_misses: u64,
    pub artifact_cache_hits: u64,
    pub artifact_cache_misses: u64,
}

// ---- the service ------------------------------------------------------

/// Traffic front-end over one persistent [`Runtime`] (see module docs).
pub struct Service {
    registry: ArtifactRegistry,
    cfg: ServiceConfig,
    runtime: Runtime,
    shards: Vec<Shard>,
    core: Mutex<Core>,
    next_id: AtomicU64,
    batch_seq: AtomicU64,
    /// Golden-input memo per kernel: repeat requests skip registry
    /// regeneration.
    golden: Mutex<BTreeMap<String, GoldenInputs>>,
    program_hits: AtomicU64,
    program_misses: AtomicU64,
    stop: AtomicBool,
    threads: Mutex<Vec<thread::JoinHandle<()>>>,
}

impl Service {
    pub fn new(registry: ArtifactRegistry, node: NodeConfig, cfg: ServiceConfig) -> Self {
        let runtime = Runtime::qos_configured(
            registry.clone(),
            node,
            cfg.lease,
            cfg.max_in_flight,
            cfg.seed,
            cfg.qos,
        )
        .with_artifact_cache();
        let shards = (0..cfg.shards.max(1))
            .map(|_| {
                let (tx, rx) = sync_channel(cfg.mailbox_cap.max(1));
                Shard { tx, rx: Mutex::new(Some(rx)) }
            })
            .collect();
        Self {
            registry,
            cfg,
            runtime,
            shards,
            core: Mutex::new(Core {
                tenants: BTreeMap::new(),
                round: 0,
                queued: 0,
                ledger: BTreeMap::new(),
                ledger_violations: 0,
                ingested: 0,
                responded: 0,
                batches: 0,
                coalesced: 0,
            }),
            next_id: AtomicU64::new(0),
            batch_seq: AtomicU64::new(0),
            golden: Mutex::new(BTreeMap::new()),
            program_hits: AtomicU64::new(0),
            program_misses: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            threads: Mutex::new(Vec::new()),
        }
    }

    /// The backing runtime (perf model, artifact cache, QoS journal).
    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    fn lock_core(&self) -> MutexGuard<'_, Core> {
        self.core.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Seeded FNV-1a over (tenant, id): which mailbox a request lands
    /// on. Deterministic per seed; spreads tenants across shards.
    fn shard_for(&self, tenant: &str, id: RequestId) -> usize {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ self.cfg.seed;
        for b in tenant.as_bytes().iter().copied().chain(id.to_le_bytes()) {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        (h % self.shards.len() as u64) as usize
    }

    /// Validate and enqueue one request. Returns the response handle,
    /// or an immediate error: malformed requests are rejected here so
    /// they can never poison a coalesced batch, and a full shard
    /// mailbox surfaces as [`EclError::MailboxFull`] (backpressure —
    /// retry after a dispatch round).
    pub fn ingest(&self, req: Request) -> Result<ResponseHandle, EclError> {
        let (n, granule) = match self.registry.bench(&req.kernel) {
            Ok(b) => (b.n, b.granule),
            Err(_) => return Err(EclError::UnknownKernel(req.kernel.clone())),
        };
        let items = req.gws.unwrap_or(n);
        if items == 0 || items > n {
            return Err(EclError::WorkSizeTooLarge { gws: items, n });
        }
        if granule == 0 || items % granule != 0 {
            return Err(EclError::MisalignedWorkSize { gws: items, granule });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = self.shard_for(&req.tenant, id);
        let (tx, rx) = channel();
        let handle = ResponseHandle { id, tenant: req.tenant.clone(), rx };
        let pending = Pending { id, req, items, shard, enqueue_round: 0, tx };
        // Ledger first: in live mode a shard thread may absorb and the
        // dispatcher release the request the instant it lands, and the
        // Queued -> Dispatched transition must find Queued in place.
        {
            let mut core = self.lock_core();
            core.ledger.insert(id, LedgerState::Queued);
            core.ingested += 1;
        }
        match self.shards[shard].tx.try_send(pending) {
            Ok(()) => Ok(handle),
            Err(e) => {
                let mut core = self.lock_core();
                core.ledger.remove(&id);
                core.ingested -= 1;
                drop(core);
                match e {
                    TrySendError::Full(_) => {
                        Err(EclError::MailboxFull { shard, cap: self.cfg.mailbox_cap })
                    }
                    TrySendError::Disconnected(_) => {
                        Err(EclError::Runtime("service is shut down".into()))
                    }
                }
            }
        }
    }

    /// Move a drained request into its tenant queue (live-mode shard
    /// threads call this; `pump_round` inlines the same step).
    fn absorb(&self, mut p: Pending) {
        let mut core = self.lock_core();
        p.enqueue_round = core.round;
        core.queued += 1;
        core.tenants.entry(p.req.tenant.clone()).or_default().fifo.push_back(p);
    }

    /// Drain every shard mailbox into the tenant queues (shard order,
    /// FIFO within a shard — deterministic in pump mode).
    fn drain_mailboxes(&self, core: &mut Core) {
        for shard in &self.shards {
            let guard = shard.rx.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(rx) = guard.as_ref() {
                while let Ok(mut p) = rx.try_recv() {
                    p.enqueue_round = core.round;
                    core.queued += 1;
                    core.tenants.entry(p.req.tenant.clone()).or_default().fifo.push_back(p);
                }
            }
        }
    }

    /// One deficit-round-robin pass: every backlogged tenant earns
    /// `quantum × weight` items of credit and releases FIFO-head
    /// requests while the credit covers their cost (their work items).
    fn drr_select(&self, core: &mut Core) -> Vec<Pending> {
        let mut released = Vec::new();
        for (tenant, state) in core.tenants.iter_mut() {
            if state.fifo.is_empty() {
                // An idle tenant banks nothing — credit hoarding would
                // let it burst past the weights later.
                state.deficit = 0;
                continue;
            }
            let weight = *self.cfg.weights.get(tenant).unwrap_or(&1);
            state.deficit += (self.cfg.quantum as u64) * (weight.max(1) as u64);
            while let Some(front) = state.fifo.front() {
                let cost = front.items as u64;
                if state.deficit < cost {
                    break;
                }
                state.deficit -= cost;
                released.push(state.fifo.pop_front().expect("front exists"));
            }
            if state.fifo.is_empty() {
                state.deficit = 0;
            }
        }
        core.queued -= released.len();
        released
    }

    /// Pack released requests into batches: same (kernel, scheduler)
    /// groups of at most `coalesce_max`, first-seen order preserved.
    fn coalesce(&self, released: Vec<Pending>) -> Vec<Vec<Pending>> {
        let cap = self.cfg.coalesce_max.max(1);
        let mut groups: Vec<(String, Vec<Pending>)> = Vec::new();
        for p in released {
            let key = format!("{}|{:?}", p.req.kernel, p.req.scheduler);
            match groups.iter_mut().find(|(k, g)| *k == key && g.len() < cap) {
                Some((_, g)) => g.push(p),
                None => groups.push((key, vec![p])),
            }
        }
        groups.into_iter().map(|(_, g)| g).collect()
    }

    /// Golden inputs for `kernel`, memoized (the service's program
    /// cache — repeat traffic skips registry regeneration).
    fn golden_for(&self, kernel: &str) -> Result<GoldenInputs, EclError> {
        {
            let cache = self.golden.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(v) = cache.get(kernel) {
                self.program_hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(v));
            }
        }
        let manifest = self
            .registry
            .bench(kernel)
            .map_err(|_| EclError::UnknownKernel(kernel.to_string()))?
            .clone();
        let bufs = self
            .registry
            .golden_inputs(&manifest)
            .map_err(|e| EclError::Runtime(format!("{e:#}")))?;
        let mut vecs = Vec::with_capacity(bufs.len());
        for b in &bufs {
            match b.as_f32() {
                Some(s) => vecs.push(s.to_vec()),
                None => {
                    return Err(EclError::Runtime(format!(
                        "golden input for '{kernel}' is not f32"
                    )))
                }
            }
        }
        let arc = Arc::new(vecs);
        let mut cache = self.golden.lock().unwrap_or_else(|e| e.into_inner());
        // A racing builder may have inserted meanwhile; keep the first
        // so every later request shares one allocation.
        let entry = cache.entry(kernel.to_string()).or_insert_with(|| {
            self.program_misses.fetch_add(1, Ordering::Relaxed);
            Arc::clone(&arc)
        });
        Ok(Arc::clone(entry))
    }

    /// A golden-input program for `kernel` (same wiring as the harness
    /// `build_program`, fed from the memo).
    fn program_for(&self, kernel: &str) -> Result<Program, EclError> {
        let manifest = self
            .registry
            .bench(kernel)
            .map_err(|_| EclError::UnknownKernel(kernel.to_string()))?
            .clone();
        let inputs = self.golden_for(kernel)?;
        let mut program = Program::new();
        program.kernel(kernel, &manifest.kernel);
        for buf in inputs.iter() {
            program.input(buf.clone());
        }
        for out in &manifest.outputs {
            program.output(out.elems);
        }
        let (num, den) = manifest.out_pattern;
        program.out_pattern(num, den);
        Ok(program)
    }

    /// Build the batched session for one coalesced group.
    fn batch_session(&self, members: &[Pending]) -> Result<RunSession, EclError> {
        let first = &members[0].req;
        let program = self.program_for(&first.kernel)?;
        let gws = members.iter().map(|p| p.items).max().expect("non-empty group");
        let seq = self.batch_seq.fetch_add(1, Ordering::Relaxed);
        let mut session = RunSession::new(program)
            .scheduler(first.scheduler.clone())
            .gws(gws)
            .label(&format!("svc-{seq}-{}x{}", first.kernel, members.len()))
            .config(self.cfg.session_config.clone());
        if let Some(d) = members.iter().filter_map(|p| p.req.deadline).min() {
            session = session.deadline(d);
        }
        Ok(session)
    }

    /// Send the terminal response for one request, exactly once (the
    /// ledger pins Queued → Dispatched → Responded; a skipped state
    /// counts as a violation).
    fn respond(&self, p: Pending, result: Result<Served, EclError>) {
        {
            let mut core = self.lock_core();
            let prev = core.ledger.insert(p.id, LedgerState::Responded);
            if prev != Some(LedgerState::Dispatched) {
                core.ledger_violations += 1;
            }
            core.responded += 1;
        }
        // A client that dropped its handle is not an error.
        p.tx.send(Response { id: p.id, tenant: p.req.tenant.clone(), result }).ok();
    }

    /// Fail every member of a group with the same stringified error
    /// (`EclError` is not `Clone`).
    fn fail_group(&self, members: Vec<Pending>, err: &EclError, what: &str) {
        let msg = format!("{err}");
        for p in members {
            self.respond(p, Err(EclError::Runtime(format!("{what}: {msg}"))));
        }
    }

    /// Demultiplex one finished batch back into per-request responses:
    /// member `k` gets, for each output, the prefix
    /// `[0, items_k × elems_per_item)` of the batch output — which per-
    /// item kernels over shared golden inputs make bit-identical to
    /// member `k`'s solo run.
    fn demux(&self, outcome: SessionOutcome, members: Vec<Pending>, dispatch_round: u64) {
        let SessionOutcome { session, label, program, result, .. } = outcome;
        let batch_size = members.len();
        match result {
            Ok(report) => {
                let epi: Vec<usize> = match self.registry.bench(&members[0].req.kernel) {
                    Ok(m) => m.outputs.iter().map(|o| o.elems_per_item).collect(),
                    Err(_) => Vec::new(),
                };
                let cache_hits = report.artifact_cache_hits();
                let cache_misses = report.artifact_cache_misses();
                for p in members {
                    let outputs: Vec<Vec<f32>> = program
                        .outputs()
                        .iter()
                        .zip(epi.iter())
                        .map(|(buf, &e)| {
                            let data = buf.as_f32();
                            let want = (p.items * e).min(data.len());
                            data[..want].to_vec()
                        })
                        .collect();
                    let rep = RequestReport {
                        session,
                        batch_label: label.clone(),
                        batch_size,
                        batch_gws: report.gws,
                        wall: report.wall,
                        cache_hits,
                        cache_misses,
                        shard: p.shard,
                        enqueue_round: p.enqueue_round,
                        dispatch_round,
                    };
                    self.respond(p, Ok(Served { outputs, report: rep }));
                }
            }
            Err(e) => self.fail_group(members, &e, "batched session failed"),
        }
    }

    /// One full admission round: drain mailboxes, DRR-release, coalesce,
    /// dispatch the batches through the runtime, demux the outcomes.
    /// Returns how many requests were served this round. Deterministic
    /// under a fixed seed when driven single-threaded (pump mode).
    pub fn pump_round(&self) -> usize {
        let (groups, dispatch_round) = {
            let mut core = self.lock_core();
            self.drain_mailboxes(&mut core);
            let released = self.drr_select(&mut core);
            core.round += 1;
            let round = core.round;
            for p in &released {
                let prev = core.ledger.insert(p.id, LedgerState::Dispatched);
                if prev != Some(LedgerState::Queued) {
                    core.ledger_violations += 1;
                }
            }
            let groups = self.coalesce(released);
            core.batches += groups.len() as u64;
            core.coalesced +=
                groups.iter().filter(|g| g.len() > 1).map(|g| g.len() as u64).sum::<u64>();
            (groups, round)
        };
        if groups.is_empty() {
            return 0;
        }
        let served: usize = groups.iter().map(|g| g.len()).sum();
        // Build outside the core lock; a build failure fails only its
        // own group.
        let mut sessions = Vec::new();
        let mut live = Vec::new();
        for g in groups {
            match self.batch_session(&g) {
                Ok(s) => {
                    sessions.push(s);
                    live.push(g);
                }
                Err(e) => self.fail_group(g, &e, "batch build failed"),
            }
        }
        // One atomic runtime submission per round: EDF + lease rotation
        // see the whole round's batches at once.
        let handles = self.runtime.submit_all(sessions);
        for (handle, members) in handles.into_iter().zip(live) {
            let outcome = handle.wait();
            self.demux(outcome, members, dispatch_round);
        }
        served
    }

    /// Requests ingested but not yet responded to.
    pub fn pending(&self) -> usize {
        let core = self.lock_core();
        (core.ingested - core.responded) as usize
    }

    /// Pump rounds until every ingested request has been answered
    /// (pump-mode helper; live mode drains via its dispatcher).
    pub fn drain(&self) {
        while self.pending() > 0 {
            self.pump_round();
        }
    }

    /// Ledger totals by state (the exactly-once observable).
    pub fn ledger_counts(&self) -> LedgerCounts {
        let core = self.lock_core();
        let mut out = LedgerCounts::default();
        for state in core.ledger.values() {
            match state {
                LedgerState::Queued => out.queued += 1,
                LedgerState::Dispatched => out.dispatched += 1,
                LedgerState::Responded => out.responded += 1,
            }
        }
        out
    }

    /// Transitions that skipped a ledger state; 0 unless exactly-once
    /// delivery broke.
    pub fn ledger_violations(&self) -> u64 {
        self.lock_core().ledger_violations
    }

    /// Aggregate counters (see [`ServiceStats`]).
    pub fn stats(&self) -> ServiceStats {
        let (ingested, responded, batches, coalesced, rounds) = {
            let core = self.lock_core();
            (core.ingested, core.responded, core.batches, core.coalesced, core.round)
        };
        let (ahits, amisses) = self
            .runtime
            .artifact_cache()
            .map(|c| c.counters())
            .unwrap_or((0, 0));
        ServiceStats {
            ingested,
            responded,
            batches,
            coalesced_requests: coalesced,
            rounds,
            program_cache_hits: self.program_hits.load(Ordering::Relaxed),
            program_cache_misses: self.program_misses.load(Ordering::Relaxed),
            artifact_cache_hits: ahits,
            artifact_cache_misses: amisses,
        }
    }

    // ---- live mode ----------------------------------------------------

    /// Start live mode: one drain thread per shard plus a dispatcher
    /// thread running `pump_round` continuously. Requests ingested
    /// after this resolve without any pumping by the caller. Stop with
    /// [`Service::shutdown`].
    pub fn start(self: &Arc<Self>) {
        let mut threads = self.threads.lock().unwrap_or_else(|e| e.into_inner());
        if !threads.is_empty() {
            return; // already live
        }
        self.stop.store(false, Ordering::SeqCst);
        for shard in &self.shards {
            let rx = shard.rx.lock().unwrap_or_else(|e| e.into_inner()).take();
            let Some(rx) = rx else { continue };
            let svc = Arc::clone(self);
            threads.push(thread::spawn(move || loop {
                match rx.recv_timeout(Duration::from_millis(20)) {
                    Ok(p) => svc.absorb(p),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        if svc.stop.load(Ordering::SeqCst) {
                            // Drain stragglers, then exit.
                            while let Ok(p) = rx.try_recv() {
                                svc.absorb(p);
                            }
                            break;
                        }
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }));
        }
        let svc = Arc::clone(self);
        threads.push(thread::spawn(move || {
            loop {
                let served = svc.pump_round();
                if svc.stop.load(Ordering::SeqCst)
                    && served == 0
                    && svc.lock_core().queued == 0
                {
                    break;
                }
                if served == 0 {
                    thread::sleep(Duration::from_millis(2));
                }
            }
        }));
    }

    /// Stop live mode: joins the service threads, then serves whatever
    /// the shard drainers absorbed on their way out. Call after clients
    /// stop ingesting. Idempotent; a no-op if `start` was never called.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let threads: Vec<_> =
            self.threads.lock().unwrap_or_else(|e| e.into_inner()).drain(..).collect();
        for t in threads {
            t.join().ok();
        }
        // Stragglers a shard drained after the dispatcher exited.
        while self.pump_round() > 0 {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn service(cfg: ServiceConfig) -> Service {
        let reg = ArtifactRegistry::synthetic();
        Service::new(reg, NodeConfig::batel(), cfg)
    }

    fn quick_cfg() -> ServiceConfig {
        ServiceConfig {
            session_config: Configurator {
                simulate_init: false,
                simulate_speed: false,
                ..Default::default()
            },
            ..Default::default()
        }
    }

    #[test]
    fn rejects_malformed_requests_at_ingestion() {
        let svc = service(quick_cfg());
        assert!(matches!(
            svc.ingest(Request::new("no-such-kernel")),
            Err(EclError::UnknownKernel(_))
        ));
        let n = svc.runtime().registry().bench("binomial").unwrap().n;
        assert!(matches!(
            svc.ingest(Request::new("binomial").gws(n + 1)),
            Err(EclError::WorkSizeTooLarge { .. })
        ));
        assert!(matches!(
            svc.ingest(Request::new("binomial").gws(0)),
            Err(EclError::WorkSizeTooLarge { .. })
        ));
        // Nothing reached the queues.
        assert_eq!(svc.stats().ingested, 0);
        assert_eq!(svc.pending(), 0);
    }

    #[test]
    fn full_mailbox_is_backpressure_not_loss() {
        let cfg = ServiceConfig { shards: 1, mailbox_cap: 2, ..quick_cfg() };
        let svc = service(cfg);
        let mut handles = Vec::new();
        let mut rejected = 0;
        for _ in 0..4 {
            match svc.ingest(Request::new("binomial")) {
                Ok(h) => handles.push(h),
                Err(EclError::MailboxFull { shard, cap }) => {
                    assert_eq!((shard, cap), (0, 2));
                    rejected += 1;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        assert_eq!(rejected, 2, "two of four bounce off a cap-2 mailbox");
        svc.drain();
        for h in handles {
            assert!(h.wait().result.is_ok());
        }
    }

    #[test]
    fn coalesced_batch_serves_every_member() {
        let cfg = ServiceConfig { coalesce_max: 4, ..quick_cfg() };
        let svc = service(cfg);
        let granule = svc.runtime().registry().bench("binomial").unwrap().granule;
        let handles: Vec<_> = (1..=3)
            .map(|k| svc.ingest(Request::new("binomial").gws(granule * k)).expect("ingest"))
            .collect();
        svc.drain();
        let mut batch_labels = Vec::new();
        for (k, h) in handles.into_iter().enumerate() {
            let resp = h.wait();
            let served = resp.result.expect("served");
            assert_eq!(served.report.batch_size, 3, "all three share one batch");
            assert_eq!(served.report.batch_gws, granule * 3, "batch runs the max gws");
            batch_labels.push(served.report.batch_label.clone());
            // Each member got exactly its own prefix.
            let epi: Vec<usize> = svc
                .runtime()
                .registry()
                .bench("binomial")
                .unwrap()
                .outputs
                .iter()
                .map(|o| o.elems_per_item)
                .collect();
            for (out, &e) in served.outputs.iter().zip(epi.iter()) {
                assert_eq!(out.len(), granule * (k + 1) * e);
            }
        }
        batch_labels.dedup();
        assert_eq!(batch_labels.len(), 1, "one session served all members");
        let stats = svc.stats();
        assert_eq!(stats.batches, 1);
        assert_eq!(stats.coalesced_requests, 3);
    }

    #[test]
    fn different_kernels_do_not_coalesce() {
        let svc = service(quick_cfg());
        let a = svc.ingest(Request::new("binomial")).expect("ingest");
        let b = svc.ingest(Request::new("gaussian")).expect("ingest");
        svc.drain();
        let ra = a.wait().result.expect("served");
        let rb = b.wait().result.expect("served");
        assert_eq!(ra.report.batch_size, 1);
        assert_eq!(rb.report.batch_size, 1);
        assert_ne!(ra.report.batch_label, rb.report.batch_label);
        assert_eq!(svc.stats().batches, 2);
    }

    #[test]
    fn drr_favors_weighted_tenant_under_contention() {
        // Tiny quantum so one round releases only part of the backlog;
        // the weight-3 tenant must clear its queue strictly sooner.
        let granule;
        let cfg = {
            let reg = ArtifactRegistry::synthetic();
            granule = reg.bench("binomial").unwrap().granule;
            let mut weights = BTreeMap::new();
            weights.insert("gold".to_string(), 3);
            ServiceConfig { quantum: granule, weights, shards: 1, ..quick_cfg() }
        };
        let svc = service(cfg);
        let mut gold = Vec::new();
        let mut bronze = Vec::new();
        for _ in 0..6 {
            gold.push(
                svc.ingest(Request::new("binomial").gws(granule).tenant("gold")).expect("ingest"),
            );
            bronze.push(
                svc.ingest(Request::new("binomial").gws(granule).tenant("bronze")).expect("ingest"),
            );
        }
        svc.drain();
        let max_wait = |hs: Vec<ResponseHandle>| {
            hs.into_iter()
                .map(|h| h.wait().result.expect("served").report.wait_rounds())
                .max()
                .unwrap()
        };
        let gold_max = max_wait(gold);
        let bronze_max = max_wait(bronze);
        assert!(
            gold_max < bronze_max,
            "weight-3 tenant drains sooner (gold {gold_max} vs bronze {bronze_max} rounds)"
        );
    }

    #[test]
    fn ledger_is_exactly_once() {
        let svc = service(quick_cfg());
        let handles: Vec<_> = (0..8)
            .map(|i| {
                svc.ingest(Request::new("binomial").tenant(if i % 2 == 0 { "a" } else { "b" }))
                    .expect("ingest")
            })
            .collect();
        svc.drain();
        let counts = svc.ledger_counts();
        assert_eq!(counts, LedgerCounts { queued: 0, dispatched: 0, responded: 8 });
        assert_eq!(svc.ledger_violations(), 0);
        for h in handles {
            assert!(h.wait().result.is_ok());
        }
    }

    #[test]
    fn repeat_traffic_hits_both_caches() {
        let cfg = ServiceConfig { coalesce_max: 1, ..quick_cfg() };
        let svc = service(cfg);
        for _ in 0..3 {
            let h = svc.ingest(Request::new("binomial")).expect("ingest");
            svc.drain();
            assert!(h.wait().result.is_ok());
        }
        let stats = svc.stats();
        assert_eq!(stats.program_cache_misses, 1, "golden inputs built once");
        assert_eq!(stats.program_cache_hits, 2);
        assert!(stats.artifact_cache_hits > 0, "later sessions reuse artifacts");
        // Misses = distinct (kernel-key, device) pairs: one kernel over
        // the whole node.
        assert_eq!(stats.artifact_cache_misses as usize, svc.runtime().node().devices.len());
    }

    #[test]
    fn live_mode_serves_without_pumping() {
        let svc = Arc::new(service(quick_cfg()));
        svc.start();
        let handles: Vec<_> = (0..6)
            .map(|i| {
                svc.ingest(Request::new(if i % 2 == 0 { "binomial" } else { "gaussian" }))
                    .expect("ingest")
            })
            .collect();
        for h in handles {
            assert!(h.wait().result.is_ok(), "live dispatcher resolves without pump_round");
        }
        svc.shutdown();
        assert_eq!(svc.pending(), 0);
        assert_eq!(svc.ledger_violations(), 0);
    }
}
