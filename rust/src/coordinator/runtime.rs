//! The persistent multi-run runtime: concurrent run sessions over one
//! device set, arbitrated by whole-device leases.
//!
//! The paper's `Engine::run()` is one-shot: spawn a worker per device,
//! execute one kernel, tear everything down. A runtime *system* serves
//! many kernels at once: clients build a [`RunSession`] (program + work
//! size + scheduler spec + optional deadline), submit it to the
//! [`Runtime`], and get a [`SessionHandle`] that resolves to the
//! session's [`RunReport`] — any number of sessions may be in flight
//! simultaneously, genuinely co-executing across the device set because
//! every device worker checks the device out of the shared
//! [`LeaseArbiter`] for exactly one package window at a time (see
//! `coordinator::lease`).
//!
//! # Layers
//!
//! * `SessionExec` (crate-private) — the execution core: validation,
//!   zero-copy buffer setup, one worker thread per selected device, and
//!   the event-driven master scheduling loop (pipelining, staging
//!   back-pressure, fault recovery — the documentation of record for
//!   the loop's mechanics). This is the code that used to live inside
//!   `Engine::run`; the engine is now a thin one-session wrapper that
//!   feeds it a single-participant arbiter.
//! * [`Runtime`] — admission and arbitration: a submit queue (FIFO, but
//!   sessions carrying deadlines are admitted earliest-deadline-first),
//!   an in-flight cap, per-session seeds for the simclock jitter, and
//!   the shared lease arbiter plus its grant journal.
//!
//! Each session keeps its own [`OutputArena`], scheduler state, fault
//! plan and recovery machinery — a device killed in one session is
//! reclaimed (leases included, via RAII registrations) without the
//! other sessions noticing anything but freed device time.
//!
//! # Master loop
//!
//! The per-session loop is event-driven over the worker channel:
//!
//! * `Ready` — device initialized; top its pipeline up to `depth`
//!   packages. A refill is *batched*: every decision is computed first,
//!   then the whole set ships as one `AssignBatch` message, so the
//!   pipeline fills off a single send and a blocked worker channel can
//!   never stall scheduler decisions for other devices.
//! * `Uploaded` — an *exposed* (fill-bubble) H2D staging landed;
//!   release the device's staging slot (at most two assignments may be
//!   un-staged at once — back-pressure for slow buses) and top up
//!   again. Steady-state prefetch stagings don't send this: they ride
//!   the next `Done`'s `prefetched` flag.
//! * `Done` — a package completed; if it carries a coalesced prefetch
//!   confirmation the staging slot frees first, then the completed
//!   range and its timing are fed to `Scheduler::observe` (the
//!   feedback loop: adaptive strategies re-size from measured
//!   throughput), one slot is freed and the next refill assigned — or
//!   `Finish` sent when the scheduler is dry for that device.
//! * `Finished`/`Failed` — worker exited; collect its traces,
//!   observation ledger (folded into the performance-model store at
//!   session end) and transfer stats (results are already in the
//!   arena) or the failure.
//!
//! Idle timeouts run the liveness sweep on an *adaptive* poll derived
//! from observed package spans (see `LivenessPoll`); the steady-state
//! event path allocates nothing per package.
//!
//! With `depth == 1` this reduces exactly to the paper's blocking
//! assign-on-completion loop.
//!
//! # Work stealing
//!
//! Under a `+steal` spec the loop adds one message pair. When a device
//! runs dry (scheduler refused or exhausted, requeue queue and steal
//! pool empty) the master prices every other device's
//! assigned-but-unstarted backlog with [`price_steal`] over a
//! master-owned [`ThroughputModel`] and, if profitable, sends the most
//! backlogged victim a `Steal` revocation. The victim's worker
//! truncates its local queue from the back (splitting the cut range at
//! a granule boundary) and always acks with `Yielded`; the master
//! matches the ack against the victim's pending ledger, defensively
//! revokes arena claims over the yielded ranges
//! ([`OutputArena::revoke_tail`] — unstarted work holds no claims),
//! pools them, and re-dispatches through the normal `AssignBatch` path
//! with the `stolen` trace flag (thief first). Exactly-once under
//! races: the victim's `top_up` is suppressed while its ack is
//! outstanding (the master must not append ranges the truncation never
//! saw), and a `Yielded` from a device already registered as failed is
//! dropped — recovery requeued its whole pending ledger, the yielded
//! ranges included. Per-worker channel order (`Yielded` is sent at a
//! package boundary, before any later `Done`/`Failed`) makes both
//! rules sufficient; the steal × fault chaos suite pins this.
//!
//! # Fault tolerance
//!
//! The loop tracks, per device, every range assigned but not yet
//! reported `Done`. When a worker dies — it reports `Failed`, or the
//! liveness sweep finds its thread exited without reporting — the
//! master recovers instead of aborting (default;
//! `Configurator::fault_tolerant = false` restores abort-on-failure):
//! the dead device's unfinished ranges plus any scheduler reservation
//! (`Scheduler::reclaim_device` — Static's pre-split share) are
//! reclaimed, their arena claims revoked ([`OutputArena::revoke`]), and
//! the ranges are requeued — split so every survivor can pull a piece.
//! Survivors drain the requeue queue before asking the scheduler, so
//! Dynamic/HGuided absorb the lost work adaptively and Static degrades
//! to a documented re-split. `Finish` is deferred until all work is
//! provably complete. Every failure is recorded as a [`FaultEvent`] on
//! the `RunReport`, and requeued packages are flagged in their traces.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::config::Configurator;
use crate::coordinator::device::{
    spawn_worker, AssignBatch, DeviceSpec, FromWorker, ToWorker, WorkerCtx,
};
use crate::coordinator::engine::MAX_PIPELINE_DEPTH;
use crate::coordinator::error::EclError;
use crate::coordinator::introspector::{DeviceTrace, FaultEvent, RunReport};
use crate::coordinator::lease::{
    DeviceRegistration, GrantRecord, LeaseArbiter, LeasePolicy, SessionId,
};
use crate::coordinator::program::{Arg, Program};
use crate::coordinator::qos::{
    admission_tiebreak, QosClass, QosController, QosEvent, QosPolicy, STARVATION_BOUND,
};
use crate::coordinator::scheduler::{
    price_steal, PackageObservation, QosHint, SchedDevice, Scheduler, SchedulerKind,
    StealPolicy, ThroughputModel,
};
use crate::coordinator::work::{split_range, Range};
use crate::platform::perfmodel::PerfModelStore;
use crate::platform::qos::{DeviceLoad, MakespanEstimate, MakespanPredictor};
use crate::platform::{ArtifactCache, DeviceKind, NodeConfig};
use crate::runtime::{input_views, ArtifactRegistry, HostBuf, InputView, OutputArena};

// ---- sessions ---------------------------------------------------------

/// One unit of admission: a program plus everything the runtime needs
/// to execute it. Built by clients, consumed by [`Runtime::submit`].
#[derive(Debug)]
pub struct RunSession {
    pub program: Program,
    /// Node devices to co-execute on; empty = every device in the node.
    pub devices: Vec<DeviceSpec>,
    pub scheduler: SchedulerKind,
    /// Tier-1 pipeline override; `None` defers to the scheduler spec.
    pub pipeline_depth: Option<usize>,
    pub gws: Option<usize>,
    pub config: Configurator,
    /// Soft completion target. Queued sessions with deadlines are
    /// admitted earliest-deadline-first; the outcome records whether the
    /// session's makespan met it ([`SessionOutcome::met_deadline`]).
    pub deadline: Option<Duration>,
    /// Human-readable tag for reports; defaults to `session-<id>`.
    pub label: String,
}

impl RunSession {
    pub fn new(program: Program) -> Self {
        Self {
            program,
            devices: Vec::new(),
            scheduler: SchedulerKind::static_default(),
            pipeline_depth: None,
            gws: None,
            config: Configurator::default(),
            deadline: None,
            label: String::new(),
        }
    }

    pub fn devices(mut self, devices: Vec<DeviceSpec>) -> Self {
        self.devices = devices;
        self
    }

    pub fn scheduler(mut self, kind: SchedulerKind) -> Self {
        self.scheduler = kind;
        self
    }

    pub fn pipeline(mut self, depth: usize) -> Self {
        self.pipeline_depth = Some(depth);
        self
    }

    pub fn gws(mut self, gws: usize) -> Self {
        self.gws = Some(gws);
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    pub fn config(mut self, config: Configurator) -> Self {
        self.config = config;
        self
    }

    /// Tweak the configurator in place (builder-style).
    pub fn configure(mut self, f: impl FnOnce(&mut Configurator)) -> Self {
        f(&mut self.config);
        self
    }
}

/// Everything a finished session hands back: the program (its output
/// containers hold the results — zero-copy publish, exactly as
/// `Engine::run`), and the report or the error.
#[derive(Debug)]
pub struct SessionOutcome {
    pub session: SessionId,
    pub label: String,
    pub deadline: Option<Duration>,
    pub program: Program,
    pub result: Result<RunReport, EclError>,
}

impl SessionOutcome {
    pub fn report(&self) -> Option<&RunReport> {
        self.result.as_ref().ok()
    }

    /// Computed output `i` (from the returned program's containers).
    pub fn output(&self, i: usize) -> Option<&[f32]> {
        self.program.outputs().get(i).map(|b| b.as_f32())
    }

    /// `Some(true)` when the session had a deadline and its makespan
    /// met it; `Some(false)` on a miss (or a failed run); `None` when
    /// no deadline was set.
    pub fn met_deadline(&self) -> Option<bool> {
        self.deadline.map(|d| match &self.result {
            Ok(report) => report.wall <= d,
            Err(_) => false,
        })
    }
}

/// Handle to an in-flight (or queued) session. Resolves to the
/// [`SessionOutcome`] once the session completes.
pub struct SessionHandle {
    session: SessionId,
    label: String,
    rx: Receiver<SessionOutcome>,
}

impl SessionHandle {
    pub fn id(&self) -> SessionId {
        self.session
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    /// Block until the session completes. Never panics: a session
    /// thread that dies without reporting yields an error outcome.
    pub fn wait(self) -> SessionOutcome {
        match self.rx.recv() {
            Ok(outcome) => outcome,
            Err(_) => SessionOutcome {
                session: self.session,
                label: self.label,
                deadline: None,
                program: Program::new(),
                result: Err(EclError::Runtime(
                    "session thread terminated without reporting an outcome".into(),
                )),
            },
        }
    }
}

// ---- the runtime ------------------------------------------------------

struct QueuedSession {
    session: SessionId,
    spec: RunSession,
    tx: Sender<SessionOutcome>,
    /// Admissions this (FIFO-ordered) entry lost to later-submitted
    /// deadlined sessions — the anti-starvation aging counter. At
    /// [`STARVATION_BOUND`] the queue head is admitted unconditionally.
    bypassed: usize,
}

/// A session that cleared admission: registered with the arbiter (in
/// admission order, under the runtime lock) and ready to spawn.
struct Admitted {
    session: SessionId,
    spec: RunSession,
    tx: Sender<SessionOutcome>,
    selected: Vec<DeviceSpec>,
    registrations: Vec<DeviceRegistration>,
    /// Admission-time makespan prediction (QoS-enabled runtimes only) —
    /// seeds the schedulers' QoS hint.
    predicted: Option<MakespanEstimate>,
}

struct RtState {
    next_session: SessionId,
    in_flight: usize,
    queue: VecDeque<QueuedSession>,
    /// Sessions in the order admission granted them (the EDF/aging
    /// observable the starvation and tie-break tests assert on).
    admitted_order: Vec<SessionId>,
}

struct RuntimeShared {
    registry: ArtifactRegistry,
    node: NodeConfig,
    arbiter: Arc<LeaseArbiter>,
    /// The cross-session performance model: every session's completed
    /// packages are folded in at session end, and every session's
    /// schedulers warm-start from the estimates accumulated so far
    /// (see `platform::perfmodel`).
    perf: Arc<PerfModelStore>,
    /// Base simclock seed: each session's jitter RNG derives from it
    /// and the session id, so a fixed runtime seed + fixed admission
    /// order reproduces every session's timing draws.
    seed: u64,
    max_in_flight: usize,
    /// QoS knobs; `enabled: false` (the default) keeps every admission
    /// and master-loop path byte-identical to the pre-QoS runtime.
    qos: QosPolicy,
    /// The shed/preempt controller (inert while `qos.enabled` is off).
    qos_ctl: Arc<QosController>,
    /// The compiled-artifact cache (`None` unless enabled via
    /// [`Runtime::with_artifact_cache`]): repeat sessions on a
    /// (kernel-key, device) pair skip eager compilation and the
    /// simulated driver init. Opt-in so uncached runtimes keep their
    /// init timing byte-identical to the pre-cache behavior.
    artifacts: Option<Arc<ArtifactCache>>,
    state: Mutex<RtState>,
    idle: Condvar,
}

/// The persistent multi-run runtime (see module docs).
pub struct Runtime {
    shared: Arc<RuntimeShared>,
}

impl Runtime {
    /// A runtime over `node` with the deterministic rotation lease
    /// policy, no in-flight cap and seed 0.
    pub fn new(registry: ArtifactRegistry, node: NodeConfig) -> Self {
        Self::configured(registry, node, LeasePolicy::Rotation, usize::MAX, 0)
    }

    pub fn configured(
        registry: ArtifactRegistry,
        node: NodeConfig,
        policy: LeasePolicy,
        max_in_flight: usize,
        seed: u64,
    ) -> Self {
        Self::qos_configured(registry, node, policy, max_in_flight, seed, QosPolicy::default())
    }

    /// [`Runtime::configured`] plus a [`QosPolicy`]: predictive
    /// admission rejection, best-effort shedding and scheduler QoS
    /// hints (all inert under `QosPolicy::default()`).
    pub fn qos_configured(
        registry: ArtifactRegistry,
        node: NodeConfig,
        policy: LeasePolicy,
        max_in_flight: usize,
        seed: u64,
        qos: QosPolicy,
    ) -> Self {
        let arbiter = LeaseArbiter::new(node.devices.len(), policy);
        Self {
            shared: Arc::new(RuntimeShared {
                registry,
                node,
                arbiter,
                perf: Arc::new(PerfModelStore::new()),
                seed,
                max_in_flight: max_in_flight.max(1),
                qos,
                qos_ctl: Arc::new(QosController::new(seed, qos)),
                artifacts: None,
                state: Mutex::new(RtState {
                    next_session: 0,
                    in_flight: 0,
                    queue: VecDeque::new(),
                    admitted_order: Vec::new(),
                }),
                idle: Condvar::new(),
            }),
        }
    }

    pub fn node(&self) -> &NodeConfig {
        &self.shared.node
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.shared.registry
    }

    /// The shared lease arbiter (journal, holders — the concurrency
    /// battery's observables).
    pub fn arbiter(&self) -> &Arc<LeaseArbiter> {
        &self.shared.arbiter
    }

    /// The global lease-grant journal so far.
    pub fn lease_journal(&self) -> Vec<GrantRecord> {
        self.shared.arbiter.journal()
    }

    /// The runtime's persistent performance model: per-(kernel, device)
    /// throughput estimates accumulated across every session this
    /// runtime has executed — what later sessions warm-start from.
    pub fn perf_model(&self) -> &Arc<PerfModelStore> {
        &self.shared.perf
    }

    /// The QoS shed/preempt controller (its journal is the
    /// replayability observable of every pause/resume/reject decision).
    pub fn qos(&self) -> &Arc<QosController> {
        &self.shared.qos_ctl
    }

    pub fn qos_policy(&self) -> QosPolicy {
        self.shared.qos
    }

    /// Enable the compiled-artifact cache (builder-style; call before
    /// the first submission — the service front-end's repeat-traffic
    /// path). Each (kernel-key, device) pair pays its setup once per
    /// runtime; later sessions skip eager compilation and the simulated
    /// driver init, and their reports record the hit
    /// ([`RunReport::artifact_cache_hits`]).
    pub fn with_artifact_cache(mut self) -> Self {
        if let Some(shared) = Arc::get_mut(&mut self.shared) {
            shared.artifacts = Some(Arc::new(ArtifactCache::new()));
        }
        self
    }

    /// The artifact cache, when enabled.
    pub fn artifact_cache(&self) -> Option<&Arc<ArtifactCache>> {
        self.shared.artifacts.as_ref()
    }

    /// Sessions in admission-grant order — what the EDF tie-break and
    /// starvation tests assert on.
    pub fn admission_order(&self) -> Vec<SessionId> {
        self.shared.state.lock().unwrap_or_else(|e| e.into_inner()).admitted_order.clone()
    }

    /// Price a session as admission would right now: the performance
    /// model's rates for its kernel key (contention-degraded by current
    /// lease registrations) over its selected devices. `None` when the
    /// spec is malformed (unknown kernel, bad device index) — admission
    /// surfaces those as their own errors.
    pub fn predict_session(&self, spec: &RunSession) -> Option<MakespanEstimate> {
        let selected = resolve_devices(&self.shared.node, spec);
        check_device_selection(&self.shared.node, &selected).ok()?;
        predict_for(&self.shared, spec, &selected)
    }

    /// Submit one session. Admission is immediate when a slot is free,
    /// else the session queues (FIFO; deadlines jump the queue,
    /// earliest first).
    pub fn submit(&self, session: RunSession) -> SessionHandle {
        self.submit_all(vec![session]).pop().expect("one handle per session")
    }

    /// Submit a batch atomically: every session is enqueued — and every
    /// admissible one *registered with the lease arbiter* — under a
    /// single lock before any of them spawns. This is what makes batch
    /// lease rotation deterministic: the rotation order is the batch
    /// order, never the wall-clock order in which session threads
    /// happen to start.
    pub fn submit_all(&self, sessions: Vec<RunSession>) -> Vec<SessionHandle> {
        let mut handles = Vec::with_capacity(sessions.len());
        let ready = {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            for mut spec in sessions {
                let session = st.next_session;
                st.next_session += 1;
                if spec.label.is_empty() {
                    spec.label = format!("session-{session}");
                }
                let (tx, rx) = channel();
                handles.push(SessionHandle { session, label: spec.label.clone(), rx });
                st.queue.push_back(QueuedSession { session, spec, tx, bypassed: 0 });
            }
            admit(&self.shared, &mut st)
        };
        for adm in ready {
            spawn_session(&self.shared, adm);
        }
        handles
    }

    /// Block until no session is running or queued.
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        while st.in_flight > 0 || !st.queue.is_empty() {
            st = self.shared.idle.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// The effective device selection of a spec: its explicit list, or the
/// whole node when empty.
fn resolve_devices(node: &NodeConfig, spec: &RunSession) -> Vec<DeviceSpec> {
    if spec.devices.is_empty() {
        (0..node.devices.len()).map(DeviceSpec::new).collect()
    } else {
        spec.devices.clone()
    }
}

/// Price `spec` with the [`MakespanPredictor`]: its work in granules
/// over `selected`, each device's rate degraded by its current lease
/// registrations (the predicted session counts itself as one sharer —
/// it is not registered yet when admission prices it). `None` when the
/// spec is malformed (unknown kernel / inconsistent manifest) — those
/// surface as their own validation errors downstream.
fn predict_for(
    shared: &RuntimeShared,
    spec: &RunSession,
    selected: &[DeviceSpec],
) -> Option<MakespanEstimate> {
    let kernel = spec.program.kernel_name()?;
    let bench = shared.registry.bench(kernel).ok()?;
    if bench.granule == 0 {
        return None;
    }
    let granules = (spec.gws.unwrap_or(bench.n) / bench.granule) as f64;
    // The store key must match what the session will record under: the
    // effective pipeline depth decides blocking vs "+pipe" spans.
    let depth = spec.pipeline_depth.unwrap_or_else(|| spec.scheduler.pipeline_depth()).max(1);
    let store_key =
        if depth > 1 { format!("{kernel}+pipe") } else { kernel.to_string() };
    let loads: Vec<DeviceLoad> = selected
        .iter()
        .map(|s| {
            let d = &shared.node.devices[s.index];
            DeviceLoad::new(
                d.name.clone(),
                d.relative_power,
                // O(1) participant count — admission prices every
                // queued session, so no snapshot clone on this path.
                shared.arbiter.registered_count(s.index) + 1,
            )
        })
        .collect();
    Some(MakespanPredictor::predict(&shared.perf, &store_key, granules, &loads))
}

/// Pull admissible sessions off the queue (EDF among deadlined
/// sessions — ties broken by the seeded label hash, never submission
/// order — then FIFO, with [`STARVATION_BOUND`] aging so deadlined
/// streams cannot starve the FIFO head) and register their workers with
/// the arbiter. QoS-enabled runtimes additionally price deadlined
/// sessions at admission and reject provably-unfittable ones, and hold
/// best-effort admissions back while any running session is at risk.
/// Runs under the runtime lock; returns the batch for the caller to
/// spawn after unlocking.
fn admit(shared: &Arc<RuntimeShared>, st: &mut RtState) -> Vec<Admitted> {
    let mut out = Vec::new();
    while st.in_flight < shared.max_in_flight && !st.queue.is_empty() {
        let head_starved =
            st.queue.front().map(|q| q.bypassed >= STARVATION_BOUND).unwrap_or(false);
        let pick = if head_starved {
            // Bounded wait: the FIFO head has been bypassed by
            // later-submitted deadlined sessions STARVATION_BOUND
            // times; admit it unconditionally.
            0
        } else {
            (0..st.queue.len())
                .min_by_key(|&i| {
                    let q = &st.queue[i];
                    match q.spec.deadline {
                        Some(d) => (d, admission_tiebreak(shared.seed, &q.spec.label), i),
                        None => (Duration::MAX, u64::MAX, i),
                    }
                })
                .expect("queue checked non-empty")
        };
        // While a deadlined session's slack is negative, admitting more
        // best-effort load would only deepen the contention it is
        // fighting — hold best-effort admissions until the risk clears
        // (deadlined sessions still admit). The starved head overrides
        // even this: bounded wait is the stronger guarantee.
        if shared.qos.enabled
            && !head_starved
            && st.queue[pick].spec.deadline.is_none()
            && shared.qos_ctl.any_at_risk()
        {
            break;
        }
        for bypassed in st.queue.iter_mut().take(pick) {
            bypassed.bypassed += 1;
        }
        let q = st.queue.remove(pick).expect("index from live range");
        let selected = resolve_devices(&shared.node, &q.spec);
        // Bounds-check before touching the arbiter: a bad device index
        // is a client error surfaced on the handle, not a panic inside
        // the admission path.
        if let Err(err) = check_device_selection(&shared.node, &selected) {
            q.tx.send(SessionOutcome {
                session: q.session,
                label: q.spec.label.clone(),
                deadline: q.spec.deadline,
                program: q.spec.program,
                result: Err(err),
            })
            .ok();
            continue;
        }
        let predicted =
            if shared.qos.enabled { predict_for(shared, &q.spec, &selected) } else { None };
        if let (true, Some(deadline), Some(est)) =
            (shared.qos.enabled, q.spec.deadline, predicted.as_ref())
        {
            // Reject only on fully-warm estimates: a cold or half-warm
            // store has no absolute scale and must never turn a
            // feasible session away (pinned by the predictor property
            // suite).
            if est.fully_warm() && est.secs > shared.qos.reject_factor * deadline.as_secs_f64()
            {
                let predicted_dur = Duration::from_secs_f64(est.secs.max(0.0));
                shared.qos_ctl.record_rejection(
                    q.session,
                    &q.spec.label,
                    predicted_dur,
                    deadline,
                );
                q.tx.send(SessionOutcome {
                    session: q.session,
                    label: q.spec.label.clone(),
                    deadline: q.spec.deadline,
                    program: q.spec.program,
                    result: Err(EclError::AdmissionRejected {
                        label: q.spec.label.clone(),
                        predicted: predicted_dur,
                        deadline,
                    }),
                })
                .ok();
                continue;
            }
        }
        let registrations: Vec<DeviceRegistration> = selected
            .iter()
            .map(|s| shared.arbiter.register(s.index, q.session))
            .collect();
        st.in_flight += 1;
        st.admitted_order.push(q.session);
        if shared.qos.enabled {
            let class = if q.spec.deadline.is_some() {
                QosClass::Deadlined
            } else {
                QosClass::BestEffort
            };
            shared.qos_ctl.register(q.session, class);
        }
        out.push(Admitted {
            session: q.session,
            spec: q.spec,
            tx: q.tx,
            selected,
            registrations,
            predicted,
        });
    }
    out
}

fn spawn_session(shared: &Arc<RuntimeShared>, adm: Admitted) {
    let shared = Arc::clone(shared);
    std::thread::Builder::new()
        .name(format!("ecl-session-{}", adm.session))
        .spawn(move || {
            let Admitted { session, spec, tx, selected, registrations, predicted } = adm;
            let RunSession {
                mut program,
                devices: _,
                scheduler,
                pipeline_depth,
                gws,
                mut config,
                deadline,
                label,
            } = spec;
            if config.rng_seed == 0 {
                // Per-session jitter stream, derived deterministically
                // from the runtime seed and the admission-ordered id.
                config.rng_seed =
                    shared.seed ^ session.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            }
            let qos = if shared.qos.enabled {
                Some(SessionQosCtx {
                    ctl: Arc::clone(&shared.qos_ctl),
                    deadline,
                    predicted_secs: predicted.map(|e| e.secs),
                })
            } else {
                None
            };
            let exec = SessionExec {
                registry: shared.registry.clone(),
                node: shared.node.clone(),
                selected,
                scheduler,
                pipeline_depth,
                config,
                gws,
                session,
                leases: SessionLeases {
                    arbiter: Arc::clone(&shared.arbiter),
                    registrations,
                },
                perf: Some(Arc::clone(&shared.perf)),
                qos,
                artifacts: shared.artifacts.clone(),
            };
            // A panicking session must not leak its admission slot
            // (queued sessions would never admit and wait_idle would
            // hang): catch the unwind, surface it as an error outcome,
            // and fall through to the slot bookkeeping below.
            let result = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                exec.run(&mut program)
            })) {
                Ok(result) => result,
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<&'static str>()
                        .map(|s| (*s).to_string())
                        .or_else(|| payload.downcast_ref::<String>().cloned())
                        .unwrap_or_else(|| "session execution panicked".to_string());
                    Err(EclError::Runtime(format!("session panicked: {msg}")))
                }
            };
            // Deregister from the controller *before* re-admitting: an
            // ended at-risk session must release its shed victims and
            // unblock queued best-effort admissions in the same step
            // that frees its slot.
            if shared.qos.enabled {
                shared.qos_ctl.deregister(session);
            }
            tx.send(SessionOutcome { session, label, deadline, program, result }).ok();

            // This slot is free: admit the next queued session(s).
            let ready = {
                let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
                st.in_flight -= 1;
                admit(&shared, &mut st)
            };
            for next in ready {
                spawn_session(&shared, next);
            }
            shared.idle.notify_all();
        })
        .expect("spawn session thread");
}

// ---- the session execution core ---------------------------------------

/// The lease context a session executes under: the shared arbiter plus
/// one registration per selected device slot (made at admission, in
/// admission order).
pub(crate) struct SessionLeases {
    pub arbiter: Arc<LeaseArbiter>,
    pub registrations: Vec<DeviceRegistration>,
}

/// The QoS context a runtime session executes under: the shared
/// controller (slack reports in, pause state out) plus the admission
/// prediction that seeds the schedulers' QoS hint. Absent for solo
/// engine runs and QoS-disabled runtimes.
pub(crate) struct SessionQosCtx {
    pub ctl: Arc<QosController>,
    pub deadline: Option<Duration>,
    /// Admission-time predicted makespan (secs), when the predictor
    /// could price the session.
    pub predicted_secs: Option<f64>,
}

/// One session's execution plan — the code that used to be
/// `Engine::run_inner`, parameterized by the lease context so engine
/// (solo) and runtime (concurrent) sessions share every line of the
/// validation, worker and master-loop machinery.
pub(crate) struct SessionExec {
    pub registry: ArtifactRegistry,
    pub node: NodeConfig,
    pub selected: Vec<DeviceSpec>,
    pub scheduler: SchedulerKind,
    pub pipeline_depth: Option<usize>,
    pub config: Configurator,
    pub gws: Option<usize>,
    pub session: SessionId,
    pub leases: SessionLeases,
    /// The cross-session performance model (the runtime's, or the
    /// engine's for solo runs): queried for scheduler warm-start rates
    /// when `config.warm_start` is on, and fed this session's
    /// observation ledger at the end of the run — failure or not.
    pub perf: Option<Arc<PerfModelStore>>,
    /// QoS participation (runtime sessions under an enabled policy):
    /// deadlined masters report slack, best-effort masters honor
    /// pause/resume, and the deadline + admission prediction become the
    /// schedulers' [`QosHint`].
    pub qos: Option<SessionQosCtx>,
    /// The runtime's compiled-artifact cache, when enabled: workers
    /// probe it at init and skip setup on a hit (see
    /// `platform::artifact_cache`). `None` for solo engine runs and
    /// uncached runtimes.
    pub artifacts: Option<Arc<ArtifactCache>>,
}

impl SessionExec {
    pub(crate) fn run(self, program: &mut Program) -> Result<RunReport, EclError> {
        let SessionExec {
            registry,
            node,
            selected,
            scheduler,
            pipeline_depth,
            config,
            gws,
            session,
            leases,
            perf,
            qos,
            artifacts,
        } = self;
        let SessionLeases { arbiter, registrations } = leases;
        debug_assert_eq!(registrations.len(), selected.len());

        if selected.is_empty() {
            return Err(EclError::NoDevices);
        }
        check_device_selection(&node, &selected)?;
        let kernel = program.kernel_name().ok_or(EclError::NoProgram)?.to_string();
        let bench = registry
            .bench(&kernel)
            .map_err(|_| EclError::UnknownKernel(kernel.clone()))?
            .clone();

        // ---- validation (the checks OpenCL leaves to the programmer) --
        let gws = gws.unwrap_or(bench.n);
        if gws > bench.n {
            return Err(EclError::WorkSizeTooLarge { gws, n: bench.n });
        }
        if gws % bench.granule != 0 {
            return Err(EclError::MisalignedWorkSize { gws, granule: bench.granule });
        }
        if program.inputs().len() != bench.inputs.len() {
            return Err(EclError::InputArity {
                expected: bench.inputs.len(),
                got: program.inputs().len(),
            });
        }
        if program.outputs().len() != bench.outputs.len() {
            return Err(EclError::OutputArity {
                expected: bench.outputs.len(),
                got: program.outputs().len(),
            });
        }
        for (spec, buf) in bench.inputs.iter().zip(program.inputs()) {
            if buf.len() != spec.elems {
                return Err(EclError::BufferSize {
                    name: spec.name.clone(),
                    expected: spec.elems,
                    got: buf.len(),
                });
            }
        }
        for (spec, buf) in bench.outputs.iter().zip(program.outputs()) {
            if buf.len() != spec.elems {
                return Err(EclError::BufferSize {
                    name: spec.name.clone(),
                    expected: spec.elems,
                    got: buf.len(),
                });
            }
            // Validated *before* any buffer is moved into the arena: a
            // failure here must not destroy outputs already taken.
            if buf.host().as_f32().is_none() {
                return Err(EclError::Runtime(format!(
                    "output buffer '{}' must be f32",
                    spec.name
                )));
            }
            // The arena windows are item-addressed, so the manifest
            // geometry must be internally consistent before we commit
            // the program's buffers to it.
            if spec.elems != bench.n * spec.elems_per_item {
                return Err(EclError::Runtime(format!(
                    "manifest output '{}' inconsistent: {} elems for {} items x {} per item",
                    spec.name, spec.elems, bench.n, spec.elems_per_item
                )));
            }
        }
        if bench.granule == 0 || bench.n % bench.granule != 0 {
            return Err(EclError::Runtime(format!(
                "manifest geometry inconsistent: n={} granule={}",
                bench.n, bench.granule
            )));
        }
        validate_args(program.args(), &bench.scalars)?;
        if let SchedulerKind::Static { props: Some(p), .. } = scheduler.base() {
            if p.len() != selected.len() {
                return Err(EclError::BadProportions {
                    got: p.len(),
                    devices: selected.len(),
                });
            }
        }
        // A fault plan naming a device slot outside the selection would
        // silently never fire — the chaos run would "pass" without ever
        // exercising recovery. Reject it up front.
        if let Some(plan) = &config.fault_plan {
            for spec in &plan.faults {
                if spec.device >= selected.len() {
                    return Err(EclError::Runtime(format!(
                        "fault plan targets device slot {} but only {} device(s) are selected",
                        spec.device,
                        selected.len()
                    )));
                }
            }
        }
        let depth = match pipeline_depth {
            Some(d) => d,
            None => scheduler.pipeline_depth(),
        }
        .max(1);
        if depth > MAX_PIPELINE_DEPTH {
            return Err(EclError::BadPipelineDepth { depth, max: MAX_PIPELINE_DEPTH });
        }

        // The performance-model / artifact-cache key carries the
        // execution mode: pipelined spans exclude the staging they
        // overlap, blocking spans include it, so the two must never
        // seed each other's warm start — nor alias each other's
        // compiled artifacts.
        let store_key = if depth > 1 { format!("{kernel}+pipe") } else { kernel.clone() };

        // ---- zero-copy buffer setup ------------------------------------
        // Inputs: one shared immutable view per program input (a single
        // O(N) materialization; every worker shares the allocation).
        let inputs: Vec<InputView> = input_views(program.inputs().iter().map(|b| b.host()))
            .map_err(|e| EclError::Runtime(format!("{e:#}")))?;
        // Outputs: move the program's buffers into the run's arena.
        // Workers claim disjoint granule-aligned windows and write
        // results in place; the buffers come back after the join. All
        // outputs were already validated f32 above, so this loop is
        // infallible — it can never abandon a half-taken program.
        let mut arena_bufs: Vec<(Vec<f32>, usize)> = Vec::with_capacity(bench.outputs.len());
        for (spec, out) in bench.outputs.iter().zip(program.outputs_mut()) {
            let data = out
                .host_mut()
                .as_f32_mut()
                .expect("outputs validated f32 above");
            arena_bufs.push((std::mem::take(data), spec.elems_per_item));
        }
        let arena = Arc::new(
            OutputArena::new(arena_bufs, bench.granule, bench.n)
                .map_err(|e| EclError::Runtime(format!("{e:#}")))?,
        );

        // ---- spawn device workers -------------------------------------
        let epoch = Instant::now();
        let has_cpu = selected
            .iter()
            .any(|s| node.devices[s.index].kind == DeviceKind::Cpu);
        let coexec = selected.len() > 1;

        // Master parking handles: tokens collected before the
        // registrations move into their workers.
        let tokens: Vec<u64> = registrations.iter().map(|r| r.token()).collect();
        let node_devs: Vec<usize> = selected.iter().map(|s| s.index).collect();

        let (to_master_tx, from_workers) = channel::<FromWorker>();
        let mut to_workers: Vec<Sender<ToWorker>> = Vec::new();
        let mut handles = Vec::new();
        let init_barrier = Arc::new(std::sync::Barrier::new(selected.len()));
        for ((slot, spec), lease) in selected.iter().enumerate().zip(registrations) {
            let profile = node.devices[spec.index].clone();
            let contended = coexec
                && has_cpu
                && profile.kind == DeviceKind::Accelerator
                && config.simulate_init;
            let (tx, rx) = channel::<ToWorker>();
            to_workers.push(tx);
            let ctx = WorkerCtx {
                dev: slot,
                profile,
                registry: registry.clone(),
                bench: bench.clone(),
                inputs: inputs.clone(),
                arena: Arc::clone(&arena),
                config: config.clone(),
                epoch,
                contended_init: contended,
                init_barrier: Arc::clone(&init_barrier),
                pipeline_depth: depth,
                seed: (0x9E3779B9u64 ^ config.rng_seed)
                    .wrapping_add((slot as u64).wrapping_mul(0x85EBCA77)),
                injector: config
                    .fault_plan
                    .as_ref()
                    .map(|p| p.injector_for(slot))
                    .unwrap_or_default(),
                lease,
                artifacts: artifacts.as_ref().map(|c| (Arc::clone(c), store_key.clone())),
            };
            handles.push(spawn_worker(ctx, to_master_tx.clone(), rx));
        }
        drop(to_master_tx);

        // ---- master scheduling loop ------------------------------------
        // Feedback-capable schedulers warm-start from the performance
        // model's cross-session estimates: the first package of this
        // run is already sized for the throughput earlier sessions
        // *measured*, not the profile's static prior.
        // Deadlined sessions hand the schedulers a QoS hint (deadline +
        // admission-time prediction): feedback strategies tighten their
        // package sizing when the deadline is at risk.
        let qos_hint: Option<QosHint> = qos.as_ref().and_then(|ctx| {
            ctx.deadline
                .map(|d| QosHint::new(d.as_secs_f64(), ctx.predicted_secs.unwrap_or(0.0)))
        });
        let sched_devices: Vec<SchedDevice> = selected
            .iter()
            .map(|s| {
                let d = &node.devices[s.index];
                let warm = if config.warm_start {
                    // Same hygiene as the MakespanPredictor: a
                    // zero/NaN/Inf rate from a degenerate store entry
                    // must cold-start the scheduler, not poison its
                    // throughput model.
                    perf.as_ref()
                        .and_then(|p| p.estimate(&store_key, &d.name))
                        .filter(|r| r.is_finite() && *r > 0.0)
                } else {
                    None
                };
                // Energy warm start rides the same store with the same
                // hygiene: a degenerate joules/granule estimate must
                // cold-start the energy model, not poison it.
                let warm_epg = if config.warm_start {
                    perf.as_ref()
                        .and_then(|p| p.energy_estimate(&store_key, &d.name))
                        .filter(|e| e.is_finite() && *e > 0.0)
                } else {
                    None
                };
                SchedDevice::new(d.name.clone(), d.relative_power)
                    .with_warm_rate(warm)
                    .with_qos(qos_hint)
                    .with_watts(d.busy_watts, d.idle_watts)
                    .with_warm_epg(warm_epg)
            })
            .collect();
        let mut sched = scheduler.build();
        sched.start(gws / bench.granule, bench.granule, &sched_devices);

        let ndev = selected.len();
        let mut device_traces: Vec<DeviceTrace> = selected
            .iter()
            .map(|s| {
                let d = &node.devices[s.index];
                DeviceTrace {
                    name: d.name.clone(),
                    kind: d.kind,
                    init_start: Default::default(),
                    init_end: Default::default(),
                    packages: Vec::new(),
                    xfer: Default::default(),
                    lease_wait: Default::default(),
                    cache_hit: None,
                    busy_watts: d.busy_watts,
                    idle_watts: d.idle_watts,
                    refused: false,
                }
            })
            .collect();
        // Assignments whose H2D staging has not been confirmed by an
        // Uploaded event yet (pipelined devices only) are capped at 2:
        // one staging, one queued behind it — back-pressure so a device
        // with a slow bus is never flooded with un-staged ranges while
        // an adaptive scheduler could still size them better elsewhere.
        let staging_cap = if depth > 1 { 2 } else { usize::MAX };
        let mut master = MasterState {
            depth,
            staging_cap,
            granule: bench.granule,
            fault_tolerant: config.fault_tolerant,
            scheduler: sched,
            to_workers,
            pending: vec![VecDeque::new(); ndev],
            unstaged: vec![0usize; ndev],
            finish_sent: vec![false; ndev],
            failed: vec![false; ndev],
            dry: vec![false; ndev],
            refused: vec![false; ndev],
            // What the scheduler was started with: the granule-aligned
            // item count (a non-aligned gws remainder is never
            // scheduled), so refusal detection compares like with like.
            total_items: (gws / bench.granule) * bench.granule,
            reclaimed: VecDeque::new(),
            paused: false,
            completed_items: 0,
            steal: StealState::new(scheduler.steal_policy(), &sched_devices),
            parker: MasterParker {
                arbiter,
                tokens,
                node_devs,
                parked: vec![false; ndev],
            },
        };
        let mut reported = vec![false; ndev];
        let mut finished = 0usize;
        let mut failure: Option<EclError> = None;
        let mut faults: Vec<FaultEvent> = Vec::new();
        // Per-slot observation ledgers (range + timing per completed
        // package), collected from Finished/Failed events and folded
        // into the performance model after the join.
        let mut observations: Vec<Vec<PackageObservation>> = vec![Vec::new(); ndev];

        // How often the idle master sweeps for worker threads that died
        // without reporting (panics are caught and converted to Failed
        // events in the worker shell; the sweep catches *silent* exits —
        // the chaos layer's "vanish" mode, a segfaulting driver).
        // Adaptive since the hot-path flattening: derived from observed
        // package spans instead of a fixed 25ms tick (see LivenessPoll).
        let mut liveness = LivenessPoll::new();
        // Reusable sweep scratch — the steady-state loop allocates
        // nothing per event or per timeout.
        let mut dead_scratch: Vec<usize> = Vec::with_capacity(ndev);

        // QoS tick state: last progress mark a slack report was sent at
        // (deadlined sessions report only when progress advanced).
        let mut last_slack_report = 0usize;

        while finished < ndev {
            match from_workers.recv_timeout(liveness.current()) {
                Ok(ev) => handle_event(
                    ev,
                    &mut master,
                    &mut liveness,
                    arena.as_ref(),
                    &mut device_traces,
                    &mut observations,
                    &mut reported,
                    &mut finished,
                    &mut faults,
                    &mut failure,
                    epoch,
                ),
                Err(err) => {
                    // Idle, or the channel died. Sweep for workers that
                    // exited without reporting. A disconnected channel
                    // means no worker can ever report again, so every
                    // unreported device is dead regardless of the (racy)
                    // thread-finished flag. Order matters: snapshot the
                    // exited-but-unreported workers *first*, then drain
                    // the channel — a worker that finished cleanly in
                    // the race window between the timeout and the
                    // snapshot sent its Finished/Failed *before* its
                    // thread exited, so the drain honors it; only what
                    // is still unreported after the drain is a genuine
                    // silent death.
                    let disconnected = err == RecvTimeoutError::Disconnected;
                    dead_scratch.clear();
                    dead_scratch.extend(
                        (0..ndev)
                            .filter(|&d| !reported[d] && (disconnected || handles[d].is_finished())),
                    );
                    while let Ok(ev) = from_workers.try_recv() {
                        handle_event(
                            ev,
                            &mut master,
                            &mut liveness,
                            arena.as_ref(),
                            &mut device_traces,
                            &mut observations,
                            &mut reported,
                            &mut finished,
                            &mut faults,
                            &mut failure,
                            epoch,
                        );
                    }
                    for &dev in &dead_scratch {
                        if !reported[dev] {
                            reported[dev] = true;
                            finished += 1;
                            register_failure(
                                &mut master,
                                arena.as_ref(),
                                &device_traces,
                                &mut faults,
                                &mut failure,
                                epoch,
                                dev,
                                "worker exited without reporting a result (dead channel)"
                                    .to_string(),
                            );
                        }
                    }
                }
            }
            // QoS tick (every loop iteration — event or liveness poll).
            // Deadlined: project the remaining work at the observed
            // rate and report the slack; the controller sheds a
            // best-effort victim when it goes negative. Best-effort:
            // honor the controller's pause state — top_up stops
            // assigning (and parks drained slots) while paused, and
            // resuming tops every live device back up.
            if let Some(ctx) = &qos {
                match ctx.deadline {
                    Some(deadline) => {
                        if master.completed_items > last_slack_report {
                            last_slack_report = master.completed_items;
                            let elapsed = epoch.elapsed().as_secs_f64();
                            let rate = master.completed_items as f64 / elapsed.max(1e-9);
                            let remaining =
                                gws.saturating_sub(master.completed_items) as f64 / rate.max(1e-9);
                            ctx.ctl.report_slack(
                                session,
                                deadline.as_secs_f64() - elapsed - remaining,
                            );
                        }
                    }
                    None => {
                        let paused = ctx.ctl.is_paused(session);
                        if paused != master.paused {
                            master.paused = paused;
                            for dev in 0..ndev {
                                master.top_up(dev);
                            }
                        }
                    }
                }
            }
            // Fault-tolerant mode defers Finish until every range is
            // provably complete (see MasterState::finish_if_complete).
            master.finish_if_complete();
        }
        for h in handles {
            let _ = h.join();
        }

        // ---- feed the performance model --------------------------------
        // One transactional ingest per session (a single lock hold in
        // `record_session`): device slots in order, packages in
        // completion order — concurrent sessions serialize at session
        // granularity and never interleave mid-ledger. Runs *before*
        // the failure return below — a fault-recovered (or even failed)
        // run still contributes every package it completed, so the
        // store's estimates survive device failures.
        if let Some(store) = &perf {
            let granule = bench.granule.max(1) as f64;
            let ledger: Vec<(&str, f64, Duration)> = observations
                .iter()
                .enumerate()
                .flat_map(|(slot, obs)| {
                    let device = device_traces[slot].name.as_str();
                    obs.iter().map(move |o| {
                        (device, o.range.len() as f64 / granule, o.timing.span)
                    })
                })
                .collect();
            store.record_session(session, &store_key, &ledger);
            // The energy ledger rides the same observations: joules per
            // package = busy watts × occupancy span, normalized to
            // granules by the store. Observations are recorded exactly
            // once per completed package (a requeued range's joules are
            // billed only by the survivor that actually computed it),
            // so the energy model never double-bills recovered work.
            let energy_ledger: Vec<(&str, f64, f64)> = observations
                .iter()
                .enumerate()
                .flat_map(|(slot, obs)| {
                    let device = device_traces[slot].name.as_str();
                    let watts = device_traces[slot].busy_watts;
                    obs.iter().map(move |o| {
                        (
                            device,
                            o.range.len() as f64 / granule,
                            watts * o.timing.span.as_secs_f64(),
                        )
                    })
                })
                .collect();
            store.record_session_energy(session, &store_key, &energy_ledger);
        }

        // ---- recover the arena: results are already in place -----------
        // Every worker wrote its packages directly into disjoint arena
        // windows, so "collecting results" is handing the allocations
        // back to the program's containers — no merge, no copy. Done
        // before the failure return so partial results survive a worker
        // failure, matching the seed's semantics.
        match Arc::try_unwrap(arena) {
            Ok(arena) => {
                for (buf, out) in arena.into_buffers().into_iter().zip(program.outputs_mut()) {
                    out.store(HostBuf::F32(buf));
                }
            }
            Err(_) => {
                failure.get_or_insert(EclError::Runtime(
                    "output arena still shared after worker join".into(),
                ));
            }
        }
        if let Some(e) = failure {
            return Err(e);
        }

        // The label reflects the *effective* depth: a Tier-1
        // pipeline(1) override on a "+pipe" spec ran blocking, and vice
        // versa — harness pairings key off this suffix.
        let mut scheduler_label = master.scheduler.name();
        if depth > 1 && !scheduler_label.contains("+pipe") {
            scheduler_label.push_str("+pipe");
        } else if depth <= 1 && scheduler_label.ends_with("+pipe") {
            let len = scheduler_label.len() - "+pipe".len();
            scheduler_label.truncate(len);
        }
        // Surface scheduler refusals (tail cutoff, energy exclusion) on
        // the traces so the balance metrics can exclude deliberate
        // non-participants.
        for (dev, trace) in device_traces.iter_mut().enumerate() {
            trace.refused = master.refused[dev];
        }
        Ok(RunReport {
            bench: bench.name.clone(),
            scheduler: scheduler_label,
            session,
            gws,
            wall: epoch.elapsed(),
            devices: device_traces,
            faults,
            steals_issued: master.steal.issued,
        })
    }
}

/// Floor of the adaptive liveness poll: never spin faster than this
/// even on microsecond packages.
const LIVENESS_POLL_MIN: Duration = Duration::from_millis(5);
/// Ceiling of the adaptive liveness poll: a vanish is detected within
/// this bound even on very long packages.
const LIVENESS_POLL_MAX: Duration = Duration::from_millis(250);
/// Default poll before the first package completes (the seed's fixed
/// tick).
const LIVENESS_POLL_DEFAULT: Duration = Duration::from_millis(25);
/// EWMA weight for observed package spans.
const LIVENESS_EWMA_ALPHA: f64 = 0.2;

/// Adaptive liveness poll: how long the idle master sleeps in
/// `recv_timeout` before sweeping for silently-dead workers. Derived
/// from the EWMA of observed package spans (half a span, clamped to
/// `[LIVENESS_POLL_MIN, LIVENESS_POLL_MAX]`): short packages mean
/// frequent events anyway, so a short poll costs nothing and catches a
/// vanish fast; long packages mean the master would otherwise burn
/// wakeups sweeping a healthy run every 25ms. Worker-channel
/// disconnects are detected immediately regardless (the `recv` returns
/// `Disconnected`, not a timeout) — the poll only bounds detection of
/// a thread that exited while *other* workers keep the channel open.
struct LivenessPoll {
    ewma_secs: f64,
    observed: bool,
}

impl LivenessPoll {
    fn new() -> Self {
        Self { ewma_secs: 0.0, observed: false }
    }

    /// Feed one completed package's occupancy span.
    fn observe(&mut self, span: Duration) {
        let s = span.as_secs_f64();
        if self.observed {
            self.ewma_secs += LIVENESS_EWMA_ALPHA * (s - self.ewma_secs);
        } else {
            self.ewma_secs = s;
            self.observed = true;
        }
    }

    /// The poll to use for the next idle wait.
    fn current(&self) -> Duration {
        if !self.observed {
            return LIVENESS_POLL_DEFAULT;
        }
        Duration::from_secs_f64(self.ewma_secs * 0.5)
            .clamp(LIVENESS_POLL_MIN, LIVENESS_POLL_MAX)
    }
}

/// The master's view of its session's lease participation: one token
/// per device slot, parked while that slot provably has nothing to
/// request (so the rotation never waits on a finished session).
struct MasterParker {
    arbiter: Arc<LeaseArbiter>,
    tokens: Vec<u64>,
    node_devs: Vec<usize>,
    parked: Vec<bool>,
}

impl MasterParker {
    fn set(&mut self, slot: usize, parked: bool) {
        if self.parked[slot] != parked {
            self.parked[slot] = parked;
            self.arbiter.set_parked(self.node_devs[slot], self.tokens[slot], parked);
        }
    }
}

/// EWMA weight of the steal-pricing throughput model. More responsive
/// than the schedulers' own models: steal decisions fire at the tail of
/// a run, where the latest package spans (a hotspot band, a degraded
/// device) matter more than the run-long average.
/// (Public so the `run --steal` virtual-clock bench prices its steals
/// with the exact model the master uses.)
pub const STEAL_MODEL_ALPHA: f64 = 0.4;

/// Master-side cooperative-stealing state (the `+steal` suffix): the
/// policy, the throughput model that prices candidate steals, the
/// per-victim outstanding-revocation markers, and the pool of yielded
/// ranges awaiting re-dispatch. Inert (`policy = Off`, empty pool,
/// never-consulted model) for every non-stealing spec.
struct StealState {
    policy: StealPolicy,
    /// Throughput estimates feeding [`price_steal`] — master-owned so
    /// pricing works identically over every scheduler family (the
    /// wrapped strategy may not keep a model of its own).
    model: ThroughputModel,
    /// `outstanding[victim] = Some(thief)` while a `Steal` sent to
    /// `victim` is un-acked. The victim's `top_up` is suppressed for
    /// the window — the worker's truncation runs against the queue as
    /// *it* saw it, so the master must not append ranges the ack's
    /// back-matching would then misattribute.
    outstanding: Vec<Option<usize>>,
    /// Yielded ranges awaiting re-dispatch: drained by `next_range`
    /// after the fault-recovery queue, before the scheduler.
    pool: VecDeque<Range>,
    /// `Steal` messages issued (acked or not) — surfaced on the report.
    issued: usize,
    /// Work-items that actually moved (sum over acked yields).
    items_moved: usize,
    /// `cooling[victim]` after an empty yield: the victim's local queue
    /// was already drained (everything in flight or staged), so
    /// re-pricing it before its next `Done` would just ping-pong
    /// Steal/Yielded messages at channel speed. Cleared on `Done`.
    cooling: Vec<bool>,
}

impl StealState {
    fn new(policy: StealPolicy, devices: &[SchedDevice]) -> Self {
        let mut model = ThroughputModel::new(STEAL_MODEL_ALPHA);
        model.start(devices);
        Self {
            policy,
            model,
            outstanding: vec![None; devices.len()],
            pool: VecDeque::new(),
            issued: 0,
            items_moved: 0,
            cooling: vec![false; devices.len()],
        }
    }

    /// `dev` is the thief of an un-acked steal (at most one at a time:
    /// the priced backlog is not re-priceable until the yield lands).
    fn thieving(&self, dev: usize) -> bool {
        self.outstanding.iter().any(|o| *o == Some(dev))
    }
}

/// Recovery-aware assignment state for the master loop: per-device
/// in-flight ranges (what recovery must reclaim when a device dies),
/// staging back-pressure counters, and the shared queue of reclaimed
/// ranges that survivors drain before asking the scheduler.
struct MasterState {
    depth: usize,
    staging_cap: usize,
    granule: usize,
    fault_tolerant: bool,
    scheduler: Box<dyn Scheduler>,
    to_workers: Vec<Sender<ToWorker>>,
    /// Ranges assigned but not yet reported `Done`, per device, in
    /// execution (assignment) order.
    pending: Vec<VecDeque<Range>>,
    unstaged: Vec<usize>,
    finish_sent: Vec<bool>,
    failed: Vec<bool>,
    /// The scheduler returned `None` for this device (terminal, per the
    /// trait contract).
    dry: Vec<bool>,
    /// The scheduler returned `None` for this device *while unassigned
    /// work still remained* — a deliberate refusal (tail cutoff, energy
    /// exclusion), not pool exhaustion. Surfaced on [`DeviceTrace`] so
    /// the balance metrics can tell the two apart.
    refused: Vec<bool>,
    /// Granule-aligned work items the scheduler was started with.
    total_items: usize,
    /// Reclaimed ranges awaiting requeue.
    reclaimed: VecDeque<Range>,
    /// QoS preemption: a paused (shed) best-effort session stops
    /// assigning new packages — in-flight work drains, drained slots
    /// park — until the controller resumes it.
    paused: bool,
    /// Items whose packages have completed so far (the deadlined
    /// master's slack-projection input).
    completed_items: usize,
    /// Cooperative stealing (inert under non-`+steal` specs).
    steal: StealState,
    parker: MasterParker,
}

/// What `MasterState::handle_failure` did, for the fault event record.
struct FailureOutcome {
    reclaimed_items: usize,
    revoked_claims: usize,
    recovered: bool,
}

impl MasterState {
    fn ndev(&self) -> usize {
        self.pending.len()
    }

    fn next_scheduler_range(&mut self, dev: usize) -> Option<Range> {
        if self.dry[dev] {
            return None;
        }
        let r = self.scheduler.next_package(dev);
        if r.is_none() {
            self.dry[dev] = true;
            // Refusal vs exhaustion: if items remain that are neither
            // completed, in flight, awaiting requeue, nor pooled from a
            // steal, the scheduler still *had* work and chose not to
            // feed this device.
            let accounted: usize = self.completed_items
                + self.pending.iter().map(|q| q.iter().map(Range::len).sum::<usize>()).sum::<usize>()
                + self.reclaimed.iter().map(Range::len).sum::<usize>()
                + self.steal.pool.iter().map(Range::len).sum::<usize>();
            if accounted < self.total_items {
                self.refused[dev] = true;
            }
        }
        r
    }

    /// The next range for `dev`: reclaimed (requeued) work first, then
    /// stolen work awaiting re-dispatch, then the scheduler. Returns
    /// the range plus its (requeued, stolen) trace flags.
    fn next_range(&mut self, dev: usize) -> Option<(Range, bool, bool)> {
        if let Some(r) = self.reclaimed.pop_front() {
            return Some((r, true, false));
        }
        if let Some(r) = self.steal.pool.pop_front() {
            return Some((r, false, true));
        }
        self.next_scheduler_range(dev).map(|r| (r, false, false))
    }

    /// Top device `dev`'s pipeline up to `depth` packages (and at most
    /// `staging_cap` unconfirmed stagings). Two phases: every scheduler
    /// decision for this refill is computed first (into an inline,
    /// allocation-free [`AssignBatch`]), then the whole refill ships as
    /// a single channel send. The decision sequence is identical to the
    /// seed's one-send-per-decision loop — reclaimed work first, then
    /// the scheduler, with the pipelined lookahead pulled under the
    /// same guards — but the scheduler is never blocked behind a
    /// worker channel, and a pipelined worker's whole refill arrives in
    /// one message.
    fn top_up(&mut self, dev: usize) {
        if self.finish_sent[dev] || self.failed[dev] {
            return;
        }
        // Victim suppression: while a Steal to this device is un-acked
        // the master appends nothing — the worker's truncation runs
        // against the queue as it saw it, and the ack's back-matching
        // against `pending` must see exactly that queue. The ack
        // handler re-enters top_up with the marker cleared.
        if self.steal.outstanding[dev].is_some() {
            return;
        }
        if self.paused {
            // Shed best-effort session: assign nothing new (in-flight
            // work drains) and park slots with nothing pending, so the
            // lease rotation never waits on the preempted session. The
            // resume path re-enters top_up with `paused` cleared and
            // un-parks on the next assignment.
            if self.pending[dev].is_empty() {
                self.parker.set(dev, true);
            }
            return;
        }
        // Phase 1: decisions. `batch` can never overflow its inline
        // capacity — a refill is bounded by `depth <= MAX_PIPELINE_DEPTH`
        // pending packages (the `is_full` guards are defensive).
        let mut batch = AssignBatch::new();
        let mut finish = false;
        while self.pending[dev].len() < self.depth
            && self.unstaged[dev] < self.staging_cap
            && !batch.is_full()
        {
            let Some((range, requeued, stolen)) = self.next_range(dev) else {
                // Legacy abort-on-failure mode finishes a device the
                // moment it runs dry (blocking workers only when idle;
                // pipelined workers drain their local queue). The
                // fault-tolerant loop instead defers Finish to
                // `finish_if_complete`: a later failure may still
                // requeue work onto this device.
                if !self.fault_tolerant && (self.pending[dev].is_empty() || self.depth > 1) {
                    finish = true;
                }
                break;
            };
            self.pending[dev].push_back(range);
            if self.depth > 1 {
                self.unstaged[dev] += 1;
            }
            batch.push(range, requeued, stolen);
            // Pipelined lookahead: pull one more scheduler range into
            // the same refill so the pipeline fills off a single
            // message (the seed's `lookahead` field, generalized).
            if self.depth > 1
                && self.pending[dev].len() < self.depth
                && self.unstaged[dev] < self.staging_cap
                && self.reclaimed.is_empty()
                && self.steal.pool.is_empty()
                && !batch.is_full()
            {
                if let Some(n) = self.next_scheduler_range(dev) {
                    self.pending[dev].push_back(n);
                    self.unstaged[dev] += 1;
                    batch.push(n, false, false);
                }
            }
        }
        // Phase 2: ship. Un-park strictly before the batch travels: the
        // arbiter must consider this slot active by the time its worker
        // requests the device lease for the new packages.
        if !batch.is_empty() {
            self.parker.set(dev, false);
            self.to_workers[dev].send(ToWorker::Assign(batch)).ok();
        }
        if finish {
            self.to_workers[dev].send(ToWorker::Finish).ok();
            self.finish_sent[dev] = true;
        }
        // Steal hook: the refill left this device dry with nothing
        // queued anywhere — if another device holds priced-profitable
        // unstarted backlog, revoke some of it (the yield re-enters
        // through the Yielded ack).
        self.try_steal(dev);
        // Park the slot once it provably has nothing left to request:
        // scheduler dry, nothing in flight, nothing reclaimed or
        // stolen pending. A later failure or yield that surfaces work
        // un-parks it (above).
        let idle = self.dry[dev]
            && self.pending[dev].is_empty()
            && self.reclaimed.is_empty()
            && self.steal.pool.is_empty();
        self.parker.set(dev, idle);
    }

    /// Issue at most one steal on behalf of dry device `thief`. The
    /// candidate backlog of a victim is everything beyond its in-flight
    /// package and (pipelined) its staged prefetch — the work its
    /// worker never yields; [`price_steal`] sizes the take so victim
    /// and thief finish together and refuses moves the victim would
    /// finish before the thief's transfer-and-restart cost. Among
    /// profitable victims the one predicted to finish *last* is chosen
    /// — squashing the tail is the whole point.
    fn try_steal(&mut self, thief: usize) {
        if self.steal.policy.is_off()
            || !self.fault_tolerant
            || self.paused
            || !self.dry[thief]
            // A refused device was *deliberately* excluded by the
            // scheduler (tail cutoff, energy objective) — stealing
            // work onto it would override that decision.
            || self.refused[thief]
            || !self.pending[thief].is_empty()
            || !self.reclaimed.is_empty()
            || !self.steal.pool.is_empty()
            || self.steal.thieving(thief)
        {
            return;
        }
        let shielded = if self.depth > 1 { 2 } else { 1 };
        let thief_rate = self.steal.model.rate(thief);
        // (victim, items to request, predicted remaining time).
        let mut best: Option<(usize, usize, f64)> = None;
        for v in 0..self.ndev() {
            if v == thief
                || self.failed[v]
                || self.finish_sent[v]
                || self.steal.outstanding[v].is_some()
                || self.steal.cooling[v]
            {
                continue;
            }
            let backlog: usize =
                self.pending[v].iter().skip(shielded).map(Range::len).sum();
            if backlog < self.granule {
                continue;
            }
            let total: usize = self.pending[v].iter().map(Range::len).sum();
            let victim_rate = self.steal.model.rate(v);
            let Some(take) = price_steal(
                self.steal.policy,
                self.granule,
                backlog,
                total,
                victim_rate,
                thief_rate,
            ) else {
                continue;
            };
            let t_old =
                total as f64 / (self.granule as f64 * victim_rate.max(1e-9));
            if best.map_or(true, |(_, _, t)| t_old > t) {
                best = Some((v, take, t_old));
            }
        }
        let Some((victim, take, _)) = best else { return };
        self.steal.outstanding[victim] = Some(thief);
        self.steal.issued += 1;
        self.to_workers[victim]
            .send(ToWorker::Steal { max_items: take, granule: self.granule })
            .ok();
    }

    /// Fold a victim's `Yielded` ack: retire the outstanding marker,
    /// remove the yielded ranges from the victim's pending ledger
    /// (deepest-first, so each matches the current back — whole or as
    /// a split suffix), defensively revoke any arena claim over them,
    /// pool them, and re-dispatch (thief first).
    fn handle_yield(&mut self, dev: usize, ranges: Vec<Range>, arena: &OutputArena) {
        let thief = self.steal.outstanding[dev].take();
        if self.failed[dev] {
            // The victim is already registered dead (liveness-sweep
            // path): recovery drained and requeued its *whole* pending
            // ledger, the yielded ranges included. Pooling them again
            // would double-requeue — drop the ack.
            return;
        }
        let mut moved = 0usize;
        for r in ranges {
            let matched = match self.pending[dev].back_mut() {
                Some(back) if *back == r => {
                    self.pending[dev].pop_back();
                    true
                }
                Some(back) if back.end == r.end && back.begin < r.begin => {
                    // The worker split this entry at a granule
                    // boundary and kept the front.
                    back.end = r.begin;
                    true
                }
                _ => {
                    // Unreachable by protocol (victim suppression plus
                    // per-worker FIFO order); scan defensively so a
                    // yielded range is never silently lost.
                    debug_assert!(false, "yielded range not at the pending back");
                    match self.pending[dev].iter().position(|p| *p == r) {
                        Some(i) => {
                            self.pending[dev].remove(i);
                            true
                        }
                        None => false,
                    }
                }
            };
            if !matched {
                continue;
            }
            // Yielded ranges are assigned-but-unstarted, so normally no
            // claim covers them and this is a no-op; the partial-revoke
            // contract (exact claim, or the tail of a wider one) covers
            // an executor that claims ahead. SAFETY: the victim acked
            // the revocation — it will never claim or write this range.
            unsafe {
                arena.revoke_tail(r.begin, r.end);
            }
            moved += r.len();
            self.steal.pool.push_back(r);
        }
        self.steal.items_moved += moved;
        if moved > 0 {
            if let Some(t) = thief {
                self.scheduler.on_steal(dev, t, moved);
            }
        } else {
            // Empty ack: the victim's local queue was already drained
            // when the revocation arrived. Its master-side ledger still
            // shows the same backlog (the Dones are in flight behind
            // this ack), so an immediate re-price would re-issue the
            // same steal and spin. Cool the victim until its next Done.
            self.steal.cooling[dev] = true;
        }
        // Re-dispatch: the thief first (the dry device this steal was
        // priced for), then the victim, then — if anything is still
        // pooled (a thief that died while the steal was in flight) —
        // every other live device, so the pool can never strand.
        if let Some(t) = thief {
            self.top_up(t);
        }
        self.top_up(dev);
        if !self.steal.pool.is_empty() {
            for d in 0..self.ndev() {
                self.top_up(d);
            }
        }
    }

    /// Re-evaluate stealing for every dry device. Called after each
    /// completion: the pricing model's rates just moved, so a steal
    /// that was unprofitable a package ago may clear the threshold now
    /// (and a dry device gets no events of its own to re-trigger from).
    fn try_steal_all(&mut self) {
        if self.steal.policy.is_off() {
            return;
        }
        for d in 0..self.ndev() {
            if !self.failed[d] && !self.finish_sent[d] {
                self.try_steal(d);
            }
        }
    }

    /// All work provably done: nothing reclaimed or stolen waits,
    /// nothing is in flight, and the scheduler is dry for every live
    /// device. Only then can no future failure or yield surface new
    /// work (dead devices have nothing pending; a non-empty yield ack
    /// still in the channel implies a non-empty pending ledger), so
    /// Finish is safe to broadcast.
    fn complete(&self) -> bool {
        self.reclaimed.is_empty()
            && self.steal.pool.is_empty()
            && self.pending.iter().all(|q| q.is_empty())
            && (0..self.ndev()).all(|d| self.failed[d] || self.dry[d])
    }

    /// Fault-tolerant finish: broadcast Finish to every live device
    /// once the run is complete. No-op in legacy mode (per-device
    /// Finish already happened in `top_up`).
    fn finish_if_complete(&mut self) {
        if !self.fault_tolerant || !self.complete() {
            return;
        }
        for dev in 0..self.ndev() {
            if !self.failed[dev] && !self.finish_sent[dev] {
                self.to_workers[dev].send(ToWorker::Finish).ok();
                self.finish_sent[dev] = true;
            }
        }
    }

    /// Device `dev`'s worker died. Reclaim its unfinished assignments
    /// plus any scheduler reservation, revoke their arena claims, and
    /// requeue the ranges — each split so every survivor can pull a
    /// piece (a Static share would otherwise land whole on a single
    /// survivor). Legacy mode reclaims nothing (abort semantics). The
    /// dead worker's lease and rotation entry release themselves (RAII
    /// registration drop on thread exit).
    fn handle_failure(&mut self, dev: usize, arena: &OutputArena) -> FailureOutcome {
        self.failed[dev] = true;
        // A Steal sent to this device will never be acked now — or its
        // ack was already processed (per-worker channel order puts any
        // sent Yielded before the failure). Clear the marker so it
        // cannot suppress a top_up or block a later steal decision;
        // the pending drain below requeues whatever the un-acked
        // revocation would have yielded, keeping exactly-once intact.
        self.steal.outstanding[dev] = None;
        let mut ranges: Vec<Range> = self.pending[dev].drain(..).collect();
        ranges.extend(self.scheduler.reclaim_device(dev));
        let reclaimed_items: usize = ranges.iter().map(Range::len).sum();
        if !self.fault_tolerant {
            return FailureOutcome { reclaimed_items, revoked_claims: 0, recovered: false };
        }
        let survivors = (0..self.ndev())
            .filter(|&d| !self.failed[d] && !self.finish_sent[d])
            .count();
        let recovered = reclaimed_items == 0 || survivors > 0;
        let mut revoked_claims = 0usize;
        for r in &ranges {
            // SAFETY: the failed worker has exited (liveness sweep) or
            // reported failure after dropping its windows on the error
            // path, so no live window covers any of these ranges.
            if unsafe { arena.revoke(r.begin, r.end) } {
                revoked_claims += 1;
            }
            if survivors > 0 {
                for piece in split_range(r.begin, r.end, survivors, self.granule) {
                    self.reclaimed.push_back(piece);
                }
            }
        }
        // Also re-dispatch a non-empty steal pool: the failed device
        // may have been the thief a yield was pooled for, and without
        // this broadcast no surviving device would ever be topped up
        // to drain it.
        if !self.reclaimed.is_empty() || !self.steal.pool.is_empty() {
            for d in 0..self.ndev() {
                if !self.failed[d] {
                    self.top_up(d);
                }
            }
        }
        FailureOutcome { reclaimed_items, revoked_claims, recovered }
    }
}

/// Fold one worker event into the master loop's state. Called from the
/// blocking receive and from the liveness sweep's channel drain (which
/// must process every already-sent event before declaring an exited
/// worker silently dead).
#[allow(clippy::too_many_arguments)]
fn handle_event(
    ev: FromWorker,
    master: &mut MasterState,
    liveness: &mut LivenessPoll,
    arena: &OutputArena,
    device_traces: &mut [DeviceTrace],
    observations: &mut [Vec<PackageObservation>],
    reported: &mut [bool],
    finished: &mut usize,
    faults: &mut Vec<FaultEvent>,
    failure: &mut Option<EclError>,
    epoch: Instant,
) {
    match ev {
        FromWorker::Ready { dev, init_start, init_end, cache_hit } => {
            device_traces[dev].init_start = init_start;
            device_traces[dev].init_end = init_end;
            device_traces[dev].cache_hit = cache_hit;
            master.top_up(dev);
        }
        FromWorker::Uploaded { dev } => {
            // An exposed (fill-bubble) staging landed on the device:
            // release its staging slot and keep the pipe full.
            master.unstaged[dev] = master.unstaged[dev].saturating_sub(1);
            master.top_up(dev);
        }
        FromWorker::Done { dev, timing, prefetched } => {
            // A coalesced prefetch rides ahead of the completion: the
            // staging slot frees first, exactly as the standalone
            // `Uploaded` that used to precede this `Done` did.
            if prefetched {
                master.unstaged[dev] = master.unstaged[dev].saturating_sub(1);
                master.top_up(dev);
            }
            liveness.observe(timing.span);
            // Workers execute in assignment order, so the front pending
            // range is the completed one; its results are fully in the
            // arena by the time Done is sent. Close the feedback loop
            // *before* topping up: the next `next_package` for this
            // device must already see the completed package's span.
            if let Some(range) = master.pending[dev].pop_front() {
                master.completed_items += range.len();
                if !master.steal.policy.is_off() {
                    master.steal.model.observe(
                        dev,
                        range.len() as f64 / master.granule.max(1) as f64,
                        timing.span,
                    );
                    // Progress re-arms a victim cooled by an empty
                    // yield: its ledger has genuinely shrunk now.
                    master.steal.cooling[dev] = false;
                }
                master.scheduler.observe(dev, range, timing);
            }
            master.top_up(dev);
            // Every completion moves the pricing model: re-evaluate
            // stealing for any device sitting dry (no-op when off).
            master.try_steal_all();
        }
        FromWorker::Yielded { dev, ranges } => {
            master.handle_yield(dev, ranges, arena);
        }
        FromWorker::Finished { dev, traces, observations: obs, xfer, lease_wait } => {
            device_traces[dev].packages = traces;
            device_traces[dev].xfer = xfer;
            device_traces[dev].lease_wait = lease_wait;
            observations[dev] = obs;
            if !reported[dev] {
                reported[dev] = true;
                *finished += 1;
            }
        }
        FromWorker::Failed { dev, message, traces, observations: obs, xfer, lease_wait } => {
            // The packages the worker *completed* stay attributed to it
            // — their results are already in the arena (and their
            // observations still feed the performance model).
            device_traces[dev].packages = traces;
            device_traces[dev].xfer = xfer;
            device_traces[dev].lease_wait = lease_wait;
            observations[dev] = obs;
            if !reported[dev] {
                reported[dev] = true;
                *finished += 1;
                register_failure(
                    master,
                    arena,
                    device_traces,
                    faults,
                    failure,
                    epoch,
                    dev,
                    message,
                );
            }
        }
    }
}

/// Fold one worker failure into the master state: reclaim + requeue (or
/// record the abort), and append the introspector's fault event.
#[allow(clippy::too_many_arguments)]
fn register_failure(
    master: &mut MasterState,
    arena: &OutputArena,
    device_traces: &[DeviceTrace],
    faults: &mut Vec<FaultEvent>,
    failure: &mut Option<EclError>,
    epoch: Instant,
    dev: usize,
    message: String,
) {
    let outcome = master.handle_failure(dev, arena);
    if !outcome.recovered {
        failure.get_or_insert(EclError::Worker {
            device: device_traces[dev].name.clone(),
            message: message.clone(),
        });
    }
    faults.push(FaultEvent {
        device: dev,
        device_name: device_traces[dev].name.clone(),
        message,
        at: epoch.elapsed(),
        reclaimed_items: outcome.reclaimed_items,
        revoked_claims: outcome.revoked_claims,
        recovered: outcome.recovered,
    });
}

/// The single formatting of the out-of-range device-selection error,
/// shared by every validation site: the engine wrapper and the
/// admission path (which must check *before* registering with the
/// arbiter — registration indexes the device table) and the session
/// core (defensive).
pub(crate) fn check_device_selection(
    node: &NodeConfig,
    selected: &[DeviceSpec],
) -> Result<(), EclError> {
    match selected.iter().find(|s| s.index >= node.devices.len()) {
        Some(bad) => Err(EclError::Runtime(format!(
            "device index {} out of range: node '{}' has {} device(s)",
            bad.index,
            node.name,
            node.devices.len()
        ))),
        None => Ok(()),
    }
}

/// Validate recorded scalar args against the baked manifest scalars.
pub(crate) fn validate_args(
    args: &BTreeMap<usize, Arg>,
    scalars: &BTreeMap<String, f64>,
) -> Result<(), EclError> {
    let baked: Vec<(&String, &f64)> = scalars.iter().collect();
    let mut scalar_idx = 0usize;
    for (index, arg) in args {
        if let Arg::Scalar(v) = arg {
            // Scalars must match some baked value (AOT kernels cannot take
            // new scalar values at run time — the paper's JIT could).
            let matched = baked.iter().any(|(_, bv)| (*bv - v).abs() < 1e-9);
            if !matched {
                let (name, expected) = baked
                    .get(scalar_idx.min(baked.len().saturating_sub(1)))
                    .map(|(n, v)| ((*n).clone(), **v))
                    .unwrap_or(("<none>".into(), f64::NAN));
                return Err(EclError::ArgMismatch { index: *index, name, expected, got: *v });
            }
            scalar_idx += 1;
        }
    }
    if scalar_idx > scalars.len() {
        return Err(EclError::UnknownArg { index: scalar_idx });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_args_accepts_baked_values() {
        let mut scalars = BTreeMap::new();
        scalars.insert("steps".to_string(), 254.0);
        scalars.insert("dt".to_string(), 0.005);
        let mut args = BTreeMap::new();
        args.insert(0, Arg::Scalar(254.0));
        args.insert(1, Arg::BufferRef);
        args.insert(2, Arg::LocalAlloc(1024));
        assert!(validate_args(&args, &scalars).is_ok());
    }

    #[test]
    fn validate_args_rejects_unbaked_scalar() {
        let mut scalars = BTreeMap::new();
        scalars.insert("steps".to_string(), 254.0);
        let mut args = BTreeMap::new();
        args.insert(0, Arg::Scalar(100.0));
        let err = validate_args(&args, &scalars).unwrap_err();
        assert!(matches!(err, EclError::ArgMismatch { .. }));
    }

    fn session_for(reg: &ArtifactRegistry, bench: &str) -> RunSession {
        let program =
            crate::harness::runs::build_program(reg, bench).expect("build test program");
        RunSession::new(program).configure(|c| {
            c.simulate_init = false;
            c.simulate_speed = false;
        })
    }

    #[test]
    fn builder_defaults_and_chaining() {
        let s = RunSession::new(Program::new())
            .scheduler(SchedulerKind::hguided())
            .pipeline(2)
            .gws(512)
            .deadline(Duration::from_millis(100))
            .label("smoke");
        assert!(s.devices.is_empty(), "empty selection = whole node");
        assert_eq!(s.pipeline_depth, Some(2));
        assert_eq!(s.gws, Some(512));
        assert_eq!(s.deadline, Some(Duration::from_millis(100)));
        assert_eq!(s.label, "smoke");
    }

    #[test]
    fn single_session_through_runtime_completes() {
        let reg = ArtifactRegistry::synthetic();
        let rt = Runtime::new(reg.clone(), NodeConfig::batel());
        let handle = rt.submit(
            session_for(&reg, "binomial")
                .scheduler(SchedulerKind::dynamic(8))
                .label("solo"),
        );
        assert_eq!(handle.label(), "solo");
        let outcome = handle.wait();
        let report = outcome.result.as_ref().expect("session completes");
        assert_eq!(report.session, outcome.session);
        let items: usize = report.devices.iter().map(|d| d.items()).sum();
        assert_eq!(items, report.gws, "all work computed exactly once");
        assert!(outcome.output(0).is_some());
        // The session fed the runtime's performance model: every device
        // that computed packages has a (kernel, device) estimate now.
        assert!(rt.perf_model().total_samples() > 0, "session observations ingested");
        for d in report.devices.iter().filter(|d| !d.packages.is_empty()) {
            assert!(
                rt.perf_model().estimate("binomial", &d.name).is_some(),
                "estimate for {} missing",
                d.name
            );
        }
        rt.wait_idle();
        // Every registration retired with its worker.
        for d in 0..rt.node().devices.len() {
            assert!(rt.arbiter().registered_sessions(d).is_empty());
            assert_eq!(rt.arbiter().holder(d), None);
        }
        assert!(!rt.lease_journal().is_empty(), "grants were journaled");
    }

    #[test]
    fn bad_device_index_is_an_error_outcome_not_a_panic() {
        let reg = ArtifactRegistry::synthetic();
        let rt = Runtime::new(reg.clone(), NodeConfig::batel());
        let handle = rt.submit(
            session_for(&reg, "binomial").devices(vec![DeviceSpec::new(17)]),
        );
        let outcome = handle.wait();
        let err = outcome.result.expect_err("out-of-range device must fail");
        assert!(err.to_string().contains("device index 17"), "{err}");
        rt.wait_idle();
    }

    #[test]
    fn met_deadline_accounting() {
        let ok = SessionOutcome {
            session: 0,
            label: "x".into(),
            deadline: Some(Duration::from_secs(3600)),
            program: Program::new(),
            result: Err(EclError::NoProgram),
        };
        assert_eq!(ok.met_deadline(), Some(false), "failed run misses its deadline");
        let none = SessionOutcome {
            session: 0,
            label: "x".into(),
            deadline: None,
            program: Program::new(),
            result: Err(EclError::NoProgram),
        };
        assert_eq!(none.met_deadline(), None);
    }

    /// Build a bare MasterState over `ndev` channel-backed device slots
    /// (no workers) for dispatch-protocol unit tests. The registrations
    /// must stay alive for the parker's tokens to stay valid.
    fn test_master(
        ndev: usize,
        depth: usize,
        kind: SchedulerKind,
        granules: usize,
        granule: usize,
    ) -> (MasterState, Vec<Receiver<ToWorker>>, Vec<DeviceRegistration>) {
        let arbiter = LeaseArbiter::new(ndev, LeasePolicy::Rotation);
        let regs: Vec<DeviceRegistration> = (0..ndev).map(|d| arbiter.register(d, 0)).collect();
        let tokens: Vec<u64> = regs.iter().map(|r| r.token()).collect();
        let devices: Vec<SchedDevice> =
            (0..ndev).map(|d| SchedDevice::new(format!("dev{d}"), 1.0)).collect();
        let mut scheduler = kind.build();
        scheduler.start(granules, granule, &devices);
        let mut to_workers = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..ndev {
            let (tx, rx) = channel();
            to_workers.push(tx);
            rxs.push(rx);
        }
        let master = MasterState {
            depth,
            staging_cap: if depth > 1 { 2 } else { usize::MAX },
            granule,
            fault_tolerant: true,
            scheduler,
            to_workers,
            pending: vec![VecDeque::new(); ndev],
            unstaged: vec![0usize; ndev],
            finish_sent: vec![false; ndev],
            failed: vec![false; ndev],
            dry: vec![false; ndev],
            refused: vec![false; ndev],
            total_items: granules * granule,
            reclaimed: VecDeque::new(),
            paused: false,
            completed_items: 0,
            steal: StealState::new(kind.steal_policy(), &devices),
            parker: MasterParker {
                arbiter,
                tokens,
                node_devs: (0..ndev).collect(),
                parked: vec![false; ndev],
            },
        };
        (master, rxs, regs)
    }

    /// A pipelined refill ships as ONE batched message carrying every
    /// decision of the top-up (range + lookahead in the seed protocol),
    /// with contiguous scheduler ranges in decision order.
    #[test]
    fn top_up_ships_one_batched_refill() {
        let (mut master, rxs, _regs) =
            test_master(1, 2, SchedulerKind::dynamic(4), 8, 4);
        master.top_up(0);
        let msg = rxs[0].try_recv().expect("one refill message");
        match msg {
            ToWorker::Assign(batch) => {
                assert_eq!(batch.len(), 2, "depth-2 refill batches both ranges");
                let ranges: Vec<Range> = batch.iter().map(|a| a.range).collect();
                assert_eq!(
                    ranges[0].end, ranges[1].begin,
                    "decision order preserved: contiguous dynamic ranges"
                );
                assert!(batch.iter().all(|a| !a.requeued));
            }
            ToWorker::Finish => panic!("expected an Assign batch, got Finish"),
        }
        assert!(rxs[0].try_recv().is_err(), "the whole refill was a single message");
        assert_eq!(master.pending[0].len(), 2);
        assert_eq!(master.unstaged[0], 2, "both ranges count against the staging cap");
        // A second top-up with a full pipeline ships nothing.
        master.top_up(0);
        assert!(rxs[0].try_recv().is_err());
    }

    /// Satellite regression: scheduler decisions are computed before any
    /// channel send, so a worker channel that died (or blocked) cannot
    /// stall scheduling — decisions and observations for *other* devices
    /// proceed untouched.
    #[test]
    fn dead_worker_channel_does_not_stall_other_devices() {
        let (mut master, mut rxs, _regs) =
            test_master(2, 1, SchedulerKind::dynamic(4), 8, 4);
        drop(rxs.remove(0)); // device 0's channel is gone
        master.top_up(0); // must neither panic nor block
        assert_eq!(master.pending[0].len(), 1, "decision was still made for dev 0");
        // Device 1 keeps scheduling, observing and re-filling.
        master.top_up(1);
        let first = match rxs[0].try_recv().expect("dev 1 gets its refill") {
            ToWorker::Assign(batch) => {
                assert_eq!(batch.len(), 1);
                batch.iter().next().unwrap().range
            }
            ToWorker::Finish => panic!("expected an Assign batch"),
        };
        let done = master.pending[1].pop_front().expect("dev 1 has in-flight work");
        assert_eq!(done, first);
        master
            .scheduler
            .observe(1, done, crate::coordinator::scheduler::PackageTiming::default());
        master.top_up(1);
        assert!(
            matches!(rxs[0].try_recv(), Ok(ToWorker::Assign(_))),
            "observation fed and the next refill shipped despite dev 0's dead channel"
        );
    }

    // ---- master-side steal protocol ----------------------------------

    use crate::coordinator::scheduler::PackageTiming;

    fn steal_kind() -> SchedulerKind {
        SchedulerKind::dynamic(8)
            .pipelined(3)
            .stealing(StealPolicy::TailOnly { threshold: 1.2 })
    }

    /// Drive a 2-device steal master (32 granules of 8 items, dynamic:8
    /// → eight 32-item packages) until device 0 is dry and fast
    /// (~1000 granules/s observed) while device 1 sits on a full
    /// depth-3 ledger at 1 granule/s — at which point the final
    /// `top_up(0)` prices and issues a Steal. Returns the Steal request
    /// as received on the victim's channel, with both channels drained.
    fn provoke_steal(master: &mut MasterState, rxs: &[Receiver<ToWorker>]) -> (usize, usize) {
        // Fill both pipelines: the staging cap (2) bounds the first
        // refill; a confirmed staging lets the third package in.
        for dev in 0..2 {
            master.top_up(dev);
            master.unstaged[dev] = 0;
            master.top_up(dev);
            assert_eq!(master.pending[dev].len(), 3, "dev{dev} pipeline full");
        }
        // The rate gap that makes the steal profitable: one slow
        // observation for the victim (4 granules over 4s = 1 g/s)...
        master.steal.model.observe(1, 4.0, Duration::from_secs(4));
        // ...while device 0 completes its whole queue fast (4 granules
        // over 4ms = 1000 g/s), replaying the Done arm's bookkeeping.
        while let Some(range) = master.pending[0].pop_front() {
            master.completed_items += range.len();
            let granules = range.len() as f64 / master.granule as f64;
            master.steal.model.observe(0, granules, Duration::from_millis(4));
            master.scheduler.observe(0, range, PackageTiming::default());
            master.unstaged[0] = 0;
            master.top_up(0);
        }
        assert!(master.dry[0], "scheduler exhausted for the fast device");
        while rxs[0].try_recv().is_ok() {}
        let mut steal = None;
        while let Ok(msg) = rxs[1].try_recv() {
            if let ToWorker::Steal { max_items, granule } = msg {
                steal = Some((max_items, granule));
            }
        }
        steal.expect("no Steal reached the backlogged victim")
    }

    #[test]
    fn dry_device_steals_from_a_backlogged_victim() {
        let (mut master, rxs, _regs) = test_master(2, 3, steal_kind(), 32, 8);
        let (max_items, granule) = provoke_steal(&mut master, &rxs);
        assert_eq!(granule, 8);
        assert!(max_items >= 8, "at least one granule requested: {max_items}");
        assert_eq!(max_items % 8, 0, "granule-aligned request");
        assert!(
            max_items <= master.pending[1].iter().skip(2).map(Range::len).sum::<usize>(),
            "never more than the unshielded backlog"
        );
        assert_eq!(master.steal.issued, 1);
        assert_eq!(master.steal.outstanding[1], Some(0), "victim 1, thief 0");
        // Victim suppression: while the ack is outstanding, nothing may
        // ship to the victim — not even requeued work it has pipeline
        // capacity for (the worker's truncation runs against the queue
        // as it saw it).
        master.pending[1].pop_front(); // its in-flight package completes
        master.unstaged[1] = 0;
        master.reclaimed.push_back(Range::new(0, 8));
        let before = master.pending[1].len();
        master.top_up(1);
        assert_eq!(master.pending[1].len(), before, "victim top_up suppressed");
        assert!(rxs[1].try_recv().is_err(), "nothing shipped to the victim");
        // Counterfactual: with the marker retired the same top_up ships.
        master.steal.outstanding[1] = None;
        master.top_up(1);
        assert!(master.pending[1].len() > before, "unsuppressed top_up assigns");
    }

    #[test]
    fn yield_ack_moves_ranges_to_the_thief_exactly_once() {
        let (mut master, rxs, _regs) = test_master(2, 3, steal_kind(), 32, 8);
        provoke_steal(&mut master, &rxs);
        // The victim yields its deepest pending entry (whole match).
        let yielded = *master.pending[1].back().expect("victim has backlog");
        let arena = OutputArena::new(vec![(vec![0.0f32; 256], 1)], 8, 256).unwrap();
        master.handle_yield(1, vec![yielded], &arena);
        assert_eq!(master.steal.outstanding[1], None, "ack retired the marker");
        assert_eq!(master.steal.items_moved, yielded.len());
        assert!(
            !master.pending[1].contains(&yielded),
            "yielded range left the victim's ledger"
        );
        // The thief was topped up with the stolen range (flagged).
        let batch = match rxs[0].try_recv() {
            Ok(ToWorker::Assign(b)) => b,
            _ => panic!("stolen work never reached the thief"),
        };
        let stolen: Vec<_> = batch.iter().filter(|a| a.stolen).collect();
        assert_eq!(stolen.len(), 1, "exactly one stolen assignment");
        assert_eq!(stolen[0].range, yielded);
        assert!(!stolen[0].requeued, "stolen, not requeued");
        assert!(master.pending[0].contains(&yielded), "thief's ledger holds it");
        assert!(master.steal.pool.is_empty(), "pool drained");
        // Exactly-once: every item is accounted exactly once across
        // completed + pending.
        let accounted: usize = master.completed_items
            + master.pending.iter().map(|q| q.iter().map(Range::len).sum::<usize>()).sum::<usize>();
        assert_eq!(accounted, master.total_items);
    }

    #[test]
    fn split_suffix_yield_shrinks_the_pending_entry() {
        let (mut master, rxs, _regs) = test_master(2, 3, steal_kind(), 32, 8);
        provoke_steal(&mut master, &rxs);
        let back = *master.pending[1].back().expect("victim has backlog");
        assert!(back.len() > 8, "test needs a splittable entry");
        // The worker kept the first granule and yielded the suffix.
        let cut = back.begin + 8;
        let suffix = Range::new(cut, back.end);
        let arena = OutputArena::new(vec![(vec![0.0f32; 256], 1)], 8, 256).unwrap();
        master.handle_yield(1, vec![suffix], &arena);
        assert_eq!(
            *master.pending[1].back().unwrap(),
            Range::new(back.begin, cut),
            "pending entry shrank to the kept front"
        );
        assert_eq!(master.steal.items_moved, suffix.len());
        assert!(master.pending[0].contains(&suffix), "suffix re-dispatched to the thief");
        let accounted: usize = master.completed_items
            + master.pending.iter().map(|q| q.iter().map(Range::len).sum::<usize>()).sum::<usize>()
            + master.steal.pool.iter().map(Range::len).sum::<usize>();
        assert_eq!(accounted, master.total_items, "no item lost or duplicated");
    }

    #[test]
    fn yield_from_a_failed_victim_is_dropped_not_double_requeued() {
        let (mut master, rxs, _regs) = test_master(2, 3, steal_kind(), 32, 8);
        provoke_steal(&mut master, &rxs);
        let yielded = *master.pending[1].back().expect("victim has backlog");
        let arena = OutputArena::new(vec![(vec![0.0f32; 256], 1)], 8, 256).unwrap();
        // The victim dies before its ack is processed: recovery drains
        // and requeues its whole ledger (the yielded range included)...
        master.handle_failure(1, &arena);
        assert_eq!(master.steal.outstanding[1], None, "failure cleared the marker");
        let requeued: usize = master.reclaimed.iter().map(Range::len).sum::<usize>()
            + master.pending[0].iter().map(Range::len).sum::<usize>();
        // ...so the late ack must be dropped, not pooled a second time.
        master.handle_yield(1, vec![yielded], &arena);
        assert!(master.steal.pool.is_empty(), "late ack dropped");
        assert_eq!(master.steal.items_moved, 0);
        let after: usize = master.reclaimed.iter().map(Range::len).sum::<usize>()
            + master.pending[0].iter().map(Range::len).sum::<usize>();
        assert_eq!(after, requeued, "no double-requeue");
        assert_eq!(after + master.completed_items, master.total_items, "exactly-once holds");
    }

    #[test]
    fn empty_yield_cools_the_victim_until_its_next_done() {
        let (mut master, rxs, _regs) = test_master(2, 3, steal_kind(), 32, 8);
        provoke_steal(&mut master, &rxs);
        let arena = OutputArena::new(vec![(vec![0.0f32; 256], 1)], 8, 256).unwrap();
        // The victim's local queue was already drained when the Steal
        // arrived: it acks with nothing. The marker retires, and the
        // victim must NOT be re-priced immediately (its master-side
        // ledger still shows the un-Done backlog — an instant re-steal
        // would ping-pong at channel speed).
        master.handle_yield(1, Vec::new(), &arena);
        assert_eq!(master.steal.outstanding[1], None, "marker retired");
        assert_eq!(master.steal.items_moved, 0);
        assert!(master.steal.pool.is_empty());
        assert!(master.steal.cooling[1], "empty ack cools the victim");
        assert_eq!(master.steal.issued, 1, "no immediate re-steal spin");
        // The victim's next Done re-arms it (the Done arm clears the
        // flag); the still-dry thief then prices the steal again.
        master.steal.cooling[1] = false;
        master.try_steal_all();
        assert_eq!(master.steal.issued, 2, "re-armed after the victim progresses");
        assert_eq!(master.steal.outstanding[1], Some(0));
    }

    #[test]
    fn off_policy_never_issues_steals() {
        let (mut master, rxs, _regs) =
            test_master(2, 3, SchedulerKind::dynamic(8).pipelined(3), 32, 8);
        for dev in 0..2 {
            master.top_up(dev);
            master.unstaged[dev] = 0;
            master.top_up(dev);
        }
        // Device 0 drains completely while device 1 holds its ledger —
        // the exact shape that triggers a steal under `+steal`.
        while let Some(range) = master.pending[0].pop_front() {
            master.completed_items += range.len();
            master.scheduler.observe(0, range, PackageTiming::default());
            master.unstaged[0] = 0;
            master.top_up(0);
        }
        master.try_steal_all();
        assert_eq!(master.steal.issued, 0);
        while let Ok(msg) = rxs[1].try_recv() {
            assert!(
                !matches!(msg, ToWorker::Steal { .. }),
                "no Steal may ship under an off policy"
            );
        }
    }

    /// The adaptive liveness poll: defaults to the seed's 25ms tick
    /// until the first observation, then tracks half the EWMA package
    /// span clamped to [5ms, 250ms].
    #[test]
    fn liveness_poll_adapts_and_clamps() {
        let mut p = LivenessPoll::new();
        assert_eq!(p.current(), LIVENESS_POLL_DEFAULT);
        p.observe(Duration::from_millis(100));
        assert_eq!(p.current(), Duration::from_millis(50), "half the observed span");
        let mut fast = LivenessPoll::new();
        fast.observe(Duration::from_micros(200));
        assert_eq!(fast.current(), LIVENESS_POLL_MIN, "floor on microsecond packages");
        let mut slow = LivenessPoll::new();
        slow.observe(Duration::from_secs(30));
        assert_eq!(slow.current(), LIVENESS_POLL_MAX, "ceiling bounds vanish detection");
        // EWMA: a step change moves the estimate toward the new level.
        let before = p.current();
        for _ in 0..50 {
            p.observe(Duration::from_millis(400));
        }
        assert!(p.current() > before);
        assert!(p.current() <= Duration::from_millis(200));
    }
}
