//! Deterministic fault injection (the chaos layer).
//!
//! A [`FaultPlan`] describes, ahead of a run, exactly where devices
//! misbehave: *kill* (the worker dies mid-package, leaving a claimed,
//! partially-written arena window behind), *stall* (a transient hang),
//! *slow* (permanent throughput degradation — thermal throttling),
//! *panic* (the worker thread unwinds) and *vanish* (the worker exits
//! silently, sending no completion event at all — a segfaulting driver).
//!
//! Faults trigger at **package boundaries**, either by per-device package
//! ordinal (`pkg2` = just before that device executes its third package)
//! or by simclock offset (`350ms` = the first package boundary at or
//! after that instant from the run epoch). Package-ordinal triggers are
//! fully deterministic: the same plan fires at the same point on every
//! run. Simclock triggers are deterministic only insofar as the
//! simulated holds dominate wall time.
//!
//! The plan is engine-agnostic data; each device worker derives a
//! [`FaultInjector`] from it and polls [`FaultInjector::on_package`]
//! once per package. Recovery — revoking the dead device's arena claims
//! and requeuing its work onto survivors — lives in the coordinator
//! (`coordinator::engine`); this module only decides *when* and *how*
//! a device fails.

use std::fmt;
use std::time::Duration;

use crate::util::rng::XorShift;

/// What goes wrong.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// The worker claims the package's arena windows, scribbles a poison
    /// pattern over them, executes only a prefix of the sub-launches and
    /// dies with an error — a device lost mid-package.
    Kill,
    /// The worker sleeps for the given duration before the package —
    /// a transient hang (adaptive schedulers shift work away from it).
    Stall(Duration),
    /// The worker's simulated throughput degrades by this factor from
    /// the package on (≥ 1 slows it down) — thermal throttling.
    Slowdown(f64),
    /// The worker thread panics (exercises the engine's unwind-to-event
    /// conversion).
    Panic,
    /// The worker exits silently without reporting anything (exercises
    /// the engine's dead-channel liveness detection).
    Vanish,
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultKind::Kill => write!(f, "kill"),
            FaultKind::Stall(d) => write!(f, "stall {}ms", d.as_millis()),
            FaultKind::Slowdown(x) => write!(f, "slow {x}x"),
            FaultKind::Panic => write!(f, "panic"),
            FaultKind::Vanish => write!(f, "vanish"),
        }
    }
}

/// When it goes wrong (checked at each package boundary).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultTrigger {
    /// Before the device executes its n-th package (0-based, counted
    /// per device). Fully deterministic.
    Package(usize),
    /// At the first package boundary at or after this offset from the
    /// run epoch. Deterministic only up to scheduling noise.
    At(Duration),
}

/// One planned fault on one device.
///
/// `device` indexes the engine's *selected* device list (the worker
/// slot), not the node's full device table — `dev1` in a 2-device run
/// is the second selected device whatever its node index is.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub device: usize,
    pub kind: FaultKind,
    pub trigger: FaultTrigger,
}

/// A full, deterministic fault schedule for one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Add any fault (builder-style).
    pub fn with(mut self, device: usize, kind: FaultKind, trigger: FaultTrigger) -> Self {
        self.faults.push(FaultSpec { device, kind, trigger });
        self
    }

    /// Kill `device` just before its `pkg`-th package.
    pub fn kill(device: usize, pkg: usize) -> Self {
        Self::new().with(device, FaultKind::Kill, FaultTrigger::Package(pkg))
    }

    /// Panic `device`'s worker thread at its `pkg`-th package.
    pub fn panic_at(device: usize, pkg: usize) -> Self {
        Self::new().with(device, FaultKind::Panic, FaultTrigger::Package(pkg))
    }

    /// Silently lose `device` at its `pkg`-th package.
    pub fn vanish(device: usize, pkg: usize) -> Self {
        Self::new().with(device, FaultKind::Vanish, FaultTrigger::Package(pkg))
    }

    /// Stall `device` for `dur` before its `pkg`-th package.
    pub fn stall(device: usize, pkg: usize, dur: Duration) -> Self {
        Self::new().with(device, FaultKind::Stall(dur), FaultTrigger::Package(pkg))
    }

    /// Degrade `device`'s simulated speed by `factor` from its `pkg`-th
    /// package on.
    pub fn slowdown(device: usize, pkg: usize, factor: f64) -> Self {
        Self::new().with(device, FaultKind::Slowdown(factor), FaultTrigger::Package(pkg))
    }

    /// A seed-derived single-kill plan for chaos sweeps: kills one of
    /// `devices` at one of the first `max_pkg` package ordinals. The
    /// same seed always produces the same plan, so a failing sweep case
    /// is reproducible from its logged seed alone.
    pub fn seeded_kill(seed: u64, devices: usize, max_pkg: usize) -> Self {
        let mut rng = XorShift::new(seed);
        let device = rng.below(devices.max(1));
        let pkg = rng.below(max_pkg.max(1));
        Self::kill(device, pkg)
    }

    /// Parse a comma-separated CLI fault spec. Grammar, per fault:
    ///
    /// ```text
    ///   kill:dev<D>@pkg<N>          kill device D at its N-th package
    ///   kill:dev<D>@<T>ms           kill at the first boundary ≥ T ms
    ///   stall:dev<D>@pkg<N>:<T>ms   stall T ms before the N-th package
    ///   slow:dev<D>@pkg<N>:<F>      degrade speed by factor F (≥ 1)
    ///   panic:dev<D>@pkg<N>         panic the worker thread
    ///   vanish:dev<D>@pkg<N>        exit silently (no completion event)
    /// ```
    ///
    /// e.g. `--fault kill:dev1@pkg2` or
    /// `--fault stall:dev0@pkg1:250ms,slow:dev2@pkg0:4`.
    pub fn parse(spec: &str) -> Option<Self> {
        let mut plan = FaultPlan::new();
        for part in spec.split(',').filter(|p| !p.is_empty()) {
            let (kind_str, rest) = part.split_once(':')?;
            let (target, extra) = match rest.split_once(':') {
                Some((t, x)) => (t, Some(x)),
                None => (rest, None),
            };
            let (dev_str, trig_str) = target.split_once('@')?;
            let device: usize = dev_str.strip_prefix("dev")?.parse().ok()?;
            let trigger = if let Some(pkg) = trig_str.strip_prefix("pkg") {
                FaultTrigger::Package(pkg.parse().ok()?)
            } else {
                let ms: u64 = trig_str.strip_suffix("ms")?.parse().ok()?;
                FaultTrigger::At(Duration::from_millis(ms))
            };
            let kind = match (kind_str, extra) {
                ("kill", None) => FaultKind::Kill,
                ("panic", None) => FaultKind::Panic,
                ("vanish", None) => FaultKind::Vanish,
                ("stall", Some(x)) => {
                    let ms: u64 = x.strip_suffix("ms").unwrap_or(x).parse().ok()?;
                    FaultKind::Stall(Duration::from_millis(ms))
                }
                ("slow", Some(x)) => {
                    let f: f64 = x.parse().ok()?;
                    // Finite and positive: `inf` would make the scaler's
                    // Duration::from_secs_f64 panic, `nan` silently no-op.
                    if !f.is_finite() || f <= 0.0 {
                        return None;
                    }
                    FaultKind::Slowdown(f)
                }
                _ => return None,
            };
            plan.faults.push(FaultSpec { device, kind, trigger });
        }
        if plan.is_empty() {
            None
        } else {
            Some(plan)
        }
    }

    /// The injector a worker in slot `device` polls at package
    /// boundaries.
    pub fn injector_for(&self, device: usize) -> FaultInjector {
        FaultInjector {
            faults: self
                .faults
                .iter()
                .filter(|f| f.device == device)
                .map(|f| (f.trigger, f.kind.clone(), false))
                .collect(),
        }
    }
}

/// Per-worker fault state derived from a [`FaultPlan`]: polled once per
/// package boundary, fires each planned fault at most once.
#[derive(Debug, Clone, Default)]
pub struct FaultInjector {
    /// (trigger, kind, fired).
    faults: Vec<(FaultTrigger, FaultKind, bool)>,
}

impl FaultInjector {
    /// An injector that never fires (no plan).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Called by the worker just before executing its `ordinal`-th
    /// package at simclock offset `now`. Returns the first planned,
    /// not-yet-fired fault whose trigger matches, marking it fired.
    pub fn on_package(&mut self, ordinal: usize, now: Duration) -> Option<FaultKind> {
        for (trigger, kind, fired) in self.faults.iter_mut() {
            if *fired {
                continue;
            }
            let hit = match trigger {
                FaultTrigger::Package(p) => *p == ordinal,
                FaultTrigger::At(t) => now >= *t,
            };
            if hit {
                *fired = true;
                return Some(kind.clone());
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn parse_kill_at_package() {
        let p = FaultPlan::parse("kill:dev1@pkg2").unwrap();
        assert_eq!(p, FaultPlan::kill(1, 2));
    }

    #[test]
    fn parse_kill_at_time() {
        let p = FaultPlan::parse("kill:dev0@350ms").unwrap();
        assert_eq!(p.faults[0].trigger, FaultTrigger::At(ms(350)));
        assert_eq!(p.faults[0].kind, FaultKind::Kill);
    }

    #[test]
    fn parse_multi_fault_spec() {
        let p = FaultPlan::parse("stall:dev0@pkg1:250ms,slow:dev2@pkg0:4,vanish:dev1@pkg3")
            .unwrap();
        assert_eq!(p.faults.len(), 3);
        assert_eq!(p.faults[0].kind, FaultKind::Stall(ms(250)));
        assert_eq!(p.faults[1].kind, FaultKind::Slowdown(4.0));
        assert_eq!(p.faults[1].device, 2);
        assert_eq!(p.faults[2].kind, FaultKind::Vanish);
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "", "kill", "kill:dev1", "kill:devx@pkg2", "kill:dev1@pkg", "boom:dev1@pkg2",
            "slow:dev1@pkg2", "slow:dev1@pkg2:0", "stall:dev1@pkg2", "kill:dev1@2s",
            "slow:dev1@pkg2:inf", "slow:dev1@pkg2:nan", "slow:dev1@pkg2:-3",
        ] {
            assert!(FaultPlan::parse(bad).is_none(), "accepted {bad:?}");
        }
    }

    #[test]
    fn injector_fires_once_at_the_right_package() {
        let plan = FaultPlan::kill(1, 2);
        let mut inj = plan.injector_for(1);
        assert_eq!(inj.on_package(0, ms(0)), None);
        assert_eq!(inj.on_package(1, ms(0)), None);
        assert_eq!(inj.on_package(2, ms(0)), Some(FaultKind::Kill));
        assert_eq!(inj.on_package(3, ms(0)), None, "fires at most once");
        // Other devices get an empty injector.
        let mut other = plan.injector_for(0);
        assert!(other.is_empty());
        assert_eq!(other.on_package(2, ms(0)), None);
    }

    #[test]
    fn injector_time_trigger_fires_at_first_boundary_after() {
        let plan = FaultPlan::new().with(0, FaultKind::Kill, FaultTrigger::At(ms(100)));
        let mut inj = plan.injector_for(0);
        assert_eq!(inj.on_package(0, ms(40)), None);
        assert_eq!(inj.on_package(1, ms(120)), Some(FaultKind::Kill));
        assert_eq!(inj.on_package(2, ms(300)), None);
    }

    #[test]
    fn seeded_kill_is_deterministic_and_in_range() {
        let a = FaultPlan::seeded_kill(42, 3, 4);
        let b = FaultPlan::seeded_kill(42, 3, 4);
        assert_eq!(a, b);
        let FaultSpec { device, kind, trigger } = &a.faults[0];
        assert!(*device < 3);
        assert_eq!(*kind, FaultKind::Kill);
        match trigger {
            FaultTrigger::Package(p) => assert!(*p < 4),
            other => panic!("unexpected trigger {other:?}"),
        }
        let distinct: std::collections::BTreeSet<String> = (0..32)
            .map(|s| format!("{:?}", FaultPlan::seeded_kill(s, 3, 4).faults[0]))
            .collect();
        assert!(distinct.len() > 1, "seeds must actually vary the plan");
    }

    #[test]
    fn display_labels() {
        assert_eq!(FaultKind::Kill.to_string(), "kill");
        assert_eq!(FaultKind::Stall(ms(250)).to_string(), "stall 250ms");
        assert_eq!(FaultKind::Slowdown(4.0).to_string(), "slow 4x");
        assert_eq!(FaultKind::Vanish.to_string(), "vanish");
    }
}
