//! Device profiles and node configurations (the simulated testbeds).
//!
//! `relative_power` is the device's throughput relative to the node's
//! fastest device (the GPU, = 1.0), calibrated from the paper's Figure 12
//! work distributions: the share of work a balanced scheduler gives a
//! device is proportional to its power. `BASE_SLOWDOWN` stretches even the
//! fastest device ≥3x over raw PJRT time so that physical contention
//! between device threads is absorbed by the stretch (see simclock).

use std::time::Duration;

/// What the paper's DeviceMask distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    Cpu,
    Gpu,
    IntegratedGpu,
    Accelerator, // Xeon Phi
}

impl DeviceKind {
    pub fn label(&self) -> &'static str {
        match self {
            DeviceKind::Cpu => "CPU",
            DeviceKind::Gpu => "GPU",
            DeviceKind::IntegratedGpu => "iGPU",
            DeviceKind::Accelerator => "ACC",
        }
    }
}

/// Every device-specific constant of the simulation.
#[derive(Debug, Clone)]
pub struct DeviceProfile {
    pub name: String,
    pub kind: DeviceKind,
    /// Throughput relative to the node's fastest device (0 < p <= 1).
    pub relative_power: f64,
    /// Driver/platform initialization latency before the first package.
    pub init: Duration,
    /// Extra init latency when a CPU device is co-executing in the same
    /// engine (the paper's Xeon Phi driver needs the CPU: 1.8s alone,
    /// ~2.7s in co-execution — Figure 13).
    pub init_contention: Duration,
    /// Fixed per-package host<->device synchronization overhead.
    pub package_overhead: Duration,
    /// Relative jitter applied to stretched durations (driver noise).
    pub jitter: f64,
    /// Power draw while a package occupies the device (H2D + compute),
    /// in watts. Always finite and positive.
    pub busy_watts: f64,
    /// Power draw while the device sits idle in the node (gaps, lease
    /// waits), in watts. Always finite, positive and <= `busy_watts`.
    pub idle_watts: f64,
}

impl DeviceProfile {
    pub fn new(name: &str, kind: DeviceKind, relative_power: f64) -> Self {
        // Kind-level defaults (nameplate-ish TDP / idle draw); the node
        // configs override these with per-device figures.
        let (busy_watts, idle_watts) = match kind {
            DeviceKind::Cpu => (80.0, 8.0),
            DeviceKind::Gpu => (150.0, 10.0),
            DeviceKind::IntegratedGpu => (35.0, 5.0),
            DeviceKind::Accelerator => (220.0, 15.0),
        };
        Self {
            name: name.to_string(),
            kind,
            relative_power,
            init: Duration::from_millis(80),
            init_contention: Duration::ZERO,
            package_overhead: Duration::from_micros(600),
            jitter: 0.0,
            busy_watts,
            idle_watts,
        }
    }

    pub fn with_init(mut self, init: Duration, contention: Duration) -> Self {
        self.init = init;
        self.init_contention = contention;
        self
    }

    pub fn with_package_overhead(mut self, d: Duration) -> Self {
        self.package_overhead = d;
        self
    }

    pub fn with_jitter(mut self, j: f64) -> Self {
        self.jitter = j;
        self
    }

    /// Set the power model. Panics on non-finite or non-positive watts
    /// (and on idle > busy): a NaN here would silently poison every
    /// joule integral downstream, so it is rejected at construction.
    pub fn with_watts(mut self, busy: f64, idle: f64) -> Self {
        assert!(
            busy.is_finite() && busy > 0.0,
            "busy_watts must be finite and positive, got {busy}"
        );
        assert!(
            idle.is_finite() && idle > 0.0,
            "idle_watts must be finite and positive, got {idle}"
        );
        assert!(idle <= busy, "idle_watts ({idle}) must not exceed busy_watts ({busy})");
        self.busy_watts = busy;
        self.idle_watts = idle;
        self
    }
}

/// A simulated heterogeneous node.
#[derive(Debug, Clone)]
pub struct NodeConfig {
    pub name: String,
    pub devices: Vec<DeviceProfile>,
}

impl NodeConfig {
    /// Batel — the paper's HPC node: 2x Xeon E5-2620 (one OpenCL device),
    /// NVIDIA K20m, Xeon Phi KNC 7120P.
    ///
    /// Powers from the paper's Figure 12 balanced work shares (roughly
    /// GPU 55-60 %, Phi ~25 %, CPU ~18 % on regular loads). The Phi gets
    /// the paper's pathological init (1.8 s alone, ~2.7 s when the CPU
    /// OpenCL driver is also active) and high variability.
    /// Init latencies are the paper's figures scaled by ~1/16: our compute
    /// phases run ~2 s where the paper's ran ~10 s, so the scaling keeps
    /// the *lateness-to-compute ratio* (what imbalances Static Binomial,
    /// Figure 13) comparable. EXPERIMENTS.md documents the substitution.
    pub fn batel() -> NodeConfig {
        NodeConfig {
            name: "batel".into(),
            devices: vec![
                DeviceProfile::new("xeon-e5-2620x2", DeviceKind::Cpu, 0.30)
                    .with_init(Duration::from_millis(8), Duration::ZERO)
                    .with_package_overhead(Duration::from_micros(350))
                    .with_jitter(0.01)
                    // 2x E5-2620 TDP 95W each, but one socket mostly
                    // carries the OpenCL device; package idle ~10W.
                    .with_watts(95.0, 10.0),
                DeviceProfile::new("tesla-k20m", DeviceKind::Gpu, 1.0)
                    .with_init(Duration::from_millis(20), Duration::ZERO)
                    .with_package_overhead(Duration::from_micros(800))
                    .with_jitter(0.01)
                    // K20m board power 225W TDP, ~12W idle.
                    .with_watts(225.0, 12.0),
                DeviceProfile::new("xeon-phi-7120p", DeviceKind::Accelerator, 0.42)
                    .with_init(Duration::from_millis(110), Duration::from_millis(55))
                    .with_package_overhead(Duration::from_micros(1500))
                    .with_jitter(0.05)
                    // Phi 7120P TDP 300W — the watt-hungriest device per
                    // unit of throughput on the node.
                    .with_watts(300.0, 15.0),
            ],
        }
    }

    /// Remo — the paper's desktop node: AMD A10-7850K (2C/4T, weak),
    /// its integrated R7 GPU, and a discrete GTX 950.
    pub fn remo() -> NodeConfig {
        NodeConfig {
            name: "remo".into(),
            devices: vec![
                DeviceProfile::new("a10-7850k", DeviceKind::Cpu, 0.12)
                    .with_init(Duration::from_millis(6), Duration::ZERO)
                    .with_package_overhead(Duration::from_micros(400))
                    .with_jitter(0.02)
                    // A10-7850K 95W APU TDP, CPU-side share ~65W.
                    .with_watts(65.0, 8.0),
                DeviceProfile::new("r7-igpu", DeviceKind::IntegratedGpu, 0.45)
                    .with_init(Duration::from_millis(10), Duration::ZERO)
                    .with_package_overhead(Duration::from_micros(500))
                    .with_jitter(0.01)
                    // The iGPU side of the same package: cheap watts per
                    // granule — the green device of the node.
                    .with_watts(35.0, 5.0),
                DeviceProfile::new("gtx-950", DeviceKind::Gpu, 1.0)
                    .with_init(Duration::from_millis(16), Duration::ZERO)
                    .with_package_overhead(Duration::from_micros(700))
                    .with_jitter(0.01)
                    // GTX 950 board power 90W, ~10W idle.
                    .with_watts(90.0, 10.0),
            ],
        }
    }

    pub fn by_name(name: &str) -> Option<NodeConfig> {
        match name {
            "batel" => Some(Self::batel()),
            "remo" => Some(Self::remo()),
            _ => None,
        }
    }

    /// Index of the fastest device (the speedup baseline, the GPU).
    /// `total_cmp` keeps a NaN-poisoned power from panicking the
    /// selection: NaN sorts above every finite power under IEEE total
    /// order, so a corrupt profile is picked, not crashed on.
    pub fn fastest(&self) -> usize {
        self.devices
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.relative_power.total_cmp(&b.1.relative_power))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// First device of `kind`, if the node has one.
    pub fn first_of_kind(&self, kind: DeviceKind) -> Option<&DeviceProfile> {
        self.devices.iter().find(|d| d.kind == kind)
    }

    /// Devices matching a predicate, as (index, profile).
    pub fn select(&self, kinds: &[DeviceKind]) -> Vec<(usize, &DeviceProfile)> {
        self.devices
            .iter()
            .enumerate()
            .filter(|(_, d)| kinds.contains(&d.kind))
            .collect()
    }

    pub fn has_cpu(&self) -> bool {
        self.devices.iter().any(|d| d.kind == DeviceKind::Cpu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batel_layout() {
        let n = NodeConfig::batel();
        assert_eq!(n.devices.len(), 3);
        assert_eq!(n.devices[n.fastest()].kind, DeviceKind::Gpu);
        assert!(n.has_cpu());
    }

    #[test]
    fn remo_layout() {
        let n = NodeConfig::remo();
        assert_eq!(n.devices.len(), 3);
        assert_eq!(n.devices[n.fastest()].name, "gtx-950");
        // The paper's Remo CPU is by far the weakest device.
        let cpu = &n.devices[0];
        assert!(cpu.relative_power < 0.2);
    }

    #[test]
    fn phi_has_init_pathology() {
        let n = NodeConfig::batel();
        let phi = n
            .first_of_kind(DeviceKind::Accelerator)
            .expect("batel is defined with a Xeon Phi accelerator");
        // Paper: 1.8s solo / +0.9s contended, scaled 1/4 (see batel docs).
        assert!(phi.init >= 5 * n.devices[n.fastest()].init);
        assert!(phi.init_contention >= phi.init / 2);
    }

    #[test]
    fn by_name_lookup() {
        assert!(NodeConfig::by_name("batel").is_some());
        assert!(NodeConfig::by_name("remo").is_some());
        assert!(NodeConfig::by_name("zzz").is_none());
    }

    #[test]
    fn select_by_kind() {
        let n = NodeConfig::batel();
        let accs = n.select(&[DeviceKind::Accelerator]);
        assert_eq!(accs.len(), 1);
        assert_eq!(accs[0].1.name, "xeon-phi-7120p");
    }

    #[test]
    fn fastest_survives_nan_power() {
        // Regression: `fastest()` used `partial_cmp(..).unwrap()` and
        // panicked the moment any profile carried a NaN power.
        let mut n = NodeConfig::batel();
        n.devices[0].relative_power = f64::NAN;
        let _ = n.fastest(); // must not panic
        n.devices.iter_mut().for_each(|d| d.relative_power = f64::NAN);
        let _ = n.fastest(); // all-NaN must not panic either
    }

    #[test]
    fn missing_kind_lookup_is_none_not_panic() {
        // Regression: the Accelerator lookup was an unguarded `.unwrap()`
        // — a node without a Phi panicked instead of reporting absence.
        let n = NodeConfig::remo();
        assert!(n.first_of_kind(DeviceKind::Accelerator).is_none());
        assert!(n.first_of_kind(DeviceKind::IntegratedGpu).is_some());
    }

    #[test]
    fn watts_are_finite_positive_and_ordered() {
        for node in [NodeConfig::batel(), NodeConfig::remo()] {
            for d in &node.devices {
                assert!(d.busy_watts.is_finite() && d.busy_watts > 0.0, "{}", d.name);
                assert!(d.idle_watts.is_finite() && d.idle_watts > 0.0, "{}", d.name);
                assert!(d.idle_watts <= d.busy_watts, "{}", d.name);
            }
        }
    }

    #[test]
    #[should_panic(expected = "busy_watts must be finite and positive")]
    fn nan_watts_rejected_at_construction() {
        let _ = DeviceProfile::new("bad", DeviceKind::Cpu, 0.5).with_watts(f64::NAN, 5.0);
    }

    #[test]
    #[should_panic(expected = "must not exceed busy_watts")]
    fn idle_above_busy_rejected() {
        let _ = DeviceProfile::new("bad", DeviceKind::Cpu, 0.5).with_watts(10.0, 20.0);
    }
}
