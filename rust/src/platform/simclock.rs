//! Time stretching for simulated devices.
//!
//! Every device worker measures the *raw* backend execution time of each
//! package and then holds the package until
//! `raw * BASE_SLOWDOWN / relative_power` wall time has elapsed *since
//! the package started*. Device threads compute genuinely in parallel
//! (the seed's global execute lock is gone), which changes what `raw`
//! means: on a host with fewer free cores than device threads it
//! includes physical core contention, so contended packages' simulated
//! durations inflate — non-uniformly, if the OS favors one thread. The
//! model accepts that deliberately: outputs are bit-identical under any
//! timing (disjoint arena writes, per-item-deterministic kernels), the
//! `BASE_SLOWDOWN` stretch keeps short contention stalls inside the
//! stretched window on adequately-provisioned hosts, and a real
//! co-executing machine's devices contend for shared resources too —
//! whereas the lock made "co-execution" physically sequential and every
//! multi-device wall-clock number a fiction.

use std::time::{Duration, Instant};

use crate::util::rng::XorShift;

use super::profile::DeviceProfile;

/// Global stretch applied to the fastest device. Must exceed the number
/// of concurrently co-executing devices so that physical core contention
/// between truly-parallel workers is absorbed by the stretched window.
pub const BASE_SLOWDOWN: f64 = 4.0;

/// Per-device stretcher. Owned by the device worker thread.
#[derive(Debug)]
pub struct TimeScaler {
    factor: f64,
    package_overhead: Duration,
    jitter: f64,
    rng: XorShift,
}

impl TimeScaler {
    pub fn new(profile: &DeviceProfile, seed: u64) -> Self {
        Self {
            factor: BASE_SLOWDOWN / profile.relative_power.max(1e-6),
            package_overhead: profile.package_overhead,
            jitter: profile.jitter,
            rng: XorShift::new(seed),
        }
    }

    /// The stretch factor over raw PJRT time.
    pub fn factor(&self) -> f64 {
        self.factor
    }

    /// Permanently degrade this device's simulated throughput by
    /// `factor` (≥ 1 slows it down) — the fault layer's `Slowdown`
    /// injection (thermal throttling, a dying fan). Every subsequent
    /// package target stretches by the degraded factor, so adaptive
    /// schedulers see the device get slower and shift work away.
    pub fn degrade(&mut self, factor: f64) {
        if factor > 0.0 {
            self.factor *= factor;
        }
    }

    /// Target duration for a package whose raw execution took `raw`.
    pub fn target(&mut self, raw: Duration, launches: u32) -> Duration {
        let mut t = raw.as_secs_f64() * self.factor;
        // Each sub-launch pays the host<->device sync cost once.
        t += self.package_overhead.as_secs_f64() * launches.max(1) as f64;
        if self.jitter > 0.0 {
            // Uniform in [1-j, 1+j].
            let u = self.rng.next_f64() * 2.0 - 1.0;
            t *= 1.0 + self.jitter * u;
        }
        Duration::from_secs_f64(t)
    }

    /// Target duration for a *pipelined* package: the device computes
    /// while the host DMA engine stages the next package's H2D transfer,
    /// so the package window is the *maximum* of stretched compute and
    /// the overlapped upload, plus the result write-back (`d2h`), which
    /// stays serial at host speed (the merge buffers are host memory).
    ///
    /// This is the honest overlap model: a transfer can hide behind
    /// compute but never make it faster, and a transfer longer than the
    /// compute window stalls the pipeline (the package cannot complete
    /// before its successor's upload finished occupying the bus).
    pub fn target_overlapped(
        &mut self,
        raw: Duration,
        launches: u32,
        overlapped_h2d: Duration,
        d2h: Duration,
    ) -> Duration {
        self.target(raw, launches).max(overlapped_h2d) + d2h
    }

    /// Sleep until `started + target` (no-op if already past — i.e. the
    /// physical wait exceeded the simulated duration, which the
    /// BASE_SLOWDOWN choice makes rare).
    pub fn hold(&self, started: Instant, target: Duration) -> Duration {
        let elapsed = started.elapsed();
        if elapsed < target {
            std::thread::sleep(target - elapsed);
            target
        } else {
            elapsed
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::profile::{DeviceKind, DeviceProfile};

    fn prof(power: f64) -> DeviceProfile {
        DeviceProfile::new("t", DeviceKind::Gpu, power)
            .with_package_overhead(Duration::from_millis(1))
    }

    #[test]
    fn factor_scales_inverse_power() {
        let a = TimeScaler::new(&prof(1.0), 1);
        let b = TimeScaler::new(&prof(0.25), 1);
        assert!((b.factor() / a.factor() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn target_includes_overhead_per_launch() {
        let mut s = TimeScaler::new(&prof(1.0), 1);
        let t1 = s.target(Duration::from_millis(10), 1);
        let t3 = s.target(Duration::from_millis(10), 3);
        let diff = t3.as_secs_f64() - t1.as_secs_f64();
        assert!((diff - 0.002).abs() < 1e-9, "2 extra launches = 2ms, got {diff}");
    }

    #[test]
    fn jitter_bounded() {
        let p = prof(1.0).with_jitter(0.05);
        let mut s = TimeScaler::new(&p, 42);
        let base = Duration::from_millis(100).as_secs_f64() * s.factor() + 0.001;
        for _ in 0..200 {
            let t = s.target(Duration::from_millis(100), 1).as_secs_f64();
            assert!(t >= base * 0.94 && t <= base * 1.06);
        }
    }

    #[test]
    fn overlapped_target_hides_short_transfers() {
        let mut s = TimeScaler::new(&prof(1.0), 1);
        let exec = Duration::from_millis(10);
        let blocking = s.target(exec, 1) + Duration::from_millis(3) + Duration::from_millis(1);
        let short = s.target_overlapped(exec, 1, Duration::from_millis(3), Duration::from_millis(1));
        // A 3ms upload hides entirely behind 40ms stretched compute.
        assert!(short < blocking, "{short:?} !< {blocking:?}");
        assert_eq!(short, s.target(exec, 1) + Duration::from_millis(1));
    }

    #[test]
    fn overlapped_target_stalls_on_long_transfers() {
        let mut s = TimeScaler::new(&prof(1.0), 1);
        let exec = Duration::from_millis(1);
        let long_h2d = Duration::from_millis(500);
        let t = s.target_overlapped(exec, 1, long_h2d, Duration::ZERO);
        assert_eq!(t, long_h2d, "transfer-bound package is bus-limited");
    }

    #[test]
    fn hold_waits_out_the_target() {
        let s = TimeScaler::new(&prof(1.0), 1);
        let start = Instant::now();
        let got = s.hold(start, Duration::from_millis(30));
        assert!(start.elapsed() >= Duration::from_millis(29));
        assert!(got >= Duration::from_millis(29));
    }

    #[test]
    fn degrade_multiplies_factor() {
        let mut s = TimeScaler::new(&prof(1.0), 1);
        let base = s.target(Duration::from_millis(10), 1);
        s.degrade(3.0);
        let slowed = s.target(Duration::from_millis(10), 1);
        // Compute stretches 3x; the per-launch overhead term does not.
        let overhead = Duration::from_millis(1).as_secs_f64();
        let want = (base.as_secs_f64() - overhead) * 3.0 + overhead;
        assert!((slowed.as_secs_f64() - want).abs() < 1e-9, "{slowed:?} vs {want}");
        // Non-positive factors are ignored, not inverted.
        s.degrade(0.0);
        s.degrade(-2.0);
        assert_eq!(s.target(Duration::from_millis(10), 1), slowed);
    }

    #[test]
    fn hold_noop_when_past() {
        let s = TimeScaler::new(&prof(1.0), 1);
        let start = Instant::now();
        std::thread::sleep(Duration::from_millis(5));
        let got = s.hold(start, Duration::from_millis(1));
        assert!(got >= Duration::from_millis(4));
    }
}
