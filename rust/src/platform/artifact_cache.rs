//! Compiled-artifact cache — the persistent runtime's answer to repeat
//! traffic paying the per-session setup bill over and over (PAPERS.md:
//! arxiv 2106.01726 reports program/artifact reuse dominating the
//! repeat-traffic setup cost in co-execution runtimes).
//!
//! Keyed exactly like [`PerfModelStore`](crate::platform::PerfModelStore):
//! `(kernel-key, device)`, where the kernel key carries the execution
//! mode (`<kernel>+pipe` for pipelined sessions) — a blocking session's
//! artifacts and a pipelined session's artifacts are distinct builds, so
//! the two must never alias. The first worker to touch a pair pays the
//! build (eager chunk-executable compilation plus the simulated
//! driver/platform init of Figure 13) and marks it resident; every later
//! worker on the same pair skips that setup. Hit/miss outcomes surface
//! per device on [`DeviceTrace`](crate::coordinator::DeviceTrace) and as
//! counters here, so "repeat traffic skips setup work" is a measured
//! number, not a claim.
//!
//! The cache is *opt-in per runtime*
//! ([`Runtime::with_artifact_cache`](crate::coordinator::Runtime::with_artifact_cache)):
//! solo engine runs and uncached runtimes keep their init timing
//! byte-identical to the pre-cache behavior.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Per-(kernel-key, device) residency record.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArtifactEntry {
    /// Sessions that found the artifact resident.
    pub hits: u64,
}

#[derive(Debug, Default)]
struct Inner {
    /// Resident artifacts in key order (deterministic iteration).
    built: BTreeMap<(String, String), ArtifactEntry>,
    hits: u64,
    misses: u64,
}

/// Thread-safe residency map + hit/miss counters (see module docs).
#[derive(Debug, Default)]
pub struct ArtifactCache {
    inner: Mutex<Inner>,
}

impl ArtifactCache {
    pub fn new() -> Self {
        Self::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Atomically look up `(key, device)` and mark it resident. Returns
    /// `true` on a hit (the artifact was already built — skip setup),
    /// `false` on the miss that makes it resident (this caller builds).
    /// Exactly one caller per pair ever sees the miss.
    pub fn acquire(&self, key: &str, device: &str) -> bool {
        let mut guard = self.lock();
        // Reborrow once: the live `entry` borrow must not overlap a
        // fresh `DerefMut` of the guard for the counter bumps.
        let inner = &mut *guard;
        match inner.built.entry((key.to_string(), device.to_string())) {
            std::collections::btree_map::Entry::Occupied(mut e) => {
                e.get_mut().hits += 1;
                inner.hits += 1;
                true
            }
            std::collections::btree_map::Entry::Vacant(v) => {
                v.insert(ArtifactEntry::default());
                inner.misses += 1;
                false
            }
        }
    }

    /// Total (hits, misses) across all pairs. Misses equal the number
    /// of distinct pairs ever touched — the invariant the cache tests
    /// pin.
    pub fn counters(&self) -> (u64, u64) {
        let inner = self.lock();
        (inner.hits, inner.misses)
    }

    /// Per-device (hits, misses) in device order — what the service
    /// harness converts into modeled setup time (a miss charges the
    /// device's init latency, a hit charges nothing).
    pub fn device_counters(&self) -> BTreeMap<String, (u64, u64)> {
        let inner = self.lock();
        let mut out: BTreeMap<String, (u64, u64)> = BTreeMap::new();
        for ((_, device), e) in &inner.built {
            let slot = out.entry(device.clone()).or_default();
            slot.0 += e.hits;
            slot.1 += 1; // one miss made this pair resident
        }
        out
    }

    /// Resident pairs in key order.
    pub fn keys(&self) -> Vec<(String, String)> {
        self.lock().built.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.lock().built.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().built.is_empty()
    }

    /// Drop every resident artifact and the counters (a cold restart).
    pub fn clear(&self) {
        let mut inner = self.lock();
        inner.built.clear();
        inner.hits = 0;
        inner.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_touch_is_the_only_miss() {
        let c = ArtifactCache::new();
        assert!(!c.acquire("binomial", "cpu"), "first touch builds");
        assert!(c.acquire("binomial", "cpu"), "second touch hits");
        assert!(c.acquire("binomial", "cpu"));
        assert_eq!(c.counters(), (2, 1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn pairs_are_independent_and_mode_keyed() {
        let c = ArtifactCache::new();
        assert!(!c.acquire("binomial", "cpu"));
        assert!(!c.acquire("binomial", "gpu"), "other device is its own build");
        assert!(!c.acquire("binomial+pipe", "cpu"), "pipelined mode is its own build");
        assert!(c.acquire("binomial", "cpu"));
        assert_eq!(c.counters(), (1, 3));
        assert_eq!(c.keys().len(), 3);
    }

    #[test]
    fn device_counters_split_by_device() {
        let c = ArtifactCache::new();
        c.acquire("a", "cpu");
        c.acquire("a", "cpu");
        c.acquire("b", "cpu");
        c.acquire("a", "gpu");
        let per = c.device_counters();
        assert_eq!(per["cpu"], (1, 2));
        assert_eq!(per["gpu"], (0, 1));
    }

    #[test]
    fn clear_resets_residency() {
        let c = ArtifactCache::new();
        c.acquire("a", "cpu");
        c.clear();
        assert!(c.is_empty());
        assert!(!c.acquire("a", "cpu"), "cleared pair rebuilds");
        assert_eq!(c.counters(), (0, 1));
    }

    /// Concurrent acquires on one pair: exactly one miss, N-1 hits.
    #[test]
    fn concurrent_acquire_has_exactly_one_miss() {
        let c = std::sync::Arc::new(ArtifactCache::new());
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = std::sync::Arc::clone(&c);
                std::thread::spawn(move || c.acquire("k", "dev"))
            })
            .collect();
        let hits = handles
            .into_iter()
            .map(|h| h.join().expect("acquire thread"))
            .filter(|&hit| hit)
            .count();
        assert_eq!(hits, 7, "exactly one thread pays the build");
        assert_eq!(c.counters(), (7, 1));
    }
}
