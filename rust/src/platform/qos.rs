//! Makespan prediction — the QoS layer's consumer of the performance
//! model (PAPERS.md: arxiv 2010.12607, co-execution under time
//! constraints).
//!
//! The [`MakespanPredictor`] prices a session before (and during) its
//! run: given the [`PerfModelStore`]'s per-(kernel, device) EWMA
//! throughput estimates and the current per-device contention (how many
//! sessions share each device's lease rotation), it estimates how long
//! the session's remaining granules will take. The runtime's admission
//! path uses it to reject provably-unfittable deadlined sessions up
//! front (`EclError::AdmissionRejected`), the session master uses it to
//! seed the schedulers' QoS hint, and the `--qos` harness uses it to
//! drive its admission decisions.
//!
//! # Cold vs warm
//!
//! The store's rates are absolute (granules/sec); the profile powers
//! are relative. Exactly like the schedulers' `ThroughputModel`, the
//! predictor bridges the two scales through the implied rate-per-power
//! of the devices the store *has* observed. A device set with no store
//! estimate at all has no absolute scale — the estimate is flagged via
//! [`MakespanEstimate::cold`] and its `secs` is only meaningful as a
//! relative quantity. Admission control therefore only rejects on
//! [`MakespanEstimate::fully_warm`] predictions: a cold store can never
//! cause a spurious rejection (asserted by the predictor property
//! suite).
//!
//! # Contention
//!
//! Device leases are granted package-by-package in rotation, so `m`
//! sessions sharing a device each see roughly `1/m` of its throughput.
//! [`DeviceLoad::sharers`] carries that count (this session included);
//! the predictor degrades each device's rate accordingly.

use crate::platform::perfmodel::PerfModelStore;

/// One selected device as the predictor sees it: the store lookup key,
/// the profile's relative-power fallback, and the lease contention.
#[derive(Debug, Clone)]
pub struct DeviceLoad {
    pub name: String,
    /// Static relative power — the cold-start fallback scale.
    pub power: f64,
    /// Sessions sharing this device's rotation, *this one included*
    /// (so always >= 1).
    pub sharers: usize,
}

impl DeviceLoad {
    pub fn new(name: impl Into<String>, power: f64, sharers: usize) -> Self {
        Self { name: name.into(), power, sharers }
    }
}

/// A priced session: predicted makespan plus how well-grounded the
/// price is.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MakespanEstimate {
    /// Predicted makespan in seconds. Only an absolute quantity when at
    /// least one device was warm (`!cold()`).
    pub secs: f64,
    /// Devices with a store-backed rate for this kernel key.
    pub warm_devices: usize,
    /// Devices in the selection.
    pub devices: usize,
}

impl MakespanEstimate {
    /// No device had a store estimate: `secs` has no absolute scale.
    /// Admission control must never reject on a cold estimate.
    pub fn cold(&self) -> bool {
        self.warm_devices == 0
    }

    /// Every selected device priced from a measured rate — the only
    /// grounding strong enough for admission *rejection*.
    pub fn fully_warm(&self) -> bool {
        self.devices > 0 && self.warm_devices == self.devices
    }

    /// Predicted slack against `deadline_secs` after `elapsed_secs` of
    /// the run: negative means the deadline is at risk.
    pub fn slack(&self, deadline_secs: f64, elapsed_secs: f64) -> f64 {
        deadline_secs - elapsed_secs - self.secs
    }
}

/// Stateless pricing over a [`PerfModelStore`] snapshot.
pub struct MakespanPredictor;

impl MakespanPredictor {
    /// Price `granules` of kernel `key` across `loads`. The aggregate
    /// throughput is the sum of each device's (store rate or
    /// power-imputed) rate divided by its sharer count.
    pub fn predict(
        store: &PerfModelStore,
        key: &str,
        granules: f64,
        loads: &[DeviceLoad],
    ) -> MakespanEstimate {
        // Finiteness guard: a zero/NaN/Inf rate from a degenerate store
        // entry (e.g. one injected past `fold`'s hygiene) is treated as
        // *unobserved* — the device falls back to power imputation and
        // does not count as warm, so a poisoned store can never produce
        // the fully-warm Inf/NaN estimate that would silently reject
        // every deadlined session at admission.
        let rates: Vec<Option<f64>> = loads
            .iter()
            .map(|l| store.estimate(key, &l.name).filter(|r| r.is_finite() && *r > 0.0))
            .collect();
        let mut sum_obs_rate = 0.0;
        let mut sum_obs_power = 0.0;
        let mut warm = 0usize;
        for (load, rate) in loads.iter().zip(&rates) {
            if let Some(r) = rate {
                sum_obs_rate += r;
                sum_obs_power += load.power.max(1e-6);
                warm += 1;
            }
        }
        let implied = if sum_obs_power > 0.0 { (sum_obs_rate / sum_obs_power).max(1e-9) } else { 1.0 };
        let effective: f64 = loads
            .iter()
            .zip(&rates)
            .map(|(load, rate)| {
                let r = rate.unwrap_or(load.power.max(1e-6) * implied);
                r / load.sharers.max(1) as f64
            })
            .sum();
        let secs = granules.max(0.0) / effective.max(1e-9);
        MakespanEstimate {
            // Belt over the per-rate filter above: if a non-finite
            // quantity slips through (e.g. Inf granules), degrade to
            // 0.0 — an estimate that can never cause a rejection —
            // rather than propagate NaN into slack accounting.
            secs: if secs.is_finite() { secs } else { 0.0 },
            warm_devices: warm,
            devices: loads.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use std::time::Duration;

    use super::*;

    fn warm_store(entries: &[(&str, f64)]) -> PerfModelStore {
        let store = PerfModelStore::new();
        for (dev, rate) in entries {
            // One observation = the EWMA seeds directly at the sample.
            store.record(0, "k", dev, *rate, Duration::from_secs(1));
        }
        store
    }

    #[test]
    fn warm_rates_price_directly() {
        let store = warm_store(&[("a", 100.0), ("b", 300.0)]);
        let loads = vec![DeviceLoad::new("a", 0.5, 1), DeviceLoad::new("b", 1.0, 1)];
        let est = MakespanPredictor::predict(&store, "k", 800.0, &loads);
        assert!(est.fully_warm());
        assert!(!est.cold());
        assert!((est.secs - 2.0).abs() < 1e-9, "800 granules / 400 g/s: {}", est.secs);
    }

    #[test]
    fn contention_degrades_throughput() {
        let store = warm_store(&[("a", 100.0)]);
        let solo = MakespanPredictor::predict(&store, "k", 100.0, &[DeviceLoad::new("a", 1.0, 1)]);
        let shared =
            MakespanPredictor::predict(&store, "k", 100.0, &[DeviceLoad::new("a", 1.0, 4)]);
        assert!((shared.secs - solo.secs * 4.0).abs() < 1e-9, "4 sharers = 4x makespan");
    }

    #[test]
    fn half_warm_imputes_from_observed_scale() {
        // Device b (power 1.0) warm at 200 g/s => implied 200/power-unit
        // => device a (power 0.5) imputed at 100 g/s.
        let store = warm_store(&[("b", 200.0)]);
        let loads = vec![DeviceLoad::new("a", 0.5, 1), DeviceLoad::new("b", 1.0, 1)];
        let est = MakespanPredictor::predict(&store, "k", 600.0, &loads);
        assert_eq!(est.warm_devices, 1);
        assert!(!est.fully_warm(), "half-warm must not clear the rejection bar");
        assert!((est.secs - 2.0).abs() < 1e-9, "600 / (100 + 200): {}", est.secs);
    }

    #[test]
    fn cold_store_is_flagged() {
        let store = PerfModelStore::new();
        let loads = vec![DeviceLoad::new("a", 0.3, 1), DeviceLoad::new("b", 1.0, 1)];
        let est = MakespanPredictor::predict(&store, "k", 130.0, &loads);
        assert!(est.cold());
        assert!(!est.fully_warm());
        // Relative scale only: granules / sum(powers).
        assert!((est.secs - 100.0).abs() < 1e-9);
    }

    #[test]
    fn wrong_kernel_key_is_cold() {
        let store = warm_store(&[("a", 100.0)]);
        let est = MakespanPredictor::predict(
            &store,
            "other-kernel",
            100.0,
            &[DeviceLoad::new("a", 1.0, 1)],
        );
        assert!(est.cold(), "rates for a different kernel must not warm this one");
    }

    #[test]
    fn slack_accounting() {
        let est = MakespanEstimate { secs: 2.0, warm_devices: 1, devices: 1 };
        assert!(est.slack(5.0, 1.0) > 0.0);
        assert!(est.slack(2.5, 1.0) < 0.0);
    }

    /// Regression (PR-8): a degenerate store entry (zero/NaN/Inf rate)
    /// must price like an *unobserved* device — power-imputed, not warm
    /// — instead of yielding an Inf/NaN "fully warm" estimate that
    /// silently rejects every deadlined session.
    #[test]
    fn poisoned_rates_fall_back_to_imputation() {
        for bad in [0.0, -3.0, f64::NAN, f64::INFINITY] {
            let store = warm_store(&[("b", 200.0)]);
            store.force_estimate("k", "a", bad, 5);
            let loads = vec![DeviceLoad::new("a", 0.5, 1), DeviceLoad::new("b", 1.0, 1)];
            let est = MakespanPredictor::predict(&store, "k", 600.0, &loads);
            assert_eq!(est.warm_devices, 1, "poisoned rate {bad} must not count as warm");
            assert!(!est.fully_warm(), "poisoned rate {bad} must block the rejection bar");
            assert!(est.secs.is_finite(), "poisoned rate {bad} leaked into secs: {}", est.secs);
            // Same price as the half-warm imputation case: 600 / (100 + 200).
            assert!((est.secs - 2.0).abs() < 1e-9, "rate {bad}: secs {}", est.secs);
        }
    }

    #[test]
    fn degenerate_inputs_do_not_blow_up() {
        let store = PerfModelStore::new();
        let est = MakespanPredictor::predict(&store, "k", 0.0, &[]);
        assert_eq!(est.devices, 0);
        assert!(est.secs.is_finite());
        let est =
            MakespanPredictor::predict(&store, "k", -5.0, &[DeviceLoad::new("a", 0.0, 0)]);
        assert!(est.secs >= 0.0 && est.secs.is_finite());
    }
}
