//! Persistent cross-session performance model: per-(kernel, device)
//! throughput estimates learned from completed-package timings.
//!
//! The store lives on the persistent [`Runtime`] (and on each
//! [`Engine`], for repeated solo runs), so sessions executed *later*
//! warm-start their schedulers from what sessions executed *earlier*
//! measured: `SessionExec` queries [`PerfModelStore::estimate`] for
//! every selected device at scheduler-start time and passes the result
//! as `SchedDevice::warm_rate`, then folds the session's observation
//! ledger back in at session end ([`PerfModelStore::record_session`],
//! the whole ledger under one lock hold). A mis-calibrated
//! `DeviceProfile::relative_power`, a device degraded by a `slow:`
//! fault in a previous run, or sustained lease contention all show up
//! here as a lower estimate — and the next session's first package is
//! already sized for the device that actually exists, not the one the
//! profile describes.
//!
//! **Units.** Estimates are granules/sec keyed by kernel, so they are
//! only ever compared within one kernel (granule sizes and per-granule
//! cost differ across kernels; the model never mixes them).
//!
//! **Fault tolerance.** Observations come from the per-worker ledgers
//! shipped with both `Finished` and `Failed` events, so a
//! fault-recovered run still contributes every package it completed —
//! the estimates survive (and reflect) device failures.
//!
//! **Determinism.** Every accepted observation is journaled in
//! ingestion order. Sessions ingest transactionally —
//! [`PerfModelStore::record_session`] holds the lock *once* for the
//! whole session ledger (devices in slot order, packages in completion
//! order), so concurrent sessions serialize at session granularity and
//! never interleave mid-ledger. A fixed seed and a *sequential* session
//! order reproduce the journal exactly; concurrent sessions ingest in
//! session-completion order, which is whatever the (seeded) simclock
//! produced. The journal is the audit trail that makes a warm-started
//! schedule explainable after the fact; it is a bounded ring (the most
//! recent [`JOURNAL_CAP`] records, [`PerfModelStore::journal_dropped`]
//! counts evictions) so a long-lived runtime's memory does not grow
//! with every package it ever executed — the EWMA estimates carry the
//! long-term state.
//!
//! **Keys.** Estimates are keyed by the kernel *and* execution mode:
//! pipelined sessions record under `<kernel>+pipe` (see
//! `SessionExec`), because a pipelined package's span excludes the
//! staging it overlapped while a blocking package's includes it —
//! mixing the two would let one mode's throughput mis-seed the other's
//! warm start.
//!
//! [`Runtime`]: crate::coordinator::runtime::Runtime
//! [`Engine`]: crate::coordinator::engine::Engine

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;
use std::time::Duration;

/// EWMA weight of the newest cross-session sample. Deliberately lower
/// than the in-run models' weights: the store spans sessions, where a
/// single outlier run should nudge, not overwrite, the estimate.
pub const STORE_ALPHA: f64 = 0.25;

/// Most journal records kept (a ring: oldest evicted first). Bounds a
/// persistent runtime's memory; the estimates keep the long-term state.
pub const JOURNAL_CAP: usize = 16_384;

/// One accepted observation, as journaled.
#[derive(Debug, Clone, PartialEq)]
pub struct ObservationRecord {
    /// Session the observation came from.
    pub session: u64,
    pub kernel: String,
    pub device: String,
    /// Package size, in granules.
    pub granules: f64,
    /// Simulated device-occupancy span of the package.
    pub span: Duration,
    /// The estimate *after* folding this observation in.
    pub estimate: f64,
}

/// Current estimate for one (kernel, device) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PerfEstimate {
    /// EWMA granules/sec.
    pub rate: f64,
    /// Observations folded in so far.
    pub samples: u64,
}

/// Current energy estimate for one (kernel, device) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyEstimate {
    /// EWMA joules per granule.
    pub epg: f64,
    /// Observations folded in so far.
    pub samples: u64,
}

#[derive(Debug, Default)]
struct Inner {
    estimates: BTreeMap<(String, String), PerfEstimate>,
    /// Joules/granule estimates, keyed like `estimates` — the energy
    /// model rides the same store (same keys, same session ingest) so
    /// a warm scheduler gets rate *and* cost-per-granule together.
    energy: BTreeMap<(String, String), EnergyEstimate>,
    journal: VecDeque<ObservationRecord>,
    /// Journal records evicted by the ring cap.
    dropped: u64,
}

/// The store itself: interior-mutable and `Sync` so one instance is
/// shared by every session of a runtime (and every run of an engine).
#[derive(Debug)]
pub struct PerfModelStore {
    alpha: f64,
    inner: Mutex<Inner>,
}

impl Default for PerfModelStore {
    fn default() -> Self {
        Self::new()
    }
}

impl PerfModelStore {
    pub fn new() -> Self {
        Self::with_alpha(STORE_ALPHA)
    }

    pub fn with_alpha(alpha: f64) -> Self {
        Self { alpha: alpha.clamp(0.01, 1.0), inner: Mutex::new(Inner::default()) }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// The current warm-start estimate for `kernel` on `device`
    /// (granules/sec), if any session has observed the pair.
    pub fn estimate(&self, kernel: &str, device: &str) -> Option<f64> {
        self.lock()
            .estimates
            .get(&(kernel.to_string(), device.to_string()))
            .map(|e| e.rate)
    }

    /// Full estimate record (rate + sample count) for a pair.
    pub fn estimate_record(&self, kernel: &str, device: &str) -> Option<PerfEstimate> {
        self.lock()
            .estimates
            .get(&(kernel.to_string(), device.to_string()))
            .copied()
    }

    /// Fold one observation into the (locked) store state. Degenerate
    /// samples (empty packages, zero/negative spans, NaNs) are dropped,
    /// not journaled.
    fn fold(
        inner: &mut Inner,
        alpha: f64,
        session: u64,
        kernel: &str,
        device: &str,
        granules: f64,
        span: Duration,
    ) {
        let secs = span.as_secs_f64();
        if !granules.is_finite() || granules <= 0.0 || secs <= 0.0 {
            return;
        }
        let sample = granules / secs;
        // A denormal-tiny span can still overflow the division: the
        // resulting rate must be finite and positive or the EWMA is
        // poisoned forever (an Inf estimate never decays away).
        if !sample.is_finite() || sample <= 0.0 {
            return;
        }
        let e = inner
            .estimates
            .entry((kernel.to_string(), device.to_string()))
            .or_insert(PerfEstimate { rate: 0.0, samples: 0 });
        e.rate = if e.samples == 0 {
            sample
        } else {
            alpha * sample + (1.0 - alpha) * e.rate
        };
        e.samples += 1;
        let estimate = e.rate;
        if inner.journal.len() == JOURNAL_CAP {
            inner.journal.pop_front();
            inner.dropped += 1;
        }
        inner.journal.push_back(ObservationRecord {
            session,
            kernel: kernel.to_string(),
            device: device.to_string(),
            granules,
            span,
            estimate,
        });
    }

    /// Fold one completed package in: `granules` granules over `span`.
    pub fn record(&self, session: u64, kernel: &str, device: &str, granules: f64, span: Duration) {
        let mut inner = self.lock();
        Self::fold(&mut inner, self.alpha, session, kernel, device, granules, span);
    }

    /// Fold a whole session's ledger in under **one** lock hold — the
    /// transactional ingest `SessionExec` uses, so concurrent sessions
    /// serialize at session granularity and their EWMA folds and
    /// journal entries never interleave mid-ledger.
    pub fn record_session(
        &self,
        session: u64,
        kernel: &str,
        ledger: &[(&str, f64, Duration)],
    ) {
        let mut inner = self.lock();
        for &(device, granules, span) in ledger {
            Self::fold(&mut inner, self.alpha, session, kernel, device, granules, span);
        }
    }

    /// The current joules/granule estimate for `kernel` on `device`,
    /// if any session has recorded energy for the pair.
    pub fn energy_estimate(&self, kernel: &str, device: &str) -> Option<f64> {
        self.lock()
            .energy
            .get(&(kernel.to_string(), device.to_string()))
            .map(|e| e.epg)
    }

    /// Full energy estimate record (joules/granule + sample count).
    pub fn energy_estimate_record(&self, kernel: &str, device: &str) -> Option<EnergyEstimate> {
        self.lock()
            .energy
            .get(&(kernel.to_string(), device.to_string()))
            .copied()
    }

    /// Fold one energy observation into the (locked) store: `joules`
    /// consumed computing `granules` granules. Same hygiene as `fold` —
    /// degenerate samples (empty packages, zero/negative/NaN joules, a
    /// non-finite per-granule quotient) are dropped.
    fn fold_energy(
        inner: &mut Inner,
        alpha: f64,
        kernel: &str,
        device: &str,
        granules: f64,
        joules: f64,
    ) {
        if !granules.is_finite() || granules <= 0.0 || !joules.is_finite() || joules <= 0.0 {
            return;
        }
        let sample = joules / granules;
        if !sample.is_finite() || sample <= 0.0 {
            return;
        }
        let e = inner
            .energy
            .entry((kernel.to_string(), device.to_string()))
            .or_insert(EnergyEstimate { epg: 0.0, samples: 0 });
        e.epg = if e.samples == 0 {
            sample
        } else {
            alpha * sample + (1.0 - alpha) * e.epg
        };
        e.samples += 1;
    }

    /// Fold one completed package's energy in.
    pub fn record_energy(
        &self,
        _session: u64,
        kernel: &str,
        device: &str,
        granules: f64,
        joules: f64,
    ) {
        let mut inner = self.lock();
        Self::fold_energy(&mut inner, self.alpha, kernel, device, granules, joules);
    }

    /// Fold a whole session's energy ledger in under one lock hold —
    /// `(device, granules, joules)` per completed package, the energy
    /// counterpart of [`record_session`](Self::record_session).
    pub fn record_session_energy(
        &self,
        _session: u64,
        kernel: &str,
        ledger: &[(&str, f64, f64)],
    ) {
        let mut inner = self.lock();
        for &(device, granules, joules) in ledger {
            Self::fold_energy(&mut inner, self.alpha, kernel, device, granules, joules);
        }
    }

    /// Inject a raw estimate, bypassing `fold`'s sample hygiene — a
    /// diagnostics/test hook for reproducing *poisoned* store states
    /// (e.g. an Inf rate restored from a corrupt journal). Consumers
    /// must survive such entries (see the poisoned-store admission
    /// regression in `qos_props`); production ingest goes through
    /// [`PerfModelStore::record`]/[`record_session`], which cannot
    /// create them.
    pub fn force_estimate(&self, kernel: &str, device: &str, rate: f64, samples: u64) {
        let mut inner = self.lock();
        inner
            .estimates
            .insert((kernel.to_string(), device.to_string()), PerfEstimate { rate, samples });
    }

    /// Every (kernel, device) pair with an estimate, in key order.
    pub fn keys(&self) -> Vec<(String, String)> {
        self.lock().estimates.keys().cloned().collect()
    }

    /// Snapshot of the observation journal (the most recent
    /// [`JOURNAL_CAP`] records).
    pub fn journal(&self) -> Vec<ObservationRecord> {
        self.lock().journal.iter().cloned().collect()
    }

    pub fn journal_len(&self) -> usize {
        self.lock().journal.len()
    }

    /// Records evicted by the journal ring so far (0 until the cap is
    /// reached; the estimates are unaffected by eviction).
    pub fn journal_dropped(&self) -> u64 {
        self.lock().dropped
    }

    /// Total samples folded in across all pairs.
    pub fn total_samples(&self) -> u64 {
        self.lock().estimates.values().map(|e| e.samples).sum()
    }

    /// Drop every estimate and the journal (a cold restart).
    pub fn clear(&self) {
        let mut inner = self.lock();
        inner.estimates.clear();
        inner.energy.clear();
        inner.journal.clear();
        inner.dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    #[test]
    fn empty_store_has_no_estimates() {
        let s = PerfModelStore::new();
        assert_eq!(s.estimate("binomial", "gpu"), None);
        assert_eq!(s.journal_len(), 0);
        assert_eq!(s.total_samples(), 0);
        assert!(s.keys().is_empty());
    }

    #[test]
    fn first_sample_sets_rate_then_ewma() {
        let s = PerfModelStore::with_alpha(0.25);
        s.record(0, "binomial", "gpu", 100.0, ms(100));
        assert!((s.estimate("binomial", "gpu").unwrap() - 1000.0).abs() < 1e-9);
        s.record(0, "binomial", "gpu", 50.0, ms(100));
        // 0.25 * 500 + 0.75 * 1000 = 875.
        let e = s.estimate_record("binomial", "gpu").unwrap();
        assert!((e.rate - 875.0).abs() < 1e-9);
        assert_eq!(e.samples, 2);
    }

    #[test]
    fn pairs_are_isolated_by_kernel_and_device() {
        let s = PerfModelStore::new();
        s.record(0, "binomial", "gpu", 100.0, ms(100));
        s.record(1, "nbody", "gpu", 10.0, ms(100));
        s.record(2, "binomial", "cpu", 30.0, ms(100));
        assert_eq!(s.keys().len(), 3);
        assert!((s.estimate("nbody", "gpu").unwrap() - 100.0).abs() < 1e-9);
        assert!((s.estimate("binomial", "cpu").unwrap() - 300.0).abs() < 1e-9);
        assert_eq!(s.estimate("nbody", "cpu"), None);
    }

    #[test]
    fn degenerate_samples_are_dropped() {
        let s = PerfModelStore::new();
        s.record(0, "b", "d", 0.0, ms(100));
        s.record(0, "b", "d", 10.0, Duration::ZERO);
        s.record(0, "b", "d", f64::NAN, ms(100));
        assert_eq!(s.estimate("b", "d"), None);
        assert_eq!(s.journal_len(), 0, "dropped samples are not journaled");
    }

    #[test]
    fn record_session_matches_per_package_records() {
        let a = PerfModelStore::with_alpha(0.5);
        let b = PerfModelStore::with_alpha(0.5);
        let ledger: Vec<(&str, f64, Duration)> = vec![
            ("gpu", 100.0, ms(100)),
            ("gpu", 50.0, ms(100)),
            ("cpu", 30.0, ms(100)),
            ("cpu", 0.0, ms(100)), // degenerate, dropped
        ];
        a.record_session(7, "binomial", &ledger);
        for &(d, g, s) in &ledger {
            b.record(7, "binomial", d, g, s);
        }
        assert_eq!(
            a.estimate_record("binomial", "gpu"),
            b.estimate_record("binomial", "gpu")
        );
        assert_eq!(
            a.estimate_record("binomial", "cpu"),
            b.estimate_record("binomial", "cpu")
        );
        assert_eq!(a.journal_len(), 3, "degenerate sample not journaled");
        assert_eq!(a.journal(), b.journal());
    }

    #[test]
    fn journal_is_a_bounded_ring() {
        let s = PerfModelStore::new();
        let extra = 10u64;
        for i in 0..(JOURNAL_CAP as u64 + extra) {
            s.record(i, "b", "d", 10.0, ms(10));
        }
        assert_eq!(s.journal_len(), JOURNAL_CAP);
        assert_eq!(s.journal_dropped(), extra);
        // The ring keeps the newest records; the estimates keep counting.
        assert_eq!(s.journal().first().unwrap().session, extra);
        assert_eq!(s.total_samples(), JOURNAL_CAP as u64 + extra);
        s.clear();
        assert_eq!(s.journal_dropped(), 0);
    }

    #[test]
    fn energy_ewma_and_hygiene() {
        let s = PerfModelStore::with_alpha(0.25);
        assert_eq!(s.energy_estimate("b", "gpu"), None);
        s.record_energy(0, "b", "gpu", 10.0, 50.0);
        assert!((s.energy_estimate("b", "gpu").unwrap() - 5.0).abs() < 1e-9);
        s.record_energy(0, "b", "gpu", 10.0, 10.0);
        // 0.25 * 1 + 0.75 * 5 = 4.
        let e = s.energy_estimate_record("b", "gpu").unwrap();
        assert!((e.epg - 4.0).abs() < 1e-9);
        assert_eq!(e.samples, 2);
        // Degenerate samples are dropped, never folded.
        s.record_energy(0, "b", "gpu", 0.0, 50.0);
        s.record_energy(0, "b", "gpu", 10.0, f64::NAN);
        s.record_energy(0, "b", "gpu", 10.0, -1.0);
        s.record_energy(0, "b", "gpu", f64::INFINITY, 10.0);
        assert_eq!(s.energy_estimate_record("b", "gpu").unwrap().samples, 2);
        // Session ingest matches per-package ingest, and clear() wipes.
        let t = PerfModelStore::with_alpha(0.25);
        t.record_session_energy(0, "b", &[("gpu", 10.0, 50.0), ("gpu", 10.0, 10.0)]);
        assert_eq!(
            t.energy_estimate_record("b", "gpu"),
            s.energy_estimate_record("b", "gpu")
        );
        s.clear();
        assert_eq!(s.energy_estimate("b", "gpu"), None);
    }

    #[test]
    fn journal_records_ingestion_order_and_estimates() {
        let s = PerfModelStore::with_alpha(0.5);
        s.record(3, "b", "d", 100.0, ms(1000));
        s.record(4, "b", "d", 300.0, ms(1000));
        let j = s.journal();
        assert_eq!(j.len(), 2);
        assert_eq!(j[0].session, 3);
        assert!((j[0].estimate - 100.0).abs() < 1e-9);
        assert!((j[1].estimate - 200.0).abs() < 1e-9, "EWMA after the second sample");
        assert_eq!(s.total_samples(), 2);
        s.clear();
        assert_eq!(s.journal_len(), 0);
        assert_eq!(s.estimate("b", "d"), None);
    }
}
