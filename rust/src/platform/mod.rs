//! Simulated heterogeneous platform.
//!
//! The paper evaluates on two physical nodes (Batel: Xeon CPU + K20m GPU +
//! Xeon Phi; Remo: A10 APU CPU + R7 iGPU + GTX 950). We do not have OpenCL
//! devices, so each `Device` worker runs the *real* chunk kernels on its
//! own PJRT CPU client and stretches the measured execution time by a
//! calibrated factor — scheduling dynamics depend only on relative speeds,
//! per-package overheads and the content-dependent cost profile, all of
//! which are preserved (DESIGN.md §4).

pub mod artifact_cache;
pub mod fault;
pub mod perfmodel;
pub mod profile;
pub mod qos;
pub mod simclock;

pub use artifact_cache::{ArtifactCache, ArtifactEntry};
pub use fault::{FaultInjector, FaultKind, FaultPlan, FaultSpec, FaultTrigger};
pub use perfmodel::{EnergyEstimate, ObservationRecord, PerfEstimate, PerfModelStore};
pub use profile::{DeviceKind, DeviceProfile, NodeConfig};
pub use qos::{DeviceLoad, MakespanEstimate, MakespanPredictor};
pub use simclock::TimeScaler;
