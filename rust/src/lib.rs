//! # enginecl — EngineCL reproduced on a Rust + JAX + Pallas stack
//!
//! A reproduction of *EngineCL: Usability and Performance in
//! Heterogeneous Computing* (Nozal, Bosque, Beivide — FGCS 2020), built
//! as a three-layer system:
//!
//! * **L1** — Pallas kernels (the paper's five OpenCL benchmarks),
//!   AOT-lowered at build time (`python/compile/kernels/`).
//! * **L2** — JAX chunk wrappers per (benchmark, chunk size), exported as
//!   HLO text artifacts (`python/compile/model.py`, `aot.py`).
//! * **L3** — this crate: the EngineCL coordinator. Tiered API
//!   ([`Engine`](coordinator::Engine)/[`Program`](coordinator::Program)
//!   = Tier-1; [`DeviceSpec`](coordinator::DeviceSpec),
//!   [`Configurator`](coordinator::Configurator), scheduler selection =
//!   Tier-2; device worker threads, the runtime backends, work
//!   decomposition = Tier-3), with the paper's three
//!   pluggable schedulers (Static / Dynamic / HGuided) plus the
//!   feedback-driven **Adaptive** scheduler (all closed into a loop by
//!   `Scheduler::observe`, backed by a persistent cross-session
//!   performance model — `platform::perfmodel`), a composable
//!   package **pipeline** (`Engine::pipeline(depth)` / the `+pipe`
//!   scheduler suffix) that overlaps host↔device transfers with compute,
//!   a persistent **runtime** ([`Runtime`](coordinator::Runtime)) that
//!   admits concurrent [`RunSession`](coordinator::RunSession)s and
//!   co-executes them across the device set under whole-device leases,
//!   and the Introspector.
//!
//! Python never runs on the request path: `make artifacts` produces
//! self-contained HLO text + golden data which the `pjrt` feature
//! executes through PJRT (`xla` crate). Without that feature (the
//! offline default) a pure-Rust native executor runs the same kernels
//! over the same scheduling machinery, and a synthetic artifact registry
//! generates the golden workloads in-process — `cargo test` and every
//! example work with no Python and no network.
//!
//! ```
//! use enginecl::prelude::*;
//!
//! let mut engine = Engine::new()?;
//! engine.use_mask(DeviceMask::All);
//! engine.scheduler(SchedulerKind::hguided());
//! engine.pipeline(2); // overlap package n+1's upload with package n
//!
//! let mut program = Program::new();
//! program.kernel("binomial", "binomial");
//! let reg = engine.registry().clone();
//! let bench = reg.bench("binomial")?.clone();
//! for buf in reg.golden_inputs(&bench)? {
//!     program.input(buf.as_f32().unwrap().to_vec());
//! }
//! program.output(bench.outputs[0].elems);
//! program.out_pattern(1, 255);
//!
//! engine.program(program);
//! engine.run()?;
//! let report = engine.report().unwrap();
//! println!("balance = {:.3}", report.balance());
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod coordinator;
pub mod harness;
pub mod metrics;
pub mod platform;
pub mod runtime;
pub mod testing;
pub mod util;

/// Everything a typical program needs.
pub mod prelude {
    pub use crate::coordinator::{
        Buffer, Configurator, DeviceMask, DeviceSpec, EclError, Engine, FaultEvent,
        LeasePolicy, Program, Request, Response, ResponseHandle, RunReport, RunSession,
        Runtime, SchedulerKind, Served, Service, ServiceConfig, SessionHandle, SessionOutcome,
    };
    pub use crate::platform::{
        DeviceKind, DeviceProfile, FaultKind, FaultPlan, NodeConfig, PerfModelStore,
    };
    pub use crate::runtime::{ArtifactRegistry, HostBuf};
}
