//! `run --steal` — the tail-squashing work-stealing sweep (PR-10
//! acceptance bench), emitted as `BENCH_steal.json`.
//!
//! Sweeps {HGuided, Adaptive} × {off, tail-only, eager} × {binomial,
//! collatz} through a depth-[`STEAL_BENCH_DEPTH`] pipelined virtual-time
//! drain that mirrors the master loop's stealing machinery: real
//! [`Scheduler`] instances fill per-device prefetch queues, a
//! master-side [`ThroughputModel`] (same [`STEAL_MODEL_ALPHA`] as the
//! runtime) prices candidate steals with the real [`price_steal`], and a
//! profitable steal absorbs the victim's queue from the back — splitting
//! the deepest entry at a granule boundary, never touching the two
//! shielded slots (in-flight plus staged) the worker cannot yield.
//! The whole sweep is a pure function of the seed; the CI steal-suite
//! diffs two invocations byte-for-byte.
//!
//! The straggler workload is the `collatz` kernel: its hot band sits at
//! the *front* of the index space, so the cold-start prior hands the hot
//! granules out in its largest, least-informed prefetch batches — the
//! queues are stale before the first observation can return, and the
//! victim's backlog is exactly what cooperative stealing exists to
//! revoke. `binomial` (regular, uniform cost) rides along to pin the
//! other side of the contract: on a well-balanced kernel the pricing
//! rule keeps the policy quiet.
//!
//! Honesty note: a stolen package is charged a restart surcharge of one
//! granule-time on the thief — the same `C = 1/r_t` the pricing rule
//! charges — so the sim can never claim a win the pricing model did not
//! pay for.
//!
//! The `--steal` guard asserts, per base scheduler:
//!
//! * collatz, tail-only vs off: makespan shrinks to <=
//!   [`STEAL_GUARD_SPEEDUP`] of no-steal AND balance efficiency gains >=
//!   [`STEAL_GUARD_BALANCE`], with at least one steal issued;
//! * binomial: tail-only and eager stay within
//!   [`STEAL_GUARD_OVERHEAD`] of no-steal makespan.

use std::collections::VecDeque;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::runtime::STEAL_MODEL_ALPHA;
use crate::coordinator::scheduler::{
    price_steal, PackageTiming, SchedDevice, Scheduler, SchedulerKind, StealPolicy,
    ThroughputModel, DEFAULT_STEAL_THRESHOLD,
};
use crate::coordinator::work::Range;
use crate::platform::NodeConfig;
use crate::runtime::kernels::collatz_item_steps;
use crate::runtime::ArtifactRegistry;
use crate::util::rng::XorShift;

/// Pipeline depth of every cell (steal and no-steal alike, so the
/// comparison isolates the policy): deep enough that a victim holds a
/// stealable backlog beyond its two shielded slots, and the depth at
/// which cold-start prefetch staleness makes the straggler band hurt.
pub const STEAL_BENCH_DEPTH: usize = 4;
/// Guard: tail-only stealing must shrink the collatz makespan to at
/// most this fraction of the no-steal run (>= 10% improvement).
pub const STEAL_GUARD_SPEEDUP: f64 = 0.90;
/// Guard: tail-only stealing must lift collatz balance efficiency by at
/// least this much over the no-steal run.
pub const STEAL_GUARD_BALANCE: f64 = 0.05;
/// Guard: stealing may cost a regular kernel at most 1% makespan.
pub const STEAL_GUARD_OVERHEAD: f64 = 1.01;
/// Queue slots a victim never yields: the in-flight package plus the
/// staged prefetch (the master's `shielded` for pipelined workers).
const SHIELDED: usize = 2;

/// Kernels of the sweep: one regular control, one heavy-tailed straggler.
pub fn steal_kernels() -> Vec<&'static str> {
    vec!["binomial", "collatz"]
}

/// Base strategies the policies wrap, in column order.
pub fn steal_bases() -> Vec<&'static str> {
    vec!["hguided", "adaptive"]
}

/// Steal policies compared per base, in column order.
pub fn steal_policies() -> Vec<(&'static str, StealPolicy)> {
    vec![
        ("off", StealPolicy::Off),
        ("tail", StealPolicy::TailOnly { threshold: DEFAULT_STEAL_THRESHOLD }),
        ("eager", StealPolicy::Eager),
    ]
}

fn base_kind(base: &str) -> SchedulerKind {
    match base {
        "hguided" => SchedulerKind::hguided(),
        "adaptive" => SchedulerKind::adaptive(),
        other => panic!("unknown steal-bench base {other}"),
    }
}

/// Knobs of the sweep (CLI: `run --steal [--seed S] [--quick]`).
///
/// `quick` is accepted for CLI symmetry with the other suites and
/// recorded in the artifact; the sweep itself is already sub-second
/// (12 virtual drains), so quick mode runs the identical grid.
#[derive(Debug, Clone)]
pub struct StealBenchConfig {
    pub seed: u64,
    pub quick: bool,
}

impl Default for StealBenchConfig {
    fn default() -> Self {
        Self { seed: 7, quick: false }
    }
}

/// One (kernel × base × policy) cell of the sweep.
#[derive(Debug, Clone)]
pub struct StealCell {
    pub kernel: String,
    pub base: &'static str,
    pub policy: &'static str,
    /// Canonical scheduler spec of the drained kind (round-trips
    /// through `parse_spec`).
    pub spec: String,
    /// Virtual-seconds makespan of the drain.
    pub makespan_s: f64,
    /// Mean device utilization: sum(busy) / (ndev × makespan).
    pub balance_eff: f64,
    /// Steals the master issued (every issued steal moved work — the
    /// sim has no in-flight races, so no empty yields).
    pub steals: usize,
    /// Work-items moved victim→thief across all steals.
    pub items_moved: usize,
    pub packages: usize,
    /// Total device idle under the makespan (the tail the policy is
    /// meant to squash).
    pub idle_s: f64,
}

/// The full `run --steal` result.
#[derive(Debug)]
pub struct StealBench {
    pub node: String,
    pub seed: u64,
    pub quick: bool,
    pub depth: usize,
    /// Row-major: kernels × bases × [`steal_policies`] order.
    pub cells: Vec<StealCell>,
}

impl StealBench {
    pub fn cell(&self, kernel: &str, base: &str, policy: &str) -> Option<&StealCell> {
        self.cells
            .iter()
            .find(|c| c.kernel == kernel && c.base == base && c.policy == policy)
    }

    /// The `BENCH_steal.json` artifact — hand-rolled like the other
    /// bench emitters (no serde offline). Every field derives from the
    /// seeded virtual-time sweep, so same-seed invocations are
    /// byte-identical.
    pub fn json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"node\": \"{}\",\n", self.node));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"depth\": {},\n", self.depth));
        s.push_str("  \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"kernel\": \"{}\", \"base\": \"{}\", \"policy\": \"{}\", \
                 \"spec\": \"{}\", \"makespan_s\": {:.4}, \"balance_eff\": {:.4}, \
                 \"steals\": {}, \"items_moved\": {}, \"packages\": {}, \
                 \"idle_s\": {:.4}}}{}\n",
                c.kernel,
                c.base,
                c.policy,
                c.spec,
                c.makespan_s,
                c.balance_eff,
                c.steals,
                c.items_moved,
                c.packages,
                c.idle_s,
                if i + 1 < self.cells.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"headline\": [\n");
        let bases = steal_bases();
        for (i, base) in bases.iter().enumerate() {
            let (speedup_pct, balance_gain) = match (
                self.cell("collatz", base, "off"),
                self.cell("collatz", base, "tail"),
            ) {
                (Some(off), Some(st)) if off.makespan_s > 0.0 => (
                    100.0 * (off.makespan_s - st.makespan_s) / off.makespan_s,
                    st.balance_eff - off.balance_eff,
                ),
                _ => (0.0, 0.0),
            };
            s.push_str(&format!(
                "    {{\"base\": \"{base}\", \"collatz_speedup_pct\": {speedup_pct:.4}, \
                 \"collatz_balance_gain\": {balance_gain:.4}}}{}\n",
                if i + 1 < bases.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    /// The CI guard (`ECL_BENCH_GUARD=1`): tail-only stealing squashes
    /// the straggler tail on both bases and never taxes the regular
    /// kernel.
    pub fn guard(&self) -> Result<()> {
        for c in &self.cells {
            anyhow::ensure!(
                c.makespan_s.is_finite() && c.makespan_s > 0.0,
                "degenerate steal cell {}/{}/{}: makespan {:.4}s",
                c.kernel,
                c.base,
                c.policy,
                c.makespan_s
            );
        }
        for base in steal_bases() {
            let off = self
                .cell("collatz", base, "off")
                .ok_or_else(|| anyhow::anyhow!("missing collatz/{base}/off cell"))?;
            let st = self
                .cell("collatz", base, "tail")
                .ok_or_else(|| anyhow::anyhow!("missing collatz/{base}/tail cell"))?;
            anyhow::ensure!(
                st.steals > 0,
                "steal regression ({base}): no steal issued on the straggler kernel"
            );
            anyhow::ensure!(
                st.makespan_s <= STEAL_GUARD_SPEEDUP * off.makespan_s,
                "steal regression ({base}): collatz makespan {:.4}s vs no-steal {:.4}s \
                 (must be <= {:.0}%)",
                st.makespan_s,
                off.makespan_s,
                STEAL_GUARD_SPEEDUP * 100.0
            );
            anyhow::ensure!(
                st.balance_eff >= off.balance_eff + STEAL_GUARD_BALANCE,
                "steal regression ({base}): collatz balance {:.3} vs no-steal {:.3} \
                 (must gain >= {:.2})",
                st.balance_eff,
                off.balance_eff,
                STEAL_GUARD_BALANCE
            );
            let off_b = self
                .cell("binomial", base, "off")
                .ok_or_else(|| anyhow::anyhow!("missing binomial/{base}/off cell"))?;
            for (policy, _) in steal_policies().into_iter().filter(|(p, _)| *p != "off") {
                let c = self
                    .cell("binomial", base, policy)
                    .ok_or_else(|| anyhow::anyhow!("missing binomial/{base}/{policy} cell"))?;
                anyhow::ensure!(
                    c.makespan_s <= STEAL_GUARD_OVERHEAD * off_b.makespan_s,
                    "steal overhead ({base}/{policy}): binomial makespan {:.4}s vs \
                     no-steal {:.4}s (must stay within {:.0}%)",
                    c.makespan_s,
                    off_b.makespan_s,
                    (STEAL_GUARD_OVERHEAD - 1.0) * 100.0
                );
            }
        }
        Ok(())
    }
}

/// Per-granule cost weights, normalized so their sum equals the granule
/// count — rates stay in nominal granules/sec while hot granules charge
/// their true multiple. For `collatz` the weights come from the exact
/// per-item cost helper the native kernel executes
/// ([`collatz_item_steps`] — a kernel test pins the lockstep); every
/// other kernel is uniform.
fn granule_weights(reg: &ArtifactRegistry, kernel: &str) -> Result<Vec<f64>> {
    let bench = reg.bench(kernel)?;
    let g_count = (bench.n / bench.granule).max(1);
    if kernel != "collatz" {
        return Ok(vec![1.0; g_count]);
    }
    let mut raw = Vec::with_capacity(g_count);
    for g in 0..g_count {
        let mut w = 0.0f64;
        for p in g * bench.granule..(g + 1) * bench.granule {
            w += collatz_item_steps(bench, p)? as f64;
        }
        raw.push(w);
    }
    let total: f64 = raw.iter().sum();
    anyhow::ensure!(total > 0.0, "collatz weights must be positive");
    Ok(raw.iter().map(|w| w * g_count as f64 / total).collect())
}

/// Seeded per-(kernel, device) rates, energy-suite style: relative
/// power, jittered ±4% and normalized so the uncontended all-device
/// ideal makespan is ~1 virtual second. Drawn in one fixed pass so the
/// RNG stream never depends on drain outcomes.
fn kernel_rates(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    kernels: &[&'static str],
    seed: u64,
) -> Result<Vec<(usize, Vec<f64>)>> {
    let total_power: f64 = node.devices.iter().map(|d| d.relative_power).sum();
    anyhow::ensure!(total_power > 0.0, "node {} has no compute power", node.name);
    let mut rng = XorShift::new(seed ^ 0x57EA_15E5);
    let mut out = Vec::with_capacity(kernels.len());
    for kernel in kernels {
        let bench = reg.bench(kernel)?;
        anyhow::ensure!(bench.granule > 0, "bench {kernel} has zero granule");
        let granules = (bench.n / bench.granule).max(1);
        let base = granules as f64 / total_power;
        let rates: Vec<f64> = node
            .devices
            .iter()
            .map(|d| base * d.relative_power.max(1e-6) * (0.96 + 0.08 * rng.next_f64()))
            .collect();
        out.push((granules, rates));
    }
    Ok(out)
}

/// The virtual-clock drain: an event-driven mirror of the master loop's
/// pipelined dispatch plus stealing. Each device executes its queue
/// front; completions feed the scheduler and the pricing model; a dry,
/// un-refused device triggers the master's steal pass (victim with the
/// worst predicted remaining time among profitably priced candidates).
struct Sim<'a> {
    granule: usize,
    total_items: usize,
    weights: &'a [f64],
    rates: &'a [f64],
    sched: Box<dyn Scheduler>,
    policy: StealPolicy,
    depth: usize,
    /// Master-side pending ledger per device: front = in-flight once
    /// started; the bool marks a stolen (pool-sourced) package.
    pending: Vec<VecDeque<(Range, bool)>>,
    /// Virtual finish time of the in-flight front, when running.
    running: Vec<Option<f64>>,
    busy: Vec<f64>,
    done_at: Vec<f64>,
    dry: Vec<bool>,
    refused: Vec<bool>,
    completed_items: usize,
    /// Yielded ranges awaiting re-dispatch (thief first).
    pool: VecDeque<(Range, bool)>,
    model: ThroughputModel,
    steals: usize,
    items_moved: usize,
    packages: usize,
    now: f64,
}

impl<'a> Sim<'a> {
    fn new(
        kind: &SchedulerKind,
        policy: StealPolicy,
        node: &NodeConfig,
        granules: usize,
        granule: usize,
        weights: &'a [f64],
        rates: &'a [f64],
    ) -> Self {
        let mut sched = kind.build();
        // Cold start by design: the straggler story is the prior-driven
        // prefetch committed before the first observations return.
        let sdevs: Vec<SchedDevice> = node
            .devices
            .iter()
            .map(|d| SchedDevice::new(d.name.clone(), d.relative_power))
            .collect();
        sched.start(granules, granule, &sdevs);
        let mut model = ThroughputModel::new(STEAL_MODEL_ALPHA);
        model.start(&sdevs);
        let ndev = node.devices.len();
        Self {
            granule,
            total_items: granules * granule,
            weights,
            rates,
            depth: sched.pipeline_depth().max(1),
            sched,
            policy,
            pending: vec![VecDeque::new(); ndev],
            running: vec![None; ndev],
            busy: vec![0.0; ndev],
            done_at: vec![0.0; ndev],
            dry: vec![false; ndev],
            refused: vec![false; ndev],
            completed_items: 0,
            pool: VecDeque::new(),
            model,
            steals: 0,
            items_moved: 0,
            packages: 0,
            now: 0.0,
        }
    }

    fn ndev(&self) -> usize {
        self.rates.len()
    }

    /// Virtual cost of `range` in granule-units (hot granules charge
    /// their true weight).
    fn weight(&self, range: Range) -> f64 {
        let gb = range.begin / self.granule;
        let ge = range.end / self.granule;
        self.weights[gb..ge].iter().sum()
    }

    /// Refill `dev`'s queue to the pipeline depth: steal pool first
    /// (the master's re-dispatch), then the scheduler. A `None` from a
    /// scheduler that has undelivered work left is a deliberate refusal
    /// (tail cutoff) — such a device never thieves.
    fn top_up(&mut self, dev: usize) {
        while self.pending[dev].len() < self.depth {
            if let Some(entry) = self.pool.pop_front() {
                self.pending[dev].push_back(entry);
                continue;
            }
            if self.dry[dev] {
                break;
            }
            match self.sched.next_package(dev) {
                Some(r) => self.pending[dev].push_back((r, false)),
                None => {
                    self.dry[dev] = true;
                    let in_ledgers: usize = self
                        .pending
                        .iter()
                        .map(|q| q.iter().map(|(r, _)| r.len()).sum::<usize>())
                        .sum();
                    if self.completed_items + in_ledgers < self.total_items {
                        self.refused[dev] = true;
                    }
                    break;
                }
            }
        }
    }

    /// Start the queue front executing, if idle and non-empty. A stolen
    /// package pays the one-granule-time restart surcharge the pricing
    /// rule charged for it.
    fn start_dev(&mut self, dev: usize) {
        if self.running[dev].is_none() {
            if let Some(&(range, stolen)) = self.pending[dev].front() {
                let mut w = self.weight(range);
                if stolen {
                    w += 1.0;
                }
                self.running[dev] = Some(self.now + w / self.rates[dev]);
            }
        }
    }

    /// The master's steal pass on behalf of a dry `thief`: price every
    /// candidate victim's unshielded backlog, pick the one predicted to
    /// finish last, absorb from the back of its queue at a granule
    /// boundary, and re-dispatch (thief first).
    fn try_steal(&mut self, thief: usize) {
        if self.policy.is_off()
            || !self.dry[thief]
            || self.refused[thief]
            || !self.pending[thief].is_empty()
            || !self.pool.is_empty()
        {
            return;
        }
        let thief_rate = self.model.rate(thief);
        // (victim, items to request, predicted remaining time).
        let mut best: Option<(usize, usize, f64)> = None;
        for v in 0..self.ndev() {
            if v == thief {
                continue;
            }
            let backlog: usize =
                self.pending[v].iter().skip(SHIELDED).map(|(r, _)| r.len()).sum();
            if backlog < self.granule {
                continue;
            }
            let total: usize = self.pending[v].iter().map(|(r, _)| r.len()).sum();
            let victim_rate = self.model.rate(v);
            let Some(take) = price_steal(
                self.policy,
                self.granule,
                backlog,
                total,
                victim_rate,
                thief_rate,
            ) else {
                continue;
            };
            let t_old = total as f64 / (self.granule as f64 * victim_rate.max(1e-9));
            if best.map_or(true, |(_, _, t)| t_old > t) {
                best = Some((v, take, t_old));
            }
        }
        let Some((victim, take, _)) = best else { return };
        // Absorb from the back of the victim's queue — whole entries
        // while they fit, then a granule-boundary split of the deepest
        // remaining entry — exactly the worker's truncation rule. The
        // shielded slots are never touched.
        let mut budget = take;
        let mut moved: Vec<Range> = Vec::new();
        while budget >= self.granule && self.pending[victim].len() > SHIELDED {
            let &(back, _) = self.pending[victim].back().expect("len > SHIELDED");
            if back.len() <= budget {
                self.pending[victim].pop_back();
                budget -= back.len();
                moved.push(back);
            } else {
                let keep_items = back.len() - budget;
                let keep_granules = keep_items.div_ceil(self.granule);
                let cut = back.begin + keep_granules * self.granule;
                if cut < back.end {
                    moved.push(Range::new(cut, back.end));
                    self.pending[victim].back_mut().expect("len > SHIELDED").0.end = cut;
                }
                break;
            }
        }
        if moved.is_empty() {
            return;
        }
        let items: usize = moved.iter().map(Range::len).sum();
        self.steals += 1;
        self.items_moved += items;
        self.sched.on_steal(victim, thief, items);
        for r in moved {
            self.pool.push_back((r, true));
        }
        self.top_up(thief);
        self.top_up(victim);
        if !self.pool.is_empty() {
            for d in 0..self.ndev() {
                self.top_up(d);
            }
        }
        for d in 0..self.ndev() {
            self.start_dev(d);
        }
    }

    /// Drain to completion; returns (makespan, balance, idle).
    fn run(&mut self) -> (f64, f64, f64) {
        for d in 0..self.ndev() {
            self.top_up(d);
            self.start_dev(d);
        }
        for d in 0..self.ndev() {
            self.try_steal(d);
        }
        loop {
            // Next completion: earliest finish, lowest index on ties.
            let mut next: Option<usize> = None;
            for d in 0..self.ndev() {
                if let Some(t) = self.running[d] {
                    if next.map_or(true, |n| t < self.running[n].expect("running")) {
                        next = Some(d);
                    }
                }
            }
            let Some(dev) = next else { break };
            self.now = self.running[dev].take().expect("selected running device");
            let (range, stolen) = self.pending[dev].pop_front().expect("in-flight front");
            let mut w = self.weight(range);
            if stolen {
                w += 1.0;
            }
            let span = w / self.rates[dev];
            self.busy[dev] += span;
            self.done_at[dev] = self.now;
            self.completed_items += range.len();
            self.packages += 1;
            let granules = range.len() as f64 / self.granule as f64;
            let timing = PackageTiming {
                span: Duration::from_secs_f64(span),
                raw_exec: Duration::from_secs_f64(span),
            };
            self.sched.observe(dev, range, timing);
            self.model.observe(dev, granules, Duration::from_secs_f64(span));
            self.top_up(dev);
            self.start_dev(dev);
            for t in 0..self.ndev() {
                self.try_steal(t);
            }
        }
        assert_eq!(
            self.completed_items, self.total_items,
            "virtual drain must execute the pool exactly once"
        );
        let makespan = self.done_at.iter().copied().fold(0.0, f64::max);
        let total_busy: f64 = self.busy.iter().sum();
        let balance = if makespan > 0.0 {
            total_busy / (self.ndev() as f64 * makespan)
        } else {
            1.0
        };
        let idle = (self.ndev() as f64 * makespan - total_busy).max(0.0);
        (makespan, balance, idle)
    }
}

/// Run the sweep over the full grid.
pub fn run_steal(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    cfg: &StealBenchConfig,
) -> Result<StealBench> {
    let kernels = steal_kernels();
    let shapes = kernel_rates(reg, node, &kernels, cfg.seed)?;
    let mut cells =
        Vec::with_capacity(kernels.len() * steal_bases().len() * steal_policies().len());
    for (kernel, (granules, rates)) in kernels.iter().zip(&shapes) {
        let granule = reg.bench(kernel)?.granule;
        let weights = granule_weights(reg, kernel)?;
        for base in steal_bases() {
            for (policy_name, policy) in steal_policies() {
                let kind = base_kind(base).pipelined(STEAL_BENCH_DEPTH).stealing(policy);
                let mut sim =
                    Sim::new(&kind, policy, node, *granules, granule, &weights, rates);
                let (makespan, balance, idle) = sim.run();
                cells.push(StealCell {
                    kernel: kernel.to_string(),
                    base,
                    policy: policy_name,
                    spec: kind.spec(),
                    makespan_s: makespan,
                    balance_eff: balance,
                    steals: sim.steals,
                    items_moved: sim.items_moved,
                    packages: sim.packages,
                    idle_s: idle,
                });
            }
        }
    }
    Ok(StealBench {
        node: node.name.clone(),
        seed: cfg.seed,
        quick: cfg.quick,
        depth: STEAL_BENCH_DEPTH,
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn bench(seed: u64, quick: bool) -> StealBench {
        let reg = ArtifactRegistry::synthetic();
        let node = NodeConfig::batel();
        let cfg = StealBenchConfig { seed, quick };
        run_steal(&reg, &node, &cfg).unwrap()
    }

    #[test]
    fn same_seed_is_byte_identical() {
        let a = bench(7, false);
        let b = bench(7, false);
        assert_eq!(a.json(), b.json(), "steal sweep must be a pure function of the seed");
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(bench(7, false).json(), bench(8, false).json());
    }

    #[test]
    fn reference_sweep_clears_the_guard() {
        let b = bench(7, false);
        assert!(b.guard().is_ok(), "guard failed:\n{}\n{:?}", b.json(), b.guard());
        assert_eq!(b.cells.len(), 12, "2 kernels x 2 bases x 3 policies");
    }

    #[test]
    fn quick_sweep_clears_the_guard_too() {
        // CI runs the guard in quick mode (the grid is identical; the
        // flag is recorded so artifacts are self-describing).
        let b = bench(7, true);
        assert!(b.guard().is_ok(), "quick guard: {}", b.json());
        assert!(b.quick);
    }

    #[test]
    fn pricing_keeps_the_regular_kernel_quiet() {
        // On binomial the balance is healthy, so every candidate steal
        // must be priced out — zero moves under the tail-only policy at
        // the reference seed.
        let b = bench(7, false);
        for base in steal_bases() {
            let c = b.cell("binomial", base, "tail").unwrap();
            assert_eq!(
                c.items_moved, 0,
                "binomial/{base}: tail-only policy moved work on a regular kernel"
            );
        }
    }

    #[test]
    fn straggler_tail_is_squashed_with_real_steals() {
        let b = bench(7, false);
        for base in steal_bases() {
            let off = b.cell("collatz", base, "off").unwrap();
            let st = b.cell("collatz", base, "tail").unwrap();
            assert!(st.steals > 0, "{base}: no steals on the straggler");
            assert!(st.items_moved > 0, "{base}: steals must move items");
            assert!(
                st.idle_s < off.idle_s,
                "{base}: stealing must shrink tail idle ({:.3} vs {:.3})",
                st.idle_s,
                off.idle_s
            );
        }
    }

    #[test]
    fn json_is_parseable_with_headline() {
        let b = bench(7, false);
        let doc = Json::parse(&b.json()).expect("valid JSON");
        assert_eq!(doc.get("node").and_then(Json::as_str), Some("batel"));
        assert_eq!(doc.get("depth").and_then(Json::as_f64), Some(STEAL_BENCH_DEPTH as f64));
        let cells = doc.get("cells").and_then(Json::as_arr).unwrap();
        assert_eq!(cells.len(), 12);
        let headline = doc.get("headline").and_then(Json::as_arr).unwrap();
        assert_eq!(headline.len(), 2);
        for h in headline {
            let speedup = h.get("collatz_speedup_pct").and_then(Json::as_f64).unwrap();
            assert!(speedup >= 10.0, "headline speedup below the guard: {speedup}");
        }
    }

    #[test]
    fn specs_carry_the_policy_suffix() {
        let b = bench(7, false);
        let tail = b.cell("collatz", "hguided", "tail").unwrap();
        assert!(tail.spec.ends_with("+steal"), "spec {}", tail.spec);
        let eager = b.cell("collatz", "adaptive", "eager").unwrap();
        assert!(eager.spec.ends_with("+steal:eager"), "spec {}", eager.spec);
        let off = b.cell("collatz", "hguided", "off").unwrap();
        assert!(!off.spec.contains("steal"), "spec {}", off.spec);
    }
}
