//! Figure 13 — initialization-to-compute timelines for Binomial on Batel:
//! the Xeon Phi driver needs the CPU, so its init stretches from ~1.8 s
//! (solo) to ~2.7 s under co-execution, imbalancing Static runs.

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{DeviceSpec, SchedulerKind};
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

use super::runs::run_once;

/// Per-device init/compute segments for one configuration.
#[derive(Debug, Clone)]
pub struct InitTimeline {
    pub config: String,
    pub devices: Vec<DeviceSegment>,
}

#[derive(Debug, Clone)]
pub struct DeviceSegment {
    pub name: String,
    pub init_end: Duration,
    pub first_compute: Duration,
    pub completion: Duration,
}

/// The paper's Figure-13 grid: each device solo (base case) plus every
/// scheduler configuration co-executing all devices.
pub fn timelines(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
) -> Result<Vec<InitTimeline>> {
    let mut out = Vec::new();
    // Base cases: one device at a time.
    for (i, d) in node.devices.iter().enumerate() {
        let report = run_once(
            reg,
            node,
            bench,
            vec![DeviceSpec::new(i)],
            SchedulerKind::static_default(),
            None,
        )?;
        out.push(InitTimeline {
            config: format!("base {}", d.name),
            devices: segments(&report),
        });
    }
    // Co-execution configs.
    let all: Vec<DeviceSpec> = (0..node.devices.len()).map(DeviceSpec::new).collect();
    for kind in super::runs::paper_schedulers() {
        let report = run_once(reg, node, bench, all.clone(), kind.clone(), None)?;
        out.push(InitTimeline { config: kind.label(), devices: segments(&report) });
    }
    Ok(out)
}

fn segments(report: &crate::coordinator::RunReport) -> Vec<DeviceSegment> {
    report
        .devices
        .iter()
        .map(|d| DeviceSegment {
            name: d.name.clone(),
            init_end: d.init_end,
            first_compute: d.packages.first().map(|p| p.start).unwrap_or(d.init_end),
            completion: d.completion(),
        })
        .collect()
}
