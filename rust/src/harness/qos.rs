//! `run --qos` — the mixed-priority QoS soak (PR-6 acceptance bench).
//!
//! Drives hundreds of sessions with seeded arrivals through a
//! virtual-time discrete-event simulation of the runtime's admission
//! and co-execution path, and reports the deadline hit-rate plus
//! p95/p99 tail latency as `BENCH_qos.json`.
//!
//! The soak reuses the *real* QoS components rather than re-modelling
//! them: the real [`QosController`] (EDF hold-back, seeded shedding,
//! journal), the real [`MakespanPredictor`] over a real, progressively
//! warming [`PerfModelStore`], the real [`admission_tiebreak`] /
//! [`STARVATION_BOUND`] admission rules, and real [`Scheduler`]
//! instances draining each admitted session package-by-package. Only
//! *time* is simulated: devices run at seeded synthetic rates on a
//! virtual clock, so the whole soak is a pure function of the seed —
//! two invocations with the same seed emit byte-identical JSON (the
//! CI qos-suite diffs them).
//!
//! # Workload model
//!
//! Session `i` draws (in a fixed order, so the RNG stream is identical
//! regardless of earlier outcomes): an inter-arrival gap, a kernel from
//! the balance grid, a QoS class (`deadlined_prob`), a deadline
//! tightness, and a per-device throughput jitter. Device rates are
//! normalized so every session's *ideal* (uncontended, perfectly
//! balanced) makespan is ~1 virtual second. Most deadlines are generous
//! multiples of the ideal; a small `tight_prob` fraction get deadlines
//! near the ideal — under lease contention those are exactly the
//! sessions the QoS layer must reject up front (warm store) or shed
//! best-effort work for (cold store), and the ones that may miss.
//!
//! Admitted sessions run their scheduler to completion at admission
//! time (rates frozen at the admission-time contention), which yields
//! the session's finish event; paused best-effort victims make no
//! progress until their at-risk cause departs, exactly like a parked
//! master loop.

use std::collections::VecDeque;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::qos::{
    admission_tiebreak, QosClass, QosController, QosEvent, QosPolicy, STARVATION_BOUND,
};
use crate::coordinator::scheduler::{parse_spec, PackageTiming, QosHint, SchedDevice, Scheduler};
use crate::harness::balance::balance_kernels;
use crate::platform::qos::{DeviceLoad, MakespanPredictor};
use crate::platform::{NodeConfig, PerfModelStore};
use crate::runtime::ArtifactRegistry;
use crate::util::rng::XorShift;
use crate::util::stats;

/// Scheduler specs the soak cycles through (session `i` gets spec
/// `i % 3`): both feedback schedulers that consume the QoS hint, plus
/// a fixed-chunk control.
pub fn qos_specs() -> Vec<&'static str> {
    vec!["adaptive", "hguided", "dynamic:32"]
}

/// Knobs of the soak (CLI: `run --qos [--sessions N] [--seed S]
/// [--quick]`).
#[derive(Debug, Clone)]
pub struct QosBenchConfig {
    pub sessions: usize,
    pub seed: u64,
    pub quick: bool,
    /// Admission window of the simulated runtime.
    pub max_in_flight: usize,
    /// Probability a session carries a deadline.
    pub deadlined_prob: f64,
    /// Probability a *deadlined* session's deadline is tight (near the
    /// uncontended ideal — likely to be rejected or shed under load).
    pub tight_prob: f64,
}

impl Default for QosBenchConfig {
    fn default() -> Self {
        Self {
            sessions: 200,
            seed: 7,
            quick: false,
            max_in_flight: 3,
            deadlined_prob: 0.6,
            tight_prob: 0.05,
        }
    }
}

/// Outcome of one simulated session.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SessionFate {
    /// Completed; for deadlined sessions the flag is `finish - arrival
    /// <= deadline`.
    Completed { met: Option<bool> },
    /// Refused at admission (fully-warm prediction over the reject bar).
    Rejected,
}

/// One simulated session's ledger row.
#[derive(Debug, Clone)]
pub struct QosSessionResult {
    pub label: String,
    pub kernel: String,
    pub spec: &'static str,
    pub deadline: Option<f64>,
    pub arrival: f64,
    /// Admission (virtual) time; for rejected sessions, the rejection
    /// time.
    pub start: f64,
    /// Completion time; equals `start` for rejected sessions.
    pub finish: f64,
    pub fate: SessionFate,
    pub packages: usize,
}

impl QosSessionResult {
    /// Submission-to-completion latency in virtual seconds.
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }
}

/// The full `run --qos` result.
#[derive(Debug)]
pub struct QosBench {
    pub node: String,
    pub seed: u64,
    pub quick: bool,
    pub max_in_flight: usize,
    pub results: Vec<QosSessionResult>,
    /// The controller's decision journal (sheds, resumes, rejections).
    pub journal: Vec<QosEvent>,
}

impl QosBench {
    pub fn completed(&self) -> usize {
        self.results.iter().filter(|r| matches!(r.fate, SessionFate::Completed { .. })).count()
    }

    pub fn rejected(&self) -> usize {
        self.results.iter().filter(|r| r.fate == SessionFate::Rejected).count()
    }

    pub fn deadlined_completed(&self) -> usize {
        self.results
            .iter()
            .filter(|r| matches!(r.fate, SessionFate::Completed { met: Some(_) }))
            .count()
    }

    pub fn met(&self) -> usize {
        self.results
            .iter()
            .filter(|r| matches!(r.fate, SessionFate::Completed { met: Some(true) }))
            .count()
    }

    pub fn missed(&self) -> usize {
        self.results
            .iter()
            .filter(|r| matches!(r.fate, SessionFate::Completed { met: Some(false) }))
            .count()
    }

    pub fn sheds(&self) -> usize {
        self.journal.iter().filter(|e| matches!(e, QosEvent::Paused { .. })).count()
    }

    pub fn at_risk_events(&self) -> usize {
        self.journal.iter().filter(|e| matches!(e, QosEvent::AtRisk { .. })).count()
    }

    /// Deadline hit-rate over *completed* deadlined sessions (rejected
    /// sessions were refused service, not served late); 1.0 when no
    /// deadlined session completed.
    pub fn hit_rate(&self) -> f64 {
        let n = self.deadlined_completed();
        if n == 0 {
            1.0
        } else {
            self.met() as f64 / n as f64
        }
    }

    fn latencies(&self) -> Vec<f64> {
        self.results
            .iter()
            .filter(|r| matches!(r.fate, SessionFate::Completed { .. }))
            .map(|r| r.latency())
            .collect()
    }

    fn best_effort_latencies(&self) -> Vec<f64> {
        self.results
            .iter()
            .filter(|r| r.fate == SessionFate::Completed { met: None })
            .map(|r| r.latency())
            .collect()
    }

    /// The `BENCH_qos.json` artifact — hand-rolled like the other bench
    /// emitters (no serde offline). Every field is derived from the
    /// seeded virtual-time run, so same-seed invocations are
    /// byte-identical.
    pub fn json(&self) -> String {
        let lat = self.latencies();
        let be = self.best_effort_latencies();
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"node\": \"{}\",\n", self.node));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"max_in_flight\": {},\n", self.max_in_flight));
        s.push_str(&format!("  \"sessions\": {},\n", self.results.len()));
        s.push_str(&format!("  \"completed\": {},\n", self.completed()));
        s.push_str(&format!("  \"rejected\": {},\n", self.rejected()));
        s.push_str(&format!(
            "  \"deadlined\": {{\"completed\": {}, \"met\": {}, \"missed\": {}}},\n",
            self.deadlined_completed(),
            self.met(),
            self.missed()
        ));
        s.push_str(&format!("  \"hit_rate\": {:.4},\n", self.hit_rate()));
        s.push_str(&format!("  \"sheds\": {},\n", self.sheds()));
        s.push_str(&format!("  \"at_risk_events\": {},\n", self.at_risk_events()));
        s.push_str(&format!(
            "  \"latency_virtual_s\": {{\"mean\": {:.4}, \"p50\": {:.4}, \"p95\": {:.4}, \
             \"p99\": {:.4}}},\n",
            stats::mean(&lat),
            stats::percentile(&lat, 50.0),
            stats::percentile(&lat, 95.0),
            stats::percentile(&lat, 99.0)
        ));
        s.push_str(&format!(
            "  \"best_effort_latency_virtual_s\": {{\"completed\": {}, \"p95\": {:.4}, \
             \"p99\": {:.4}}}\n",
            be.len(),
            stats::percentile(&be, 95.0),
            stats::percentile(&be, 99.0)
        ));
        s.push_str("}\n");
        s
    }

    /// The CI guard (`ECL_BENCH_GUARD=1`): the reference mix must land
    /// a >= 0.90 deadline hit-rate (the PR-6 acceptance bar), and every
    /// submitted session must be accounted for.
    pub fn guard(&self) -> Result<()> {
        anyhow::ensure!(
            self.completed() + self.rejected() == self.results.len(),
            "qos accounting leak: {} completed + {} rejected != {} sessions",
            self.completed(),
            self.rejected(),
            self.results.len()
        );
        let hit = self.hit_rate();
        anyhow::ensure!(
            hit >= 0.90,
            "qos regression: deadline hit-rate {hit:.3} below the 0.90 floor \
             ({} met / {} completed deadlined, {} rejected)",
            self.met(),
            self.deadlined_completed(),
            self.rejected()
        );
        Ok(())
    }
}

// ---- the virtual-time simulation ------------------------------------

/// One generated session, pre-drawn before the event loop runs so the
/// RNG stream never depends on scheduling outcomes.
#[derive(Clone)]
struct SimSpec {
    id: u64,
    label: String,
    kernel: String,
    spec: &'static str,
    granules: usize,
    granule: usize,
    arrival: f64,
    deadline: Option<f64>,
    /// True per-device rates (granules / virtual second), uncontended.
    rates: Vec<f64>,
}

struct Queued {
    spec: SimSpec,
    bypassed: usize,
}

struct RunningSess {
    id: u64,
    deadlined: bool,
    finish: f64,
    /// Virtual time the controller paused this victim (best-effort
    /// only); progress freezes until resume.
    paused_at: Option<f64>,
    result: QosSessionResult,
}

/// Drain one session's scheduler over the node's devices at the given
/// contention, recording uncontended occupancy spans into the store
/// (lease waits are not occupancy — mirroring the real master loop) and
/// returning (makespan, packages).
fn drain_session(
    spec: &SimSpec,
    node: &NodeConfig,
    store: &PerfModelStore,
    contention: usize,
    hint: Option<QosHint>,
) -> (f64, usize) {
    let kind = parse_spec(spec.spec).expect("qos_specs are valid scheduler specs");
    let mut sched = kind.build();
    let sdevs: Vec<SchedDevice> = node
        .devices
        .iter()
        .map(|d| {
            SchedDevice::new(d.name.clone(), d.relative_power)
                .with_warm_rate(store.estimate(&spec.kernel, &d.name))
                .with_qos(hint)
        })
        .collect();
    let ndev = node.devices.len();
    sched.start(spec.granules, spec.granule, &sdevs);
    let mut busy = vec![0.0f64; ndev];
    let mut open = vec![true; ndev];
    let mut packages = 0usize;
    let c = contention.max(1) as f64;
    loop {
        // Always extend the least-loaded still-open device — the
        // virtual-time analogue of "the free device asks next".
        let dev = match (0..ndev)
            .filter(|d| open[*d])
            .min_by(|a, b| busy[*a].total_cmp(&busy[*b]).then(a.cmp(b)))
        {
            Some(d) => d,
            None => break,
        };
        match sched.next_package(dev) {
            Some(range) => {
                let g = (range.len() / spec.granule).max(1) as f64;
                let occ = g / spec.rates[dev];
                sched.observe(
                    dev,
                    range,
                    PackageTiming {
                        span: Duration::from_secs_f64(occ),
                        raw_exec: Duration::from_secs_f64(occ),
                    },
                );
                store.record(
                    spec.id,
                    &spec.kernel,
                    &node.devices[dev].name,
                    g,
                    Duration::from_secs_f64(occ),
                );
                // Wall-clock progress is slowed by lease rotation among
                // `contention` sessions.
                busy[dev] += occ * c;
                packages += 1;
            }
            None => open[dev] = false,
        }
    }
    (busy.iter().copied().fold(0.0, f64::max), packages)
}

/// Generate the soak's sessions up front from one seeded stream.
fn generate(reg: &ArtifactRegistry, node: &NodeConfig, cfg: &QosBenchConfig) -> Result<Vec<SimSpec>> {
    let kernels = balance_kernels();
    let specs = qos_specs();
    let total_power: f64 = node.devices.iter().map(|d| d.relative_power).sum();
    anyhow::ensure!(total_power > 0.0, "node {} has no compute power", node.name);
    let mut rng = XorShift::new(cfg.seed ^ 0x9059_B3C4);
    let mut out = Vec::with_capacity(cfg.sessions);
    let mut arrival = 0.0f64;
    for i in 0..cfg.sessions {
        // Fixed draw order per session: gap, kernel, class, tight,
        // tightness, then one jitter per device.
        arrival += 1.2 + 2.6 * rng.next_f64();
        let kernel = kernels[rng.below(kernels.len())];
        let u_class = rng.next_f64();
        let u_tight = rng.next_f64();
        let u_dl = rng.next_f64();
        let bench = reg.bench(kernel).with_context(|| format!("qos soak kernel {kernel}"))?;
        anyhow::ensure!(bench.granule > 0, "bench {kernel} has zero granule");
        let granules = (bench.n / bench.granule).max(1);
        // Rates normalized so the uncontended ideal makespan is ~1s.
        let base = granules as f64 / total_power;
        let rates: Vec<f64> = node
            .devices
            .iter()
            .map(|d| base * d.relative_power.max(1e-6) * (0.9 + 0.2 * rng.next_f64()))
            .collect();
        let ideal = granules as f64 / rates.iter().sum::<f64>();
        let deadline = if u_class < cfg.deadlined_prob {
            Some(if u_tight < cfg.tight_prob {
                // Near-ideal: unfittable under contention — the
                // reject/shed exercise.
                ideal * (0.9 + 0.4 * u_dl)
            } else {
                // Generous: must always be met (the hit-rate floor
                // rides on these).
                ideal * (40.0 + 40.0 * u_dl)
            })
        } else {
            None
        };
        out.push(SimSpec {
            id: i as u64,
            label: format!("s{i:03}-{kernel}"),
            kernel: kernel.to_string(),
            spec: specs[i % specs.len()],
            granules,
            granule: bench.granule,
            arrival,
            deadline,
            rates,
        });
    }
    Ok(out)
}

/// Admission at virtual time `now`, mirroring the runtime's `admit`:
/// EDF with the seeded tie-break among deadlined sessions, FIFO with
/// [`STARVATION_BOUND`] aging otherwise, the at-risk best-effort hold,
/// and predictor-based rejection on fully-warm estimates.
#[allow(clippy::too_many_arguments)]
fn admit(
    now: f64,
    queue: &mut VecDeque<Queued>,
    running: &mut Vec<RunningSess>,
    store: &PerfModelStore,
    ctl: &QosController,
    policy: &QosPolicy,
    node: &NodeConfig,
    cfg: &QosBenchConfig,
    finished: &mut Vec<QosSessionResult>,
) {
    while running.len() < cfg.max_in_flight && !queue.is_empty() {
        let head_starved = queue.front().map(|q| q.bypassed >= STARVATION_BOUND).unwrap_or(false);
        let pick = if head_starved {
            0
        } else {
            queue
                .iter()
                .enumerate()
                .min_by(|(i, a), (j, b)| {
                    let ka = match a.spec.deadline {
                        Some(d) => (d, admission_tiebreak(cfg.seed, &a.spec.label)),
                        None => (f64::INFINITY, u64::MAX),
                    };
                    let kb = match b.spec.deadline {
                        Some(d) => (d, admission_tiebreak(cfg.seed, &b.spec.label)),
                        None => (f64::INFINITY, u64::MAX),
                    };
                    ka.0.total_cmp(&kb.0).then(ka.1.cmp(&kb.1)).then(i.cmp(j))
                })
                .map(|(i, _)| i)
                .unwrap_or(0)
        };
        // Hold queued best-effort work back while any admitted deadline
        // is at risk (the starved head overrides the hold).
        if !head_starved && queue[pick].spec.deadline.is_none() && ctl.any_at_risk() {
            break;
        }
        for q in queue.iter_mut().take(pick) {
            q.bypassed += 1;
        }
        let q = queue.remove(pick).expect("pick is in range");
        let spec = q.spec;
        let sharers = running.len() + 1;
        let loads: Vec<DeviceLoad> = node
            .devices
            .iter()
            .map(|d| DeviceLoad::new(d.name.clone(), d.relative_power, sharers))
            .collect();
        let est = MakespanPredictor::predict(store, &spec.kernel, spec.granules as f64, &loads);
        if let Some(d) = spec.deadline {
            if est.fully_warm() && est.secs > policy.reject_factor * d {
                ctl.record_rejection(
                    spec.id,
                    &spec.label,
                    Duration::from_secs_f64(est.secs),
                    Duration::from_secs_f64(d),
                );
                finished.push(QosSessionResult {
                    label: spec.label,
                    kernel: spec.kernel,
                    spec: spec.spec,
                    deadline: Some(d),
                    arrival: spec.arrival,
                    start: now,
                    finish: now,
                    fate: SessionFate::Rejected,
                    packages: 0,
                });
                continue;
            }
        }
        let class = if spec.deadline.is_some() { QosClass::Deadlined } else { QosClass::BestEffort };
        ctl.register(spec.id, class);
        let hint = spec.deadline.map(|d| {
            QosHint::new(d, if est.cold() { 0.0 } else { est.secs })
        });
        let (makespan, packages) = drain_session(&spec, node, store, sharers, hint);
        let finish = now + makespan;
        if let Some(d) = spec.deadline {
            // The master's slack report, grounded on the true finish
            // time: negative slack marks the session at risk and sheds
            // one best-effort victim.
            let slack = (spec.arrival + d) - finish;
            if slack < 0.0 {
                ctl.report_slack(spec.id, slack);
                for r in running.iter_mut() {
                    if r.paused_at.is_none() && ctl.is_paused(r.id) {
                        r.paused_at = Some(now);
                    }
                }
            }
        }
        running.push(RunningSess {
            id: spec.id,
            deadlined: spec.deadline.is_some(),
            finish,
            paused_at: None,
            result: QosSessionResult {
                label: spec.label,
                kernel: spec.kernel,
                spec: spec.spec,
                deadline: spec.deadline,
                arrival: spec.arrival,
                start: now,
                finish,
                fate: SessionFate::Completed { met: None },
                packages,
            },
        });
    }
}

/// Run the soak: a deterministic virtual-time event loop over seeded
/// arrivals.
pub fn run_qos(reg: &ArtifactRegistry, node: &NodeConfig, cfg: &QosBenchConfig) -> Result<QosBench> {
    let mut cfg = cfg.clone();
    if cfg.quick {
        cfg.sessions = (cfg.sessions / 4).max(12);
    }
    anyhow::ensure!(cfg.max_in_flight > 0, "max_in_flight must be positive");
    let specs = generate(reg, node, &cfg)?;
    let policy = QosPolicy::enabled();
    let ctl = QosController::new(cfg.seed, policy);
    let store = PerfModelStore::new();
    let mut queue: VecDeque<Queued> = VecDeque::new();
    let mut running: Vec<RunningSess> = Vec::new();
    let mut finished: Vec<QosSessionResult> = Vec::new();
    let mut next = 0usize;
    let mut now = 0.0f64;
    while next < specs.len() || !queue.is_empty() || !running.is_empty() {
        admit(now, &mut queue, &mut running, &store, &ctl, &policy, node, &cfg, &mut finished);
        // Next event: the earliest unpaused completion or the next
        // arrival; completions win exact ties. Paused victims make no
        // progress, but their at-risk cause is always unpaused and
        // running, so a completion event always exists while anything
        // is paused.
        let fin = running
            .iter()
            .enumerate()
            .filter(|(_, r)| r.paused_at.is_none())
            .min_by(|(_, a), (_, b)| a.finish.total_cmp(&b.finish).then(a.id.cmp(&b.id)))
            .map(|(i, r)| (i, r.finish));
        let arr = specs.get(next).map(|s| s.arrival);
        let take_completion = match (fin, arr) {
            (Some((_, f)), Some(a)) => f <= a,
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => break,
        };
        if take_completion {
            let (idx, f) = fin.expect("completion selected");
            now = f;
            let mut done = running.swap_remove(idx);
            done.result.finish = now;
            done.result.fate = SessionFate::Completed {
                met: done
                    .result
                    .deadline
                    .map(|d| now - done.result.arrival <= d),
            };
            debug_assert!(done.deadlined == done.result.deadline.is_some());
            ctl.deregister(done.id);
            // Victims the departure resumed pick their clocks back up;
            // the paused interval is pure delay.
            for r in running.iter_mut() {
                if let Some(p) = r.paused_at {
                    if !ctl.is_paused(r.id) {
                        r.finish += now - p;
                        r.paused_at = None;
                    }
                }
            }
            finished.push(done.result);
        } else {
            now = arr.expect("arrival selected");
            queue.push_back(Queued { spec: specs[next].clone(), bypassed: 0 });
            next += 1;
        }
    }
    // Stable report order: by submission (arrivals are strictly
    // increasing), not completion.
    finished.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    Ok(QosBench {
        node: node.name.clone(),
        seed: cfg.seed,
        quick: cfg.quick,
        max_in_flight: cfg.max_in_flight,
        results: finished,
        journal: ctl.journal(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn bench(sessions: usize, seed: u64) -> QosBench {
        let reg = ArtifactRegistry::synthetic();
        let node = NodeConfig::batel();
        let cfg = QosBenchConfig { sessions, seed, ..QosBenchConfig::default() };
        run_qos(&reg, &node, &cfg).unwrap()
    }

    #[test]
    fn same_seed_is_byte_identical() {
        let a = bench(60, 7);
        let b = bench(60, 7);
        assert_eq!(a.json(), b.json(), "virtual-time soak must be a pure function of the seed");
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(bench(60, 7).json(), bench(60, 8).json());
    }

    #[test]
    fn reference_mix_clears_the_guard() {
        let b = bench(120, 7);
        assert!(b.guard().is_ok(), "hit_rate {:.3}", b.hit_rate());
        assert!(b.deadlined_completed() > 0, "the mix must contain deadlined sessions");
        assert_eq!(b.completed() + b.rejected(), 120);
    }

    #[test]
    fn json_is_parseable_and_reports_tails() {
        let b = bench(60, 7);
        let doc = Json::parse(&b.json()).expect("valid JSON");
        let hit = doc.get("hit_rate").and_then(Json::as_f64).unwrap();
        assert!((0.0..=1.0).contains(&hit));
        let lat = doc.get("latency_virtual_s").unwrap();
        let p95 = lat.get("p95").and_then(Json::as_f64).unwrap();
        let p99 = lat.get("p99").and_then(Json::as_f64).unwrap();
        assert!(p99 >= p95 && p95 > 0.0, "p95={p95} p99={p99}");
        assert_eq!(doc.get("sessions").and_then(Json::as_f64).unwrap() as usize, 60);
    }

    #[test]
    fn all_tight_mix_rejects_or_misses() {
        let reg = ArtifactRegistry::synthetic();
        let node = NodeConfig::batel();
        let cfg = QosBenchConfig {
            sessions: 30,
            seed: 11,
            deadlined_prob: 1.0,
            tight_prob: 1.0,
            ..QosBenchConfig::default()
        };
        let b = run_qos(&reg, &node, &cfg).unwrap();
        assert!(
            b.rejected() + b.missed() > 0,
            "near-ideal deadlines under contention must trip the QoS machinery"
        );
        // Accounting still closes.
        assert_eq!(b.completed() + b.rejected(), 30);
    }

    #[test]
    fn quick_mode_shrinks_the_soak() {
        let reg = ArtifactRegistry::synthetic();
        let node = NodeConfig::batel();
        let cfg = QosBenchConfig { sessions: 200, seed: 7, quick: true, ..Default::default() };
        let b = run_qos(&reg, &node, &cfg).unwrap();
        assert_eq!(b.results.len(), 50);
        assert!(b.quick);
    }
}
