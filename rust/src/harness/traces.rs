//! Figures 5 & 6 — Introspector package traces for a regular (Gaussian)
//! and an irregular (Mandelbrot) benchmark under each scheduler.

use anyhow::Result;

use crate::coordinator::{DeviceSpec, RunReport, SchedulerKind};
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

use super::runs::run_once;

/// The three algorithms of Figures 5/6 in paper order.
pub fn trace_schedulers() -> Vec<SchedulerKind> {
    vec![
        SchedulerKind::static_default(),
        SchedulerKind::dynamic(50),
        SchedulerKind::hguided(),
    ]
}

/// One full-device trace run per scheduler for `bench`.
pub fn collect(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
) -> Result<Vec<(String, RunReport)>> {
    let all: Vec<DeviceSpec> = (0..node.devices.len()).map(DeviceSpec::new).collect();
    trace_schedulers()
        .into_iter()
        .map(|kind| {
            let label = kind.label();
            run_once(reg, node, bench, all.clone(), kind, None).map(|r| (label, r))
        })
        .collect()
}

/// Chunk-size-over-time series per device (what Figures 5/6 plot): for
/// each package, (device, start_ms, items).
pub fn chunk_series(report: &RunReport) -> Vec<(String, f64, usize)> {
    let mut rows = Vec::new();
    for d in &report.devices {
        for p in &d.packages {
            rows.push((d.name.clone(), p.start.as_secs_f64() * 1e3, p.items()));
        }
    }
    // A trace with a NaN start (possible when a report is assembled from
    // a poisoned clock) must not panic the sort — IEEE total order keeps
    // it deterministic instead.
    rows.sort_by(|a, b| a.1.total_cmp(&b.1));
    rows
}

#[cfg(test)]
mod tests {
    use crate::coordinator::introspector::{DeviceTrace, PackageTrace, TransferStats};
    use crate::platform::DeviceKind;
    use std::time::Duration;

    #[test]
    fn chunk_series_sort_survives_nan_key() {
        // Regression: `chunk_series` sorted its start-time keys with
        // `partial_cmp(..).unwrap()` and panicked on a NaN key (Duration
        // itself can't hold NaN, but the f64 sort key can be poisoned by
        // NaN-scaled arithmetic upstream). The sort must be total.
        let mut rows: Vec<(String, f64, usize)> =
            vec![("a".into(), 1.0, 8), ("b".into(), f64::NAN, 8), ("c".into(), 0.5, 8)];
        rows.sort_by(|a, b| a.1.total_cmp(&b.1));
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].0, "c", "finite keys stay ordered");

        // And the public entry point stays panic-free on real traces.
        let d = DeviceTrace {
            name: "d0".into(),
            kind: DeviceKind::Cpu,
            init_start: Duration::ZERO,
            init_end: Duration::ZERO,
            packages: vec![PackageTrace {
                device: 0,
                begin_item: 0,
                end_item: 8,
                start: Duration::from_millis(3),
                end: Duration::from_millis(5),
                h2d_start: Duration::from_millis(3),
                h2d_end: Duration::from_millis(3),
                exec_start: Duration::from_millis(3),
                raw_exec: Duration::from_millis(1),
                launches: 1,
                h2d_bytes: 0,
                d2h_bytes: 0,
                energy_j: 0.1,
                requeued: false,
                stolen: false,
            }],
            xfer: TransferStats::default(),
            lease_wait: Duration::ZERO,
            cache_hit: None,
            busy_watts: 50.0,
            idle_watts: 5.0,
            refused: false,
        };
        let report = crate::coordinator::RunReport {
            bench: "b".into(),
            scheduler: "s".into(),
            session: 0,
            gws: 8,
            wall: Duration::from_millis(5),
            devices: vec![d],
            faults: Vec::new(),
            steals_issued: 0,
        };
        assert_eq!(super::chunk_series(&report).len(), 1);
    }
}
