//! Figures 5 & 6 — Introspector package traces for a regular (Gaussian)
//! and an irregular (Mandelbrot) benchmark under each scheduler.

use anyhow::Result;

use crate::coordinator::{DeviceSpec, RunReport, SchedulerKind};
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

use super::runs::run_once;

/// The three algorithms of Figures 5/6 in paper order.
pub fn trace_schedulers() -> Vec<SchedulerKind> {
    vec![
        SchedulerKind::static_default(),
        SchedulerKind::dynamic(50),
        SchedulerKind::hguided(),
    ]
}

/// One full-device trace run per scheduler for `bench`.
pub fn collect(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
) -> Result<Vec<(String, RunReport)>> {
    let all: Vec<DeviceSpec> = (0..node.devices.len()).map(DeviceSpec::new).collect();
    trace_schedulers()
        .into_iter()
        .map(|kind| {
            let label = kind.label();
            run_once(reg, node, bench, all.clone(), kind, None).map(|r| (label, r))
        })
        .collect()
}

/// Chunk-size-over-time series per device (what Figures 5/6 plot): for
/// each package, (device, start_ms, items).
pub fn chunk_series(report: &RunReport) -> Vec<(String, f64, usize)> {
    let mut rows = Vec::new();
    for d in &report.devices {
        for p in &d.packages {
            rows.push((d.name.clone(), p.start.as_secs_f64() * 1e3, p.items()));
        }
    }
    rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    rows
}
