//! Concurrent-session harness (the CLI's `--concurrent N` mode): run
//! every session solo for a baseline, then submit the whole batch to
//! one [`Runtime`] and report per-session makespans, lease-wait bills,
//! aggregate throughput and the speedup over running the sessions
//! back-to-back. Each concurrent session's outputs are checked
//! bit-identical to its solo run — co-execution must never change
//! results, only timing.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::lease::LeasePolicy;
use crate::coordinator::runtime::{RunSession, Runtime, SessionOutcome};
use crate::coordinator::{Configurator, SchedulerKind};
use crate::harness::runs::build_program;
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

/// One session of a concurrent batch.
#[derive(Debug, Clone)]
pub struct SessionSpec {
    pub bench: String,
    pub scheduler: SchedulerKind,
    pub gws: Option<usize>,
}

/// Per-session measurement: solo vs concurrent.
#[derive(Debug, Clone)]
pub struct SessionStat {
    pub label: String,
    pub bench: String,
    pub scheduler: String,
    /// Simclock makespan of the session run alone on the full node.
    pub solo: Duration,
    /// Simclock makespan of the same session inside the batch.
    pub concurrent: Duration,
    /// Time the session's workers spent waiting for device leases (the
    /// devices serving the other sessions).
    pub lease_wait: Duration,
    pub items: usize,
    pub packages: usize,
    /// Concurrent outputs were bit-identical to the solo outputs.
    pub outputs_match: bool,
}

/// Outcome of one `--concurrent` measurement.
#[derive(Debug, Clone)]
pub struct ConcurrentReport {
    pub sessions: Vec<SessionStat>,
    /// Wall time from batch submission to the last session outcome.
    pub batch_wall: Duration,
    /// Sum of the solo makespans — the serial (one-at-a-time) baseline.
    pub solo_sum: Duration,
}

impl ConcurrentReport {
    /// How much faster the batch finished than running its sessions
    /// back-to-back (solo-sum / batch-wall; > 1 means the sessions
    /// genuinely co-executed across the device set).
    pub fn speedup_vs_serial(&self) -> f64 {
        let batch = self.batch_wall.as_secs_f64();
        if batch > 0.0 {
            self.solo_sum.as_secs_f64() / batch
        } else {
            0.0
        }
    }

    /// Aggregate batch throughput in work-items per second.
    pub fn throughput_items_per_sec(&self) -> f64 {
        let batch = self.batch_wall.as_secs_f64();
        let items: usize = self.sessions.iter().map(|s| s.items).sum();
        if batch > 0.0 {
            items as f64 / batch
        } else {
            0.0
        }
    }

    /// Every session's concurrent outputs matched its solo outputs.
    pub fn all_outputs_match(&self) -> bool {
        self.sessions.iter().all(|s| s.outputs_match)
    }
}

/// The measurement configuration: simulated device speeds ON (the
/// makespans under comparison are simclock makespans) but init sleeps
/// OFF (a constant per session that would pad both sides equally).
pub fn measure_config() -> Configurator {
    Configurator { simulate_init: false, ..Default::default() }
}

/// The jitter seed for spec `index`, set explicitly on *both* the solo
/// baseline and the batch session so the two runs under comparison draw
/// identical timing streams (nonzero: 0 is the "unset" sentinel the
/// runtime would override per-session).
fn session_seed(seed: u64, index: usize) -> u64 {
    (seed ^ (index as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)).max(1)
}

fn session_for(
    reg: &ArtifactRegistry,
    spec: &SessionSpec,
    label: &str,
    config: &Configurator,
    rng_seed: u64,
) -> Result<RunSession> {
    let mut s = RunSession::new(build_program(reg, &spec.bench)?)
        .scheduler(spec.scheduler.clone())
        .label(label)
        .config(Configurator { rng_seed, ..config.clone() });
    if let Some(g) = spec.gws {
        s = s.gws(g);
    }
    Ok(s)
}

/// Measure `specs` solo and as one concurrent batch on `node`.
pub fn run_concurrent(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    specs: &[SessionSpec],
    policy: LeasePolicy,
    seed: u64,
    config: Configurator,
) -> Result<ConcurrentReport> {
    anyhow::ensure!(!specs.is_empty(), "need at least one session spec");

    // Solo baselines: each session alone on a fresh runtime with the
    // same policy and the same per-spec jitter seed the batch run will
    // use, so the only variable in the comparison is the presence of
    // the other sessions.
    let mut solo_walls: Vec<Duration> = Vec::with_capacity(specs.len());
    let mut solo_outputs: Vec<Vec<Vec<f32>>> = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        let rt = Runtime::configured(reg.clone(), node.clone(), policy, usize::MAX, seed);
        let outcome = rt
            .submit(session_for(reg, spec, &format!("solo-{i}"), &config, session_seed(seed, i))?)
            .wait();
        let report = outcome
            .result
            .as_ref()
            .map_err(|e| anyhow::anyhow!("solo run of '{}' failed: {e}", spec.bench))?;
        solo_walls.push(report.wall);
        let nouts = outcome.program.outputs().len();
        solo_outputs
            .push((0..nouts).map(|j| outcome.output(j).unwrap().to_vec()).collect());
        rt.wait_idle();
    }

    // The concurrent batch: one submit_all so admission (and the lease
    // rotation order) is the spec order.
    let rt = Runtime::configured(reg.clone(), node.clone(), policy, usize::MAX, seed);
    let sessions: Vec<RunSession> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            session_for(reg, s, &format!("{}-{i}", s.bench), &config, session_seed(seed, i))
        })
        .collect::<Result<_>>()?;
    let started = Instant::now();
    let handles = rt.submit_all(sessions);
    // Drain every outcome before doing any O(N) output comparison: the
    // batch makespan must measure submit -> last session completion,
    // not the bookkeeping between waits (the solo side, report.wall,
    // carries no such padding either).
    let outcomes: Vec<(String, SessionOutcome)> = handles
        .into_iter()
        .map(|h| {
            let label = h.label().to_string();
            (label, h.wait())
        })
        .collect();
    let batch_wall = started.elapsed();
    rt.wait_idle();

    let mut stats = Vec::with_capacity(specs.len());
    for (((label, outcome), spec), (solo, want)) in outcomes
        .into_iter()
        .zip(specs)
        .zip(solo_walls.iter().zip(&solo_outputs))
    {
        let report = outcome
            .result
            .as_ref()
            .map_err(|e| anyhow::anyhow!("concurrent session '{label}' failed: {e}"))?;
        let outputs_match = (0..want.len()).all(|j| {
            outcome.output(j).map(|o| o == want[j].as_slice()).unwrap_or(false)
        });
        stats.push(SessionStat {
            label,
            bench: spec.bench.clone(),
            scheduler: report.scheduler.clone(),
            solo: *solo,
            concurrent: report.wall,
            lease_wait: report.lease_wait_total(),
            items: report.gws,
            packages: report.total_packages(),
            outputs_match,
        });
    }

    Ok(ConcurrentReport {
        sessions: stats,
        batch_wall,
        solo_sum: solo_walls.iter().sum(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fast-sim smoke: two sessions, correctness bookkeeping only (the
    /// makespan acceptance lives in the concurrency battery with the
    /// full simclock on).
    #[test]
    fn concurrent_harness_checks_outputs() {
        let reg = ArtifactRegistry::discover().expect("registry");
        let specs = vec![
            SessionSpec {
                bench: "binomial".into(),
                scheduler: SchedulerKind::dynamic(6),
                gws: None,
            },
            SessionSpec {
                bench: "gaussian".into(),
                scheduler: SchedulerKind::hguided(),
                gws: None,
            },
        ];
        let config = Configurator {
            simulate_init: false,
            simulate_speed: false,
            ..Default::default()
        };
        let report = run_concurrent(
            &reg,
            &NodeConfig::batel(),
            &specs,
            LeasePolicy::Rotation,
            11,
            config,
        )
        .expect("harness completes");
        assert_eq!(report.sessions.len(), 2);
        assert!(report.all_outputs_match(), "co-execution changed results");
        assert!(report.batch_wall > Duration::ZERO);
        assert!(report.solo_sum > Duration::ZERO);
        assert!(report.throughput_items_per_sec() > 0.0);
        for s in &report.sessions {
            assert!(s.items > 0 && s.packages > 0);
        }
    }
}
