//! Figure 9 — load balance per benchmark × scheduler × node, plus the
//! shared co-execution runner used by Figures 10/11/12.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::DeviceSpec;
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

use super::runs::{coexec_metrics, paper_benches, paper_schedulers, run_once, solo_time, CoexecMetrics};

/// All (bench × scheduler) co-execution cells for one node, with solo
/// baselines computed once per (bench, device).
pub struct NodeEvaluation {
    pub node: String,
    pub cells: Vec<CoexecMetrics>,
    /// Solo compute times per bench per device index.
    pub solos: BTreeMap<String, Vec<Duration>>,
}

/// Run the full evaluation grid on `node`. `reps` co-execution runs per
/// cell are aggregated by best-balance (the paper reports averages of 60
/// runs; we default to small reps to keep bench wall time sane and report
/// the median cell).
pub fn evaluate_node(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    benches: Option<Vec<&'static str>>,
    reps: usize,
) -> Result<NodeEvaluation> {
    let all_devices: Vec<DeviceSpec> =
        (0..node.devices.len()).map(DeviceSpec::new).collect();
    let benches = benches.unwrap_or_else(paper_benches);
    let mut cells = Vec::new();
    let mut solos: BTreeMap<String, Vec<Duration>> = BTreeMap::new();

    for bench in &benches {
        // Solo baselines.
        let mut solo = Vec::new();
        for d in 0..node.devices.len() {
            solo.push(solo_time(reg, node, bench, d)?);
        }
        solos.insert(bench.to_string(), solo.clone());

        for kind in paper_schedulers() {
            let mut best: Option<CoexecMetrics> = None;
            for _ in 0..reps.max(1) {
                let report =
                    run_once(reg, node, bench, all_devices.clone(), kind.clone(), None)?;
                let m = coexec_metrics(&report, &solo);
                // Keep the median-ish representative: middle efficiency.
                best = Some(match best {
                    None => m,
                    Some(prev) => {
                        if (m.efficiency - 0.5 * (m.efficiency + prev.efficiency)).abs()
                            < (prev.efficiency - 0.5 * (m.efficiency + prev.efficiency)).abs()
                        {
                            m
                        } else {
                            prev
                        }
                    }
                });
            }
            cells.push(best.unwrap());
        }
    }
    Ok(NodeEvaluation { node: node.name.clone(), cells, solos })
}

/// Paper-style balance table rows: bench, then one balance per scheduler.
pub fn balance_rows(eval: &NodeEvaluation) -> Vec<(String, Vec<(String, f64)>)> {
    let mut rows: Vec<(String, Vec<(String, f64)>)> = Vec::new();
    for cell in &eval.cells {
        match rows.last_mut() {
            Some((b, v)) if *b == cell.bench => v.push((cell.scheduler.clone(), cell.balance)),
            _ => rows.push((cell.bench.clone(), vec![(cell.scheduler.clone(), cell.balance)])),
        }
    }
    rows
}
