//! Figure 9 — load balance per benchmark × scheduler × node, plus the
//! shared co-execution runner used by Figures 10/11/12, plus the PR-5
//! balance-efficiency harness behind `enginecl run --balance`: the
//! per-scheduler busy-time efficiency grid over the five kernels,
//! emitted as `BENCH_balance.json` with an optional CI guard
//! (`ECL_BENCH_GUARD=1`) that fails when `adaptive` drops below
//! `hguided` on the reference node.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::scheduler::parse_spec;
use crate::coordinator::{Configurator, DeviceSpec};
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

use super::runs::{
    build_engine, coexec_metrics, paper_benches, paper_schedulers, run_once, solo_time,
    CoexecMetrics,
};

/// All (bench × scheduler) co-execution cells for one node, with solo
/// baselines computed once per (bench, device).
pub struct NodeEvaluation {
    pub node: String,
    pub cells: Vec<CoexecMetrics>,
    /// Solo compute times per bench per device index.
    pub solos: BTreeMap<String, Vec<Duration>>,
}

/// Run the full evaluation grid on `node`. `reps` co-execution runs per
/// cell are aggregated by best-balance (the paper reports averages of 60
/// runs; we default to small reps to keep bench wall time sane and report
/// the median cell).
pub fn evaluate_node(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    benches: Option<Vec<&'static str>>,
    reps: usize,
) -> Result<NodeEvaluation> {
    let all_devices: Vec<DeviceSpec> =
        (0..node.devices.len()).map(DeviceSpec::new).collect();
    let benches = benches.unwrap_or_else(paper_benches);
    let mut cells = Vec::new();
    let mut solos: BTreeMap<String, Vec<Duration>> = BTreeMap::new();

    for bench in &benches {
        // Solo baselines.
        let mut solo = Vec::new();
        for d in 0..node.devices.len() {
            solo.push(solo_time(reg, node, bench, d)?);
        }
        solos.insert(bench.to_string(), solo.clone());

        for kind in paper_schedulers() {
            let mut best: Option<CoexecMetrics> = None;
            for _ in 0..reps.max(1) {
                let report =
                    run_once(reg, node, bench, all_devices.clone(), kind.clone(), None)?;
                let m = coexec_metrics(&report, &solo);
                // Keep the median-ish representative: middle efficiency.
                best = Some(match best {
                    None => m,
                    Some(prev) => {
                        if (m.efficiency - 0.5 * (m.efficiency + prev.efficiency)).abs()
                            < (prev.efficiency - 0.5 * (m.efficiency + prev.efficiency)).abs()
                        {
                            m
                        } else {
                            prev
                        }
                    }
                });
            }
            cells.push(best.unwrap());
        }
    }
    Ok(NodeEvaluation { node: node.name.clone(), cells, solos })
}

/// Paper-style balance table rows: bench, then one balance per scheduler.
pub fn balance_rows(eval: &NodeEvaluation) -> Vec<(String, Vec<(String, f64)>)> {
    let mut rows: Vec<(String, Vec<(String, f64)>)> = Vec::new();
    for cell in &eval.cells {
        match rows.last_mut() {
            Some((b, v)) if *b == cell.bench => v.push((cell.scheduler.clone(), cell.balance)),
            _ => rows.push((cell.bench.clone(), vec![(cell.scheduler.clone(), cell.balance)])),
        }
    }
    rows
}

// ---- PR-5: the balance-efficiency harness (`run --balance`) -----------

/// The five kernels of the efficiency grid (one ray scene stands in for
/// the three — they share a kernel and differ only in content).
pub fn balance_kernels() -> Vec<&'static str> {
    vec!["gaussian", "ray1", "binomial", "mandelbrot", "nbody"]
}

/// The scheduler specs of the efficiency grid. Spec strings (parsed
/// through the CLI grammar) so the emitted JSON names reproducible
/// configurations; `hguided:feedback=0` is the static-profile ablation
/// baseline.
pub fn balance_specs() -> Vec<&'static str> {
    vec!["static", "dynamic:50", "hguided", "hguided:feedback=0", "adaptive", "adaptive+pipe"]
}

/// One (bench, scheduler spec) cell of the efficiency grid.
#[derive(Debug, Clone)]
pub struct BalancePoint {
    pub bench: String,
    pub spec: String,
    /// Busy-time balance efficiency (`RunReport::balance_efficiency`).
    pub efficiency: f64,
    /// Completion-ratio balance (`RunReport::balance`), for reference.
    pub balance: f64,
    pub wall: Duration,
    pub packages: usize,
}

/// The full `run --balance` result.
#[derive(Debug, Clone)]
pub struct BalanceBench {
    pub node: String,
    pub quick: bool,
    pub points: Vec<BalancePoint>,
}

impl BalanceBench {
    /// Mean balance efficiency of one scheduler spec across kernels.
    pub fn mean_efficiency(&self, spec: &str) -> Option<f64> {
        let effs: Vec<f64> = self
            .points
            .iter()
            .filter(|p| p.spec == spec)
            .map(|p| p.efficiency)
            .collect();
        if effs.is_empty() {
            None
        } else {
            Some(effs.iter().sum::<f64>() / effs.len() as f64)
        }
    }

    /// The `BENCH_balance.json` artifact: per-cell efficiencies plus
    /// per-spec means (hand-rolled JSON like the hotpath baseline —
    /// no serde offline).
    pub fn json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"node\": \"{}\",\n", self.node));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str("  \"cells\": [\n");
        for (i, p) in self.points.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"bench\": \"{}\", \"scheduler\": \"{}\", \"efficiency\": {:.4}, \
                 \"balance\": {:.4}, \"wall_ms\": {:.2}, \"packages\": {}}}{}\n",
                p.bench,
                p.spec,
                p.efficiency,
                p.balance,
                p.wall.as_secs_f64() * 1e3,
                p.packages,
                if i + 1 < self.points.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"mean_efficiency\": {\n");
        let specs = balance_specs();
        for (i, spec) in specs.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {:.4}{}\n",
                spec,
                self.mean_efficiency(spec).unwrap_or(0.0),
                if i + 1 < specs.len() { "," } else { "" }
            ));
        }
        s.push_str("  }\n}\n");
        s
    }

    /// The CI guard (`ECL_BENCH_GUARD=1`): `adaptive` must not fall
    /// below `hguided` (0.05 slack for timing jitter) and must clear an
    /// absolute efficiency floor — 0.85 on a full-size run (the PR-5
    /// acceptance bar), relaxed to 0.70 in quick mode where per-package
    /// overheads weigh disproportionately.
    pub fn guard(&self) -> Result<()> {
        let adaptive = self
            .mean_efficiency("adaptive")
            .ok_or_else(|| anyhow::anyhow!("no adaptive cells in the balance bench"))?;
        let hguided = self
            .mean_efficiency("hguided")
            .ok_or_else(|| anyhow::anyhow!("no hguided cells in the balance bench"))?;
        anyhow::ensure!(
            adaptive + 0.05 >= hguided,
            "balance regression: adaptive mean efficiency {adaptive:.3} below hguided {hguided:.3}"
        );
        let floor = if self.quick { 0.70 } else { 0.85 };
        anyhow::ensure!(
            adaptive >= floor,
            "balance regression: adaptive mean efficiency {adaptive:.3} below the {floor:.2} floor"
        );
        Ok(())
    }
}

/// The measurement configuration: simulated speeds ON (efficiency is a
/// simclock property), init sleeps OFF (a constant that pads every
/// scheduler equally), cold store per engine (each cell measures one
/// self-contained run).
fn balance_config() -> Configurator {
    Configurator { simulate_init: false, ..Default::default() }
}

/// Run the efficiency grid on `node`. `quick` shrinks every kernel to a
/// quarter of its problem size (granule-aligned) for CI smoke runs.
pub fn run_balance(reg: &ArtifactRegistry, node: &NodeConfig, quick: bool) -> Result<BalanceBench> {
    let all_devices: Vec<DeviceSpec> = (0..node.devices.len()).map(DeviceSpec::new).collect();
    let mut points = Vec::new();
    for bench in balance_kernels() {
        let m = reg.bench(bench)?.clone();
        let gws = if quick {
            ((m.n / m.granule / 4).max(1)) * m.granule
        } else {
            m.n
        };
        for spec in balance_specs() {
            let kind = parse_spec(spec).map_err(|e| anyhow::anyhow!("{e}"))?;
            let mut engine =
                build_engine(reg, node, bench, all_devices.clone(), kind, Some(gws))?;
            *engine.configurator() = balance_config();
            engine.run().map_err(|e| anyhow::anyhow!("{bench}/{spec}: {e}"))?;
            let report = engine.report().expect("successful run has a report");
            points.push(BalancePoint {
                bench: bench.to_string(),
                spec: spec.to_string(),
                efficiency: report.balance_efficiency(),
                balance: report.balance(),
                wall: report.wall,
                packages: report.total_packages(),
            });
        }
    }
    Ok(BalanceBench { node: node.name.clone(), quick, points })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(bench: &str, spec: &str, eff: f64) -> BalancePoint {
        BalancePoint {
            bench: bench.into(),
            spec: spec.into(),
            efficiency: eff,
            balance: eff,
            wall: Duration::from_millis(10),
            packages: 4,
        }
    }

    fn bench_with(adaptive: f64, hguided: f64, quick: bool) -> BalanceBench {
        BalanceBench {
            node: "batel".into(),
            quick,
            points: vec![
                point("binomial", "adaptive", adaptive),
                point("nbody", "adaptive", adaptive),
                point("binomial", "hguided", hguided),
                point("nbody", "hguided", hguided),
            ],
        }
    }

    #[test]
    fn mean_efficiency_groups_by_spec() {
        let b = bench_with(0.9, 0.8, false);
        assert!((b.mean_efficiency("adaptive").unwrap() - 0.9).abs() < 1e-12);
        assert!((b.mean_efficiency("hguided").unwrap() - 0.8).abs() < 1e-12);
        assert!(b.mean_efficiency("nope").is_none());
    }

    #[test]
    fn guard_accepts_adaptive_at_or_above_hguided() {
        assert!(bench_with(0.90, 0.88, false).guard().is_ok());
        // Within the 0.05 jitter slack.
        assert!(bench_with(0.86, 0.90, false).guard().is_ok());
    }

    #[test]
    fn guard_rejects_regressions() {
        let err = bench_with(0.70, 0.90, false).guard().unwrap_err();
        assert!(err.to_string().contains("below hguided"), "{err}");
        // Above hguided but below the absolute full-run floor.
        let err = bench_with(0.80, 0.75, false).guard().unwrap_err();
        assert!(err.to_string().contains("floor"), "{err}");
        // The quick floor is laxer.
        assert!(bench_with(0.80, 0.75, true).guard().is_ok());
    }

    #[test]
    fn json_artifact_is_parseable() {
        let b = bench_with(0.9, 0.8, true);
        let parsed = crate::util::json::Json::parse(&b.json()).expect("valid json");
        assert_eq!(parsed.get("node").unwrap().as_str(), Some("batel"));
        assert_eq!(parsed.get("quick").unwrap().as_bool(), Some(true));
        assert_eq!(parsed.get("cells").unwrap().as_arr().unwrap().len(), 4);
        let means = parsed.get("mean_efficiency").unwrap();
        assert!((means.get("adaptive").unwrap().as_f64().unwrap() - 0.9).abs() < 1e-3);
    }
}
