//! Shared experiment plumbing: standard engine construction, solo-device
//! baselines and the co-execution metric set (balance / speedup /
//! efficiency) the paper reports in §7.3.

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{DeviceSpec, Engine, Program, RunReport, SchedulerKind};
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;

/// The scheduler configurations of Figures 9-12, in paper order.
pub fn paper_schedulers() -> Vec<SchedulerKind> {
    vec![
        SchedulerKind::Static { props: None, reversed: false },
        SchedulerKind::Static { props: None, reversed: true },
        SchedulerKind::dynamic(50),
        SchedulerKind::dynamic(150),
        SchedulerKind::hguided(),
    ]
}

/// The benchmark list of the evaluation (ray split into its 3 scenes).
pub fn paper_benches() -> Vec<&'static str> {
    vec!["gaussian", "ray1", "ray2", "ray3", "binomial", "mandelbrot", "nbody"]
}

/// Build a golden-input program for `bench` — the standard wiring every
/// harness run (engine or runtime session) starts from.
pub fn build_program(reg: &ArtifactRegistry, bench: &str) -> Result<Program> {
    let manifest = reg.bench(bench)?.clone();
    let mut program = Program::new();
    program.kernel(bench, &manifest.kernel);
    for buf in reg.golden_inputs(&manifest)? {
        program.input(buf.as_f32().unwrap().to_vec());
    }
    for out in &manifest.outputs {
        program.output(out.elems);
    }
    let (num, den) = manifest.out_pattern;
    program.out_pattern(num, den);
    Ok(program)
}

/// Build a ready-to-run engine for `bench` on `node` with golden inputs.
pub fn build_engine(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    devices: Vec<DeviceSpec>,
    scheduler: SchedulerKind,
    gws: Option<usize>,
) -> Result<Engine> {
    let mut engine = Engine::with_registry(reg.clone());
    engine.node(node.clone());
    engine.use_devices(devices);
    engine.scheduler(scheduler);
    if let Some(g) = gws {
        engine.global_work_items(g);
    }
    engine.program(build_program(reg, bench)?);
    Ok(engine)
}

/// Run and return the report.
pub fn run_once(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    devices: Vec<DeviceSpec>,
    scheduler: SchedulerKind,
    gws: Option<usize>,
) -> Result<RunReport> {
    let mut engine = build_engine(reg, node, bench, devices, scheduler, gws)?;
    engine.run().map_err(|e| anyhow::anyhow!("{e}"))?;
    Ok(engine.report().unwrap().clone())
}

/// Solo response time of device `index` (the T_i of the S_max formula):
/// a single-device run of the full problem, compute phase only (completion
/// minus init end, matching the paper's "response time" per device).
pub fn solo_time(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    index: usize,
) -> Result<Duration> {
    let report = run_once(
        reg,
        node,
        bench,
        vec![DeviceSpec::new(index)],
        SchedulerKind::static_default(),
        None,
    )?;
    Ok(report.device_response(0))
}

/// Full co-execution metric set for one (bench, scheduler) cell.
#[derive(Debug, Clone)]
pub struct CoexecMetrics {
    pub bench: String,
    pub scheduler: String,
    pub balance: f64,
    pub speedup: f64,
    pub max_speedup: f64,
    pub efficiency: f64,
    pub work_shares: Vec<f64>,
    pub total_packages: usize,
    pub wall: Duration,
}

/// Compute balance/speedup/efficiency for a co-execution report given the
/// per-device solo times (paper §7.3: baseline = fastest device).
pub fn coexec_metrics(report: &RunReport, solo: &[Duration]) -> CoexecMetrics {
    let times: Vec<f64> = solo.iter().map(|d| d.as_secs_f64()).collect();
    let t_best = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let t_max = times.iter().cloned().fold(0.0f64, f64::max);
    let max_speedup = if t_max > 0.0 { times.iter().sum::<f64>() / t_max } else { 0.0 };
    // Co-execution response time: from the compute epoch (earliest device
    // ready) to the last completion — late initializers (Phi, Figure 13)
    // are charged for their lateness, as in the paper's response times.
    let t_co = report.response_time().as_secs_f64();
    let speedup = if t_co > 0.0 { t_best / t_co } else { 0.0 };
    CoexecMetrics {
        bench: report.bench.clone(),
        scheduler: report.scheduler.clone(),
        balance: report.balance(),
        speedup,
        max_speedup,
        efficiency: if max_speedup > 0.0 { speedup / max_speedup } else { 0.0 },
        work_shares: report.work_shares(),
        total_packages: report.total_packages(),
        wall: report.wall,
    }
}

/// Quick-mode switch for benches (ECL_BENCH_QUICK=1 shrinks sweeps).
pub fn quick_mode() -> bool {
    std::env::var("ECL_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Problem-size ladder for a bench: multiples of the granule from small
/// prefixes up to the full size (Figure 7's sweep).
pub fn size_ladder(reg: &ArtifactRegistry, bench: &str, points: usize) -> Result<Vec<usize>> {
    let m = reg.bench(bench)?;
    let total_granules = m.n / m.granule;
    let mut out = Vec::new();
    let mut g = (total_granules / (1 << (points - 1))).max(1);
    while g < total_granules {
        out.push(g * m.granule);
        g *= 2;
    }
    out.push(m.n);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::introspector::{DeviceTrace, PackageTrace};
    use crate::platform::DeviceKind;

    fn ms(x: u64) -> Duration {
        Duration::from_millis(x)
    }

    fn fake_report(completions: &[u64]) -> RunReport {
        RunReport {
            bench: "b".into(),
            scheduler: "s".into(),
            session: 0,
            gws: 100,
            wall: ms(*completions.iter().max().unwrap()),
            devices: completions
                .iter()
                .enumerate()
                .map(|(i, c)| DeviceTrace {
                    name: format!("d{i}"),
                    kind: DeviceKind::Cpu,
                    init_start: ms(0),
                    init_end: ms(0),
                    packages: vec![PackageTrace {
                        device: i,
                        begin_item: i * 10,
                        end_item: i * 10 + 10,
                        start: ms(0),
                        end: ms(*c),
                        h2d_start: ms(0),
                        h2d_end: ms(0),
                        exec_start: ms(0),
                        raw_exec: ms(1),
                        launches: 1,
                        h2d_bytes: 4,
                        d2h_bytes: 0,
                        energy_j: 0.0,
                        requeued: false,
                        stolen: false,
                    }],
                    xfer: Default::default(),
                    lease_wait: Default::default(),
                    cache_hit: None,
                    busy_watts: 80.0,
                    idle_watts: 8.0,
                    refused: false,
                })
                .collect(),
            faults: Vec::new(),
            steals_issued: 0,
        }
    }

    #[test]
    fn metrics_ideal_coexec() {
        // Two devices, equal solo times of 100ms, both finish at 50ms.
        let report = fake_report(&[50, 50]);
        let m = coexec_metrics(&report, &[ms(100), ms(100)]);
        assert!((m.balance - 1.0).abs() < 1e-9);
        assert!((m.max_speedup - 2.0).abs() < 1e-9);
        assert!((m.speedup - 2.0).abs() < 1e-9);
        assert!((m.efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn metrics_imbalanced() {
        let report = fake_report(&[40, 80]);
        let m = coexec_metrics(&report, &[ms(100), ms(100)]);
        assert!((m.balance - 0.5).abs() < 1e-9);
        assert!((m.speedup - 1.25).abs() < 1e-9);
        assert!(m.efficiency < 0.7);
    }

    #[test]
    fn paper_lists() {
        assert_eq!(paper_schedulers().len(), 5);
        assert_eq!(paper_benches().len(), 7);
    }
}
