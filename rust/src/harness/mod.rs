//! Experiment harness — one runner per paper table/figure (DESIGN.md §6).

pub mod balance;
pub mod concurrent;
pub mod energy;
pub mod init;
pub mod overhead;
pub mod perf;
pub mod qos;
pub mod runs;
pub mod service;
pub mod steal;
pub mod traces;
