//! `run --energy` — the energy-aware scheduling sweep (PR-9 acceptance
//! bench), emitted as `BENCH_energy.json`.
//!
//! Sweeps the five balance kernels under three Adaptive configurations
//! — time-optimal (`adaptive`), EDP-optimal (`adaptive:obj=edp`) and
//! power-capped (`adaptive:power=400`) — through the same virtual-time
//! drain the QoS soak uses: real [`Scheduler`] instances pull packages
//! over seeded synthetic device rates, so the whole sweep is a pure
//! function of the seed and two invocations with the same seed emit
//! byte-identical JSON (the CI energy-suite diffs them).
//!
//! Energy is integrated exactly as the engine's introspector does it:
//! a package burns its device's busy watts over its occupancy span;
//! a device bills idle watts for the remainder of the node makespan.
//! A warm-up phase first populates a [`PerfModelStore`] with both rate
//! and joules/granule estimates, so the measured phase runs with warm
//! models — the regime the `--energy` guard asserts in:
//!
//! * EDP-optimal beats time-optimal on EDP on >= 4 of the 5 kernels,
//! * the 400 W power cap is never exceeded (zero violations).

use std::time::Duration;

use anyhow::Result;

use crate::coordinator::scheduler::{parse_spec, PackageTiming, SchedDevice};
use crate::harness::balance::balance_kernels;
use crate::platform::{NodeConfig, PerfModelStore};
use crate::runtime::ArtifactRegistry;
use crate::util::rng::XorShift;

/// The node power budget of the capped configuration (watts). Batel's
/// all-busy draw is 620 W; 400 W admits {cpu, gpu} (335 W) but not any
/// set containing the Phi alongside another device.
pub const BENCH_POWER_CAP_W: f64 = 400.0;

/// Scheduler specs the sweep compares, in column order.
pub fn energy_specs() -> Vec<&'static str> {
    vec!["adaptive", "adaptive:obj=edp", "adaptive:power=400"]
}

/// Knobs of the sweep (CLI: `run --energy [--seed S] [--quick]`).
#[derive(Debug, Clone)]
pub struct EnergyBenchConfig {
    pub seed: u64,
    pub quick: bool,
    /// Warm-up drains per kernel before the measured phase.
    pub warm_rounds: usize,
}

impl Default for EnergyBenchConfig {
    fn default() -> Self {
        Self { seed: 7, quick: false, warm_rounds: 3 }
    }
}

/// One (kernel × spec) cell of the sweep.
#[derive(Debug, Clone)]
pub struct EnergyCell {
    pub kernel: String,
    pub spec: &'static str,
    /// Virtual-seconds makespan of the drain.
    pub makespan_s: f64,
    /// Busy-watts joules integrated over package occupancy spans.
    pub busy_energy_j: f64,
    /// Idle-watts joules for the devices' slack under the makespan.
    pub idle_energy_j: f64,
    /// Peak instantaneous node draw: busy watts of every participating
    /// device plus idle watts of the refused ones.
    pub peak_power_w: f64,
    /// Devices that computed at least one package.
    pub active_devices: usize,
    pub packages: usize,
    /// 1 when this cell is power-capped and `peak_power_w` exceeds the
    /// cap (the guard requires the column sums to zero).
    pub cap_violations: usize,
}

impl EnergyCell {
    pub fn total_energy_j(&self) -> f64 {
        self.busy_energy_j + self.idle_energy_j
    }

    /// Energy-delay product (J·s) — the sweep's headline metric.
    pub fn edp(&self) -> f64 {
        self.total_energy_j() * self.makespan_s
    }

    pub fn avg_power_w(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.total_energy_j() / self.makespan_s
        } else {
            0.0
        }
    }
}

/// The full `run --energy` result.
#[derive(Debug)]
pub struct EnergyBench {
    pub node: String,
    pub seed: u64,
    pub quick: bool,
    pub power_cap_w: f64,
    /// Row-major: kernels × [`energy_specs`] order.
    pub cells: Vec<EnergyCell>,
}

impl EnergyBench {
    fn cell(&self, kernel: &str, spec: &str) -> Option<&EnergyCell> {
        self.cells.iter().find(|c| c.kernel == kernel && c.spec == spec)
    }

    /// Kernels where the EDP objective strictly improved EDP over the
    /// time objective.
    pub fn edp_wins(&self) -> usize {
        balance_kernels()
            .iter()
            .filter(|k| {
                match (self.cell(k, "adaptive"), self.cell(k, "adaptive:obj=edp")) {
                    (Some(t), Some(e)) => e.edp() < t.edp(),
                    _ => false,
                }
            })
            .count()
    }

    /// Total cap violations across the power-capped column.
    pub fn cap_violations(&self) -> usize {
        self.cells
            .iter()
            .filter(|c| c.spec == "adaptive:power=400")
            .map(|c| c.cap_violations)
            .sum()
    }

    /// The `BENCH_energy.json` artifact — hand-rolled like the other
    /// bench emitters (no serde offline). Every field derives from the
    /// seeded virtual-time sweep, so same-seed invocations are
    /// byte-identical.
    pub fn json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"node\": \"{}\",\n", self.node));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"power_cap_w\": {:.4},\n", self.power_cap_w));
        s.push_str(&format!("  \"edp_wins\": {},\n", self.edp_wins()));
        s.push_str(&format!("  \"cap_violations\": {},\n", self.cap_violations()));
        s.push_str("  \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"kernel\": \"{}\", \"spec\": \"{}\", \"makespan_s\": {:.4}, \
                 \"total_energy_j\": {:.4}, \"edp\": {:.4}, \"avg_power_w\": {:.4}, \
                 \"peak_power_w\": {:.4}, \"active_devices\": {}, \"packages\": {}, \
                 \"cap_violations\": {}}}{}\n",
                c.kernel,
                c.spec,
                c.makespan_s,
                c.total_energy_j(),
                c.edp(),
                c.avg_power_w(),
                c.peak_power_w,
                c.active_devices,
                c.packages,
                c.cap_violations,
                if i + 1 < self.cells.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n");
        s.push_str("  \"deltas_vs_time_pct\": [\n");
        let kernels = balance_kernels();
        for (i, k) in kernels.iter().enumerate() {
            let (edp_d, mk_d) = match (self.cell(k, "adaptive"), self.cell(k, "adaptive:obj=edp"))
            {
                (Some(t), Some(e)) if t.edp() > 0.0 && t.makespan_s > 0.0 => (
                    100.0 * (e.edp() - t.edp()) / t.edp(),
                    100.0 * (e.makespan_s - t.makespan_s) / t.makespan_s,
                ),
                _ => (0.0, 0.0),
            };
            s.push_str(&format!(
                "    {{\"kernel\": \"{k}\", \"edp\": {edp_d:.4}, \"makespan\": {mk_d:.4}}}{}\n",
                if i + 1 < kernels.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    /// The CI guard (`ECL_BENCH_GUARD=1`): warm-model EDP superiority
    /// on at least 4 of the 5 kernels, a clean power-cap column, and
    /// closed accounting on every cell.
    pub fn guard(&self) -> Result<()> {
        for c in &self.cells {
            anyhow::ensure!(
                c.makespan_s > 0.0 && c.total_energy_j().is_finite() && c.total_energy_j() > 0.0,
                "degenerate energy cell {}/{}: makespan {:.4}s, {:.4} J",
                c.kernel,
                c.spec,
                c.makespan_s,
                c.total_energy_j()
            );
        }
        let wins = self.edp_wins();
        anyhow::ensure!(
            wins >= 4,
            "energy regression: EDP objective beat the time objective on only {wins}/5 kernels \
             (warm models must win on >= 4)"
        );
        let violations = self.cap_violations();
        anyhow::ensure!(
            violations == 0,
            "power-cap breach: {violations} capped cell(s) exceeded {:.0} W",
            self.power_cap_w
        );
        Ok(())
    }
}

/// Seeded per-(kernel, device) rates: relative power, jittered a few
/// percent and normalized so the uncontended all-device ideal makespan
/// is ~1 virtual second. Drawn in one fixed pass so the RNG stream
/// never depends on drain outcomes. The jitter band is deliberately
/// tight (±4%): batel's EDP margin for dropping the Phi is ~5%, so the
/// sweep perturbs rates without inverting the energy ordering the
/// guard pins.
fn kernel_rates(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    kernels: &[&'static str],
    seed: u64,
) -> Result<Vec<(usize, Vec<f64>)>> {
    let total_power: f64 = node.devices.iter().map(|d| d.relative_power).sum();
    anyhow::ensure!(total_power > 0.0, "node {} has no compute power", node.name);
    let mut rng = XorShift::new(seed ^ 0x51C4_E93A);
    let mut out = Vec::with_capacity(kernels.len());
    for kernel in kernels {
        let bench = reg.bench(kernel)?;
        anyhow::ensure!(bench.granule > 0, "bench {kernel} has zero granule");
        let granules = (bench.n / bench.granule).max(1);
        let base = granules as f64 / total_power;
        let rates: Vec<f64> = node
            .devices
            .iter()
            .map(|d| base * d.relative_power.max(1e-6) * (0.96 + 0.08 * rng.next_f64()))
            .collect();
        out.push((granules, rates));
    }
    Ok(out)
}

/// Drain one (kernel, spec) cell: real scheduler, virtual clock, the
/// introspector's energy integration. `store` supplies warm rate and
/// joules/granule priors and (when `record`) absorbs this drain's
/// observations.
#[allow(clippy::too_many_arguments)]
fn drain_cell(
    kernel: &str,
    spec: &str,
    node: &NodeConfig,
    store: &PerfModelStore,
    granules: usize,
    granule: usize,
    rates: &[f64],
    record: bool,
) -> EnergyCell {
    let kind = parse_spec(spec).expect("energy_specs are valid scheduler specs");
    let mut sched = kind.build();
    let sdevs: Vec<SchedDevice> = node
        .devices
        .iter()
        .map(|d| {
            SchedDevice::new(d.name.clone(), d.relative_power)
                .with_warm_rate(store.estimate(kernel, &d.name))
                .with_watts(d.busy_watts, d.idle_watts)
                .with_warm_epg(store.energy_estimate(kernel, &d.name))
        })
        .collect();
    let ndev = node.devices.len();
    sched.start(granules, granule, &sdevs);
    let mut busy = vec![0.0f64; ndev];
    let mut open = vec![true; ndev];
    let mut busy_energy = 0.0f64;
    let mut packages = 0usize;
    loop {
        // Always extend the least-loaded still-open device — the
        // virtual-time analogue of "the free device asks next".
        let dev = match (0..ndev)
            .filter(|d| open[*d])
            .min_by(|a, b| busy[*a].total_cmp(&busy[*b]).then(a.cmp(b)))
        {
            Some(d) => d,
            None => break,
        };
        match sched.next_package(dev) {
            Some(range) => {
                let g = (range.len() / granule).max(1) as f64;
                let occ = g / rates[dev];
                sched.observe(
                    dev,
                    range,
                    PackageTiming {
                        span: Duration::from_secs_f64(occ),
                        raw_exec: Duration::from_secs_f64(occ),
                    },
                );
                if record {
                    let name = &node.devices[dev].name;
                    store.record(0, kernel, name, g, Duration::from_secs_f64(occ));
                    store.record_energy(0, kernel, name, g, node.devices[dev].busy_watts * occ);
                }
                busy[dev] += occ;
                busy_energy += node.devices[dev].busy_watts * occ;
                packages += 1;
            }
            None => open[dev] = false,
        }
    }
    let makespan = busy.iter().copied().fold(0.0, f64::max);
    let idle_energy: f64 = node
        .devices
        .iter()
        .zip(&busy)
        .map(|(d, b)| d.idle_watts * (makespan - b).max(0.0))
        .sum();
    let peak: f64 = node
        .devices
        .iter()
        .zip(&busy)
        .map(|(d, b)| if *b > 0.0 { d.busy_watts } else { d.idle_watts })
        .sum();
    let capped = kind
        .base()
        .power_cap()
        .map(|cap| if peak > cap { 1usize } else { 0 })
        .unwrap_or(0);
    EnergyCell {
        kernel: kernel.to_string(),
        spec: energy_specs()
            .into_iter()
            .find(|s| *s == spec)
            .expect("drained spec is in the sweep"),
        makespan_s: makespan,
        busy_energy_j: busy_energy,
        idle_energy_j: idle_energy,
        peak_power_w: peak,
        active_devices: busy.iter().filter(|b| **b > 0.0).count(),
        packages,
        cap_violations: capped,
    }
}

/// Run the sweep: per kernel, warm the store with time-objective
/// drains, then measure all three configurations against the same
/// warm models and seeded rates.
pub fn run_energy(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    cfg: &EnergyBenchConfig,
) -> Result<EnergyBench> {
    let mut cfg = cfg.clone();
    if cfg.quick {
        cfg.warm_rounds = 1;
    }
    anyhow::ensure!(cfg.warm_rounds > 0, "warm_rounds must be positive");
    let kernels = balance_kernels();
    let shapes = kernel_rates(reg, node, &kernels, cfg.seed)?;
    let store = PerfModelStore::new();
    let mut cells = Vec::with_capacity(kernels.len() * energy_specs().len());
    for (kernel, (granules, rates)) in kernels.iter().zip(&shapes) {
        let granule = reg.bench(kernel)?.granule;
        for _ in 0..cfg.warm_rounds {
            drain_cell(kernel, "adaptive", node, &store, *granules, granule, rates, true);
        }
        for spec in energy_specs() {
            cells.push(drain_cell(
                kernel, spec, node, &store, *granules, granule, rates, false,
            ));
        }
    }
    Ok(EnergyBench {
        node: node.name.clone(),
        seed: cfg.seed,
        quick: cfg.quick,
        power_cap_w: BENCH_POWER_CAP_W,
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn bench(seed: u64, quick: bool) -> EnergyBench {
        let reg = ArtifactRegistry::synthetic();
        let node = NodeConfig::batel();
        let cfg = EnergyBenchConfig { seed, quick, ..Default::default() };
        run_energy(&reg, &node, &cfg).unwrap()
    }

    #[test]
    fn same_seed_is_byte_identical() {
        let a = bench(7, false);
        let b = bench(7, false);
        assert_eq!(a.json(), b.json(), "energy sweep must be a pure function of the seed");
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(bench(7, false).json(), bench(8, false).json());
    }

    #[test]
    fn reference_sweep_clears_the_guard() {
        let b = bench(7, false);
        assert!(
            b.guard().is_ok(),
            "edp_wins {} cap_violations {}\n{}",
            b.edp_wins(),
            b.cap_violations(),
            b.json()
        );
        assert_eq!(b.cells.len(), 15, "5 kernels x 3 specs");
    }

    #[test]
    fn quick_sweep_clears_the_guard_too() {
        // CI runs the guard in quick mode: one warm round must already
        // be enough signal for the EDP and cap columns.
        let b = bench(7, true);
        assert!(b.guard().is_ok(), "quick guard: {}", b.json());
        assert!(b.quick);
    }

    #[test]
    fn edp_objective_sheds_the_power_hungry_device() {
        let b = bench(7, false);
        // On the large-pool kernels the EDP column must run fewer
        // devices than the time column (the Phi is EDP-inefficient on
        // batel) and land a lower EDP.
        let t = b.cell("gaussian", "adaptive").unwrap();
        let e = b.cell("gaussian", "adaptive:obj=edp").unwrap();
        assert!(e.active_devices < t.active_devices, "{} vs {}", e.active_devices, t.active_devices);
        assert!(e.edp() < t.edp(), "EDP must improve: {} vs {}", e.edp(), t.edp());
        // Trading energy for time: the EDP run may be slower, but
        // never burns more joules than the all-device run.
        assert!(e.total_energy_j() < t.total_energy_j());
    }

    #[test]
    fn capped_column_respects_the_budget() {
        let b = bench(7, false);
        for c in b.cells.iter().filter(|c| c.spec == "adaptive:power=400") {
            assert_eq!(c.cap_violations, 0, "{}: peak {:.1} W", c.kernel, c.peak_power_w);
            assert!(
                c.peak_power_w <= BENCH_POWER_CAP_W,
                "{}: peak {:.1} W over the {:.0} W cap",
                c.kernel,
                c.peak_power_w,
                BENCH_POWER_CAP_W
            );
        }
    }

    #[test]
    fn json_is_parseable_and_accounts_energy() {
        let b = bench(7, false);
        let doc = Json::parse(&b.json()).expect("valid JSON");
        assert_eq!(doc.get("node").and_then(Json::as_str), Some("batel"));
        let wins = doc.get("edp_wins").and_then(Json::as_f64).unwrap();
        assert!((0.0..=5.0).contains(&wins));
        for c in &b.cells {
            let total = c.total_energy_j();
            assert!(
                (total - c.busy_energy_j - c.idle_energy_j).abs() < 1e-9,
                "busy + idle must equal total"
            );
            assert!(c.edp() >= 0.0 && c.avg_power_w() > 0.0);
        }
    }
}
