//! Figures 7 & 8 — EngineCL-vs-native overhead on a single device.
//!
//! The paper's measurement protocol times the *whole program lifecycle*
//! ("including initialization, management and releasing", §7.3), so both
//! sides here do the same work per repetition:
//!
//!  * native:  create a PJRT client, compile the needed executables,
//!             upload inputs, execute, collect results, release — a
//!             hand-driven `ChunkExecutor` (what `examples/native/*` do).
//!  * EngineCL: a fresh engine with simulation off (`Configurator::raw()`)
//!             and lazy compilation (same executables compiled as native).
//!
//! The difference is therefore pure coordination cost: worker threads,
//! channels, scheduler, introspection, result merge.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{DeviceSpec, SchedulerKind};
use crate::platform::NodeConfig;
use crate::runtime::{ArtifactRegistry, ChunkExecutor, HostBuf};

use super::runs::build_engine;

#[derive(Debug, Clone)]
pub struct OverheadPoint {
    pub bench: String,
    pub gws: usize,
    pub native: Duration,
    pub enginecl: Duration,
    /// (T_ECL - T_OCL) / T_OCL * 100 (paper §7.3).
    pub overhead_pct: f64,
    pub native_std: f64,
    pub ecl_std: f64,
}

/// Full-lifecycle native time for a `gws`-item prefix of `bench`:
/// client + compile + upload + execute + release, per repetition.
pub fn native_time(
    reg: &ArtifactRegistry,
    bench: &str,
    gws: usize,
    reps: usize,
) -> Result<(Duration, f64)> {
    let manifest = reg.bench(bench)?.clone();
    let inputs = reg.golden_inputs(&manifest)?;
    let mut times = Vec::with_capacity(reps);
    for rep in 0..=reps {
        let t0 = Instant::now();
        {
            let mut exec = ChunkExecutor::new(reg, &manifest, &inputs)?;
            let mut outs: Vec<HostBuf> = manifest
                .outputs
                .iter()
                .map(|o| HostBuf::zeros_f32(o.elems))
                .collect();
            exec.execute_range(0, gws, &mut outs)?;
            // exec dropped here: client released (the paper's clRelease*).
        }
        if rep > 0 {
            times.push(t0.elapsed().as_secs_f64());
        }
    }
    Ok(summary(&times))
}

/// Full-lifecycle EngineCL time on one device, simulation off, lazy
/// compilation (so both sides build the same executables per rep).
pub fn enginecl_time(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    device: usize,
    gws: usize,
    reps: usize,
) -> Result<(Duration, f64)> {
    let mut times = Vec::with_capacity(reps);
    for rep in 0..=reps {
        let mut engine = build_engine(
            reg,
            node,
            bench,
            vec![DeviceSpec::new(device)],
            SchedulerKind::static_default(),
            Some(gws),
        )?;
        *engine.configurator() = crate::coordinator::Configurator::raw();
        engine.configurator().eager_compile = false;
        let t0 = Instant::now();
        engine.run().map_err(|e| anyhow::anyhow!("{e}"))?;
        if rep > 0 {
            times.push(t0.elapsed().as_secs_f64());
        }
    }
    Ok(summary(&times))
}

fn summary(times: &[f64]) -> (Duration, f64) {
    let med = crate::util::stats::median(times);
    let std = crate::util::stats::stddev(times);
    (Duration::from_secs_f64(med), std)
}

/// One (bench, device, gws) overhead cell.
pub fn measure(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    device: usize,
    gws: usize,
    reps: usize,
) -> Result<OverheadPoint> {
    let (native, native_std) = native_time(reg, bench, gws, reps)?;
    let (ecl, ecl_std) = enginecl_time(reg, node, bench, device, gws, reps)?;
    let overhead_pct =
        (ecl.as_secs_f64() - native.as_secs_f64()) / native.as_secs_f64() * 100.0;
    Ok(OverheadPoint {
        bench: bench.to_string(),
        gws,
        native,
        enginecl: ecl,
        overhead_pct,
        native_std,
        ecl_std,
    })
}
