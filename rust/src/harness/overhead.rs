//! Figures 7 & 8 — EngineCL-vs-native overhead on a single device, plus
//! the blocking-vs-pipelined engine comparison.
//!
//! The paper's measurement protocol times the *whole program lifecycle*
//! ("including initialization, management and releasing", §7.3), so all
//! sides here do the same work per repetition:
//!
//!  * native:    create an executor, compile the needed executables,
//!               upload inputs, execute, collect results, release — a
//!               hand-driven `ChunkExecutor` (what `examples/native/*`
//!               do over the raw runtime).
//!  * EngineCL:  a fresh engine with simulation off (`Configurator::
//!               raw()`) and lazy compilation (same executables compiled
//!               as native), Static schedule, blocking loop — the
//!               paper's protocol; `overhead_pct` is its number.
//!  * pipe base / EngineCL+pipe: the same engine on a fine-grained
//!               Dynamic schedule, blocking (`pipeline(1)`) vs
//!               double-buffered (`pipeline(2)`). Same schedule, same
//!               package count — the only delta is the pipeline, so
//!               this pair isolates what prefetch + overlapped staging
//!               buys: package *n+1*'s H2D hides inside package *n*'s
//!               window and the assign round-trip leaves the critical
//!               path (arXiv:2010.12607's sub-second-load optimization).
//!
//! The native/EngineCL difference is pure coordination cost: worker
//! threads, channels, scheduler, introspection, result merge.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{DeviceSpec, SchedulerKind};
use crate::platform::NodeConfig;
use crate::runtime::{ArtifactRegistry, ChunkExecutor, HostBuf};

use super::runs::build_engine;

#[derive(Debug, Clone)]
pub struct OverheadPoint {
    pub bench: String,
    pub gws: usize,
    pub native: Duration,
    /// The paper's measurement: blocking engine, Static schedule
    /// (one package).
    pub enginecl: Duration,
    /// Blocking engine on the multi-package Dynamic schedule — the
    /// like-for-like baseline for `pipelined` (same schedule, same
    /// package count, only the pipeline differs).
    pub pipe_base: Duration,
    /// Same Dynamic schedule, pipeline depth 2.
    pub pipelined: Duration,
    /// (T_ECL - T_OCL) / T_OCL * 100 (paper §7.3), Static blocking.
    pub overhead_pct: f64,
    /// Multi-package blocking engine vs native.
    pub pipe_base_pct: f64,
    /// Multi-package pipelined engine vs native.
    pub pipelined_pct: f64,
    pub native_std: f64,
    pub ecl_std: f64,
}

/// Full-lifecycle native time for a `gws`-item prefix of `bench`:
/// executor + compile + upload + execute + release, per repetition.
pub fn native_time(
    reg: &ArtifactRegistry,
    bench: &str,
    gws: usize,
    reps: usize,
) -> Result<(Duration, f64)> {
    let manifest = reg.bench(bench)?.clone();
    let inputs = reg.golden_inputs(&manifest)?;
    let mut times = Vec::with_capacity(reps);
    for rep in 0..=reps {
        let t0 = Instant::now();
        {
            let mut exec = ChunkExecutor::new(reg, &manifest, &inputs)?;
            let mut outs: Vec<HostBuf> = manifest
                .outputs
                .iter()
                .map(|o| HostBuf::zeros_f32(o.elems))
                .collect();
            exec.execute_range(0, gws, &mut outs)?;
            // exec dropped here: client released (the paper's clRelease*).
        }
        if rep > 0 {
            times.push(t0.elapsed().as_secs_f64());
        }
    }
    Ok(summary(&times))
}

/// Full-lifecycle EngineCL time on one device with the given scheduler
/// and pipeline depth, simulation off, lazy compilation (so every side
/// builds the same executables per rep).
#[allow(clippy::too_many_arguments)]
fn enginecl_time_with(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    device: usize,
    gws: usize,
    reps: usize,
    scheduler: SchedulerKind,
    depth: usize,
) -> Result<(Duration, f64)> {
    let mut times = Vec::with_capacity(reps);
    for rep in 0..=reps {
        let mut engine = build_engine(
            reg,
            node,
            bench,
            vec![DeviceSpec::new(device)],
            scheduler.clone(),
            Some(gws),
        )?;
        *engine.configurator() = crate::coordinator::Configurator::raw();
        engine.configurator().eager_compile = false;
        engine.pipeline(depth);
        let t0 = Instant::now();
        engine.run().map_err(|e| anyhow::anyhow!("{e}"))?;
        if rep > 0 {
            times.push(t0.elapsed().as_secs_f64());
        }
    }
    Ok(summary(&times))
}

/// Blocking-engine time under the paper's measurement protocol
/// (Static schedule: one package covering the whole prefix).
pub fn enginecl_time(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    device: usize,
    gws: usize,
    reps: usize,
) -> Result<(Duration, f64)> {
    enginecl_time_with(reg, node, bench, device, gws, reps, SchedulerKind::static_default(), 1)
}

/// Engine time on the fine-grained Dynamic schedule the pipeline
/// comparison uses (short loads still get multiple packages), with the
/// given pipeline depth (1 = blocking baseline, 2 = double-buffered).
pub fn enginecl_time_with_depth(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    device: usize,
    gws: usize,
    reps: usize,
    depth: usize,
) -> Result<(Duration, f64)> {
    let manifest = reg.bench(bench)?.clone();
    let packages = (gws / manifest.granule).clamp(1, 8);
    enginecl_time_with(
        reg,
        node,
        bench,
        device,
        gws,
        reps,
        SchedulerKind::dynamic(packages),
        depth,
    )
}

/// Byte counters from one default-config engine run (resident shared
/// inputs, arena outputs) on the paper's Static protocol — makes the
/// zero-copy win a countable number in the harness output.
pub fn transfer_stats(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    device: usize,
    gws: usize,
) -> Result<(usize, usize, usize)> {
    let mut engine = build_engine(
        reg,
        node,
        bench,
        vec![DeviceSpec::new(device)],
        SchedulerKind::static_default(),
        Some(gws),
    )?;
    *engine.configurator() = crate::coordinator::Configurator::raw();
    engine.run().map_err(|e| anyhow::anyhow!("{e}"))?;
    let report = engine.report().expect("run succeeded");
    Ok((report.input_upload_bytes(), report.h2d_bytes(), report.d2h_bytes()))
}

fn summary(times: &[f64]) -> (Duration, f64) {
    let med = crate::util::stats::median(times);
    let std = crate::util::stats::stddev(times);
    (Duration::from_secs_f64(med), std)
}

/// One (bench, device, gws) overhead cell: native vs the paper's
/// Static blocking engine (`overhead_pct`), plus the blocking-vs-
/// pipelined pair on the multi-package Dynamic schedule.
pub fn measure(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    bench: &str,
    device: usize,
    gws: usize,
    reps: usize,
) -> Result<OverheadPoint> {
    let (native, native_std) = native_time(reg, bench, gws, reps)?;
    let (ecl, ecl_std) = enginecl_time(reg, node, bench, device, gws, reps)?;
    let (base, _) = enginecl_time_with_depth(reg, node, bench, device, gws, reps, 1)?;
    let (piped, _) = enginecl_time_with_depth(reg, node, bench, device, gws, reps, 2)?;
    let pct = |t: Duration| (t.as_secs_f64() - native.as_secs_f64()) / native.as_secs_f64() * 100.0;
    Ok(OverheadPoint {
        bench: bench.to_string(),
        gws,
        native,
        enginecl: ecl,
        pipe_base: base,
        pipelined: piped,
        overhead_pct: pct(ecl),
        pipe_base_pct: pct(base),
        pipelined_pct: pct(piped),
        native_std,
        ecl_std,
    })
}
