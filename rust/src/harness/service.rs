//! `run --service` — the ingest-storm soak (PR-8 acceptance bench).
//!
//! Drives a seeded storm of small mixed-tenant requests through the
//! real [`Service`] front-end in deterministic pump mode: bursts of
//! requests land in the sharded mailboxes, each burst boundary runs one
//! admission round (drain → DRR → coalesce → dispatch → demux), and
//! backpressure ([`EclError::MailboxFull`]) is handled the way a real
//! client would — pump a round, retry. The result is
//! `BENCH_service.json`.
//!
//! Every JSON field is a pure function of the seed: request draws come
//! from one fixed-order [`XorShift`] stream, the pump loop is
//! single-threaded, and the cache counters it reports are aggregate
//! totals (artifact-cache *misses* are the number of distinct
//! (kernel-key, device) pairs — a set, not a race). Wall-clock never
//! enters the artifact; setup cost is *modeled* from the per-device
//! hit/miss counters times the device's profiled init latency, which is
//! exactly the work a cache hit skips. The CI service-suite runs the
//! storm twice under one seed and diffs the bytes.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::{
    Configurator, EclError, Request, SchedulerKind, Service, ServiceConfig, ServiceStats,
};
use crate::platform::NodeConfig;
use crate::runtime::ArtifactRegistry;
use crate::util::rng::XorShift;
use crate::util::stats;

/// Kernels the storm mixes (all four families present in every
/// registry, synthetic or AOT).
pub fn storm_kernels() -> Vec<&'static str> {
    vec!["binomial", "gaussian", "mandelbrot", "nbody"]
}

/// Knobs of the storm (CLI: `run --service [--requests N] [--seed S]
/// [--quick]`).
#[derive(Debug, Clone)]
pub struct ServiceBenchConfig {
    pub requests: usize,
    /// Distinct tenant labels; tenant `t0` draws double traffic (the
    /// skew the fairness metric is judged under).
    pub tenants: usize,
    pub seed: u64,
    pub quick: bool,
    pub shards: usize,
    pub coalesce_max: usize,
    /// DRR quantum (work-items per tenant per round).
    pub quantum: usize,
    /// Requests ingested between admission rounds.
    pub burst: usize,
}

impl Default for ServiceBenchConfig {
    fn default() -> Self {
        Self {
            requests: 1000,
            tenants: 5,
            seed: 7,
            quick: false,
            shards: 4,
            coalesce_max: 8,
            quantum: 4096,
            burst: 64,
        }
    }
}

/// One served request's ledger row.
#[derive(Debug, Clone)]
pub struct RequestRow {
    pub tenant: String,
    pub kernel: String,
    pub items: usize,
    /// Admission rounds spent queued (the fairness observable).
    pub wait_rounds: u64,
    /// Siblings in the batch that served it (1 = ran solo).
    pub batch_size: usize,
}

/// The full `run --service` result.
#[derive(Debug)]
pub struct ServiceBench {
    pub node: String,
    pub seed: u64,
    pub quick: bool,
    pub shards: usize,
    pub coalesce_max: usize,
    pub tenants: usize,
    pub rows: Vec<RequestRow>,
    pub failed: usize,
    pub stats: ServiceStats,
    /// Per-device (name, artifact hits, artifact misses, init ms).
    pub setup: Vec<(String, u64, u64, f64)>,
}

impl ServiceBench {
    pub fn served(&self) -> usize {
        self.rows.len()
    }

    /// Mean requests per batched session (1.0 = no coalescing at all).
    pub fn coalesce_ratio(&self) -> f64 {
        self.served() as f64 / (self.stats.batches.max(1)) as f64
    }

    fn wait_rounds(&self) -> Vec<f64> {
        self.rows.iter().map(|r| r.wait_rounds as f64).collect()
    }

    fn per_tenant_waits(&self) -> BTreeMap<String, Vec<f64>> {
        let mut out: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        for r in &self.rows {
            out.entry(r.tenant.clone()).or_default().push(r.wait_rounds as f64);
        }
        out
    }

    /// Worst per-tenant p95 admission wait over the fleet-wide median —
    /// the weighted-fairness observable (1.0 = perfectly even).
    pub fn fairness_ratio(&self) -> f64 {
        let fleet = self.wait_rounds();
        let median = stats::median(&fleet).max(1.0);
        self.per_tenant_waits()
            .values()
            .map(|w| stats::percentile(w, 95.0) / median)
            .fold(0.0, f64::max)
    }

    /// Modeled setup milliseconds (paid, saved): each artifact-cache
    /// miss charges its device's profiled init latency, each hit saves
    /// it.
    pub fn modeled_setup_ms(&self) -> (f64, f64) {
        let mut paid = 0.0;
        let mut saved = 0.0;
        for (_, hits, misses, init_ms) in &self.setup {
            paid += *misses as f64 * init_ms;
            saved += *hits as f64 * init_ms;
        }
        (paid, saved)
    }

    /// The `BENCH_service.json` artifact — hand-rolled like the other
    /// bench emitters. Deterministic quantities only (see module docs).
    pub fn json(&self) -> String {
        let waits = self.wait_rounds();
        let (paid_ms, saved_ms) = self.modeled_setup_ms();
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"node\": \"{}\",\n", self.node));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"shards\": {},\n", self.shards));
        s.push_str(&format!("  \"coalesce_max\": {},\n", self.coalesce_max));
        s.push_str(&format!("  \"requests\": {},\n", self.served() + self.failed));
        s.push_str(&format!("  \"served\": {},\n", self.served()));
        s.push_str(&format!("  \"failed\": {},\n", self.failed));
        s.push_str(&format!("  \"rounds\": {},\n", self.stats.rounds));
        s.push_str(&format!("  \"batches\": {},\n", self.stats.batches));
        s.push_str(&format!("  \"coalesced_requests\": {},\n", self.stats.coalesced_requests));
        s.push_str(&format!("  \"coalesce_ratio\": {:.4},\n", self.coalesce_ratio()));
        s.push_str(&format!(
            "  \"program_cache\": {{\"hits\": {}, \"misses\": {}}},\n",
            self.stats.program_cache_hits, self.stats.program_cache_misses
        ));
        s.push_str(&format!(
            "  \"artifact_cache\": {{\"hits\": {}, \"misses\": {}}},\n",
            self.stats.artifact_cache_hits, self.stats.artifact_cache_misses
        ));
        s.push_str(&format!(
            "  \"modeled_setup_ms\": {{\"paid\": {:.3}, \"saved\": {:.3}}},\n",
            paid_ms, saved_ms
        ));
        s.push_str("  \"per_device_setup\": {\n");
        for (i, (name, hits, misses, init_ms)) in self.setup.iter().enumerate() {
            let comma = if i + 1 == self.setup.len() { "" } else { "," };
            s.push_str(&format!(
                "    \"{name}\": {{\"hits\": {hits}, \"misses\": {misses}, \
                 \"init_ms\": {init_ms:.3}}}{comma}\n"
            ));
        }
        s.push_str("  },\n");
        s.push_str(&format!(
            "  \"wait_rounds\": {{\"p50\": {:.2}, \"p95\": {:.2}, \"max\": {}}},\n",
            stats::percentile(&waits, 50.0),
            stats::percentile(&waits, 95.0),
            self.rows.iter().map(|r| r.wait_rounds).max().unwrap_or(0)
        ));
        s.push_str("  \"per_tenant\": {\n");
        let per = self.per_tenant_waits();
        for (i, (tenant, w)) in per.iter().enumerate() {
            let comma = if i + 1 == per.len() { "" } else { "," };
            s.push_str(&format!(
                "    \"{tenant}\": {{\"requests\": {}, \"p50_wait\": {:.2}, \
                 \"p95_wait\": {:.2}}}{comma}\n",
                w.len(),
                stats::percentile(w, 50.0),
                stats::percentile(w, 95.0)
            ));
        }
        s.push_str("  },\n");
        s.push_str(&format!("  \"fairness_p95_over_median\": {:.4}\n", self.fairness_ratio()));
        s.push_str("}\n");
        s
    }

    /// The CI guard (`ECL_BENCH_GUARD=1`): every request served,
    /// coalescing actually happening, repeat traffic actually hitting
    /// the artifact cache, and no tenant starved past the fairness bar.
    pub fn guard(&self) -> Result<()> {
        anyhow::ensure!(self.failed == 0, "service storm dropped {} requests", self.failed);
        let ratio = self.coalesce_ratio();
        anyhow::ensure!(
            ratio >= 1.2,
            "coalescing regression: {:.2} requests/batch ({} served over {} batches)",
            ratio,
            self.served(),
            self.stats.batches
        );
        anyhow::ensure!(
            self.stats.artifact_cache_hits > 0,
            "artifact cache never hit across {} batches",
            self.stats.batches
        );
        let (paid, saved) = self.modeled_setup_ms();
        anyhow::ensure!(
            saved > paid,
            "repeat traffic should save more modeled setup than it pays \
             (paid {paid:.1}ms, saved {saved:.1}ms)"
        );
        let fair = self.fairness_ratio();
        anyhow::ensure!(
            fair <= 6.0,
            "fairness regression: worst tenant p95 wait is {fair:.2}x the fleet median"
        );
        Ok(())
    }
}

/// One pre-drawn storm request (the draw order is fixed so the RNG
/// stream is identical regardless of service behavior).
fn generate(
    reg: &ArtifactRegistry,
    cfg: &ServiceBenchConfig,
) -> Result<Vec<(Request, String, usize)>> {
    let kernels = storm_kernels();
    let mut rng = XorShift::new(cfg.seed ^ 0x51CE_F00D);
    let mut out = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        // Fixed draw order: kernel, size, tenant, scheduler, deadline.
        let kernel = kernels[rng.below(kernels.len())];
        let mult = 1 + rng.below(4);
        let t = rng.below(cfg.tenants + 1);
        let tenant = format!("t{}", if t >= cfg.tenants { 0 } else { t });
        let sched = if rng.below(2) == 0 {
            SchedulerKind::static_default()
        } else {
            SchedulerKind::dynamic(50)
        };
        let deadlined = rng.next_f64() < 0.25;
        let dl_ms = 50 + rng.below(200) as u64;
        let granule = reg.bench(kernel)?.granule;
        let items = granule * mult;
        let mut req = Request::new(kernel).gws(items).tenant(&tenant).scheduler(sched);
        if deadlined {
            req = req.deadline(Duration::from_millis(dl_ms));
        }
        out.push((req, kernel.to_string(), items));
    }
    Ok(out)
}

/// Run the storm: ingest in bursts, pump a round per burst (and per
/// backpressure bounce), drain, collect.
pub fn run_service(
    reg: &ArtifactRegistry,
    node: &NodeConfig,
    cfg: &ServiceBenchConfig,
) -> Result<ServiceBench> {
    let mut cfg = cfg.clone();
    if cfg.quick {
        cfg.requests = (cfg.requests / 5).max(50);
    }
    anyhow::ensure!(cfg.tenants > 0, "storm needs at least one tenant");
    anyhow::ensure!(cfg.burst > 0, "burst must be positive");
    // t0 draws double traffic and pays for it with a double DRR weight —
    // weighted fairness means waits even out despite the skew.
    let mut weights = BTreeMap::new();
    weights.insert("t0".to_string(), 2);
    let svc_cfg = ServiceConfig {
        shards: cfg.shards,
        coalesce_max: cfg.coalesce_max,
        quantum: cfg.quantum,
        seed: cfg.seed,
        weights,
        session_config: Configurator {
            simulate_init: false,
            simulate_speed: false,
            ..Default::default()
        },
        ..ServiceConfig::default()
    };
    let svc = Service::new(reg.clone(), node.clone(), svc_cfg);
    let drawn = generate(reg, &cfg)?;
    let mut handles = Vec::with_capacity(drawn.len());
    let mut meta = Vec::with_capacity(drawn.len());
    for (i, (req, kernel, items)) in drawn.into_iter().enumerate() {
        loop {
            match svc.ingest(req.clone()) {
                Ok(h) => {
                    handles.push(h);
                    meta.push((kernel.clone(), items));
                    break;
                }
                Err(EclError::MailboxFull { .. }) => {
                    // Backpressure: serve a round, then retry.
                    svc.pump_round();
                }
                Err(e) => anyhow::bail!("storm request {i} rejected at ingestion: {e}"),
            }
        }
        if (i + 1) % cfg.burst == 0 {
            svc.pump_round();
        }
    }
    svc.drain();
    anyhow::ensure!(
        svc.ledger_violations() == 0,
        "service ledger broke exactly-once delivery"
    );
    let mut rows = Vec::with_capacity(handles.len());
    let mut failed = 0usize;
    for (handle, (kernel, items)) in handles.into_iter().zip(meta) {
        let resp = handle.wait();
        match resp.result {
            Ok(served) => rows.push(RequestRow {
                tenant: resp.tenant,
                kernel,
                items,
                wait_rounds: served.report.wait_rounds(),
                batch_size: served.report.batch_size,
            }),
            Err(_) => failed += 1,
        }
    }
    let per_device = svc
        .runtime()
        .artifact_cache()
        .map(|c| c.device_counters())
        .unwrap_or_default();
    let setup = node
        .devices
        .iter()
        .map(|d| {
            let (hits, misses) = per_device.get(&d.name).copied().unwrap_or((0, 0));
            (d.name.clone(), hits, misses, d.init.as_secs_f64() * 1e3)
        })
        .collect();
    Ok(ServiceBench {
        node: node.name.clone(),
        seed: cfg.seed,
        quick: cfg.quick,
        shards: cfg.shards,
        coalesce_max: cfg.coalesce_max,
        tenants: cfg.tenants,
        rows,
        failed,
        stats: svc.stats(),
        setup,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn bench(requests: usize, seed: u64) -> ServiceBench {
        let reg = ArtifactRegistry::synthetic();
        let node = NodeConfig::batel();
        let cfg = ServiceBenchConfig { requests, seed, ..Default::default() };
        run_service(&reg, &node, &cfg).unwrap()
    }

    #[test]
    fn same_seed_is_byte_identical() {
        let a = bench(80, 7);
        let b = bench(80, 7);
        assert_eq!(a.json(), b.json(), "storm must be a pure function of the seed");
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(bench(80, 7).json(), bench(80, 8).json());
    }

    #[test]
    fn reference_storm_clears_the_guard() {
        let b = bench(150, 7);
        b.guard().unwrap_or_else(|e| panic!("guard failed: {e}"));
        assert_eq!(b.served(), 150);
        assert!(b.coalesce_ratio() > 1.0, "storm traffic must coalesce");
    }

    #[test]
    fn json_is_parseable_and_accounts_for_every_request() {
        let b = bench(80, 7);
        let doc = Json::parse(&b.json()).expect("valid JSON");
        assert_eq!(doc.get("served").and_then(Json::as_f64).unwrap() as usize, 80);
        assert_eq!(doc.get("failed").and_then(Json::as_f64).unwrap() as usize, 0);
        let ratio = doc.get("coalesce_ratio").and_then(Json::as_f64).unwrap();
        assert!(ratio >= 1.0);
        let fair = doc.get("fairness_p95_over_median").and_then(Json::as_f64).unwrap();
        assert!(fair > 0.0);
        let ac = doc.get("artifact_cache").unwrap();
        let misses = ac.get("misses").and_then(Json::as_f64).unwrap();
        assert!(misses > 0.0, "first-touch builds must be counted");
    }

    #[test]
    fn quick_mode_shrinks_the_storm() {
        let reg = ArtifactRegistry::synthetic();
        let node = NodeConfig::batel();
        let cfg =
            ServiceBenchConfig { requests: 1000, seed: 7, quick: true, ..Default::default() };
        let b = run_service(&reg, &node, &cfg).unwrap();
        assert_eq!(b.served(), 200);
        assert!(b.quick);
    }
}
