//! Figures 10, 11, 12 — speedups, efficiency and work distribution, plus
//! the §8.4 headline aggregation (HGuided mean efficiency per node).

use crate::util::stats;

use super::balance::NodeEvaluation;
use super::runs::CoexecMetrics;

/// Rows for the speedup/efficiency figures: (bench, scheduler, metrics).
pub fn perf_rows(eval: &NodeEvaluation) -> &[CoexecMetrics] {
    &eval.cells
}

/// Mean efficiency per scheduler label (Figure 11 summary; the paper's
/// headline is the HGuided row).
pub fn mean_efficiency_by_scheduler(eval: &NodeEvaluation) -> Vec<(String, f64)> {
    let mut labels: Vec<String> = Vec::new();
    for c in &eval.cells {
        if !labels.contains(&c.scheduler) {
            labels.push(c.scheduler.clone());
        }
    }
    labels
        .into_iter()
        .map(|l| {
            let effs: Vec<f64> = eval
                .cells
                .iter()
                .filter(|c| c.scheduler == l)
                .map(|c| c.efficiency)
                .collect();
            (l, stats::mean(&effs))
        })
        .collect()
}

/// Geometric-mean efficiency per scheduler (the paper quotes geo-mean for
/// Dynamic on Batel).
pub fn geomean_efficiency_by_scheduler(eval: &NodeEvaluation) -> Vec<(String, f64)> {
    mean_efficiency_by_scheduler(eval)
        .into_iter()
        .map(|(l, _)| {
            let effs: Vec<f64> = eval
                .cells
                .iter()
                .filter(|c| c.scheduler == l)
                .map(|c| c.efficiency)
                .collect();
            (l.clone(), stats::geomean(&effs))
        })
        .collect()
}

/// Work-share rows (Figure 12): bench, scheduler, one share per device.
pub fn worksize_rows(eval: &NodeEvaluation) -> Vec<(String, String, Vec<f64>)> {
    eval.cells
        .iter()
        .map(|c| (c.bench.clone(), c.scheduler.clone(), c.work_shares.clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn cell(bench: &str, sched: &str, eff: f64) -> CoexecMetrics {
        CoexecMetrics {
            bench: bench.into(),
            scheduler: sched.into(),
            balance: 0.9,
            speedup: eff * 2.0,
            max_speedup: 2.0,
            efficiency: eff,
            work_shares: vec![0.3, 0.7],
            total_packages: 2,
            wall: Duration::from_millis(10),
        }
    }

    fn eval() -> NodeEvaluation {
        NodeEvaluation {
            node: "t".into(),
            cells: vec![
                cell("a", "Static", 0.8),
                cell("a", "HGuided", 0.9),
                cell("b", "Static", 0.6),
                cell("b", "HGuided", 0.88),
            ],
            solos: BTreeMap::new(),
        }
    }

    #[test]
    fn mean_efficiency_groups_by_scheduler() {
        let rows = mean_efficiency_by_scheduler(&eval());
        assert_eq!(rows.len(), 2);
        let hg = rows.iter().find(|(l, _)| l == "HGuided").unwrap();
        assert!((hg.1 - 0.89).abs() < 1e-9);
    }

    #[test]
    fn worksize_rows_shape() {
        let rows = worksize_rows(&eval());
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].2.len(), 2);
    }
}
