//! Figures 10, 11, 12 — speedups, efficiency and work distribution, plus
//! the §8.4 headline aggregation (HGuided mean efficiency per node).

use crate::util::stats;

use super::balance::NodeEvaluation;
use super::runs::CoexecMetrics;

/// Rows for the speedup/efficiency figures: (bench, scheduler, metrics).
pub fn perf_rows(eval: &NodeEvaluation) -> &[CoexecMetrics] {
    &eval.cells
}

/// Mean efficiency per scheduler label (Figure 11 summary; the paper's
/// headline is the HGuided row).
pub fn mean_efficiency_by_scheduler(eval: &NodeEvaluation) -> Vec<(String, f64)> {
    let mut labels: Vec<String> = Vec::new();
    for c in &eval.cells {
        if !labels.contains(&c.scheduler) {
            labels.push(c.scheduler.clone());
        }
    }
    labels
        .into_iter()
        .map(|l| {
            let effs: Vec<f64> = eval
                .cells
                .iter()
                .filter(|c| c.scheduler == l)
                .map(|c| c.efficiency)
                .collect();
            (l, stats::mean(&effs))
        })
        .collect()
}

/// Geometric-mean efficiency per scheduler (the paper quotes geo-mean for
/// Dynamic on Batel).
pub fn geomean_efficiency_by_scheduler(eval: &NodeEvaluation) -> Vec<(String, f64)> {
    mean_efficiency_by_scheduler(eval)
        .into_iter()
        .map(|(l, _)| {
            let effs: Vec<f64> = eval
                .cells
                .iter()
                .filter(|c| c.scheduler == l)
                .map(|c| c.efficiency)
                .collect();
            (l.clone(), stats::geomean(&effs))
        })
        .collect()
}

/// One blocking-vs-pipelined pairing from an evaluation grid.
#[derive(Debug, Clone)]
pub struct PipelineGain {
    pub bench: String,
    /// Base scheduler label (without the `+pipe` suffix).
    pub scheduler: String,
    pub blocking_wall: std::time::Duration,
    pub pipelined_wall: std::time::Duration,
    pub blocking_eff: f64,
    pub pipelined_eff: f64,
}

impl PipelineGain {
    /// Wall-time change, pipelined vs blocking (negative = faster).
    pub fn wall_delta_pct(&self) -> f64 {
        let b = self.blocking_wall.as_secs_f64();
        if b <= 0.0 {
            return 0.0;
        }
        (self.pipelined_wall.as_secs_f64() - b) / b * 100.0
    }
}

/// Pair every `X+pipe` cell in an evaluation with its blocking `X` cell
/// on the same bench — the harness view of what the package pipeline
/// buys each scheduler.
pub fn pipeline_gains(cells: &[CoexecMetrics]) -> Vec<PipelineGain> {
    let mut out = Vec::new();
    for piped in cells.iter().filter(|c| c.scheduler.ends_with("+pipe")) {
        let base = piped.scheduler.trim_end_matches("+pipe");
        if let Some(blocking) =
            cells.iter().find(|c| c.bench == piped.bench && c.scheduler == base)
        {
            out.push(PipelineGain {
                bench: piped.bench.clone(),
                scheduler: base.to_string(),
                blocking_wall: blocking.wall,
                pipelined_wall: piped.wall,
                blocking_eff: blocking.efficiency,
                pipelined_eff: piped.efficiency,
            });
        }
    }
    out
}

/// Work-share rows (Figure 12): bench, scheduler, one share per device.
pub fn worksize_rows(eval: &NodeEvaluation) -> Vec<(String, String, Vec<f64>)> {
    eval.cells
        .iter()
        .map(|c| (c.bench.clone(), c.scheduler.clone(), c.work_shares.clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::time::Duration;

    fn cell(bench: &str, sched: &str, eff: f64) -> CoexecMetrics {
        CoexecMetrics {
            bench: bench.into(),
            scheduler: sched.into(),
            balance: 0.9,
            speedup: eff * 2.0,
            max_speedup: 2.0,
            efficiency: eff,
            work_shares: vec![0.3, 0.7],
            total_packages: 2,
            wall: Duration::from_millis(10),
        }
    }

    fn eval() -> NodeEvaluation {
        NodeEvaluation {
            node: "t".into(),
            cells: vec![
                cell("a", "Static", 0.8),
                cell("a", "HGuided", 0.9),
                cell("b", "Static", 0.6),
                cell("b", "HGuided", 0.88),
            ],
            solos: BTreeMap::new(),
        }
    }

    #[test]
    fn pipeline_gains_pair_up() {
        let mut e = eval();
        let mut piped = cell("a", "HGuided+pipe", 0.92);
        piped.wall = Duration::from_millis(8);
        e.cells.push(piped);
        let gains = pipeline_gains(&e.cells);
        assert_eq!(gains.len(), 1);
        let g = &gains[0];
        assert_eq!(g.bench, "a");
        assert_eq!(g.scheduler, "HGuided");
        assert!(g.wall_delta_pct() < 0.0, "pipelined cell was faster");
    }

    #[test]
    fn mean_efficiency_groups_by_scheduler() {
        let rows = mean_efficiency_by_scheduler(&eval());
        assert_eq!(rows.len(), 2);
        let hg = rows.iter().find(|(l, _)| l == "HGuided").unwrap();
        assert!((hg.1 - 0.89).abs() < 1e-9);
    }

    #[test]
    fn worksize_rows_shape() {
        let rows = worksize_rows(&eval());
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].2.len(), 2);
    }
}
