//! Artifact registry — the Rust view of `artifacts/manifest.json`.
//!
//! The manifest is the contract between the Python AOT step (L1/L2) and
//! the Rust coordinator (L3): problem sizes, scheduling granules, buffer
//! layouts, baked scalar args and the per-chunk-size HLO files.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::host::{read_f32_file, HostBuf};

/// One input or output buffer of a benchmark.
#[derive(Debug, Clone)]
pub struct BufferEntry {
    pub name: String,
    /// Total flattened f32 elements for the full problem.
    pub elems: usize,
    /// Flattened elements contributed per work-item (0 for broadcast
    /// inputs that are not partitioned, e.g. filter weights, scenes).
    pub elems_per_item: usize,
    /// Golden data file, relative to the artifact root.
    pub file: String,
}

/// Everything the runtime knows about one benchmark.
#[derive(Debug, Clone)]
pub struct BenchManifest {
    pub name: String,
    /// Global work items (the paper's global work size, in granule units
    /// see `granule`).
    pub n: usize,
    /// Scheduling granule: packages are multiples of this (the paper's
    /// local work size / work-group).
    pub granule: usize,
    pub irregular: bool,
    /// Paper Table 2 out-pattern (out indexes : work-items), API metadata.
    pub out_pattern: (usize, usize),
    /// Kernel family providing the HLO files (ray2/ray3 alias ray1).
    pub kernel: String,
    pub scalars: BTreeMap<String, f64>,
    pub inputs: Vec<BufferEntry>,
    pub outputs: Vec<BufferEntry>,
    /// Available chunk sizes (work-items) -> HLO file.
    pub chunks: BTreeMap<usize, String>,
}

impl BenchManifest {
    /// Largest available chunk size ≤ `want`, if any.
    pub fn chunk_at_most(&self, want: usize) -> Option<usize> {
        self.chunks.range(..=want).next_back().map(|(s, _)| *s)
    }

    pub fn hlo_path(&self, root: &Path, size: usize) -> Option<PathBuf> {
        self.chunks.get(&size).map(|f| root.join(f))
    }
}

/// Registry over the artifact directory.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    pub root: PathBuf,
    pub benches: BTreeMap<String, BenchManifest>,
}

fn parse_buffer(j: &Json) -> Result<BufferEntry> {
    Ok(BufferEntry {
        name: j.get("name").and_then(Json::as_str).context("buffer.name")?.into(),
        elems: j.get("elems").and_then(Json::as_usize).context("buffer.elems")?,
        elems_per_item: j
            .get("elems_per_item")
            .and_then(Json::as_usize)
            .context("buffer.elems_per_item")?,
        file: j.get("file").and_then(Json::as_str).context("buffer.file")?.into(),
    })
}

fn parse_bench(name: &str, j: &Json) -> Result<BenchManifest> {
    let out_pattern = j
        .get("out_pattern")
        .and_then(Json::as_arr)
        .map(|a| {
            (
                a.first().and_then(Json::as_usize).unwrap_or(1),
                a.get(1).and_then(Json::as_usize).unwrap_or(1),
            )
        })
        .unwrap_or((1, 1));
    let mut scalars = BTreeMap::new();
    if let Some(obj) = j.get("scalars").and_then(Json::as_obj) {
        for (k, v) in obj {
            scalars.insert(k.clone(), v.as_f64().context("scalar not a number")?);
        }
    }
    let mut chunks = BTreeMap::new();
    for c in j.get("chunks").and_then(Json::as_arr).context("chunks")? {
        chunks.insert(
            c.get("size").and_then(Json::as_usize).context("chunk.size")?,
            c.get("file").and_then(Json::as_str).context("chunk.file")?.to_string(),
        );
    }
    let parse_bufs = |key: &str| -> Result<Vec<BufferEntry>> {
        j.get(key)
            .and_then(Json::as_arr)
            .map(|a| a.iter().map(parse_buffer).collect())
            .unwrap_or_else(|| Ok(vec![]))
    };
    Ok(BenchManifest {
        name: name.to_string(),
        n: j.get("n").and_then(Json::as_usize).context("n")?,
        granule: j.get("granule").and_then(Json::as_usize).context("granule")?,
        irregular: j.get("irregular").and_then(Json::as_bool).unwrap_or(false),
        out_pattern,
        kernel: j
            .get("kernel")
            .and_then(Json::as_str)
            .unwrap_or(name)
            .to_string(),
        scalars,
        inputs: parse_bufs("inputs")?,
        outputs: parse_bufs("outputs")?,
        chunks,
    })
}

impl ArtifactRegistry {
    /// Load `<root>/manifest.json`. `root` is typically `artifacts/`.
    pub fn load(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut benches = BTreeMap::new();
        for (name, bj) in j.get("benches").and_then(Json::as_obj).context("benches")? {
            benches.insert(name.clone(), parse_bench(name, bj)?);
        }
        Ok(ArtifactRegistry { root, benches })
    }

    /// Locate the artifact dir: $ECL_ARTIFACTS, ./artifacts, or
    /// CARGO_MANIFEST_DIR/artifacts.
    pub fn discover() -> Result<Self> {
        if let Ok(p) = std::env::var("ECL_ARTIFACTS") {
            return Self::load(p);
        }
        for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::load(cand);
            }
        }
        anyhow::bail!("no artifacts/manifest.json found; run `make artifacts`")
    }

    pub fn bench(&self, name: &str) -> Result<&BenchManifest> {
        self.benches
            .get(name)
            .with_context(|| format!("unknown bench '{name}' (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.benches.keys().map(|s| s.as_str()).collect()
    }

    /// Load the golden inputs for a bench (deterministic workload from aot).
    pub fn golden_inputs(&self, bench: &BenchManifest) -> Result<Vec<HostBuf>> {
        bench
            .inputs
            .iter()
            .map(|b| Ok(HostBuf::F32(read_f32_file(&self.root.join(&b.file))?)))
            .collect()
    }

    /// Load the golden (oracle) outputs for a bench.
    pub fn golden_outputs(&self, bench: &BenchManifest) -> Result<Vec<HostBuf>> {
        bench
            .outputs
            .iter()
            .map(|b| Ok(HostBuf::F32(read_f32_file(&self.root.join(&b.file))?)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest() -> &'static str {
        r#"{"version": 1, "benches": {"toy": {
            "n": 1024, "granule": 128, "irregular": false,
            "out_pattern": [1, 1], "kernel": "toy",
            "scalars": {"steps": 4.0},
            "inputs": [{"name": "x", "elems": 1024, "elems_per_item": 1, "file": "toy/in.f32"}],
            "outputs": [{"name": "y", "elems": 1024, "elems_per_item": 1, "file": "toy/out.f32"}],
            "chunks": [{"size": 128, "file": "toy/c128.hlo.txt"},
                       {"size": 256, "file": "toy/c256.hlo.txt"},
                       {"size": 1024, "file": "toy/c1024.hlo.txt"}]
        }}}"#
    }

    fn load_mini() -> ArtifactRegistry {
        let dir = std::env::temp_dir().join(format!("ecl_art_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), mini_manifest()).unwrap();
        ArtifactRegistry::load(&dir).unwrap()
    }

    #[test]
    fn parses_manifest() {
        let reg = load_mini();
        let b = reg.bench("toy").unwrap();
        assert_eq!(b.n, 1024);
        assert_eq!(b.granule, 128);
        assert_eq!(b.out_pattern, (1, 1));
        assert_eq!(b.scalars["steps"], 4.0);
        assert_eq!(b.inputs.len(), 1);
        assert_eq!(b.chunks.len(), 3);
    }

    #[test]
    fn chunk_at_most_picks_floor() {
        let reg = load_mini();
        let b = reg.bench("toy").unwrap();
        assert_eq!(b.chunk_at_most(128), Some(128));
        assert_eq!(b.chunk_at_most(300), Some(256));
        assert_eq!(b.chunk_at_most(5000), Some(1024));
        assert_eq!(b.chunk_at_most(64), None);
    }

    #[test]
    fn unknown_bench_errors() {
        let reg = load_mini();
        assert!(reg.bench("nope").is_err());
    }
}
