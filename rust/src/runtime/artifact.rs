//! Artifact registry — the Rust view of `artifacts/manifest.json`, plus a
//! synthetic in-memory fallback so the crate is fully usable offline.
//!
//! The manifest is the contract between the Python AOT step (L1/L2) and
//! the Rust coordinator (L3): problem sizes, scheduling granules, buffer
//! layouts, baked scalar args and the per-chunk-size HLO files.
//!
//! When no `artifacts/` directory exists (no Python toolchain ran),
//! [`ArtifactRegistry::discover`] falls back to
//! [`ArtifactRegistry::synthetic`]: the same seven benchmarks (plus the
//! synthetic-only `collatz` straggler workload) at reduced
//! problem sizes, with
//! deterministic generated inputs and golden outputs computed by the
//! native kernels in [`super::kernels`]. Everything above the runtime —
//! engine, schedulers, harnesses, tests — behaves identically against
//! either source.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::rng::XorShift;

use super::host::{read_f32_file, HostBuf};

/// One input or output buffer of a benchmark.
#[derive(Debug, Clone)]
pub struct BufferEntry {
    pub name: String,
    /// Total flattened f32 elements for the full problem.
    pub elems: usize,
    /// Flattened elements contributed per work-item (0 for broadcast
    /// inputs that are not partitioned, e.g. filter weights, scenes).
    pub elems_per_item: usize,
    /// Golden data file, relative to the artifact root (synthetic
    /// registries generate data instead; the name is informational).
    pub file: String,
}

/// Everything the runtime knows about one benchmark.
#[derive(Debug, Clone)]
pub struct BenchManifest {
    pub name: String,
    /// Global work items (the paper's global work size, in granule units
    /// see `granule`).
    pub n: usize,
    /// Scheduling granule: packages are multiples of this (the paper's
    /// local work size / work-group).
    pub granule: usize,
    pub irregular: bool,
    /// Paper Table 2 out-pattern (out indexes : work-items), API metadata.
    pub out_pattern: (usize, usize),
    /// Kernel family providing the executables (ray2/ray3 alias ray1).
    pub kernel: String,
    pub scalars: BTreeMap<String, f64>,
    pub inputs: Vec<BufferEntry>,
    pub outputs: Vec<BufferEntry>,
    /// Available chunk sizes (work-items) -> HLO file.
    pub chunks: BTreeMap<usize, String>,
}

impl BenchManifest {
    /// Largest available chunk size ≤ `want`, if any.
    pub fn chunk_at_most(&self, want: usize) -> Option<usize> {
        self.chunks.range(..=want).next_back().map(|(s, _)| *s)
    }

    pub fn hlo_path(&self, root: &Path, size: usize) -> Option<PathBuf> {
        self.chunks.get(&size).map(|f| root.join(f))
    }
}

/// Registry over the artifact directory (or the synthetic workloads).
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    pub root: PathBuf,
    pub benches: BTreeMap<String, BenchManifest>,
    /// True when this registry generates data instead of reading files.
    pub synthetic: bool,
}

fn parse_buffer(j: &Json) -> Result<BufferEntry> {
    Ok(BufferEntry {
        name: j.get("name").and_then(Json::as_str).context("buffer.name")?.into(),
        elems: j.get("elems").and_then(Json::as_usize).context("buffer.elems")?,
        elems_per_item: j
            .get("elems_per_item")
            .and_then(Json::as_usize)
            .context("buffer.elems_per_item")?,
        file: j.get("file").and_then(Json::as_str).context("buffer.file")?.into(),
    })
}

fn parse_bench(name: &str, j: &Json) -> Result<BenchManifest> {
    let out_pattern = j
        .get("out_pattern")
        .and_then(Json::as_arr)
        .map(|a| {
            (
                a.first().and_then(Json::as_usize).unwrap_or(1),
                a.get(1).and_then(Json::as_usize).unwrap_or(1),
            )
        })
        .unwrap_or((1, 1));
    let mut scalars = BTreeMap::new();
    if let Some(obj) = j.get("scalars").and_then(Json::as_obj) {
        for (k, v) in obj {
            scalars.insert(k.clone(), v.as_f64().context("scalar not a number")?);
        }
    }
    let mut chunks = BTreeMap::new();
    for c in j.get("chunks").and_then(Json::as_arr).context("chunks")? {
        chunks.insert(
            c.get("size").and_then(Json::as_usize).context("chunk.size")?,
            c.get("file").and_then(Json::as_str).context("chunk.file")?.to_string(),
        );
    }
    let parse_bufs = |key: &str| -> Result<Vec<BufferEntry>> {
        j.get(key)
            .and_then(Json::as_arr)
            .map(|a| a.iter().map(parse_buffer).collect())
            .unwrap_or_else(|| Ok(vec![]))
    };
    Ok(BenchManifest {
        name: name.to_string(),
        n: j.get("n").and_then(Json::as_usize).context("n")?,
        granule: j.get("granule").and_then(Json::as_usize).context("granule")?,
        irregular: j.get("irregular").and_then(Json::as_bool).unwrap_or(false),
        out_pattern,
        kernel: j
            .get("kernel")
            .and_then(Json::as_str)
            .unwrap_or(name)
            .to_string(),
        scalars,
        inputs: parse_bufs("inputs")?,
        outputs: parse_bufs("outputs")?,
        chunks,
    })
}

impl ArtifactRegistry {
    /// Load `<root>/manifest.json`. `root` is typically `artifacts/`.
    pub fn load(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut benches = BTreeMap::new();
        for (name, bj) in j.get("benches").and_then(Json::as_obj).context("benches")? {
            benches.insert(name.clone(), parse_bench(name, bj)?);
        }
        Ok(ArtifactRegistry { root, benches, synthetic: false })
    }

    /// Locate the artifact dir: `$ECL_ARTIFACTS` (the literal value
    /// `synthetic` forces the generated workloads), `./artifacts`,
    /// `CARGO_MANIFEST_DIR/artifacts`, else the synthetic registry.
    ///
    /// The PJRT backend executes on-disk HLO artifacts, so under the
    /// `pjrt` feature the synthetic fallback is an error, not a silent
    /// substitution — the old actionable "run `make artifacts`" message
    /// is preserved there.
    pub fn discover() -> Result<Self> {
        let synthetic_or_bail = || -> Result<Self> {
            if cfg!(feature = "pjrt") {
                anyhow::bail!(
                    "no artifacts/manifest.json found; run `make artifacts` \
                     (the pjrt backend executes HLO artifacts and cannot use \
                     the synthetic registry)"
                )
            } else {
                Ok(Self::synthetic())
            }
        };
        if let Ok(p) = std::env::var("ECL_ARTIFACTS") {
            if p == "synthetic" {
                return synthetic_or_bail();
            }
            return Self::load(p);
        }
        for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
            if Path::new(cand).join("manifest.json").exists() {
                return Self::load(cand);
            }
        }
        synthetic_or_bail()
    }

    /// The built-in workload set: the paper's seven benchmarks plus the
    /// `collatz` straggler workload, at reduced problem sizes, fully
    /// generated in-process (no files, no Python).
    pub fn synthetic() -> Self {
        let mut benches = BTreeMap::new();
        for b in synthetic_benches() {
            benches.insert(b.name.clone(), b);
        }
        ArtifactRegistry { root: PathBuf::from("<synthetic>"), benches, synthetic: true }
    }

    pub fn bench(&self, name: &str) -> Result<&BenchManifest> {
        self.benches
            .get(name)
            .with_context(|| format!("unknown bench '{name}' (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.benches.keys().map(|s| s.as_str()).collect()
    }

    /// Load the golden inputs for a bench (deterministic workload).
    pub fn golden_inputs(&self, bench: &BenchManifest) -> Result<Vec<HostBuf>> {
        if self.synthetic {
            return Ok(synthetic_inputs(bench));
        }
        bench
            .inputs
            .iter()
            .map(|b| Ok(HostBuf::F32(read_f32_file(&self.root.join(&b.file))?)))
            .collect()
    }

    /// Load the golden (oracle) outputs for a bench. Synthetic registries
    /// compute them with the native kernels; disk registries read the
    /// files the Python AOT step wrote.
    pub fn golden_outputs(&self, bench: &BenchManifest) -> Result<Vec<HostBuf>> {
        if self.synthetic {
            let inputs: Vec<Vec<f32>> = synthetic_inputs(bench)
                .into_iter()
                .map(|b| b.as_f32().unwrap().to_vec())
                .collect();
            let mut outs: Vec<Vec<f32>> = bench
                .outputs
                .iter()
                .map(|o| vec![0.0f32; bench.n * o.elems_per_item])
                .collect();
            super::kernels::compute_range_vecs(bench, &inputs, 0, bench.n, &mut outs)?;
            return Ok(outs.into_iter().map(HostBuf::F32).collect());
        }
        bench
            .outputs
            .iter()
            .map(|b| Ok(HostBuf::F32(read_f32_file(&self.root.join(&b.file))?)))
            .collect()
    }
}

// ---- synthetic workloads ---------------------------------------------

fn ladder(granule: usize, n: usize) -> BTreeMap<usize, String> {
    // granule * 4^k up to the full size, plus the full size — the same
    // ladder the AOT step compiles (model.py chunk_sizes()).
    let mut chunks = BTreeMap::new();
    let mut s = granule;
    while s < n {
        chunks.insert(s, format!("synthetic/c{s}"));
        s *= 4;
    }
    chunks.insert(n, format!("synthetic/c{n}"));
    chunks
}

fn buf(name: &str, elems: usize, elems_per_item: usize) -> BufferEntry {
    BufferEntry {
        name: name.into(),
        elems,
        elems_per_item,
        file: format!("synthetic/{name}.f32"),
    }
}

fn scalars(pairs: &[(&str, f64)]) -> BTreeMap<String, f64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// Reduced-size counterparts of `python/compile/model.py`'s BENCHES —
/// small enough that debug-mode test runs stay fast, large enough that
/// every scheduler produces multi-package co-executions — plus the
/// synthetic-only `collatz` straggler workload (no AOT counterpart).
fn synthetic_benches() -> Vec<BenchManifest> {
    let mut out = Vec::new();

    // Gaussian: 128x128 image, 9-tap separable blur. Regular.
    let (gw, gh) = (128usize, 128usize);
    out.push(BenchManifest {
        name: "gaussian".into(),
        n: gw * gh,
        granule: 4 * gw,
        irregular: false,
        out_pattern: (1, 1),
        kernel: "gaussian".into(),
        scalars: scalars(&[("width", gw as f64), ("height", gh as f64), ("ksize", 9.0)]),
        inputs: vec![buf("img", gw * gh, 1), buf("filt", 9, 0)],
        outputs: vec![buf("blur", gw * gh, 1)],
        chunks: ladder(4 * gw, gw * gh),
    });

    // Binomial: 1024 options on a 126-step lattice. Regular, compute-heavy.
    let bn = 1024usize;
    out.push(BenchManifest {
        name: "binomial".into(),
        n: bn,
        granule: 64,
        irregular: false,
        out_pattern: (1, 255),
        kernel: "binomial".into(),
        scalars: scalars(&[("steps", 126.0)]),
        inputs: vec![buf("prices", bn, 1)],
        outputs: vec![buf("value", bn, 1)],
        chunks: ladder(64, bn),
    });

    // Mandelbrot: 128x128 pixels over a mixed interior/exterior view.
    let (mw, mh) = (128usize, 128usize);
    out.push(BenchManifest {
        name: "mandelbrot".into(),
        n: mw * mh,
        granule: 256,
        irregular: true,
        out_pattern: (4, 1),
        kernel: "mandelbrot".into(),
        scalars: scalars(&[
            ("width", mw as f64),
            ("height", mh as f64),
            ("maxiter", 128.0),
            ("x0", -2.0),
            ("y0", -1.25),
            ("x1", 0.5),
            ("y1", 1.25),
        ]),
        inputs: vec![],
        outputs: vec![buf("iters", mw * mh, 1)],
        chunks: ladder(256, mw * mh),
    });

    // Collatz: trajectory lengths with a seeded hotspot band — the
    // heavy-tailed straggler workload of the work-stealing bench. Not a
    // paper benchmark; synthetic-only (no HLO artifact exists for it).
    // The hot band sits at the *front* of the index space, where the
    // cold-start prior hands out the largest, least-informed packages:
    // the prefetch queues built before the first observations return are
    // exactly the backlog stealing exists to revoke.
    let cn = 4096usize;
    out.push(BenchManifest {
        name: "collatz".into(),
        n: cn,
        granule: 64,
        irregular: true,
        out_pattern: (1, 1),
        kernel: "collatz".into(),
        scalars: scalars(&[
            ("seed", 2026.0),
            ("maxiter", 512.0),
            ("hot_lo", 0.0),
            ("hot_hi", 0.125),
            ("hot_rounds", 16.0),
        ]),
        inputs: vec![],
        outputs: vec![buf("steps", cn, 1)],
        chunks: ladder(64, cn),
    });

    // NBody: 1024 bodies, one integration step. Regular, O(n^2).
    let nb = 1024usize;
    out.push(BenchManifest {
        name: "nbody".into(),
        n: nb,
        granule: 256,
        irregular: false,
        out_pattern: (1, 1),
        kernel: "nbody".into(),
        scalars: scalars(&[("dt", 0.005), ("eps2", 50.0), ("bodies", nb as f64)]),
        inputs: vec![buf("pos", nb * 4, 4), buf("vel", nb * 4, 4)],
        outputs: vec![buf("opos", nb * 4, 4), buf("ovel", nb * 4, 4)],
        chunks: ladder(256, nb),
    });

    // Ray: 96x96 pixels, 16 spheres, three scenes of growing complexity.
    let (rw, rh, rns) = (96usize, 96usize, 16usize);
    for which in 1..=3u32 {
        out.push(BenchManifest {
            name: format!("ray{which}"),
            n: rw * rh,
            granule: 256,
            irregular: true,
            out_pattern: (1, 1),
            kernel: "ray1".into(),
            scalars: scalars(&[
                ("width", rw as f64),
                ("height", rh as f64),
                ("nspheres", rns as f64),
                ("maxbounce", 8.0),
                ("scene", which as f64),
            ]),
            inputs: vec![buf("spheres", rns * 8, 0)],
            outputs: vec![buf("rgba", rw * rh * 4, 4)],
            chunks: ladder(256, rw * rh),
        });
    }
    out
}

/// Deterministic generated inputs, mirroring `model.py`'s distributions
/// (different RNG, same shapes and ranges).
fn synthetic_inputs(bench: &BenchManifest) -> Vec<HostBuf> {
    match bench.kernel.as_str() {
        "gaussian" => {
            let mut r = XorShift::new(11);
            let img: Vec<f32> =
                (0..bench.inputs[0].elems).map(|_| r.next_f32() * 255.0).collect();
            let k = bench.scalars["ksize"] as usize;
            let sigma = 1.5f32;
            let mut filt: Vec<f32> = (0..k)
                .map(|i| {
                    let ax = i as f32 - (k / 2) as f32;
                    (-(ax * ax) / (2.0 * sigma * sigma)).exp()
                })
                .collect();
            let sum: f32 = filt.iter().sum();
            for f in &mut filt {
                *f /= sum;
            }
            vec![HostBuf::F32(img), HostBuf::F32(filt)]
        }
        "binomial" => {
            let mut r = XorShift::new(12);
            vec![HostBuf::F32((0..bench.n).map(|_| r.next_f32()).collect())]
        }
        // Input-less kernels: the whole workload is derived from scalars.
        "collatz" | "mandelbrot" => vec![],
        "nbody" => {
            let mut r = XorShift::new(13);
            let n = bench.n;
            let mut pos = Vec::with_capacity(n * 4);
            let mut vel = Vec::with_capacity(n * 4);
            for _ in 0..n {
                pos.push((r.next_f32() - 0.5) * 200.0);
                pos.push((r.next_f32() - 0.5) * 200.0);
                pos.push((r.next_f32() - 0.5) * 200.0);
                pos.push(r.next_f32() * 10.0 + 1.0); // mass
            }
            for _ in 0..n {
                vel.push((r.next_f32() - 0.5) * 2.0);
                vel.push((r.next_f32() - 0.5) * 2.0);
                vel.push((r.next_f32() - 0.5) * 2.0);
                vel.push(0.0);
            }
            vec![HostBuf::F32(pos), HostBuf::F32(vel)]
        }
        _ => {
            // ray1/2/3: scene geometry — model.py's make_scene(which).
            let which = bench.scalars.get("scene").copied().unwrap_or(1.0) as u32;
            let ns = bench.scalars["nspheres"] as usize;
            let mut r = XorShift::new(100 + which as u64);
            let mut s = vec![0.0f32; ns * 8];
            // Ground-ish large sphere.
            s[..8].copy_from_slice(&[
                0.0,
                -103.0,
                10.0,
                100.0,
                0.6,
                0.6,
                0.6,
                0.05 * which as f32,
            ]);
            let spread = 14.0 / which as f32;
            for i in 1..ns {
                s[i * 8] = (r.next_f32() - 0.5) * spread;
                s[i * 8 + 1] = (r.next_f32() - 0.5) * spread * 0.5;
                s[i * 8 + 2] = 6.0 + r.next_f32() * 10.0 / which as f32;
                s[i * 8 + 3] = 0.6 + r.next_f32() * 1.2;
                s[i * 8 + 4] = r.next_f32() * 0.9 + 0.1;
                s[i * 8 + 5] = r.next_f32() * 0.9 + 0.1;
                s[i * 8 + 6] = r.next_f32() * 0.9 + 0.1;
                s[i * 8 + 7] = (r.next_f32() * 0.3 * which as f32).min(0.9);
            }
            vec![HostBuf::F32(s)]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest() -> &'static str {
        r#"{"version": 1, "benches": {"toy": {
            "n": 1024, "granule": 128, "irregular": false,
            "out_pattern": [1, 1], "kernel": "toy",
            "scalars": {"steps": 4.0},
            "inputs": [{"name": "x", "elems": 1024, "elems_per_item": 1, "file": "toy/in.f32"}],
            "outputs": [{"name": "y", "elems": 1024, "elems_per_item": 1, "file": "toy/out.f32"}],
            "chunks": [{"size": 128, "file": "toy/c128.hlo.txt"},
                       {"size": 256, "file": "toy/c256.hlo.txt"},
                       {"size": 1024, "file": "toy/c1024.hlo.txt"}]
        }}}"#
    }

    fn load_mini() -> ArtifactRegistry {
        let dir = std::env::temp_dir().join(format!("ecl_art_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), mini_manifest()).unwrap();
        ArtifactRegistry::load(&dir).unwrap()
    }

    #[test]
    fn parses_manifest() {
        let reg = load_mini();
        assert!(!reg.synthetic);
        let b = reg.bench("toy").unwrap();
        assert_eq!(b.n, 1024);
        assert_eq!(b.granule, 128);
        assert_eq!(b.out_pattern, (1, 1));
        assert_eq!(b.scalars["steps"], 4.0);
        assert_eq!(b.inputs.len(), 1);
        assert_eq!(b.chunks.len(), 3);
    }

    #[test]
    fn chunk_at_most_picks_floor() {
        let reg = load_mini();
        let b = reg.bench("toy").unwrap();
        assert_eq!(b.chunk_at_most(128), Some(128));
        assert_eq!(b.chunk_at_most(300), Some(256));
        assert_eq!(b.chunk_at_most(5000), Some(1024));
        assert_eq!(b.chunk_at_most(64), None);
    }

    #[test]
    fn unknown_bench_errors() {
        let reg = load_mini();
        assert!(reg.bench("nope").is_err());
    }

    #[test]
    fn synthetic_has_all_paper_benches() {
        let reg = ArtifactRegistry::synthetic();
        for name in
            ["gaussian", "binomial", "collatz", "mandelbrot", "nbody", "ray1", "ray2", "ray3"]
        {
            let b = reg.bench(name).unwrap();
            assert!(b.n % b.granule == 0, "{name}: n granule-aligned");
            assert!(b.chunks.contains_key(&b.granule), "{name}: granule chunk");
            assert!(b.chunks.contains_key(&b.n), "{name}: full-size chunk");
        }
    }

    #[test]
    fn synthetic_inputs_match_manifest_shapes() {
        let reg = ArtifactRegistry::synthetic();
        for b in reg.benches.values() {
            let ins = reg.golden_inputs(b).unwrap();
            assert_eq!(ins.len(), b.inputs.len(), "{}", b.name);
            for (spec, data) in b.inputs.iter().zip(&ins) {
                assert_eq!(data.len(), spec.elems, "{}.{}", b.name, spec.name);
            }
        }
    }

    #[test]
    fn synthetic_inputs_deterministic() {
        let reg = ArtifactRegistry::synthetic();
        let b = reg.bench("nbody").unwrap();
        let a = reg.golden_inputs(b).unwrap();
        let c = reg.golden_inputs(b).unwrap();
        assert_eq!(a, c);
    }

    #[test]
    fn ray_scenes_differ() {
        let reg = ArtifactRegistry::synthetic();
        let s1 = reg.golden_inputs(reg.bench("ray1").unwrap()).unwrap();
        let s3 = reg.golden_inputs(reg.bench("ray3").unwrap()).unwrap();
        assert_ne!(s1, s3);
    }
}
