//! Native (pure-Rust) implementations of the five paper benchmarks —
//! item-for-item ports of `python/compile/kernels/*.py` and the pure-jnp
//! oracles in `ref.py` — plus the synthetic `collatz` straggler kernel
//! (the heavy-tailed work-stealing workload; no Python counterpart).
//!
//! These serve two roles:
//!
//! 1. The compute backend of [`super::native::NativeExecutor`], used when
//!    the crate is built without the `pjrt` feature (the offline default).
//! 2. The oracle for synthetic golden outputs when no `artifacts/`
//!    directory exists (see [`super::ArtifactRegistry::synthetic`]).
//!
//! Every kernel is strictly per-item deterministic: the value of item `i`
//! depends only on the inputs and `i`, never on which chunk or device
//! computed it. That property is what makes co-execution bit-identical to
//! a single-device run — the correctness core the integration tests
//! assert.

use anyhow::{Context, Result};

use super::artifact::BenchManifest;

/// Compute work-items `[begin, end)` of `bench` into `chunk_outs` —
/// one mutable slice per output buffer, each of length
/// `(end - begin) * elems_per_item`, indexed relative to `begin`.
///
/// Slice-based so callers choose the destination: the executors hand in
/// windows of the run's output arena (kernels write straight into the
/// final buffers — no chunk-local scratch, no scatter copy), tests hand
/// in plain vectors.
pub fn compute_range(
    bench: &BenchManifest,
    inputs: &[&[f32]],
    begin: usize,
    end: usize,
    chunk_outs: &mut [&mut [f32]],
) -> Result<()> {
    anyhow::ensure!(end > begin && end <= bench.n, "bad range {begin}..{end}");
    let family = if bench.kernel.is_empty() { &bench.name } else { &bench.kernel };
    match family.as_str() {
        "binomial" => binomial(bench, inputs, begin, end, chunk_outs),
        "gaussian" => gaussian(bench, inputs, begin, end, chunk_outs),
        "collatz" => collatz(bench, begin, end, chunk_outs),
        "mandelbrot" => mandelbrot(bench, begin, end, chunk_outs),
        "nbody" => nbody(bench, inputs, begin, end, chunk_outs),
        f if f.starts_with("ray") => ray(bench, inputs, begin, end, chunk_outs),
        other => anyhow::bail!("no native kernel for '{other}'"),
    }
}

/// [`compute_range`] over `Vec`-backed storage — the convenience form
/// the synthetic golden-oracle generation and tests use.
pub fn compute_range_vecs(
    bench: &BenchManifest,
    inputs: &[Vec<f32>],
    begin: usize,
    end: usize,
    outs: &mut [Vec<f32>],
) -> Result<()> {
    let ins: Vec<&[f32]> = inputs.iter().map(|v| v.as_slice()).collect();
    let mut windows: Vec<&mut [f32]> = outs.iter_mut().map(|v| v.as_mut_slice()).collect();
    compute_range(bench, &ins, begin, end, &mut windows)
}

fn scalar(bench: &BenchManifest, key: &str) -> Result<f64> {
    bench
        .scalars
        .get(key)
        .copied()
        .with_context(|| format!("bench '{}' missing scalar '{key}'", bench.name))
}

// ---- binomial: European call on a `steps`-step lattice ----------------

fn binomial(
    bench: &BenchManifest,
    inputs: &[&[f32]],
    begin: usize,
    end: usize,
    outs: &mut [&mut [f32]],
) -> Result<()> {
    let steps = scalar(bench, "steps")? as usize;
    let prices = inputs.first().context("binomial needs a price input")?;
    let strike = 50.0f32;
    let dt = 1.0f32 / steps as f32;
    let vsdt = 0.30f32 * dt.sqrt(); // VOLATILITY
    let rdt = (0.02f32 * dt).exp(); // RISK_FREE
    let u = vsdt.exp();
    let d = 1.0 / u;
    let pu = (rdt - d) / (u - d);
    let pd = 1.0 - pu;
    let pu_by_r = pu / rdt;
    let pd_by_r = pd / rdt;

    let width = steps + 1;
    let mut v = vec![0.0f32; width];
    let out = &mut outs[0];
    for i in begin..end {
        let s = 10.0 + prices[i] * 90.0;
        for (j, vj) in v.iter_mut().enumerate() {
            let st = s * (vsdt * (2.0 * j as f32 - steps as f32)).exp();
            *vj = (st - strike).max(0.0);
        }
        // Backward induction, width shrinking each step (ref.py form).
        for w in (1..width).rev() {
            for j in 0..w {
                v[j] = pu_by_r * v[j + 1] + pd_by_r * v[j];
            }
        }
        out[i - begin] = v[0];
    }
    Ok(())
}

// ---- gaussian: separable K-tap clamped-border blur --------------------

fn gaussian(
    bench: &BenchManifest,
    inputs: &[&[f32]],
    begin: usize,
    end: usize,
    outs: &mut [&mut [f32]],
) -> Result<()> {
    let w = scalar(bench, "width")? as usize;
    let h = scalar(bench, "height")? as usize;
    let k = scalar(bench, "ksize")? as usize;
    let r = k / 2;
    let img = inputs.first().context("gaussian needs an image input")?;
    let filt = inputs.get(1).context("gaussian needs a filter input")?;
    anyhow::ensure!(img.len() == w * h, "image size mismatch");
    anyhow::ensure!(filt.len() == k, "filter size mismatch");

    // Row pass at clamped (y, x), then column pass at the output pixel —
    // the exact clamp-then-separate border semantics of the Pallas kernel.
    let row_pass = |y: usize, x: usize| -> f32 {
        let mut acc = 0.0f32;
        for dx in 0..k {
            let xi = (x + dx).saturating_sub(r).min(w - 1);
            acc += img[y * w + xi] * filt[dx];
        }
        acc
    };
    let out = &mut outs[0];
    for p in begin..end {
        let y = p / w;
        let x = p % w;
        let mut acc = 0.0f32;
        for dy in 0..k {
            let yi = (y + dy).saturating_sub(r).min(h - 1);
            acc += row_pass(yi, x) * filt[dy];
        }
        out[p - begin] = acc;
    }
    Ok(())
}

// ---- collatz: heavy-tailed trajectory lengths, seeded hotspot band ----

/// Collatz trajectory length of `n`, capped at `maxiter` steps.
fn collatz_len(mut n: u64, maxiter: u32) -> u32 {
    let mut it = 0u32;
    while n > 1 && it < maxiter {
        n = if n % 2 == 0 { n / 2 } else { 3 * n + 1 };
        it += 1;
    }
    it
}

/// Total Collatz steps executed for work-item `p` of `bench` — the exact
/// per-item cost [`compute_range`] pays on the `collatz` family, exported
/// so the straggler bench's virtual clock (`harness::steal`) charges the
/// same heavy tail the native kernel does.
///
/// Items whose index falls in the `[hot_lo, hot_hi)` fraction band of the
/// problem run `hot_rounds` seeded trajectories instead of one: a
/// contiguous straggler band, placed at the front of the index space in
/// the synthetic manifest — the region the cold-start prior assigns in
/// its largest, least-informed prefetch batches.
pub fn collatz_item_steps(bench: &BenchManifest, p: usize) -> Result<u32> {
    let seed = scalar(bench, "seed")? as u64;
    let maxiter = scalar(bench, "maxiter")? as u32;
    let hot_lo = scalar(bench, "hot_lo")?;
    let hot_hi = scalar(bench, "hot_hi")?;
    let frac = p as f64 / bench.n as f64;
    let rounds =
        if (hot_lo..hot_hi).contains(&frac) { scalar(bench, "hot_rounds")? as u32 } else { 1 };
    let mut acc = 0u32;
    for r in 0..rounds as u64 {
        // Mix index, seed and round into an odd 32-bit start value
        // (splitmix64-style finalizer): trajectories stay bounded and the
        // value of item `p` depends only on `p` and the manifest scalars.
        let mut x = (p as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(seed ^ r);
        x ^= x >> 31;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        acc += collatz_len((x & 0xFFFF_FFFF) | 1, maxiter);
    }
    Ok(acc)
}

fn collatz(
    bench: &BenchManifest,
    begin: usize,
    end: usize,
    outs: &mut [&mut [f32]],
) -> Result<()> {
    // Output = work done: step counts stay well under 2^24, so the f32
    // round-trip is exact and the oracle comparison stays bit-strict.
    let out = &mut outs[0];
    for p in begin..end {
        out[p - begin] = collatz_item_steps(bench, p)? as f32;
    }
    Ok(())
}

// ---- mandelbrot: escape iterations per pixel --------------------------

fn mandelbrot(
    bench: &BenchManifest,
    begin: usize,
    end: usize,
    outs: &mut [&mut [f32]],
) -> Result<()> {
    let w = scalar(bench, "width")? as usize;
    let h = scalar(bench, "height")? as usize;
    let maxiter = scalar(bench, "maxiter")? as u32;
    let x0 = scalar(bench, "x0")? as f32;
    let y0 = scalar(bench, "y0")? as f32;
    let x1 = scalar(bench, "x1")? as f32;
    let y1 = scalar(bench, "y1")? as f32;

    let out = &mut outs[0];
    for p in begin..end {
        let cre = x0 + (p % w) as f32 * ((x1 - x0) / w as f32);
        let cim = y0 + (p / w) as f32 * ((y1 - y0) / h as f32);
        let mut zre = 0.0f32;
        let mut zim = 0.0f32;
        let mut iters = maxiter as f32;
        for it in 0..maxiter {
            let nre = zre * zre - zim * zim + cre;
            let nim = 2.0 * zre * zim + cim;
            zre = nre;
            zim = nim;
            if zre * zre + zim * zim > 4.0 {
                iters = (it + 1) as f32;
                break;
            }
        }
        out[p - begin] = iters;
    }
    Ok(())
}

// ---- nbody: one leapfrog step of all-pairs gravity --------------------

fn nbody(
    bench: &BenchManifest,
    inputs: &[&[f32]],
    begin: usize,
    end: usize,
    outs: &mut [&mut [f32]],
) -> Result<()> {
    let dt = scalar(bench, "dt")? as f32;
    let eps2 = scalar(bench, "eps2")? as f32;
    let n = scalar(bench, "bodies")? as usize;
    let pos = inputs.first().context("nbody needs a position input")?;
    let vel = inputs.get(1).context("nbody needs a velocity input")?;
    anyhow::ensure!(pos.len() == n * 4 && vel.len() == n * 4, "nbody buffer size mismatch");

    let (opos, ovel) = {
        let (a, b) = outs.split_at_mut(1);
        (&mut a[0], &mut b[0])
    };
    for i in begin..end {
        let (pix, piy, piz) = (pos[i * 4], pos[i * 4 + 1], pos[i * 4 + 2]);
        let mut ax = 0.0f32;
        let mut ay = 0.0f32;
        let mut az = 0.0f32;
        for j in 0..n {
            let dx = pos[j * 4] - pix;
            let dy = pos[j * 4 + 1] - piy;
            let dz = pos[j * 4 + 2] - piz;
            let dist2 = dx * dx + dy * dy + dz * dz + eps2;
            let inv = 1.0 / dist2.sqrt();
            let inv3 = inv * inv * inv * pos[j * 4 + 3]; // * mass_j
            ax += dx * inv3;
            ay += dy * inv3;
            az += dz * inv3;
        }
        let nvx = vel[i * 4] + ax * dt;
        let nvy = vel[i * 4 + 1] + ay * dt;
        let nvz = vel[i * 4 + 2] + az * dt;
        let o = (i - begin) * 4;
        opos[o] = pix + nvx * dt;
        opos[o + 1] = piy + nvy * dt;
        opos[o + 2] = piz + nvz * dt;
        opos[o + 3] = pos[i * 4 + 3]; // mass carried through
        ovel[o] = nvx;
        ovel[o + 1] = nvy;
        ovel[o + 2] = nvz;
        ovel[o + 3] = vel[i * 4 + 3];
    }
    Ok(())
}

// ---- ray: sphere raytracer with reflective bounces --------------------

fn ray(
    bench: &BenchManifest,
    inputs: &[&[f32]],
    begin: usize,
    end: usize,
    outs: &mut [&mut [f32]],
) -> Result<()> {
    let w = scalar(bench, "width")? as usize;
    let h = scalar(bench, "height")? as usize;
    let ns = scalar(bench, "nspheres")? as usize;
    let maxbounce = scalar(bench, "maxbounce")? as u32;
    let spheres = inputs.first().context("ray needs a scene input")?;
    anyhow::ensure!(spheres.len() == ns * 8, "scene size mismatch");
    const AMBIENT: f32 = 0.1;
    const LIGHT: (f32, f32, f32) = (5.0, 5.0, -2.0);

    let out = &mut outs[0];
    for p in begin..end {
        let px = (p % w) as f32;
        let py = (p / w) as f32;
        // Camera ray: screen plane at z=1, fov ~90deg (kernel geometry).
        let mut dx = (px + 0.5) / w as f32 * 2.0 - 1.0;
        let mut dy = ((py + 0.5) / h as f32 * 2.0 - 1.0) * (h as f32 / w as f32);
        let mut dz = 1.0f32;
        let inv = 1.0 / (dx * dx + dy * dy + dz * dz).sqrt();
        dx *= inv;
        dy *= inv;
        dz *= inv;
        let (mut ox, mut oy, mut oz) = (0.0f32, 0.0f32, -4.0f32);
        let (mut cr, mut cg, mut cb) = (0.0f32, 0.0f32, 0.0f32);
        let mut att = 1.0f32;

        for _ in 0..maxbounce {
            // Nearest positive intersection over all spheres.
            let mut tmin = f32::INFINITY;
            let mut idx = 0usize;
            for s in 0..ns {
                let b = &spheres[s * 8..s * 8 + 8];
                let lx = b[0] - ox;
                let ly = b[1] - oy;
                let lz = b[2] - oz;
                let bb = lx * dx + ly * dy + lz * dz;
                let cc = lx * lx + ly * ly + lz * lz - b[3] * b[3];
                let disc = bb * bb - cc;
                if disc > 0.0 {
                    let sq = disc.sqrt();
                    let t0 = bb - sq;
                    let t = if t0 > 1e-3 { t0 } else { bb + sq };
                    if t > 1e-3 && t < tmin {
                        tmin = t;
                        idx = s;
                    }
                }
            }
            if !tmin.is_finite() {
                break; // missed everything
            }
            let b = &spheres[idx * 8..idx * 8 + 8];
            let hx = ox + dx * tmin;
            let hy = oy + dy * tmin;
            let hz = oz + dz * tmin;
            let nr = (hx - b[0]) / b[3];
            let ng = (hy - b[1]) / b[3];
            let nb = (hz - b[2]) / b[3];
            // Lambert shading toward the point light (no shadow rays —
            // same simplification as the Pallas kernel).
            let mut tlx = LIGHT.0 - hx;
            let mut tly = LIGHT.1 - hy;
            let mut tlz = LIGHT.2 - hz;
            let linv = 1.0 / (tlx * tlx + tly * tly + tlz * tlz).sqrt();
            tlx *= linv;
            tly *= linv;
            tlz *= linv;
            let lam = (nr * tlx + ng * tly + nb * tlz).max(0.0);
            let shade = AMBIENT + lam * (1.0 - AMBIENT);
            let refl = b[7];
            let contrib = att * (1.0 - refl);
            cr += contrib * b[4] * shade;
            cg += contrib * b[5] * shade;
            cb += contrib * b[6] * shade;
            if refl <= 0.01 {
                break; // diffuse hit terminates the path
            }
            let dn = dx * nr + dy * ng + dz * nb;
            dx -= 2.0 * dn * nr;
            dy -= 2.0 * dn * ng;
            dz -= 2.0 * dn * nb;
            ox = hx + nr * 1e-2;
            oy = hy + ng * 1e-2;
            oz = hz + nb * 1e-2;
            att *= refl;
        }
        let o = (p - begin) * 4;
        out[o] = cr.clamp(0.0, 1.0);
        out[o + 1] = cg.clamp(0.0, 1.0);
        out[o + 2] = cb.clamp(0.0, 1.0);
        out[o + 3] = 1.0;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ArtifactRegistry;

    fn chunk_outs(bench: &BenchManifest, items: usize) -> Vec<Vec<f32>> {
        bench.outputs.iter().map(|o| vec![0.0f32; items * o.elems_per_item]).collect()
    }

    fn full_inputs(reg: &ArtifactRegistry, bench: &BenchManifest) -> Vec<Vec<f32>> {
        reg.golden_inputs(bench)
            .unwrap()
            .into_iter()
            .map(|b| b.as_f32().unwrap().to_vec())
            .collect()
    }

    /// Per-item determinism: computing a sub-range must equal the matching
    /// slice of a full-range computation, bit for bit, for every bench.
    #[test]
    fn chunks_match_full_computation() {
        let reg = ArtifactRegistry::synthetic();
        for name in ["binomial", "collatz", "gaussian", "mandelbrot", "nbody", "ray1"] {
            let bench = reg.bench(name).unwrap().clone();
            let inputs = full_inputs(&reg, &bench);
            let mut full = chunk_outs(&bench, bench.n);
            compute_range_vecs(&bench, &inputs, 0, bench.n, &mut full).unwrap();

            let begin = bench.granule;
            let end = (3 * bench.granule).min(bench.n);
            let mut part = chunk_outs(&bench, end - begin);
            compute_range_vecs(&bench, &inputs, begin, end, &mut part).unwrap();
            for (spec, (fo, po)) in bench.outputs.iter().zip(full.iter().zip(&part)) {
                let lo = begin * spec.elems_per_item;
                let hi = end * spec.elems_per_item;
                assert_eq!(&fo[lo..hi], &po[..], "{name}: chunk differs from full run");
            }
        }
    }

    #[test]
    fn mandelbrot_interior_hits_maxiter() {
        let reg = ArtifactRegistry::synthetic();
        let bench = reg.bench("mandelbrot").unwrap().clone();
        let maxiter = bench.scalars["maxiter"] as f32;
        let mut outs = chunk_outs(&bench, bench.n);
        compute_range_vecs(&bench, &[], 0, bench.n, &mut outs).unwrap();
        let vals = &outs[0];
        assert!(vals.iter().any(|&v| v == maxiter), "some pixels in the set");
        assert!(vals.iter().any(|&v| v < maxiter), "some pixels escape");
        assert!(vals.iter().all(|&v| (1.0..=maxiter).contains(&v)));
    }

    /// The hotspot band must be a real straggler: items inside it cost a
    /// multiple of the cold mean, and the written output is the exact step
    /// count the cost helper reports (the bench sim's virtual clock and
    /// the native kernel must never drift apart).
    #[test]
    fn collatz_hotspot_is_heavy_tailed() {
        let reg = ArtifactRegistry::synthetic();
        let bench = reg.bench("collatz").unwrap().clone();
        let mut outs = chunk_outs(&bench, bench.n);
        compute_range_vecs(&bench, &[], 0, bench.n, &mut outs).unwrap();
        let vals = &outs[0];

        let (hot_lo, hot_hi) = (bench.scalars["hot_lo"], bench.scalars["hot_hi"]);
        let in_band = |p: usize| (hot_lo..hot_hi).contains(&(p as f64 / bench.n as f64));
        let mean = |band: bool| {
            let picked: Vec<f64> = (0..bench.n)
                .filter(|&p| in_band(p) == band)
                .map(|p| vals[p] as f64)
                .collect();
            assert!(!picked.is_empty(), "band(in={band}) non-empty");
            picked.iter().sum::<f64>() / picked.len() as f64
        };
        let (hot, cold) = (mean(true), mean(false));
        assert!(cold > 1.0, "cold items do real work (mean {cold})");
        assert!(hot >= 4.0 * cold, "hotspot {hot} not heavy vs cold {cold}");

        for p in [0, bench.n / 2, bench.n - 1] {
            let steps = collatz_item_steps(&bench, p).unwrap();
            assert_eq!(vals[p], steps as f32, "item {p}: output == cost helper");
        }
    }

    #[test]
    fn unknown_kernel_rejected() {
        let reg = ArtifactRegistry::synthetic();
        let mut bench = reg.bench("binomial").unwrap().clone();
        bench.kernel = "no-such-kernel".into();
        let mut outs = chunk_outs(&bench, bench.granule);
        assert!(compute_range_vecs(&bench, &[], 0, bench.granule, &mut outs).is_err());
    }
}
